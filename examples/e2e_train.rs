//! End-to-end driver (deliverable): the full three-layer system on a real
//! small workload — synthetic-fMoW imagery, the Planet-Labs-like 191-
//! satellite constellation, PJRT-executed local training and Pallas
//! aggregation — training for a few simulated days and logging the loss /
//! accuracy curve (recorded in EXPERIMENTS.md).
//!
//! Run: `make artifacts && cargo run --release --example e2e_train`
//!
//! Flags (all optional):
//!   --algorithm sync|async|fedbuff|fedspace   (fedspace)
//!   --dist iid|noniid                          (iid)
//!   --sats N      (48)     --steps N           (192 = 2 days)
//!   --size small|fmow (fmow)
//!   --target ACC  stop when reached            --out curve.csv
//!   --full        paper-scale: 191 sats, 480 steps, 19100 samples

use fedspace::app::{run_pjrt_experiment, Args};
use fedspace::cfg::{AlgorithmKind, DataDist, ExperimentConfig};
use fedspace::metrics::write_file;

fn main() -> anyhow::Result<()> {
    // Args' grammar is `<command> [options]`; examples have no subcommand.
    let args = Args::parse(
        std::iter::once("e2e_train".to_string()).chain(std::env::args().skip(1)),
    )?;
    let full = args.has_flag("full");
    let mut cfg = ExperimentConfig {
        algorithm: AlgorithmKind::FedSpace,
        n_sats: if full { 191 } else { 48 },
        n_steps: if full { 480 } else { 192 },
        n_train: if full { 19_100 } else { 4_800 },
        n_val: if full { 2_048 } else { 512 },
        fedbuff_m: if full { 96 } else { 24 },
        eval_every: 8,
        ..Default::default()
    };
    if let Some(a) = args.get("algorithm") {
        cfg.algorithm = AlgorithmKind::parse(a)?;
    }
    if let Some(d) = args.get("dist") {
        cfg.dist = DataDist::parse(d)?;
    }
    cfg.n_sats = args.get_usize("sats", cfg.n_sats)?;
    cfg.n_steps = args.get_usize("steps", cfg.n_steps)?;
    // buffer threshold scales with the fleet (paper: M = 96 at K = 191)
    cfg.fedbuff_m = args.get_usize("fedbuff-m", (cfg.n_sats / 2).max(1))?;
    if let Some(s) = args.get("size") {
        cfg.model_size = s.to_string();
    }
    let stop_at = args.get("target").map(|t| t.parse::<f64>()).transpose()?;
    let eval_samples = args.get_usize("eval-samples", if full { 1024 } else { 512 })?;

    println!(
        "e2e: {} / {:?} | {} satellites, {} steps ({:.1} simulated days), model={}",
        cfg.algorithm.name(),
        cfg.dist,
        cfg.n_sats,
        cfg.n_steps,
        cfg.n_steps as f64 * cfg.days_per_step(),
        cfg.model_size,
    );
    let t0 = std::time::Instant::now();
    let out = run_pjrt_experiment(&cfg, eval_samples, stop_at)?;
    let r = &out.result;
    println!("\nday     step  round   acc     loss");
    for p in &r.trace.curve.points {
        println!(
            "{:<7.3} {:<5} {:<6} {:<7.4} {:<7.4}",
            p.day, p.step, p.round, p.accuracy, p.loss
        );
    }
    println!(
        "\nrounds={} uploads={} idle={} ({:.1}%) best_acc={:.4} wall={:.1}s",
        r.final_round,
        r.trace.uploads,
        r.trace.idle,
        100.0 * r.trace.idle_fraction(),
        r.trace.curve.best_accuracy(),
        t0.elapsed().as_secs_f64(),
    );
    println!(
        "time breakdown: local-train {:.1}s | aggregate {:.1}s | eval {:.1}s",
        r.trace.t_train_s, r.trace.t_agg_s, r.trace.t_eval_s
    );
    if let Some(t) = stop_at {
        match r.days_to_target {
            Some(d) => println!("reached {:.0}% after {:.2} simulated days", t * 100.0, d),
            None => println!("did not reach {:.0}%", t * 100.0),
        }
    }
    let path = args.get_or(
        "out",
        &format!("results/e2e_{}_{:?}.csv", out.algorithm.name(), out.dist),
    );
    write_file(&path, &r.trace.curve.to_csv())?;
    println!("curve written to {path}");
    Ok(())
}
