//! Quickstart: the paper in 30 seconds, no artifacts needed.
//!
//! 1. Reproduces Table 1 (the 3-satellite illustrative example).
//! 2. Computes a day of real constellation connectivity (Figure 2 stats).
//! 3. Runs a fast mock FL experiment with each aggregation policy.
//!
//! Run: `cargo run --release --example quickstart`

use fedspace::app::run_mock_experiment;
use fedspace::cfg::{AlgorithmKind, ExperimentConfig};
use fedspace::connectivity::ConnectivityStats;
use fedspace::fl::illustrative;
use fedspace::metrics::Table;

fn main() -> anyhow::Result<()> {
    // --- Table 1 -----------------------------------------------------
    println!("== Table 1: illustrative example (3 satellites, 9 slots) ==");
    let mut t = Table::new(&["scheme", "updates", "s=0", "s=1", "s=2", "s=5", "total", "idle"]);
    for r in illustrative::table1() {
        t.row(&[
            r.scheme.to_string(),
            r.global_updates.to_string(),
            r.staleness.count(0).to_string(),
            r.staleness.count(1).to_string(),
            r.staleness.count(2).to_string(),
            r.staleness.count(5).to_string(),
            r.total_aggregated.to_string(),
            r.idle.to_string(),
        ]);
    }
    println!("{}", t.render());

    // --- Figure 2 stats ----------------------------------------------
    println!("== Figure 2: connectivity of 191 satellites / 12 stations ==");
    let cfg = ExperimentConfig { n_steps: 96, ..Default::default() };
    let (_, sched) = fedspace::app::build_schedule(&cfg);
    let stats = ConnectivityStats::from_schedule(&sched);
    println!(
        "|C_i| over one day: min={} max={}  (paper: 4 / 68)",
        stats.min_set, stats.max_set
    );
    println!("mean contacts per satellite per day: {:.1}\n", stats.mean_contacts);

    // --- mock FL run per algorithm ------------------------------------
    println!("== mock FL (20 satellites, 1 simulated day) ==");
    let mut t = Table::new(&["scheme", "rounds", "idle%", "max staleness", "best acc"]);
    for alg in [
        AlgorithmKind::Sync,
        AlgorithmKind::Async,
        AlgorithmKind::FedBuff,
        AlgorithmKind::FedSpace,
    ] {
        let cfg = ExperimentConfig {
            algorithm: alg,
            n_sats: 20,
            n_steps: 96,
            fedbuff_m: 8,
            n_search: 200,
            utility_samples: 100,
            i0: 24,
            n_min: 2,
            n_max: 8,
            ..Default::default()
        };
        let out = run_mock_experiment(&cfg, None)?;
        let r = &out.result;
        t.row(&[
            alg.name().to_string(),
            r.final_round.to_string(),
            format!("{:.0}%", 100.0 * r.trace.idle_fraction()),
            r.trace.staleness.max_key().unwrap_or(0).to_string(),
            format!("{:.3}", r.trace.curve.best_accuracy()),
        ]);
    }
    println!("{}", t.render());
    println!(
        "next: `cargo run --release --example e2e_train` for the full\nthree-layer PJRT training run (requires `make artifacts`)."
    );
    Ok(())
}
