//! Scheduler ablation: the design choices DESIGN.md §5 calls out, measured
//! on the fast analytic mock so the whole study runs in seconds.
//!
//!   1. utility regressor: random forest (paper) vs linear baseline
//!   2. window objective: chained-T (ours) vs frozen-T (paper Eq. 13)
//!   3. search budget |R|
//!
//! Run: `cargo run --release --example scheduler_ablation`

use fedspace::connectivity::ConnectivitySchedule;
use fedspace::metrics::Table;
use fedspace::orbit::{planet_ground_stations, planet_labs_like};
use fedspace::rng::Rng;
use fedspace::sched::{
    generate_samples, pretrain_bank, schedule_utility_opts, MockBackend,
    SatForecastState, SearchParams, UtilityModel,
};
use fedspace::ml::{mse, LinearRegression, Regressor};

fn schedule(n_sats: usize) -> ConnectivitySchedule {
    let c = planet_labs_like(n_sats, 0);
    ConnectivitySchedule::compute(&c, &planet_ground_stations(), 96, Default::default())
}

fn main() -> anyhow::Result<()> {
    let backend = MockBackend::new(32, 0);
    let mut rng = Rng::new(1);
    let bank = pretrain_bank(&backend, 20, 8, 0.5, &mut rng)?;
    let (inputs, targets) = generate_samples(&backend, &bank, 600, 8, 16, 0.5, &mut rng)?;
    let split = 480;

    // --- 1. regressor comparison --------------------------------------
    println!("== utility regressor (held-out MSE over 120 samples) ==");
    let mut t = Table::new(&["regressor", "test MSE"]);
    for kind in ["forest", "linear"] {
        let mut u = UtilityModel::new(kind)?;
        u.fit(&inputs[..split].to_vec(), &targets[..split]);
        let err: f64 = inputs[split..]
            .iter()
            .zip(&targets[split..])
            .map(|((s, ts), y)| {
                let p = u.predict(s, *ts);
                (p - y) * (p - y)
            })
            .sum::<f64>()
            / (inputs.len() - split) as f64;
        t.row(&[kind.to_string(), format!("{err:.6}")]);
    }
    // context: variance of targets
    let mean = targets.iter().sum::<f64>() / targets.len() as f64;
    let var = targets.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / targets.len() as f64;
    t.row(&["(target variance)".to_string(), format!("{var:.6}")]);
    println!("{}", t.render());

    // --- 2. chained vs frozen T ---------------------------------------
    println!("== window objective: chained-T vs frozen-T (Eq. 13) ==");
    let sched = schedule(48);
    let mut u = UtilityModel::new("forest")?;
    u.fit(&inputs, &targets);
    let states = vec![SatForecastState::fresh(); 48];
    let t_status = bank.losses[2];
    let mut t = Table::new(&["objective", "best n_agg", "predicted utility"]);
    for (name, chain) in [("chained-T", true), ("frozen-T", false)] {
        // scan candidate counts, measure where the objective peaks
        let mut best = (0usize, f64::NEG_INFINITY);
        let mut srng = Rng::new(7);
        for n in 1..=24 {
            let mut acc = 0.0;
            for _ in 0..8 {
                let mut cand = vec![false; 24];
                for p in srng.choose_k(24, n) {
                    cand[p] = true;
                }
                acc += schedule_utility_opts(&sched, 0, &cand, &states, &u, t_status, chain);
            }
            let avg = acc / 8.0;
            if avg > best.1 {
                best = (n, avg);
            }
        }
        t.row(&[name.to_string(), best.0.to_string(), format!("{:.4}", best.1)]);
    }
    println!("{}", t.render());
    println!(
        "(frozen-T inflates with aggregation count; chained-T saturates — see DESIGN.md §5)\n"
    );

    // --- 3. |R| sweep ---------------------------------------------------
    println!("== random-search budget |R| ==");
    let mut t = Table::new(&["|R|", "best predicted utility", "ms"]);
    for n_search in [50usize, 500, 5000] {
        let params = SearchParams { i0: 24, n_min: 4, n_max: 8, n_search };
        let mut srng = Rng::new(9);
        let t0 = std::time::Instant::now();
        let (_, util) = fedspace::sched::random_search(
            &sched, 0, &states, &u, t_status, &params, &mut srng,
        );
        t.row(&[
            n_search.to_string(),
            format!("{util:.4}"),
            format!("{:.1}", t0.elapsed().as_secs_f64() * 1e3),
        ]);
    }
    println!("{}", t.render());

    // --- 4. forest helps over always-aggregate heuristic ----------------
    println!("== fitted û vs cold-start heuristic on sample prediction ==");
    let mut lin = LinearRegression::new(1e-6);
    let x: Vec<Vec<f64>> =
        inputs.iter().map(|(s, ts)| fedspace::sched::featurize(s, *ts)).collect();
    lin.fit(&x[..split].to_vec(), &targets[..split]);
    println!(
        "linear test MSE (direct featurized fit): {:.6}\n",
        mse(&lin, &x[split..].to_vec(), &targets[split..])
    );
    Ok(())
}
