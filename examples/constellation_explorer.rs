//! Constellation explorer: the orbital-mechanics substrate as a tool.
//!
//! Sweeps constellation sizes / elevation masks and prints the Figure-2
//! style connectivity statistics plus per-station contact loads — the kind
//! of capacity-planning analysis a ground-segment operator would run.
//!
//! Run: `cargo run --release --example constellation_explorer`

use fedspace::connectivity::{ConnectivityParams, ConnectivitySchedule, ConnectivityStats};
use fedspace::metrics::Table;
use fedspace::orbit::{is_visible, planet_ground_stations, planet_labs_like};

fn main() -> anyhow::Result<()> {
    let stations = planet_ground_stations();

    println!("== fleet-size sweep (one day, T0 = 15 min, alpha_min = 10 deg) ==");
    let mut t = Table::new(&["sats", "min |C_i|", "max |C_i|", "mean n_k", "min n_k", "max n_k"]);
    for n in [24usize, 96, 191] {
        let c = planet_labs_like(n, 0);
        let s = ConnectivitySchedule::compute(&c, &stations, 96, ConnectivityParams::default());
        let st = ConnectivityStats::from_schedule(&s);
        t.row(&[
            n.to_string(),
            st.min_set.to_string(),
            st.max_set.to_string(),
            format!("{:.1}", st.mean_contacts),
            st.contacts_per_sat.iter().min().unwrap().to_string(),
            st.contacts_per_sat.iter().max().unwrap().to_string(),
        ]);
    }
    println!("{}", t.render());

    println!("== elevation-mask sweep (191 satellites) ==");
    let c = planet_labs_like(191, 0);
    let mut t = Table::new(&["alpha_min", "mean n_k", "max |C_i|"]);
    for elev in [5.0, 10.0, 20.0, 30.0] {
        let s = ConnectivitySchedule::compute(
            &c,
            &stations,
            96,
            ConnectivityParams { min_elev_deg: elev, ..Default::default() },
        );
        let st = ConnectivityStats::from_schedule(&s);
        t.row(&[
            format!("{elev:.0} deg"),
            format!("{:.1}", st.mean_contacts),
            st.max_set.to_string(),
        ]);
    }
    println!("{}", t.render());

    println!("== per-station visibility load (191 satellites, one day) ==");
    let mut t = Table::new(&["station", "lat", "sat-minutes/day"]);
    for gs in &stations {
        let mut minutes = 0usize;
        for orbit in &c.orbits {
            for m in 0..(24 * 60) {
                let time = m as f64 * 60.0;
                let p = orbit.position_eci(time);
                if is_visible(&p, time, gs, 10.0) {
                    minutes += 1;
                }
            }
        }
        t.row(&[
            gs.name.clone(),
            format!("{:+.1}", gs.lat_deg),
            minutes.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("note: polar stations dominate — SSO satellites see them every orbit,");
    println!("which is exactly the Figure-2(b) contact-count heterogeneity.");
    Ok(())
}
