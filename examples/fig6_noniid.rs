// NonIID half of the Figure 6 / Table 2 PJRT record (restartable).
use fedspace::app::run_pjrt_experiment;
use fedspace::cfg::{AlgorithmKind, DataDist, ExperimentConfig};
use fedspace::metrics::write_file;
fn main() -> anyhow::Result<()> {
    for alg in [
        AlgorithmKind::Sync,
        AlgorithmKind::Async,
        AlgorithmKind::FedBuff,
        AlgorithmKind::FedSpace,
    ] {
        let cfg = ExperimentConfig {
            algorithm: alg,
            dist: DataDist::NonIid,
            n_sats: 48,
            n_steps: 192,
            n_train: 4_800,
            n_val: 512,
            fedbuff_m: 24,
            i0: 24,
            n_min: 1,
            n_max: 6,
            n_search: 1000,
            utility_samples: 150,
            eval_every: 8,
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let out = run_pjrt_experiment(&cfg, 512, None)?;
        let r = &out.result;
        println!(
            "{:>9}: best_acc={:.3} rounds={} idle={:.0}% days_to_40={} ({:.1}s wall)",
            alg.name(),
            r.trace.curve.best_accuracy(),
            r.final_round,
            100.0 * r.trace.idle_fraction(),
            r.trace.curve.days_to_accuracy(0.40).map_or("-".into(), |d| format!("{d:.2}")),
            t0.elapsed().as_secs_f64(),
        );
        write_file(&format!("results/fig6_{}_NonIid.csv", alg.name()), &r.trace.curve.to_csv())?;
    }
    Ok(())
}
