//! Tour of the scenario registry: list the zoo, then run a scaled-down
//! copy of each built-in and compare the algorithm grids side by side.
//!
//! Run: `cargo run --release --example scenario_zoo`
//!
//! Full-size runs are one command each, e.g.
//! `cargo run --release -- scenarios run walker-starlink-1584`.

use fedspace::app::run_scenario;
use fedspace::cfg::Scenario;
use fedspace::metrics::Table;

fn main() -> anyhow::Result<()> {
    println!("== the constellation zoo ==");
    for sc in Scenario::builtins() {
        println!("  {:<22} {}", sc.name, sc.summary);
    }

    println!("\n== scaled-down grid runs (24 satellites, 1 simulated day) ==");
    let mut t = Table::new(&["scenario", "algorithm", "rounds", "idle%", "best acc"]);
    for sc in Scenario::builtins() {
        let sc = sc.scaled(Some(24), Some(96));
        for out in run_scenario(&sc, None)? {
            let r = &out.result;
            t.row(&[
                sc.name.clone(),
                out.algorithm.name().to_string(),
                r.final_round.to_string(),
                format!("{:.1}", 100.0 * r.trace.idle_fraction()),
                format!("{:.4}", r.trace.curve.best_accuracy()),
            ]);
        }
    }
    println!("{}", t.render());
    println!("(scenario TOMLs: `fedspace scenarios describe <name>`)");
    Ok(())
}
