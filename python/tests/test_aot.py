# pytest: AOT lowering — HLO text artifacts are produced and well formed.
from __future__ import annotations

import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def out_dir(tmp_path_factory):
    d = tmp_path_factory.mktemp("artifacts")
    aot.lower_size("small", str(d), aot.LOWER_PARAMS["small"])
    return str(d)


ARTIFACTS = [
    "local_train_small.hlo.txt",
    "grad_eval_small.hlo.txt",
    "eval_step_small.hlo.txt",
    "aggregate_chunk_small.hlo.txt",
]


@pytest.mark.parametrize("name", ARTIFACTS)
def test_artifact_exists_and_is_hlo_text(out_dir, name):
    path = os.path.join(out_dir, name)
    assert os.path.exists(path)
    text = open(path).read()
    assert "ENTRY" in text and "HloModule" in text
    # 64-bit-id proto escape hatch must NOT be used: this is plain text.
    assert len(text) > 200


def test_meta_contents(out_dir):
    meta = dict(
        line.split("=", 1)
        for line in open(os.path.join(out_dir, "meta_small.txt"))
        if "=" in line.strip()
    )
    assert int(meta["d"]) == model.d_model("small")
    assert int(meta["num_classes"]) == model.NUM_CLASSES
    assert int(meta["img_dim"]) == model.IMG_DIM
    assert int(meta["e_steps"]) == aot.LOWER_PARAMS["small"]["e_steps"]
    assert "param_shapes" in meta


def test_local_train_entry_signature(out_dir):
    text = open(os.path.join(out_dir, "local_train_small.hlo.txt")).read()
    d = model.d_model("small")
    # flat parameter vector appears as an f32[d] parameter
    assert f"f32[{d}]" in text
