# pytest: kernel vs ref allclose — the CORE correctness signal.
# Hypothesis sweeps shapes/dtypes of the Pallas kernels against the pure-jnp
# oracles in compile/kernels/ref.py.
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import matmul, stale_aggregate
from compile.kernels.matmul import _matmul_impl
from compile.kernels.ref import matmul_ref, stale_aggregate_ref

DIMS = st.integers(min_value=1, max_value=96)


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, dtype=jnp.float32).astype(dtype)


def _tols(dtype):
    return {"rtol": 2e-2, "atol": 2e-2} if dtype == jnp.bfloat16 else {
        "rtol": 1e-5,
        "atol": 1e-5,
    }


class TestMatmul:
    @settings(max_examples=25, deadline=None)
    @given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref_f32(self, m, k, n, seed):
        kx, ky = jax.random.split(jax.random.PRNGKey(seed))
        x, y = _rand(kx, (m, k), jnp.float32), _rand(ky, (k, n), jnp.float32)
        np.testing.assert_allclose(
            matmul(x, y), matmul_ref(x, y), **_tols(jnp.float32)
        )

    @settings(max_examples=10, deadline=None)
    @given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref_bf16(self, m, k, n, seed):
        kx, ky = jax.random.split(jax.random.PRNGKey(seed))
        x, y = _rand(kx, (m, k), jnp.bfloat16), _rand(ky, (k, n), jnp.bfloat16)
        got = matmul(x, y).astype(jnp.float32)
        want = matmul_ref(x, y).astype(jnp.float32)
        np.testing.assert_allclose(got, want, **_tols(jnp.bfloat16))

    @pytest.mark.parametrize(
        "m,k,n", [(1, 1, 1), (128, 128, 128), (129, 130, 131), (7, 256, 3)]
    )
    def test_edge_shapes(self, m, k, n):
        kx, ky = jax.random.split(jax.random.PRNGKey(0))
        x, y = _rand(kx, (m, k), jnp.float32), _rand(ky, (k, n), jnp.float32)
        np.testing.assert_allclose(
            matmul(x, y), matmul_ref(x, y), rtol=1e-5, atol=1e-5
        )

    def test_multi_k_tile_accumulation(self):
        # K spans several grid steps -> exercises the VMEM accumulator path.
        kx, ky = jax.random.split(jax.random.PRNGKey(1))
        x, y = _rand(kx, (64, 512), jnp.float32), _rand(ky, (512, 64), jnp.float32)
        np.testing.assert_allclose(
            _matmul_impl(x, y, bm=32, bn=32, bk=64),
            matmul_ref(x, y),
            rtol=1e-4,
            atol=1e-4,
        )

    def test_gradients_match_jnp(self):
        # custom_vjp backward (itself Pallas) vs plain-jnp autodiff.
        kx, ky = jax.random.split(jax.random.PRNGKey(2))
        x, y = _rand(kx, (9, 17), jnp.float32), _rand(ky, (17, 5), jnp.float32)

        def f_pallas(x, y):
            return (matmul(x, y) ** 2).sum()

        def f_ref(x, y):
            return (jnp.matmul(x, y) ** 2).sum()

        gx, gy = jax.grad(f_pallas, argnums=(0, 1))(x, y)
        rx, ry = jax.grad(f_ref, argnums=(0, 1))(x, y)
        np.testing.assert_allclose(gx, rx, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(gy, ry, rtol=1e-4, atol=1e-4)

    def test_jit_compatible(self):
        kx, ky = jax.random.split(jax.random.PRNGKey(3))
        x, y = _rand(kx, (33, 20), jnp.float32), _rand(ky, (20, 11), jnp.float32)
        np.testing.assert_allclose(
            jax.jit(matmul)(x, y), matmul_ref(x, y), rtol=1e-5, atol=1e-5
        )


class TestStaleAggregate:
    @settings(max_examples=25, deadline=None)
    @given(
        d=st.integers(1, 3000),
        ch=st.integers(1, 16),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, d, ch, seed):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        w = _rand(k1, (d,), jnp.float32)
        g = _rand(k2, (ch, d), jnp.float32)
        wt = jax.random.uniform(k3, (ch,), dtype=jnp.float32)
        np.testing.assert_allclose(
            stale_aggregate(w, g, wt),
            stale_aggregate_ref(w, g, wt),
            rtol=1e-5,
            atol=1e-5,
        )

    def test_zero_weights_are_identity(self):
        # Empty buffer slots carry weight 0 and must not perturb w.
        k1, k2 = jax.random.split(jax.random.PRNGKey(7))
        w = _rand(k1, (513,), jnp.float32)
        g = _rand(k2, (8, 513), jnp.float32)
        wt = jnp.zeros((8,), jnp.float32)
        np.testing.assert_allclose(stale_aggregate(w, g, wt), w)

    def test_partial_mask(self):
        # Half-full chunk: masked rows contribute nothing.
        k1, k2 = jax.random.split(jax.random.PRNGKey(8))
        w = _rand(k1, (100,), jnp.float32)
        g = _rand(k2, (4, 100), jnp.float32)
        wt = jnp.array([0.5, 0.5, 0.0, 0.0], jnp.float32)
        want = w + 0.5 * g[0] + 0.5 * g[1]
        np.testing.assert_allclose(
            stale_aggregate(w, g, wt), want, rtol=1e-5, atol=1e-5
        )

    def test_weights_normalized_sum(self):
        # Eq. (4): weights c(s)/C sum to 1 -> aggregating identical gradients
        # equals adding that gradient once.
        k1, k2 = jax.random.split(jax.random.PRNGKey(9))
        w = _rand(k1, (257,), jnp.float32)
        g_row = _rand(k2, (257,), jnp.float32)
        g = jnp.tile(g_row[None, :], (8, 1))
        wt = jnp.full((8,), 1.0 / 8.0, jnp.float32)
        np.testing.assert_allclose(
            stale_aggregate(w, g, wt), w + g_row, rtol=1e-5, atol=1e-5
        )

    def test_large_d_multiple_blocks(self):
        # d > DEFAULT_BD exercises the grid over model-dimension tiles.
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(10), 3)
        d = 4096 * 2 + 37
        w = _rand(k1, (d,), jnp.float32)
        g = _rand(k2, (8, d), jnp.float32)
        wt = jax.random.uniform(k3, (8,), dtype=jnp.float32)
        np.testing.assert_allclose(
            stale_aggregate(w, g, wt),
            stale_aggregate_ref(w, g, wt),
            rtol=1e-5,
            atol=1e-5,
        )
