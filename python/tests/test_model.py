# pytest: L2 model — shapes, gradients, training dynamics, export surface.
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

SIZE = "small"
D = model.d_model(SIZE)


def _params(seed=0, scale=0.05):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), (D,))


def _batch(b=8, seed=1):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (b, model.IMG_DIM))
    y = jax.random.randint(ky, (b,), 0, model.NUM_CLASSES).astype(jnp.float32)
    return x, y


class TestStructure:
    def test_d_model_matches_shapes(self):
        want = sum(int(np.prod(s)) for _, s in model.param_shapes(SIZE))
        assert D == want

    def test_unflatten_roundtrip(self):
        w = jnp.arange(D, dtype=jnp.float32)
        parts = model.unflatten(w, SIZE)
        flat = jnp.concatenate([parts[n].reshape(-1) for n, _ in model.param_shapes(SIZE)])
        np.testing.assert_array_equal(flat, w)

    def test_frozen_matrix_deterministic(self):
        a = model.frozen_features_matrix(SIZE)
        b = model.frozen_features_matrix(SIZE)
        np.testing.assert_array_equal(a, b)
        assert a.shape == (model.PATCH_DIM, model.SIZES[SIZE]["feat"])

    def test_forward_shape(self):
        x, _ = _batch(5)
        logits = model.forward(_params(), x, SIZE)
        assert logits.shape == (5, model.NUM_CLASSES)

    def test_patchify_preserves_content(self):
        x = jnp.arange(2 * model.IMG_DIM, dtype=jnp.float32).reshape(2, -1)
        p = model._patchify(x)
        assert p.shape == (2 * model.N_PATCH, model.PATCH_DIM)
        # first patch of first image = top-left 4x4 block, all channels
        img = x[0].reshape(model.IMG_H, model.IMG_W, model.IMG_C)
        want = img[:4, :4, :].reshape(-1)
        np.testing.assert_array_equal(p[0], want)


class TestLoss:
    def test_loss_finite_positive(self):
        x, y = _batch()
        loss = model.loss_fn(_params(), x, y, SIZE)
        assert np.isfinite(loss) and loss > 0

    def test_uniform_logits_loss_is_log_c(self):
        x, y = _batch()
        loss = model.loss_fn(jnp.zeros((D,)), x, y, SIZE)
        np.testing.assert_allclose(loss, np.log(model.NUM_CLASSES), rtol=1e-5)

    def test_grad_matches_finite_difference(self):
        x, y = _batch(4)
        w = _params()
        g = jax.grad(functools.partial(model.loss_fn, size=SIZE))(w, x, y)
        rng = np.random.RandomState(0)
        idx = rng.choice(D, size=5, replace=False)
        eps = 1e-3
        for i in idx:
            e = jnp.zeros((D,)).at[i].set(eps)
            fd = (
                model.loss_fn(w + e, x, y, SIZE) - model.loss_fn(w - e, x, y, SIZE)
            ) / (2 * eps)
            np.testing.assert_allclose(g[i], fd, rtol=5e-2, atol=5e-4)


class TestLocalTrain:
    def test_delta_matches_manual_loop(self):
        w = _params()
        e, b, lr = 3, 4, 0.1
        kx, ky = jax.random.split(jax.random.PRNGKey(5))
        xs = jax.random.normal(kx, (e, b, model.IMG_DIM))
        ys = jax.random.randint(ky, (e, b), 0, model.NUM_CLASSES).astype(jnp.float32)
        delta, mean_loss = model.local_train(w, xs, ys, jnp.float32(lr), size=SIZE)
        wc, losses = w, []
        gfn = jax.value_and_grad(functools.partial(model.loss_fn, size=SIZE))
        for i in range(e):
            l, g = gfn(wc, xs[i], ys[i])
            losses.append(l)
            wc = wc - lr * g
        np.testing.assert_allclose(delta, wc - w, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(mean_loss, np.mean(losses), rtol=1e-5)

    def test_training_reduces_loss(self):
        # A few local rounds on a fixed batch must reduce the loss.
        w = _params()
        x, y = _batch(8, seed=3)
        xs, ys = jnp.tile(x[None], (4, 1, 1)), jnp.tile(y[None], (4, 1))
        l0 = model.loss_fn(w, x, y, SIZE)
        delta, _ = model.local_train(w, xs, ys, jnp.float32(0.5), size=SIZE)
        l1 = model.loss_fn(w + delta, x, y, SIZE)
        assert l1 < l0

    def test_zero_lr_zero_delta(self):
        w = _params()
        x, y = _batch()
        xs, ys = x[None], y[None]
        delta, _ = model.local_train(w, xs, ys, jnp.float32(0.0), size=SIZE)
        np.testing.assert_allclose(delta, jnp.zeros_like(w), atol=1e-7)


class TestGradEval:
    def test_matches_value_and_grad(self):
        w = _params()
        x, y = _batch()
        g, loss = model.grad_eval(w, x, y, size=SIZE)
        l2, g2 = jax.value_and_grad(functools.partial(model.loss_fn, size=SIZE))(
            w, x, y
        )
        np.testing.assert_allclose(loss, l2, rtol=1e-6)
        np.testing.assert_allclose(g, g2, rtol=1e-6, atol=1e-7)


class TestEvalStep:
    def test_counts_and_loss(self):
        w = _params()
        x, y = _batch(16, seed=11)
        loss_sum, correct = model.eval_step(w, x, y, size=SIZE)
        logits = model.forward(w, x, SIZE)
        want_correct = (jnp.argmax(logits, -1) == y.astype(jnp.int32)).sum()
        assert int(correct) == int(want_correct)
        per = model.loss_fn(w, x, y, SIZE) * 16
        np.testing.assert_allclose(loss_sum, per, rtol=1e-5)

    def test_perfect_and_zero_accuracy_bounds(self):
        w = _params()
        x, y = _batch(16, seed=12)
        _, correct = model.eval_step(w, x, y, size=SIZE)
        assert 0 <= int(correct) <= 16


class TestAggregateChunk:
    def test_matches_eq4(self):
        w = _params()
        k1, k2 = jax.random.split(jax.random.PRNGKey(13))
        g = 0.01 * jax.random.normal(k1, (8, D))
        s = jnp.array([0, 1, 2, 3, 0, 0, 0, 0], jnp.float32)
        alpha = 0.5
        c = (s[:4] + 1) ** (-alpha)
        wt = jnp.concatenate([c / c.sum(), jnp.zeros(4)])
        got = model.aggregate_chunk(w, g, wt)
        want = w + (wt[:, None] * g).sum(0)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
