"""Layer-2: the satellite workload as JAX fwd/bwd, calling the L1 kernels.

The paper trains DenseNet-161 (ImageNet-pretrained, lower 3 dense blocks
frozen, BN->GN) on fMoW (62 classes).  Substitution (DESIGN.md §3): a frozen
random patch-embedding feature extractor + a trainable 2-layer dense head.
Only the trainable subspace matters to the staleness/idleness dynamics the
paper studies, and the frozen-bottom / trainable-top structure mirrors the
paper's transfer-learning setup exactly.

All dense products run through ``kernels.matmul`` (the Pallas kernel), so the
whole fwd/bwd lowers through Layer 1.  Parameters travel as one flat f32
vector so the Rust coordinator is ``Vec<f32>`` end to end.

Exported functions (lowered by aot.py):
  local_train(w, xs[E,B,...], ys[E,B], lr) -> (delta, mean_loss)   Eq. (3)
  grad_eval(w, x[B,...], y[B])             -> (grad, loss)          Eq. (12)
  eval_step(w, x[B,...], y[B])             -> (loss_sum, n_correct)
  aggregate_chunk(w, G[CH,d], wt[CH])      -> w'                    Eq. (4)
"""

from __future__ import annotations

import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import matmul, stale_aggregate

# ---------------------------------------------------------------------------
# Task constants (synthetic fMoW substitute — must match rust/src/data/).
# ---------------------------------------------------------------------------

IMG_H, IMG_W, IMG_C = 32, 32, 3
IMG_DIM = IMG_H * IMG_W * IMG_C  # 3072
PATCH = 4
N_PATCH = (IMG_H // PATCH) * (IMG_W // PATCH)  # 64
PATCH_DIM = PATCH * PATCH * IMG_C  # 48
NUM_CLASSES = 62
FROZEN_SEED = 1234  # bakes the frozen extractor deterministically into HLO

# Model sizes: `small` for CI/unit tests, `fmow` for the paper's experiments.
SIZES: Dict[str, Dict[str, int]] = {
    "small": {"feat": 64, "hidden": 64},
    "fmow": {"feat": 512, "hidden": 1024},
}


def param_shapes(size: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """Trainable parameter layout (order defines the flat vector)."""
    f, h = SIZES[size]["feat"], SIZES[size]["hidden"]
    return [
        ("w1", (f, h)),
        ("b1", (h,)),
        ("w2", (h, NUM_CLASSES)),
        ("b2", (NUM_CLASSES,)),
    ]


def d_model(size: str) -> int:
    """Flat trainable-parameter dimension d."""
    return sum(int(np.prod(s)) for _, s in param_shapes(size))


def frozen_features_matrix(size: str) -> np.ndarray:
    """The frozen patch-embedding W_p [PATCH_DIM, feat], He-init, fixed seed.

    Baked into the HLO as a constant — the satellite never trains it,
    mirroring the paper's frozen DenseNet blocks.
    """
    f = SIZES[size]["feat"]
    rng = np.random.RandomState(FROZEN_SEED)
    scale = np.sqrt(2.0 / PATCH_DIM)
    return (rng.randn(PATCH_DIM, f) * scale).astype(np.float32)


def unflatten(w: jax.Array, size: str) -> Dict[str, jax.Array]:
    """Split the flat vector into named parameter tensors (static slices)."""
    out, off = {}, 0
    for name, shape in param_shapes(size):
        n = int(np.prod(shape))
        out[name] = w[off : off + n].reshape(shape)
        off += n
    return out


def _patchify(x: jax.Array) -> jax.Array:
    """[B, IMG_DIM] -> [B * N_PATCH, PATCH_DIM] non-overlapping patches."""
    b = x.shape[0]
    x = x.reshape(b, IMG_H // PATCH, PATCH, IMG_W // PATCH, PATCH, IMG_C)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b * N_PATCH, PATCH_DIM)


def forward(w: jax.Array, x: jax.Array, size: str) -> jax.Array:
    """Logits [B, NUM_CLASSES] from flat params and flat images [B, IMG_DIM]."""
    b = x.shape[0]
    p = unflatten(w, size)
    wp = jnp.asarray(frozen_features_matrix(size))
    # Frozen extractor: patch embedding -> ReLU -> mean-pool over patches.
    feats = jax.nn.relu(matmul(_patchify(x), wp))
    feats = feats.reshape(b, N_PATCH, -1).mean(axis=1)
    # Trainable head (the paper's unfrozen top).
    h = jax.nn.relu(matmul(feats, p["w1"]) + p["b1"])
    return matmul(h, p["w2"]) + p["b2"]


def loss_fn(w: jax.Array, x: jax.Array, y: jax.Array, size: str) -> jax.Array:
    """Mean softmax cross-entropy. ``y`` is f32 class ids (cast inside)."""
    logits = forward(w, x, size)
    labels = y.astype(jnp.int32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()


# ---------------------------------------------------------------------------
# Exported entry points
# ---------------------------------------------------------------------------


def local_train(w, xs, ys, lr, *, size: str):
    """E local SGD steps (Eq. 3) via lax.scan; returns (delta, mean_loss).

    xs: [E, B, IMG_DIM] f32, ys: [E, B] f32 class ids, lr: scalar f32.
    delta = w_E - w_0 is the paper's local update g_k.
    """
    vg = jax.value_and_grad(functools.partial(loss_fn, size=size))

    def step(wc, xy):
        x, y = xy
        loss, g = vg(wc, x, y)
        return wc - lr * g, loss

    w_end, losses = jax.lax.scan(step, w, (xs, ys))
    return w_end - w, losses.mean()


def grad_eval(w, x, y, *, size: str):
    """Single-batch (gradient, loss) — utility-sample generation (Eq. 12)."""
    vg = jax.value_and_grad(functools.partial(loss_fn, size=size))
    loss, g = vg(w, x, y)
    return g, loss


def eval_step(w, x, y, *, size: str):
    """(sum of per-sample CE loss, #correct) over one validation batch."""
    logits = forward(w, x, size)
    labels = y.astype(jnp.int32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss_sum = -jnp.take_along_axis(logp, labels[:, None], axis=1).sum()
    correct = (jnp.argmax(logits, axis=-1) == labels).sum().astype(jnp.float32)
    return loss_sum, correct


def aggregate_chunk(w, grads, weights):
    """GS-side Eq. (4) over one buffer chunk, via the Pallas kernel."""
    return stale_aggregate(w, grads, weights)
