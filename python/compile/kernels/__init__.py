# L1: Pallas kernels for the paper's compute hot-spots.
from .aggregate import DEFAULT_CHUNK, stale_aggregate  # noqa: F401
from .matmul import matmul  # noqa: F401
