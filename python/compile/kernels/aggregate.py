"""Layer-1 Pallas kernel: staleness-weighted gradient aggregation (Eq. 4).

Computes ``w' = w + sum_c wt[c] * G[c, :]`` over a chunk of ``CH`` buffered
gradients.  The staleness-compensation weights ``wt[c] = c_alpha(s_c)/C``
(and zeros for empty slots) are computed by the Rust coordinator; the kernel
is a pure weighted accumulation so a single lowered artifact serves every
buffer size by streaming the buffer in chunks.

TPU mapping: bandwidth-bound — the grid tiles the model dimension ``d`` into
``bd``-sized blocks, so each (w-block, CH gradient rows, weights) tile makes
exactly one HBM->VMEM trip.  Arithmetic intensity ~2 FLOP/byte puts the
roofline at HBM bandwidth; the BlockSpec reads each byte once.
``interpret=True`` as everywhere (CPU-PJRT image).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Gradients per chunk: the Rust GS streams its buffer CH rows at a time.
DEFAULT_CHUNK = 16
# Model-dimension tile. The kernel is tiled for generality; the tile size
# is a *target* knob:
#   - TPU deployment: bd = 4096..32768 keeps (CH+2)*bd*4B inside VMEM with
#     double-buffering headroom (DESIGN.md §Hardware-Adaptation).
#   - CPU-PJRT AOT (this image): the old XLA lowers the Pallas grid to a
#     while-loop whose per-step dynamic-update-slice copies dominate; one
#     grid step (bd >= d) is 5.1x faster (577ms -> 113ms per 16-gradient
#     chunk at d=589k — EXPERIMENTS.md §Perf), so the build default covers
#     any d <= 2^21 in a single step.
DEFAULT_BD = 1 << 21


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _aggregate_kernel(w_ref, g_ref, wt_ref, o_ref):
    # w_ref: (bd,), g_ref: (CH, bd), wt_ref: (CH,), o_ref: (bd,)
    o_ref[...] = w_ref[...] + jnp.sum(
        g_ref[...] * wt_ref[...][:, None], axis=0
    )


def stale_aggregate(
    w: jax.Array, grads: jax.Array, weights: jax.Array, bd: int = DEFAULT_BD
) -> jax.Array:
    """``w + weights @ grads`` via the Pallas chunk kernel.

    Args:
      w: flat model/parameter vector, shape ``(d,)`` f32.
      grads: chunk of buffered gradients, shape ``(CH, d)`` f32.
      weights: staleness-compensation weights, shape ``(CH,)`` f32 (zero for
        empty slots).
    """
    (d,) = w.shape
    ch, d2 = grads.shape
    assert d == d2, (w.shape, grads.shape)
    bd = min(bd, _round_up(d, 8))
    dp = _round_up(d, bd)
    wp = jnp.pad(w, (0, dp - d))
    gp = jnp.pad(grads, ((0, 0), (0, dp - d)))
    out = pl.pallas_call(
        _aggregate_kernel,
        grid=(dp // bd,),
        in_specs=[
            pl.BlockSpec((bd,), lambda i: (i,)),
            pl.BlockSpec((ch, bd), lambda i: (0, i)),
            pl.BlockSpec((ch,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), w.dtype),
        interpret=True,
    )(wp, gp, weights)
    return out[:d]
