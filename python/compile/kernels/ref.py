"""Pure-jnp oracles for the Layer-1 Pallas kernels.

These are the correctness ground truth: python/tests/ sweeps shapes and
dtypes with hypothesis and asserts the Pallas kernels match these to
tolerance.  Nothing here is ever lowered into the shipped artifacts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    """Reference for kernels.matmul: plain jnp matmul with f32 accumulate."""
    return jnp.matmul(x, y, preferred_element_type=jnp.float32).astype(x.dtype)


def stale_aggregate_ref(
    w: jax.Array, grads: jax.Array, weights: jax.Array
) -> jax.Array:
    """Reference for kernels.stale_aggregate: ``w + weights @ grads``."""
    return w + jnp.einsum("c,cd->d", weights, grads)
