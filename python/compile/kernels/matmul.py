"""Layer-1 Pallas kernel: tiled matmul — the FLOP hot-spot of local training.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid iterates over
(M/bm, N/bn, K/bk) tiles; each step keeps an (bm, bk) x-tile, a (bk, bn)
y-tile and an f32 (bm, bn) accumulator in VMEM, feeding the MXU systolic
array. ``interpret=True`` is mandatory on this CPU-PJRT image — real TPU
lowering emits a Mosaic custom-call the CPU plugin cannot execute.

The public entry point :func:`matmul` pads arbitrary shapes up to tile
multiples, invokes the kernel and slices the result back.  It carries a
``jax.custom_vjp`` whose backward pass reuses the same kernel
(dx = g @ y^T, dy = x^T @ g) so the whole fwd/bwd graph of the model runs
through Pallas.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default MXU-shaped tiles. ~(3 * 128*128 * 4B) = 192 KiB of the ~16 MiB
# VMEM per step, leaving headroom for double buffering.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, nk: int):
    """Grid point (i, j, k): accumulate x[i,k] @ y[k,j] into the VMEM acc."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _matmul_padded(x: jax.Array, y: jax.Array, bm: int, bn: int, bk: int):
    """Pallas call on tile-aligned operands."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    nm, nn, nk = m // bm, n // bn, k // bk
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(x, y)


def _matmul_impl(
    x: jax.Array,
    y: jax.Array,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
) -> jax.Array:
    """Pad to tile multiples, run the kernel, slice back."""
    m, k = x.shape
    _, n = y.shape
    bm = min(bm, _round_up(m, 8))
    bn = min(bn, _round_up(n, 8))
    bk = min(bk, _round_up(k, 8))
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))
    out = _matmul_padded(xp, yp, bm, bn, bk)
    return out[:m, :n]


@jax.custom_vjp
def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """``x @ y`` through the Pallas tiled kernel, differentiable."""
    return _matmul_impl(x, y)


def _matmul_fwd(x, y):
    return _matmul_impl(x, y), (x, y)


def _matmul_bwd(res, g):
    x, y = res
    # Both cotangents are themselves Pallas matmuls.
    return _matmul_impl(g, y.T), _matmul_impl(x.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)
