"""AOT compile path: lower the L2 model to HLO *text* artifacts.

Interchange format is HLO text, NOT ``lowered.compile().serialize()`` and NOT
a serialized HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction
ids which the xla crate's runtime (xla_extension 0.5.1) rejects
(``proto.id() <= INT_MAX``).  The HLO text parser on the Rust side reassigns
ids, so text round-trips cleanly (see /opt/xla-example/README.md).

Usage (from python/):  python -m compile.aot --out-dir ../artifacts
Python runs ONCE at build time; the Rust binary is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.aggregate import DEFAULT_CHUNK

# Baked per-size lowering parameters (must match rust cfg defaults).
LOWER_PARAMS = {
    "small": {"e_steps": 2, "batch": 8, "eval_batch": 16},
    "fmow": {"e_steps": 4, "batch": 32, "eval_batch": 64},
}


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default printer elides
    # big literals as `constant({...})`, which the 0.5.1 HLO parser silently
    # zero-fills — the frozen feature extractor would train-time vanish.
    text = comp.as_hlo_text(print_large_constants=True)
    assert "{...}" not in text, "elided constant survived printing"
    return text


def _write(path: str, text: str) -> None:
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)} chars)")


def lower_size(size: str, out_dir: str, params: dict) -> None:
    d = model.d_model(size)
    e, b, eb = params["e_steps"], params["batch"], params["eval_batch"]
    ch = DEFAULT_CHUNK
    f32 = jnp.float32
    w_s = jax.ShapeDtypeStruct((d,), f32)

    print(f"[aot] size={size} d={d} E={e} B={b} eval_B={eb} CH={ch}")

    # local_train: (w, xs[E,B,IMG_DIM], ys[E,B], lr) -> (delta, mean_loss)
    fn = functools.partial(model.local_train, size=size)
    lowered = jax.jit(fn).lower(
        w_s,
        jax.ShapeDtypeStruct((e, b, model.IMG_DIM), f32),
        jax.ShapeDtypeStruct((e, b), f32),
        jax.ShapeDtypeStruct((), f32),
    )
    _write(os.path.join(out_dir, f"local_train_{size}.hlo.txt"), to_hlo_text(lowered))

    # grad_eval: (w, x[B,IMG_DIM], y[B]) -> (grad, loss)
    fn = functools.partial(model.grad_eval, size=size)
    lowered = jax.jit(fn).lower(
        w_s,
        jax.ShapeDtypeStruct((b, model.IMG_DIM), f32),
        jax.ShapeDtypeStruct((b,), f32),
    )
    _write(os.path.join(out_dir, f"grad_eval_{size}.hlo.txt"), to_hlo_text(lowered))

    # eval_step: (w, x[EB,IMG_DIM], y[EB]) -> (loss_sum, n_correct)
    fn = functools.partial(model.eval_step, size=size)
    lowered = jax.jit(fn).lower(
        w_s,
        jax.ShapeDtypeStruct((eb, model.IMG_DIM), f32),
        jax.ShapeDtypeStruct((eb,), f32),
    )
    _write(os.path.join(out_dir, f"eval_step_{size}.hlo.txt"), to_hlo_text(lowered))

    # aggregate_chunk: (w, G[CH,d], wt[CH]) -> w'
    lowered = jax.jit(model.aggregate_chunk).lower(
        w_s,
        jax.ShapeDtypeStruct((ch, d), f32),
        jax.ShapeDtypeStruct((ch,), f32),
    )
    _write(
        os.path.join(out_dir, f"aggregate_chunk_{size}.hlo.txt"), to_hlo_text(lowered)
    )

    # Metadata consumed by rust/src/runtime/artifact.rs (key=value lines).
    shapes = ";".join(
        f"{name}:{','.join(str(x) for x in shape)}"
        for name, shape in model.param_shapes(size)
    )
    meta = "\n".join(
        [
            f"size={size}",
            f"d={d}",
            f"img_dim={model.IMG_DIM}",
            f"num_classes={model.NUM_CLASSES}",
            f"e_steps={e}",
            f"batch={b}",
            f"eval_batch={eb}",
            f"chunk={ch}",
            f"feat={model.SIZES[size]['feat']}",
            f"hidden={model.SIZES[size]['hidden']}",
            f"param_shapes={shapes}",
        ]
    )
    _write(os.path.join(out_dir, f"meta_{size}.txt"), meta + "\n")


def emit_golden(size: str, out_dir: str, params: dict) -> None:
    """Golden cross-layer fixtures: inputs + python-computed outputs that
    the Rust integration tests replay through the compiled artifacts.

    This guards the whole interchange (printer, parser, old-XLA execution):
    the elided-constant bug this repo hit would have been caught here.
    """
    import numpy as np

    gdir = os.path.join(out_dir, f"golden_{size}")
    os.makedirs(gdir, exist_ok=True)
    d = model.d_model(size)
    e, b, eb = params["e_steps"], params["batch"], params["eval_batch"]
    rng = np.random.RandomState(42)
    w = (0.05 * rng.randn(d)).astype(np.float32)
    xs = rng.randn(e, b, model.IMG_DIM).astype(np.float32)
    ys = rng.randint(0, model.NUM_CLASSES, (e, b)).astype(np.float32)
    xe = rng.randn(eb, model.IMG_DIM).astype(np.float32)
    ye = rng.randint(0, model.NUM_CLASSES, (eb,)).astype(np.float32)
    lr = np.float32(0.5)

    delta, tloss = model.local_train(jnp.array(w), jnp.array(xs), jnp.array(ys), lr, size=size)
    grad, gloss = model.grad_eval(jnp.array(w), jnp.array(xs[0]), jnp.array(ys[0]), size=size)
    lsum, ncorr = model.eval_step(jnp.array(w), jnp.array(xe), jnp.array(ye), size=size)

    def dump(name, arr):
        np.asarray(arr, dtype=np.float32).tofile(os.path.join(gdir, name + ".bin"))

    dump("w", w)
    dump("xs", xs)
    dump("ys", ys)
    dump("xe", xe)
    dump("ye", ye)
    dump("delta", delta)
    dump("grad", grad)
    scalars = (
        f"lr={float(lr)}\ntrain_loss={float(tloss)}\ngrad_loss={float(gloss)}\n"
        f"eval_loss_sum={float(lsum)}\neval_correct={float(ncorr)}\n"
    )
    with open(os.path.join(gdir, "scalars.txt"), "w") as f:
        f.write(scalars)
    print(f"  wrote {gdir}/ (golden fixtures)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sizes", default="small,fmow")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for size in args.sizes.split(","):
        lower_size(size, args.out_dir, LOWER_PARAMS[size])
    emit_golden("small", args.out_dir, LOWER_PARAMS["small"])
    print("[aot] done")


if __name__ == "__main__":
    main()
