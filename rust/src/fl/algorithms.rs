//! Aggregation-indicator policies a^i (paper §2.4 Eqs. 5–7, §3 FedSpace).

use super::buffer::Buffer;

/// Decides a^i ∈ {0, 1} at each time index (Algorithm 1's SCHEDULER).
pub trait AggregationPolicy: Send {
    /// `i` — time index; `connected` — C_i; `buffer` — B_i (already holding
    /// this slot's uploads). Returns true to aggregate now.
    fn decide(&mut self, i: usize, connected: &[usize], buffer: &Buffer) -> bool;

    /// Short lowercase policy name (matches `AlgorithmKind::name`).
    fn name(&self) -> &'static str;
}

/// Synchronous FL (Eq. 5): wait for every satellite's gradient.
pub struct SyncPolicy {
    /// Number of satellites that must contribute before aggregating
    /// (satellites that can never contribute are excluded by the engine).
    pub n_sats: usize,
}

impl AggregationPolicy for SyncPolicy {
    fn decide(&mut self, _i: usize, _connected: &[usize], buffer: &Buffer) -> bool {
        buffer.n_sats() >= self.n_sats
    }

    fn name(&self) -> &'static str {
        "sync"
    }
}

/// Asynchronous FL (Eq. 6): aggregate whenever any gradient arrived.
pub struct AsyncPolicy;

impl AggregationPolicy for AsyncPolicy {
    fn decide(&mut self, _i: usize, _connected: &[usize], buffer: &Buffer) -> bool {
        !buffer.is_empty()
    }

    fn name(&self) -> &'static str {
        "async"
    }
}

/// FedBuff (Eq. 7, Nguyen et al. 2021): aggregate when |R_i| ≥ M.
pub struct FedBuffPolicy {
    /// M — distinct contributing satellites required to trigger aggregation.
    pub m: usize,
}

impl AggregationPolicy for FedBuffPolicy {
    fn decide(&mut self, _i: usize, _connected: &[usize], buffer: &Buffer) -> bool {
        buffer.n_sats() >= self.m
    }

    fn name(&self) -> &'static str {
        "fedbuff"
    }
}

/// FedSpace: consume a precomputed aggregation vector a^{i,i+I0} (Eq. 8).
///
/// The schedule itself is produced by `sched::planner` every I0 slots; this
/// policy only plays it back, skipping aggregation when the buffer is empty
/// (aggregating nothing is a no-op that would still burn a round index).
pub struct ScheduledPolicy {
    /// absolute time index → a^i; extended window-by-window by the planner
    schedule: Vec<bool>,
}

impl ScheduledPolicy {
    /// An empty policy (no windows committed yet).
    pub fn new() -> Self {
        ScheduledPolicy { schedule: Vec::new() }
    }

    /// Append the next window's schedule (called by the planner at window
    /// boundaries). `window` holds a^l for l ∈ [schedule.len(), ..).
    pub fn extend(&mut self, window: &[bool]) {
        self.schedule.extend_from_slice(window);
    }

    /// How many slots are scheduled so far.
    pub fn horizon(&self) -> usize {
        self.schedule.len()
    }

    /// First slot `>= from` with a planned aggregation, if any lies within
    /// the committed horizon — the contact-list engine mode uses this to
    /// jump straight to the next slot where `decide` could fire without a
    /// contact having occurred.
    pub fn next_scheduled(&self, from: usize) -> Option<usize> {
        let from = from.min(self.schedule.len());
        self.schedule[from..].iter().position(|&a| a).map(|p| from + p)
    }
}

impl Default for ScheduledPolicy {
    fn default() -> Self {
        Self::new()
    }
}

impl AggregationPolicy for ScheduledPolicy {
    fn decide(&mut self, i: usize, _connected: &[usize], buffer: &Buffer) -> bool {
        let planned = self.schedule.get(i).copied().unwrap_or(false);
        planned && !buffer.is_empty()
    }

    fn name(&self) -> &'static str {
        "fedspace"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::buffer::GradientEntry;

    fn buffer_with(sats: &[usize]) -> Buffer {
        let mut b = Buffer::new();
        for &s in sats {
            b.push(GradientEntry { sat: s, staleness: 0, grad: Vec::new().into(), n_samples: 1 });
        }
        b
    }

    #[test]
    fn sync_waits_for_all() {
        let mut p = SyncPolicy { n_sats: 3 };
        assert!(!p.decide(0, &[], &buffer_with(&[0, 1])));
        assert!(p.decide(0, &[], &buffer_with(&[0, 1, 2])));
    }

    #[test]
    fn async_fires_on_any() {
        let mut p = AsyncPolicy;
        assert!(!p.decide(0, &[], &Buffer::new()));
        assert!(p.decide(0, &[], &buffer_with(&[5])));
    }

    #[test]
    fn fedbuff_threshold_distinct_sats() {
        let mut p = FedBuffPolicy { m: 2 };
        assert!(!p.decide(0, &[], &buffer_with(&[1])));
        // same satellite twice still counts once
        assert!(!p.decide(0, &[], &buffer_with(&[1, 1])));
        assert!(p.decide(0, &[], &buffer_with(&[1, 2])));
    }

    #[test]
    fn scheduled_plays_back_and_skips_empty() {
        let mut p = ScheduledPolicy::new();
        p.extend(&[false, true, true]);
        assert_eq!(p.horizon(), 3);
        assert!(!p.decide(0, &[], &buffer_with(&[0])));
        assert!(p.decide(1, &[], &buffer_with(&[0])));
        // planned but empty buffer -> no-op
        assert!(!p.decide(2, &[], &Buffer::new()));
        // beyond horizon -> false
        assert!(!p.decide(7, &[], &buffer_with(&[0])));
    }

    #[test]
    fn next_scheduled_scans_forward_within_horizon() {
        let mut p = ScheduledPolicy::new();
        p.extend(&[false, true, false, true]);
        assert_eq!(p.next_scheduled(0), Some(1));
        assert_eq!(p.next_scheduled(1), Some(1));
        assert_eq!(p.next_scheduled(2), Some(3));
        assert_eq!(p.next_scheduled(4), None);
        assert_eq!(p.next_scheduled(100), None);
        assert_eq!(ScheduledPolicy::new().next_scheduled(0), None);
    }
}
