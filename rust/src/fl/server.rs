//! Ground-station (GS) state and the Eq. (4) model update.

use super::buffer::{Buffer, GradientEntry};
use super::codec::Update;
use super::staleness::normalized_weights;
use anyhow::Result;

/// Applies Eq. (4): w' = w + Σ_k (c(s_k)/C)·g_k over the drained buffer.
///
/// Two implementations: [`CpuAggregator`] (pure Rust hot loop, used by mock
/// experiments and as the correctness oracle) and `runtime::PjrtAggregator`
/// (streams chunks through the Pallas `stale_aggregate` artifact — the
/// shipped hot path). Not `Send`: PJRT handles live on the coordinator
/// thread.
pub trait ServerAggregator {
    /// Apply Eq. (4) in place: `w += Σ_k (c(s_k)/C)·g_k` over `entries`.
    fn aggregate(&mut self, w: &mut Vec<f32>, entries: &[GradientEntry], alpha: f64)
        -> Result<()>;
}

/// Reference aggregation in Rust: exact Eq. (4) with f32 accumulate.
///
/// The accumulate is blocked: the model vector is walked in cache-sized
/// blocks with the entry loop inside, so `w` streams through DRAM once per
/// aggregation instead of once per buffered gradient (entries stream once
/// either way). Per element the adds happen in entry order — identical
/// floating-point results to the naive per-entry loop, just ~`entries`×
/// less write-back traffic on `w`. The dimension check is hoisted out of
/// the hot loop entirely.
///
/// Sparse entries (top-k wire form, ADR-0008) take a dedicated arm inside
/// the same blocked walk: a per-entry cursor advances through the ascending
/// index list, touching only the `nnz` stored coordinates — never
/// densifying. Because each coordinate still receives its adds in entry
/// order, a sparse accumulate is bit-identical to densify-then-aggregate
/// (the oracle the tests assert against).
pub struct CpuAggregator;

/// Elements per block of the blocked accumulate: 16 KiB of f32 — a few
/// gradients' worth of block fits L1/L2 alongside the streamed entries.
const AGG_BLOCK: usize = 4096;

impl ServerAggregator for CpuAggregator {
    fn aggregate(
        &mut self,
        w: &mut Vec<f32>,
        entries: &[GradientEntry],
        alpha: f64,
    ) -> Result<()> {
        if entries.is_empty() {
            return Ok(());
        }
        let stalenesses: Vec<usize> = entries.iter().map(|e| e.staleness).collect();
        let weights = normalized_weights(&stalenesses, alpha);
        for entry in entries {
            anyhow::ensure!(
                entry.grad.len() == w.len(),
                "gradient/model dim mismatch: {} vs {}",
                entry.grad.len(),
                w.len()
            );
        }
        let d = w.len();
        // per-entry cursor into each sparse entry's ascending index list
        let mut pos = vec![0usize; entries.len()];
        let mut lo = 0usize;
        while lo < d {
            let hi = (lo + AGG_BLOCK).min(d);
            let wb = &mut w[lo..hi];
            for (ei, (entry, &wt)) in entries.iter().zip(weights.iter()).enumerate() {
                match &entry.grad {
                    Update::Dense(g) => {
                        for (wi, gi) in wb.iter_mut().zip(g[lo..hi].iter()) {
                            *wi += wt * gi;
                        }
                    }
                    Update::Sparse { idx, val, .. } => {
                        let p = &mut pos[ei];
                        while *p < idx.len() && (idx[*p] as usize) < hi {
                            wb[idx[*p] as usize - lo] += wt * val[*p];
                            *p += 1;
                        }
                    }
                }
            }
            lo = hi;
        }
        Ok(())
    }
}

/// Weighted element-wise model merge: `out[e] = Σ_g wt_g · w_g[e]`,
/// accumulated **in input order** — the deterministic cross-gateway
/// reconcile primitive of [`crate::fl::Federation`] (ADR-0006; callers pass
/// gateways in index order so replays are bit-identical). A single model
/// with weight 1.0 comes back bit-for-bit unchanged (`0.0 + 1.0·x = x`
/// exactly in f32), which is what makes single-gateway `Periodic`
/// reconciliation trace-identical to `Centralized`.
///
/// An all-zero weight vector (every replica idle over the merge window —
/// e.g. a reconcile cadence landing on an all-downtime window) would
/// otherwise zero the model; the guard returns the first replica unchanged
/// instead, so an idle reconcile is a no-op rather than a reset.
pub fn weighted_model_merge(models: &[(&[f32], f32)], d: usize) -> Vec<f32> {
    if !models.is_empty() && models.iter().all(|(_, wt)| *wt == 0.0) {
        assert_eq!(models[0].0.len(), d, "merge dim mismatch");
        return models[0].0.to_vec();
    }
    let mut out = vec![0.0f32; d];
    for (w, wt) in models {
        assert_eq!(w.len(), d, "merge dim mismatch");
        for (o, x) in out.iter_mut().zip(w.iter()) {
            *o += wt * x;
        }
    }
    out
}

/// GS state of Algorithm 1: current global model w^i, round index i_g, the
/// buffer B_i, and the running trace the figures need — the single-server
/// building block [`crate::fl::Federation`] generalizes to many gateways.
pub struct GsState {
    /// Current global model w^i.
    pub w: Vec<f32>,
    /// Global round index i_g.
    pub i_g: usize,
    /// The gradient buffer B_i.
    pub buffer: Buffer,
    /// Staleness-compensation exponent α (Eq. 4).
    pub alpha: f64,
    /// total gradients ever aggregated (Table 1 "total")
    pub n_aggregated: usize,
}

impl GsState {
    /// Fresh GS state around an initial model.
    pub fn new(w: Vec<f32>, alpha: f64) -> Self {
        GsState { w, i_g: 0, buffer: Buffer::new(), alpha, n_aggregated: 0 }
    }

    /// Receive (g_k, i_{g,k}) from satellite k: staleness fixed now. The
    /// update arrives in whatever wire form the codec produced (a plain
    /// `Vec<f32>` converts implicitly).
    pub fn receive(
        &mut self,
        sat: usize,
        grad: impl Into<Update>,
        base_round: usize,
        n_samples: usize,
    ) {
        assert!(base_round <= self.i_g, "satellite from the future");
        self.buffer.push(GradientEntry {
            sat,
            staleness: self.i_g - base_round,
            grad: grad.into(),
            n_samples,
        });
    }

    /// SERVERUPDATE (Eq. 4): drain buffer, update w, bump i_g.
    /// Returns the aggregated entries' stalenesses (for the Figure 7 trace).
    ///
    /// The buffer is drained only after aggregation succeeds — on an
    /// aggregator error (e.g. a dimension mismatch) the buffered gradients
    /// survive and neither i_g nor n_aggregated advances, so a caller that
    /// recovers from the error hasn't silently lost the round's uploads.
    pub fn update(&mut self, aggregator: &mut dyn ServerAggregator) -> Result<Vec<usize>> {
        let stalenesses = self.buffer.stalenesses();
        aggregator.aggregate(&mut self.w, self.buffer.entries(), self.alpha)?;
        let n = self.buffer.drain().len();
        self.i_g += 1;
        self.n_aggregated += n;
        Ok(stalenesses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_aggregator_matches_manual_eq4() {
        let mut w = vec![1.0f32, 2.0, 3.0];
        let entries = vec![
            GradientEntry { sat: 0, staleness: 0, grad: vec![1.0, 0.0, 0.0].into(), n_samples: 1 },
            GradientEntry { sat: 1, staleness: 1, grad: vec![0.0, 2.0, 0.0].into(), n_samples: 1 },
        ];
        let alpha = 0.5;
        let c0 = 1.0f64;
        let c1 = 2.0f64.powf(-0.5);
        let total = c0 + c1;
        CpuAggregator.aggregate(&mut w, &entries, alpha).unwrap();
        let want = [
            1.0 + (c0 / total) as f32,
            2.0 + 2.0 * (c1 / total) as f32,
            3.0,
        ];
        for (g, e) in w.iter().zip(want.iter()) {
            assert!((g - e).abs() < 1e-6, "{w:?} vs {want:?}");
        }
    }

    #[test]
    fn blocked_aggregate_matches_naive_reference() {
        // multi-block model dim (not a multiple of the block) + uneven
        // entry count: the blocked loop must equal the per-entry loop
        // bit-for-bit, since per element the adds happen in entry order
        let mut rng = crate::rng::Rng::new(9);
        let d = 3 * super::AGG_BLOCK + 17;
        let mut w: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut w_ref = w.clone();
        let entries: Vec<GradientEntry> = (0..5)
            .map(|sat| GradientEntry {
                sat,
                staleness: sat % 3,
                grad: (0..d).map(|_| rng.normal_f32(0.0, 0.1)).collect::<Vec<f32>>().into(),
                n_samples: 1,
            })
            .collect();
        let alpha = 0.5;
        CpuAggregator.aggregate(&mut w, &entries, alpha).unwrap();
        // naive reference: entry-major, whole-vector passes
        let st: Vec<usize> = entries.iter().map(|e| e.staleness).collect();
        let weights = crate::fl::staleness::normalized_weights(&st, alpha);
        for (entry, &wt) in entries.iter().zip(weights.iter()) {
            for (wi, gi) in w_ref.iter_mut().zip(entry.grad.values().iter()) {
                *wi += wt * gi;
            }
        }
        assert_eq!(w, w_ref);
    }

    #[test]
    fn sparse_accumulate_matches_densify_then_aggregate_bitwise() {
        // the sparse-vs-dense oracle (ADR-0008): mixed dense + sparse
        // entries through the blocked loop must equal the same entries
        // densified first, to the bit — per coordinate the adds happen in
        // entry order either way
        let mut rng = crate::rng::Rng::new(31);
        let d = 2 * super::AGG_BLOCK + 129;
        let w0: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut entries = Vec::new();
        for sat in 0..7usize {
            if sat % 2 == 0 {
                // sparse: a strided 1% of coordinates, crossing block edges
                let idx: Vec<u32> =
                    (0..d as u32).filter(|j| (j + sat as u32) % 97 == 0).collect();
                let val: Vec<f32> = idx.iter().map(|_| rng.normal_f32(0.0, 0.1)).collect();
                entries.push(GradientEntry {
                    sat,
                    staleness: sat % 3,
                    grad: Update::Sparse { dim: d, idx, val },
                    n_samples: 1,
                });
            } else {
                entries.push(GradientEntry {
                    sat,
                    staleness: sat % 3,
                    grad: (0..d).map(|_| rng.normal_f32(0.0, 0.1)).collect::<Vec<f32>>().into(),
                    n_samples: 1,
                });
            }
        }
        let dense_entries: Vec<GradientEntry> = entries
            .iter()
            .map(|e| GradientEntry {
                sat: e.sat,
                staleness: e.staleness,
                grad: e.grad.to_dense().into(),
                n_samples: e.n_samples,
            })
            .collect();
        let mut w = w0.clone();
        let mut w_ref = w0;
        CpuAggregator.aggregate(&mut w, &entries, 0.5).unwrap();
        CpuAggregator.aggregate(&mut w_ref, &dense_entries, 0.5).unwrap();
        assert_eq!(w, w_ref, "sparse accumulate ≡ densify-then-aggregate, bit-for-bit");
    }

    #[test]
    fn sparse_dim_mismatch_is_rejected_by_the_hoisted_check() {
        let mut w = vec![0.0f32; 4];
        let entries = vec![GradientEntry {
            sat: 0,
            staleness: 0,
            grad: Update::Sparse { dim: 3, idx: vec![1], val: vec![1.0] },
            n_samples: 1,
        }];
        assert!(CpuAggregator.aggregate(&mut w, &entries, 0.5).is_err());
        assert_eq!(w, vec![0.0f32; 4]);
    }

    #[test]
    fn dim_mismatch_is_an_error_not_a_partial_update() {
        let mut w = vec![0.0f32; 4];
        let entries = vec![
            GradientEntry { sat: 0, staleness: 0, grad: vec![1.0; 4].into(), n_samples: 1 },
            GradientEntry { sat: 1, staleness: 0, grad: vec![1.0; 3].into(), n_samples: 1 },
        ];
        assert!(CpuAggregator.aggregate(&mut w, &entries, 0.5).is_err());
        // the hoisted check rejects before any element is touched
        assert_eq!(w, vec![0.0f32; 4]);
    }

    #[test]
    fn empty_buffer_update_is_identity_but_bumps_round() {
        let mut gs = GsState::new(vec![5.0; 4], 0.5);
        let w0 = gs.w.clone();
        gs.update(&mut CpuAggregator).unwrap();
        assert_eq!(gs.w, w0);
        assert_eq!(gs.i_g, 1);
        assert_eq!(gs.n_aggregated, 0);
    }

    #[test]
    fn staleness_fixed_at_receive() {
        let mut gs = GsState::new(vec![0.0; 2], 0.5);
        gs.receive(0, vec![1.0, 1.0], 0, 5);
        gs.i_g = 3; // rounds pass before aggregation
        gs.receive(1, vec![1.0, 1.0], 1, 5);
        let st = gs.buffer.stalenesses();
        assert_eq!(st, vec![0, 2]);
    }

    #[test]
    fn update_reports_stalenesses_and_counts() {
        let mut gs = GsState::new(vec![0.0; 1], 0.5);
        gs.receive(0, vec![1.0], 0, 1);
        gs.receive(1, vec![3.0], 0, 1);
        let st = gs.update(&mut CpuAggregator).unwrap();
        assert_eq!(st, vec![0, 0]);
        assert_eq!(gs.n_aggregated, 2);
        assert_eq!(gs.i_g, 1);
        // equal weights: w = 0 + (1+3)/2
        assert!((gs.w[0] - 2.0).abs() < 1e-6);
        assert!(gs.buffer.is_empty());
    }

    #[test]
    fn failed_update_preserves_buffer_and_round() {
        let mut gs = GsState::new(vec![0.0f32; 4], 0.5);
        gs.receive(0, vec![1.0; 4], 0, 1);
        gs.receive(1, vec![1.0; 3], 0, 1); // wrong dimension
        assert!(gs.update(&mut CpuAggregator).is_err());
        // nothing consumed, nothing advanced, model untouched
        assert_eq!(gs.buffer.len(), 2);
        assert_eq!(gs.i_g, 0);
        assert_eq!(gs.n_aggregated, 0);
        assert_eq!(gs.w, vec![0.0f32; 4]);
    }

    #[test]
    #[should_panic]
    fn future_round_rejected() {
        let mut gs = GsState::new(vec![0.0], 0.5);
        gs.receive(0, vec![1.0], 7, 1);
    }

    #[test]
    fn weighted_merge_is_exact_for_a_single_full_weight_model() {
        let w: Vec<f32> = (0..100).map(|i| (i as f32).sin() * 1e3).collect();
        let merged = weighted_model_merge(&[(&w, 1.0)], w.len());
        for (a, b) in merged.iter().zip(w.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn weighted_merge_accumulates_in_input_order() {
        let a = vec![1.0f32, 2.0];
        let b = vec![3.0f32, 5.0];
        let m = weighted_model_merge(&[(&a, 0.25), (&b, 0.75)], 2);
        assert!((m[0] - 2.5).abs() < 1e-6);
        assert!((m[1] - 4.25).abs() < 1e-6);
        // empty input is the zero model
        assert_eq!(weighted_model_merge(&[], 3), vec![0.0; 3]);
    }

    #[test]
    fn weighted_merge_all_zero_weights_returns_first_replica_unchanged() {
        // zero-activity regression: a merge window in which no gateway
        // aggregated anything must not zero the model
        let a: Vec<f32> = (0..50).map(|i| (i as f32).cos() * 7.0).collect();
        let b = vec![9.0f32; 50];
        let m = weighted_model_merge(&[(&a, 0.0), (&b, 0.0)], 50);
        for (x, y) in m.iter().zip(a.iter()) {
            assert_eq!(x.to_bits(), y.to_bits(), "first replica, bit-for-bit");
        }
        // a single zero-weight replica likewise survives
        let m = weighted_model_merge(&[(&a, 0.0)], 50);
        assert_eq!(m, a);
        // any nonzero weight re-enables the weighted path
        let m = weighted_model_merge(&[(&a, 0.0), (&b, 1.0)], 50);
        assert_eq!(m, b);
    }

    #[test]
    #[should_panic]
    fn weighted_merge_rejects_dim_mismatch() {
        let a = vec![1.0f32];
        let _ = weighted_model_merge(&[(&a, 1.0)], 2);
    }
}
