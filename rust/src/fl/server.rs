//! Ground-station (GS) state and the Eq. (4) model update.

use super::buffer::{Buffer, GradientEntry};
use super::staleness::normalized_weights;
use anyhow::Result;

/// Applies Eq. (4): w' = w + Σ_k (c(s_k)/C)·g_k over the drained buffer.
///
/// Two implementations: [`CpuAggregator`] (pure Rust hot loop, used by mock
/// experiments and as the correctness oracle) and `runtime::PjrtAggregator`
/// (streams chunks through the Pallas `stale_aggregate` artifact — the
/// shipped hot path). Not `Send`: PJRT handles live on the coordinator
/// thread.
pub trait ServerAggregator {
    fn aggregate(&mut self, w: &mut Vec<f32>, entries: &[GradientEntry], alpha: f64)
        -> Result<()>;
}

/// Reference aggregation in Rust: exact Eq. (4) with f32 accumulate.
pub struct CpuAggregator;

impl ServerAggregator for CpuAggregator {
    fn aggregate(
        &mut self,
        w: &mut Vec<f32>,
        entries: &[GradientEntry],
        alpha: f64,
    ) -> Result<()> {
        if entries.is_empty() {
            return Ok(());
        }
        let stalenesses: Vec<usize> = entries.iter().map(|e| e.staleness).collect();
        let weights = normalized_weights(&stalenesses, alpha);
        for (entry, &wt) in entries.iter().zip(weights.iter()) {
            assert_eq!(entry.grad.len(), w.len(), "gradient/model dim mismatch");
            for (wi, gi) in w.iter_mut().zip(entry.grad.iter()) {
                *wi += wt * gi;
            }
        }
        Ok(())
    }
}

/// GS state of Algorithm 1: current global model w^i, round index i_g, the
/// buffer B_i, and the running trace the figures need.
pub struct GsState {
    pub w: Vec<f32>,
    pub i_g: usize,
    pub buffer: Buffer,
    pub alpha: f64,
    /// total gradients ever aggregated (Table 1 "total")
    pub n_aggregated: usize,
}

impl GsState {
    pub fn new(w: Vec<f32>, alpha: f64) -> Self {
        GsState { w, i_g: 0, buffer: Buffer::new(), alpha, n_aggregated: 0 }
    }

    /// Receive (g_k, i_{g,k}) from satellite k: staleness fixed now.
    pub fn receive(&mut self, sat: usize, grad: Vec<f32>, base_round: usize, n_samples: usize) {
        assert!(base_round <= self.i_g, "satellite from the future");
        self.buffer.push(GradientEntry {
            sat,
            staleness: self.i_g - base_round,
            grad,
            n_samples,
        });
    }

    /// SERVERUPDATE (Eq. 4): drain buffer, update w, bump i_g.
    /// Returns the aggregated entries' stalenesses (for the Figure 7 trace).
    pub fn update(&mut self, aggregator: &mut dyn ServerAggregator) -> Result<Vec<usize>> {
        let entries = self.buffer.drain();
        let stalenesses: Vec<usize> = entries.iter().map(|e| e.staleness).collect();
        aggregator.aggregate(&mut self.w, &entries, self.alpha)?;
        self.i_g += 1;
        self.n_aggregated += entries.len();
        Ok(stalenesses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_aggregator_matches_manual_eq4() {
        let mut w = vec![1.0f32, 2.0, 3.0];
        let entries = vec![
            GradientEntry { sat: 0, staleness: 0, grad: vec![1.0, 0.0, 0.0], n_samples: 1 },
            GradientEntry { sat: 1, staleness: 1, grad: vec![0.0, 2.0, 0.0], n_samples: 1 },
        ];
        let alpha = 0.5;
        let c0 = 1.0f64;
        let c1 = 2.0f64.powf(-0.5);
        let total = c0 + c1;
        CpuAggregator.aggregate(&mut w, &entries, alpha).unwrap();
        let want = [
            1.0 + (c0 / total) as f32,
            2.0 + 2.0 * (c1 / total) as f32,
            3.0,
        ];
        for (g, e) in w.iter().zip(want.iter()) {
            assert!((g - e).abs() < 1e-6, "{w:?} vs {want:?}");
        }
    }

    #[test]
    fn empty_buffer_update_is_identity_but_bumps_round() {
        let mut gs = GsState::new(vec![5.0; 4], 0.5);
        let w0 = gs.w.clone();
        gs.update(&mut CpuAggregator).unwrap();
        assert_eq!(gs.w, w0);
        assert_eq!(gs.i_g, 1);
        assert_eq!(gs.n_aggregated, 0);
    }

    #[test]
    fn staleness_fixed_at_receive() {
        let mut gs = GsState::new(vec![0.0; 2], 0.5);
        gs.receive(0, vec![1.0, 1.0], 0, 5);
        gs.i_g = 3; // rounds pass before aggregation
        gs.receive(1, vec![1.0, 1.0], 1, 5);
        let st = gs.buffer.stalenesses();
        assert_eq!(st, vec![0, 2]);
    }

    #[test]
    fn update_reports_stalenesses_and_counts() {
        let mut gs = GsState::new(vec![0.0; 1], 0.5);
        gs.receive(0, vec![1.0], 0, 1);
        gs.receive(1, vec![3.0], 0, 1);
        let st = gs.update(&mut CpuAggregator).unwrap();
        assert_eq!(st, vec![0, 0]);
        assert_eq!(gs.n_aggregated, 2);
        assert_eq!(gs.i_g, 1);
        // equal weights: w = 0 + (1+3)/2
        assert!((gs.w[0] - 2.0).abs() < 1e-6);
        assert!(gs.buffer.is_empty());
    }

    #[test]
    #[should_panic]
    fn future_round_rejected() {
        let mut gs = GsState::new(vec![0.0], 0.5);
        gs.receive(0, vec![1.0], 7, 1);
    }
}
