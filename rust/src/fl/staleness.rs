//! Staleness compensation c_α(s) = (s+1)^{-α} (paper Eq. 4, after
//! Xie et al. 2019). The paper uses the polynomial form as it "shows similar
//! or better performance than the other options".

/// c_α(s): monotonically decreasing in s, c_α(0) = 1.
pub fn compensation(s: usize, alpha: f64) -> f64 {
    assert!(alpha >= 0.0, "alpha must be non-negative");
    ((s + 1) as f64).powf(-alpha)
}

/// Eq. (4) weights: c(s_k)/C with C = Σ c(s_k). Empty input → empty output.
pub fn normalized_weights(stalenesses: &[usize], alpha: f64) -> Vec<f32> {
    if stalenesses.is_empty() {
        return Vec::new();
    }
    let raw: Vec<f64> = stalenesses.iter().map(|&s| compensation(s, alpha)).collect();
    let total: f64 = raw.iter().sum();
    raw.iter().map(|&c| (c / total) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_staleness_is_one() {
        assert_eq!(compensation(0, 0.5), 1.0);
        assert_eq!(compensation(0, 2.0), 1.0);
    }

    #[test]
    fn monotonically_decreasing() {
        for alpha in [0.25, 0.5, 1.0] {
            for s in 0..10 {
                assert!(compensation(s + 1, alpha) < compensation(s, alpha));
            }
        }
    }

    #[test]
    fn alpha_zero_ignores_staleness() {
        for s in 0..10 {
            assert_eq!(compensation(s, 0.0), 1.0);
        }
    }

    #[test]
    fn weights_sum_to_one() {
        let w = normalized_weights(&[0, 1, 5, 2], 0.5);
        let sum: f32 = w.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        // fresher gradients weigh more
        assert!(w[0] > w[1] && w[1] > w[2]);
    }

    #[test]
    fn uniform_when_same_staleness() {
        let w = normalized_weights(&[3, 3, 3], 0.5);
        for v in &w {
            assert!((v - 1.0 / 3.0).abs() < 1e-6);
        }
    }

    #[test]
    fn empty_is_empty() {
        assert!(normalized_weights(&[], 0.5).is_empty());
    }
}
