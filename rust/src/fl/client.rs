//! Satellite-side FL client state machine (paper §2.3, Eq. 3).
//!
//! Protocol per contact (Appendix A's four steps):
//!   1. if a trained local update is pending, upload (g_k, i_{g,k});
//!   2. GS buffers it (staleness fixed there) and may aggregate;
//!   3. GS sends (w^{i+1}, i_g) if this satellite doesn't hold that version;
//!   4. on receive, the satellite starts E local SGD steps.
//!
//! Local training itself is delegated to the simulation engine's trainer
//! backend (PJRT artifact or mock), so this module is pure state.

/// Training lifecycle of one satellite.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SatPhase {
    /// never received a global model yet
    Cold,
    /// training on `base_round`; update ready at `ready_at`
    Training,
    /// local update computed, waiting for the next contact to upload
    HasUpdate,
    /// uploaded; waiting to receive a fresh global model
    AwaitingModel,
}

/// One satellite's FL state.
#[derive(Clone, Debug)]
pub struct SatClient {
    /// Satellite id k.
    pub id: usize,
    /// Where in the training lifecycle this satellite is.
    pub phase: SatPhase,
    /// i_{g,k}: round index of the model the pending update is based on
    pub base_round: usize,
    /// version of the global model this satellite currently holds
    pub held_version: Option<usize>,
    /// time index at which local training completes
    pub ready_at: usize,
    /// pending local update g_k (set by the trainer backend)
    pub pending: Option<Vec<f32>>,
    /// m_k
    pub n_samples: usize,
    /// error-feedback residual carried by lossy upload codecs (ADR-0008):
    /// the part of past updates a `top-k` / `quant-q8` encode did not
    /// transmit, added back before the next encode. Empty until the first
    /// lossy encode (and always empty when the codec is off).
    pub residual: Vec<f32>,
}

impl SatClient {
    /// A cold client with `n_samples` local samples.
    pub fn new(id: usize, n_samples: usize) -> Self {
        SatClient {
            id,
            phase: SatPhase::Cold,
            base_round: 0,
            held_version: None,
            ready_at: 0,
            pending: None,
            n_samples,
            residual: Vec::new(),
        }
    }

    /// Does this satellite have an update to send at time index `i`?
    pub fn can_upload(&self, i: usize) -> bool {
        self.can_upload_relayed(i, 0)
    }

    /// [`Self::can_upload`] with an ISL relay-latency charge (ADR-0005): an
    /// update arriving over `h` relay hops spends `h × hop_delay` slots in
    /// flight, so to land at the ground station at step `i` it must have
    /// been ready `delay_slots` slots earlier. With `delay_slots = 0` this
    /// is exactly the direct-contact condition.
    pub fn can_upload_relayed(&self, i: usize, delay_slots: usize) -> bool {
        matches!(self.phase, SatPhase::HasUpdate | SatPhase::Training)
            && self.pending.is_some()
            && self.ready_at.saturating_add(delay_slots) <= i
    }

    /// Take the pending update for upload. Returns (g_k, i_{g,k}).
    pub fn upload(&mut self, i: usize) -> (Vec<f32>, usize) {
        assert!(self.can_upload(i), "upload without pending update");
        let g = self.pending.take().expect("pending update");
        self.phase = SatPhase::AwaitingModel;
        (g, self.base_round)
    }

    /// Would receiving (w, version) at this contact start new training?
    /// Per the protocol the GS re-sends only unseen versions; a satellite
    /// mid-training ignores broadcasts (single-core OBC).
    pub fn wants_model(&self, version: usize, i: usize) -> bool {
        let busy = self.phase == SatPhase::Training && self.ready_at > i;
        !busy && self.held_version != Some(version)
    }

    /// Accept (w, version); training completes after `duration` slots.
    /// The engine computes the actual update via its trainer backend and
    /// stores it through [`SatClient::set_update`].
    pub fn receive(&mut self, version: usize, i: usize, duration: usize) {
        debug_assert!(self.wants_model(version, i));
        self.held_version = Some(version);
        self.base_round = version;
        self.ready_at = i + duration;
        self.phase = SatPhase::Training;
        self.pending = None;
    }

    /// Install the computed local update (g_k).
    pub fn set_update(&mut self, grad: Vec<f32>) {
        assert_eq!(self.phase, SatPhase::Training);
        self.pending = Some(grad);
        self.phase = if self.ready_at == usize::MAX {
            SatPhase::Training
        } else {
            SatPhase::HasUpdate
        };
    }

    /// A satellite with no local data never trains or uploads (possible
    /// under the Non-IID partition when it overflies no sampled zone).
    pub fn has_data(&self) -> bool {
        self.n_samples > 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_cold_to_upload() {
        let mut c = SatClient::new(0, 100);
        assert_eq!(c.phase, SatPhase::Cold);
        assert!(!c.can_upload(0));
        assert!(c.wants_model(0, 0));
        c.receive(0, 0, 1);
        assert_eq!(c.phase, SatPhase::Training);
        c.set_update(vec![1.0]);
        assert_eq!(c.phase, SatPhase::HasUpdate);
        assert!(!c.can_upload(0), "not ready before ready_at");
        assert!(c.can_upload(1));
        let (g, base) = c.upload(1);
        assert_eq!(g, vec![1.0]);
        assert_eq!(base, 0);
        assert_eq!(c.phase, SatPhase::AwaitingModel);
        assert!(!c.can_upload(2));
    }

    #[test]
    fn ignores_same_version() {
        let mut c = SatClient::new(0, 100);
        c.receive(3, 0, 1);
        c.set_update(vec![0.5]);
        let _ = c.upload(1);
        // GS hasn't aggregated: version still 3 -> no re-send, idle contact
        assert!(!c.wants_model(3, 2));
        assert!(c.wants_model(4, 2));
    }

    #[test]
    fn busy_satellite_ignores_broadcast() {
        let mut c = SatClient::new(0, 100);
        c.receive(0, 0, 3); // training until i=3
        assert!(!c.wants_model(1, 1), "mid-training must not restart");
        assert!(c.wants_model(1, 3), "done training, new version welcome");
    }

    #[test]
    fn relayed_upload_needs_head_start() {
        let mut c = SatClient::new(0, 100);
        c.receive(0, 0, 1); // ready at 1
        c.set_update(vec![1.0]);
        // direct contact at 1 works; a 2-slot relay path needs i >= 3
        assert!(c.can_upload_relayed(1, 0));
        assert!(!c.can_upload_relayed(1, 2));
        assert!(!c.can_upload_relayed(2, 2));
        assert!(c.can_upload_relayed(3, 2));
        // usize::MAX ready_at (never-finishing training) must not overflow
        c.ready_at = usize::MAX;
        assert!(!c.can_upload_relayed(5, 3));
    }

    #[test]
    fn no_data_flag() {
        let c = SatClient::new(0, 0);
        assert!(!c.has_data());
    }

    #[test]
    #[should_panic]
    fn upload_without_update_panics() {
        let mut c = SatClient::new(0, 10);
        let _ = c.upload(0);
    }
}
