//! Gradient compression at the upload boundary (ADR-0008): a pluggable
//! `UpdateCodec` between `SatClient::upload` and the adversary/federation,
//! plus the `[link]` byte-budget spec that makes contacts carry a finite
//! capacity (rate × pass duration) instead of treating uploads as free.
//!
//! The codec sits at the *same* single boundary the PR 6 adversary uses,
//! with a fixed ordering — encode first, adversary second — so poisoning
//! and link faults act on what is actually transmitted. Payloads flow as
//! [`Update`]: dense `Vec<f32>` (identity / quantized) or `(indices,
//! values)` sparse pairs (top-k), which the aggregators consume without
//! densifying (sparse accumulate on `CpuAggregator`, lazy per-coordinate
//! reads in `fl/robust.rs`).
//!
//! Determinism contract, mirroring ADR-0007: the stochastic quantizer
//! draws from its own xoshiro stream `Rng::new(run_seed ^ CODEC_STREAM)`,
//! created only when a codec is enabled, and draws happen only at contact
//! steps — so codec-on runs are trace-bit-identical across Dense /
//! ContactList / Streamed, and codec-off runs consume no codec randomness
//! at all (bit-identical to a build without this module). Top-k keeps the
//! exact f32 bits of the coordinates it selects and holds the unselected
//! remainder as an error-feedback residual on the client, so
//! `decoded + residual` reconstructs the compensated update exactly.

use crate::cfg::toml::{TomlDoc, TomlValue};
use crate::rng::Rng;
use anyhow::{bail, Context, Result};

/// Stream-id XOR'd into the run seed for the codec RNG, keeping its draws
/// independent of the training (`split(i+1)`), planner/utility/data
/// (`PLANNER_STREAM` / `UTILITY_STREAM` / `DATA_STREAM` in `app::runner`)
/// and adversary (`ADVERSARY_STREAM`) streams — pairwise distinctness is
/// machine-checked by `fedspace lint`'s `rng-stream` rule.
pub const CODEC_STREAM: u64 = 0xC0DE_C0DE;

/// One transmitted model update. Dense is the uncompressed (and quantized)
/// wire form; Sparse is the top-k `(indices, values)` pair with indices
/// strictly ascending. `Sparse` keeps its logical dimension so dimension
/// checks and lazy per-coordinate reads need no side channel.
#[derive(Clone, Debug, PartialEq)]
pub enum Update {
    /// All `d` coordinates, in order.
    Dense(Vec<f32>),
    /// `(indices, values)` pairs over a `dim`-sized vector; `idx` is
    /// strictly ascending and `val` is parallel to it. Coordinates not
    /// listed are exactly zero.
    Sparse { dim: usize, idx: Vec<u32>, val: Vec<f32> },
}

impl Update {
    /// Logical dimension (what a dense view would have). Named `len` so
    /// existing `entry.grad.len()` dimension checks read unchanged.
    pub fn len(&self) -> usize {
        match self {
            Update::Dense(v) => v.len(),
            Update::Sparse { dim, .. } => *dim,
        }
    }

    /// True when the logical dimension is zero.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Stored (transmitted) coordinates: `d` for dense, `nnz` for sparse.
    pub fn nnz(&self) -> usize {
        match self {
            Update::Dense(v) => v.len(),
            Update::Sparse { val, .. } => val.len(),
        }
    }

    /// Coordinate `e` of the logical vector (0.0 for unlisted sparse
    /// coordinates). `O(1)` dense, `O(log nnz)` sparse — the lazy
    /// densify primitive the robust aggregators use per coordinate.
    pub fn at(&self, e: usize) -> f32 {
        match self {
            Update::Dense(v) => v[e],
            Update::Sparse { idx, val, .. } => match idx.binary_search(&(e as u32)) {
                Ok(p) => val[p],
                Err(_) => 0.0,
            },
        }
    }

    /// The raw stored values (dense coordinates, or sparse `val`). The
    /// adversary's transforms operate here: on the wire payload, whatever
    /// its encoding — matching the codec→adversary boundary ordering.
    pub fn values(&self) -> &[f32] {
        match self {
            Update::Dense(v) => v,
            Update::Sparse { val, .. } => val,
        }
    }

    /// Mutable view of the stored values (see [`Self::values`]).
    pub fn values_mut(&mut self) -> &mut [f32] {
        match self {
            Update::Dense(v) => v,
            Update::Sparse { val, .. } => val,
        }
    }

    /// Borrow the dense coordinate slice, if this is a dense update.
    pub fn as_dense(&self) -> Option<&[f32]> {
        match self {
            Update::Dense(v) => Some(v),
            Update::Sparse { .. } => None,
        }
    }

    /// Materialize the full `len()`-sized vector (sparse gaps are 0.0).
    pub fn to_dense(&self) -> Vec<f32> {
        match self {
            Update::Dense(v) => v.clone(),
            Update::Sparse { dim, idx, val } => {
                let mut out = vec![0.0f32; *dim];
                for (&j, &v) in idx.iter().zip(val.iter()) {
                    out[j as usize] = v;
                }
                out
            }
        }
    }

    /// Squared euclidean distance in f64, per-coordinate in index order —
    /// the multi-Krum scoring primitive. The dense×dense arm is the exact
    /// loop the PR 6 engine ran, so scores (and selections) are
    /// bit-identical for uncompressed runs.
    pub fn sq_dist(&self, other: &Update) -> f64 {
        match (self, other) {
            (Update::Dense(a), Update::Dense(b)) => a
                .iter()
                .zip(b.iter())
                .map(|(x, y)| {
                    let d = *x as f64 - *y as f64;
                    d * d
                })
                .sum(),
            _ => (0..self.len().min(other.len()))
                .map(|e| {
                    let d = self.at(e) as f64 - other.at(e) as f64;
                    d * d
                })
                .sum(),
        }
    }
}

impl From<Vec<f32>> for Update {
    fn from(v: Vec<f32>) -> Update {
        Update::Dense(v)
    }
}

/// Which codec runs at the upload boundary (the `[link]` TOML `codec`
/// key). `Identity` transmits the raw f32 payload untouched.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CodecKind {
    /// No compression: the dense gradient crosses the link as-is.
    #[default]
    Identity,
    /// Top-k magnitude sparsification with error-feedback residuals held
    /// on the satellite (`topk_frac` selects `k = ceil(frac · d)`).
    TopK,
    /// 8-bit stochastic quantization (per-update max-abs scale), drawn
    /// from the codec stream; the quantization error feeds the residual.
    QuantQ8,
}

impl CodecKind {
    /// Parse the TOML/CLI spelling.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "identity" | "none" => CodecKind::Identity,
            "top-k" | "topk" | "top_k" => CodecKind::TopK,
            "quant-q8" | "quant_q8" | "q8" => CodecKind::QuantQ8,
            other => bail!("unknown codec {other:?} (identity | top-k | quant-q8)"),
        })
    }

    /// Canonical lowercase name (inverse of [`Self::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            CodecKind::Identity => "identity",
            CodecKind::TopK => "top-k",
            CodecKind::QuantQ8 => "quant-q8",
        }
    }
}

/// The `[link]` TOML section: per-contact byte budget and upload codec.
/// Omitted ⇒ default ⇒ disabled ⇒ byte-identical old specs and
/// bit-identical uncompressed, capacity-free runs.
#[derive(Clone, Debug, PartialEq)]
pub struct LinkSpec {
    /// Bytes one link moves in one full time slot (rate × slot length).
    /// A contact spanning a fraction of the slot carries that fraction of
    /// this budget. `0` = unlimited (the pre-PR 7 instantaneous model).
    pub rate_bytes_per_slot: u64,
    /// Upload codec at the boundary (encode runs before the adversary).
    pub codec: CodecKind,
    /// Fraction of coordinates `top-k` keeps, in `(0, 1]`.
    pub topk_frac: f64,
}

impl Default for LinkSpec {
    fn default() -> Self {
        LinkSpec { rate_bytes_per_slot: 0, codec: CodecKind::Identity, topk_frac: 0.01 }
    }
}

impl LinkSpec {
    /// Whether this spec changes anything at all. Disabled ⇒ the engine
    /// builds no [`Codec`], skips every capacity check, and consumes no
    /// codec randomness.
    pub fn enabled(&self) -> bool {
        self.rate_bytes_per_slot > 0 || self.codec != CodecKind::Identity
    }

    /// Whether contacts carry a finite byte budget (uploads can defer).
    pub fn capacity_enabled(&self) -> bool {
        self.rate_bytes_per_slot > 0
    }

    /// Top-k keep count for a `d`-dimensional model: `ceil(frac · d)`,
    /// at least 1, at most `d`.
    pub fn topk_k(&self, d: usize) -> usize {
        ((self.topk_frac * d as f64).ceil() as usize).clamp(1, d.max(1))
    }

    /// Nominal wire size of one encoded update of dimension `d`: the
    /// number the capacity check charges against the contact budget.
    /// Dense f32 = 4 bytes/coord; sparse = 8 bytes per kept pair
    /// (u32 index + f32 value); q8 = 1 byte/coord + a 4-byte scale.
    pub fn payload_bytes(&self, d: usize) -> u64 {
        match self.codec {
            CodecKind::Identity => 4 * d as u64,
            CodecKind::TopK => 8 * self.topk_k(d) as u64,
            CodecKind::QuantQ8 => d as u64 + 4,
        }
    }

    /// Reject self-inconsistent specs.
    pub fn validate(&self) -> Result<()> {
        if !self.topk_frac.is_finite() || self.topk_frac <= 0.0 || self.topk_frac > 1.0 {
            bail!("[link] topk_frac must be in (0, 1], got {}", self.topk_frac);
        }
        Ok(())
    }

    /// Emit the `[link]` TOML section (callers skip the call when
    /// `!enabled()` so pre-link specs stay byte-identical).
    pub fn emit_toml(&self, out: &mut String) {
        use std::fmt::Write as _;
        let _ = writeln!(out, "\n[link]");
        let _ = writeln!(out, "rate_bytes_per_slot = {}", self.rate_bytes_per_slot);
        let _ = writeln!(out, "codec = \"{}\"", self.codec.name());
        let _ = writeln!(out, "topk_frac = {}", self.topk_frac);
    }

    /// Parse the `[link]` section; `Ok(None)` when absent (callers keep
    /// their default) — the shared scenario/experiment-config idiom.
    pub fn from_doc(doc: &TomlDoc) -> Result<Option<LinkSpec>> {
        if doc.get("link").is_none() {
            return Ok(None);
        }
        let get = |key: &str| -> Option<&TomlValue> { doc.get("link").and_then(|s| s.get(key)) };
        let mut spec = LinkSpec::default();
        if let Some(v) = get("rate_bytes_per_slot") {
            let raw = v.as_int().context("[link] rate_bytes_per_slot must be an integer")?;
            spec.rate_bytes_per_slot =
                u64::try_from(raw).context("[link] rate_bytes_per_slot must be non-negative")?;
        }
        if let Some(v) = get("codec") {
            spec.codec = CodecKind::parse(v.as_str().context("[link] codec must be a string")?)?;
        }
        if let Some(v) = get("topk_frac") {
            spec.topk_frac = v.as_float().context("[link] topk_frac must be a number")?;
        }
        Ok(Some(spec))
    }
}

impl crate::cfg::section::SectionSpec for LinkSpec {
    const SECTION: &'static str = "link";

    fn from_doc(doc: &TomlDoc) -> Result<Option<Self>> {
        LinkSpec::from_doc(doc)
    }

    fn emit_toml(&self, out: &mut String) {
        LinkSpec::emit_toml(self, out)
    }

    fn is_emitted(&self) -> bool {
        self.enabled()
    }

    fn validate(&self, _ctx: &crate::cfg::section::SectionCtx) -> Result<()> {
        LinkSpec::validate(self)
    }
}

/// Live encoder owned by the engine's `RunState`, built only when
/// [`LinkSpec::enabled`]. One instance serves the whole fleet; per-client
/// error-feedback residuals live on `SatClient` and are passed in.
pub struct UpdateCodec {
    spec: LinkSpec,
    rng: Rng,
}

impl UpdateCodec {
    /// Build the encoder under `run_seed` (the scenario seed; the codec
    /// stream is derived, not shared).
    pub fn new(spec: &LinkSpec, run_seed: u64) -> UpdateCodec {
        UpdateCodec { spec: spec.clone(), rng: Rng::new(run_seed ^ CODEC_STREAM) }
    }

    /// Encode one upload. `residual` is the calling client's error-
    /// feedback carry (resized lazily on first use); lossy codecs add it
    /// to the gradient before compressing and store the uncompensated
    /// remainder back, so no signal is ever discarded — only delayed.
    ///
    /// `Identity` is a byte-level no-op: the gradient's f32 bits move
    /// into the returned `Update::Dense` unchanged, the residual is never
    /// touched, and no randomness is consumed.
    pub fn encode(&mut self, grad: Vec<f32>, residual: &mut Vec<f32>) -> Update {
        match self.spec.codec {
            CodecKind::Identity => Update::Dense(grad),
            CodecKind::TopK => self.encode_topk(grad, residual),
            CodecKind::QuantQ8 => self.encode_q8(grad, residual),
        }
    }

    /// Top-k: compensate (`x = grad + residual`), keep the `k` largest
    /// magnitudes (ties broken toward the lower index — fully
    /// deterministic, no RNG), transmit their exact f32 bits as
    /// `(indices, values)`, and hold everything else in the residual.
    fn encode_topk(&mut self, grad: Vec<f32>, residual: &mut Vec<f32>) -> Update {
        let d = grad.len();
        if residual.len() != d {
            residual.resize(d, 0.0);
        }
        let mut x = grad;
        for (xi, r) in x.iter_mut().zip(residual.iter()) {
            *xi += *r;
        }
        let k = self.spec.topk_k(d);
        let mut order: Vec<u32> = (0..d as u32).collect();
        order.sort_unstable_by(|&a, &b| {
            x[b as usize]
                .abs()
                .total_cmp(&x[a as usize].abs())
                .then(a.cmp(&b))
        });
        let mut idx = order[..k.min(d)].to_vec();
        idx.sort_unstable();
        let val: Vec<f32> = idx.iter().map(|&j| x[j as usize]).collect();
        residual.copy_from_slice(&x);
        for &j in &idx {
            residual[j as usize] = 0.0;
        }
        Update::Sparse { dim: d, idx, val }
    }

    /// Q8: compensate, scale by the update's max-abs over 127 levels,
    /// round stochastically (one codec-stream draw per coordinate —
    /// skipped entirely for an all-zero update), dequantize immediately
    /// (the wire form is `i8 × scale`, the in-memory form is the
    /// dequantized dense vector), and carry the quantization error.
    fn encode_q8(&mut self, grad: Vec<f32>, residual: &mut Vec<f32>) -> Update {
        let d = grad.len();
        if residual.len() != d {
            residual.resize(d, 0.0);
        }
        let mut x = grad;
        for (xi, r) in x.iter_mut().zip(residual.iter()) {
            *xi += *r;
        }
        let mut scale = 0.0f32;
        for &v in &x {
            scale = scale.max(v.abs());
        }
        let mut deq = vec![0.0f32; d];
        if scale > 0.0 && scale.is_finite() {
            let s = scale / 127.0;
            for (o, &v) in deq.iter_mut().zip(x.iter()) {
                let t = (v / s).clamp(-127.0, 127.0);
                let lo = t.floor();
                let q = if (self.rng.next_f64() as f32) < t - lo { lo + 1.0 } else { lo };
                *o = q.clamp(-127.0, 127.0) * s;
            }
        }
        for ((r, &xv), &dv) in residual.iter_mut().zip(x.iter()).zip(deq.iter()) {
            *r = xv - dv;
        }
        Update::Dense(deq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp(d: usize) -> Vec<f32> {
        (0..d).map(|i| ((i as f32) - (d as f32) / 3.0) * 0.37).collect()
    }

    #[test]
    fn identity_is_a_byte_level_noop() {
        let spec = LinkSpec::default();
        let mut codec = UpdateCodec::new(&spec, 42);
        let grad = vec![1.5, -0.0, f32::MIN_POSITIVE, 3.25e-30];
        let bits: Vec<u32> = grad.iter().map(|v| v.to_bits()).collect();
        let mut residual = Vec::new();
        let out = codec.encode(grad, &mut residual);
        let Update::Dense(v) = out else { panic!("identity must stay dense") };
        assert_eq!(v.iter().map(|x| x.to_bits()).collect::<Vec<_>>(), bits);
        assert!(residual.is_empty(), "identity must never touch the residual");
    }

    #[test]
    fn topk_keeps_selected_bits_and_reconstructs_exactly() {
        let spec =
            LinkSpec { codec: CodecKind::TopK, topk_frac: 0.25, ..Default::default() };
        let mut codec = UpdateCodec::new(&spec, 7);
        let grad = ramp(32);
        let mut residual = Vec::new();
        let out = codec.encode(grad.clone(), &mut residual);
        let Update::Sparse { dim, ref idx, ref val } = out else { panic!("topk is sparse") };
        assert_eq!(dim, 32);
        assert_eq!(idx.len(), 8, "k = ceil(0.25 · 32)");
        assert!(idx.windows(2).all(|w| w[0] < w[1]), "indices strictly ascending");
        // with a fresh residual, selected coordinates carry the original bits
        for (&j, &v) in idx.iter().zip(val.iter()) {
            assert_eq!(v.to_bits(), grad[j as usize].to_bits());
        }
        // error-feedback invariant: decoded + residual == original, bit-for-bit
        let dec = out.to_dense();
        for e in 0..32 {
            assert_eq!(
                (dec[e] + residual[e]).to_bits(),
                grad[e].to_bits(),
                "coordinate {e}: decoded + residual must reconstruct the update"
            );
        }
        // second round: the compensated update is grad + residual, exactly
        let carried = residual.clone();
        let out2 = codec.encode(grad.clone(), &mut residual);
        let dec2 = out2.to_dense();
        for e in 0..32 {
            assert_eq!(
                (dec2[e] + residual[e]).to_bits(),
                (grad[e] + carried[e]).to_bits(),
                "coordinate {e}: round 2 reconstructs grad + carried residual"
            );
        }
    }

    #[test]
    fn topk_selects_largest_magnitudes_with_index_ties() {
        let spec = LinkSpec { codec: CodecKind::TopK, topk_frac: 0.5, ..Default::default() };
        let mut codec = UpdateCodec::new(&spec, 1);
        let mut residual = Vec::new();
        // |…| = [3, 1, 3, 2]; k = 2 ⇒ the two 3s win, lower index first
        let out = codec.encode(vec![-3.0, 1.0, 3.0, 2.0], &mut residual);
        let Update::Sparse { idx, val, .. } = out else { panic!() };
        assert_eq!(idx, vec![0, 2]);
        assert_eq!(val, vec![-3.0, 3.0]);
        assert_eq!(residual, vec![0.0, 1.0, 0.0, 2.0]);
    }

    #[test]
    fn q8_is_seed_stable_and_error_bounded() {
        let spec = LinkSpec { codec: CodecKind::QuantQ8, ..Default::default() };
        let run = |seed: u64| {
            let mut codec = UpdateCodec::new(&spec, seed);
            let mut residual = Vec::new();
            let mut outs = Vec::new();
            for r in 0..8 {
                let grad: Vec<f32> = ramp(64).iter().map(|v| v * (r as f32 + 1.0)).collect();
                outs.push(codec.encode(grad, &mut residual));
            }
            (outs, residual)
        };
        let (a, ra) = run(42);
        let (b, rb) = run(42);
        assert_eq!(a, b, "same seed ⇒ identical quantized stream");
        assert_eq!(ra, rb);
        let (c, _) = run(43);
        assert_ne!(a, c, "different seed ⇒ different stochastic rounding");
        // every quantized coordinate is within one level of the input
        let mut codec = UpdateCodec::new(&spec, 9);
        let mut residual = Vec::new();
        let grad = ramp(64);
        let out = codec.encode(grad.clone(), &mut residual);
        let scale = grad.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let level = scale / 127.0;
        for (e, (&g, &q)) in grad.iter().zip(out.values().iter()).enumerate() {
            assert!((g - q).abs() <= level * 1.001, "coord {e}: {g} vs {q}");
            assert_eq!(residual[e], g - q, "residual carries the quantization error");
        }
        // all-zero update: no draws, exact zero out (stream position must
        // not depend on call count — verified by the identical-runs check
        // above which includes differently-scaled rounds)
        let out = codec.encode(vec![0.0; 16], &mut residual);
        assert!(out.values().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn update_accessors_agree_with_dense_view() {
        let sp = Update::Sparse { dim: 6, idx: vec![1, 4], val: vec![2.5, -1.25] };
        assert_eq!(sp.len(), 6);
        assert_eq!(sp.nnz(), 2);
        assert_eq!(sp.to_dense(), vec![0.0, 2.5, 0.0, 0.0, -1.25, 0.0]);
        for e in 0..6 {
            assert_eq!(sp.at(e), sp.to_dense()[e]);
        }
        let de: Update = vec![1.0, 2.0, 3.0].into();
        assert_eq!(de.as_dense(), Some(&[1.0, 2.0, 3.0][..]));
        assert_eq!(de.values(), &[1.0, 2.0, 3.0]);
        assert!(sp.as_dense().is_none());
        // sq_dist: sparse arm agrees with the dense oracle
        let dense_self = Update::Dense(sp.to_dense());
        let other = Update::Dense(vec![1.0, -1.0, 0.5, 0.0, 2.0, -3.0]);
        assert_eq!(sp.sq_dist(&other), dense_self.sq_dist(&other));
        assert_eq!(sp.sq_dist(&sp.clone()), 0.0);
    }

    #[test]
    fn spec_round_trips_and_validates() {
        let spec = LinkSpec {
            rate_bytes_per_slot: 1_500_000,
            codec: CodecKind::TopK,
            topk_frac: 0.01,
        };
        let mut s = String::new();
        spec.emit_toml(&mut s);
        let doc = crate::cfg::toml::parse_toml(&s).unwrap();
        let back = LinkSpec::from_doc(&doc).unwrap().expect("section present");
        assert_eq!(back, spec, "{s}");
        assert!(spec.validate().is_ok());
        assert!(spec.enabled() && spec.capacity_enabled());
        // absent section -> None; disabled default never emits
        let doc = crate::cfg::toml::parse_toml("[scenario]\nname = \"x\"").unwrap();
        assert!(LinkSpec::from_doc(&doc).unwrap().is_none());
        assert!(!LinkSpec::default().enabled());
        // codec-only spec is enabled without a byte budget
        let codec_only = LinkSpec { codec: CodecKind::QuantQ8, ..Default::default() };
        assert!(codec_only.enabled() && !codec_only.capacity_enabled());
        // rejections
        let bad = LinkSpec { topk_frac: 0.0, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = LinkSpec { topk_frac: 1.5, ..Default::default() };
        assert!(bad.validate().is_err());
        assert!(CodecKind::parse("gzip").is_err());
        for k in [CodecKind::Identity, CodecKind::TopK, CodecKind::QuantQ8] {
            assert_eq!(CodecKind::parse(k.name()).unwrap(), k);
        }
    }

    #[test]
    fn payload_bytes_matches_the_wire_model() {
        let d = 1000;
        assert_eq!(LinkSpec::default().payload_bytes(d), 4000);
        let topk = LinkSpec { codec: CodecKind::TopK, topk_frac: 0.01, ..Default::default() };
        assert_eq!(topk.topk_k(d), 10);
        assert_eq!(topk.payload_bytes(d), 80, "8 bytes per kept (index, value) pair");
        let q8 = LinkSpec { codec: CodecKind::QuantQ8, ..Default::default() };
        assert_eq!(q8.payload_bytes(d), 1004);
        // k is at least 1 even for tiny models
        assert_eq!(topk.topk_k(3), 1);
    }
}
