//! The federated-learning core (paper §2.3–2.4): GS state, gradient buffer,
//! staleness compensation, the four aggregation-indicator policies, the
//! 3-satellite illustrative example behind Figures 3–4 / Table 1, the
//! multi-gateway [`Federation`] layer (ADR-0006) that generalizes the
//! single logical FL server to per-gateway buffers with deterministic
//! cross-gateway reconciliation, and the throughput-grade serving driver
//! ([`serve`], ADR-0010) over the clock-agnostic [`FederationCore`].

pub mod algorithms;
pub mod buffer;
pub mod client;
pub mod codec;
pub mod federation;
pub mod illustrative;
pub mod robust;
pub mod serve;
pub mod server;
pub mod staleness;

pub use algorithms::{AggregationPolicy, AsyncPolicy, FedBuffPolicy, ScheduledPolicy, SyncPolicy};
pub use buffer::{Buffer, GradientEntry};
pub use codec::{CodecKind, LinkSpec, Update, UpdateCodec, CODEC_STREAM};
pub use client::{SatClient, SatPhase};
pub use federation::{
    Federation, FederationCore, FederationSpec, Gateway, GatewayWindow, ReconcilePolicy,
    StationMap, UploadRouting,
};
pub use serve::{DrainStats, Offer, PendingUpload, ServeCore, ServeSpec};
pub use robust::{CoordinateMedian, MultiKrum, RobustKind, RobustSpec, TrimmedMean};
pub use server::{weighted_model_merge, CpuAggregator, GsState, ServerAggregator};
pub use staleness::{compensation, normalized_weights};
