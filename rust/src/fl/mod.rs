//! The federated-learning core (paper §2.3–2.4): GS state, gradient buffer,
//! staleness compensation, the four aggregation-indicator policies, and the
//! 3-satellite illustrative example behind Figures 3–4 / Table 1.

pub mod algorithms;
pub mod buffer;
pub mod client;
pub mod illustrative;
pub mod server;
pub mod staleness;

pub use algorithms::{AggregationPolicy, AsyncPolicy, FedBuffPolicy, ScheduledPolicy, SyncPolicy};
pub use buffer::{Buffer, GradientEntry};
pub use client::{SatClient, SatPhase};
pub use server::{CpuAggregator, GsState, ServerAggregator};
pub use staleness::{compensation, normalized_weights};
