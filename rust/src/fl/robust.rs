//! Byzantine-robust Eq.-4 aggregators (ADR-0007): coordinate-wise median,
//! trimmed mean, and multi-Krum beside the reference [`CpuAggregator`]
//! mean, plus the `[robust]` spec that selects one per scenario.
//!
//! The federation trusts every upload; a single poisoned gradient moves the
//! weighted mean arbitrarily far (Eq. 4 is linear in each entry). These
//! aggregators bound that influence. Staleness-weight handling is defined
//! per aggregator:
//!
//! - **Trimmed mean** keeps the Eq.-4 staleness weights: per coordinate the
//!   `t` smallest and `t` largest entry values are discarded and the
//!   survivors' weights renormalized. At `t == 0` (trim fraction below
//!   `1/n`) it takes the exact [`CpuAggregator`] blocked accumulate — the
//!   bit-identity the property tests assert.
//! - **Coordinate median** ignores magnitude weights entirely: the median
//!   is already insensitive to any minority of outliers, and weighting
//!   would reopen the door it closes. Staleness still shapes *when*
//!   gradients arrive; it just no longer scales them here.
//! - **Multi-Krum** (Blanchard et al. 2017) selects whole entries by
//!   pairwise-distance score before aggregating, then applies the Eq.-4
//!   staleness weights renormalized over the selected subset — an
//!   adversary must look like its peers to be heard at all.
//!
//! All three run the 256k-parameter hot path blocked and parallel on
//! [`exec::scope_chunks`]: per-coordinate work is independent, so the model
//! vector is split into cache-sized blocks and each block's delta is
//! computed on its own thread, deterministically at any thread count
//! (block results are combined in block order, and nothing in a block
//! depends on the thread that ran it).

use super::buffer::GradientEntry;
use super::server::{CpuAggregator, ServerAggregator};
use super::staleness::normalized_weights;
use crate::cfg::toml::{TomlDoc, TomlValue};
use crate::exec;
use anyhow::{bail, Context, Result};

/// Elements per parallel block (matches `CpuAggregator`'s cache blocking).
const BLOCK: usize = 4096;

/// Reject entry/model dimension mismatches before touching any element —
/// same hoisted contract as [`CpuAggregator`].
fn check_dims(w: &[f32], entries: &[GradientEntry]) -> Result<()> {
    for entry in entries {
        anyhow::ensure!(
            entry.grad.len() == w.len(),
            "gradient/model dim mismatch: {} vs {}",
            entry.grad.len(),
            w.len()
        );
    }
    Ok(())
}

/// Compute per-block deltas in parallel and apply them to `w` in block
/// order. `per_coord(e)` returns the robust update for coordinate `e`;
/// it must not depend on anything thread-local, which makes the result
/// bit-identical at any thread count.
fn blocked_apply<F: Fn(usize) -> f32 + Sync>(w: &mut [f32], per_coord: F) {
    let d = w.len();
    let blocks: Vec<usize> = (0..d.div_ceil(BLOCK)).collect();
    let threads = exec::default_parallelism();
    let deltas: Vec<Vec<f32>> = exec::scope_chunks(&blocks, threads, |_, chunk| {
        chunk
            .iter()
            .map(|&b| {
                let lo = b * BLOCK;
                let hi = ((b + 1) * BLOCK).min(d);
                (lo..hi).map(&per_coord).collect()
            })
            .collect()
    });
    for (b, delta) in deltas.iter().enumerate() {
        let lo = b * BLOCK;
        for (wi, di) in w[lo..].iter_mut().zip(delta.iter()) {
            *wi += di;
        }
    }
}

/// Coordinate-wise median: `w[e] += median_k(g_k[e])`. Unweighted by
/// design (see module docs); the even-count median is the midpoint of the
/// two central values. Permutation-invariant: each coordinate sorts its
/// values, so entry order cannot change a bit of the output.
pub struct CoordinateMedian;

impl ServerAggregator for CoordinateMedian {
    fn aggregate(
        &mut self,
        w: &mut Vec<f32>,
        entries: &[GradientEntry],
        _alpha: f64,
    ) -> Result<()> {
        if entries.is_empty() {
            return Ok(());
        }
        check_dims(w, entries)?;
        let n = entries.len();
        blocked_apply(w, |e| {
            let mut vals: Vec<f32> = entries.iter().map(|en| en.grad.at(e)).collect();
            vals.sort_unstable_by(f32::total_cmp);
            if n % 2 == 1 {
                vals[n / 2]
            } else {
                0.5 * (vals[n / 2 - 1] + vals[n / 2])
            }
        });
        Ok(())
    }
}

/// Trimmed mean: per coordinate, drop the `t` smallest and `t` largest
/// entry values (`t = ⌊trim · n⌋`, clamped so at least one survives), then
/// take the staleness-weighted mean of the survivors with renormalized
/// weights. With up to `t` adversarial entries the output stays inside the
/// honest values' range per coordinate (property-tested). `t == 0` is the
/// exact [`CpuAggregator`] accumulate, bit for bit.
pub struct TrimmedMean {
    /// Fraction trimmed from *each* side, in `[0, 0.5)`.
    pub trim: f64,
}

impl ServerAggregator for TrimmedMean {
    fn aggregate(&mut self, w: &mut Vec<f32>, entries: &[GradientEntry], alpha: f64) -> Result<()> {
        if entries.is_empty() {
            return Ok(());
        }
        let n = entries.len();
        let t = ((self.trim * n as f64).floor() as usize).min((n - 1) / 2);
        if t == 0 {
            // nothing to trim: take the reference blocked accumulate so a
            // trim=0 spec is bit-identical to the plain mean
            return CpuAggregator.aggregate(w, entries, alpha);
        }
        check_dims(w, entries)?;
        let stalenesses: Vec<usize> = entries.iter().map(|e| e.staleness).collect();
        let weights = normalized_weights(&stalenesses, alpha);
        blocked_apply(w, |e| {
            let mut pairs: Vec<(f32, f32)> =
                entries.iter().zip(weights.iter()).map(|(en, &wt)| (en.grad.at(e), wt)).collect();
            // total order on (value, weight) so equal values with unequal
            // weights trim identically under any entry permutation
            pairs.sort_unstable_by(|a, b| {
                a.0.total_cmp(&b.0).then_with(|| a.1.total_cmp(&b.1))
            });
            let survivors = &pairs[t..n - t];
            let mut num = 0.0f64;
            let mut den = 0.0f64;
            for &(v, wt) in survivors {
                num += wt as f64 * v as f64;
                den += wt as f64;
            }
            if den > 0.0 {
                (num / den) as f32
            } else {
                0.0
            }
        });
        Ok(())
    }
}

/// Multi-Krum (Blanchard et al. 2017, adapted to buffered uploads): score
/// every entry by the sum of its `n - f - 2` smallest squared distances to
/// the other entries, keep the `m` best-scored entries, and aggregate them
/// with Eq.-4 staleness weights renormalized over the selection. Entries
/// far from every cluster (scaled or flipped gradients) score badly and
/// are excluded wholesale. `m == 0` means "auto": keep `n - f`. With
/// `n < f + 3` the score is undefined and the aggregator degrades to the
/// weighted mean over all entries (documented fallback, not an error —
/// tiny buffers are common early in a run).
///
/// Deterministic and permutation-invariant: selection ties break on
/// `(score, sat, staleness)` and the selected entries accumulate in that
/// canonical order.
pub struct MultiKrum {
    /// Assumed upper bound on Byzantine entries per buffer.
    pub f: usize,
    /// Entries to keep (0 = auto: `n - f`).
    pub m: usize,
}

impl ServerAggregator for MultiKrum {
    fn aggregate(&mut self, w: &mut Vec<f32>, entries: &[GradientEntry], alpha: f64) -> Result<()> {
        if entries.is_empty() {
            return Ok(());
        }
        check_dims(w, entries)?;
        let n = entries.len();
        if n < self.f + 3 {
            return CpuAggregator.aggregate(w, entries, alpha);
        }
        // pairwise squared distances, one row per entry, rows in parallel
        let idx: Vec<usize> = (0..n).collect();
        let threads = exec::default_parallelism();
        let rows: Vec<Vec<f64>> = exec::scope_chunks(&idx, threads, |_, chunk| {
            chunk
                .iter()
                .map(|&i| {
                    (0..n)
                        .map(|j| {
                            if i == j {
                                return 0.0;
                            }
                            // dense×dense takes the exact pre-codec loop;
                            // sparse operands read lazily per coordinate
                            entries[i].grad.sq_dist(&entries[j].grad)
                        })
                        .collect()
                })
                .collect()
        });
        let neighbors = n - self.f - 2;
        let mut scored: Vec<(f64, usize)> = rows
            .iter()
            .enumerate()
            .map(|(i, row)| {
                let mut dists: Vec<f64> =
                    row.iter().enumerate().filter(|&(j, _)| j != i).map(|(_, &d)| d).collect();
                dists.sort_unstable_by(f64::total_cmp);
                (dists[..neighbors.max(1).min(dists.len())].iter().sum(), i)
            })
            .collect();
        // canonical selection order: score, then intrinsic entry identity
        scored.sort_unstable_by(|a, b| {
            a.0.total_cmp(&b.0)
                .then_with(|| entries[a.1].sat.cmp(&entries[b.1].sat))
                .then_with(|| entries[a.1].staleness.cmp(&entries[b.1].staleness))
        });
        let m = if self.m == 0 { n - self.f } else { self.m };
        let m = m.clamp(1, n);
        let selected: Vec<&GradientEntry> =
            scored[..m].iter().map(|&(_, i)| &entries[i]).collect();
        let stalenesses: Vec<usize> = selected.iter().map(|e| e.staleness).collect();
        let weights = normalized_weights(&stalenesses, alpha);
        blocked_apply(w, |e| {
            let mut acc = 0.0f32;
            for (entry, &wt) in selected.iter().zip(weights.iter()) {
                acc += wt * entry.grad.at(e);
            }
            acc
        });
        Ok(())
    }
}

/// Which Eq.-4 aggregator a run uses (the `[robust]` TOML `aggregator`
/// key); `Mean` is the implicit default — the untouched [`CpuAggregator`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RobustKind {
    /// The reference staleness-weighted mean ([`CpuAggregator`]).
    #[default]
    Mean,
    /// Coordinate-wise median ([`CoordinateMedian`]).
    Median,
    /// Per-coordinate trimmed mean ([`TrimmedMean`]).
    TrimmedMean,
    /// Entry-level multi-Krum selection ([`MultiKrum`]).
    MultiKrum,
}

impl RobustKind {
    /// Parse the TOML/CLI spelling.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "mean" => RobustKind::Mean,
            "median" => RobustKind::Median,
            "trimmed-mean" | "trimmed_mean" | "trimmed" => RobustKind::TrimmedMean,
            "multi-krum" | "multi_krum" | "krum" => RobustKind::MultiKrum,
            other => bail!(
                "unknown robust aggregator {other:?} (mean | median | trimmed-mean | multi-krum)"
            ),
        })
    }

    /// Canonical lowercase name (inverse of [`Self::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            RobustKind::Mean => "mean",
            RobustKind::Median => "median",
            RobustKind::TrimmedMean => "trimmed-mean",
            RobustKind::MultiKrum => "multi-krum",
        }
    }
}

/// The `[robust]` TOML section on `Scenario` and `ExperimentConfig`:
/// which aggregator Eq. 4 runs through, with its knobs. Omitted ⇒ the
/// default ⇒ [`CpuAggregator`] ⇒ bit-identical pre-robust runs (specs
/// stay byte-identical too — the section is only emitted when
/// non-default).
#[derive(Clone, Debug, PartialEq)]
pub struct RobustSpec {
    /// Aggregator family.
    pub aggregator: RobustKind,
    /// Trim fraction per side for `trimmed-mean`, in `[0, 0.5)`.
    pub trim: f64,
    /// Assumed Byzantine entries per buffer for `multi-krum`.
    pub krum_f: usize,
    /// Entries `multi-krum` keeps (0 = auto: `n - f`).
    pub krum_m: usize,
}

impl Default for RobustSpec {
    fn default() -> Self {
        RobustSpec { aggregator: RobustKind::Mean, trim: 0.1, krum_f: 1, krum_m: 0 }
    }
}

impl RobustSpec {
    /// Exactly the implicit default (controls `[robust]` emission).
    pub fn is_default(&self) -> bool {
        *self == Self::default()
    }

    /// Reject self-inconsistent specs.
    pub fn validate(&self) -> Result<()> {
        if !(0.0..0.5).contains(&self.trim) {
            bail!("[robust] trim must be in [0, 0.5), got {}", self.trim);
        }
        Ok(())
    }

    /// Build the live aggregator this spec names.
    pub fn make(&self) -> Box<dyn ServerAggregator> {
        match self.aggregator {
            RobustKind::Mean => Box::new(CpuAggregator),
            RobustKind::Median => Box::new(CoordinateMedian),
            RobustKind::TrimmedMean => Box::new(TrimmedMean { trim: self.trim }),
            RobustKind::MultiKrum => Box::new(MultiKrum { f: self.krum_f, m: self.krum_m }),
        }
    }

    /// Emit the `[robust]` TOML section (callers skip the call when
    /// [`Self::is_default`] so pre-robust specs stay byte-identical).
    pub fn emit_toml(&self, out: &mut String) {
        use std::fmt::Write as _;
        let _ = writeln!(out, "\n[robust]");
        let _ = writeln!(out, "aggregator = \"{}\"", self.aggregator.name());
        let _ = writeln!(out, "trim = {}", self.trim);
        let _ = writeln!(out, "krum_f = {}", self.krum_f);
        let _ = writeln!(out, "krum_m = {}", self.krum_m);
    }

    /// Parse the `[robust]` section; `Ok(None)` when absent (callers keep
    /// their default) — the shared scenario/experiment-config idiom.
    pub fn from_doc(doc: &TomlDoc) -> Result<Option<RobustSpec>> {
        if doc.get("robust").is_none() {
            return Ok(None);
        }
        let get = |key: &str| -> Option<&TomlValue> { doc.get("robust").and_then(|s| s.get(key)) };
        let mut spec = RobustSpec::default();
        if let Some(v) = get("aggregator") {
            spec.aggregator =
                RobustKind::parse(v.as_str().context("[robust] aggregator must be a string")?)?;
        }
        if let Some(v) = get("trim") {
            spec.trim = v.as_float().context("[robust] trim must be a number")?;
        }
        if let Some(v) = get("krum_f") {
            spec.krum_f =
                usize::try_from(v.as_int().context("[robust] krum_f must be an integer")?)?;
        }
        if let Some(v) = get("krum_m") {
            spec.krum_m =
                usize::try_from(v.as_int().context("[robust] krum_m must be an integer")?)?;
        }
        Ok(Some(spec))
    }
}

impl crate::cfg::section::SectionSpec for RobustSpec {
    const SECTION: &'static str = "robust";

    fn from_doc(doc: &TomlDoc) -> Result<Option<Self>> {
        RobustSpec::from_doc(doc)
    }

    fn emit_toml(&self, out: &mut String) {
        RobustSpec::emit_toml(self, out)
    }

    fn is_emitted(&self) -> bool {
        !self.is_default()
    }

    fn validate(&self, _ctx: &crate::cfg::section::SectionCtx) -> Result<()> {
        RobustSpec::validate(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(sat: usize, staleness: usize, grad: Vec<f32>) -> GradientEntry {
        GradientEntry { sat, staleness, grad: grad.into(), n_samples: 1 }
    }

    #[test]
    fn median_odd_and_even_counts() {
        let mut w = vec![0.0f32; 2];
        let entries = vec![
            entry(0, 0, vec![1.0, -3.0]),
            entry(1, 0, vec![2.0, 5.0]),
            entry(2, 0, vec![100.0, 1.0]),
        ];
        CoordinateMedian.aggregate(&mut w, &entries, 0.5).unwrap();
        assert_eq!(w, vec![2.0, 1.0], "odd count: middle value, outlier ignored");
        let mut w = vec![0.0f32];
        let entries =
            vec![entry(0, 0, vec![1.0]), entry(1, 0, vec![3.0]), entry(2, 0, vec![5.0]),
                 entry(3, 0, vec![7.0])];
        CoordinateMedian.aggregate(&mut w, &entries, 0.5).unwrap();
        assert_eq!(w, vec![4.0], "even count: midpoint of the two central values");
    }

    #[test]
    fn trimmed_mean_drops_extremes_per_coordinate() {
        // 5 equal-staleness entries, trim 0.2 -> t = 1 per side
        let mut w = vec![0.0f32];
        let entries = vec![
            entry(0, 0, vec![-1000.0]),
            entry(1, 0, vec![1.0]),
            entry(2, 0, vec![2.0]),
            entry(3, 0, vec![3.0]),
            entry(4, 0, vec![1000.0]),
        ];
        TrimmedMean { trim: 0.2 }.aggregate(&mut w, &entries, 0.5).unwrap();
        assert!((w[0] - 2.0).abs() < 1e-6, "{w:?}");
    }

    #[test]
    fn trim_zero_is_bit_identical_to_mean() {
        let mut rng = crate::rng::Rng::new(11);
        let d = 2 * super::BLOCK + 5;
        let w0: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let entries: Vec<GradientEntry> = (0..4)
            .map(|s| entry(s, s % 3, (0..d).map(|_| rng.normal_f32(0.0, 0.1)).collect()))
            .collect();
        let mut a = w0.clone();
        let mut b = w0.clone();
        TrimmedMean { trim: 0.0 }.aggregate(&mut a, &entries, 0.5).unwrap();
        CpuAggregator.aggregate(&mut b, &entries, 0.5).unwrap();
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn multi_krum_excludes_the_scaled_outlier() {
        // 5 clustered honest entries + 1 scaled adversary; f=1 keeps n-f=5
        let mut rng = crate::rng::Rng::new(3);
        let d = 64;
        let honest: Vec<f32> = (0..d).map(|_| rng.normal_f32(1.0, 0.01)).collect();
        let mut entries: Vec<GradientEntry> = (0..5)
            .map(|s| {
                entry(s, 0, honest.iter().map(|v| v + rng.normal_f32(0.0, 0.01)).collect())
            })
            .collect();
        entries.push(entry(5, 0, honest.iter().map(|v| v * -50.0).collect()));
        let mut w = vec![0.0f32; d];
        MultiKrum { f: 1, m: 0 }.aggregate(&mut w, &entries, 0.5).unwrap();
        for v in &w {
            assert!((v - 1.0).abs() < 0.1, "adversary leaked into the update: {v}");
        }
    }

    #[test]
    fn multi_krum_tiny_buffer_falls_back_to_mean() {
        let mut w = vec![0.0f32; 2];
        let entries = vec![entry(0, 0, vec![2.0, 4.0]), entry(1, 0, vec![4.0, 2.0])];
        let mut w_mean = w.clone();
        MultiKrum { f: 1, m: 0 }.aggregate(&mut w, &entries, 0.5).unwrap();
        CpuAggregator.aggregate(&mut w_mean, &entries, 0.5).unwrap();
        assert_eq!(w, w_mean, "n < f + 3 degrades to the weighted mean");
    }

    #[test]
    fn robust_aggregators_reject_dim_mismatch_untouched() {
        let entries = vec![entry(0, 0, vec![1.0; 4]), entry(1, 0, vec![1.0; 3])];
        let aggs: Vec<Box<dyn ServerAggregator>> = vec![
            Box::new(CoordinateMedian),
            Box::new(TrimmedMean { trim: 0.3 }),
            Box::new(MultiKrum { f: 0, m: 0 }),
        ];
        for mut a in aggs {
            let mut w = vec![0.0f32; 4];
            assert!(a.aggregate(&mut w, &entries, 0.5).is_err());
            assert_eq!(w, vec![0.0f32; 4], "failed aggregation must not touch the model");
        }
    }

    #[test]
    fn empty_buffer_is_identity_for_all() {
        for mut a in [
            Box::new(CoordinateMedian) as Box<dyn ServerAggregator>,
            Box::new(TrimmedMean { trim: 0.2 }),
            Box::new(MultiKrum { f: 1, m: 0 }),
        ] {
            let mut w = vec![7.0f32; 3];
            a.aggregate(&mut w, &[], 0.5).unwrap();
            assert_eq!(w, vec![7.0f32; 3]);
        }
    }

    #[test]
    fn sparse_entries_aggregate_like_their_dense_view() {
        // lazy per-coordinate densify (ADR-0008): a sparse wire-form entry
        // must aggregate exactly like its dense materialization in every
        // robust family — `at(e)` reads 0.0 for unlisted coordinates and
        // the stored bits for listed ones, so the per-coordinate math is
        // literally the same
        use crate::fl::codec::Update;
        let d = super::BLOCK + 33;
        let mut rng = crate::rng::Rng::new(17);
        let mut entries: Vec<GradientEntry> = Vec::new();
        for s in 0..5usize {
            let grad = if s % 2 == 0 {
                let idx: Vec<u32> = (0..d as u32).filter(|j| (j + s as u32) % 53 == 0).collect();
                let val: Vec<f32> = idx.iter().map(|_| rng.normal_f32(0.0, 1.0)).collect();
                Update::Sparse { dim: d, idx, val }
            } else {
                Update::Dense((0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            };
            entries.push(GradientEntry { sat: s, staleness: s % 2, grad, n_samples: 1 });
        }
        let dense: Vec<GradientEntry> = entries
            .iter()
            .map(|e| GradientEntry {
                sat: e.sat,
                staleness: e.staleness,
                grad: e.grad.to_dense().into(),
                n_samples: e.n_samples,
            })
            .collect();
        let families: Vec<fn() -> Box<dyn ServerAggregator>> = vec![
            || Box::new(CoordinateMedian),
            || Box::new(TrimmedMean { trim: 0.2 }),
            || Box::new(MultiKrum { f: 1, m: 0 }),
        ];
        for make in families {
            let mut a = vec![0.25f32; d];
            let mut b = vec![0.25f32; d];
            make().aggregate(&mut a, &entries, 0.5).unwrap();
            make().aggregate(&mut b, &dense, 0.5).unwrap();
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn spec_round_trips_and_validates() {
        let mut spec = RobustSpec {
            aggregator: RobustKind::TrimmedMean,
            trim: 0.15,
            krum_f: 2,
            krum_m: 4,
        };
        let mut s = String::new();
        spec.emit_toml(&mut s);
        let doc = crate::cfg::toml::parse_toml(&s).unwrap();
        let back = RobustSpec::from_doc(&doc).unwrap().expect("section present");
        assert_eq!(back, spec, "{s}");
        // absent section -> None; default never emits
        let doc = crate::cfg::toml::parse_toml("[scenario]\nname = \"x\"").unwrap();
        assert!(RobustSpec::from_doc(&doc).unwrap().is_none());
        assert!(RobustSpec::default().is_default());
        // invalid trim rejected
        spec.trim = 0.5;
        assert!(spec.validate().is_err());
        spec.trim = -0.1;
        assert!(spec.validate().is_err());
        assert!(RobustKind::parse("huber").is_err());
        for k in
            [RobustKind::Mean, RobustKind::Median, RobustKind::TrimmedMean, RobustKind::MultiKrum]
        {
            assert_eq!(RobustKind::parse(k.name()).unwrap(), k);
        }
    }

    #[test]
    fn spec_make_builds_each_family() {
        // the made aggregator behaves like its family on a known buffer
        let entries = vec![
            entry(0, 0, vec![1.0]),
            entry(1, 0, vec![2.0]),
            entry(2, 0, vec![900.0]),
        ];
        let spec = RobustSpec { aggregator: RobustKind::Median, ..Default::default() };
        let mut w = vec![0.0f32];
        spec.make().aggregate(&mut w, &entries, 0.5).unwrap();
        assert_eq!(w, vec![2.0]);
        let mean = RobustSpec::default();
        let mut w = vec![0.0f32];
        mean.make().aggregate(&mut w, &entries, 0.5).unwrap();
        assert!(w[0] > 100.0, "mean is poisoned by the outlier: {w:?}");
    }
}
