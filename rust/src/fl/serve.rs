//! The long-lived serving driver over [`FederationCore`] (ADR-0010).
//!
//! The sim engine drives the federation synchronously: one step, one pass
//! over the step's contacts, aggregation decided inline. A serving front
//! end cannot work that way — uploads arrive whenever a pass opens, burst
//! with the constellation geometry, and the server must keep accepting
//! while it aggregates. This module is the second driver the ADR-0010
//! split exists for:
//!
//! - **Bounded ingestion queue per gateway.** [`ServeCore::offer`] enqueues
//!   an upload at its gateway; a full queue returns
//!   [`Offer::Deferred`] *with the upload handed back* — the PR 7
//!   deferred-upload semantics reused as backpressure. Nothing is dropped
//!   and nothing is reordered: a gateway's queue is strictly FIFO.
//! - **Sharded ingest validation.** Each drain batch is validated
//!   (dimension + finiteness) across [`exec::scope_chunks`] worker shards.
//!   `scope_chunks` is order-preserving and thread-count independent, so
//!   the shard count is a resource knob, never a semantics knob — the
//!   shard-determinism tests gate exactly this.
//! - **Batched, double-buffered aggregation.** [`ServeCore::drain`] splits
//!   at most `batch` uploads off the *front* of each queue and aggregates
//!   them while the queue itself keeps accepting new offers — the
//!   in-process form of double buffering. One drain is one tick of the
//!   serving clock, and [`FederationCore::on_boundary`] maps ticks onto
//!   the same `Periodic`/`Quorum` reconcile cadence the sim driver uses.
//! - **Observability.** Each drain emits deterministic
//!   [`RunEvent::ServeBatch`] events (queue depth, drained count, deferred
//!   count) plus the standard `Aggregate`/`Reconcile` events, so serving
//!   runs flow through the exact PR 8 sink/artifact layer sim runs do.
//!   Wall-clock throughput lands in the identity-exempt
//!   [`RunEvent::ServeReport`].
//!
//! Model state is deterministic per (trace, seed, spec); wall-clock timing
//! is not — that asymmetry is the point (ADR-0010), and `is_deterministic`
//! encodes it per event.

use super::codec::Update;
use super::federation::{FederationCore, FederationSpec};
use super::server::ServerAggregator;
use crate::cfg::section::{SectionCtx, SectionSpec};
use crate::cfg::toml::TomlDoc;
use crate::exec;
use crate::sim::events::{EventSink, RunEvent};
use anyhow::{bail, Context, Result};
use std::collections::VecDeque;
use std::fmt::Write as _;

/// The `[serve]` TOML section: the serving front end's resource shape.
/// Like `[sim] threads`, every knob here is a resource knob, not a
/// semantics knob — the final model is identical at any shard count, and
/// queue capacity changes only *when* an upload is accepted, never whether
/// it eventually is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServeSpec {
    /// Bounded ingestion-queue capacity per gateway; a full queue defers
    /// (backpressure), it never drops.
    pub queue_cap: usize,
    /// Maximum uploads drained from one gateway's queue per serving tick.
    pub batch: usize,
    /// Validation worker shards per drain batch (0 = auto, the exec-layer
    /// default parallelism).
    pub shards: usize,
}

impl Default for ServeSpec {
    fn default() -> Self {
        ServeSpec { queue_cap: 1024, batch: 256, shards: 0 }
    }
}

impl ServeSpec {
    /// Exactly the implicit default (controls `[serve]` emission).
    pub fn is_default(&self) -> bool {
        *self == ServeSpec::default()
    }

    /// Reject shapes the serving core cannot honour.
    pub fn validate(&self) -> Result<()> {
        if self.queue_cap == 0 {
            bail!("[serve] queue_cap must be > 0 (a zero-capacity queue defers everything)");
        }
        if self.batch == 0 {
            bail!("[serve] batch must be > 0 (a zero batch would never drain)");
        }
        Ok(())
    }

    /// Emit the `[serve]` TOML section (callers skip it when default so
    /// pre-serving specs stay byte-identical).
    pub fn emit_toml(&self, out: &mut String) {
        let _ = writeln!(out, "\n[serve]");
        let _ = writeln!(out, "queue_cap = {}", self.queue_cap);
        let _ = writeln!(out, "batch = {}", self.batch);
        let _ = writeln!(out, "shards = {}", self.shards);
    }

    /// Parse the `[serve]` section; `Ok(None)` when absent (callers keep
    /// their default) — the shared scenario/experiment-config idiom.
    pub fn from_doc(doc: &TomlDoc) -> Result<Option<ServeSpec>> {
        let Some(section) = doc.get("serve") else {
            return Ok(None);
        };
        let mut spec = ServeSpec::default();
        let read = |key: &str| -> Result<Option<usize>> {
            match section.get(key) {
                None => Ok(None),
                Some(v) => {
                    let n =
                        v.as_int().with_context(|| format!("[serve] {key} must be an integer"))?;
                    Ok(Some(usize::try_from(n)?))
                }
            }
        };
        if let Some(n) = read("queue_cap")? {
            spec.queue_cap = n;
        }
        if let Some(n) = read("batch")? {
            spec.batch = n;
        }
        if let Some(n) = read("shards")? {
            spec.shards = n;
        }
        Ok(Some(spec))
    }
}

impl SectionSpec for ServeSpec {
    const SECTION: &'static str = "serve";

    fn from_doc(doc: &TomlDoc) -> Result<Option<Self>> {
        ServeSpec::from_doc(doc)
    }

    fn emit_toml(&self, out: &mut String) {
        ServeSpec::emit_toml(self, out)
    }

    fn is_emitted(&self) -> bool {
        !self.is_default()
    }

    fn validate(&self, _ctx: &SectionCtx) -> Result<()> {
        ServeSpec::validate(self)
    }
}

/// One upload waiting in a gateway's ingestion queue: exactly the
/// arguments the caller would have passed to [`FederationCore::receive`],
/// in wire form.
#[derive(Clone, Debug)]
pub struct PendingUpload {
    /// Originating satellite id.
    pub sat: usize,
    /// The (possibly codec-compressed) gradient payload.
    pub grad: Update,
    /// Global round the satellite's local model was based on (fixed when
    /// the upload was *generated*; staleness accrues while it queues).
    pub base_round: usize,
    /// Local sample count behind the gradient.
    pub n_samples: usize,
}

/// Outcome of one [`ServeCore::offer`].
#[derive(Debug)]
pub enum Offer {
    /// The upload entered its gateway's queue.
    Accepted,
    /// The queue is full: the upload is handed back untouched and the
    /// caller retries later — PR 7's deferred-upload semantics as
    /// backpressure. Never a drop, never a reorder.
    Deferred(PendingUpload),
}

/// Per-drain summary returned by [`ServeCore::drain`].
#[derive(Clone, Copy, Debug, Default)]
pub struct DrainStats {
    /// Uploads taken off queues and received into gateway buffers.
    pub drained: usize,
    /// Gateway aggregations performed this tick.
    pub aggregations: usize,
    /// Whether the tick boundary fired a cross-gateway merge.
    pub merged: bool,
}

/// The serving driver: bounded per-gateway ingestion queues in front of a
/// clock-agnostic [`FederationCore`], drained in batches on the serving
/// clock. See the module docs for the full contract.
pub struct ServeCore {
    core: FederationCore,
    spec: ServeSpec,
    queues: Vec<VecDeque<PendingUpload>>,
    /// Offers deferred per gateway since its last drain (reported in the
    /// next `ServeBatch` event, then reset).
    deferred_since_drain: Vec<usize>,
    ticks: usize,
    accepted: u64,
    deferred: u64,
    rejected: u64,
    /// Power-of-two queue-depth histogram: bucket 0 is depth 0, bucket
    /// `b > 0` covers depths in `[2^(b-1), 2^b)`.
    depth_hist: Vec<u64>,
}

impl ServeCore {
    /// A fresh serving core around an initial model.
    pub fn new(fed: &FederationSpec, spec: &ServeSpec, w0: Vec<f32>, alpha: f64) -> Self {
        Self::from_core(FederationCore::new(fed, w0, alpha), spec)
    }

    /// Wrap an existing federation core (e.g. state handed over from a sim
    /// run via `Federation::into_core`).
    pub fn from_core(core: FederationCore, spec: &ServeSpec) -> Self {
        let n = core.n_gateways();
        let buckets = (usize::BITS - spec.queue_cap.leading_zeros()) as usize + 1;
        ServeCore {
            core,
            spec: *spec,
            queues: (0..n).map(|_| VecDeque::new()).collect(),
            deferred_since_drain: vec![0; n],
            ticks: 0,
            accepted: 0,
            deferred: 0,
            rejected: 0,
            depth_hist: vec![0; buckets],
        }
    }

    /// The wrapped clock-agnostic state machine.
    pub fn core(&self) -> &FederationCore {
        &self.core
    }

    /// Decompose back into the bare federation core.
    pub fn into_core(self) -> FederationCore {
        self.core
    }

    /// Serving ticks (drains) completed.
    pub fn ticks(&self) -> usize {
        self.ticks
    }

    /// Uploads accepted into queues so far.
    pub fn accepted(&self) -> u64 {
        self.accepted
    }

    /// Offers backpressured so far.
    pub fn deferred(&self) -> u64 {
        self.deferred
    }

    /// Uploads that failed ingest validation and were discarded.
    pub fn rejected(&self) -> u64 {
        self.rejected
    }

    /// Current depth of gateway `g`'s ingestion queue.
    pub fn queue_depth(&self, g: usize) -> usize {
        self.queues[g].len()
    }

    /// The power-of-two queue-depth histogram, sampled once per gateway
    /// per drain (bucket 0 = empty queue).
    pub fn depth_hist(&self) -> &[u64] {
        &self.depth_hist
    }

    /// Offer one upload to gateway `g`'s bounded queue. A full queue
    /// defers — the upload comes back to the caller, untouched, for retry.
    pub fn offer(&mut self, g: usize, up: PendingUpload) -> Offer {
        if self.queues[g].len() >= self.spec.queue_cap {
            self.deferred_since_drain[g] += 1;
            self.deferred += 1;
            return Offer::Deferred(up);
        }
        self.queues[g].push_back(up);
        self.accepted += 1;
        Offer::Accepted
    }

    /// One tick of the serving clock: for every gateway in index order,
    /// split up to `batch` uploads off the front of its queue (the queue
    /// keeps accepting — the double buffer), validate them across worker
    /// shards, receive the valid ones FIFO, and aggregate. The tick then
    /// reports the boundary to the core, which fires the `Periodic` /
    /// `Quorum` reconcile cadence on the serving clock.
    pub fn drain<S: EventSink>(
        &mut self,
        aggregator: &mut dyn ServerAggregator,
        sink: &mut S,
    ) -> Result<DrainStats> {
        let tick = self.ticks + 1;
        let dim = self.core.model_dim();
        let shards =
            if self.spec.shards == 0 { exec::default_parallelism() } else { self.spec.shards };
        let mut stats = DrainStats::default();
        for g in 0..self.core.n_gateways() {
            let depth = self.queues[g].len();
            let bucket = (usize::BITS - depth.leading_zeros()) as usize;
            let bucket = bucket.min(self.depth_hist.len() - 1);
            self.depth_hist[bucket] += 1;
            let deferred = std::mem::take(&mut self.deferred_since_drain[g]);
            let take = depth.min(self.spec.batch);
            let batch: Vec<PendingUpload> = self.queues[g].drain(..take).collect();
            // sharded ingest validation: order-preserving by scope_chunks'
            // contract, so any shard count accepts the same uploads in the
            // same order
            let valid: Vec<bool> = exec::scope_chunks(&batch, shards, |_start, chunk| {
                chunk
                    .iter()
                    .map(|u| u.grad.len() == dim && u.grad.values().iter().all(|v| v.is_finite()))
                    .collect()
            });
            let mut drained = 0;
            for (up, ok) in batch.into_iter().zip(valid) {
                if !ok {
                    self.rejected += 1;
                    continue;
                }
                self.core.receive(g, up.sat, up.grad, up.base_round, up.n_samples);
                drained += 1;
            }
            if drained > 0 {
                let staleness = self.core.update(g, aggregator)?;
                let round = self.core.round();
                sink.emit(&RunEvent::Aggregate { step: tick, gateway: g, round, staleness });
                stats.aggregations += 1;
            }
            sink.emit(&RunEvent::ServeBatch { tick, gateway: g, drained, depth, deferred });
            stats.drained += drained;
        }
        self.ticks = tick;
        stats.merged = self.core.on_boundary(tick);
        if stats.merged {
            sink.emit(&RunEvent::Reconcile { step: tick, merges: 1 });
        }
        Ok(stats)
    }
}

/// Nearest-rank percentile over an unsorted latency sample set (`p` in
/// `[0, 100]`); 0 when the set is empty. The loadgen's p50/p99 reducer.
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("latency samples must be comparable"));
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.saturating_sub(1).min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::{CpuAggregator, ReconcilePolicy};
    use crate::sim::events::ArtifactSink;
    use crate::sim::NullSink;

    fn spec2() -> FederationSpec {
        FederationSpec::split(
            &["north", "south"],
            &[0, 1],
            ReconcilePolicy::Periodic { every: 2 },
        )
    }

    fn upload(sat: usize, v: f32, base_round: usize) -> PendingUpload {
        PendingUpload { sat, grad: vec![v, -v].into(), base_round, n_samples: 1 }
    }

    #[test]
    fn serve_spec_roundtrip_validate_and_default_omission() {
        assert!(ServeSpec::default().is_default());
        ServeSpec::default().validate().unwrap();
        let spec = ServeSpec { queue_cap: 8, batch: 2, shards: 3 };
        let mut s = String::new();
        spec.emit_toml(&mut s);
        let doc = crate::cfg::toml::parse_toml(&s).unwrap();
        assert_eq!(ServeSpec::from_doc(&doc).unwrap(), Some(spec));
        let absent = crate::cfg::toml::parse_toml("[scenario]\nname = \"x\"").unwrap();
        assert_eq!(ServeSpec::from_doc(&absent).unwrap(), None);
        assert!(ServeSpec { queue_cap: 0, ..Default::default() }.validate().is_err());
        assert!(ServeSpec { batch: 0, ..Default::default() }.validate().is_err());
        let bad = crate::cfg::toml::parse_toml("[serve]\nqueue_cap = \"big\"").unwrap();
        assert!(ServeSpec::from_doc(&bad).is_err());
    }

    #[test]
    fn backpressure_defers_never_drops_or_reorders() {
        // cap 3, batch 2: the 4th offer must come back (not vanish), and
        // after retrying every deferred offer the served model must equal a
        // federation driven directly in arrival order — the only way that
        // holds is if no upload was dropped or reordered
        let serve_spec = ServeSpec { queue_cap: 3, batch: 2, shards: 2 };
        let mut serve = ServeCore::new(&spec2(), &serve_spec, vec![0.0; 2], 0.5);
        let values: Vec<f32> = (1..=7).map(|i| i as f32 * 0.25).collect();
        let mut pending: VecDeque<PendingUpload> =
            values.iter().enumerate().map(|(i, &v)| upload(i, v, 0)).collect();
        let mut arrival_order = Vec::new();
        let mut guard = 0;
        while let Some(up) = pending.pop_front() {
            guard += 1;
            assert!(guard < 100, "retry loop must converge");
            match serve.offer(0, up) {
                Offer::Accepted => {
                    arrival_order.push(*arrival_order.last().unwrap_or(&0usize) + 1);
                }
                Offer::Deferred(up) => {
                    // the upload comes back intact; drain, then retry it
                    // before anything that arrived after it
                    assert!(serve.deferred() > 0);
                    serve.drain(&mut CpuAggregator, &mut NullSink).unwrap();
                    pending.push_front(up);
                }
            }
        }
        while serve.queue_depth(0) > 0 {
            serve.drain(&mut CpuAggregator, &mut NullSink).unwrap();
        }
        assert_eq!(serve.accepted(), 7);
        assert_eq!(serve.rejected(), 0);
        // reference: the same uploads in arrival order, aggregated in the
        // same batch boundaries the serve core used
        let mut reference = FederationCore::new(&spec2(), vec![0.0; 2], 0.5);
        let mut tick = 0;
        let mut queued = 0;
        for (i, &v) in values.iter().enumerate() {
            reference.receive(0, i, vec![v, -v], 0, 1);
            queued += 1;
            if queued == serve_spec.batch {
                reference.update(0, &mut CpuAggregator).unwrap();
                tick += 1;
                reference.on_boundary(tick);
                queued = 0;
            }
        }
        if queued > 0 {
            reference.update(0, &mut CpuAggregator).unwrap();
            tick += 1;
            reference.on_boundary(tick);
        }
        for (a, b) in serve.core().global_model().iter().zip(reference.global_model().iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "served model diverged from FIFO reference");
        }
    }

    #[test]
    fn shard_count_never_changes_state_or_deterministic_events() {
        // the satellite-task determinism gate: same trace ⇒ identical final
        // model bits and identical deterministic event stream at any
        // worker-shard count
        let mut streams = Vec::new();
        let mut models = Vec::new();
        for shards in [1usize, 2, 3, 8] {
            let serve_spec = ServeSpec { queue_cap: 16, batch: 4, shards };
            let mut serve = ServeCore::new(&spec2(), &serve_spec, vec![0.0; 2], 0.5);
            let mut sink = ArtifactSink::new();
            for round in 0..6usize {
                for sat in 0..5usize {
                    let v = (round * 5 + sat) as f32 * 0.125 - 1.0;
                    let g = sat % 2;
                    match serve.offer(g, upload(sat, v, serve.core().round())) {
                        Offer::Accepted => {}
                        Offer::Deferred(_) => panic!("cap 16 cannot fill in this replay"),
                    }
                }
                serve.drain(&mut CpuAggregator, &mut sink).unwrap();
            }
            let stream: Vec<_> =
                sink.events.into_iter().filter(|e| e.is_deterministic()).collect();
            streams.push(stream);
            models.push(serve.core().global_model().into_owned());
        }
        for i in 1..streams.len() {
            assert_eq!(streams[0], streams[i], "event stream diverged at shard set {i}");
            assert_eq!(models[0].len(), models[i].len());
            for (a, b) in models[0].iter().zip(models[i].iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "model bits diverged at shard set {i}");
            }
        }
    }

    #[test]
    fn invalid_uploads_are_rejected_not_aggregated() {
        let serve_spec = ServeSpec { queue_cap: 8, batch: 8, shards: 2 };
        let mut serve = ServeCore::new(&spec2(), &serve_spec, vec![0.0; 2], 0.5);
        // wrong dimension and a NaN payload: both must be filtered by the
        // sharded validation pass, leaving the good upload aggregated
        let bad_dim = PendingUpload { sat: 0, grad: vec![1.0].into(), base_round: 0, n_samples: 1 };
        let bad_nan =
            PendingUpload { sat: 1, grad: vec![f32::NAN, 0.0].into(), base_round: 0, n_samples: 1 };
        assert!(matches!(serve.offer(0, bad_dim), Offer::Accepted));
        assert!(matches!(serve.offer(0, bad_nan), Offer::Accepted));
        assert!(matches!(serve.offer(0, upload(2, 1.0, 0)), Offer::Accepted));
        let stats = serve.drain(&mut CpuAggregator, &mut NullSink).unwrap();
        assert_eq!(stats.drained, 1);
        assert_eq!(serve.rejected(), 2);
        assert_eq!(serve.core().round(), 1);
    }

    #[test]
    fn drain_ticks_fire_the_reconcile_cadence() {
        // Periodic { every: 2 } on the serving clock: merges at ticks 2, 4
        let serve_spec = ServeSpec { queue_cap: 8, batch: 8, shards: 1 };
        let mut serve = ServeCore::new(&spec2(), &serve_spec, vec![0.0; 2], 0.5);
        let mut merged_ticks = Vec::new();
        for tick in 1..=4usize {
            serve.offer(tick % 2, upload(tick, tick as f32, serve.core().round()));
            let stats = serve.drain(&mut CpuAggregator, &mut NullSink).unwrap();
            if stats.merged {
                merged_ticks.push(tick);
            }
        }
        assert_eq!(merged_ticks, vec![2, 4]);
        assert_eq!(serve.core().reconciles, 2);
    }

    #[test]
    fn depth_histogram_buckets_are_log2() {
        let serve_spec = ServeSpec { queue_cap: 8, batch: 1, shards: 1 };
        let mut serve = ServeCore::new(&spec2(), &serve_spec, vec![0.0; 2], 0.5);
        // depths observed at drain: 0 (bucket 0), then 3 (bucket 2)
        serve.drain(&mut CpuAggregator, &mut NullSink).unwrap();
        for i in 0..3 {
            serve.offer(0, upload(i, 1.0, 0));
        }
        serve.drain(&mut CpuAggregator, &mut NullSink).unwrap();
        let hist = serve.depth_hist();
        assert_eq!(hist[0], 3, "gateway 1 was empty twice, gateway 0 once");
        assert_eq!(hist[2], 1, "depth 3 lands in the [2, 4) bucket");
    }

    #[test]
    fn percentile_is_nearest_rank() {
        assert_eq!(percentile(&[], 99.0), 0.0);
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 99.0), 99.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&[5.0, 1.0, 3.0], 50.0), 3.0);
    }
}
