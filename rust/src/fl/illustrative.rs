//! The 3-satellite illustrative example of §2.4 / Appendix A — the
//! executable form of Figures 3(a), 3(b), 4 and Table 1.
//!
//! Connectivity (reverse-engineered so the executable Algorithm-1 semantics
//! reproduce the paper's Table 1 *exactly* for Sync and Async):
//!
//!   SA1: {0, 2, 3, 4}      SA2: {1, 3, 5, 6, 8}      SA3: {0, 7}
//!
//! over time indexes i ∈ 0..=8, local training completing within one slot.
//! SA3 is the straggler with 2 contacts; there are 8 connections in the
//! window i ∈ [2, 8] the paper counts.
//!
//! Reproduction note (recorded in EXPERIMENTS.md): the paper's FedBuff row
//! (8 aggregated: 7×s=0, 1×s=2; 0 idle) is not reachable under any single
//! execution semantics that also yields its Sync row — Sync's 5 idle
//! connections require satellites to *wait* when the global model hasn't
//! changed, while FedBuff's 8 uploads require them to *retrain* on the
//! unchanged model. Under the self-consistent Algorithm-1 semantics used
//! throughout this crate, FedBuff(M=2) yields 3 global updates (matches),
//! max staleness 2 (matches the "reduced from 5 to 2" headline), with
//! 6 aggregated gradients (5×s=0, 1×s=2) and 2 idle connections.

use crate::connectivity::ConnectivitySchedule;
use crate::metrics::Histogram;

/// The example's connectivity: 3 satellites, 9 slots.
pub fn example_schedule() -> ConnectivitySchedule {
    let contacts: [&[usize]; 3] = [&[0, 2, 3, 4], &[1, 3, 5, 6, 8], &[0, 7]];
    let n_slots = 9;
    let mut sets = vec![Vec::new(); n_slots];
    for (k, cs) in contacts.iter().enumerate() {
        for &i in *cs {
            sets[i].push(k);
        }
    }
    for s in &mut sets {
        s.sort_unstable();
    }
    ConnectivitySchedule::from_sets(sets, 3)
}

/// Aggregation rule for the mini-simulator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rule {
    /// Wait for all three satellites (Eq. 5).
    Sync,
    /// Aggregate on every upload (Eq. 6).
    Async,
    /// Aggregate once `m` distinct satellites contributed (Eq. 7).
    FedBuff {
        /// The buffer threshold M.
        m: usize,
    },
}

/// Outcome of one scheme on the example (one row of Table 1).
#[derive(Clone, Debug)]
pub struct IllustrativeResult {
    /// Scheme name as printed in Table 1.
    pub scheme: &'static str,
    /// Number of global updates over the window.
    pub global_updates: usize,
    /// staleness → count over all aggregated gradients
    pub staleness: Histogram,
    /// Total gradients aggregated (Table 1 "total").
    pub total_aggregated: usize,
    /// connections in i ∈ [2, 8] that carried no upload
    pub idle: usize,
    /// total connections in i ∈ [2, 8] (the paper counts 8)
    pub window_connections: usize,
}

/// Run the pure-scheduling simulation of Algorithm 1 on the example.
///
/// Scheduling-only: gradients are unit markers (the model update itself is
/// irrelevant to Table 1), but the state machine is the same one the full
/// engine uses.
pub fn run(rule: Rule) -> IllustrativeResult {
    let sched = example_schedule();
    let k = sched.n_sats;
    let mut i_g = 0usize;
    // per-satellite: version held, base round of pending update, has update
    let mut held: Vec<Option<usize>> = vec![None; k];
    let mut base: Vec<usize> = vec![0; k];
    let mut pending: Vec<bool> = vec![false; k];
    let mut buffer: Vec<usize> = Vec::new(); // stalenesses (fixed at receive)
    let mut buf_sats: Vec<usize> = Vec::new();
    let mut staleness = Histogram::new();
    let mut updates = 0usize;
    let mut total = 0usize;
    let mut idle = 0usize;
    let mut window_connections = 0usize;

    for i in 0..sched.n_steps() {
        let conn = sched.sets[i].clone();
        // 1. uploads
        let mut uploaded = vec![false; k];
        for &s in &conn {
            if pending[s] {
                buffer.push(i_g - base[s]);
                if !buf_sats.contains(&s) {
                    buf_sats.push(s);
                }
                pending[s] = false;
                uploaded[s] = true;
            }
        }
        // 2. aggregation decision (SCHEDULER + SERVERUPDATE)
        let agg = match rule {
            Rule::Sync => buf_sats.len() >= k,
            Rule::Async => !buffer.is_empty(),
            Rule::FedBuff { m } => buf_sats.len() >= m,
        };
        if agg {
            for &s in &buffer {
                staleness.add(s as i64);
            }
            total += buffer.len();
            updates += 1;
            i_g += 1;
            buffer.clear();
            buf_sats.clear();
        }
        // 3. broadcast (w, i_g) to connected satellites lacking it
        for &s in &conn {
            if held[s] != Some(i_g) {
                held[s] = Some(i_g);
                base[s] = i_g;
                pending[s] = true; // training completes within the slot
            }
        }
        // 4. idle accounting over the paper's window [2, 8]
        if (2..=8).contains(&i) {
            for &s in &conn {
                window_connections += 1;
                if !uploaded[s] {
                    idle += 1;
                }
            }
        }
    }

    IllustrativeResult {
        scheme: match rule {
            Rule::Sync => "sync",
            Rule::Async => "async",
            Rule::FedBuff { .. } => "fedbuff",
        },
        global_updates: updates,
        staleness,
        total_aggregated: total,
        idle,
        window_connections,
    }
}

/// All three rows of Table 1.
pub fn table1() -> Vec<IllustrativeResult> {
    vec![run(Rule::Sync), run(Rule::Async), run(Rule::FedBuff { m: 2 })]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn example_has_8_window_connections() {
        let r = run(Rule::Sync);
        assert_eq!(r.window_connections, 8);
    }

    #[test]
    fn sync_matches_table1_exactly() {
        // Table 1 row "Sync": 1 global update, 3 aggregated (all s=0), 5 idle.
        let r = run(Rule::Sync);
        assert_eq!(r.global_updates, 1);
        assert_eq!(r.total_aggregated, 3);
        assert_eq!(r.staleness.count(0), 3);
        assert_eq!(r.staleness.max_key(), Some(0));
        assert_eq!(r.idle, 5);
    }

    #[test]
    fn async_matches_table1_exactly() {
        // Table 1 row "Async": 7 updates, 8 aggregated (4×s=0, 3×s=1,
        // 1×s=5), 0 idle.
        let r = run(Rule::Async);
        assert_eq!(r.global_updates, 7);
        assert_eq!(r.total_aggregated, 8);
        assert_eq!(r.staleness.count(0), 4);
        assert_eq!(r.staleness.count(1), 3);
        assert_eq!(r.staleness.count(5), 1);
        assert_eq!(r.idle, 0);
    }

    #[test]
    fn fedbuff_matches_paper_headlines() {
        // Paper headlines that survive self-consistent semantics: 3 global
        // updates, max staleness reduced from async's 5 to 2. See module
        // docs for the documented deviation from the hand-drawn Table 1 row.
        let r = run(Rule::FedBuff { m: 2 });
        assert_eq!(r.global_updates, 3);
        assert_eq!(r.staleness.max_key(), Some(2));
        assert_eq!(r.total_aggregated, 6);
        assert_eq!(r.staleness.count(0), 5);
        assert_eq!(r.staleness.count(2), 1);
        assert_eq!(r.idle, 2);
    }

    #[test]
    fn staleness_ordering_sync_le_fedbuff_le_async() {
        // The qualitative trade-off of §2.4: sparser aggregation → lower
        // staleness, more idleness.
        let sync = run(Rule::Sync);
        let fb = run(Rule::FedBuff { m: 2 });
        let asy = run(Rule::Async);
        let max = |r: &IllustrativeResult| r.staleness.max_key().unwrap_or(0);
        assert!(max(&sync) <= max(&fb));
        assert!(max(&fb) <= max(&asy));
        assert!(sync.idle >= fb.idle);
        assert!(fb.idle >= asy.idle);
        assert!(sync.global_updates <= fb.global_updates);
        assert!(fb.global_updates <= asy.global_updates);
    }

    #[test]
    fn fedbuff_m1_equals_async_updates() {
        // §Appendix A: sync and async are FedBuff with M=1 and M=K.
        let fb1 = run(Rule::FedBuff { m: 1 });
        let asy = run(Rule::Async);
        assert_eq!(fb1.global_updates, asy.global_updates);
        let fbk = run(Rule::FedBuff { m: 3 });
        let sync = run(Rule::Sync);
        assert_eq!(fbk.global_updates, sync.global_updates);
    }
}
