//! The multi-gateway federation layer (ADR-0006): per-gateway buffers and
//! model replicas, deterministic upload routing from station visibility,
//! and pluggable cross-gateway reconciliation.
//!
//! FedSpace (and this repo through PR 4) assumes every ground station feeds
//! one logical FL server. Real gateway networks backhaul per-station
//! buffers — and Razmi et al. (arXiv:2109.01348) and Matthiesen et al.
//! (arXiv:2206.00307) both show that *where* aggregation happens changes
//! staleness and convergence. This module makes that question expressible:
//!
//! - a [`FederationSpec`] names the gateways, assigns every ground station
//!   to one via a [`StationMap`], and picks a [`ReconcilePolicy`];
//! - [`UploadRouting`] attributes every schedule contact to "the first
//!   station, by index, that heard the satellite" (relayed uploads land at
//!   the step's first listening station — ADR-0006 tie-breaks), computed
//!   once per run from the same visibility pipeline as the schedule;
//! - the live [`Federation`] holds one [`Gateway`] per spec entry — its
//!   own buffer B_i^g, model replica, and counters — plus the **global
//!   round counter** shared by all gateways (every aggregation anywhere
//!   bumps it, so staleness and model versions stay globally ordered);
//! - reconciliation merges gateway models with activity weights
//!   (gradients aggregated since the last merge), accumulated in gateway
//!   index order so traces replay bit-identically.
//!
//! With a single gateway every operation reduces — bit for bit — to the
//! pre-federation `GsState` engine semantics: routing is skipped, the
//! central model is the gateway model, and `Periodic`/`OnAggregate`
//! merges of one full-weight model are exact copies (see
//! [`crate::fl::server::weighted_model_merge`]). That identity is the
//! refactor's safety net, asserted across all four algorithms and all
//! three engine modes in `sim::engine` tests and `tests/scenarios.rs`.

use super::buffer::{Buffer, GradientEntry};
use super::codec::Update;
use super::server::{weighted_model_merge, ServerAggregator};
use crate::cfg::toml::{TomlDoc, TomlValue};
use crate::connectivity::{ConnectivityParams, ConnectivitySchedule, StepView, SweepRecord};
use crate::exec;
use crate::orbit::{station_frames, Constellation, GroundStation};
use anyhow::{bail, Context, Result};
use std::borrow::Cow;

/// When (and whether) gateway models merge across the backhaul.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReconcilePolicy {
    /// Every aggregation applies directly to one shared central model —
    /// the pre-federation semantics (gateways keep separate buffers but no
    /// separate models). The default.
    Centralized,
    /// Gateways evolve local model replicas; every `every` engine slots
    /// the replicas merge (activity-weighted, gateway-index order) and the
    /// merged model becomes every gateway's new base.
    Periodic {
        /// Merge cadence in engine slots (validated > 0).
        every: usize,
    },
    /// Merge immediately after every aggregation — eager reconciliation
    /// through the same merge machinery (trace-identical to `Centralized`,
    /// tested; the policy exists to exercise and gate the merge path).
    OnAggregate,
    /// Like `Periodic`, but each gateway's Sync aggregation threshold is
    /// the number of with-data satellites the routing table attributes
    /// *directly to that gateway* rather than the global fleet — the
    /// ROADMAP per-gateway sync quorum. A starved gateway (few direct
    /// contacts) reaches quorum over the satellites it can actually hear
    /// instead of stalling the whole Sync run waiting for uploads that
    /// will only ever land elsewhere. Only Sync consults the quorum;
    /// FedBuff's `m` and the scheduled policies are already local by
    /// construction. Single-gateway runs have no routing table, so the
    /// quorum falls back to the global with-data count — ≡ `Periodic`.
    Quorum {
        /// Merge cadence in engine slots (validated > 0).
        every: usize,
    },
}

impl ReconcilePolicy {
    /// Canonical lowercase name (inverse of the TOML spelling).
    pub fn name(&self) -> &'static str {
        match self {
            ReconcilePolicy::Centralized => "centralized",
            ReconcilePolicy::Periodic { .. } => "periodic",
            ReconcilePolicy::OnAggregate => "on-aggregate",
            ReconcilePolicy::Quorum { .. } => "quorum",
        }
    }

    /// The end-of-step merge cadence, for the policies that have one
    /// (`Periodic` and `Quorum` share the merge schedule).
    pub fn cadence(&self) -> Option<usize> {
        match self {
            ReconcilePolicy::Periodic { every } | ReconcilePolicy::Quorum { every } => Some(*every),
            _ => None,
        }
    }
}

/// Assignment of every ground station to a gateway: entry `s` is the
/// gateway index of station `s` (indexes follow the scenario's station
/// network build order). Empty means "every station feeds gateway 0" —
/// the single-gateway catch-all that keeps old specs valid.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StationMap {
    map: Vec<usize>,
}

impl StationMap {
    /// A map from an explicit station → gateway assignment vector.
    pub fn new(map: Vec<usize>) -> Self {
        StationMap { map }
    }

    /// The single-gateway catch-all (no explicit assignments).
    pub fn all_to_single() -> Self {
        StationMap::default()
    }

    /// True when no explicit assignment exists (catch-all to gateway 0).
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Number of explicitly assigned stations.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Gateway of station `s`.
    ///
    /// Contract: the gateway-0 catch-all exists **only** for the empty
    /// (single-gateway) map — `validate` rejects partially mapped networks,
    /// so on a non-empty map every queried station must be in range. A
    /// station index beyond a non-empty map is a caller bug (a routing
    /// table built against a different station network); silently mapping
    /// it to gateway 0 would mis-attribute its uploads, so debug builds
    /// assert the bound.
    pub fn gateway(&self, station: usize) -> usize {
        debug_assert!(
            self.map.is_empty() || station < self.map.len(),
            "station {station} is outside the {}-station map — the routing table and \
             station network disagree",
            self.map.len()
        );
        self.map.get(station).copied().unwrap_or(0)
    }

    /// The raw assignment vector.
    pub fn as_slice(&self) -> &[usize] {
        &self.map
    }
}

/// Configuration of a federation: gateway names (index = gateway id), the
/// station assignment, and the reconcile policy. The TOML `[federation]`
/// section on `Scenario` and `ExperimentConfig`; omitted ⇒
/// [`FederationSpec::single`] ⇒ the pre-federation engine, byte-identical
/// specs included.
#[derive(Clone, Debug, PartialEq)]
pub struct FederationSpec {
    /// Gateway names, in gateway-index order (merge order).
    pub gateways: Vec<String>,
    /// Station → gateway assignment.
    pub stations: StationMap,
    /// Cross-gateway reconciliation policy.
    pub reconcile: ReconcilePolicy,
}

impl Default for FederationSpec {
    fn default() -> Self {
        Self::single()
    }
}

impl FederationSpec {
    /// The implicit pre-federation setup: one central gateway owning every
    /// station, centralized aggregation.
    pub fn single() -> Self {
        FederationSpec {
            gateways: vec!["central".to_string()],
            stations: StationMap::all_to_single(),
            reconcile: ReconcilePolicy::Centralized,
        }
    }

    /// Builder: named gateways with an explicit station map.
    pub fn split(names: &[&str], station_map: &[usize], reconcile: ReconcilePolicy) -> Self {
        FederationSpec {
            gateways: names.iter().map(|n| n.to_string()).collect(),
            stations: StationMap::new(station_map.to_vec()),
            reconcile,
        }
    }

    /// Builder: replace the reconcile policy.
    pub fn with_reconcile(mut self, reconcile: ReconcilePolicy) -> Self {
        self.reconcile = reconcile;
        self
    }

    /// Number of gateways.
    pub fn n_gateways(&self) -> usize {
        self.gateways.len()
    }

    /// One gateway — the fast path that skips routing entirely.
    pub fn is_single(&self) -> bool {
        self.gateways.len() == 1
    }

    /// Exactly the implicit default (controls `[federation]` emission).
    pub fn is_default(&self) -> bool {
        *self == Self::single()
    }

    /// The station-count-independent half of [`Self::validate`]: no
    /// gateways, blank or duplicate names, out-of-range gateway indexes in
    /// the map, gateways the map leaves without a station, or a zero
    /// `Periodic` cadence. `ExperimentConfig::validate` runs this before
    /// the runner knows the station network.
    pub fn validate_structure(&self) -> Result<()> {
        if self.gateways.is_empty() {
            bail!("[federation] needs at least one gateway");
        }
        if self.gateways.len() > u8::MAX as usize {
            bail!("[federation] supports at most {} gateways", u8::MAX);
        }
        for (g, name) in self.gateways.iter().enumerate() {
            if name.is_empty() {
                bail!("[federation] gateway {g} has an empty name");
            }
            if self.gateways[..g].contains(name) {
                bail!("[federation] duplicate gateway name {name:?}");
            }
        }
        if let Some(every) = self.reconcile.cadence() {
            if every == 0 {
                bail!("[federation] {} reconcile needs every > 0", self.reconcile.name());
            }
        }
        if self.is_single() && self.stations.is_empty() {
            return Ok(()); // catch-all: gateway 0 owns every station
        }
        let g = self.n_gateways();
        let mut seen = vec![false; g];
        for (s, &gw) in self.stations.as_slice().iter().enumerate() {
            if gw >= g {
                bail!("[federation] station {s} maps to gateway {gw} but only {g} exist");
            }
            seen[gw] = true;
        }
        if let Some(empty) = seen.iter().position(|&s| !s) {
            bail!(
                "[federation] gateway {:?} owns no station — empty gateways cannot aggregate",
                self.gateways[empty]
            );
        }
        Ok(())
    }

    /// Reject self-inconsistent federations against a station network of
    /// `n_stations` stations: everything [`Self::validate_structure`]
    /// rejects, plus a map that leaves stations unmapped (or maps ghosts).
    pub fn validate(&self, n_stations: usize) -> Result<()> {
        self.validate_structure()?;
        if self.is_single() && self.stations.is_empty() {
            return Ok(());
        }
        if self.stations.len() != n_stations {
            bail!(
                "[federation] station map assigns {} stations but the network has {} — \
                 every station must map to a gateway",
                self.stations.len(),
                n_stations
            );
        }
        Ok(())
    }

    /// Emit the `[federation]` TOML section (callers skip the call when
    /// [`Self::is_default`] so old specs stay byte-identical).
    pub fn emit_toml(&self, out: &mut String) {
        use std::fmt::Write as _;
        let _ = writeln!(out, "\n[federation]");
        let names: Vec<String> = self.gateways.iter().map(|n| format!("\"{n}\"")).collect();
        let _ = writeln!(out, "gateways = [{}]", names.join(", "));
        if !self.stations.is_empty() {
            let cols: Vec<String> =
                self.stations.as_slice().iter().map(|g| g.to_string()).collect();
            let _ = writeln!(out, "stations = [{}]", cols.join(", "));
        }
        let _ = writeln!(out, "reconcile = \"{}\"", self.reconcile.name());
        if let Some(every) = self.reconcile.cadence() {
            let _ = writeln!(out, "every = {every}");
        }
    }

    /// Parse the `[federation]` section of a TOML document; `Ok(None)` when
    /// the section is absent (callers keep their default).
    pub fn from_doc(doc: &TomlDoc) -> Result<Option<FederationSpec>> {
        if doc.get("federation").is_none() {
            return Ok(None);
        }
        let mut spec = FederationSpec::single();
        if let Some(v) = doc.get("federation").and_then(|s| s.get("gateways")) {
            let TomlValue::Array(items) = v else {
                bail!("[federation] gateways must be an array of strings");
            };
            spec.gateways = items
                .iter()
                .map(|it| {
                    Ok(it
                        .as_str()
                        .context("[federation] gateway names must be strings")?
                        .to_string())
                })
                .collect::<Result<_>>()?;
        }
        if let Some(v) = doc.get("federation").and_then(|s| s.get("stations")) {
            let TomlValue::Array(items) = v else {
                bail!("[federation] stations must be an array of gateway indexes");
            };
            let map = items
                .iter()
                .map(|it| {
                    let i = it
                        .as_int()
                        .context("[federation] stations entries must be integers")?;
                    Ok(usize::try_from(i)?)
                })
                .collect::<Result<Vec<usize>>>()?;
            spec.stations = StationMap::new(map);
        }
        let kind = doc
            .get("federation")
            .and_then(|s| s.get("reconcile"))
            .map(|v| v.as_str().context("[federation] reconcile must be a string"))
            .transpose()?
            .unwrap_or("centralized");
        spec.reconcile = match kind.to_ascii_lowercase().as_str() {
            "centralized" | "central" => ReconcilePolicy::Centralized,
            "on-aggregate" | "on_aggregate" | "onaggregate" => ReconcilePolicy::OnAggregate,
            kind @ ("periodic" | "quorum") => {
                let every = match doc.get("federation").and_then(|s| s.get("every")) {
                    Some(v) => usize::try_from(
                        v.as_int().context("[federation] every must be an integer")?,
                    )?,
                    None => bail!("[federation] {kind} reconcile needs an `every` cadence"),
                };
                if kind == "periodic" {
                    ReconcilePolicy::Periodic { every }
                } else {
                    ReconcilePolicy::Quorum { every }
                }
            }
            other => {
                bail!(
                    "unknown reconcile policy {other:?} \
                     (centralized | periodic | on-aggregate | quorum)"
                )
            }
        };
        Ok(Some(spec))
    }
}

impl crate::cfg::section::SectionSpec for FederationSpec {
    const SECTION: &'static str = "federation";

    fn from_doc(doc: &TomlDoc) -> Result<Option<Self>> {
        FederationSpec::from_doc(doc)
    }

    fn emit_toml(&self, out: &mut String) {
        FederationSpec::emit_toml(self, out)
    }

    fn is_emitted(&self) -> bool {
        !self.is_default()
    }

    fn validate(&self, ctx: &crate::cfg::section::SectionCtx) -> Result<()> {
        // the station map can only be bounds-checked against a known
        // station network; contexts without one (experiment configs, which
        // always rebuild planet12 downstream) check internal consistency
        match ctx.n_stations {
            Some(n) => FederationSpec::validate(self, n),
            None => self.validate_structure(),
        }
    }
}

/// The per-contact upload-routing table of a multi-gateway run: which
/// gateway hears which satellite at which step, attributed to the
/// lowest-indexed visible station (ADR-0006). Built once per run from raw
/// station visibility — the identical sampling pipeline as the schedule
/// compute, so attribution exists for every schedule contact (downtime
/// only *removes* contacts). Memory is O(total contacts), far below the
/// schedule bitsets, so even streamed runs can afford the table.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct UploadRouting {
    n_steps: usize,
    n_gateways: usize,
    /// Per step: raw-visibility satellite ids, ascending.
    sats: Vec<Vec<u32>>,
    /// Gateway index parallel to `sats`.
    gws: Vec<Vec<u8>>,
    /// Per step: gateway of the lowest-indexed station hearing *anyone* —
    /// where relayed uploads land (their sink is ground-visible by
    /// definition, and the step's first listening station is the
    /// deterministic stand-in for it); 0 on contact-free steps.
    fallback: Vec<u8>,
}

impl UploadRouting {
    /// Attribute every connected window of the horizon. `stations` must be
    /// the same list (same order) the schedule was computed against, and
    /// `map` a validated [`StationMap`] over it. The constellation's
    /// downtime windows are applied like the schedule's own post-pass, so
    /// the table covers exactly the contacts the engine can walk — a
    /// downed-but-raw-visible satellite neither appears nor defines a
    /// step's relay fallback.
    pub fn build(
        constellation: &Constellation,
        stations: &[GroundStation],
        n_steps: usize,
        params: &ConnectivityParams,
        map: &StationMap,
    ) -> Self {
        use crate::connectivity::schedule::{
            feasible_need, sample_rotations_range, sat_station_attr,
        };
        let n_gateways = map
            .as_slice()
            .iter()
            .map(|&g| g + 1)
            .max()
            .unwrap_or(1);
        let spw = params.samples_per_window;
        let sin_min = params.min_elev_deg.to_radians().sin();
        let need = feasible_need(params);
        let frames = station_frames(stations);
        let rots = sample_rotations_range(0, n_steps, spw, params.t0_s);
        let bases: Vec<crate::orbit::OrbitBasis> =
            constellation.orbits.iter().map(|o| o.basis()).collect();
        let mut down_by_sat = vec![Vec::new(); constellation.len()];
        for w in &constellation.downtime {
            down_by_sat[w.sat].push((w.from_step, w.until_step));
        }
        let threads = exec::default_parallelism();
        let per_sat: Vec<Vec<(usize, u16)>> = exec::scope_chunks(&bases, threads, |k0, shard| {
            shard
                .iter()
                .enumerate()
                .map(|(j, basis)| {
                    let mut windows =
                        sat_station_attr(basis, &frames, &rots, 0, n_steps, spw, sin_min, need);
                    let down = &down_by_sat[k0 + j];
                    if !down.is_empty() {
                        windows.retain(|&(i, _)| {
                            !down.iter().any(|&(from, until)| (from..until).contains(&i))
                        });
                    }
                    windows
                })
                .collect()
        });
        let mut sats = vec![Vec::new(); n_steps];
        let mut gws = vec![Vec::new(); n_steps];
        let mut min_station = vec![u16::MAX; n_steps];
        for (k, windows) in per_sat.iter().enumerate() {
            for &(i, st) in windows {
                // k ascends across the outer loop, so each step stays sorted
                sats[i].push(k as u32);
                gws[i].push(map.gateway(st as usize) as u8);
                min_station[i] = min_station[i].min(st);
            }
        }
        let fallback = min_station
            .iter()
            .map(|&st| if st == u16::MAX { 0 } else { map.gateway(st as usize) as u8 })
            .collect();
        UploadRouting { n_steps, n_gateways, sats, gws, fallback }
    }

    /// One-pass multi-gateway precompute: the connectivity schedule
    /// (downtime applied, durations recorded iff `durations`) AND its
    /// attribution table out of a single visibility sweep
    /// ([`ConnectivitySchedule::compute_sweep`]). The two-pass pipeline —
    /// a schedule compute followed by [`Self::build`] — samples the whole
    /// horizon twice with the identical pipeline; this fuses the sweeps
    /// and is asserted bit-identical to the two-pass build in tests, which
    /// keeps [`Self::build`] as the oracle.
    pub fn build_with_schedule(
        constellation: &Constellation,
        stations: &[GroundStation],
        n_steps: usize,
        params: &ConnectivityParams,
        map: &StationMap,
        durations: bool,
    ) -> (ConnectivitySchedule, Self) {
        let out = ConnectivitySchedule::compute_sweep(
            constellation,
            stations,
            n_steps,
            params.clone(),
            SweepRecord { durations, attribution: true },
        );
        let attr = out.attribution.expect("attribution was requested");
        let n_gateways = map.as_slice().iter().map(|&g| g + 1).max().unwrap_or(1);
        let mut down_by_sat = vec![Vec::new(); constellation.len()];
        for w in &constellation.downtime {
            down_by_sat[w.sat].push((w.from_step, w.until_step));
        }
        let mut sats = vec![Vec::new(); n_steps];
        let mut gws = vec![Vec::new(); n_steps];
        let mut fallback = vec![0u8; n_steps];
        for (i, (set, st_at)) in out.schedule.sets.iter().zip(attr.iter()).enumerate() {
            let mut min_station = u16::MAX;
            for (&k, &st) in set.iter().zip(st_at.iter()) {
                let down = &down_by_sat[k];
                if down.iter().any(|&(from, until)| (from..until).contains(&i)) {
                    continue; // downed: neither attributed nor a fallback
                }
                // k ascends within each step's set, so `sats[i]` stays sorted
                sats[i].push(k as u32);
                gws[i].push(map.gateway(st as usize) as u8);
                min_station = min_station.min(st);
            }
            if min_station != u16::MAX {
                fallback[i] = map.gateway(min_station as usize) as u8;
            }
        }
        let routing = UploadRouting { n_steps, n_gateways, sats, gws, fallback };
        let sched = out.schedule.with_downtime(&constellation.downtime);
        (sched, routing)
    }

    /// Number of time indexes the table covers.
    pub fn n_steps(&self) -> usize {
        self.n_steps
    }

    /// Number of gateways the table routes to.
    pub fn n_gateways(&self) -> usize {
        self.n_gateways
    }

    /// Per-gateway sync quorum (`ReconcilePolicy::Quorum`): how many
    /// distinct satellites with local data each gateway ever hears
    /// *directly* over the horizon (relayed contacts are excluded — their
    /// attribution is the step fallback, not a stable gateway membership).
    /// This is the Sync threshold of each gateway under the quorum policy:
    /// the fleet a gateway can actually await.
    pub fn quorum_counts(
        &self,
        n_sats: usize,
        has_data: impl Fn(usize) -> bool,
    ) -> Vec<usize> {
        let mut heard = vec![false; self.n_gateways * n_sats];
        for (sats, gws) in self.sats.iter().zip(self.gws.iter()) {
            for (&sat, &g) in sats.iter().zip(gws.iter()) {
                heard[g as usize * n_sats + sat as usize] = true;
            }
        }
        (0..self.n_gateways)
            .map(|g| {
                (0..n_sats)
                    .filter(|&k| heard[g * n_sats + k] && has_data(k))
                    .count()
            })
            .collect()
    }

    /// The gateway that hears satellite `sat` at step `i` over `hops` relay
    /// hops: direct contacts (`hops == 0`) route to the gateway of the
    /// first station, by index, that heard the satellite; relayed contacts
    /// route to the step's fallback gateway (the first listening station).
    pub fn gateway_for(&self, i: usize, sat: usize, hops: usize) -> usize {
        if hops == 0 {
            if let Ok(j) = self.sats[i].binary_search(&(sat as u32)) {
                return self.gws[i][j] as usize;
            }
        }
        self.fallback[i] as usize
    }

    /// Materialize gateway `g`'s visibility window `[start, start + len)`
    /// out of any [`StepView`]: the per-gateway planning relation FedSpace
    /// planners consume (each gateway forecasts only the contacts routed to
    /// it). Hop counts and the hop-delay view are preserved so relay
    /// discounting composes with federation.
    pub fn gateway_window(
        &self,
        view: &dyn StepView,
        start: usize,
        len: usize,
        g: usize,
    ) -> GatewayWindow {
        let end = (start + len).min(view.n_steps()).min(self.n_steps);
        let mut sets = Vec::with_capacity(end.saturating_sub(start));
        let mut hops = Vec::with_capacity(end.saturating_sub(start));
        for i in start..end {
            let conn = view.sats_at(i);
            let ch = view.hops_at(i);
            let mut s = Vec::new();
            let mut h = Vec::new();
            for (j, &sat) in conn.iter().enumerate() {
                let hop = if ch.is_empty() { 0 } else { ch[j] as usize };
                if self.gateway_for(i, sat, hop) == g {
                    s.push(sat);
                    if !ch.is_empty() {
                        h.push(ch[j]);
                    }
                }
            }
            sets.push(s);
            hops.push(h);
        }
        GatewayWindow {
            start,
            n_steps_total: view.n_steps(),
            n_sats: view.n_sats(),
            hop_delay: view.hop_delay_slots(),
            sets,
            hops,
        }
    }
}

/// One gateway's slice of a [`StepView`], materialized over a planning
/// window by [`UploadRouting::gateway_window`] — what a per-gateway
/// FedSpace planner forecasts over.
#[derive(Clone, Debug)]
pub struct GatewayWindow {
    start: usize,
    n_steps_total: usize,
    n_sats: usize,
    hop_delay: usize,
    sets: Vec<Vec<usize>>,
    hops: Vec<Vec<u8>>,
}

impl StepView for GatewayWindow {
    fn n_sats(&self) -> usize {
        self.n_sats
    }

    fn n_steps(&self) -> usize {
        self.n_steps_total
    }

    fn sats_at(&self, i: usize) -> &[usize] {
        &self.sets[i - self.start]
    }

    fn hops_at(&self, i: usize) -> &[u8] {
        &self.hops[i - self.start]
    }

    fn hop_delay_slots(&self) -> usize {
        self.hop_delay
    }
}

/// One gateway's live server state: its buffer B_i^g, model replica, and
/// counters. The aggregation kernel itself ([`ServerAggregator`]) stays
/// engine-owned and shared — it is a stateless Eq.-4 implementation (or a
/// PJRT handle pinned to the coordinator thread), so per-gateway ownership
/// would buy nothing but lifetime plumbing (ADR-0006).
#[derive(Clone, Debug)]
pub struct Gateway {
    /// Gateway name (from the spec).
    pub name: String,
    /// This gateway's gradient buffer B_i^g.
    pub buffer: Buffer,
    /// Local model replica (empty under `Centralized`, which keeps one
    /// shared central model instead).
    pub w: Vec<f32>,
    /// Aggregations this gateway performed.
    pub aggregations: usize,
    /// Uploads this gateway received.
    pub uploads: usize,
    /// Total gradients this gateway aggregated.
    pub n_aggregated: usize,
    /// Gradients aggregated since the last reconcile (the merge weight).
    grads_since_merge: usize,
}

/// The clock-agnostic federation state machine (ADR-0010): receive →
/// buffer → aggregate → reconcile, with no knowledge of sim steps or
/// wall-clock time. Drivers own the clock and translate it into calls on
/// this core: the sim-step driver ([`Federation`]) maps engine slots onto
/// reconcile ticks via [`Federation::end_of_step`], and the serving driver
/// ([`crate::fl::serve::ServeCore`]) maps drain batches onto the same
/// ticks. Every state transition the engine's `run_step` arithmetic
/// depends on lives here, so identical call sequences replay identical
/// state bit for bit regardless of which driver issued them.
pub struct FederationCore {
    /// Per-gateway state, in spec (= merge) order.
    pub gateways: Vec<Gateway>,
    /// Reconciliation policy.
    pub reconcile: ReconcilePolicy,
    /// Staleness-compensation exponent α (Eq. 4), shared by all gateways.
    pub alpha: f64,
    /// Cross-gateway merges performed so far.
    pub reconciles: usize,
    /// Global round counter i_g: bumped by every aggregation at any
    /// gateway, so versions and staleness stay globally ordered.
    round: usize,
    /// The central model (`Centralized`) / last-reconciled model (others).
    w: Vec<f32>,
}

impl FederationCore {
    /// A fresh federation core around an initial model.
    pub fn new(spec: &FederationSpec, w0: Vec<f32>, alpha: f64) -> Self {
        let centralized = matches!(spec.reconcile, ReconcilePolicy::Centralized);
        let gateways = spec
            .gateways
            .iter()
            .map(|name| Gateway {
                name: name.clone(),
                buffer: Buffer::new(),
                w: if centralized { Vec::new() } else { w0.clone() },
                aggregations: 0,
                uploads: 0,
                n_aggregated: 0,
                grads_since_merge: 0,
            })
            .collect();
        FederationCore {
            gateways,
            reconcile: spec.reconcile,
            alpha,
            reconciles: 0,
            round: 0,
            w: w0,
        }
    }

    /// Number of gateways.
    pub fn n_gateways(&self) -> usize {
        self.gateways.len()
    }

    /// The global round counter i_g.
    pub fn round(&self) -> usize {
        self.round
    }

    /// Dimension of the global model (and of every acceptable update).
    pub fn model_dim(&self) -> usize {
        self.w.len()
    }

    /// Receive (g_k, i_{g,k}) at gateway `g`: staleness fixed now against
    /// the global round, exactly like `GsState::receive` against its i_g.
    /// The update arrives in whatever wire form the codec produced
    /// (a plain `Vec<f32>` converts implicitly).
    pub fn receive(
        &mut self,
        g: usize,
        sat: usize,
        grad: impl Into<Update>,
        base_round: usize,
        n_samples: usize,
    ) {
        assert!(base_round <= self.round, "satellite from the future");
        let staleness = self.round - base_round;
        let gw = &mut self.gateways[g];
        gw.uploads += 1;
        gw.buffer.push(GradientEntry { sat, staleness, grad: grad.into(), n_samples });
    }

    /// SERVERUPDATE at gateway `g` (Eq. 4): aggregate its buffer into the
    /// central model (`Centralized`) or its replica (otherwise), bump the
    /// global round, and — under `OnAggregate` — merge immediately.
    /// Mirrors `GsState::update`'s error contract: on aggregator failure
    /// the buffer survives and no counter advances.
    pub fn update(
        &mut self,
        g: usize,
        aggregator: &mut dyn ServerAggregator,
    ) -> Result<Vec<usize>> {
        let alpha = self.alpha;
        let stalenesses = self.gateways[g].buffer.stalenesses();
        if matches!(self.reconcile, ReconcilePolicy::Centralized) {
            let (w, gw) = (&mut self.w, &mut self.gateways[g]);
            aggregator.aggregate(w, gw.buffer.entries(), alpha)?;
        } else {
            let gw = &mut self.gateways[g];
            aggregator.aggregate(&mut gw.w, gw.buffer.entries(), alpha)?;
        }
        let gw = &mut self.gateways[g];
        let n = gw.buffer.drain().len();
        gw.aggregations += 1;
        gw.n_aggregated += n;
        gw.grads_since_merge += n;
        self.round += 1;
        if matches!(self.reconcile, ReconcilePolicy::OnAggregate) {
            self.reconcile_now();
        }
        Ok(stalenesses)
    }

    /// The model gateway `g` broadcasts to the satellites it hears.
    pub fn broadcast_model(&self, g: usize) -> &[f32] {
        if matches!(self.reconcile, ReconcilePolicy::Centralized) {
            &self.w
        } else {
            &self.gateways[g].w
        }
    }

    /// Activity weight total since the last merge.
    fn pending_merge_weight(&self) -> usize {
        self.gateways.iter().map(|g| g.grads_since_merge).sum()
    }

    /// Activity-weighted merge of the gateway replicas, in gateway-index
    /// order (`total` must be [`Self::pending_merge_weight`] > 0).
    fn merged_model(&self, total: usize) -> Vec<f32> {
        let models: Vec<(&[f32], f32)> = self
            .gateways
            .iter()
            .filter(|g| g.grads_since_merge > 0)
            .map(|g| (&g.w[..], (g.grads_since_merge as f64 / total as f64) as f32))
            .collect();
        weighted_model_merge(&models, self.w.len())
    }

    /// The global model the run evaluates and reports: the central model
    /// under `Centralized`; otherwise the last reconciled model, refreshed
    /// on demand with the activity-weighted merge whenever gateways have
    /// aggregated since the last reconcile. With one gateway this is that
    /// gateway's live model bit for bit — the `Periodic ≡ Centralized`
    /// single-gateway identity.
    pub fn global_model(&self) -> Cow<'_, [f32]> {
        if matches!(self.reconcile, ReconcilePolicy::Centralized) {
            return Cow::Borrowed(&self.w);
        }
        match self.pending_merge_weight() {
            0 => Cow::Borrowed(&self.w),
            total => Cow::Owned(self.merged_model(total)),
        }
    }

    /// [`Self::global_model`] by value, without a copy on the borrowed
    /// paths (the end-of-run extraction).
    pub fn into_global_model(self) -> Vec<f32> {
        if matches!(self.reconcile, ReconcilePolicy::Centralized) {
            return self.w;
        }
        match self.pending_merge_weight() {
            0 => self.w,
            total => self.merged_model(total),
        }
    }

    /// Force a cross-gateway merge now: every replica (and the global
    /// model) becomes the activity-weighted merge, and the activity
    /// counters reset. Returns false (and does nothing) when no gateway
    /// aggregated since the last merge, or under `Centralized`.
    pub fn reconcile_now(&mut self) -> bool {
        if matches!(self.reconcile, ReconcilePolicy::Centralized) {
            return false;
        }
        let total = self.pending_merge_weight();
        if total == 0 {
            return false;
        }
        let merged = self.merged_model(total);
        for gw in &mut self.gateways {
            gw.w.copy_from_slice(&merged);
            gw.grads_since_merge = 0;
        }
        self.w = merged;
        self.reconciles += 1;
        true
    }

    /// Clock-agnostic cadence boundary: the driver reports that `tick`
    /// ticks of *its* clock have completed — engine slots for the sim
    /// driver, drain batches for the serving driver — and the `Periodic` /
    /// `Quorum` merge fires whenever the cadence divides the tick count.
    /// Returns whether a merge actually happened (an idle boundary is a
    /// no-op, like [`Self::reconcile_now`]).
    pub fn on_boundary(&mut self, tick: usize) -> bool {
        if let Some(every) = self.reconcile.cadence() {
            if every > 0 && tick % every == 0 {
                return self.reconcile_now();
            }
        }
        false
    }
}

/// The sim-step driver over [`FederationCore`] — what the engine's
/// `run_step` drives instead of a single `GsState`. It `Deref`s to the
/// core (call sites read gateway state and issue receive/update/reconcile
/// directly); the only thing the driver itself owns is the sim clock:
/// completing engine step `i` completes reconcile tick `i + 1`.
pub struct Federation {
    core: FederationCore,
}

impl std::ops::Deref for Federation {
    type Target = FederationCore;

    fn deref(&self) -> &FederationCore {
        &self.core
    }
}

impl std::ops::DerefMut for Federation {
    fn deref_mut(&mut self) -> &mut FederationCore {
        &mut self.core
    }
}

impl Federation {
    /// A fresh federation around an initial model.
    pub fn new(spec: &FederationSpec, w0: Vec<f32>, alpha: f64) -> Self {
        Federation { core: FederationCore::new(spec, w0, alpha) }
    }

    /// Decompose into the clock-agnostic core (e.g. to hand the state to
    /// the serving driver).
    pub fn into_core(self) -> FederationCore {
        self.core
    }

    /// [`FederationCore::into_global_model`] through the driver.
    pub fn into_global_model(self) -> Vec<f32> {
        self.core.into_global_model()
    }

    /// End-of-step hook the engine calls before evaluating: fires the
    /// `Periodic` / `Quorum` cadence (step `i` completes slot `i + 1`).
    pub fn end_of_step(&mut self, i: usize) {
        self.core.on_boundary(i + 1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::CpuAggregator;

    fn two_gw_spec(reconcile: ReconcilePolicy) -> FederationSpec {
        FederationSpec::split(&["north", "south"], &[0, 0, 1, 1], reconcile)
    }

    #[test]
    fn spec_validate_accepts_good_and_rejects_bad() {
        FederationSpec::single().validate(12).unwrap();
        two_gw_spec(ReconcilePolicy::Centralized).validate(4).unwrap();
        // unmapped stations (map shorter than the network)
        assert!(two_gw_spec(ReconcilePolicy::Centralized).validate(5).is_err());
        // empty gateway (gateway 1 owns nothing)
        let lonely =
            FederationSpec::split(&["a", "b"], &[0, 0, 0, 0], ReconcilePolicy::Centralized);
        assert!(lonely.validate(4).is_err());
        // out-of-range gateway index
        let ghost = FederationSpec::split(&["a"], &[0, 1], ReconcilePolicy::Centralized);
        assert!(ghost.validate(2).is_err());
        // no gateways at all / blank / duplicate names
        let none = FederationSpec { gateways: vec![], ..FederationSpec::single() };
        assert!(none.validate(1).is_err());
        let blank = FederationSpec::split(&[""], &[], ReconcilePolicy::Centralized);
        assert!(blank.validate(1).is_err());
        let dup = FederationSpec::split(&["x", "x"], &[0, 1], ReconcilePolicy::Centralized);
        assert!(dup.validate(2).is_err());
        // periodic / quorum cadence 0
        assert!(two_gw_spec(ReconcilePolicy::Periodic { every: 0 }).validate(4).is_err());
        two_gw_spec(ReconcilePolicy::Periodic { every: 24 }).validate(4).unwrap();
        assert!(two_gw_spec(ReconcilePolicy::Quorum { every: 0 }).validate(4).is_err());
        two_gw_spec(ReconcilePolicy::Quorum { every: 24 }).validate(4).unwrap();
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "outside the")]
    fn station_map_rejects_out_of_range_station_in_debug() {
        // regression: a non-empty map used to silently send unknown
        // stations to gateway 0 — a routing table built against the wrong
        // station network would mis-attribute every such upload
        let map = StationMap::new(vec![0, 1]);
        map.gateway(2);
    }

    #[test]
    fn station_map_catch_all_stays_permissive() {
        // the documented contract: only the EMPTY map is a catch-all
        let map = StationMap::all_to_single();
        assert_eq!(map.gateway(0), 0);
        assert_eq!(map.gateway(999), 0);
        let map = StationMap::new(vec![0, 1]);
        assert_eq!(map.gateway(1), 1);
    }

    #[test]
    fn spec_toml_roundtrip_and_default_omission() {
        for spec in [
            two_gw_spec(ReconcilePolicy::Periodic { every: 24 }),
            two_gw_spec(ReconcilePolicy::OnAggregate),
            two_gw_spec(ReconcilePolicy::Centralized),
            two_gw_spec(ReconcilePolicy::Quorum { every: 12 }),
        ] {
            let mut s = String::new();
            spec.emit_toml(&mut s);
            let doc = crate::cfg::toml::parse_toml(&s).unwrap();
            let back = FederationSpec::from_doc(&doc).unwrap().expect("section present");
            assert_eq!(back, spec, "{s}");
        }
        assert!(FederationSpec::single().is_default());
        // absent section parses to None; periodic without `every` rejected
        let doc = crate::cfg::toml::parse_toml("[scenario]\nname = \"x\"").unwrap();
        assert!(FederationSpec::from_doc(&doc).unwrap().is_none());
        let doc =
            crate::cfg::toml::parse_toml("[federation]\nreconcile = \"periodic\"").unwrap();
        assert!(FederationSpec::from_doc(&doc).is_err());
        let doc = crate::cfg::toml::parse_toml("[federation]\nreconcile = \"quorum\"").unwrap();
        assert!(FederationSpec::from_doc(&doc).is_err(), "quorum needs an `every` cadence");
        let doc = crate::cfg::toml::parse_toml("[federation]\nreconcile = \"gossip\"").unwrap();
        assert!(FederationSpec::from_doc(&doc).is_err());
    }

    #[test]
    fn single_gateway_federation_matches_gs_state() {
        // the federation around one gateway must replay GsState's arithmetic
        use crate::fl::GsState;
        let w0 = vec![0.0f32; 4];
        let mut gs = GsState::new(w0.clone(), 0.5);
        let mut fed = Federation::new(&FederationSpec::single(), w0, 0.5);
        for (sat, base) in [(0usize, 0usize), (1, 0)] {
            gs.receive(sat, vec![1.0; 4], base, 1);
            fed.receive(0, sat, vec![1.0; 4], base, 1);
        }
        let s1 = gs.update(&mut CpuAggregator).unwrap();
        let s2 = fed.update(0, &mut CpuAggregator).unwrap();
        assert_eq!(s1, s2);
        assert_eq!(gs.i_g, fed.round());
        assert_eq!(gs.n_aggregated, fed.gateways[0].n_aggregated);
        for (a, b) in gs.w.iter().zip(fed.global_model().iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn global_round_orders_cross_gateway_staleness() {
        let mut fed =
            Federation::new(&two_gw_spec(ReconcilePolicy::Centralized), vec![0.0; 2], 0.5);
        fed.receive(0, 0, vec![1.0, 0.0], 0, 1);
        fed.update(0, &mut CpuAggregator).unwrap(); // round -> 1
        // a satellite that trained on round 0 uploads to the OTHER gateway:
        // staleness is measured against the global round, not gateway 1's
        // (zero) aggregation history
        fed.receive(1, 1, vec![0.0, 1.0], 0, 1);
        assert_eq!(fed.gateways[1].buffer.stalenesses(), vec![1]);
        let st = fed.update(1, &mut CpuAggregator).unwrap();
        assert_eq!(st, vec![1]);
        assert_eq!(fed.round(), 2);
        assert_eq!(fed.gateways[0].aggregations, 1);
        assert_eq!(fed.gateways[1].aggregations, 1);
    }

    #[test]
    #[should_panic]
    fn future_round_rejected_across_gateways() {
        let mut fed =
            Federation::new(&two_gw_spec(ReconcilePolicy::Centralized), vec![0.0; 1], 0.5);
        fed.receive(0, 0, vec![1.0], 7, 1);
    }

    #[test]
    fn periodic_reconcile_merges_and_resets_activity() {
        let mut fed = Federation::new(
            &two_gw_spec(ReconcilePolicy::Periodic { every: 4 }),
            vec![0.0f32; 1],
            0.5,
        );
        // gateway 0 aggregates 3 gradients of +1, gateway 1 one of -1
        for _ in 0..3 {
            fed.receive(0, 0, vec![1.0], fed.round(), 1);
            fed.update(0, &mut CpuAggregator).unwrap();
        }
        fed.receive(1, 1, vec![-1.0], fed.round(), 1);
        fed.update(1, &mut CpuAggregator).unwrap();
        let w0 = fed.gateways[0].w[0];
        let w1 = fed.gateways[1].w[0];
        assert!(w0 > 0.0 && w1 < 0.0, "replicas diverged: {w0} vs {w1}");
        // end of step 3 = slot 4 -> cadence fires
        fed.end_of_step(2);
        assert_eq!(fed.reconciles, 0, "cadence must not fire early");
        fed.end_of_step(3);
        assert_eq!(fed.reconciles, 1);
        let expect = 0.75 * w0 + 0.25 * w1;
        assert!((fed.gateways[0].w[0] - expect).abs() < 1e-6);
        assert_eq!(fed.gateways[0].w[0].to_bits(), fed.gateways[1].w[0].to_bits());
        assert_eq!(fed.global_model()[0].to_bits(), fed.gateways[0].w[0].to_bits());
        // nothing new since the merge: a second fire is a no-op
        fed.end_of_step(7);
        assert_eq!(fed.reconciles, 1);
    }

    #[test]
    fn on_aggregate_merges_after_every_update() {
        let mut fed =
            Federation::new(&two_gw_spec(ReconcilePolicy::OnAggregate), vec![0.0f32; 1], 0.5);
        fed.receive(0, 0, vec![2.0], 0, 1);
        fed.update(0, &mut CpuAggregator).unwrap();
        assert_eq!(fed.reconciles, 1);
        // both replicas and the global model already carry the update
        assert_eq!(fed.gateways[1].w[0].to_bits(), fed.gateways[0].w[0].to_bits());
        fed.receive(1, 1, vec![-2.0], fed.round(), 1);
        fed.update(1, &mut CpuAggregator).unwrap();
        assert_eq!(fed.reconciles, 2);
        assert!((fed.global_model()[0]).abs() < 1e-6);
    }

    #[test]
    fn failed_update_preserves_gateway_buffer_and_round() {
        let mut fed =
            Federation::new(&two_gw_spec(ReconcilePolicy::Centralized), vec![0.0f32; 4], 0.5);
        fed.receive(0, 0, vec![1.0; 3], 0, 1); // wrong dimension
        assert!(fed.update(0, &mut CpuAggregator).is_err());
        assert_eq!(fed.gateways[0].buffer.len(), 1);
        assert_eq!(fed.round(), 0);
        assert_eq!(fed.gateways[0].aggregations, 0);
    }

    #[test]
    fn routing_build_applies_downtime_like_the_schedule() {
        use crate::connectivity::{ConnectivityParams, ConnectivitySchedule};
        use crate::orbit::{planet_ground_stations, planet_labs_like, DowntimeWindow};
        let c = planet_labs_like(6, 0)
            .with_downtime(vec![DowntimeWindow { sat: 0, from_step: 0, until_step: 48 }]);
        let gs = planet_ground_stations();
        let params = ConnectivityParams::default();
        let map = StationMap::new(vec![0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 1, 1]);
        let routed = UploadRouting::build(&c, &gs, 48, &params, &map);
        // a downed satellite neither appears nor defines a step's fallback
        for i in 0..48 {
            assert!(routed.sats[i].binary_search(&0).is_err(), "downed sat attributed at {i}");
        }
        // every contact of the downtime-filtered schedule is attributed
        let sched = ConnectivitySchedule::compute(&c, &gs, 48, params).with_downtime(&c.downtime);
        for i in 0..48 {
            for &s in sched.sats_at(i) {
                assert!(
                    routed.sats[i].binary_search(&(s as u32)).is_ok(),
                    "contact (sat {s}, step {i}) has no attribution"
                );
            }
        }
    }

    #[test]
    fn fused_build_is_bit_identical_to_the_two_pass_build() {
        // the one-pass precompute must reproduce EXACTLY what the two-pass
        // pipeline (schedule compute, then UploadRouting::build) produces —
        // same routing table, same contact sets, same pass durations —
        // including under downtime windows
        use crate::connectivity::ConnectivitySchedule;
        use crate::orbit::{planet_ground_stations, planet_labs_like, DowntimeWindow};
        let c = planet_labs_like(6, 0)
            .with_downtime(vec![DowntimeWindow { sat: 2, from_step: 5, until_step: 30 }]);
        let gs = planet_ground_stations();
        let params = ConnectivityParams::default();
        let map = StationMap::new(vec![0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 1, 1]);
        let (fused_sched, fused_routing) =
            UploadRouting::build_with_schedule(&c, &gs, 48, &params, &map, true);
        let two_pass_routing = UploadRouting::build(&c, &gs, 48, &params, &map);
        assert_eq!(fused_routing, two_pass_routing);
        let two_pass_sched =
            ConnectivitySchedule::compute_with_durations(&c, &gs, 48, params.clone())
                .with_downtime(&c.downtime);
        assert_eq!(fused_sched.sets, two_pass_sched.sets);
        assert_eq!(fused_sched.contacts, two_pass_sched.contacts);
        assert!(fused_sched.has_durations());
        for i in 0..48 {
            assert_eq!(
                fused_sched.contact_durations_at(i),
                two_pass_sched.contact_durations_at(i),
                "durations diverge at step {i}"
            );
        }
        // and without durations the fused schedule matches plain compute
        let (plain, _) = UploadRouting::build_with_schedule(&c, &gs, 48, &params, &map, false);
        assert!(!plain.has_durations());
        assert_eq!(plain.sets, ConnectivitySchedule::compute(&c, &gs, 48, params)
            .with_downtime(&c.downtime)
            .sets);
    }

    #[test]
    fn quorum_counts_respect_the_downtime_boundary() {
        // a satellite downed for the whole horizon is never heard, so it
        // must not inflate any gateway's sync quorum; downing it for only
        // part of the horizon leaves the quorum untouched (membership is
        // "ever heard directly", not per-step)
        use crate::connectivity::ConnectivityParams;
        use crate::orbit::{planet_ground_stations, planet_labs_like, DowntimeWindow};
        let gs = planet_ground_stations();
        let params = ConnectivityParams::default();
        let map = StationMap::new(vec![0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 1, 1]);
        let clean = planet_labs_like(6, 0);
        let base = UploadRouting::build(&clean, &gs, 96, &params, &map);
        let counts_clean = base.quorum_counts(6, |_| true);
        // full-horizon downtime: sat 0 leaves every quorum it was in
        let downed = planet_labs_like(6, 0)
            .with_downtime(vec![DowntimeWindow { sat: 0, from_step: 0, until_step: 96 }]);
        let routed = UploadRouting::build(&downed, &gs, 96, &params, &map);
        let counts_downed = routed.quorum_counts(6, |_| true);
        for (g, (a, b)) in counts_clean.iter().zip(counts_downed.iter()).enumerate() {
            let was_member = base
                .sats
                .iter()
                .zip(base.gws.iter())
                .any(|(s, gw)| {
                    s.iter().zip(gw.iter()).any(|(&sat, &x)| sat == 0 && x as usize == g)
                });
            assert_eq!(*b, *a - usize::from(was_member), "gateway {g}");
        }
        // partial downtime leaving at least one live contact: unchanged
        let blip = planet_labs_like(6, 0)
            .with_downtime(vec![DowntimeWindow { sat: 0, from_step: 0, until_step: 1 }]);
        let routed = UploadRouting::build(&blip, &gs, 96, &params, &map);
        assert_eq!(routed.quorum_counts(6, |_| true), counts_clean);
    }

    #[test]
    fn zero_activity_reconcile_is_a_no_op_not_a_reset() {
        // regression companion to the weighted_model_merge all-zero-weight
        // guard: a reconcile cadence landing on a window in which no
        // gateway aggregated must leave every replica untouched
        let w0: Vec<f32> = (0..8).map(|i| (i as f32) * 0.5 - 1.0).collect();
        let mut fed = Federation::new(
            &two_gw_spec(ReconcilePolicy::Periodic { every: 1 }),
            w0.clone(),
            0.5,
        );
        for i in 0..5 {
            fed.end_of_step(i); // cadence fires every step, nothing to merge
        }
        assert_eq!(fed.reconciles, 0);
        assert_eq!(fed.global_model().as_ref(), &w0[..]);
        for gw in &fed.gateways {
            assert_eq!(gw.w, w0, "idle reconcile must not move a replica");
        }
    }

    #[test]
    fn sim_driver_and_raw_core_replay_identically() {
        // ADR-0010: the sim driver adds only the slot → tick clock mapping;
        // the same call sequence against the bare core replays bit for bit
        let spec = two_gw_spec(ReconcilePolicy::Periodic { every: 4 });
        let mut fed = Federation::new(&spec, vec![0.0f32; 1], 0.5);
        let mut core = FederationCore::new(&spec, vec![0.0f32; 1], 0.5);
        for step in 0..8 {
            if step % 2 == 0 {
                let g = step % 4 / 2;
                fed.receive(g, step, vec![1.0 - step as f32], fed.round(), 1);
                fed.update(g, &mut CpuAggregator).unwrap();
                core.receive(g, step, vec![1.0 - step as f32], core.round(), 1);
                core.update(g, &mut CpuAggregator).unwrap();
            }
            fed.end_of_step(step);
            core.on_boundary(step + 1);
        }
        assert_eq!(fed.reconciles, core.reconciles);
        assert_eq!(fed.round(), core.round());
        assert!(fed.reconciles > 0, "the cadence must have fired in this replay");
        for (x, y) in fed.global_model().iter().zip(core.global_model().iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn gateway_window_filters_a_step_view() {
        // hand-build a routing table via the struct (build() is exercised
        // end-to-end by the scenario tests): sats 0,1 at step 0 — 0 heard
        // by gateway 0, 1 by gateway 1
        let routing = UploadRouting {
            n_steps: 2,
            n_gateways: 2,
            sats: vec![vec![0, 1], vec![1]],
            gws: vec![vec![0, 1], vec![1]],
            fallback: vec![0, 1],
        };
        let sched = crate::connectivity::ConnectivitySchedule::from_sets(
            vec![vec![0, 1], vec![1]],
            2,
        );
        let w0 = routing.gateway_window(&sched, 0, 2, 0);
        assert_eq!(w0.sats_at(0), &[0]);
        assert!(w0.sats_at(1).is_empty());
        let w1 = routing.gateway_window(&sched, 0, 2, 1);
        assert_eq!(w1.sats_at(0), &[1]);
        assert_eq!(w1.sats_at(1), &[1]);
        assert_eq!(StepView::n_steps(&w1), 2);
        // a satellite unknown to the table routes to the step fallback
        assert_eq!(routing.gateway_for(1, 0, 0), 1);
        // relayed contacts take the fallback even when directly listed
        assert_eq!(routing.gateway_for(0, 1, 2), 0);
    }

    #[test]
    fn quorum_counts_are_distinct_direct_with_data_sats() {
        // gateway 0 hears sat 0 (twice — counted once) and sat 2; gateway 1
        // hears sats 1 and 2; sat 2 has no data and drops out of both
        let routing = UploadRouting {
            n_steps: 3,
            n_gateways: 2,
            sats: vec![vec![0, 1], vec![0, 2], vec![2]],
            gws: vec![vec![0, 1], vec![0, 0], vec![1]],
            fallback: vec![0, 0, 1],
        };
        let counts = routing.quorum_counts(3, |s| s != 2);
        assert_eq!(counts, vec![1, 1]);
        let counts = routing.quorum_counts(3, |_| true);
        assert_eq!(counts, vec![2, 2]);
        // a gateway the table never routes to has quorum 0 (the engine
        // clamps it to 1 so Sync cannot fire unconditionally)
        let counts = routing.quorum_counts(3, |_| false);
        assert_eq!(counts, vec![0, 0]);
    }
}
