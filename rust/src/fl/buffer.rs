//! The GS gradient buffer B_i of Algorithm 1.
//!
//! Under a multi-gateway federation (ADR-0006) each gateway owns one of
//! these, fed only by the satellites its stations happen to hear — so the
//! satellite ids a buffer sees are **sparse and arbitrary** (gateway 1 may
//! only ever buffer sats {3, 57, 190}). Everything here is therefore sized
//! by the buffer's own contents: the contributor set is a sorted vec of the
//! ids actually buffered (never an id-indexed table), `n_sats` is O(1), and
//! no operation allocates or scans past the local buffer's entries.

use crate::fl::codec::Update;

/// One buffered local update (g_k, s_k). Staleness is fixed at receive time
/// (Algorithm 1: s_k = i_g − i_{g,k} with the *current* i_g).
#[derive(Clone, Debug)]
pub struct GradientEntry {
    /// Uploading satellite k.
    pub sat: usize,
    /// s_k, fixed when the upload is received.
    pub staleness: usize,
    /// flat local update g_k = w_k^E − w_k^0, in the codec's wire form
    /// (dense, or top-k sparse — ADR-0008)
    pub grad: Update,
    /// number of local samples m_k (available for size-weighted variants)
    pub n_samples: usize,
}

/// B_i plus the contributing-satellite index set R_i.
#[derive(Clone, Debug, Default)]
pub struct Buffer {
    /// Arrival order — `drain` hands entries to Eq. 4 in exactly this
    /// order, so aggregation results are independent of the contributor
    /// set's representation.
    entries: Vec<GradientEntry>,
    /// R_i as a sorted vec of the distinct ids buffered (O(|R_i|) memory
    /// whatever the global fleet size or id range).
    sats: Vec<usize>,
}

impl Buffer {
    /// An empty buffer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Receive (g_k, i_{g,k}) from satellite k (Algorithm 1 receive step).
    pub fn push(&mut self, entry: GradientEntry) {
        if let Err(pos) = self.sats.binary_search(&entry.sat) {
            self.sats.insert(pos, entry.sat);
        }
        self.entries.push(entry);
    }

    /// |R_i|: number of distinct satellites with buffered gradients.
    pub fn n_sats(&self) -> usize {
        self.sats.len()
    }

    /// Number of buffered gradients (≥ n_sats if a satellite re-uploads).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True iff no gradients are buffered.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The buffered gradients, in arrival order.
    pub fn entries(&self) -> &[GradientEntry] {
        &self.entries
    }

    /// Stalenesses of the buffered gradients, in arrival order.
    pub fn stalenesses(&self) -> Vec<usize> {
        self.entries.iter().map(|e| e.staleness).collect()
    }

    /// Drain for aggregation (Algorithm 1: B_{i+1} ← ∅, R_{i+1} ← ∅).
    /// Entries come out in arrival order — the order Eq. 4 accumulates in.
    pub fn drain(&mut self) -> Vec<GradientEntry> {
        self.sats.clear();
        std::mem::take(&mut self.entries)
    }

    /// R_i as a sorted vec (for policies / logging).
    pub fn sat_set(&self) -> Vec<usize> {
        self.sats.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(sat: usize, s: usize) -> GradientEntry {
        GradientEntry { sat, staleness: s, grad: vec![0.0; 4].into(), n_samples: 10 }
    }

    #[test]
    fn push_and_counts() {
        let mut b = Buffer::new();
        assert!(b.is_empty());
        b.push(entry(3, 0));
        b.push(entry(5, 1));
        b.push(entry(3, 2)); // same satellite twice
        assert_eq!(b.len(), 3);
        assert_eq!(b.n_sats(), 2);
        assert_eq!(b.sat_set(), vec![3, 5]);
        assert_eq!(b.stalenesses(), vec![0, 1, 2]);
    }

    #[test]
    fn drain_resets() {
        let mut b = Buffer::new();
        b.push(entry(1, 0));
        let drained = b.drain();
        assert_eq!(drained.len(), 1);
        assert!(b.is_empty());
        assert_eq!(b.n_sats(), 0);
    }

    #[test]
    fn sparse_ids_cost_only_the_buffered_contents() {
        // a per-gateway buffer may see arbitrarily sparse ids — the
        // contributor set must track exactly what was pushed, not the id
        // range (an id-indexed table would need ~10^18 slots here)
        let mut b = Buffer::new();
        for &sat in &[usize::MAX - 1, 3, 999_999_999_999, 3, 0] {
            b.push(entry(sat, 1));
        }
        assert_eq!(b.len(), 5);
        assert_eq!(b.n_sats(), 4);
        assert_eq!(b.sat_set(), vec![0, 3, 999_999_999_999, usize::MAX - 1]);
    }

    #[test]
    fn drain_preserves_arrival_order() {
        // Eq. 4 accumulates per element in entry order; re-uploads and
        // out-of-order ids must come back exactly as they arrived
        let mut b = Buffer::new();
        for (i, &sat) in [9usize, 2, 9, 5].iter().enumerate() {
            b.push(entry(sat, i));
        }
        let drained = b.drain();
        assert_eq!(drained.iter().map(|e| e.sat).collect::<Vec<_>>(), vec![9, 2, 9, 5]);
        assert_eq!(drained.iter().map(|e| e.staleness).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }
}
