//! The GS gradient buffer B_i of Algorithm 1.

use std::collections::BTreeSet;

/// One buffered local update (g_k, s_k). Staleness is fixed at receive time
/// (Algorithm 1: s_k = i_g − i_{g,k} with the *current* i_g).
#[derive(Clone, Debug)]
pub struct GradientEntry {
    /// Uploading satellite k.
    pub sat: usize,
    /// s_k, fixed when the upload is received.
    pub staleness: usize,
    /// flat local update g_k = w_k^E − w_k^0
    pub grad: Vec<f32>,
    /// number of local samples m_k (available for size-weighted variants)
    pub n_samples: usize,
}

/// B_i plus the contributing-satellite index set R_i.
#[derive(Clone, Debug, Default)]
pub struct Buffer {
    entries: Vec<GradientEntry>,
    sats: BTreeSet<usize>,
}

impl Buffer {
    /// An empty buffer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Receive (g_k, i_{g,k}) from satellite k (Algorithm 1 receive step).
    pub fn push(&mut self, entry: GradientEntry) {
        self.sats.insert(entry.sat);
        self.entries.push(entry);
    }

    /// |R_i|: number of distinct satellites with buffered gradients.
    pub fn n_sats(&self) -> usize {
        self.sats.len()
    }

    /// Number of buffered gradients (≥ n_sats if a satellite re-uploads).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True iff no gradients are buffered.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The buffered gradients, in arrival order.
    pub fn entries(&self) -> &[GradientEntry] {
        &self.entries
    }

    /// Stalenesses of the buffered gradients, in arrival order.
    pub fn stalenesses(&self) -> Vec<usize> {
        self.entries.iter().map(|e| e.staleness).collect()
    }

    /// Drain for aggregation (Algorithm 1: B_{i+1} ← ∅, R_{i+1} ← ∅).
    pub fn drain(&mut self) -> Vec<GradientEntry> {
        self.sats.clear();
        std::mem::take(&mut self.entries)
    }

    /// R_i as a sorted vec (for policies / logging).
    pub fn sat_set(&self) -> Vec<usize> {
        self.sats.iter().copied().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(sat: usize, s: usize) -> GradientEntry {
        GradientEntry { sat, staleness: s, grad: vec![0.0; 4], n_samples: 10 }
    }

    #[test]
    fn push_and_counts() {
        let mut b = Buffer::new();
        assert!(b.is_empty());
        b.push(entry(3, 0));
        b.push(entry(5, 1));
        b.push(entry(3, 2)); // same satellite twice
        assert_eq!(b.len(), 3);
        assert_eq!(b.n_sats(), 2);
        assert_eq!(b.sat_set(), vec![3, 5]);
        assert_eq!(b.stalenesses(), vec![0, 1, 2]);
    }

    #[test]
    fn drain_resets() {
        let mut b = Buffer::new();
        b.push(entry(1, 0));
        let drained = b.drain();
        assert_eq!(drained.len(), 1);
        assert!(b.is_empty());
        assert_eq!(b.n_sats(), 0);
    }
}
