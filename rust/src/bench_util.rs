//! Micro-benchmark harness — criterion substitute (offline vendor set has
//! no criterion). Warmup + timed iterations, reporting min/median/p95/mean.
//!
//! Used by every target in `benches/` (all declared `harness = false`).

use std::time::Instant;

/// Statistics of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchStats {
    /// Case label as printed.
    pub name: String,
    /// Measured iterations.
    pub iters: usize,
    /// Fastest iteration [s].
    pub min_s: f64,
    /// Median iteration [s].
    pub median_s: f64,
    /// 95th-percentile iteration [s].
    pub p95_s: f64,
    /// Mean iteration [s].
    pub mean_s: f64,
}

impl BenchStats {
    /// One-line human-readable report.
    pub fn report(&self) -> String {
        format!(
            "{:<44} iters={:<4} min={} median={} p95={} mean={}",
            self.name,
            self.iters,
            fmt_s(self.min_s),
            fmt_s(self.median_s),
            fmt_s(self.p95_s),
            fmt_s(self.mean_s),
        )
    }

    /// ops/sec at the median.
    pub fn throughput(&self, ops_per_iter: f64) -> f64 {
        ops_per_iter / self.median_s
    }
}

/// Human duration formatting.
pub fn fmt_s(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

/// Run `f` repeatedly: `warmup` unmeasured + `iters` measured executions.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        // lint: allow(wall-clock): measuring wall time is the bench harness's job
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[iters / 2];
    let p95 = times[((iters as f64 * 0.95) as usize).min(iters - 1)];
    let mean = times.iter().sum::<f64>() / iters as f64;
    let stats = BenchStats {
        name: name.to_string(),
        iters,
        min_s: times[0],
        median_s: median,
        p95_s: p95,
        mean_s: mean,
    };
    println!("{}", stats.report());
    stats
}

/// Time a single long-running closure (end-to-end bench cases).
pub fn time_once<T, F: FnOnce() -> T>(name: &str, f: F) -> (T, f64) {
    // lint: allow(wall-clock): measuring wall time is the bench harness's job
    let t = Instant::now();
    let out = f();
    let dt = t.elapsed().as_secs_f64();
    println!("{:<44} {}", name, fmt_s(dt));
    (out, dt)
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_stats() {
        let mut n = 0u64;
        let s = bench("noop", 2, 16, || n += 1);
        assert_eq!(n, 18);
        assert_eq!(s.iters, 16);
        assert!(s.min_s <= s.median_s && s.median_s <= s.p95_s);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_s(5e-9).ends_with("ns"));
        assert!(fmt_s(5e-5).ends_with("µs"));
        assert!(fmt_s(5e-2).ends_with("ms"));
        assert!(fmt_s(5.0).ends_with('s'));
    }

    #[test]
    fn time_once_returns_value() {
        let (v, dt) = time_once("x", || 42);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }
}
