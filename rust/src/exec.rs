//! Minimal work-stealing-free thread pool — substrate built from scratch
//! (no `tokio`/`rayon` in the offline vendor set).
//!
//! The coordinator uses it to run satellite local-training jobs in parallel
//! across PJRT executions and to parallelize the L3 hot paths (connectivity
//! computation, scheduler random search). Two complementary patterns:
//!
//! - [`ThreadPool::scope_map`]: map a function over owned (`'static`) items
//!   on the pool's long-lived workers, collecting results in input order.
//! - [`scope_chunks`]: map over contiguous chunks of a *borrowed* slice on
//!   scoped threads — no `'static` bound, so large read-only state (the
//!   connectivity schedule, a fitted utility model) is shared zero-copy,
//!   and each worker gets one callback invocation to reuse scratch buffers
//!   across its whole chunk.
//!
//! [`global_pool`] is the process-wide pool the hot paths share, so the
//! parallelism degree has a single knob. Workers contain job panics
//! (`catch_unwind`): a panicking job never kills its worker thread, and
//! [`ThreadPool::scope_map`] re-raises the first payload on the caller.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, OnceLock};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A caught panic payload in flight from a worker back to the caller.
type Panic = Box<dyn std::any::Any + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Fixed-size thread pool.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    workers: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Create a pool with `size` worker threads (clamped to >= 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|_| {
                let rx = Arc::clone(&rx);
                thread::spawn(move || loop {
                    let msg = rx.lock().unwrap().recv();
                    match msg {
                        // a panicking job must not take the worker with it:
                        // the process-wide global_pool would silently lose
                        // parallelism for the rest of the run. scope_map
                        // re-raises the payload on the caller's thread;
                        // fire-and-forget `execute` jobs drop it.
                        Ok(Msg::Run(job)) => {
                            let _ = catch_unwind(AssertUnwindSafe(job));
                        }
                        Ok(Msg::Shutdown) | Err(_) => break,
                    }
                })
            })
            .collect();
        ThreadPool { tx, workers, size }
    }

    /// Pool sized to the machine's available parallelism.
    pub fn with_default_size() -> Self {
        Self::new(default_parallelism())
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a fire-and-forget job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.send(Msg::Run(Box::new(f))).expect("pool shut down");
    }

    /// Apply `f` to every item in parallel, returning results in input order.
    ///
    /// Blocks until every item has been processed. `f` must be cloneable
    /// across threads (wrap shared state in `Arc`). If any `f(item)`
    /// panics, the remaining items still run to completion, the workers
    /// stay alive, and the panic of the lowest-indexed failing item is
    /// re-raised on the caller's thread with its original payload.
    pub fn scope_map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel::<(usize, Result<R, Panic>)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.execute(move || {
                let r = catch_unwind(AssertUnwindSafe(|| f(item)));
                // Receiver outlives all jobs inside this call; a caught
                // panic is sent home like any result, so the worker loop
                // never unwinds and the recv below always completes.
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut first_panic: Option<(usize, Panic)> = None;
        for _ in 0..n {
            let (i, r) = rrx.recv().expect("pool worker disconnected");
            match r {
                Ok(r) => out[i] = Some(r),
                Err(p) => match first_panic {
                    Some((j, _)) if j < i => {}
                    _ => first_panic = Some((i, p)),
                },
            }
        }
        if let Some((_, payload)) = first_panic {
            resume_unwind(payload);
        }
        out.into_iter().map(|r| r.unwrap()).collect()
    }
}

/// Operator-set parallelism bound; 0/unset = machine auto.
static PARALLELISM_OVERRIDE: OnceLock<usize> = OnceLock::new();

/// Bound the worker count used by [`default_parallelism`] (and therefore
/// [`global_pool`] and the parallel hot paths). `n = 0` is a no-op (auto);
/// the first positive setter wins, and it only affects the global pool if
/// it runs before the pool's first use. This is a resource knob, not a
/// semantics knob: results are identical at any thread count (ADR-0002).
/// Wired from `ExperimentConfig::threads` (`[sim] threads`) by the runner.
pub fn set_default_parallelism(n: usize) {
    if n > 0 {
        let _ = PARALLELISM_OVERRIDE.set(n);
    }
}

/// The parallelism degree used by [`global_pool`] and [`scope_chunks`]
/// callers: the operator override when set ([`set_default_parallelism`]),
/// otherwise the machine's available parallelism. Cheap: no threads are
/// created by asking.
pub fn default_parallelism() -> usize {
    match PARALLELISM_OVERRIDE.get() {
        Some(&n) if n > 0 => n,
        _ => thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
    }
}

/// The process-wide pool shared by the coordinator's parallel hot paths.
/// Sized to the machine's available parallelism; created on first use.
pub fn global_pool() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(ThreadPool::with_default_size)
}

/// Map `f` over contiguous chunks of a borrowed slice in parallel, returning
/// per-item results in input order.
///
/// `f` is called once per chunk with `(start_index, chunk)` and must return
/// one result per chunk item, in order. Unlike [`ThreadPool::scope_map`],
/// items and captures may borrow caller state (no `'static` bound, no `Arc`
/// wrapping), and the once-per-chunk shape lets workers allocate scratch
/// once and reuse it across their whole chunk. With `n_threads <= 1` (or a
/// single-item input) `f` runs on the caller's thread; results are
/// identical either way, so parallelism never affects determinism.
pub fn scope_chunks<T, R, F>(items: &[T], n_threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &[T]) -> Vec<R> + Sync,
{
    let n = items.len();
    let n_threads = if n == 0 { 1 } else { n_threads.clamp(1, n) };
    if n_threads == 1 {
        let out = f(0, items);
        assert_eq!(out.len(), n, "scope_chunks callback returned a wrong-sized chunk");
        return out;
    }
    let chunk = n.div_ceil(n_threads);
    let mut out: Vec<R> = Vec::with_capacity(n);
    thread::scope(|s| {
        let f = &f;
        let handles: Vec<_> = items
            .chunks(chunk)
            .enumerate()
            .map(|(c, slice)| s.spawn(move || f(c * chunk, slice)))
            .collect();
        for (h, slice) in handles.into_iter().zip(items.chunks(chunk)) {
            let part = h.join().expect("scope_chunks worker panicked");
            assert_eq!(part.len(), slice.len(), "callback returned a wrong-sized chunk");
            out.extend(part);
        }
    });
    out
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn scope_map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.scope_map((0..100).collect(), |x: usize| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn all_jobs_run() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        let c2 = Arc::clone(&counter);
        let _ = pool.scope_map((0..50).collect::<Vec<usize>>(), move |_| {
            c2.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn empty_input_ok() {
        let pool = ThreadPool::new(2);
        let out: Vec<usize> = pool.scope_map(Vec::<usize>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = ThreadPool::new(1);
        let out = pool.scope_map(vec![3usize, 1, 2], |x| x + 1);
        assert_eq!(out, vec![4, 2, 3]);
    }

    #[test]
    fn scope_map_panic_propagates_lowest_index_payload() {
        let pool = ThreadPool::new(2);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.scope_map(vec![0usize, 1, 2, 3], |x| {
                if x % 2 == 0 {
                    panic!("boom {x}");
                }
                x
            })
        }));
        let payload = caught.expect_err("a panicking job must reach the caller");
        let msg = payload.downcast_ref::<String>().expect("panic! with format produces String");
        assert_eq!(msg, "boom 0", "the first (lowest-index) payload wins");
    }

    #[test]
    fn pool_keeps_full_throughput_after_a_panicked_job() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let pool = ThreadPool::new(2);
        let _ = catch_unwind(AssertUnwindSafe(|| {
            pool.scope_map(vec![0usize], |_| -> usize { panic!("boom") })
        }));
        // both workers must still be alive: two jobs rendezvous, each
        // returning only once it has seen the other in flight. With the old
        // panic-kills-worker behavior the survivor runs them sequentially
        // and the rendezvous can never complete.
        let arrivals = Arc::new(AtomicUsize::new(0));
        let a = Arc::clone(&arrivals);
        let out = pool.scope_map(vec![10usize, 20], move |x| {
            a.fetch_add(1, Ordering::SeqCst);
            let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
            while a.load(Ordering::SeqCst) < 2 {
                assert!(
                    std::time::Instant::now() < deadline,
                    "pool lost a worker after a panicked job"
                );
                thread::yield_now();
            }
            x
        });
        assert_eq!(out, vec![10, 20]);
        // and scope_map results stay complete and ordered afterwards
        let out = pool.scope_map((0..64).collect(), |x: usize| x + 1);
        assert_eq!(out, (1..65).collect::<Vec<_>>());
    }

    #[test]
    fn scope_chunks_preserves_order_and_borrows() {
        // captures borrow caller state without Arc / 'static
        let offset = 7usize;
        let items: Vec<usize> = (0..100).collect();
        for threads in [1, 2, 3, 8, 200] {
            let out = scope_chunks(&items, threads, |_start, chunk| {
                chunk.iter().map(|x| x + offset).collect()
            });
            assert_eq!(out, (7..107).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn scope_chunks_start_indexes_are_global() {
        let items = vec![0usize; 10];
        let out = scope_chunks(&items, 3, |start, chunk| {
            (start..start + chunk.len()).collect()
        });
        assert_eq!(out, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn scope_chunks_empty_input() {
        let out: Vec<usize> = scope_chunks(&[], 4, |_, chunk| chunk.to_vec());
        assert!(out.is_empty());
    }

    #[test]
    fn zero_parallelism_override_is_a_noop() {
        // 0 = auto must not poison the override slot or the default
        set_default_parallelism(0);
        assert!(default_parallelism() >= 1);
    }

    #[test]
    fn global_pool_is_shared_and_usable() {
        let a = global_pool();
        let b = global_pool();
        assert!(std::ptr::eq(a, b));
        assert!(a.size() >= 1);
        let out = a.scope_map((0..10).collect(), |x: usize| x * 2);
        assert_eq!(out, (0..10).map(|x| x * 2).collect::<Vec<_>>());
    }
}
