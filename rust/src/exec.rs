//! Minimal work-stealing-free thread pool — substrate built from scratch
//! (no `tokio`/`rayon` in the offline vendor set).
//!
//! The coordinator uses it to run satellite local-training jobs in parallel
//! across PJRT executions and to parallelize the scheduler's random search.
//! Jobs are `FnOnce` closures; [`ThreadPool::scope_map`] provides the only
//! pattern the framework needs: map a function over items in parallel and
//! collect results in input order.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Fixed-size thread pool.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    workers: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Create a pool with `size` worker threads (clamped to >= 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|_| {
                let rx = Arc::clone(&rx);
                thread::spawn(move || loop {
                    let msg = rx.lock().unwrap().recv();
                    match msg {
                        Ok(Msg::Run(job)) => job(),
                        Ok(Msg::Shutdown) | Err(_) => break,
                    }
                })
            })
            .collect();
        ThreadPool { tx, workers, size }
    }

    /// Pool sized to the machine's available parallelism.
    pub fn with_default_size() -> Self {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n)
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a fire-and-forget job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.send(Msg::Run(Box::new(f))).expect("pool shut down");
    }

    /// Apply `f` to every item in parallel, returning results in input order.
    ///
    /// Blocks until every item has been processed. `f` must be cloneable
    /// across threads (wrap shared state in `Arc`).
    pub fn scope_map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel::<(usize, R)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.execute(move || {
                let r = f(item);
                // Receiver outlives all jobs inside this call; ignore failure
                // only if the caller panicked.
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rrx.recv().expect("worker panicked");
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.unwrap()).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn scope_map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.scope_map((0..100).collect(), |x: usize| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn all_jobs_run() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        let c2 = Arc::clone(&counter);
        let _ = pool.scope_map((0..50).collect::<Vec<usize>>(), move |_| {
            c2.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn empty_input_ok() {
        let pool = ThreadPool::new(2);
        let out: Vec<usize> = pool.scope_map(Vec::<usize>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = ThreadPool::new(1);
        let out = pool.scope_map(vec![3usize, 1, 2], |x| x + 1);
        assert_eq!(out, vec![4, 2, 3]);
    }
}
