//! One compiled AOT artifact: HLO text → PJRT executable → typed execution.

use anyhow::{Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// A compiled computation loaded from an HLO-text file.
///
/// All artifacts are lowered with `return_tuple=True`, so execution returns
/// the flattened tuple elements.
pub struct Artifact {
    /// Source path of the HLO text (diagnostics).
    pub name: String,
    exe: PjRtLoadedExecutable,
}

impl Artifact {
    /// Load + compile `path` on `client`.
    pub fn load(client: &PjRtClient, path: &str) -> Result<Self> {
        let proto = HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path} — run `make artifacts`?"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).with_context(|| format!("compiling {path}"))?;
        Ok(Artifact { name: path.to_string(), exe })
    }

    /// Execute with literal inputs; unwrap the output tuple.
    pub fn execute(&self, args: &[Literal]) -> Result<Vec<Literal>> {
        let out = self
            .exe
            .execute::<Literal>(args)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = out[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        Ok(lit.to_tuple()?)
    }
}

/// Build an f32 literal of the given logical shape from a flat slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
    let expect: i64 = dims.iter().product();
    anyhow::ensure!(
        expect as usize == data.len(),
        "literal shape {dims:?} wants {expect} elements, got {}",
        data.len()
    );
    if dims.len() == 1 {
        return Ok(Literal::vec1(data));
    }
    Ok(Literal::vec1(data).reshape(dims)?)
}

/// Extract a flat f32 vector from a literal.
pub fn to_vec_f32(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract a scalar f32.
pub fn scalar_f32(lit: &Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}
