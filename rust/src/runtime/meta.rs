//! Artifact metadata (`artifacts/meta_<size>.txt`, key=value lines) — the
//! contract between the L2 lowering parameters and the L3 coordinator.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Parsed lowering metadata for one model size.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelMeta {
    /// Model-size tag ("small" / "fmow").
    pub size: String,
    /// flat trainable-parameter dimension
    pub d: usize,
    /// Flat input-image dimension.
    pub img_dim: usize,
    /// Classifier output classes.
    pub num_classes: usize,
    /// E local SGD steps baked into local_train
    pub e_steps: usize,
    /// local-training batch size B
    pub batch: usize,
    /// Evaluation batch size.
    pub eval_batch: usize,
    /// gradients per aggregate_chunk call
    pub chunk: usize,
    /// Frozen-extractor feature width.
    pub feat: usize,
    /// Dense-head hidden width.
    pub hidden: usize,
    /// (name, shape) of each trainable tensor, in flat-vector order
    pub param_shapes: Vec<(String, Vec<usize>)>,
}

impl ModelMeta {
    /// Parse `key=value` metadata text (see `python/compile/aot.py`).
    pub fn parse(text: &str) -> Result<Self> {
        let mut kv = BTreeMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line.split_once('=').with_context(|| format!("bad meta line {line:?}"))?;
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
        let get = |k: &str| -> Result<&String> {
            kv.get(k).with_context(|| format!("meta missing key {k:?}"))
        };
        let get_usize = |k: &str| -> Result<usize> {
            get(k)?.parse::<usize>().with_context(|| format!("meta key {k:?} not an integer"))
        };
        let mut param_shapes = Vec::new();
        for part in get("param_shapes")?.split(';') {
            let (name, dims) = part
                .split_once(':')
                .with_context(|| format!("bad param shape {part:?}"))?;
            let shape: Vec<usize> = dims
                .split(',')
                .map(|d| d.parse::<usize>().context("bad dim"))
                .collect::<Result<_>>()?;
            param_shapes.push((name.to_string(), shape));
        }
        let meta = ModelMeta {
            size: get("size")?.clone(),
            d: get_usize("d")?,
            img_dim: get_usize("img_dim")?,
            num_classes: get_usize("num_classes")?,
            e_steps: get_usize("e_steps")?,
            batch: get_usize("batch")?,
            eval_batch: get_usize("eval_batch")?,
            chunk: get_usize("chunk")?,
            feat: get_usize("feat")?,
            hidden: get_usize("hidden")?,
            param_shapes,
        };
        let d_sum: usize = meta.param_shapes.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
        if d_sum != meta.d {
            bail!("param_shapes sum {d_sum} != d {}", meta.d);
        }
        Ok(meta)
    }

    /// Load `artifacts_dir/meta_<size>.txt`.
    pub fn load(artifacts_dir: &str, size: &str) -> Result<Self> {
        let path = format!("{artifacts_dir}/meta_{size}.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path} — run `make artifacts` first"))?;
        Self::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "size=small\nd=8190\nimg_dim=3072\nnum_classes=62\n\
        e_steps=2\nbatch=8\neval_batch=16\nchunk=8\nfeat=64\nhidden=64\n\
        param_shapes=w1:64,64;b1:64;w2:64,62;b2:62\n";

    #[test]
    fn parses_sample() {
        let m = ModelMeta::parse(SAMPLE).unwrap();
        assert_eq!(m.size, "small");
        assert_eq!(m.d, 8190);
        assert_eq!(m.e_steps, 2);
        assert_eq!(m.param_shapes.len(), 4);
        assert_eq!(m.param_shapes[0], ("w1".to_string(), vec![64, 64]));
    }

    #[test]
    fn rejects_inconsistent_d() {
        let bad = SAMPLE.replace("d=8190", "d=9999");
        assert!(ModelMeta::parse(&bad).is_err());
    }

    #[test]
    fn rejects_missing_key() {
        let bad = SAMPLE.replace("e_steps=2\n", "");
        assert!(ModelMeta::parse(&bad).is_err());
    }
}
