//! Typed model runtime: the four AOT artifacts behind one API.

use super::artifact::{literal_f32, scalar_f32, to_vec_f32, Artifact};
use super::meta::ModelMeta;
use crate::fl::buffer::GradientEntry;
use crate::fl::server::ServerAggregator;
use crate::fl::staleness::normalized_weights;
use crate::rng::Rng;
use anyhow::{ensure, Result};
use xla::PjRtClient;

/// Loads and executes every artifact of one model size.
pub struct ModelRuntime {
    /// Artifact metadata (dimensions, batch shapes, parameter layout).
    pub meta: ModelMeta,
    client: PjRtClient,
    local_train: Artifact,
    grad_eval: Artifact,
    eval_step: Artifact,
    aggregate_chunk: Artifact,
    /// execution counters (perf accounting)
    pub n_train_calls: std::cell::Cell<u64>,
    /// eval_step executions (perf accounting).
    pub n_eval_calls: std::cell::Cell<u64>,
    /// aggregate_chunk executions (perf accounting).
    pub n_agg_calls: std::cell::Cell<u64>,
}

impl ModelRuntime {
    /// Load all artifacts for `size` from `artifacts_dir` on a CPU client.
    pub fn load(artifacts_dir: &str, size: &str) -> Result<Self> {
        let meta = ModelMeta::load(artifacts_dir, size)?;
        let client = PjRtClient::cpu()?;
        let path = |name: &str| format!("{artifacts_dir}/{name}_{size}.hlo.txt");
        Ok(ModelRuntime {
            local_train: Artifact::load(&client, &path("local_train"))?,
            grad_eval: Artifact::load(&client, &path("grad_eval"))?,
            eval_step: Artifact::load(&client, &path("eval_step"))?,
            aggregate_chunk: Artifact::load(&client, &path("aggregate_chunk"))?,
            meta,
            client,
            n_train_calls: std::cell::Cell::new(0),
            n_eval_calls: std::cell::Cell::new(0),
            n_agg_calls: std::cell::Cell::new(0),
        })
    }

    /// The PJRT client the artifacts are compiled on.
    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    /// He-initialized flat parameter vector (matches the L2 layout; biases
    /// zero). Initialization lives in Rust so experiment replay needs no
    /// Python.
    pub fn init_params(&self, rng: &mut Rng) -> Vec<f32> {
        let mut w = Vec::with_capacity(self.meta.d);
        for (name, shape) in &self.meta.param_shapes {
            let n: usize = shape.iter().product();
            if name.starts_with('b') {
                w.extend(std::iter::repeat(0.0f32).take(n));
            } else {
                let fan_in = shape[0] as f32;
                let std = (2.0 / fan_in).sqrt();
                w.extend((0..n).map(|_| rng.normal_f32(0.0, std)));
            }
        }
        debug_assert_eq!(w.len(), self.meta.d);
        w
    }

    /// E local SGD steps (Eq. 3): returns (delta = w_E − w_0, mean loss).
    ///
    /// `xs`: [E·B·img_dim] flat, `ys`: [E·B] f32 class ids.
    pub fn local_train(
        &self,
        w: &[f32],
        xs: &[f32],
        ys: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let m = &self.meta;
        ensure!(w.len() == m.d, "w dim {} != {}", w.len(), m.d);
        let (e, b) = (m.e_steps as i64, m.batch as i64);
        let args = [
            literal_f32(w, &[m.d as i64])?,
            literal_f32(xs, &[e, b, m.img_dim as i64])?,
            literal_f32(ys, &[e, b])?,
            xla::Literal::from(lr),
        ];
        let out = self.local_train.execute(&args)?;
        ensure!(out.len() == 2, "local_train returned {} outputs", out.len());
        self.n_train_calls.set(self.n_train_calls.get() + 1);
        Ok((to_vec_f32(&out[0])?, scalar_f32(&out[1])?))
    }

    /// Single-batch (∇f, loss) — utility-sample generation (Eq. 12).
    pub fn grad_eval(&self, w: &[f32], x: &[f32], y: &[f32]) -> Result<(Vec<f32>, f32)> {
        let m = &self.meta;
        let args = [
            literal_f32(w, &[m.d as i64])?,
            literal_f32(x, &[m.batch as i64, m.img_dim as i64])?,
            literal_f32(y, &[m.batch as i64])?,
        ];
        let out = self.grad_eval.execute(&args)?;
        ensure!(out.len() == 2);
        Ok((to_vec_f32(&out[0])?, scalar_f32(&out[1])?))
    }

    /// One validation batch: (sum CE loss, #correct).
    pub fn eval_batch(&self, w: &[f32], x: &[f32], y: &[f32]) -> Result<(f32, f32)> {
        let m = &self.meta;
        let args = [
            literal_f32(w, &[m.d as i64])?,
            literal_f32(x, &[m.eval_batch as i64, m.img_dim as i64])?,
            literal_f32(y, &[m.eval_batch as i64])?,
        ];
        let out = self.eval_step.execute(&args)?;
        ensure!(out.len() == 2);
        self.n_eval_calls.set(self.n_eval_calls.get() + 1);
        Ok((scalar_f32(&out[0])?, scalar_f32(&out[1])?))
    }

    /// One Eq. (4) chunk: w ← w + Σ_c wt[c]·G[c]. `grads` is CH·d flat with
    /// zero-weighted padding rows.
    pub fn aggregate_chunk_raw(
        &self,
        w: &[f32],
        grads: &[f32],
        weights: &[f32],
    ) -> Result<Vec<f32>> {
        let m = &self.meta;
        ensure!(weights.len() == m.chunk);
        ensure!(grads.len() == m.chunk * m.d);
        let args = [
            literal_f32(w, &[m.d as i64])?,
            literal_f32(grads, &[m.chunk as i64, m.d as i64])?,
            literal_f32(weights, &[m.chunk as i64])?,
        ];
        let out = self.aggregate_chunk.execute(&args)?;
        ensure!(out.len() == 1);
        self.n_agg_calls.set(self.n_agg_calls.get() + 1);
        to_vec_f32(&out[0])
    }

    /// Full Eq. (4) over a drained buffer, streaming CH gradients at a time
    /// through the Pallas `stale_aggregate` kernel.
    pub fn aggregate(&self, w: &mut Vec<f32>, entries: &[GradientEntry], alpha: f64) -> Result<()> {
        if entries.is_empty() {
            return Ok(());
        }
        let m = &self.meta;
        let stal: Vec<usize> = entries.iter().map(|e| e.staleness).collect();
        let weights = normalized_weights(&stal, alpha);
        let ch = m.chunk;
        let mut gbuf = vec![0.0f32; ch * m.d];
        let mut wbuf = vec![0.0f32; ch];
        for (chunk_entries, chunk_weights) in
            entries.chunks(ch).zip(weights.chunks(ch))
        {
            for slot in 0..ch {
                if let Some(e) = chunk_entries.get(slot) {
                    ensure!(e.grad.len() == m.d, "gradient dim mismatch");
                    let row = &mut gbuf[slot * m.d..(slot + 1) * m.d];
                    match e.grad.as_dense() {
                        Some(g) => row.copy_from_slice(g),
                        // sparse wire form (ADR-0008): densify the row
                        None => row.copy_from_slice(&e.grad.to_dense()),
                    }
                    wbuf[slot] = chunk_weights[slot];
                } else {
                    // zero weight masks the stale row left in gbuf
                    wbuf[slot] = 0.0;
                }
            }
            *w = self.aggregate_chunk_raw(w, &gbuf, &wbuf)?;
        }
        Ok(())
    }
}

/// `ServerAggregator` adapter: the shipped GS hot path.
pub struct PjrtAggregator<'a> {
    /// The loaded runtime providing the `aggregate_chunk` artifact.
    pub rt: &'a ModelRuntime,
}

impl ServerAggregator for PjrtAggregator<'_> {
    fn aggregate(&mut self, w: &mut Vec<f32>, entries: &[GradientEntry], alpha: f64) -> Result<()> {
        self.rt.aggregate(w, entries, alpha)
    }
}

// Safety note: ModelRuntime is intentionally !Send (raw PJRT pointers);
// everything runs on the coordinator thread.
