//! API-compatible stand-in for the PJRT executor when the `pjrt` cargo
//! feature (and thus the vendored `xla` crate) is unavailable.
//!
//! [`ModelRuntime::load`] always returns an error and is the only
//! constructor, so a stub runtime is never observed in a constructed state —
//! the other methods exist only so callers (trainer, runner, benches)
//! typecheck identically against either implementation.

use crate::fl::buffer::GradientEntry;
use crate::fl::server::ServerAggregator;
use crate::rng::Rng;
use anyhow::{bail, Result};

/// Message returned by the stub constructor.
const UNAVAILABLE: &str =
    "fedspace was built without the `pjrt` feature: the PJRT/XLA runtime is \
     unavailable (use the mock backend, or rebuild with `--features pjrt` \
     and the vendored `xla` crate)";

/// Stub runtime: `load` is the only constructor and it always fails.
pub struct ModelRuntime {
    /// Artifact metadata (never observed: construction is impossible).
    pub meta: super::ModelMeta,
    _priv: (),
}

impl ModelRuntime {
    /// Always fails: the `pjrt` feature is off in this build.
    pub fn load(_artifacts_dir: &str, _size: &str) -> Result<Self> {
        bail!(UNAVAILABLE)
    }

    /// Mirrors `executor::ModelRuntime::init_params`; unreachable in stubs.
    pub fn init_params(&self, _rng: &mut Rng) -> Vec<f32> {
        unreachable!("stub ModelRuntime cannot be constructed")
    }

    /// Mirrors `executor::ModelRuntime::local_train`; unreachable in stubs.
    pub fn local_train(
        &self,
        _w: &[f32],
        _xs: &[f32],
        _ys: &[f32],
        _lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        unreachable!("stub ModelRuntime cannot be constructed")
    }

    /// Mirrors `executor::ModelRuntime::grad_eval`; unreachable in stubs.
    pub fn grad_eval(&self, _w: &[f32], _x: &[f32], _y: &[f32]) -> Result<(Vec<f32>, f32)> {
        unreachable!("stub ModelRuntime cannot be constructed")
    }

    /// Mirrors `executor::ModelRuntime::eval_batch`; unreachable in stubs.
    pub fn eval_batch(&self, _w: &[f32], _x: &[f32], _y: &[f32]) -> Result<(f32, f32)> {
        unreachable!("stub ModelRuntime cannot be constructed")
    }

    /// Mirrors `executor::ModelRuntime::aggregate_chunk_raw`; unreachable.
    pub fn aggregate_chunk_raw(
        &self,
        _w: &[f32],
        _grads: &[f32],
        _weights: &[f32],
    ) -> Result<Vec<f32>> {
        unreachable!("stub ModelRuntime cannot be constructed")
    }

    /// Mirrors `executor::ModelRuntime::aggregate`; unreachable in stubs.
    pub fn aggregate(
        &self,
        _w: &mut Vec<f32>,
        _entries: &[GradientEntry],
        _alpha: f64,
    ) -> Result<()> {
        unreachable!("stub ModelRuntime cannot be constructed")
    }
}

/// Stub `ServerAggregator` adapter mirroring `executor::PjrtAggregator`.
pub struct PjrtAggregator<'a> {
    /// The (unconstructible) stub runtime.
    pub rt: &'a ModelRuntime,
}

impl ServerAggregator for PjrtAggregator<'_> {
    fn aggregate(
        &mut self,
        w: &mut Vec<f32>,
        entries: &[GradientEntry],
        alpha: f64,
    ) -> Result<()> {
        self.rt.aggregate(w, entries, alpha)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_reports_missing_feature() {
        let err = ModelRuntime::load("artifacts", "small").unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
