//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them natively — Python never runs
//! on this path.
//!
//! Interchange is HLO *text* (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md).
//!
//! Threading note: the `xla` crate's wrappers are not `Send` (raw PJRT
//! pointers), so all executions happen on the coordinator thread; the CPU
//! PJRT client (TFRT) parallelizes internally.
//!
//! The executor depends on the vendored `xla` crate, which is only present
//! in the offline toolchain image — so the real implementation is gated
//! behind the `pjrt` cargo feature (see rust/Cargo.toml). Without it,
//! [`stub`] provides the same API surface with a `load` that errors, so the
//! pure-Rust coordinator paths build and run everywhere.

#[cfg(feature = "pjrt")]
pub mod artifact;
#[cfg(feature = "pjrt")]
pub mod executor;
pub mod meta;
#[cfg(not(feature = "pjrt"))]
pub mod stub;

#[cfg(feature = "pjrt")]
pub use artifact::Artifact;
#[cfg(feature = "pjrt")]
pub use executor::{ModelRuntime, PjrtAggregator};
pub use meta::ModelMeta;
#[cfg(not(feature = "pjrt"))]
pub use stub::{ModelRuntime, PjrtAggregator};
