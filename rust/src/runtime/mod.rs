//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them natively — Python never runs
//! on this path.
//!
//! Interchange is HLO *text* (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md).
//!
//! Threading note: the `xla` crate's wrappers are not `Send` (raw PJRT
//! pointers), so all executions happen on the coordinator thread; the CPU
//! PJRT client (TFRT) parallelizes internally.

pub mod artifact;
pub mod executor;
pub mod meta;

pub use artifact::Artifact;
pub use executor::{ModelRuntime, PjrtAggregator};
pub use meta::ModelMeta;
