//! Machine-readable bench results and the CI perf-regression gate.
//!
//! The bench binaries (`bench_perf`, `bench_engine_modes`) [`record`] the
//! median of every tracked hot path under a stable snake-case key and, when
//! the `FEDSPACE_BENCH_JSON` env var names a file, [`flush_to_env_path`]
//! writes them as a small JSON document. CI runs the benches, then
//! `fedspace bench-check` parses those documents plus the committed
//! baselines (`rust/BENCH_pr*.json`, listed newest first — the first
//! non-provisional one gates), renders a markdown comparison table into
//! the GitHub step summary, and **fails the build** when any tracked path
//! is more than `--max-regress` (default 25%) slower than its baseline
//! median. Tracked paths absent from the baseline are a *counted warning*
//! ([`Comparison::new_paths`]), never a silent pass.
//!
//! A baseline with `"provisional": true` (or no overlapping keys) puts the
//! gate in bootstrap mode: the comparison is reported but never fails, and
//! the summary explains how to commit real numbers. That is how the gate
//! ships from an authoring environment that cannot run the benches — every
//! green CI run emits a ready-to-commit armed baseline via
//! `fedspace bench-baseline` (the `bench-baseline` artifact).
//!
//! JSON support is a deliberately tiny in-repo subset (objects, arrays,
//! strings without `\u` escapes, numbers, booleans, null) — consistent
//! with the crate's no-new-dependencies substrate policy (ADR-0001).

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};

/// The schema tag written into every report.
pub const SCHEMA: &str = "fedspace-bench-v1";

fn registry() -> &'static Mutex<BTreeMap<String, f64>> {
    static REG: OnceLock<Mutex<BTreeMap<String, f64>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Record one tracked bench result (median seconds) under a stable key.
/// Later records with the same key overwrite earlier ones.
pub fn record(name: &str, median_s: f64) {
    registry().lock().expect("bench registry poisoned").insert(name.to_string(), median_s);
}

/// Snapshot of everything [`record`]ed so far in this process.
pub fn recorded() -> BTreeMap<String, f64> {
    registry().lock().expect("bench registry poisoned").clone()
}

/// Write the recorded results to the file named by `FEDSPACE_BENCH_JSON`
/// (no-op returning `None` when the env var is unset). Called by the bench
/// binaries at the end of `main`.
pub fn flush_to_env_path() -> Result<Option<String>> {
    let Ok(path) = std::env::var("FEDSPACE_BENCH_JSON") else {
        return Ok(None);
    };
    let report = BenchReport { provisional: false, benches: recorded() };
    crate::metrics::write_file(&path, &report.to_json())
        .with_context(|| format!("writing bench JSON {path}"))?;
    Ok(Some(path))
}

/// One bench-results document: tracked path → median seconds.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchReport {
    /// True for placeholder baselines that must not gate anything yet.
    pub provisional: bool,
    /// Median seconds per tracked path.
    pub benches: BTreeMap<String, f64>,
}

impl BenchReport {
    /// Serialize (stable key order, round-trips through [`Self::from_json`]).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"schema\": \"{SCHEMA}\",\n"));
        s.push_str(&format!("  \"provisional\": {},\n", self.provisional));
        s.push_str("  \"benches\": {");
        let entries: Vec<String> =
            self.benches.iter().map(|(k, v)| format!("\n    \"{k}\": {v}")).collect();
        s.push_str(&entries.join(","));
        if !entries.is_empty() {
            s.push_str("\n  ");
        }
        s.push_str("}\n}\n");
        s
    }

    /// Parse a report document.
    pub fn from_json(text: &str) -> Result<Self> {
        let v = parse_json(text)?;
        let Json::Obj(top) = v else {
            bail!("bench report must be a JSON object");
        };
        let mut report = BenchReport { provisional: false, benches: BTreeMap::new() };
        for (key, val) in top {
            match (key.as_str(), val) {
                ("provisional", Json::Bool(b)) => report.provisional = b,
                ("benches", Json::Obj(entries)) => {
                    for (name, entry) in entries {
                        let Json::Num(n) = entry else {
                            bail!("bench {name:?} must be a number of seconds");
                        };
                        report.benches.insert(name, n);
                    }
                }
                // schema/note/anything else: tolerated and ignored
                _ => {}
            }
        }
        Ok(report)
    }

    /// Parse a report from a file on disk.
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading bench report {path}"))?;
        Self::from_json(&text).with_context(|| format!("parsing bench report {path}"))
    }
}

/// Verdict for one tracked path in a baseline/current comparison.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RowStatus {
    /// Within the allowed regression budget.
    Ok,
    /// Slower than baseline by more than the budget — fails the gate.
    Regressed,
    /// Present in the current run only (no baseline yet).
    NewInCurrent,
    /// Present in the baseline only (bench removed or renamed).
    MissingInCurrent,
}

/// One comparison row.
#[derive(Clone, Debug)]
pub struct CompareRow {
    /// Tracked path key.
    pub name: String,
    /// Baseline median seconds, if present.
    pub baseline_s: Option<f64>,
    /// Current median seconds, if present.
    pub current_s: Option<f64>,
    /// current / baseline when both sides exist.
    pub ratio: Option<f64>,
    /// Gate verdict for this row.
    pub status: RowStatus,
}

/// Result of comparing a current run against the committed baseline.
#[derive(Clone, Debug)]
pub struct Comparison {
    /// Per-path rows, baseline key order then new keys.
    pub rows: Vec<CompareRow>,
    /// Names of rows whose status is [`RowStatus::Regressed`].
    pub regressions: Vec<String>,
    /// Names of rows whose status is [`RowStatus::NewInCurrent`] — tracked
    /// paths with no baseline entry. Not a pass: they are reported as a
    /// counted warning so a new bench cannot silently dodge the gate until
    /// the baseline is refreshed.
    pub new_paths: Vec<String>,
    /// True when the baseline is provisional or shares no keys with the
    /// current run — report, never fail.
    pub bootstrap: bool,
    /// The regression budget the comparison ran with.
    pub max_regress: f64,
}

/// Compare `current` against `baseline` with a relative budget
/// (`max_regress = 0.25` fails any path >25% slower than its baseline).
pub fn compare(baseline: &BenchReport, current: &BenchReport, max_regress: f64) -> Comparison {
    let mut rows = Vec::new();
    let mut regressions = Vec::new();
    let mut overlap = 0usize;
    for (name, &base) in &baseline.benches {
        match current.benches.get(name) {
            Some(&cur) => {
                overlap += 1;
                let ratio = if base > 0.0 { cur / base } else { 1.0 };
                let status = if ratio > 1.0 + max_regress {
                    regressions.push(name.clone());
                    RowStatus::Regressed
                } else {
                    RowStatus::Ok
                };
                rows.push(CompareRow {
                    name: name.clone(),
                    baseline_s: Some(base),
                    current_s: Some(cur),
                    ratio: Some(ratio),
                    status,
                });
            }
            None => rows.push(CompareRow {
                name: name.clone(),
                baseline_s: Some(base),
                current_s: None,
                ratio: None,
                status: RowStatus::MissingInCurrent,
            }),
        }
    }
    let mut new_paths = Vec::new();
    for (name, &cur) in &current.benches {
        if !baseline.benches.contains_key(name) {
            new_paths.push(name.clone());
            rows.push(CompareRow {
                name: name.clone(),
                baseline_s: None,
                current_s: Some(cur),
                ratio: None,
                status: RowStatus::NewInCurrent,
            });
        }
    }
    let bootstrap = baseline.provisional || overlap == 0;
    if bootstrap {
        regressions.clear();
    }
    Comparison { rows, regressions, new_paths, bootstrap, max_regress }
}

impl Comparison {
    /// Render the comparison as a GitHub-flavored markdown section (the CI
    /// step-summary payload).
    pub fn to_markdown(&self) -> String {
        let mut s = String::from("## Perf-regression gate\n\n");
        if self.bootstrap {
            s.push_str(
                "**Bootstrap mode** — the committed baseline is provisional (or shares no \
                 tracked paths with this run), so nothing fails yet. To arm the gate, download \
                 this run's `bench-baseline` artifact (already merged, `\"provisional\": \
                 false`) and commit it as the newest `rust/BENCH_pr*.json`.\n\n",
            );
        } else if self.regressions.is_empty() {
            s.push_str(&format!(
                "All tracked paths within {:.0}% of the committed baseline.\n\n",
                self.max_regress * 100.0
            ));
        } else {
            s.push_str(&format!(
                "**FAIL** — {} tracked path(s) regressed more than {:.0}%: {}. If the slowdown \
                 is intended, commit a refreshed baseline from this run's `bench-output` \
                 artifact and justify the change in the PR.\n\n",
                self.regressions.len(),
                self.max_regress * 100.0,
                self.regressions.join(", ")
            ));
        }
        if !self.new_paths.is_empty() {
            s.push_str(&format!(
                "**Warning** — {} tracked path(s) have no baseline entry and are not \
                 gated: {}. Refresh the committed baseline (the CI `bench-baseline` \
                 artifact is ready to commit) so they join the gate.\n\n",
                self.new_paths.len(),
                self.new_paths.iter().map(|n| format!("`{n}`")).collect::<Vec<_>>().join(", ")
            ));
        }
        s.push_str("| tracked path | baseline | current | ratio | status |\n");
        s.push_str("|---|---|---|---|---|\n");
        for r in &self.rows {
            let fmt = |v: Option<f64>| match v {
                Some(x) => crate::bench_util::fmt_s(x),
                None => "—".to_string(),
            };
            let ratio = match r.ratio {
                Some(x) => format!("{x:.2}x"),
                None => "—".to_string(),
            };
            let status = match r.status {
                RowStatus::Ok => "ok",
                RowStatus::Regressed => "**REGRESSED**",
                RowStatus::NewInCurrent => "new (no baseline)",
                RowStatus::MissingInCurrent => "missing in current",
            };
            s.push_str(&format!(
                "| `{}` | {} | {} | {} | {} |\n",
                r.name,
                fmt(r.baseline_s),
                fmt(r.current_s),
                ratio,
                status
            ));
        }
        s
    }
}

/// Minimal JSON value (parse side only — the emit side is hand-formatted).
/// Public since PR 8: the run-artifact bundle (`sim::events::RunArtifact`)
/// serializes through the same tiny layer, and its tests parse back with
/// [`parse_json`] + the accessors below.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (parsed as `f64`).
    Num(f64),
    /// Double-quoted string.
    Str(String),
    /// `[...]` array.
    Arr(Vec<Json>),
    /// `{...}` object, in document key order (duplicate keys preserved).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// First value under `key`, if this is an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(entries) => entries.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// As number, if this is a `Num`.
    pub fn as_num(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// As string slice, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(v) => Some(v),
            _ => None,
        }
    }

    /// As bool, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(v) => Some(*v),
            _ => None,
        }
    }

    /// As array slice, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Is this the `null` literal?
    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }
}

/// Parse one JSON document (the subset the module doc names); rejects
/// trailing bytes.
pub fn parse_json(text: &str) -> Result<Json> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        bail!("trailing bytes after JSON value at offset {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected {:?} at offset {}", c as char, self.i);
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at offset {}", self.i);
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => bail!("unexpected byte at offset {}", self.i),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            out.push((key, val));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => bail!("expected ',' or '}}' at offset {}", self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => bail!("expected ',' or ']' at offset {}", self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self.peek().context("dangling escape")?;
                    out.push(match esc {
                        b'"' => '"',
                        b'\\' => '\\',
                        b'/' => '/',
                        b'n' => '\n',
                        b't' => '\t',
                        b'r' => '\r',
                        other => bail!("unsupported escape \\{}", other as char),
                    });
                    self.i += 1;
                }
                Some(_) => {
                    // copy one UTF-8 scalar (keys here are ASCII in practice)
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .context("invalid UTF-8 in string")?;
                    let ch = rest.chars().next().context("unterminated string")?;
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).expect("ascii number bytes");
        let n: f64 = s.parse().with_context(|| format!("bad number {s:?}"))?;
        Ok(Json::Num(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(entries: &[(&str, f64)], provisional: bool) -> BenchReport {
        BenchReport {
            provisional,
            benches: entries.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        }
    }

    #[test]
    fn json_round_trip() {
        let r = report(&[("compute_c", 0.0123), ("search_5000", 1.5)], false);
        let back = BenchReport::from_json(&r.to_json()).unwrap();
        assert_eq!(r, back);
        // empty benches round-trips too
        let empty = report(&[], true);
        assert_eq!(BenchReport::from_json(&empty.to_json()).unwrap(), empty);
    }

    #[test]
    fn parser_handles_extras_and_rejects_garbage() {
        let r = BenchReport::from_json(
            "{\"schema\": \"fedspace-bench-v1\", \"note\": \"hi\\n\", \"provisional\": true, \
             \"benches\": {\"a\": 1e-3}, \"extra\": [1, 2, null]}",
        )
        .unwrap();
        assert!(r.provisional);
        assert_eq!(r.benches["a"], 1e-3);
        assert!(BenchReport::from_json("{\"benches\": {\"a\": \"fast\"}}").is_err());
        assert!(BenchReport::from_json("[1, 2]").is_err());
        assert!(BenchReport::from_json("{\"a\": 1} trailing").is_err());
        assert!(BenchReport::from_json("{\"a\": ").is_err());
    }

    #[test]
    fn gate_fails_only_past_the_budget() {
        let base = report(&[("a", 1.0), ("b", 1.0), ("gone", 1.0)], false);
        let cur = report(&[("a", 1.24), ("b", 1.26), ("fresh", 0.5)], false);
        let cmp = compare(&base, &cur, 0.25);
        assert!(!cmp.bootstrap);
        assert_eq!(cmp.regressions, vec!["b".to_string()]);
        assert_eq!(cmp.new_paths, vec!["fresh".to_string()]);
        let by_name = |n: &str| cmp.rows.iter().find(|r| r.name == n).unwrap().status;
        assert_eq!(by_name("a"), RowStatus::Ok);
        assert_eq!(by_name("b"), RowStatus::Regressed);
        assert_eq!(by_name("gone"), RowStatus::MissingInCurrent);
        assert_eq!(by_name("fresh"), RowStatus::NewInCurrent);
        let md = cmp.to_markdown();
        assert!(md.contains("REGRESSED"));
        assert!(md.contains("| `a` |"));
        // unknown bench names surface as a counted warning, not a pass
        assert!(md.contains("**Warning** — 1 tracked path(s)"), "{md}");
        assert!(md.contains("`fresh`"));
        let clean = compare(&base, &report(&[("a", 1.0), ("b", 1.0), ("gone", 1.0)], false), 0.25);
        assert!(clean.new_paths.is_empty());
        assert!(!clean.to_markdown().contains("Warning"));
    }

    #[test]
    fn provisional_baseline_bootstraps_instead_of_failing() {
        let base = report(&[("a", 0.0001)], true);
        let cur = report(&[("a", 10.0)], false);
        let cmp = compare(&base, &cur, 0.25);
        assert!(cmp.bootstrap);
        assert!(cmp.regressions.is_empty());
        assert!(cmp.to_markdown().contains("Bootstrap mode"));
        // disjoint keys bootstrap too, even with a non-provisional baseline
        let disjoint = compare(&report(&[("x", 1.0)], false), &cur, 0.25);
        assert!(disjoint.bootstrap);
    }

    #[test]
    fn record_and_snapshot() {
        record("unit_test_path", 0.5);
        record("unit_test_path", 0.25); // overwrite wins
        let snap = recorded();
        assert_eq!(snap["unit_test_path"], 0.25);
    }
}
