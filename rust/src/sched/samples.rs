//! Utility-sample generation (paper Eq. 12, Figure 5 phase 1).
//!
//! The GS pre-trains on a source dataset D^s, stores the checkpoint
//! sequence {w^{i_g}}, then measures the realized loss reduction Δf of
//! applying staleness-weighted stale updates to random checkpoints.
//!
//! Reproduction note (DESIGN.md §5): the paper's Eq. 12 subtracts *raw*
//! gradients; the live GS applies Eq. 4's compensated, normalized update.
//! We sample Δf under the same Eq. 4 update the scheduler will actually
//! trigger, so û predicts the deployed behaviour rather than an
//! unnormalized proxy.

use crate::fl::buffer::GradientEntry;
use crate::fl::server::{CpuAggregator, ServerAggregator};
use crate::rng::Rng;
use anyhow::Result;

/// Backend abstraction so sample generation runs against the PJRT runtime
/// (production) or an analytic mock (tests, scheduler benches).
pub trait SampleBackend {
    /// flat parameter dimension
    fn d(&self) -> usize;
    /// initial parameter vector
    fn init(&self, rng: &mut Rng) -> Vec<f32>;
    /// one satellite-style local update (E SGD steps) from `w`
    fn local_delta(&self, w: &[f32], rng: &mut Rng) -> Result<Vec<f32>>;
    /// source-dataset loss f(w)
    fn loss(&self, w: &[f32]) -> Result<f64>;
}

/// Checkpoint sequence from pre-training on the source dataset.
pub struct CheckpointBank {
    /// w after each federated pre-training round (index = round).
    pub checkpoints: Vec<Vec<f32>>,
    /// Source-dataset loss of each checkpoint.
    pub losses: Vec<f64>,
}

/// Phase-1 pre-training: `rounds` federated rounds with `contributors`
/// fresh updates each, Eq. 4 aggregation (all s = 0).
pub fn pretrain_bank(
    backend: &dyn SampleBackend,
    rounds: usize,
    contributors: usize,
    alpha: f64,
    rng: &mut Rng,
) -> Result<CheckpointBank> {
    let mut w = backend.init(rng);
    let mut checkpoints = Vec::with_capacity(rounds + 1);
    let mut losses = Vec::with_capacity(rounds + 1);
    checkpoints.push(w.clone());
    losses.push(backend.loss(&w)?);
    let mut agg = CpuAggregator;
    for _ in 0..rounds {
        let entries: Vec<GradientEntry> = (0..contributors)
            .map(|c| {
                Ok(GradientEntry {
                    sat: c,
                    staleness: 0,
                    grad: backend.local_delta(&w, rng)?.into(),
                    n_samples: 1,
                })
            })
            .collect::<Result<_>>()?;
        agg.aggregate(&mut w, &entries, alpha)?;
        checkpoints.push(w.clone());
        losses.push(backend.loss(&w)?);
    }
    Ok(CheckpointBank { checkpoints, losses })
}

/// One generated sample: (stalenesses, T) → Δf.
pub type UtilitySamples = (Vec<(Vec<usize>, f64)>, Vec<f64>);

/// Phase-1 sample generation: N random (s, i_start) pairs replayed against
/// the checkpoint bank.
pub fn generate_samples(
    backend: &dyn SampleBackend,
    bank: &CheckpointBank,
    n_samples: usize,
    s_max: usize,
    max_contributors: usize,
    alpha: f64,
    rng: &mut Rng,
) -> Result<UtilitySamples> {
    assert!(bank.checkpoints.len() >= 2, "bank too small");
    let mut inputs = Vec::with_capacity(n_samples);
    let mut targets = Vec::with_capacity(n_samples);
    let mut agg = CpuAggregator;
    for _ in 0..n_samples {
        let i_start = rng.gen_range(1, bank.checkpoints.len());
        let n_c = rng.gen_range(1, max_contributors + 1);
        let stalenesses: Vec<usize> = (0..n_c)
            .map(|_| rng.gen_range(0, s_max.min(i_start) + 1))
            .collect();
        let entries: Vec<GradientEntry> = stalenesses
            .iter()
            .enumerate()
            .map(|(c, &s)| {
                let base = &bank.checkpoints[i_start - s];
                Ok(GradientEntry {
                    sat: c,
                    staleness: s,
                    grad: backend.local_delta(base, rng)?.into(),
                    n_samples: 1,
                })
            })
            .collect::<Result<_>>()?;
        let mut w = bank.checkpoints[i_start].clone();
        let f_before = bank.losses[i_start];
        agg.aggregate(&mut w, &entries, alpha)?;
        let f_after = backend.loss(&w)?;
        inputs.push((stalenesses, f_before));
        targets.push(f_before - f_after);
    }
    Ok((inputs, targets))
}

/// CSV cache so û refits instantly across runs: `s1;s2;...,T,target`.
pub fn samples_to_csv(samples: &UtilitySamples) -> String {
    let mut out = String::from("stalenesses,T,delta_f\n");
    for ((st, t), y) in samples.0.iter().zip(samples.1.iter()) {
        let s: Vec<String> = st.iter().map(|v| v.to_string()).collect();
        out.push_str(&format!("{},{},{}\n", s.join(";"), t, y));
    }
    out
}

/// Parse the CSV cache back.
pub fn samples_from_csv(text: &str) -> Result<UtilitySamples> {
    let mut inputs = Vec::new();
    let mut targets = Vec::new();
    for line in text.lines().skip(1) {
        if line.trim().is_empty() {
            continue;
        }
        let parts: Vec<&str> = line.split(',').collect();
        anyhow::ensure!(parts.len() == 3, "bad sample line {line:?}");
        let st: Vec<usize> = if parts[0].is_empty() {
            Vec::new()
        } else {
            parts[0].split(';').map(|v| v.parse()).collect::<Result<_, _>>()?
        };
        inputs.push((st, parts[1].parse()?));
        targets.push(parts[2].parse()?);
    }
    Ok((inputs, targets))
}

/// Analytic mock backend: federated least squares f(w) = ½‖w − c‖², local
/// updates are noisy gradient steps. Used by tests and scheduler benches;
/// staleness provably reduces Δf here, which the tests verify û learns.
pub struct MockBackend {
    /// Parameter dimension.
    pub dim: usize,
    /// The least-squares optimum c.
    pub target: Vec<f32>,
    /// Local-update step size.
    pub lr: f32,
    /// Gradient noise std.
    pub noise: f32,
}

impl MockBackend {
    /// A mock task with a seeded random optimum.
    pub fn new(dim: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        MockBackend {
            dim,
            target: (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
            lr: 0.2,
            noise: 0.05,
        }
    }
}

impl SampleBackend for MockBackend {
    fn d(&self) -> usize {
        self.dim
    }

    fn init(&self, rng: &mut Rng) -> Vec<f32> {
        (0..self.dim).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    fn local_delta(&self, w: &[f32], rng: &mut Rng) -> Result<Vec<f32>> {
        Ok(w.iter()
            .zip(self.target.iter())
            .map(|(wi, ci)| -self.lr * (wi - ci) + rng.normal_f32(0.0, self.noise))
            .collect())
    }

    fn loss(&self, w: &[f32]) -> Result<f64> {
        Ok(w.iter()
            .zip(self.target.iter())
            .map(|(wi, ci)| 0.5 * ((wi - ci) as f64).powi(2))
            .sum::<f64>()
            / self.dim as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::utility::UtilityModel;

    #[test]
    fn pretrain_reduces_loss() {
        let b = MockBackend::new(16, 0);
        let mut rng = Rng::new(1);
        let bank = pretrain_bank(&b, 10, 4, 0.5, &mut rng).unwrap();
        assert_eq!(bank.checkpoints.len(), 11);
        assert!(bank.losses[10] < bank.losses[0]);
    }

    #[test]
    fn samples_have_right_shapes() {
        let b = MockBackend::new(8, 0);
        let mut rng = Rng::new(2);
        let bank = pretrain_bank(&b, 8, 4, 0.5, &mut rng).unwrap();
        let (inp, tgt) = generate_samples(&b, &bank, 50, 5, 8, 0.5, &mut rng).unwrap();
        assert_eq!(inp.len(), 50);
        assert_eq!(tgt.len(), 50);
        for (st, t) in &inp {
            assert!(!st.is_empty() && st.len() <= 8);
            assert!(st.iter().all(|&s| s <= 5));
            assert!(t.is_finite());
        }
    }

    #[test]
    fn utility_model_learns_staleness_penalty_from_samples() {
        // End-to-end phase 1 on the mock: û must learn that fresh
        // aggregations reduce loss more than stale ones.
        let b = MockBackend::new(16, 3);
        let mut rng = Rng::new(4);
        let bank = pretrain_bank(&b, 12, 4, 0.5, &mut rng).unwrap();
        let (inp, tgt) = generate_samples(&b, &bank, 400, 6, 8, 0.5, &mut rng).unwrap();
        let mut u = UtilityModel::new("forest").unwrap();
        u.fit(&inp, &tgt);
        let t_mid = bank.losses[4];
        let fresh = u.predict(&[0, 0, 0, 0], t_mid);
        let stale = u.predict(&[6, 6, 6, 6], t_mid);
        assert!(fresh > stale, "fresh={fresh} stale={stale}");
    }

    #[test]
    fn csv_roundtrip() {
        let samples: UtilitySamples = (
            vec![(vec![0, 2, 5], 1.5), (vec![1], 0.25)],
            vec![0.125, -0.01],
        );
        let csv = samples_to_csv(&samples);
        let back = samples_from_csv(&csv).unwrap();
        assert_eq!(back.0, samples.0);
        assert_eq!(back.1, samples.1);
    }
}
