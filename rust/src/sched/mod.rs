//! The FedSpace aggregation scheduler (paper §3) — the system contribution.
//!
//! Pipeline (Figure 5): [`samples`] generates (staleness-vector, training
//! status) → Δf pairs from a pre-trained checkpoint sequence (Eq. 12);
//! [`utility`] fits the regression model û on them; [`forecast`] replays the
//! deterministic future connectivity under a candidate aggregation vector
//! a^{i,i+I0} to obtain the exact staleness vectors s^l (Eq. 9) and idle
//! contacts (Eq. 10); [`search`] random-searches over a ∈ R ⊂ {0,1}^I0
//! maximizing Σ_l û(s_l, T) (Eq. 13); [`planner`] ties it together at each
//! window boundary.

pub mod features;
pub mod forecast;
pub mod planner;
pub mod samples;
pub mod search;
pub mod utility;

pub use features::featurize;
pub use forecast::{
    forecast_window, forecast_window_with, ForecastScratch, SatForecastState, WindowForecast,
};
pub use planner::FedSpacePlanner;
pub use samples::{
    generate_samples, pretrain_bank, samples_from_csv, samples_to_csv, CheckpointBank,
    MockBackend, SampleBackend, UtilitySamples,
};
pub use search::{
    infer_n_range, random_search, random_search_serial, schedule_utility, schedule_utility_opts,
    schedule_utility_with, SearchParams,
};
pub use utility::UtilityModel;
