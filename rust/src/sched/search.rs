//! Random search over aggregation vectors (paper Eq. 13, §3.2 phase 2).
//!
//! R ⊂ {0,1}^I0 is sampled by drawing n_agg ∈ [N_min, N_max] and placing
//! n_agg aggregations uniformly without replacement over the I0 slots —
//! exactly the paper's search-space reduction (|R| = 5000 by default).
//!
//! [`random_search`] is an L3 hot path (|R| candidate replays per planned
//! window). It draws every candidate serially from the seeded [`Rng`] —
//! consuming the stream in exactly the legacy order, so fixed seeds stay
//! bit-identical — then scores candidates in parallel over borrowed state
//! via [`crate::exec::scope_chunks`], each worker reusing one
//! [`ForecastScratch`] across its whole chunk. The argmax is reduced
//! serially in candidate order (first maximum wins), matching the serial
//! reference [`random_search_serial`] exactly; the determinism tests below
//! assert equality.

use super::forecast::{forecast_window_with, ForecastScratch, SatForecastState};
use super::utility::UtilityModel;
use crate::connectivity::StepView;
use crate::exec;
use crate::rng::Rng;

/// Search hyper-parameters (paper §4.1 defaults in `ExperimentConfig`).
#[derive(Clone, Debug)]
pub struct SearchParams {
    /// Window length I0 in slots.
    pub i0: usize,
    /// Minimum aggregations per window N_min.
    pub n_min: usize,
    /// Maximum aggregations per window N_max.
    pub n_max: usize,
    /// |R| — number of candidate vectors evaluated
    pub n_search: usize,
}

/// Window objective for one candidate (Eq. 13).
///
/// The paper scores Σ_l û(s_l, f(w^i)) with the training status frozen at
/// the window start. Applied literally, that objective is additive in the
/// number of aggregations — splitting one batch into two always raises the
/// sum (û has diminishing returns in contributors), so the search
/// degenerates to a^l ≡ 1. The paper escapes this by hand-tuning
/// [N_min, N_max]; we additionally *chain* the training status through the
/// window (T ← T − û, exactly the dependence §3.1 motivates introducing T
/// for): as predicted loss drops, small or stale aggregations turn
/// negative-utility and the search finds an interior aggregation count.
/// `chain_t = false` recovers the paper's frozen-T objective (ablation
/// bench `bench_ablation`).
pub fn schedule_utility_opts(
    sched: &dyn StepView,
    start: usize,
    candidate: &[bool],
    states: &[SatForecastState],
    utility: &UtilityModel,
    training_status: f64,
    chain_t: bool,
) -> f64 {
    let mut scratch = ForecastScratch::default();
    schedule_utility_with(
        &mut scratch,
        sched,
        start,
        candidate,
        states,
        utility,
        training_status,
        chain_t,
    )
}

/// [`schedule_utility_opts`] with caller-owned forecast scratch (hot-path
/// form used by the parallel search workers).
#[allow(clippy::too_many_arguments)]
pub fn schedule_utility_with(
    scratch: &mut ForecastScratch,
    sched: &dyn StepView,
    start: usize,
    candidate: &[bool],
    states: &[SatForecastState],
    utility: &UtilityModel,
    training_status: f64,
    chain_t: bool,
) -> f64 {
    let f = forecast_window_with(scratch, sched, start, candidate, states);
    let mut t_cur = training_status;
    let mut total = 0.0;
    for st in &f.aggregations {
        let u = if utility.is_fitted() {
            utility.predict(st, t_cur)
        } else {
            UtilityModel::heuristic(st, t_cur)
        };
        total += u;
        if chain_t {
            t_cur = (t_cur - u).max(1e-6);
        }
    }
    total
}

/// Chained-T window objective (the default; see `schedule_utility_opts`).
pub fn schedule_utility(
    sched: &dyn StepView,
    start: usize,
    candidate: &[bool],
    states: &[SatForecastState],
    utility: &UtilityModel,
    training_status: f64,
) -> f64 {
    schedule_utility_opts(sched, start, candidate, states, utility, training_status, true)
}

/// Draw one Eq.-13 candidate: n_agg ∈ [N_min, N_max] aggregations placed
/// uniformly without replacement over the I0 slots.
fn draw_candidate(params: &SearchParams, rng: &mut Rng) -> Vec<bool> {
    let n_agg = rng.gen_range(params.n_min, params.n_max + 1);
    let mut cand = vec![false; params.i0];
    for pos in rng.choose_k(params.i0, n_agg) {
        cand[pos] = true;
    }
    cand
}

/// Random search (Eq. 13): returns (best schedule, its predicted utility).
///
/// Candidates are drawn serially from `rng` (stream order identical to
/// [`random_search_serial`], so determinism is seed-only), scored in
/// parallel, and argmax-reduced in candidate order — bit-identical to the
/// serial reference at any thread count.
pub fn random_search(
    sched: &dyn StepView,
    start: usize,
    states: &[SatForecastState],
    utility: &UtilityModel,
    training_status: f64,
    params: &SearchParams,
    rng: &mut Rng,
) -> (Vec<bool>, f64) {
    assert!(params.n_min >= 1 && params.n_min <= params.n_max);
    assert!(params.n_max <= params.i0);
    assert!(params.n_search > 0, "n_search must be positive");
    let cands: Vec<Vec<bool>> =
        (0..params.n_search).map(|_| draw_candidate(params, rng)).collect();
    // ≥ 64 candidates per worker so tiny searches stay on the caller thread
    let threads = exec::default_parallelism().min(params.n_search.div_ceil(64));
    let utilities: Vec<f64> = exec::scope_chunks(&cands, threads, |_, chunk| {
        let mut scratch = ForecastScratch::default();
        chunk
            .iter()
            .map(|cand| {
                schedule_utility_with(
                    &mut scratch,
                    sched,
                    start,
                    cand,
                    states,
                    utility,
                    training_status,
                    true,
                )
            })
            .collect()
    });
    // first maximum wins: ties (and NaNs) resolve to the earliest candidate,
    // exactly as the serial loop's strict `u > best` update rule
    let mut best_idx = 0usize;
    let mut best_u = utilities[0];
    for (i, &u) in utilities.iter().enumerate().skip(1) {
        if u > best_u {
            best_u = u;
            best_idx = i;
        }
    }
    let mut cands = cands;
    (cands.swap_remove(best_idx), best_u)
}

/// The original serial search: draws and scores one candidate at a time.
/// Kept as the determinism oracle for [`random_search`] and the
/// single-thread baseline in `bench_perf` (EXPERIMENTS.md §Perf).
pub fn random_search_serial(
    sched: &dyn StepView,
    start: usize,
    states: &[SatForecastState],
    utility: &UtilityModel,
    training_status: f64,
    params: &SearchParams,
    rng: &mut Rng,
) -> (Vec<bool>, f64) {
    assert!(params.n_min >= 1 && params.n_min <= params.n_max);
    assert!(params.n_max <= params.i0);
    let mut best: Option<(Vec<bool>, f64)> = None;
    for _ in 0..params.n_search {
        let cand = draw_candidate(params, rng);
        let u = schedule_utility(sched, start, &cand, states, utility, training_status);
        let better = match &best {
            None => true,
            Some((_, bu)) => u > *bu,
        };
        if better {
            best = Some((cand, u));
        }
    }
    best.expect("n_search > 0")
}

/// Infer a reasonable [N_min, N_max] from û (paper: "we infer N_min and
/// N_max from û"): scan aggregation counts on the real window, keep the
/// count-range whose marginal utility stays positive.
pub fn infer_n_range(
    sched: &dyn StepView,
    start: usize,
    states: &[SatForecastState],
    utility: &UtilityModel,
    training_status: f64,
    i0: usize,
    rng: &mut Rng,
) -> (usize, usize) {
    let mut best_n = 1;
    let mut best_u = f64::NEG_INFINITY;
    let mut utilities = Vec::new();
    for n in 1..=i0 {
        // average utility over a few uniform placements of n aggregations
        let mut acc = 0.0;
        const TRIALS: usize = 8;
        for _ in 0..TRIALS {
            let mut cand = vec![false; i0];
            for pos in rng.choose_k(i0, n) {
                cand[pos] = true;
            }
            acc += schedule_utility(sched, start, &cand, states, utility, training_status);
        }
        let u = acc / TRIALS as f64;
        utilities.push(u);
        if u > best_u {
            best_u = u;
            best_n = n;
        }
    }
    // widen around the argmax to counts within 80% of the best utility
    let lo = (1..=best_n)
        .find(|&n| utilities[n - 1] >= 0.8 * best_u)
        .unwrap_or(best_n);
    let hi = (best_n..=i0)
        .rev()
        .find(|&n| utilities[n - 1] >= 0.8 * best_u)
        .unwrap_or(best_n);
    (lo.max(1), hi.max(lo))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::connectivity::ConnectivitySchedule;
    use crate::testing::property;

    fn line_schedule(k: usize, steps: usize, rng: &mut Rng) -> ConnectivitySchedule {
        let sets: Vec<Vec<usize>> = (0..steps)
            .map(|_| {
                let n = rng.gen_range(0, k + 1);
                let mut v = rng.choose_k(k, n);
                v.sort_unstable();
                v
            })
            .collect();
        ConnectivitySchedule::from_sets(sets, k)
    }

    fn fresh(k: usize) -> Vec<SatForecastState> {
        vec![SatForecastState::fresh(); k]
    }

    #[test]
    fn search_respects_n_range() {
        let mut rng = Rng::new(1);
        let s = line_schedule(5, 24, &mut rng);
        let u = UtilityModel::new("forest").unwrap(); // unfitted -> heuristic
        let params = SearchParams { i0: 24, n_min: 4, n_max: 8, n_search: 200 };
        let (best, _) =
            random_search(&s, 0, &fresh(5), &u, 1.0, &params, &mut rng);
        let n: usize = best.iter().filter(|&&b| b).count();
        assert!((4..=8).contains(&n), "n={n}");
        assert_eq!(best.len(), 24);
    }

    #[test]
    fn search_beats_random_candidate_on_average() {
        let mut rng = Rng::new(2);
        let s = line_schedule(6, 24, &mut rng);
        let u = UtilityModel::new("forest").unwrap();
        let params = SearchParams { i0: 24, n_min: 2, n_max: 10, n_search: 300 };
        let (_, best_u) = random_search(&s, 0, &fresh(6), &u, 1.0, &params, &mut rng);
        // any single random candidate can't beat the max over 300
        for _ in 0..20 {
            let n = rng.gen_range(2, 11);
            let mut cand = vec![false; 24];
            for p in rng.choose_k(24, n) {
                cand[p] = true;
            }
            let cu = schedule_utility(&s, 0, &cand, &fresh(6), &u, 1.0);
            assert!(cu <= best_u + 1e-9);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut r1 = Rng::new(3);
        let mut r2 = Rng::new(3);
        let s1 = line_schedule(4, 24, &mut r1);
        let s2 = line_schedule(4, 24, &mut r2);
        let u = UtilityModel::new("forest").unwrap();
        let params = SearchParams { i0: 24, n_min: 1, n_max: 6, n_search: 100 };
        let a = random_search(&s1, 0, &fresh(4), &u, 1.0, &params, &mut r1);
        let b = random_search(&s2, 0, &fresh(4), &u, 1.0, &params, &mut r2);
        assert_eq!(a.0, b.0);
    }

    #[test]
    fn parallel_search_bit_identical_to_serial() {
        // same seed → identical best schedule, identical utility, and an
        // identically-positioned rng stream afterwards (the parallel path
        // must consume draws in exactly the legacy order)
        let u = UtilityModel::new("forest").unwrap();
        for (seed, n_search) in [(3u64, 100usize), (17, 640), (99, 1)] {
            let mut rp = Rng::new(seed);
            let mut rs = Rng::new(seed);
            let sp = line_schedule(5, 24, &mut rp);
            let ss = line_schedule(5, 24, &mut rs);
            let params = SearchParams { i0: 24, n_min: 2, n_max: 8, n_search };
            let a = random_search(&sp, 0, &fresh(5), &u, 1.0, &params, &mut rp);
            let b = random_search_serial(&ss, 0, &fresh(5), &u, 1.0, &params, &mut rs);
            assert_eq!(a.0, b.0, "seed={seed} n_search={n_search}");
            assert_eq!(a.1.to_bits(), b.1.to_bits(), "seed={seed}");
            assert_eq!(rp.next_u64(), rs.next_u64(), "rng stream diverged (seed={seed})");
        }
    }

    #[test]
    fn property_candidates_always_valid() {
        property(30, |rng| {
            let k = rng.gen_range(1, 8);
            let i0 = rng.gen_range(4, 30);
            let s = line_schedule(k, i0, rng);
            let n_min = rng.gen_range(1, i0.min(4) + 1);
            let n_max = rng.gen_range(n_min, i0 + 1);
            let u = UtilityModel::new("forest").unwrap();
            let params = SearchParams { i0, n_min, n_max, n_search: 20 };
            let (best, util) =
                random_search(&s, 0, &fresh(k), &u, 1.0, &params, rng);
            let n: usize = best.iter().filter(|&&b| b).count();
            assert!(n >= n_min && n <= n_max);
            assert!(util.is_finite());
        });
    }

    #[test]
    fn infer_n_range_sane() {
        let mut rng = Rng::new(5);
        let s = line_schedule(6, 24, &mut rng);
        let u = UtilityModel::new("forest").unwrap();
        let (lo, hi) = infer_n_range(&s, 0, &fresh(6), &u, 1.0, 24, &mut rng);
        assert!(lo >= 1 && lo <= hi && hi <= 24, "({lo}, {hi})");
    }
}
