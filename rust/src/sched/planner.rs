//! Window-boundary planning: glue between the live engine and the
//! forecast + utility + random-search pipeline.
//!
//! `plan` delegates to [`random_search`], whose candidate scoring runs in
//! parallel on scoped worker threads ([`crate::exec::scope_chunks`], sized
//! by [`crate::exec::default_parallelism`]). Determinism is seed-only: the
//! planner's private `rng` is consumed in the same order at any thread
//! count, so replans (and whole engine runs) replay bit-identically — see
//! `search::tests::parallel_search_bit_identical_to_serial` and
//! `sim::engine::tests::deterministic_given_seed`.

use super::forecast::SatForecastState;
use super::search::{random_search, SearchParams};
use super::utility::UtilityModel;
use crate::connectivity::StepView;
use crate::rng::Rng;

/// Plans a^{i,i+I0} at every window boundary i ∈ {0, I0, 2I0, …}.
pub struct FedSpacePlanner {
    /// The fitted utility regression û.
    pub utility: UtilityModel,
    /// Random-search hyper-parameters.
    pub params: SearchParams,
    rng: Rng,
    /// predicted utility of each committed window (telemetry)
    pub planned_utilities: Vec<f64>,
}

impl FedSpacePlanner {
    /// A planner with its own seeded search RNG.
    pub fn new(utility: UtilityModel, params: SearchParams, seed: u64) -> Self {
        FedSpacePlanner { utility, params, rng: Rng::new(seed), planned_utilities: Vec::new() }
    }

    /// Produce the next window's aggregation vector (Eq. 13).
    pub fn plan(
        &mut self,
        sched: &dyn StepView,
        start: usize,
        states: &[SatForecastState],
        training_status: f64,
    ) -> Vec<bool> {
        let (best, u) = random_search(
            sched,
            start,
            states,
            &self.utility,
            training_status,
            &self.params,
            &mut self.rng,
        );
        self.planned_utilities.push(u);
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::connectivity::ConnectivitySchedule;

    #[test]
    fn plans_valid_windows_repeatedly() {
        let sets: Vec<Vec<usize>> =
            (0..48).map(|i| if i % 3 == 0 { vec![0, 1] } else { vec![1] }).collect();
        let sched = ConnectivitySchedule::from_sets(sets, 2);
        let u = UtilityModel::new("forest").unwrap();
        let params = SearchParams { i0: 24, n_min: 2, n_max: 6, n_search: 50 };
        let mut p = FedSpacePlanner::new(u, params, 0);
        let states = vec![SatForecastState::fresh(); 2];
        for start in [0, 24] {
            let w = p.plan(&sched, start, &states, 1.0);
            assert_eq!(w.len(), 24);
            let n = w.iter().filter(|&&b| b).count();
            assert!((2..=6).contains(&n));
        }
        assert_eq!(p.planned_utilities.len(), 2);
    }
}
