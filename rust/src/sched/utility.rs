//! The utility model û(s, T) ≈ Δf — the regression at the heart of the
//! FedSpace scheduler (paper §3.2, Figure 5 phase 1).

use super::features::featurize;
use crate::ml::{LinearRegression, RandomForest, RandomForestParams, Regressor};
use anyhow::{bail, Result};

/// û: a fitted regressor over featurized (staleness multiset, T) inputs.
pub struct UtilityModel {
    regressor: Box<dyn Regressor>,
    fitted: bool,
}

impl Clone for UtilityModel {
    /// Deep-clones the fitted regressor — one phase-1 fit can feed every
    /// gateway's planner in a multi-gateway federation (ADR-0006).
    fn clone(&self) -> Self {
        UtilityModel { regressor: self.regressor.clone_box(), fitted: self.fitted }
    }
}

impl UtilityModel {
    /// `kind`: "forest" (paper default) or "linear" (ablation baseline).
    pub fn new(kind: &str) -> Result<Self> {
        let regressor: Box<dyn Regressor> = match kind {
            "forest" => Box::new(RandomForest::new(RandomForestParams::default())),
            "linear" => Box::new(LinearRegression::new(1e-6)),
            other => bail!("unknown regressor kind {other:?}"),
        };
        Ok(UtilityModel { regressor, fitted: false })
    }

    /// Fit on raw samples: (stalenesses of one aggregation, T) → Δf.
    pub fn fit(&mut self, samples: &[(Vec<usize>, f64)], targets: &[f64]) {
        assert_eq!(samples.len(), targets.len());
        assert!(!samples.is_empty(), "no utility samples");
        let x: Vec<Vec<f64>> = samples.iter().map(|(s, t)| featurize(s, *t)).collect();
        self.regressor.fit(&x, targets);
        self.fitted = true;
    }

    /// Predicted Δf of aggregating `stalenesses` at training status `t`.
    pub fn predict(&self, stalenesses: &[usize], t: f64) -> f64 {
        assert!(self.fitted, "utility model not fitted");
        self.regressor.predict(&featurize(stalenesses, t))
    }

    /// Has `fit` run? (`predict` panics otherwise; use [`Self::heuristic`].)
    pub fn is_fitted(&self) -> bool {
        self.fitted
    }

    /// Fallback heuristic û when no samples are available (cold start):
    /// fresh gradients help, stale ones help less (the Eq.-4 compensation
    /// shape), aggregating nothing is worthless. Keeps FedSpace functional
    /// before phase 1 completes; tested to prefer the same orderings.
    pub fn heuristic(stalenesses: &[usize], _t: f64) -> f64 {
        stalenesses.iter().map(|&s| ((s + 1) as f64).powf(-0.5)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synthetic_samples(n: usize) -> (Vec<(Vec<usize>, f64)>, Vec<f64>) {
        // ground truth: Δf = Σ (s+1)^-1 scaled by training status decay
        let mut samples = Vec::new();
        let mut targets = Vec::new();
        let mut rng = crate::rng::Rng::new(0);
        for _ in 0..n {
            let k = rng.gen_range(1, 12);
            let st: Vec<usize> = (0..k).map(|_| rng.gen_range(0, 7)).collect();
            let t = rng.gen_f64(0.5, 4.0);
            let y: f64 =
                st.iter().map(|&s| 1.0 / (s + 1) as f64).sum::<f64>() * (t / 4.0);
            samples.push((st, t));
            targets.push(y);
        }
        (samples, targets)
    }

    #[test]
    fn learns_staleness_hurts() {
        let (s, y) = synthetic_samples(600);
        let mut u = UtilityModel::new("forest").unwrap();
        u.fit(&s, &y);
        let fresh = u.predict(&[0, 0, 0, 0], 2.0);
        let stale = u.predict(&[6, 6, 6, 6], 2.0);
        assert!(fresh > stale, "fresh={fresh} stale={stale}");
    }

    #[test]
    fn learns_more_contributors_help() {
        let (s, y) = synthetic_samples(600);
        let mut u = UtilityModel::new("forest").unwrap();
        u.fit(&s, &y);
        let many = u.predict(&[0, 0, 0, 0, 0, 0, 0, 0], 2.0);
        let few = u.predict(&[0], 2.0);
        assert!(many > few, "many={many} few={few}");
    }

    #[test]
    fn linear_kind_works() {
        let (s, y) = synthetic_samples(300);
        let mut u = UtilityModel::new("linear").unwrap();
        u.fit(&s, &y);
        assert!(u.is_fitted());
        assert!(u.predict(&[0, 0], 2.0).is_finite());
    }

    #[test]
    fn unknown_kind_rejected() {
        assert!(UtilityModel::new("svm").is_err());
    }

    #[test]
    #[should_panic]
    fn predict_before_fit_panics() {
        let u = UtilityModel::new("forest").unwrap();
        let _ = u.predict(&[0], 1.0);
    }

    #[test]
    fn clone_predicts_identically() {
        let (s, y) = synthetic_samples(300);
        let mut u = UtilityModel::new("forest").unwrap();
        u.fit(&s, &y);
        let c = u.clone();
        assert!(c.is_fitted());
        for probe in [&[0usize, 1, 2][..], &[4], &[0, 0, 0, 0, 6]] {
            assert_eq!(u.predict(probe, 1.5).to_bits(), c.predict(probe, 1.5).to_bits());
        }
    }

    #[test]
    fn heuristic_prefers_fresh_and_more() {
        assert!(UtilityModel::heuristic(&[0], 1.0) > UtilityModel::heuristic(&[5], 1.0));
        assert!(
            UtilityModel::heuristic(&[0, 0], 1.0) > UtilityModel::heuristic(&[0], 1.0)
        );
        assert_eq!(UtilityModel::heuristic(&[], 1.0), 0.0);
    }
}
