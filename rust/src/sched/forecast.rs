//! Deterministic replay of a candidate aggregation vector over the known
//! future connectivity — computes the staleness vectors s^l (Eq. 9) and
//! idle indicators (Eq. 10) FedSpace's objective needs.
//!
//! This is the paper's key insight made executable: because C is
//! deterministic, the GS can evaluate *exactly* what any schedule would do
//! to every satellite's staleness before committing to it.

use crate::connectivity::StepView;

/// Scheduling-relevant state of one satellite at the window start.
#[derive(Clone, Copy, Debug)]
pub struct SatForecastState {
    /// satellite holds a trained (or in-flight) update not yet uploaded
    pub pending: bool,
    /// staleness its pending update has already accumulated (i_g − i_{g,k})
    pub staleness_now: usize,
    /// satellite holds the current global model version (a contact without
    /// aggregation in between re-sends nothing → idle)
    pub holds_current: bool,
    /// satellite has local data at all (Non-IID may starve some)
    pub has_data: bool,
}

impl SatForecastState {
    /// The cold-start state: no pending update, nothing held, has data.
    pub fn fresh() -> Self {
        SatForecastState { pending: false, staleness_now: 0, holds_current: false, has_data: true }
    }
}

/// Result of replaying one candidate schedule.
#[derive(Clone, Debug)]
pub struct WindowForecast {
    /// for each l with a^l = 1 (in window order): stalenesses of the
    /// gradients that aggregation would consume (the s^l vector's
    /// non-negative entries; absent satellites are the paper's −1 entries)
    pub aggregations: Vec<Vec<usize>>,
    /// idle contacts in the window (connected, nothing new to send)
    pub idle: usize,
    /// total contacts in the window
    pub contacts: usize,
}

/// Reusable per-satellite state buffers for [`forecast_window_with`].
///
/// The scheduler's random search replays thousands of candidate windows per
/// plan; one scratch per search worker means a candidate evaluation
/// allocates nothing K-sized (K = number of satellites).
#[derive(Clone, Debug, Default)]
pub struct ForecastScratch {
    pending: Vec<bool>,
    base: Vec<i64>,
    holds_current: Vec<bool>,
    buffered: Vec<usize>,
    /// Step at which each satellite's pending update became (or becomes)
    /// ready — the relay-latency bookkeeping (only consulted while
    /// `pending` is set; with hop delay 0 it reduces to "next slot").
    ready: Vec<i64>,
}

/// Replay `schedule` (a^{start..start+I0}) over the known connectivity.
///
/// `sched` is any [`StepView`] — the fully materialized schedule or a
/// [`crate::connectivity::WindowView`] lifted out of a stream; the replay
/// only reads the window's steps. `states` is indexed by satellite. The
/// replay uses the same client semantics as the live engine (upload at
/// first contact with a pending update; re-train only on version change;
/// training completes within one slot, matching T0 = 15 min ≫ E local
/// steps).
///
/// Relayed contacts are discounted by their relay latency (ADR-0005): a
/// contact over `h` hops with `hop_delay = sched.hop_delay_slots()` both
/// delivers the model `h × hop_delay` slots late (training finishes later)
/// and requires the pending update to have been ready `h × hop_delay`
/// slots before the contact. With `hop_delay = 0` — every pre-existing
/// schedule, and both ISL built-ins — the replay is unchanged bit for bit.
/// Initial pending updates are modelled as ready at the window start (the
/// engine knows the exact `ready_at`; the window does not carry it).
pub fn forecast_window(
    sched: &dyn StepView,
    start: usize,
    schedule: &[bool],
    states: &[SatForecastState],
) -> WindowForecast {
    forecast_window_with(&mut ForecastScratch::default(), sched, start, schedule, states)
}

/// [`forecast_window`] with caller-owned scratch buffers (hot-path form).
pub fn forecast_window_with(
    scratch: &mut ForecastScratch,
    sched: &dyn StepView,
    start: usize,
    schedule: &[bool],
    states: &[SatForecastState],
) -> WindowForecast {
    let k = sched.n_sats();
    assert_eq!(states.len(), k);
    let hop_delay = sched.hop_delay_slots();
    // relative aggregation counter; pending base expressed in it
    let mut agg_count: usize = 0;
    scratch.pending.clear();
    scratch.pending.extend(states.iter().map(|s| s.pending));
    // staleness of pending update if uploaded after `agg_count` rounds:
    // staleness_now + agg_count − base_offset
    scratch.base.clear();
    scratch.base.extend(states.iter().map(|s| -(s.staleness_now as i64)));
    scratch.holds_current.clear();
    scratch.holds_current.extend(states.iter().map(|s| s.holds_current));
    scratch.buffered.clear();
    // initial pendings: ready at the window start at the latest
    scratch.ready.clear();
    scratch.ready.resize(k, start as i64);
    let pending = &mut scratch.pending;
    let base = &mut scratch.base;
    let holds_current = &mut scratch.holds_current;
    let buffered = &mut scratch.buffered;
    let ready = &mut scratch.ready;
    let mut aggregations = Vec::new();
    let mut idle = 0usize;
    let mut contacts = 0usize;

    let end = (start + schedule.len()).min(sched.n_steps());
    for (w, l) in (start..end).enumerate() {
        let conn = sched.sats_at(l);
        let hops = sched.hops_at(l);
        // relay latency of contact j: hops[j] × hop_delay slots each way
        // (empty hops ⇒ all direct, the plain-schedule fast path)
        let delay_of = |j: usize| -> i64 {
            if hops.is_empty() {
                0
            } else {
                (hops[j] as usize * hop_delay) as i64
            }
        };
        for (j, &s) in conn.iter().enumerate() {
            contacts += 1;
            if !states[s].has_data {
                idle += 1;
                continue;
            }
            // an upload over this contact's relay path must have been ready
            // `delay` slots ago to land now (mirrors SatClient::
            // can_upload_relayed); with hop_delay = 0 this is exactly the
            // legacy "pending ⇒ upload" condition
            if pending[s] && ready[s] + delay_of(j) <= l as i64 {
                buffered.push((agg_count as i64 - base[s]) as usize);
                pending[s] = false;
            } else if pending[s] || holds_current[s] {
                // connected with nothing deliverable: a re-contact holding
                // the current version, or a pending update still in flight
                // on its relay path (hop_delay > 0 only)
                idle += 1;
            }
        }
        if schedule[w] && !buffered.is_empty() {
            aggregations.push(std::mem::take(buffered));
            agg_count += 1;
            // everyone's held version is now outdated
            for h in holds_current.iter_mut() {
                *h = false;
            }
        }
        // broadcast: connected sats not holding the current version receive
        // it and start training; a relayed delivery spends `delay` slots in
        // flight, so the update is ready that much later (mirrors the
        // engine's `train_duration_slots + delay`)
        for (j, &s) in conn.iter().enumerate() {
            if states[s].has_data && !holds_current[s] {
                holds_current[s] = true;
                base[s] = agg_count as i64;
                pending[s] = true;
                ready[s] = l as i64 + 1 + delay_of(j);
            }
        }
    }
    WindowForecast { aggregations, idle, contacts }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::connectivity::ConnectivitySchedule;

    fn sched3() -> ConnectivitySchedule {
        // the illustrative example's connectivity
        crate::fl::illustrative::example_schedule()
    }

    fn fresh(k: usize) -> Vec<SatForecastState> {
        vec![SatForecastState::fresh(); k]
    }

    #[test]
    fn always_aggregate_equals_async_counts() {
        let s = sched3();
        let f = forecast_window(&s, 0, &vec![true; 9], &fresh(3));
        // must match the illustrative async row: 7 updates, 8 gradients,
        // staleness multiset {0×4, 1×3, 5×1}
        assert_eq!(f.aggregations.len(), 7);
        let all: Vec<usize> = f.aggregations.iter().flatten().copied().collect();
        assert_eq!(all.len(), 8);
        assert_eq!(all.iter().filter(|&&x| x == 0).count(), 4);
        assert_eq!(all.iter().filter(|&&x| x == 1).count(), 3);
        assert_eq!(all.iter().filter(|&&x| x == 5).count(), 1);
    }

    #[test]
    fn never_aggregate_no_aggregations_much_idle() {
        let s = sched3();
        let f = forecast_window(&s, 0, &vec![false; 9], &fresh(3));
        assert!(f.aggregations.is_empty());
        // every repeat contact is idle (first contact trains)
        assert!(f.idle > 0);
    }

    #[test]
    fn pending_state_carries_initial_staleness() {
        let sets = vec![vec![0], vec![]];
        let s = ConnectivitySchedule::from_sets(sets, 1);
        let st = vec![SatForecastState {
            pending: true,
            staleness_now: 3,
            holds_current: false,
            has_data: true,
        }];
        let f = forecast_window(&s, 0, &[true, true], &st);
        assert_eq!(f.aggregations, vec![vec![3]]);
    }

    #[test]
    fn no_data_satellite_always_idle() {
        let sets = vec![vec![0], vec![0]];
        let s = ConnectivitySchedule::from_sets(sets, 1);
        let st = vec![SatForecastState { has_data: false, ..SatForecastState::fresh() }];
        let f = forecast_window(&s, 0, &[true, true], &st);
        assert!(f.aggregations.is_empty());
        assert_eq!(f.idle, 2);
    }

    #[test]
    fn scratch_reuse_is_pure() {
        // repeated calls through one scratch match the allocating path
        let s = sched3();
        let states = fresh(3);
        let mut scratch = ForecastScratch::default();
        for sched_len in [3usize, 9, 5] {
            let cand = vec![true; sched_len];
            let a = forecast_window(&s, 0, &cand, &states);
            let b = forecast_window_with(&mut scratch, &s, 0, &cand, &states);
            assert_eq!(a.aggregations, b.aggregations);
            assert_eq!(a.idle, b.idle);
            assert_eq!(a.contacts, b.contacts);
        }
    }

    /// A hand-built routed view: explicit reach sets, hop counts, and a
    /// per-hop relay latency — what a [`crate::connectivity::ContactGraph`]
    /// or routed window presents to the planner.
    struct RelayView {
        sets: Vec<Vec<usize>>,
        hops: Vec<Vec<u8>>,
        n_sats: usize,
        delay: usize,
    }

    impl StepView for RelayView {
        fn n_sats(&self) -> usize {
            self.n_sats
        }
        fn n_steps(&self) -> usize {
            self.sets.len()
        }
        fn sats_at(&self, i: usize) -> &[usize] {
            &self.sets[i]
        }
        fn hops_at(&self, i: usize) -> &[u8] {
            &self.hops[i]
        }
        fn hop_delay_slots(&self) -> usize {
            self.delay
        }
    }

    fn relay_ring(steps: usize, hops: u8, delay: usize) -> RelayView {
        RelayView {
            sets: vec![vec![0]; steps],
            hops: vec![vec![hops]; steps],
            n_sats: 1,
            delay,
        }
    }

    #[test]
    fn hop_delay_discounts_relayed_contacts() {
        // one satellite reachable every step over a 1-hop relay; with
        // hop_delay = 2 both legs are charged: the broadcast at step 0
        // finishes training at 0 + 1 + 2 = 3, and the upload needs two more
        // slots in flight, so the first aggregation can fire at step 5 —
        // against 7 aggregations when the relay is treated as free
        let free = forecast_window(&relay_ring(8, 1, 0), 0, &vec![true; 8], &fresh(1));
        let slow = forecast_window(&relay_ring(8, 1, 2), 0, &vec![true; 8], &fresh(1));
        assert_eq!(free.aggregations.len(), 7);
        assert_eq!(slow.aggregations.len(), 1);
        // the forecast counts in-flight contacts as idle, like the engine
        assert!(slow.idle > free.idle, "slow={} free={}", slow.idle, free.idle);
    }

    #[test]
    fn zero_hop_contacts_ignore_hop_delay() {
        // direct contacts (hop count 0) must be untouched by any delay —
        // and a routed view with all-zero hops must equal the plain view
        let direct = forecast_window(&relay_ring(8, 0, 5), 0, &vec![true; 8], &fresh(1));
        let sets = vec![vec![0usize]; 8];
        let plain = ConnectivitySchedule::from_sets(sets, 1);
        let legacy = forecast_window(&plain, 0, &vec![true; 8], &fresh(1));
        assert_eq!(direct.aggregations, legacy.aggregations);
        assert_eq!(direct.idle, legacy.idle);
        assert_eq!(direct.contacts, legacy.contacts);
    }

    #[test]
    fn initial_pending_waits_out_its_relay_path() {
        // a pending update at window start over a 2-hop path with delay 1
        // is modelled ready at `start`, so it lands at start + 2
        let v = relay_ring(6, 2, 1);
        let st = vec![SatForecastState {
            pending: true,
            staleness_now: 4,
            holds_current: true,
            has_data: true,
        }];
        let f = forecast_window(&v, 0, &[true, true, true, false, false, false], &st);
        assert_eq!(f.aggregations, vec![vec![4]], "lands at step 2 with its staleness intact");
    }

    #[test]
    fn staleness_grows_with_skipped_uploads() {
        // sat 0 contacts at 0 and 4; sat 1 every slot keeps aggregating
        let sets = vec![vec![0, 1], vec![1], vec![1], vec![1], vec![0, 1]];
        let s = ConnectivitySchedule::from_sets(sets, 2);
        let f = forecast_window(&s, 0, &vec![true; 5], &fresh(2));
        let max = f.aggregations.iter().flatten().max().copied().unwrap();
        assert!(max >= 3, "sat0's update should be stale, got max={max}");
    }
}
