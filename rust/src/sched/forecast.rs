//! Deterministic replay of a candidate aggregation vector over the known
//! future connectivity — computes the staleness vectors s^l (Eq. 9) and
//! idle indicators (Eq. 10) FedSpace's objective needs.
//!
//! This is the paper's key insight made executable: because C is
//! deterministic, the GS can evaluate *exactly* what any schedule would do
//! to every satellite's staleness before committing to it.

use crate::connectivity::StepView;

/// Scheduling-relevant state of one satellite at the window start.
#[derive(Clone, Copy, Debug)]
pub struct SatForecastState {
    /// satellite holds a trained (or in-flight) update not yet uploaded
    pub pending: bool,
    /// staleness its pending update has already accumulated (i_g − i_{g,k})
    pub staleness_now: usize,
    /// satellite holds the current global model version (a contact without
    /// aggregation in between re-sends nothing → idle)
    pub holds_current: bool,
    /// satellite has local data at all (Non-IID may starve some)
    pub has_data: bool,
}

impl SatForecastState {
    /// The cold-start state: no pending update, nothing held, has data.
    pub fn fresh() -> Self {
        SatForecastState { pending: false, staleness_now: 0, holds_current: false, has_data: true }
    }
}

/// Result of replaying one candidate schedule.
#[derive(Clone, Debug)]
pub struct WindowForecast {
    /// for each l with a^l = 1 (in window order): stalenesses of the
    /// gradients that aggregation would consume (the s^l vector's
    /// non-negative entries; absent satellites are the paper's −1 entries)
    pub aggregations: Vec<Vec<usize>>,
    /// idle contacts in the window (connected, nothing new to send)
    pub idle: usize,
    /// total contacts in the window
    pub contacts: usize,
}

/// Reusable per-satellite state buffers for [`forecast_window_with`].
///
/// The scheduler's random search replays thousands of candidate windows per
/// plan; one scratch per search worker means a candidate evaluation
/// allocates nothing K-sized (K = number of satellites).
#[derive(Clone, Debug, Default)]
pub struct ForecastScratch {
    pending: Vec<bool>,
    base: Vec<i64>,
    holds_current: Vec<bool>,
    buffered: Vec<usize>,
}

/// Replay `schedule` (a^{start..start+I0}) over the known connectivity.
///
/// `sched` is any [`StepView`] — the fully materialized schedule or a
/// [`crate::connectivity::WindowView`] lifted out of a stream; the replay
/// only reads the window's steps. `states` is indexed by satellite. The
/// replay uses the same client semantics as the live engine (upload at
/// first contact with a pending update; re-train only on version change;
/// training completes within one slot, matching T0 = 15 min ≫ E local
/// steps).
pub fn forecast_window(
    sched: &dyn StepView,
    start: usize,
    schedule: &[bool],
    states: &[SatForecastState],
) -> WindowForecast {
    forecast_window_with(&mut ForecastScratch::default(), sched, start, schedule, states)
}

/// [`forecast_window`] with caller-owned scratch buffers (hot-path form).
pub fn forecast_window_with(
    scratch: &mut ForecastScratch,
    sched: &dyn StepView,
    start: usize,
    schedule: &[bool],
    states: &[SatForecastState],
) -> WindowForecast {
    let k = sched.n_sats();
    assert_eq!(states.len(), k);
    // relative aggregation counter; pending base expressed in it
    let mut agg_count: usize = 0;
    scratch.pending.clear();
    scratch.pending.extend(states.iter().map(|s| s.pending));
    // staleness of pending update if uploaded after `agg_count` rounds:
    // staleness_now + agg_count − base_offset
    scratch.base.clear();
    scratch.base.extend(states.iter().map(|s| -(s.staleness_now as i64)));
    scratch.holds_current.clear();
    scratch.holds_current.extend(states.iter().map(|s| s.holds_current));
    scratch.buffered.clear();
    let pending = &mut scratch.pending;
    let base = &mut scratch.base;
    let holds_current = &mut scratch.holds_current;
    let buffered = &mut scratch.buffered;
    let mut aggregations = Vec::new();
    let mut idle = 0usize;
    let mut contacts = 0usize;

    let end = (start + schedule.len()).min(sched.n_steps());
    for (w, l) in (start..end).enumerate() {
        let conn = sched.sats_at(l);
        for &s in conn {
            contacts += 1;
            if !states[s].has_data {
                idle += 1;
                continue;
            }
            if pending[s] {
                buffered.push((agg_count as i64 - base[s]) as usize);
                pending[s] = false;
            } else if holds_current[s] {
                idle += 1;
            }
        }
        if schedule[w] && !buffered.is_empty() {
            aggregations.push(std::mem::take(buffered));
            agg_count += 1;
            // everyone's held version is now outdated
            for h in holds_current.iter_mut() {
                *h = false;
            }
        }
        // broadcast: connected sats not holding the current version receive
        // it and start training (update pending by next slot)
        for &s in conn {
            if states[s].has_data && !holds_current[s] {
                holds_current[s] = true;
                base[s] = agg_count as i64;
                pending[s] = true;
            }
        }
    }
    WindowForecast { aggregations, idle, contacts }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::connectivity::ConnectivitySchedule;

    fn sched3() -> ConnectivitySchedule {
        // the illustrative example's connectivity
        crate::fl::illustrative::example_schedule()
    }

    fn fresh(k: usize) -> Vec<SatForecastState> {
        vec![SatForecastState::fresh(); k]
    }

    #[test]
    fn always_aggregate_equals_async_counts() {
        let s = sched3();
        let f = forecast_window(&s, 0, &vec![true; 9], &fresh(3));
        // must match the illustrative async row: 7 updates, 8 gradients,
        // staleness multiset {0×4, 1×3, 5×1}
        assert_eq!(f.aggregations.len(), 7);
        let all: Vec<usize> = f.aggregations.iter().flatten().copied().collect();
        assert_eq!(all.len(), 8);
        assert_eq!(all.iter().filter(|&&x| x == 0).count(), 4);
        assert_eq!(all.iter().filter(|&&x| x == 1).count(), 3);
        assert_eq!(all.iter().filter(|&&x| x == 5).count(), 1);
    }

    #[test]
    fn never_aggregate_no_aggregations_much_idle() {
        let s = sched3();
        let f = forecast_window(&s, 0, &vec![false; 9], &fresh(3));
        assert!(f.aggregations.is_empty());
        // every repeat contact is idle (first contact trains)
        assert!(f.idle > 0);
    }

    #[test]
    fn pending_state_carries_initial_staleness() {
        let sets = vec![vec![0], vec![]];
        let s = ConnectivitySchedule::from_sets(sets, 1);
        let st = vec![SatForecastState {
            pending: true,
            staleness_now: 3,
            holds_current: false,
            has_data: true,
        }];
        let f = forecast_window(&s, 0, &[true, true], &st);
        assert_eq!(f.aggregations, vec![vec![3]]);
    }

    #[test]
    fn no_data_satellite_always_idle() {
        let sets = vec![vec![0], vec![0]];
        let s = ConnectivitySchedule::from_sets(sets, 1);
        let st = vec![SatForecastState { has_data: false, ..SatForecastState::fresh() }];
        let f = forecast_window(&s, 0, &[true, true], &st);
        assert!(f.aggregations.is_empty());
        assert_eq!(f.idle, 2);
    }

    #[test]
    fn scratch_reuse_is_pure() {
        // repeated calls through one scratch match the allocating path
        let s = sched3();
        let states = fresh(3);
        let mut scratch = ForecastScratch::default();
        for sched_len in [3usize, 9, 5] {
            let cand = vec![true; sched_len];
            let a = forecast_window(&s, 0, &cand, &states);
            let b = forecast_window_with(&mut scratch, &s, 0, &cand, &states);
            assert_eq!(a.aggregations, b.aggregations);
            assert_eq!(a.idle, b.idle);
            assert_eq!(a.contacts, b.contacts);
        }
    }

    #[test]
    fn staleness_grows_with_skipped_uploads() {
        // sat 0 contacts at 0 and 4; sat 1 every slot keeps aggregating
        let sets = vec![vec![0, 1], vec![1], vec![1], vec![1], vec![0, 1]];
        let s = ConnectivitySchedule::from_sets(sets, 2);
        let f = forecast_window(&s, 0, &vec![true; 5], &fresh(2));
        let max = f.aggregations.iter().flatten().max().copied().unwrap();
        assert!(max >= 3, "sat0's update should be stale, got max={max}");
    }
}
