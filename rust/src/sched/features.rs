//! Featurization of the utility function's input (s, T) — paper §3.2.
//!
//! The paper feeds the raw K-dimensional staleness vector to the random
//! forest. With K = 191 and a few hundred samples that is needlessly
//! sparse; the staleness vector enters the loss only through how many
//! gradients of each staleness are averaged (Eq. 4 is permutation
//! invariant in k), so we featurize as a staleness *histogram* — a
//! sufficient statistic for Eq. 4 — plus contributor count, mean staleness
//! and the training status T.

/// Staleness values ≥ this are binned together.
pub const S_CAP: usize = 6;

/// Feature vector length.
pub const N_FEATURES: usize = S_CAP + 4;

/// Featurize one aggregation's staleness multiset + training status T.
///
/// Layout: [hist(s=0), …, hist(s=S_CAP−1), hist(s≥S_CAP), n_contributors,
/// mean_staleness, T].
pub fn featurize(stalenesses: &[usize], training_status: f64) -> Vec<f64> {
    let mut f = vec![0.0; N_FEATURES];
    for &s in stalenesses {
        let bin = s.min(S_CAP);
        f[bin] += 1.0;
    }
    let n = stalenesses.len() as f64;
    f[S_CAP + 1] = n;
    f[S_CAP + 2] = if stalenesses.is_empty() {
        0.0
    } else {
        stalenesses.iter().sum::<usize>() as f64 / n
    };
    f[S_CAP + 3] = training_status;
    f
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_and_counts() {
        let f = featurize(&[0, 0, 1, 7, 9], 2.5);
        assert_eq!(f.len(), N_FEATURES);
        assert_eq!(f[0], 2.0); // two s=0
        assert_eq!(f[1], 1.0); // one s=1
        assert_eq!(f[S_CAP], 2.0); // 7 and 9 capped
        assert_eq!(f[S_CAP + 1], 5.0); // contributors
        assert!((f[S_CAP + 2] - 17.0 / 5.0).abs() < 1e-12);
        assert_eq!(f[S_CAP + 3], 2.5);
    }

    #[test]
    fn empty_aggregation() {
        let f = featurize(&[], 1.0);
        assert_eq!(f[S_CAP + 1], 0.0);
        assert_eq!(f[S_CAP + 2], 0.0);
        assert_eq!(f[S_CAP + 3], 1.0);
    }

    #[test]
    fn permutation_invariant() {
        assert_eq!(featurize(&[0, 2, 5], 1.0), featurize(&[5, 0, 2], 1.0));
    }
}
