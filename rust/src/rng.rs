//! Deterministic, splittable PRNG — substrate built from scratch (no `rand`
//! crate in the offline vendor set).
//!
//! Core generator is xoshiro256++ seeded through SplitMix64, which is the
//! standard, well-tested seeding recipe. Every stochastic component of the
//! framework (dataset synthesis, partitioning, parameter init, random search,
//! forest bootstrap) takes an explicit [`Rng`] so whole experiments replay
//! bit-identically from one seed.

/// xoshiro256++ PRNG with SplitMix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal deviate from the polar Box-Muller transform
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare_normal: None,
        }
    }

    /// Derive an independent child stream (e.g. one per satellite).
    pub fn split(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [lo, hi) — unbiased via rejection sampling.
    pub fn gen_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "gen_range: empty range [{lo}, {hi})");
        let span = (hi - lo) as u64;
        // Lemire-style rejection to avoid modulo bias.
        let zone = u64::MAX - (u64::MAX % span);
        loop {
            let v = self.next_u64();
            if v < zone {
                return lo + (v % span) as usize;
            }
        }
    }

    /// Uniform f64 in [lo, hi).
    pub fn gen_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Bernoulli draw.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal deviate (polar Box-Muller, with spare caching).
    pub fn next_normal(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.spare_normal = Some(v * f);
                return u * f;
            }
        }
    }

    /// Normal deviate with given mean / std, as f32.
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.next_normal() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "choose_k: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.gen_range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Weighted index draw proportional to non-negative `weights`.
    pub fn choose_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "choose_weighted: zero total weight");
        let mut t = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn split_streams_are_independent_and_deterministic() {
        let mut root1 = Rng::new(7);
        let mut root2 = Rng::new(7);
        let mut c1 = root1.split(3);
        let mut c2 = root2.split(3);
        assert_eq!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(5);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.gen_range(3, 10);
            assert!((3..10).contains(&v));
            seen[v - 3] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(17);
        for _ in 0..50 {
            let k = r.gen_range(0, 20);
            let sel = r.choose_k(30, k);
            assert_eq!(sel.len(), k);
            let mut s = sel.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), k, "duplicates in {sel:?}");
        }
    }

    #[test]
    fn choose_weighted_respects_weights() {
        let mut r = Rng::new(19);
        let w = [0.0, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..20_000 {
            counts[r.choose_weighted(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }
}
