//! `fedspace` — the Layer-3 coordinator CLI / launcher.
//!
//! Subcommands:
//!   connectivity  compute the constellation connectivity (Figure 2 data)
//!   illustrative  run the 3-satellite example (Figures 3-4, Table 1)
//!   train         run one FL experiment (mock or full PJRT backend)
//!   scenarios     list/describe/run the named scenario registry
//!   serve         drive the serving front end over a scenario trace, paced
//!   loadgen       replay a scenario trace at full speed; report throughput
//!   utility       generate utility samples and fit/report the regressor
//!   schedule      plan one FedSpace window and print the forecast
//!   lint          static-check the determinism contract over the sources
//!   bench-check   compare bench JSON against the committed baseline (CI)
//!   bench-baseline  merge bench JSON into a ready-to-commit baseline (CI)
//!   help          this text

use anyhow::{bail, Result};
use fedspace::app::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    match args.command.as_str() {
        "connectivity" => fedspace::app::cmd::connectivity(&args),
        "illustrative" => fedspace::app::cmd::illustrative(&args),
        "train" => fedspace::app::cmd::train(&args),
        "scenarios" => fedspace::app::cmd::scenarios(&args),
        "serve" => fedspace::app::cmd::serve(&args),
        "loadgen" => fedspace::app::cmd::loadgen(&args),
        "utility" => fedspace::app::cmd::utility(&args),
        "schedule" => fedspace::app::cmd::schedule(&args),
        "lint" => fedspace::app::cmd::lint(&args),
        "bench-check" => fedspace::app::cmd::bench_check(&args),
        "bench-baseline" => fedspace::app::cmd::bench_baseline(&args),
        "" | "help" | "--help" | "-h" => {
            print!("{}", fedspace::app::cmd::HELP);
            Ok(())
        }
        other => bail!("unknown command {other:?} — try `fedspace help`"),
    }
}
