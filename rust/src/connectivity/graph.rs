//! Per-step contact *graphs*: bounded-hop ISL routing on top of the
//! satellite⇄station contact sets (ADR-0005).
//!
//! PR 3's connectivity is a per-step *set* C_i ⊆ sats; with inter-satellite
//! links it becomes a graph whose useful projection for the FL layer is the
//! **reachability relation**: satellite k is reachable at step i when an
//! ISL path of at most `max_hops` hops ends at a ground-visible sink
//! satellite (hop 0 = k itself is in C_i). [`IslTopology::route_step`]
//! computes that relation with one breadth-first search per step, sourced
//! at the direct contacts, expanding over the static intra-plane rings and
//! the range-gated adjacent-plane candidates of
//! [`crate::orbit::IslGeometry`], and skipping satellites silenced by a
//! downtime window (a powered-off satellite neither uploads nor relays).
//!
//! Determinism mirrors the streamed-connectivity discipline (ADR-0004):
//! the cross-plane range gate samples positions at the window midpoint
//! `(i + 0.5)·T0` derived from the **absolute** step index, and the BFS
//! visits in ascending-id frontier order — so the dense whole-horizon
//! [`ContactGraph`] and the per-chunk routing inside
//! [`crate::connectivity::ScheduleChunk`] produce bit-identical reach sets
//! and hop counts, which the engine-mode bit-identity tests rely on.
//!
//! What the rest of the stack sees:
//! - the engine walks `(reach set, hop counts)` per step and charges
//!   `hops × hop_delay_slots` of relay latency on both the upload and the
//!   broadcast leg (`sim::engine`), attributing uploads to their *origin*
//!   satellite so staleness is measured from local train time;
//! - the scheduler sees reachability through [`StepView`] — a
//!   [`ContactGraph`] (dense modes) or a routed
//!   [`crate::connectivity::WindowView`] (streamed mode) — so
//!   forecast/search/planner count a relayed satellite as connected
//!   without any code change of their own.

use super::schedule::{ConnectivitySchedule, StepView};
use crate::orbit::{Constellation, IslGeometry, Vec3};
use anyhow::{ensure, Result};

/// Resolved ISL routing parameters (the connectivity-layer mirror of
/// `cfg::IslSpec`, which cannot be imported here without a cycle).
#[derive(Clone, Copy, Debug)]
pub struct IslParams {
    /// Maximum relay hops from a satellite to its ground-visible sink.
    pub max_hops: usize,
    /// Relay latency charged per hop, in engine slots.
    pub hop_delay_slots: usize,
    /// Maintain range-gated adjacent-plane links in addition to the rings.
    pub cross_plane: bool,
    /// Cross-plane links switch on only within this slant range [m].
    pub max_range_m: f64,
    /// Wall-clock seconds per time index (for the range-gate sample time).
    pub t0_s: f64,
}

/// Recycled working memory of [`IslTopology::route_step`]: per-satellite
/// hop distances, the BFS frontier, and the per-step position table.
#[derive(Clone, Debug, Default)]
pub struct RouteScratch {
    dist: Vec<u8>,
    queue: Vec<usize>,
    pos: Vec<Vec3>,
}

/// A constellation's ISL routing model: link-candidate geometry plus the
/// routing bounds and the downtime windows that silence relays.
#[derive(Clone, Debug)]
pub struct IslTopology {
    geo: IslGeometry,
    /// Maximum relay hops (reach entries never exceed this).
    pub max_hops: usize,
    /// Relay latency charged per hop, in engine slots.
    pub hop_delay_slots: usize,
    cross_plane: bool,
    max_range_m: f64,
    t0_s: f64,
    /// Downtime windows indexed by satellite: `(from_step, until_step)`,
    /// half-open — mirrors `ConnectivityStream`'s per-chunk filter.
    down_by_sat: Vec<Vec<(usize, usize)>>,
}

impl IslTopology {
    /// Build the routing model for a constellation (downtime windows are
    /// taken from the constellation itself, like the streamed path does).
    pub fn new(constellation: &Constellation, params: IslParams) -> Result<Self> {
        ensure!(params.max_hops >= 1, "ISL routing needs max_hops >= 1");
        ensure!(params.max_hops <= u8::MAX as usize, "max_hops must fit a u8 hop counter");
        let geo = IslGeometry::new(constellation)?;
        let mut down_by_sat = vec![Vec::new(); constellation.len()];
        for w in &constellation.downtime {
            down_by_sat[w.sat].push((w.from_step, w.until_step));
        }
        Ok(IslTopology {
            geo,
            max_hops: params.max_hops,
            hop_delay_slots: params.hop_delay_slots,
            cross_plane: params.cross_plane,
            max_range_m: params.max_range_m,
            t0_s: params.t0_s,
            down_by_sat,
        })
    }

    /// Number of satellites the topology covers.
    pub fn n_sats(&self) -> usize {
        self.geo.n_sats()
    }

    /// Is satellite `k` silenced by a downtime window at step `i`?
    fn down(&self, k: usize, i: usize) -> bool {
        self.down_by_sat[k].iter().any(|&(from, until)| (from..until).contains(&i))
    }

    /// Range-gate sample instant of step `i`: the window midpoint, derived
    /// from the absolute index so dense and chunked routing agree exactly.
    fn sample_time(&self, i: usize) -> f64 {
        (i as f64 + 0.5) * self.t0_s
    }

    /// Is the ISL between `a` and `b` up at step `i`? True for ring
    /// neighbors and for in-range adjacent-plane candidates, with both
    /// endpoints alive. Symmetric by construction (tested).
    pub fn is_linked(&self, a: usize, b: usize, i: usize) -> bool {
        let n = self.n_sats();
        if a == b || a >= n || b >= n || self.down(a, i) || self.down(b, i) {
            return false;
        }
        if self.geo.ring_neighbors(a).contains(&b) {
            return true;
        }
        if self.cross_plane && self.geo.cross_candidates(a).contains(&b) {
            let t = self.sample_time(i);
            let d = self.geo.position_at(a, t).sub(&self.geo.position_at(b, t)).norm();
            return d <= self.max_range_m;
        }
        false
    }

    /// Compute the reach set of step `i`: `out_sats` gets the reachable
    /// satellite ids ascending, `out_hops` the parallel minimal hop counts
    /// (0 ⇔ the satellite is in `direct`). `direct` must be the step's
    /// ground-contact set, sorted ascending, already downtime-filtered.
    pub fn route_step(
        &self,
        i: usize,
        direct: &[usize],
        scratch: &mut RouteScratch,
        out_sats: &mut Vec<usize>,
        out_hops: &mut Vec<u8>,
    ) {
        out_sats.clear();
        out_hops.clear();
        if direct.is_empty() {
            // relays need a ground-visible sink: nobody visible, nobody reachable
            return;
        }
        let k = self.n_sats();
        scratch.dist.clear();
        scratch.dist.resize(k, u8::MAX);
        scratch.queue.clear();
        if self.cross_plane {
            self.geo.positions_at(self.sample_time(i), &mut scratch.pos);
        }
        for &s in direct {
            scratch.dist[s] = 0;
            scratch.queue.push(s);
        }
        let mut head = 0usize;
        while head < scratch.queue.len() {
            let u = scratch.queue[head];
            head += 1;
            let d = scratch.dist[u];
            if d as usize >= self.max_hops {
                continue;
            }
            for &v in self.geo.ring_neighbors(u) {
                if scratch.dist[v] == u8::MAX && !self.down(v, i) {
                    scratch.dist[v] = d + 1;
                    scratch.queue.push(v);
                }
            }
            if self.cross_plane {
                for &v in self.geo.cross_candidates(u) {
                    if scratch.dist[v] != u8::MAX
                        || scratch.pos[u].sub(&scratch.pos[v]).norm() > self.max_range_m
                    {
                        continue;
                    }
                    if !self.down(v, i) {
                        scratch.dist[v] = d + 1;
                        scratch.queue.push(v);
                    }
                }
            }
        }
        for (s, &d) in scratch.dist.iter().enumerate() {
            if d != u8::MAX {
                out_sats.push(s);
                out_hops.push(d);
            }
        }
    }
}

/// The whole-horizon routed relation, materialized: per step the reachable
/// satellites (ascending) with their minimal hop counts, plus the event
/// list the contact-list engine walks. The routed counterpart of
/// [`ConnectivitySchedule`] for the precomputed engine modes; streamed mode
/// routes chunk by chunk instead ([`crate::connectivity::ScheduleChunk`]).
#[derive(Clone, Debug)]
pub struct ContactGraph {
    /// sets[i] = reachable satellite ids at step i, ascending.
    sets: Vec<Vec<usize>>,
    /// hops[i] = minimal hop counts parallel to `sets[i]` (0 = direct).
    hops: Vec<Vec<u8>>,
    /// Steps with at least one reachable satellite, ascending.
    active: Vec<usize>,
    n_sats: usize,
    /// Relay latency the engine charges per hop, in slots (copied from the
    /// topology so the graph is self-contained).
    pub hop_delay_slots: usize,
}

impl ContactGraph {
    /// Route every step of a materialized schedule through the topology.
    pub fn build(topology: &IslTopology, sched: &ConnectivitySchedule) -> Self {
        assert_eq!(
            topology.n_sats(),
            sched.n_sats,
            "topology covers {} satellites but the schedule covers {}",
            topology.n_sats(),
            sched.n_sats
        );
        let n_steps = sched.n_steps();
        let mut scratch = RouteScratch::default();
        let mut sets = Vec::with_capacity(n_steps);
        let mut hops = Vec::with_capacity(n_steps);
        let mut active = Vec::new();
        for i in 0..n_steps {
            let mut s = Vec::new();
            let mut h = Vec::new();
            topology.route_step(i, sched.sats_at(i), &mut scratch, &mut s, &mut h);
            if !s.is_empty() {
                active.push(i);
            }
            sets.push(s);
            hops.push(h);
        }
        ContactGraph {
            sets,
            hops,
            active,
            n_sats: sched.n_sats,
            hop_delay_slots: topology.hop_delay_slots,
        }
    }

    /// Number of satellites the graph covers.
    pub fn n_sats(&self) -> usize {
        self.n_sats
    }

    /// Number of time indexes the graph covers.
    pub fn n_steps(&self) -> usize {
        self.sets.len()
    }

    /// Reachable satellites at step `i`, ascending (zero-copy).
    pub fn sats_at(&self, i: usize) -> &[usize] {
        &self.sets[i]
    }

    /// Minimal hop counts parallel to [`Self::sats_at`] (0 = direct).
    pub fn hops_at(&self, i: usize) -> &[u8] {
        &self.hops[i]
    }

    /// Steps with at least one reachable satellite, ascending — the event
    /// list for the contact-list engine mode.
    pub fn active_steps(&self) -> &[usize] {
        &self.active
    }
}

impl StepView for ContactGraph {
    fn n_sats(&self) -> usize {
        self.n_sats
    }

    fn n_steps(&self) -> usize {
        ContactGraph::n_steps(self)
    }

    fn sats_at(&self, i: usize) -> &[usize] {
        ContactGraph::sats_at(self, i)
    }

    fn hops_at(&self, i: usize) -> &[u8] {
        ContactGraph::hops_at(self, i)
    }

    fn hop_delay_slots(&self) -> usize {
        self.hop_delay_slots
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orbit::{DowntimeWindow, WalkerPattern, WalkerSpec};

    /// A single 5-satellite plane: ring 0-1-2-3-4-0.
    fn ring5() -> Constellation {
        Constellation::walker(&WalkerSpec {
            pattern: WalkerPattern::Delta,
            n_sats: 5,
            planes: 1,
            phasing: 0,
            alt_m: 550e3,
            inc_deg: 53.0,
        })
    }

    fn intra_params(max_hops: usize) -> IslParams {
        IslParams {
            max_hops,
            hop_delay_slots: 0,
            cross_plane: false,
            max_range_m: 0.0,
            t0_s: 900.0,
        }
    }

    #[test]
    fn ring_bfs_finds_minimal_hops() {
        let c = ring5();
        let topo = IslTopology::new(&c, intra_params(2)).unwrap();
        let sched = ConnectivitySchedule::from_sets(vec![vec![0]], 5);
        let g = ContactGraph::build(&topo, &sched);
        // walker single plane: phase order is id order, so the ring is
        // 0-1-2-3-4-0 and hops from {0} are [0, 1, 2, 2, 1]
        assert_eq!(g.sats_at(0), &[0, 1, 2, 3, 4]);
        assert_eq!(g.hops_at(0), &[0, 1, 2, 2, 1]);
    }

    #[test]
    fn hop_bound_truncates_the_ring() {
        let c = ring5();
        let topo = IslTopology::new(&c, intra_params(1)).unwrap();
        let sched = ConnectivitySchedule::from_sets(vec![vec![0]], 5);
        let g = ContactGraph::build(&topo, &sched);
        assert_eq!(g.sats_at(0), &[0, 1, 4]);
        assert_eq!(g.hops_at(0), &[0, 1, 1]);
    }

    #[test]
    fn no_ground_contact_means_no_reach() {
        let c = ring5();
        let topo = IslTopology::new(&c, intra_params(3)).unwrap();
        let sched = ConnectivitySchedule::from_sets(vec![vec![], vec![2]], 5);
        let g = ContactGraph::build(&topo, &sched);
        assert!(g.sats_at(0).is_empty());
        assert!(g.hops_at(0).is_empty());
        assert_eq!(g.active_steps(), &[1]);
    }

    #[test]
    fn downed_satellite_neither_relays_nor_appears() {
        let c = ring5().with_downtime(vec![DowntimeWindow {
            sat: 1,
            from_step: 0,
            until_step: 1,
        }]);
        let topo = IslTopology::new(&c, intra_params(2)).unwrap();
        // direct sets are already downtime-filtered by the schedule layer
        let sched = ConnectivitySchedule::from_sets(vec![vec![0], vec![0]], 5);
        let g = ContactGraph::build(&topo, &sched);
        // step 0: sat 1 down — the clockwise arm stops, counter-clockwise
        // still reaches 4 (1 hop) and 3 (2 hops)
        assert_eq!(g.sats_at(0), &[0, 3, 4]);
        assert_eq!(g.hops_at(0), &[0, 2, 1]);
        // step 1: sat 1 recovered, full reach again
        assert_eq!(g.sats_at(1), &[0, 1, 2, 3, 4]);
    }

    #[test]
    fn multiple_sinks_take_the_nearer_one() {
        let c = ring5();
        let topo = IslTopology::new(&c, intra_params(2)).unwrap();
        let sched = ConnectivitySchedule::from_sets(vec![vec![0, 2]], 5);
        let g = ContactGraph::build(&topo, &sched);
        assert_eq!(g.sats_at(0), &[0, 1, 2, 3, 4]);
        // sat 1 and 3 are one hop from a sink either way; 4 is 1 from 0
        assert_eq!(g.hops_at(0), &[0, 1, 0, 1, 1]);
    }

    #[test]
    fn cross_plane_range_gate_is_symmetric_and_effective() {
        let c = Constellation::walker(&WalkerSpec {
            pattern: WalkerPattern::Star,
            n_sats: 12,
            planes: 3,
            phasing: 1,
            alt_m: 780e3,
            inc_deg: 86.4,
        });
        let loose = IslTopology::new(
            &c,
            IslParams {
                max_hops: 2,
                hop_delay_slots: 0,
                cross_plane: true,
                max_range_m: 1e9,
                t0_s: 900.0,
            },
        )
        .unwrap();
        let tight = IslTopology::new(
            &c,
            IslParams {
                max_hops: 2,
                hop_delay_slots: 0,
                cross_plane: true,
                max_range_m: 1.0,
                t0_s: 900.0,
            },
        )
        .unwrap();
        let mut n_loose = 0usize;
        let mut n_tight = 0usize;
        for i in [0usize, 5, 11] {
            for a in 0..12 {
                for b in 0..12 {
                    assert_eq!(loose.is_linked(a, b, i), loose.is_linked(b, a, i));
                    assert_eq!(tight.is_linked(a, b, i), tight.is_linked(b, a, i));
                    n_loose += loose.is_linked(a, b, i) as usize;
                    n_tight += tight.is_linked(a, b, i) as usize;
                }
            }
        }
        // an effectively-infinite range admits every candidate; a 1-metre
        // range reduces to the rings alone
        assert!(n_loose > n_tight);
        assert!(n_tight > 0, "rings survive any range gate");
    }

    #[test]
    fn max_hops_zero_is_rejected() {
        assert!(IslTopology::new(&ring5(), intra_params(0)).is_err());
    }
}
