//! Connectivity sets C_i (paper Eq. 2) and their statistics (Figure 2).
//!
//! The GS treats all ground stations as one logical FL server: satellite k
//! is *connected* at time index i if a link to **any** station is feasible
//! during the window [i·T0, (i+1)·T0). Because orbits and Earth rotation are
//! deterministic, the whole schedule C = {C_0, C_1, ...} is computable ahead
//! of time — the key property FedSpace exploits (§3.1).

pub mod schedule;
pub mod stats;

pub use schedule::{ConnectivityParams, ConnectivitySchedule};
pub use stats::{contacts_per_day, set_sizes, ConnectivityStats};
