//! Connectivity sets C_i (paper Eq. 2) and their statistics (Figure 2).
//!
//! The GS treats all ground stations as one logical FL server: satellite k
//! is *connected* at time index i if a link to **any** station is feasible
//! during the window [i·T0, (i+1)·T0). Because orbits and Earth rotation are
//! deterministic, the whole schedule C = {C_0, C_1, ...} is computable ahead
//! of time — the key property FedSpace exploits (§3.1).
//!
//! Two materializations of the same relation: [`ConnectivitySchedule`]
//! computes the whole horizon at once (the paper-scale default), while
//! [`ConnectivityStream`] yields it in fixed-size, recyclable time-chunks
//! so mega-constellation horizons never reside in memory at once
//! (ADR-0004). Planning code is written against the [`StepView`] trait and
//! works over either.
//!
//! [`graph`] lifts the per-step *sets* to per-step *graphs* (ADR-0005):
//! with inter-satellite links enabled, [`IslTopology`] routes every step's
//! direct contacts over bounded-hop ISL paths and [`ContactGraph`] (dense)
//! or the routed chunks/windows (streamed) present the resulting
//! reachability relation through the same [`StepView`] surface.

pub mod graph;
pub mod schedule;
pub mod stats;
pub mod stream;

pub use graph::{ContactGraph, IslParams, IslTopology, RouteScratch};
pub use schedule::{ConnectivityParams, ConnectivitySchedule, StepView, SweepOutput, SweepRecord};
pub use stats::{contacts_per_day, set_sizes, ConnectivityStats};
pub use stream::{ConnectivityStream, ScheduleChunk, StreamCursor, WindowView};
