//! Computation and storage of the connectivity schedule C.
//!
//! `compute` is an L3 hot path: the paper's default scenario is 191
//! satellites × 96 slots × 10 sub-samples × 12 stations ≈ 2.2M visibility
//! tests, and the ROADMAP's production scenarios push to 1000+ satellites ×
//! multi-week horizons. The optimized pipeline (see EXPERIMENTS.md §Perf):
//!
//! 1. ground-station ECEF positions/up-vectors cached once per call
//!    ([`crate::orbit::StationFrame`]) instead of re-derived per test;
//! 2. the GMST rotation computed once per sample timestamp and shared
//!    across all satellites and stations;
//! 3. per-satellite orbital propagation hoisted to an
//!    [`crate::orbit::OrbitBasis`] (one `sin_cos` per sample);
//! 4. elevation compared in sin space against a precomputed sin(α_min) —
//!    no `asin`/`to_degrees` in the inner loop — with a horizon-plane
//!    dot-product prefilter rejecting below-horizon stations early;
//! 5. the outer satellite loop parallelized on [`crate::exec::global_pool`]
//!    (results are per-satellite and collected in input order, so the
//!    output is identical at any thread count).
//!
//! [`ConnectivitySchedule::compute_reference`] keeps the original
//! trig-heavy serial implementation as the correctness oracle and the
//! `bench_perf` baseline.

use crate::exec;
use crate::orbit::{
    station_frames, Constellation, DowntimeWindow, GroundStation, OrbitBasis, StationFrame,
};
use std::sync::Arc;

/// Read-only per-step view of a connectivity relation — the subset of
/// [`ConnectivitySchedule`]'s surface the forecast/search pipeline needs.
///
/// Implemented by the fully materialized [`ConnectivitySchedule`] and by
/// [`crate::connectivity::WindowView`], a planning window materialized on
/// demand from a [`crate::connectivity::ConnectivityStream`] — so the
/// FedSpace planner never requires the whole horizon in memory.
///
/// `Sync` is a supertrait because candidate scoring shares one view across
/// the search workers ([`crate::exec::scope_chunks`]).
pub trait StepView: Sync {
    /// Number of satellites the relation covers (ids `0..n_sats`).
    fn n_sats(&self) -> usize;
    /// Total number of time steps of the underlying horizon (not of the
    /// materialized slice — forecast end-clamping needs the global value).
    fn n_steps(&self) -> usize;
    /// Satellites connected at absolute time index `i`, ascending.
    ///
    /// Implementations may cover only a sub-range of `0..n_steps()` and
    /// panic outside it (the window views do); callers stay within the
    /// range they materialized.
    fn sats_at(&self, i: usize) -> &[usize];

    /// The routing view (ADR-0005): minimal ISL hop counts parallel to
    /// [`Self::sats_at`] — entry j is how many relay hops satellite
    /// `sats_at(i)[j]` needs to reach a ground-visible sink (0 = direct
    /// contact). The default empty slice means "all direct": plain
    /// schedules carry no ISLs, so every connected satellite is a sink.
    /// Overridden by [`crate::connectivity::ContactGraph`] and by routed
    /// [`crate::connectivity::WindowView`]s.
    fn hops_at(&self, _i: usize) -> &[u8] {
        &[]
    }

    /// Relay latency charged per ISL hop, in engine slots (ADR-0005/0006).
    /// The default 0 matches plain schedules (no ISLs ⇒ no relay latency);
    /// routed views ([`crate::connectivity::ContactGraph`], routed
    /// [`crate::connectivity::WindowView`]s) override it so the forecast
    /// can discount relayed contacts by `hops × hop_delay_slots` instead of
    /// treating them as direct.
    fn hop_delay_slots(&self) -> usize {
        0
    }

    /// Contact durations parallel to [`Self::sats_at`] (ADR-0008): entry j
    /// is how many of the window's sub-samples satellite `sats_at(i)[j]`
    /// was actually visible for, i.e. the pass spans
    /// `durations_at(i)[j] / duration_denom()` of the slot. The default
    /// empty slice means "full slot" — views that never computed durations
    /// charge every contact the whole slot's byte budget, which is exactly
    /// the capacity-off behaviour. Overridden by schedules/windows built
    /// with durations.
    fn durations_at(&self, _i: usize) -> &[u16] {
        &[]
    }

    /// Denominator of [`Self::durations_at`] fractions (the window's
    /// sub-sample count). 1 when durations are not computed.
    fn duration_denom(&self) -> u16 {
        1
    }
}

/// Parameters of the link model (paper §2.2 / §4.1 defaults).
#[derive(Clone, Debug)]
pub struct ConnectivityParams {
    /// Wall-clock seconds between adjacent time indexes (paper: 15 min).
    pub t0_s: f64,
    /// Minimum elevation angle α_min [deg].
    pub min_elev_deg: f64,
    /// Sub-samples per window when testing feasibility.
    pub samples_per_window: usize,
    /// Fraction of sub-samples that must be feasible for the window to
    /// count as connected. The paper's "feasible for all t" read literally
    /// would require a full 15-min pass (longer than any LEO pass); the
    /// defaults (25° operational mask, ≥30% of the window ≈ a ≥4.5-min
    /// downlink session) calibrate the schedule to the paper's Figure 2
    /// statistics: min |C_i| = 4 (exact) and n_k ∈ [1, 20] per day vs the
    /// paper's [5, 19] — see EXPERIMENTS.md §Fig2.
    pub min_feasible_frac: f64,
}

impl Default for ConnectivityParams {
    fn default() -> Self {
        ConnectivityParams {
            t0_s: 15.0 * 60.0,
            min_elev_deg: 25.0,
            samples_per_window: 10,
            min_feasible_frac: 0.3,
        }
    }
}

/// The deterministic schedule C = {C_0, ..., C_{n-1}} plus fast lookups.
///
/// Three synchronized views of the same relation:
/// - `sets[i]` — sorted satellite ids in C_i (window iteration);
/// - `contacts[k]` — sorted time indexes of satellite k (staleness lookups);
/// - a packed per-step bitset (`n_steps × words_per_step` u64 words) making
///   [`Self::connected`] a single word probe instead of a binary search.
///
/// The bitset is derived from `sets` at construction; mutating the public
/// vectors directly would desynchronize it — build a new schedule via
/// [`Self::from_sets`] instead.
#[derive(Clone, Debug)]
pub struct ConnectivitySchedule {
    /// sets[i] = sorted satellite ids in C_i.
    pub sets: Vec<Vec<usize>>,
    /// contacts[k] = sorted time indexes at which satellite k is connected.
    pub contacts: Vec<Vec<usize>>,
    /// Number of satellites the schedule covers (ids 0..n_sats).
    pub n_sats: usize,
    /// Link-model parameters the schedule was computed with.
    pub params: ConnectivityParams,
    /// u64 words per time step in `bits`.
    words_per_step: usize,
    /// Packed connectivity: bit k of step i lives at
    /// bits[i * words_per_step + k/64] >> (k % 64).
    bits: Vec<u64>,
    /// Per-step feasible-sample counts parallel to `sets` (ADR-0008):
    /// durs[i][j] is how many sub-samples satellite `sets[i][j]` was
    /// visible for — the contact spans `durs[i][j] / samples_per_window`
    /// of the slot. Empty when durations were not computed (capacity-off
    /// runs and plain [`Self::compute`]), meaning "full slot".
    durs: Vec<Vec<u16>>,
}

/// What the one-pass visibility sweep records beyond contact membership
/// (see [`ConnectivitySchedule::compute_sweep`]). The default records
/// nothing extra — the plain [`ConnectivitySchedule::compute`] semantics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SweepRecord {
    /// Record per-contact pass durations (ADR-0008 byte budgets).
    pub durations: bool,
    /// Record per-contact lowest-visible-station attribution (ADR-0006
    /// multi-gateway upload routing).
    pub attribution: bool,
}

/// Output of [`ConnectivitySchedule::compute_sweep`].
#[derive(Clone, Debug)]
pub struct SweepOutput {
    /// The computed schedule, durations attached iff they were recorded.
    pub schedule: ConnectivitySchedule,
    /// `attribution[i][j]` = lowest-indexed station that heard satellite
    /// `schedule.sets[i][j]` at step `i`; `Some` iff attribution was
    /// recorded.
    pub attribution: Option<Vec<Vec<u16>>>,
}

impl ConnectivitySchedule {
    /// Compute C for `n_steps` windows from a constellation + station list.
    ///
    /// Runs the optimized pipeline described in the module docs. The result
    /// is independent of the thread count, and agrees with
    /// [`Self::compute_reference`] up to floating-point ties exactly at the
    /// elevation threshold (the sin-space test rounds differently from the
    /// reference's `asin` path; tests assert agreement with a tiny
    /// tie-budget rather than bit-exactness).
    pub fn compute(
        constellation: &Constellation,
        stations: &[GroundStation],
        n_steps: usize,
        params: ConnectivityParams,
    ) -> Self {
        Self::compute_sweep(constellation, stations, n_steps, params, SweepRecord::default())
            .schedule
    }

    /// The unified one-pass visibility sweep every dense compute goes
    /// through: membership always, plus whatever `record` asks for —
    /// per-contact pass durations (ADR-0008) and/or per-contact station
    /// attribution (ADR-0006, the upload-routing primitive). Membership is
    /// identical for every `record` combination (the extra bookkeeping
    /// never changes the ≥-`need` admission decision), so
    /// [`Self::compute`] and [`Self::compute_with_durations`] are thin
    /// wrappers over this, and the multi-gateway precompute
    /// (`UploadRouting::build_with_schedule`) fuses its attribution sweep
    /// into the same pass instead of sampling the horizon twice.
    pub fn compute_sweep(
        constellation: &Constellation,
        stations: &[GroundStation],
        n_steps: usize,
        params: ConnectivityParams,
        record: SweepRecord,
    ) -> SweepOutput {
        let n_sats = constellation.len();
        let need = feasible_need(&params);
        let spw = params.samples_per_window;
        let sin_min = params.min_elev_deg.to_radians().sin();
        let frames: Arc<Vec<StationFrame>> = Arc::new(station_frames(stations));
        let rots: Arc<Vec<SampleRot>> =
            Arc::new(sample_rotations_range(0, n_steps, spw, params.t0_s));
        let bases: Vec<OrbitBasis> = constellation.orbits.iter().map(|o| o.basis()).collect();
        let pool = exec::global_pool();

        if record == SweepRecord::default() {
            // membership-only fast path: keeps the early exit at `need`
            let contacts: Vec<Vec<usize>> = if n_sats > 1 && pool.size() > 1 {
                let frames = Arc::clone(&frames);
                let rots = Arc::clone(&rots);
                pool.scope_map(bases, move |basis| {
                    sat_contacts(&basis, &frames, &rots, 0, n_steps, spw, sin_min, need)
                })
            } else {
                bases
                    .iter()
                    .map(|basis| {
                        sat_contacts(basis, &frames, &rots, 0, n_steps, spw, sin_min, need)
                    })
                    .collect()
            };
            let mut sets = vec![Vec::new(); n_steps];
            for (k, cs) in contacts.iter().enumerate() {
                for &i in cs {
                    sets[i].push(k); // k ascends, so each set stays sorted
                }
            }
            let schedule = Self::assemble(sets, contacts, n_sats, params);
            return SweepOutput { schedule, attribution: None };
        }

        let per_sat: Vec<Vec<(usize, u16, u16)>> = if n_sats > 1 && pool.size() > 1 {
            let frames = Arc::clone(&frames);
            let rots = Arc::clone(&rots);
            pool.scope_map(bases, move |basis| {
                sat_sweep(&basis, &frames, &rots, 0, n_steps, spw, sin_min, need)
            })
        } else {
            bases
                .iter()
                .map(|basis| sat_sweep(basis, &frames, &rots, 0, n_steps, spw, sin_min, need))
                .collect()
        };

        let mut sets = vec![Vec::new(); n_steps];
        let mut durs = vec![Vec::new(); n_steps];
        let mut attr = vec![Vec::new(); n_steps];
        let mut contacts = vec![Vec::new(); n_sats];
        for (k, windows) in per_sat.iter().enumerate() {
            for &(i, dur, st) in windows {
                sets[i].push(k); // k ascends, so each set stays sorted
                durs[i].push(dur);
                attr[i].push(st);
                contacts[k].push(i);
            }
        }
        let mut schedule = Self::assemble(sets, contacts, n_sats, params);
        if record.durations {
            schedule.durs = durs;
        }
        SweepOutput { schedule, attribution: record.attribution.then_some(attr) }
    }

    /// The original (pre-optimization) serial implementation: per-test
    /// geodetic trig, per-station GMST rotations, asin-space elevation.
    /// Kept as the correctness oracle for [`Self::compute`] and as the
    /// single-thread baseline in `bench_perf` / EXPERIMENTS.md §Perf.
    pub fn compute_reference(
        constellation: &Constellation,
        stations: &[GroundStation],
        n_steps: usize,
        params: ConnectivityParams,
    ) -> Self {
        use crate::orbit::is_visible;
        let n_sats = constellation.len();
        let mut sets = vec![Vec::new(); n_steps];
        let mut contacts = vec![Vec::new(); n_sats];
        let need = feasible_need(&params);
        for (k, orbit) in constellation.orbits.iter().enumerate() {
            for (i, set) in sets.iter_mut().enumerate() {
                let t_start = i as f64 * params.t0_s;
                let mut feasible = 0usize;
                'window: for s in 0..params.samples_per_window {
                    let t = t_start
                        + params.t0_s * (s as f64 + 0.5) / params.samples_per_window as f64;
                    let p = orbit.position_eci(t);
                    for gs in stations {
                        if is_visible(&p, t, gs, params.min_elev_deg) {
                            feasible += 1;
                            if feasible >= need {
                                break 'window;
                            }
                            break; // any station suffices for this sample
                        }
                    }
                }
                if feasible >= need {
                    set.push(k);
                    contacts[k].push(i);
                }
            }
        }
        Self::assemble(sets, contacts, n_sats, params)
    }

    /// Build directly from explicit sets (tests, illustrative example).
    pub fn from_sets(sets: Vec<Vec<usize>>, n_sats: usize) -> Self {
        Self::from_sets_with_params(sets, n_sats, ConnectivityParams::default())
    }

    /// [`Self::from_sets`] keeping the given link-model parameters — used by
    /// the derived-schedule constructors (`with_dropout`, `with_downtime`)
    /// and by [`crate::connectivity::ConnectivityStream::collect_dense`] so
    /// the documented `params` field stays authoritative for them.
    pub(crate) fn from_sets_with_params(
        sets: Vec<Vec<usize>>,
        n_sats: usize,
        params: ConnectivityParams,
    ) -> Self {
        let mut contacts = vec![Vec::new(); n_sats];
        for (i, set) in sets.iter().enumerate() {
            for &k in set {
                assert!(k < n_sats, "satellite id {k} out of range");
                contacts[k].push(i);
            }
        }
        Self::assemble(sets, contacts, n_sats, params)
    }

    /// Finish construction: derive the packed bitset from the sorted views.
    fn assemble(
        sets: Vec<Vec<usize>>,
        contacts: Vec<Vec<usize>>,
        n_sats: usize,
        params: ConnectivityParams,
    ) -> Self {
        let words_per_step = n_sats.div_ceil(64);
        let mut bits = vec![0u64; sets.len() * words_per_step];
        for (i, set) in sets.iter().enumerate() {
            let base = i * words_per_step;
            for &k in set {
                bits[base + k / 64] |= 1u64 << (k % 64);
            }
        }
        ConnectivitySchedule { sets, contacts, n_sats, params, words_per_step, bits, durs: Vec::new() }
    }

    /// [`Self::compute`] plus per-contact pass durations (ADR-0008): every
    /// admitted window also records how many of its sub-samples were
    /// feasible, which the engine's byte-budget check scales the link rate
    /// by. Membership is provably identical to [`Self::compute`] — the
    /// per-satellite pass counts feasibility the same way, only without the
    /// early exit at `need` (see [`sat_contacts_with_durs`]).
    pub fn compute_with_durations(
        constellation: &Constellation,
        stations: &[GroundStation],
        n_steps: usize,
        params: ConnectivityParams,
    ) -> Self {
        Self::compute_sweep(
            constellation,
            stations,
            n_steps,
            params,
            SweepRecord { durations: true, attribution: false },
        )
        .schedule
    }

    /// Attach per-contact durations computed elsewhere (the streamed
    /// bridge, [`crate::connectivity::ConnectivityStream::collect_dense`]).
    /// Shapes must mirror `sets` exactly.
    pub(crate) fn set_durations(&mut self, durs: Vec<Vec<u16>>) {
        assert_eq!(durs.len(), self.sets.len(), "durations cover a different horizon");
        for (set, ds) in self.sets.iter().zip(durs.iter()) {
            assert_eq!(ds.len(), set.len(), "durations desynchronized from sets");
        }
        self.durs = durs;
    }

    /// Were per-contact durations computed for this schedule?
    pub fn has_durations(&self) -> bool {
        !self.durs.is_empty()
    }

    /// Pass durations parallel to [`Self::sats_at`] — empty when the
    /// schedule was built without durations (full-slot capacity).
    #[inline]
    pub fn contact_durations_at(&self, i: usize) -> &[u16] {
        if self.durs.is_empty() {
            &[]
        } else {
            &self.durs[i]
        }
    }

    /// Number of time indexes the schedule covers.
    pub fn n_steps(&self) -> usize {
        self.sets.len()
    }

    /// Time indexes with at least one contact, ascending — the event list
    /// the contact-list engine mode (`EngineMode::ContactList`) advances
    /// over instead of visiting every step. For sparse scenarios (single
    /// ground station, strict elevation masks) this is a small fraction of
    /// `n_steps()`.
    pub fn active_steps(&self) -> Vec<usize> {
        (0..self.n_steps()).filter(|&i| !self.sets[i].is_empty()).collect()
    }

    /// Is satellite k connected at time index i? O(1) via the bitset.
    #[inline]
    pub fn connected(&self, k: usize, i: usize) -> bool {
        if k >= self.n_sats {
            return false;
        }
        (self.bits[i * self.words_per_step + k / 64] >> (k % 64)) & 1 == 1
    }

    /// Satellites connected at step `i`, ascending — a zero-copy view for
    /// contact iteration (the engine's per-step loop).
    #[inline]
    pub fn sats_at(&self, i: usize) -> &[usize] {
        &self.sets[i]
    }

    /// Packed connectivity words of step `i` (bit k = satellite k).
    #[inline]
    pub fn step_words(&self, i: usize) -> &[u64] {
        let base = i * self.words_per_step;
        &self.bits[base..base + self.words_per_step]
    }

    /// u64 words per step in the packed view.
    pub fn words_per_step(&self) -> usize {
        self.words_per_step
    }

    /// Latest contact of k strictly before i (the paper's i'_k), if any.
    pub fn prev_contact(&self, k: usize, i: usize) -> Option<usize> {
        let c = &self.contacts[k];
        match c.binary_search(&i) {
            Ok(0) | Err(0) => None,
            Ok(p) | Err(p) => Some(c[p - 1]),
        }
    }

    /// Next contact of k at or after i, if any.
    pub fn next_contact(&self, k: usize, i: usize) -> Option<usize> {
        let c = &self.contacts[k];
        match c.binary_search(&i) {
            Ok(p) => Some(c[p]),
            Err(p) if p < c.len() => Some(c[p]),
            _ => None,
        }
    }

    /// Failure injection: independently drop each contact with
    /// probability `p` (weather, pointing errors, station outages). The
    /// scheduler treats C as deterministic; dropout models reality
    /// deviating from the forecast — `sim` tests verify training still
    /// converges.
    pub fn with_dropout(&self, p: f64, rng: &mut crate::rng::Rng) -> ConnectivitySchedule {
        assert!((0.0..=1.0).contains(&p));
        let keep_durs = self.has_durations();
        let mut durs = if keep_durs { vec![Vec::new(); self.sets.len()] } else { Vec::new() };
        let mut sets: Vec<Vec<usize>> = Vec::with_capacity(self.sets.len());
        for (i, set) in self.sets.iter().enumerate() {
            let mut kept = Vec::new();
            for (j, &k) in set.iter().enumerate() {
                if !rng.gen_bool(p) {
                    kept.push(k);
                    if keep_durs {
                        durs[i].push(self.durs[i][j]);
                    }
                }
            }
            sets.push(kept);
        }
        let mut s = Self::from_sets_with_params(sets, self.n_sats, self.params.clone());
        s.durs = durs;
        s
    }

    /// Scheduled-outage injection: remove every contact a
    /// [`DowntimeWindow`] covers. Unlike [`Self::with_dropout`] this is
    /// deterministic — the outage is part of C, so the FedSpace planner
    /// forecasts around it rather than being surprised by it (the
    /// `dove-dropout` scenario exercises exactly that).
    pub fn with_downtime(&self, windows: &[DowntimeWindow]) -> ConnectivitySchedule {
        if windows.is_empty() {
            return self.clone();
        }
        let keep_durs = self.has_durations();
        let mut durs = if keep_durs { vec![Vec::new(); self.sets.len()] } else { Vec::new() };
        let mut sets: Vec<Vec<usize>> = Vec::with_capacity(self.sets.len());
        for (i, set) in self.sets.iter().enumerate() {
            let mut kept = Vec::new();
            for (j, &k) in set.iter().enumerate() {
                if !windows.iter().any(|w| w.sat == k && w.covers(i)) {
                    kept.push(k);
                    if keep_durs {
                        durs[i].push(self.durs[i][j]);
                    }
                }
            }
            sets.push(kept);
        }
        let mut s = Self::from_sets_with_params(sets, self.n_sats, self.params.clone());
        s.durs = durs;
        s
    }

    /// Serialize as CSV lines `i,k1;k2;...` (one row per time index).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("i,sats\n");
        for (i, set) in self.sets.iter().enumerate() {
            let sats: Vec<String> = set.iter().map(|k| k.to_string()).collect();
            out.push_str(&format!("{},{}\n", i, sats.join(";")));
        }
        out
    }
}

impl StepView for ConnectivitySchedule {
    fn n_sats(&self) -> usize {
        self.n_sats
    }

    fn n_steps(&self) -> usize {
        ConnectivitySchedule::n_steps(self)
    }

    fn sats_at(&self, i: usize) -> &[usize] {
        ConnectivitySchedule::sats_at(self, i)
    }

    fn durations_at(&self, i: usize) -> &[u16] {
        self.contact_durations_at(i)
    }

    fn duration_denom(&self) -> u16 {
        self.params.samples_per_window as u16
    }
}

/// Minimum feasible sub-samples for a window to count as connected.
pub(crate) fn feasible_need(params: &ConnectivityParams) -> usize {
    let need = ((params.samples_per_window as f64) * params.min_feasible_frac).ceil() as usize;
    need.max(1)
}

/// One sub-sample timestamp with its hoisted GMST rotation (t, sin θ, cos θ).
pub(crate) type SampleRot = (f64, f64, f64);

/// Append the sample timetable of steps `step0..step0 + len` to `out`:
/// entry `(i - step0) * samples_per_window + s` covers absolute step i's
/// s-th sub-sample. Timestamps are derived from the *absolute* step index,
/// so a chunked computation ([`crate::connectivity::ConnectivityStream`])
/// samples the identical instants as the all-at-once [`sample_rotations_range`]
/// over the whole horizon — the chunk-concatenation bit-identity tests rely
/// on this. Shared across all satellites and stations.
pub(crate) fn sample_rotations_into(
    out: &mut Vec<SampleRot>,
    step0: usize,
    len: usize,
    samples_per_window: usize,
    t0_s: f64,
) {
    out.clear();
    out.reserve(len * samples_per_window);
    for i in step0..step0 + len {
        let t_start = i as f64 * t0_s;
        for s in 0..samples_per_window {
            let t = t_start + t0_s * (s as f64 + 0.5) / samples_per_window as f64;
            let (sin_t, cos_t) = crate::orbit::gmst_rad(t).sin_cos();
            out.push((t, sin_t, cos_t));
        }
    }
}

/// Allocating form of [`sample_rotations_into`].
pub(crate) fn sample_rotations_range(
    step0: usize,
    len: usize,
    samples_per_window: usize,
    t0_s: f64,
) -> Vec<SampleRot> {
    let mut rots = Vec::new();
    sample_rotations_into(&mut rots, step0, len, samples_per_window, t0_s);
    rots
}

/// Connected step indexes (absolute, ascending) of one satellite over steps
/// `step0..step0 + len` — the per-satellite unit of work of the parallel
/// outer loop, for both the all-at-once compute (`step0 = 0`) and the
/// chunked stream. `rots` must cover exactly that step range (built by
/// [`sample_rotations_into`]). Mirrors the reference sampling semantics
/// exactly (any station suffices per sample; early exit at `need`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn sat_contacts(
    basis: &OrbitBasis,
    frames: &[StationFrame],
    rots: &[SampleRot],
    step0: usize,
    len: usize,
    samples_per_window: usize,
    sin_min: f64,
    need: usize,
) -> Vec<usize> {
    // The horizon prefilter rejects stations that can't see the satellite
    // even at 0° elevation, with one dot product and no sqrt. Gated on a
    // strictly positive mask so a boundary-ulp disagreement between the
    // prefilter (up·e vs up_dot_pos) and the exact test below (up·(e−pos))
    // can only occur near 0° elevation — far from the decision boundary —
    // and therefore never changes the outcome.
    let prefilter = sin_min > 0.0;
    let mut out = Vec::new();
    for l in 0..len {
        let mut feasible = 0usize;
        'window: for s in 0..samples_per_window {
            let (t, sin_t, cos_t) = rots[l * samples_per_window + s];
            let p = basis.position_eci(t);
            let e = crate::orbit::eci_to_ecef_rot(&p, sin_t, cos_t);
            for f in frames {
                if prefilter && f.up.dot(&e) < f.up_dot_pos {
                    continue; // below this station's horizon plane
                }
                if crate::orbit::visible_from_frame(&e, f, sin_min) {
                    feasible += 1;
                    if feasible >= need {
                        break 'window;
                    }
                    break; // any station suffices for this sample
                }
            }
        }
        if feasible >= need {
            out.push(step0 + l);
        }
    }
    out
}

/// Connected windows of one satellite with their pass durations over steps
/// `step0..step0 + len`: `(absolute step, feasible sub-sample count)` pairs,
/// ascending by step — the byte-budget primitive (ADR-0008). A window is
/// emitted iff [`sat_contacts`] would emit it: the feasibility count is
/// computed identically, just without the early exit at `need`, which
/// cannot change the ≥-`need` decision (the same argument documented on
/// [`sat_station_attr`]). The count is therefore always in
/// `need..=samples_per_window`, so the capacity fraction
/// `dur / samples_per_window` is at least `min_feasible_frac`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn sat_contacts_with_durs(
    basis: &OrbitBasis,
    frames: &[StationFrame],
    rots: &[SampleRot],
    step0: usize,
    len: usize,
    samples_per_window: usize,
    sin_min: f64,
    need: usize,
) -> Vec<(usize, u16)> {
    sat_sweep(basis, frames, rots, step0, len, samples_per_window, sin_min, need)
        .into_iter()
        .map(|(i, dur, _)| (i, dur))
        .collect()
}

/// The one fused per-satellite sweep behind every non-early-exit variant:
/// `(absolute step, feasible sub-sample count, lowest-indexed visible
/// station)` triples over steps `step0..step0 + len`, ascending by step. A
/// window is emitted iff [`sat_contacts`] would emit it — the feasibility
/// count is computed identically, just without the early exit at `need`,
/// which cannot change the ≥-`need` decision. Within each feasible
/// sub-sample the station scan stops at the first visible station (exactly
/// the "any station suffices" order of the membership sweep); the window
/// attribution is the minimum of those station indexes over its feasible
/// samples.
#[allow(clippy::too_many_arguments)]
pub(crate) fn sat_sweep(
    basis: &OrbitBasis,
    frames: &[StationFrame],
    rots: &[SampleRot],
    step0: usize,
    len: usize,
    samples_per_window: usize,
    sin_min: f64,
    need: usize,
) -> Vec<(usize, u16, u16)> {
    let prefilter = sin_min > 0.0;
    let mut out = Vec::new();
    for l in 0..len {
        let mut feasible = 0usize;
        let mut min_station = u16::MAX;
        for s in 0..samples_per_window {
            let (t, sin_t, cos_t) = rots[l * samples_per_window + s];
            let p = basis.position_eci(t);
            let e = crate::orbit::eci_to_ecef_rot(&p, sin_t, cos_t);
            for (fi, f) in frames.iter().enumerate() {
                if prefilter && f.up.dot(&e) < f.up_dot_pos {
                    continue; // below this station's horizon plane
                }
                if crate::orbit::visible_from_frame(&e, f, sin_min) {
                    feasible += 1;
                    min_station = min_station.min(fi as u16);
                    break; // any station suffices for this sample
                }
            }
        }
        if feasible >= need {
            debug_assert_ne!(min_station, u16::MAX, "feasible window saw no station");
            out.push((step0 + l, feasible as u16, min_station));
        }
    }
    out
}

/// Station attribution of one satellite's connected windows over steps
/// `step0..step0 + len`: `(absolute step, lowest-indexed visible station)`
/// pairs, ascending by step — the multi-gateway upload-routing primitive
/// (ADR-0006), a projection of [`sat_sweep`]. Attribution is total over
/// every schedule contact ("the first station, by index, that heard the
/// satellite"). The two-pass `UploadRouting::build` oracle goes through
/// this; production precompute fuses the attribution into the schedule
/// sweep itself (`UploadRouting::build_with_schedule`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn sat_station_attr(
    basis: &OrbitBasis,
    frames: &[StationFrame],
    rots: &[SampleRot],
    step0: usize,
    len: usize,
    samples_per_window: usize,
    sin_min: f64,
    need: usize,
) -> Vec<(usize, u16)> {
    sat_sweep(basis, frames, rots, step0, len, samples_per_window, sin_min, need)
        .into_iter()
        .map(|(i, _, st)| (i, st))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orbit::{planet_ground_stations, planet_labs_like};

    fn small_schedule() -> ConnectivitySchedule {
        let c = planet_labs_like(20, 0);
        let gs = planet_ground_stations();
        ConnectivitySchedule::compute(&c, &gs, 96, ConnectivityParams::default())
    }

    #[test]
    fn sets_and_contacts_consistent() {
        let s = small_schedule();
        for (i, set) in s.sets.iter().enumerate() {
            for &k in set {
                assert!(s.contacts[k].contains(&i));
                assert!(s.connected(k, i));
            }
        }
        for (k, cs) in s.contacts.iter().enumerate() {
            for &i in cs {
                assert!(s.sets[i].contains(&k));
            }
        }
    }

    #[test]
    fn sets_sorted_unique() {
        let s = small_schedule();
        for set in &s.sets {
            let mut sorted = set.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(&sorted, set);
        }
    }

    #[test]
    fn satellites_do_contact_ground() {
        let s = small_schedule();
        let total: usize = s.contacts.iter().map(|c| c.len()).sum();
        assert!(total > 0, "no contacts in a day of simulation");
    }

    #[test]
    fn optimized_compute_matches_reference() {
        // the sin-space / hoisted-rotation / parallel pipeline must agree
        // with the original trig-heavy serial implementation. The two paths
        // round differently, so a sample sitting within FP noise of the
        // elevation threshold may legitimately flip a window decision —
        // allow a tiny tie-budget instead of demanding bit-exact sets.
        let c = planet_labs_like(20, 0);
        let gs = planet_ground_stations();
        for params in [
            ConnectivityParams::default(),
            ConnectivityParams { min_elev_deg: 5.0, ..Default::default() },
            ConnectivityParams { min_elev_deg: 40.0, samples_per_window: 4, ..Default::default() },
        ] {
            let fast = ConnectivitySchedule::compute(&c, &gs, 48, params.clone());
            let slow = ConnectivitySchedule::compute_reference(&c, &gs, 48, params);
            let mut diffs = 0usize;
            let mut agreements = 0usize;
            for i in 0..48 {
                for k in 0..c.len() {
                    if fast.connected(k, i) == slow.connected(k, i) {
                        agreements += 1;
                    } else {
                        diffs += 1;
                    }
                }
            }
            assert!(diffs <= 2, "{diffs} window decisions differ (of {})", diffs + agreements);
            // and the schedules are substantial, not trivially empty
            let total: usize = slow.contacts.iter().map(|c| c.len()).sum();
            assert!(total > 0);
        }
    }

    #[test]
    fn bitset_matches_sorted_views() {
        let s = small_schedule();
        assert_eq!(s.words_per_step(), 1);
        for i in 0..s.n_steps() {
            // connected() (bitset) vs binary search on the sorted view
            for k in 0..s.n_sats {
                assert_eq!(s.connected(k, i), s.sets[i].binary_search(&k).is_ok(), "k={k} i={i}");
            }
            // word iteration reconstructs the sorted set exactly
            let mut rebuilt = Vec::new();
            for (w, &word) in s.step_words(i).iter().enumerate() {
                let mut word = word;
                while word != 0 {
                    let b = word.trailing_zeros() as usize;
                    rebuilt.push(w * 64 + b);
                    word &= word - 1;
                }
            }
            assert_eq!(rebuilt, s.sets[i]);
            assert_eq!(s.sats_at(i), &s.sets[i][..]);
        }
        // out-of-range satellite id is simply not connected
        assert!(!s.connected(s.n_sats, 0));
    }

    #[test]
    fn bitset_handles_many_words_per_step() {
        // n_sats > 64 forces multi-word steps
        let n_sats = 130;
        let sets = vec![vec![0, 63, 64, 127, 129], vec![], vec![65]];
        let s = ConnectivitySchedule::from_sets(sets, n_sats);
        assert_eq!(s.words_per_step(), 3);
        for &k in &[0usize, 63, 64, 127, 129] {
            assert!(s.connected(k, 0), "k={k}");
        }
        assert!(!s.connected(1, 0));
        assert!(!s.connected(128, 0));
        assert!(s.step_words(1).iter().all(|&w| w == 0));
        assert!(s.connected(65, 2));
    }

    #[test]
    fn prev_next_contact() {
        let sets = vec![vec![0], vec![], vec![0, 1], vec![1], vec![0]];
        let s = ConnectivitySchedule::from_sets(sets, 2);
        assert_eq!(s.prev_contact(0, 2), Some(0));
        assert_eq!(s.prev_contact(0, 0), None);
        assert_eq!(s.prev_contact(0, 4), Some(2));
        assert_eq!(s.next_contact(0, 3), Some(4));
        assert_eq!(s.next_contact(1, 4), None);
        assert_eq!(s.next_contact(0, 2), Some(2));
    }

    #[test]
    fn from_sets_roundtrip_csv() {
        let sets = vec![vec![0, 2], vec![1]];
        let s = ConnectivitySchedule::from_sets(sets, 3);
        let csv = s.to_csv();
        assert!(csv.contains("0,0;2"));
        assert!(csv.contains("1,1"));
    }

    #[test]
    fn dropout_only_removes_contacts() {
        let s = small_schedule();
        let mut rng = crate::rng::Rng::new(5);
        let d = s.with_dropout(0.3, &mut rng);
        let before: usize = s.contacts.iter().map(|c| c.len()).sum();
        let after: usize = d.contacts.iter().map(|c| c.len()).sum();
        assert!(after < before);
        for (i, set) in d.sets.iter().enumerate() {
            for k in set {
                assert!(s.sets[i].contains(k), "dropout invented a contact");
            }
        }
        // p=0 identity, p=1 empties
        let mut rng = crate::rng::Rng::new(6);
        assert_eq!(
            s.with_dropout(0.0, &mut rng).contacts.iter().map(|c| c.len()).sum::<usize>(),
            before
        );
        assert_eq!(
            s.with_dropout(1.0, &mut rng).contacts.iter().map(|c| c.len()).sum::<usize>(),
            0
        );
    }

    #[test]
    fn active_steps_are_exactly_nonempty_steps() {
        let sets = vec![vec![0, 2], vec![], vec![1], vec![], vec![]];
        let s = ConnectivitySchedule::from_sets(sets, 3);
        assert_eq!(s.active_steps(), vec![0, 2]);
        let dense = small_schedule();
        for &i in &dense.active_steps() {
            assert!(!dense.sets[i].is_empty());
        }
    }

    #[test]
    fn downtime_silences_covered_contacts_only() {
        let sets = vec![vec![0, 1], vec![0, 1], vec![0, 1], vec![0, 1]];
        let s = ConnectivitySchedule::from_sets(sets, 2);
        let d = s.with_downtime(&[DowntimeWindow { sat: 0, from_step: 1, until_step: 3 }]);
        assert_eq!(d.sets[0], vec![0, 1]);
        assert_eq!(d.sets[1], vec![1]);
        assert_eq!(d.sets[2], vec![1]);
        assert_eq!(d.sets[3], vec![0, 1]);
        // satellite 1 untouched
        assert_eq!(d.contacts[1], s.contacts[1]);
        // empty window list is the identity
        let id = s.with_downtime(&[]);
        assert_eq!(id.sets, s.sets);
    }

    #[test]
    fn derived_schedules_keep_link_params() {
        let c = planet_labs_like(10, 0);
        let gs = planet_ground_stations();
        let params = ConnectivityParams { min_elev_deg: 40.0, t0_s: 60.0, ..Default::default() };
        let s = ConnectivitySchedule::compute(&c, &gs, 24, params);
        let down = s.with_downtime(&[DowntimeWindow { sat: 0, from_step: 0, until_step: 24 }]);
        assert_eq!(down.params.min_elev_deg, 40.0);
        assert_eq!(down.params.t0_s, 60.0);
        let mut rng = crate::rng::Rng::new(1);
        let drop = s.with_dropout(0.5, &mut rng);
        assert_eq!(drop.params.min_elev_deg, 40.0);
    }

    #[test]
    fn overlapping_downtime_windows_compose() {
        let sets = vec![vec![0]; 6];
        let s = ConnectivitySchedule::from_sets(sets, 1);
        let d = s.with_downtime(&[
            DowntimeWindow { sat: 0, from_step: 0, until_step: 2 },
            DowntimeWindow { sat: 0, from_step: 1, until_step: 4 },
        ]);
        assert_eq!(d.contacts[0], vec![4, 5]);
    }

    #[test]
    fn station_attribution_covers_exactly_the_scheduled_contacts() {
        // the attribution pass must emit a station for precisely the
        // windows sat_contacts admits (same feasibility count, no early
        // exit), and every attributed station index must be in range
        let c = planet_labs_like(14, 0);
        let gs = planet_ground_stations();
        let params = ConnectivityParams::default();
        let need = feasible_need(&params);
        let spw = params.samples_per_window;
        let sin_min = params.min_elev_deg.to_radians().sin();
        let frames = station_frames(&gs);
        let rots = sample_rotations_range(0, 48, spw, params.t0_s);
        for orbit in &c.orbits {
            let basis = orbit.basis();
            let contacts = sat_contacts(&basis, &frames, &rots, 0, 48, spw, sin_min, need);
            let attr = sat_station_attr(&basis, &frames, &rots, 0, 48, spw, sin_min, need);
            let steps: Vec<usize> = attr.iter().map(|&(i, _)| i).collect();
            assert_eq!(steps, contacts);
            for &(_, st) in &attr {
                assert!((st as usize) < gs.len());
            }
        }
    }

    #[test]
    fn durations_cover_exactly_the_scheduled_contacts() {
        // compute_with_durations must admit precisely the windows compute
        // admits (same feasibility count, no early exit), with every
        // duration in need..=samples_per_window
        let c = planet_labs_like(14, 0);
        let gs = planet_ground_stations();
        let params = ConnectivityParams::default();
        let need = feasible_need(&params);
        let spw = params.samples_per_window;
        let plain = ConnectivitySchedule::compute(&c, &gs, 48, params.clone());
        let timed = ConnectivitySchedule::compute_with_durations(&c, &gs, 48, params);
        assert!(!plain.has_durations());
        assert!(timed.has_durations());
        assert_eq!(timed.sets, plain.sets);
        assert_eq!(timed.contacts, plain.contacts);
        for i in 0..48 {
            let durs = timed.contact_durations_at(i);
            assert_eq!(durs.len(), timed.sets[i].len());
            for &d in durs {
                assert!((need..=spw).contains(&(d as usize)), "dur {d} out of range");
            }
            // the StepView surface agrees with the inherent accessors
            assert_eq!(StepView::durations_at(&timed, i), durs);
            assert!(StepView::durations_at(&plain, i).is_empty());
        }
        assert_eq!(StepView::duration_denom(&timed), spw as u16);
    }

    #[test]
    fn derived_schedules_preserve_durations_of_surviving_contacts() {
        let c = planet_labs_like(12, 0);
        let gs = planet_ground_stations();
        let s = ConnectivitySchedule::compute_with_durations(
            &c,
            &gs,
            48,
            ConnectivityParams::default(),
        );
        // downtime: surviving contacts keep their exact duration, in order
        let down = s.with_downtime(&[DowntimeWindow { sat: 0, from_step: 0, until_step: 48 }]);
        assert!(down.has_durations());
        for i in 0..48 {
            let expect: Vec<u16> = s.sets[i]
                .iter()
                .zip(s.contact_durations_at(i))
                .filter(|(&k, _)| k != 0)
                .map(|(_, &d)| d)
                .collect();
            assert_eq!(down.contact_durations_at(i), &expect[..], "step {i}");
        }
        // dropout: every surviving (sat, dur) pair existed in the original
        let mut rng = crate::rng::Rng::new(9);
        let dropped = s.with_dropout(0.5, &mut rng);
        assert!(dropped.has_durations());
        for i in 0..48 {
            for (&k, &d) in dropped.sets[i].iter().zip(dropped.contact_durations_at(i)) {
                let j = s.sets[i].iter().position(|&x| x == k).expect("invented contact");
                assert_eq!(s.contact_durations_at(i)[j], d, "step {i} sat {k}");
            }
        }
        // a schedule without durations stays without them through deriving
        let plain = ConnectivitySchedule::compute(&c, &gs, 48, ConnectivityParams::default());
        assert!(!plain
            .with_downtime(&[DowntimeWindow { sat: 1, from_step: 2, until_step: 5 }])
            .has_durations());
    }

    #[test]
    fn stricter_elevation_means_fewer_contacts() {
        let c = planet_labs_like(15, 1);
        let gs = planet_ground_stations();
        let loose = ConnectivitySchedule::compute(
            &c,
            &gs,
            48,
            ConnectivityParams { min_elev_deg: 5.0, ..Default::default() },
        );
        let strict = ConnectivitySchedule::compute(
            &c,
            &gs,
            48,
            ConnectivityParams { min_elev_deg: 30.0, ..Default::default() },
        );
        let n_loose: usize = loose.contacts.iter().map(|c| c.len()).sum();
        let n_strict: usize = strict.contacts.iter().map(|c| c.len()).sum();
        assert!(n_strict <= n_loose, "strict={n_strict} loose={n_loose}");
    }
}
