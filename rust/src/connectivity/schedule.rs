//! Computation and storage of the connectivity schedule C.

use crate::orbit::{is_visible, Constellation, GroundStation};

/// Parameters of the link model (paper §2.2 / §4.1 defaults).
#[derive(Clone, Debug)]
pub struct ConnectivityParams {
    /// Wall-clock seconds between adjacent time indexes (paper: 15 min).
    pub t0_s: f64,
    /// Minimum elevation angle α_min [deg].
    pub min_elev_deg: f64,
    /// Sub-samples per window when testing feasibility.
    pub samples_per_window: usize,
    /// Fraction of sub-samples that must be feasible for the window to
    /// count as connected. The paper's "feasible for all t" read literally
    /// would require a full 15-min pass (longer than any LEO pass); the
    /// defaults (25° operational mask, ≥30% of the window ≈ a ≥4.5-min
    /// downlink session) calibrate the schedule to the paper's Figure 2
    /// statistics: min |C_i| = 4 (exact) and n_k ∈ [1, 20] per day vs the
    /// paper's [5, 19] — see EXPERIMENTS.md §Fig2.
    pub min_feasible_frac: f64,
}

impl Default for ConnectivityParams {
    fn default() -> Self {
        ConnectivityParams {
            t0_s: 15.0 * 60.0,
            min_elev_deg: 25.0,
            samples_per_window: 10,
            min_feasible_frac: 0.3,
        }
    }
}

/// The deterministic schedule C = {C_0, ..., C_{n-1}} plus fast lookups.
#[derive(Clone, Debug)]
pub struct ConnectivitySchedule {
    /// sets[i] = sorted satellite ids in C_i.
    pub sets: Vec<Vec<usize>>,
    /// contacts[k] = sorted time indexes at which satellite k is connected.
    pub contacts: Vec<Vec<usize>>,
    pub n_sats: usize,
    pub params: ConnectivityParams,
}

impl ConnectivitySchedule {
    /// Compute C for `n_steps` windows from a constellation + station list.
    pub fn compute(
        constellation: &Constellation,
        stations: &[GroundStation],
        n_steps: usize,
        params: ConnectivityParams,
    ) -> Self {
        let n_sats = constellation.len();
        let mut sets = vec![Vec::new(); n_steps];
        let mut contacts = vec![Vec::new(); n_sats];
        let need = ((params.samples_per_window as f64) * params.min_feasible_frac).ceil() as usize;
        let need = need.max(1);
        for (k, orbit) in constellation.orbits.iter().enumerate() {
            for (i, set) in sets.iter_mut().enumerate() {
                let t_start = i as f64 * params.t0_s;
                let mut feasible = 0usize;
                'window: for s in 0..params.samples_per_window {
                    let t = t_start
                        + params.t0_s * (s as f64 + 0.5) / params.samples_per_window as f64;
                    let p = orbit.position_eci(t);
                    for gs in stations {
                        if is_visible(&p, t, gs, params.min_elev_deg) {
                            feasible += 1;
                            if feasible >= need {
                                break 'window;
                            }
                            break; // any station suffices for this sample
                        }
                    }
                }
                if feasible >= need {
                    set.push(k);
                    contacts[k].push(i);
                }
            }
        }
        ConnectivitySchedule { sets, contacts, n_sats, params }
    }

    /// Build directly from explicit sets (tests, illustrative example).
    pub fn from_sets(sets: Vec<Vec<usize>>, n_sats: usize) -> Self {
        let mut contacts = vec![Vec::new(); n_sats];
        for (i, set) in sets.iter().enumerate() {
            for &k in set {
                assert!(k < n_sats, "satellite id {k} out of range");
                contacts[k].push(i);
            }
        }
        ConnectivitySchedule {
            sets,
            contacts,
            n_sats,
            params: ConnectivityParams::default(),
        }
    }

    pub fn n_steps(&self) -> usize {
        self.sets.len()
    }

    /// Is satellite k connected at time index i?
    pub fn connected(&self, k: usize, i: usize) -> bool {
        self.sets[i].binary_search(&k).is_ok()
    }

    /// Latest contact of k strictly before i (the paper's i'_k), if any.
    pub fn prev_contact(&self, k: usize, i: usize) -> Option<usize> {
        let c = &self.contacts[k];
        match c.binary_search(&i) {
            Ok(0) | Err(0) => None,
            Ok(p) | Err(p) => Some(c[p - 1]),
        }
    }

    /// Next contact of k at or after i, if any.
    pub fn next_contact(&self, k: usize, i: usize) -> Option<usize> {
        let c = &self.contacts[k];
        match c.binary_search(&i) {
            Ok(p) => Some(c[p]),
            Err(p) if p < c.len() => Some(c[p]),
            _ => None,
        }
    }

    /// Failure injection: independently drop each contact with
    /// probability `p` (weather, pointing errors, station outages). The
    /// scheduler treats C as deterministic; dropout models reality
    /// deviating from the forecast — `sim` tests verify training still
    /// converges.
    pub fn with_dropout(&self, p: f64, rng: &mut crate::rng::Rng) -> ConnectivitySchedule {
        assert!((0.0..=1.0).contains(&p));
        let sets: Vec<Vec<usize>> = self
            .sets
            .iter()
            .map(|set| set.iter().copied().filter(|_| !rng.gen_bool(p)).collect())
            .collect();
        ConnectivitySchedule::from_sets(sets, self.n_sats)
    }

    /// Serialize as CSV lines `i,k1;k2;...` (one row per time index).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("i,sats\n");
        for (i, set) in self.sets.iter().enumerate() {
            let sats: Vec<String> = set.iter().map(|k| k.to_string()).collect();
            out.push_str(&format!("{},{}\n", i, sats.join(";")));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orbit::{planet_ground_stations, planet_labs_like};

    fn small_schedule() -> ConnectivitySchedule {
        let c = planet_labs_like(20, 0);
        let gs = planet_ground_stations();
        ConnectivitySchedule::compute(&c, &gs, 96, ConnectivityParams::default())
    }

    #[test]
    fn sets_and_contacts_consistent() {
        let s = small_schedule();
        for (i, set) in s.sets.iter().enumerate() {
            for &k in set {
                assert!(s.contacts[k].contains(&i));
                assert!(s.connected(k, i));
            }
        }
        for (k, cs) in s.contacts.iter().enumerate() {
            for &i in cs {
                assert!(s.sets[i].contains(&k));
            }
        }
    }

    #[test]
    fn sets_sorted_unique() {
        let s = small_schedule();
        for set in &s.sets {
            let mut sorted = set.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(&sorted, set);
        }
    }

    #[test]
    fn satellites_do_contact_ground() {
        let s = small_schedule();
        let total: usize = s.contacts.iter().map(|c| c.len()).sum();
        assert!(total > 0, "no contacts in a day of simulation");
    }

    #[test]
    fn prev_next_contact() {
        let sets = vec![vec![0], vec![], vec![0, 1], vec![1], vec![0]];
        let s = ConnectivitySchedule::from_sets(sets, 2);
        assert_eq!(s.prev_contact(0, 2), Some(0));
        assert_eq!(s.prev_contact(0, 0), None);
        assert_eq!(s.prev_contact(0, 4), Some(2));
        assert_eq!(s.next_contact(0, 3), Some(4));
        assert_eq!(s.next_contact(1, 4), None);
        assert_eq!(s.next_contact(0, 2), Some(2));
    }

    #[test]
    fn from_sets_roundtrip_csv() {
        let sets = vec![vec![0, 2], vec![1]];
        let s = ConnectivitySchedule::from_sets(sets, 3);
        let csv = s.to_csv();
        assert!(csv.contains("0,0;2"));
        assert!(csv.contains("1,1"));
    }

    #[test]
    fn dropout_only_removes_contacts() {
        let s = small_schedule();
        let mut rng = crate::rng::Rng::new(5);
        let d = s.with_dropout(0.3, &mut rng);
        let before: usize = s.contacts.iter().map(|c| c.len()).sum();
        let after: usize = d.contacts.iter().map(|c| c.len()).sum();
        assert!(after < before);
        for (i, set) in d.sets.iter().enumerate() {
            for k in set {
                assert!(s.sets[i].contains(k), "dropout invented a contact");
            }
        }
        // p=0 identity, p=1 empties
        let mut rng = crate::rng::Rng::new(6);
        assert_eq!(
            s.with_dropout(0.0, &mut rng).contacts.iter().map(|c| c.len()).sum::<usize>(),
            before
        );
        assert_eq!(
            s.with_dropout(1.0, &mut rng).contacts.iter().map(|c| c.len()).sum::<usize>(),
            0
        );
    }

    #[test]
    fn stricter_elevation_means_fewer_contacts() {
        let c = planet_labs_like(15, 1);
        let gs = planet_ground_stations();
        let loose = ConnectivitySchedule::compute(
            &c,
            &gs,
            48,
            ConnectivityParams { min_elev_deg: 5.0, ..Default::default() },
        );
        let strict = ConnectivitySchedule::compute(
            &c,
            &gs,
            48,
            ConnectivityParams { min_elev_deg: 30.0, ..Default::default() },
        );
        let n_loose: usize = loose.contacts.iter().map(|c| c.len()).sum();
        let n_strict: usize = strict.contacts.iter().map(|c| c.len()).sum();
        assert!(n_strict <= n_loose, "strict={n_strict} loose={n_loose}");
    }
}
