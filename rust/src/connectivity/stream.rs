//! Windowed, streaming computation of the connectivity schedule — the
//! memory model that makes mega-constellation scenarios first-class
//! (ADR-0004 in docs/ADRs.md).
//!
//! [`ConnectivitySchedule::compute`] materializes the whole `sats × slots`
//! relation before the first engine step runs: fine for the paper's
//! 191-satellite fleet, a wall for Starlink/Kuiper-class fleets over
//! multi-week horizons (both the precompute latency and the
//! O(sats × horizon) resident sets/contacts/bitset). A
//! [`ConnectivityStream`] instead yields fixed-size time-chunks of the same
//! bitset representation, computed on demand:
//!
//! - each [`ScheduleChunk`] covers `chunk_len` consecutive steps and is
//!   **recyclable** — [`ConnectivityStream::fill_chunk`] reuses the chunk's
//!   buffers, so a whole-horizon walk allocates O(sats × chunk_len) once;
//! - the per-chunk satellite work is sharded across worker threads via
//!   [`crate::exec::scope_chunks`] (the same substrate the parallel
//!   scheduler search uses), borrowing the stream's frames/bases zero-copy;
//! - downtime windows and link parameters are applied *per chunk*, so a
//!   chunk landing exactly on an outage boundary filters identically to the
//!   dense [`ConnectivitySchedule::with_downtime`] post-pass (property-
//!   tested in `tests/properties.rs`).
//!
//! Chunks concatenated over the horizon are **bit-identical** to the dense
//! compute + downtime pipeline: both paths run the same
//! `sample_rotations_into`/`sat_contacts` helpers with absolute step
//! indexes, so every floating-point input and operation matches.
//!
//! [`StreamCursor`] is the walking companion the streamed engine mode
//! (`EngineMode::Streamed`) drives: monotone `seek`, a chunk-boundary-safe
//! [`ScheduleChunk::active_steps`] view in absolute indexes, and
//! [`StreamCursor::window`] to materialize a FedSpace planning window
//! ([`WindowView`]) that spans chunk boundaries without materializing the
//! horizon.

use super::graph::{IslTopology, RouteScratch};
use super::schedule::{
    feasible_need, sample_rotations_into, sat_contacts, sat_contacts_with_durs,
    ConnectivityParams, ConnectivitySchedule, SampleRot, StepView,
};
use crate::exec;
use crate::orbit::{station_frames, Constellation, GroundStation, OrbitBasis, StationFrame};

/// On-demand, chunked generator of the deterministic schedule C.
///
/// Holds only O(sats + stations) state (orbit bases, station frames, link
/// params, per-satellite downtime); the O(sats × chunk) working set lives
/// in caller-owned [`ScheduleChunk`]s.
pub struct ConnectivityStream {
    bases: Vec<OrbitBasis>,
    frames: Vec<StationFrame>,
    params: ConnectivityParams,
    n_steps: usize,
    chunk_len: usize,
    /// Downtime windows indexed by satellite: `(from_step, until_step)`,
    /// half-open, applied while assembling every chunk.
    down_by_sat: Vec<Vec<(usize, usize)>>,
    /// ISL routing model (ADR-0005): when attached, every chunk comes out
    /// with its routed reach sets computed, bit-identical to the dense
    /// [`super::ContactGraph`] over the same schedule.
    isl: Option<IslTopology>,
    /// Compute per-contact pass durations (ADR-0008)? Mutually exclusive
    /// with ISL routing — relayed reach sets have no single pass duration,
    /// so routed streams always charge full-slot capacity.
    durations: bool,
}

impl ConnectivityStream {
    /// Default chunk length: one simulated day at T0 = 15 min.
    pub const DEFAULT_CHUNK_LEN: usize = 96;

    /// Build a stream over a constellation and station network.
    ///
    /// The constellation's [`crate::orbit::DowntimeWindow`]s are baked in:
    /// every chunk comes out with outages already removed, mirroring the
    /// dense `compute(..)` + `with_downtime(..)` pipeline.
    pub fn new(
        constellation: &Constellation,
        stations: &[GroundStation],
        n_steps: usize,
        params: ConnectivityParams,
        chunk_len: usize,
    ) -> Self {
        assert!(chunk_len > 0, "chunk_len must be > 0");
        let mut down_by_sat = vec![Vec::new(); constellation.len()];
        for w in &constellation.downtime {
            down_by_sat[w.sat].push((w.from_step, w.until_step));
        }
        ConnectivityStream {
            bases: constellation.orbits.iter().map(|o| o.basis()).collect(),
            frames: station_frames(stations),
            params,
            n_steps,
            chunk_len,
            down_by_sat,
            isl: None,
            durations: false,
        }
    }

    /// Compute per-contact pass durations in every chunk from now on
    /// (builder style, like [`Self::with_isl`]). Panics when combined with
    /// ISL routing — a relayed reach set has no single pass duration
    /// (ADR-0008), so capacity-limited scenarios must be unrouted.
    pub fn with_durations(mut self) -> Self {
        assert!(self.isl.is_none(), "pass durations and ISL routing are mutually exclusive");
        self.durations = true;
        self
    }

    /// Does the stream compute per-contact pass durations?
    pub fn has_durations(&self) -> bool {
        self.durations
    }

    /// Denominator of the per-contact duration fractions (1 when the
    /// stream computes no durations).
    pub fn duration_denom(&self) -> u16 {
        if self.durations {
            self.params.samples_per_window as u16
        } else {
            1
        }
    }

    /// Attach an ISL routing model: every chunk filled from now on carries
    /// the routed reach sets alongside the direct contact sets (builder
    /// style, mirroring how downtime is baked in at construction).
    pub fn with_isl(mut self, topology: IslTopology) -> Self {
        assert_eq!(
            topology.n_sats(),
            self.n_sats(),
            "ISL topology covers a different fleet than the stream"
        );
        assert!(!self.durations, "pass durations and ISL routing are mutually exclusive");
        self.isl = Some(topology);
        self
    }

    /// Does the stream route its chunks through an ISL topology?
    pub fn has_isl(&self) -> bool {
        self.isl.is_some()
    }

    /// Relay latency the engine charges per hop, in slots (0 without ISLs).
    pub fn hop_delay_slots(&self) -> usize {
        self.isl.as_ref().map_or(0, |t| t.hop_delay_slots)
    }

    /// Number of satellites the stream covers.
    pub fn n_sats(&self) -> usize {
        self.bases.len()
    }

    /// Total time indexes of the horizon.
    pub fn n_steps(&self) -> usize {
        self.n_steps
    }

    /// Steps per chunk (the final chunk may be shorter).
    pub fn chunk_len(&self) -> usize {
        self.chunk_len
    }

    /// Link-model parameters the stream computes with.
    pub fn params(&self) -> &ConnectivityParams {
        &self.params
    }

    /// Number of chunks covering the horizon.
    pub fn n_chunks(&self) -> usize {
        self.n_steps.div_ceil(self.chunk_len)
    }

    /// Chunk index covering absolute step `i`.
    pub fn chunk_of(&self, i: usize) -> usize {
        i / self.chunk_len
    }

    /// `[start, end)` step range of chunk `c`.
    pub fn chunk_bounds(&self, c: usize) -> (usize, usize) {
        let start = c * self.chunk_len;
        (start, (start + self.chunk_len).min(self.n_steps))
    }

    /// Compute chunk `c` into a fresh [`ScheduleChunk`].
    pub fn chunk(&self, c: usize) -> ScheduleChunk {
        let mut out = ScheduleChunk::default();
        self.fill_chunk(c, &mut out);
        out
    }

    /// Compute chunk `c` in place, recycling `out`'s buffers.
    ///
    /// The satellite loop is sharded across worker threads
    /// ([`exec::scope_chunks`], sized by [`exec::default_parallelism`]);
    /// per-satellite results are collected in input order, so the chunk is
    /// identical at any thread count (ADR-0002).
    pub fn fill_chunk(&self, c: usize, out: &mut ScheduleChunk) {
        let (start, end) = self.chunk_bounds(c);
        assert!(start < end || self.n_steps == 0, "chunk {c} out of range");
        let len = end - start;
        let spw = self.params.samples_per_window;
        let sin_min = self.params.min_elev_deg.to_radians().sin();
        let need = feasible_need(&self.params);
        sample_rotations_into(&mut out.rots, start, len, spw, self.params.t0_s);
        let rots = &out.rots;
        let threads = exec::default_parallelism();
        if self.durations {
            // timed fill: same membership as the plain path (the duration
            // pass counts feasibility identically, minus the early exit)
            let per_sat: Vec<Vec<(usize, u16)>> =
                exec::scope_chunks(&self.bases, threads, |k0, shard| {
                    shard
                        .iter()
                        .enumerate()
                        .map(|(j, basis)| {
                            let k = k0 + j;
                            let mut cs = sat_contacts_with_durs(
                                basis, &self.frames, rots, start, len, spw, sin_min, need,
                            );
                            let down = &self.down_by_sat[k];
                            if !down.is_empty() {
                                cs.retain(|&(i, _)| {
                                    !down.iter().any(|&(from, until)| (from..until).contains(&i))
                                });
                            }
                            cs
                        })
                        .collect()
                });
            out.reset(start, len, self.n_sats());
            out.timed = true;
            for (k, cs) in per_sat.iter().enumerate() {
                for &(i, d) in cs {
                    out.push_contact(k, i);
                    out.durs[i - start].push(d);
                }
            }
            out.finish();
            out.clear_routing();
            return;
        }
        let per_sat: Vec<Vec<usize>> = exec::scope_chunks(&self.bases, threads, |k0, shard| {
            shard
                .iter()
                .enumerate()
                .map(|(j, basis)| {
                    let k = k0 + j;
                    let mut cs =
                        sat_contacts(basis, &self.frames, rots, start, len, spw, sin_min, need);
                    let down = &self.down_by_sat[k];
                    if !down.is_empty() {
                        cs.retain(|&i| {
                            !down.iter().any(|&(from, until)| (from..until).contains(&i))
                        });
                    }
                    cs
                })
                .collect()
        });
        out.reset(start, len, self.n_sats());
        for (k, cs) in per_sat.iter().enumerate() {
            for &i in cs {
                out.push_contact(k, i);
            }
        }
        out.finish();
        match &self.isl {
            Some(topology) => out.route(topology),
            None => out.clear_routing(),
        }
    }

    /// Materialize the whole horizon as a dense [`ConnectivitySchedule`]
    /// by concatenating chunks — the correctness bridge used by tests and
    /// small scenarios (defeats the memory bound; prefer the cursor walk).
    pub fn collect_dense(&self) -> ConnectivitySchedule {
        let mut sets: Vec<Vec<usize>> = Vec::with_capacity(self.n_steps);
        let mut durs: Vec<Vec<u16>> = Vec::new();
        let mut chunk = ScheduleChunk::default();
        for c in 0..self.n_chunks() {
            self.fill_chunk(c, &mut chunk);
            for i in chunk.start()..chunk.end() {
                sets.push(chunk.sats_at(i).to_vec());
                if self.durations {
                    durs.push(chunk.durations_at(i).to_vec());
                }
            }
        }
        let mut s =
            ConnectivitySchedule::from_sets_with_params(sets, self.n_sats(), self.params.clone());
        if self.durations {
            s.set_durations(durs);
        }
        s
    }
}

/// One computed time-chunk of the schedule: `len` consecutive steps with
/// the same dual representation as [`ConnectivitySchedule`] (sorted
/// per-step sets + packed per-step bitset), addressed by **absolute** step
/// index, plus the chunk-local event list for the streamed engine walk.
///
/// Reusable: [`ConnectivityStream::fill_chunk`] recycles all buffers.
#[derive(Clone, Debug, Default)]
pub struct ScheduleChunk {
    start: usize,
    len: usize,
    n_sats: usize,
    words_per_step: usize,
    /// sets[l] = sorted satellite ids connected at absolute step start + l.
    sets: Vec<Vec<usize>>,
    /// Packed connectivity: bit k of local step l lives at
    /// bits[l * words_per_step + k/64] >> (k % 64).
    bits: Vec<u64>,
    /// Absolute step indexes inside the chunk with ≥ 1 contact, ascending —
    /// the chunk-boundary-safe `active_steps` view.
    active: Vec<usize>,
    /// Recycled sub-sample rotation table scratch.
    rots: Vec<SampleRot>,
    /// True when the owning stream routed this fill through an ISL topology
    /// (the `reach_*` fields below are then valid).
    routed: bool,
    /// reach_sets[l] = reachable satellite ids at absolute step start + l.
    reach_sets: Vec<Vec<usize>>,
    /// reach_hops[l] = minimal hop counts parallel to `reach_sets[l]`.
    reach_hops: Vec<Vec<u8>>,
    /// Relay latency per hop in slots (copied from the topology per fill).
    hop_delay: usize,
    /// Recycled BFS scratch for the per-step routing.
    route_scratch: RouteScratch,
    /// True when the owning stream filled this chunk with pass durations
    /// (`durs` below is then parallel to `sets`).
    timed: bool,
    /// durs[l] = feasible sub-sample counts parallel to `sets[l]`
    /// (ADR-0008). Recycled like `sets`.
    durs: Vec<Vec<u16>>,
}

impl ScheduleChunk {
    /// First absolute step the chunk covers (inclusive).
    pub fn start(&self) -> usize {
        self.start
    }

    /// One past the last absolute step the chunk covers (exclusive).
    pub fn end(&self) -> usize {
        self.start + self.len
    }

    /// Number of steps in the chunk.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True iff the chunk covers no steps.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Does the chunk cover absolute step `i`?
    pub fn contains(&self, i: usize) -> bool {
        (self.start..self.end()).contains(&i)
    }

    /// Satellites connected at absolute step `i`, ascending (zero-copy).
    pub fn sats_at(&self, i: usize) -> &[usize] {
        assert!(self.contains(i), "step {i} outside chunk [{}, {})", self.start, self.end());
        &self.sets[i - self.start]
    }

    /// Is satellite `k` connected at absolute step `i`? O(1) via the bitset.
    pub fn connected(&self, k: usize, i: usize) -> bool {
        if k >= self.n_sats || !self.contains(i) {
            return false;
        }
        let l = i - self.start;
        (self.bits[l * self.words_per_step + k / 64] >> (k % 64)) & 1 == 1
    }

    /// Packed connectivity words of absolute step `i` (bit k = satellite k).
    pub fn step_words(&self, i: usize) -> &[u64] {
        assert!(self.contains(i), "step {i} outside chunk [{}, {})", self.start, self.end());
        let base = (i - self.start) * self.words_per_step;
        &self.bits[base..base + self.words_per_step]
    }

    /// Absolute step indexes with at least one contact, ascending — safe to
    /// concatenate across chunk boundaries because indexes are absolute
    /// (the streamed engine's event list).
    pub fn active_steps(&self) -> &[usize] {
        &self.active
    }

    /// Start a new fill, recycling buffers.
    fn reset(&mut self, start: usize, len: usize, n_sats: usize) {
        self.start = start;
        self.len = len;
        self.n_sats = n_sats;
        self.words_per_step = n_sats.div_ceil(64);
        if self.sets.len() > len {
            self.sets.truncate(len);
        }
        for set in &mut self.sets {
            set.clear();
        }
        self.sets.resize_with(len, Vec::new);
        self.bits.clear();
        self.bits.resize(len * self.words_per_step, 0);
        self.active.clear();
        self.timed = false;
        if self.durs.len() > len {
            self.durs.truncate(len);
        }
        for d in &mut self.durs {
            d.clear();
        }
        self.durs.resize_with(len, Vec::new);
    }

    /// Record a contact; callers push in ascending (k, i) order so each
    /// per-step set stays sorted.
    fn push_contact(&mut self, k: usize, i: usize) {
        debug_assert!(self.contains(i) && k < self.n_sats);
        let l = i - self.start;
        self.sets[l].push(k);
        self.bits[l * self.words_per_step + k / 64] |= 1u64 << (k % 64);
    }

    /// Derive the event list after all contacts are pushed.
    fn finish(&mut self) {
        self.active.clear();
        for (l, set) in self.sets.iter().enumerate() {
            if !set.is_empty() {
                self.active.push(self.start + l);
            }
        }
    }

    /// Route every step of the chunk through an ISL topology, recycling the
    /// reach buffers. Bit-identical to [`super::ContactGraph::build`] over
    /// the concatenated horizon: both call the same
    /// [`IslTopology::route_step`] on absolute step indexes.
    fn route(&mut self, topology: &IslTopology) {
        self.routed = true;
        self.hop_delay = topology.hop_delay_slots;
        if self.reach_sets.len() > self.len {
            self.reach_sets.truncate(self.len);
            self.reach_hops.truncate(self.len);
        }
        self.reach_sets.resize_with(self.len, Vec::new);
        self.reach_hops.resize_with(self.len, Vec::new);
        for l in 0..self.len {
            topology.route_step(
                self.start + l,
                &self.sets[l],
                &mut self.route_scratch,
                &mut self.reach_sets[l],
                &mut self.reach_hops[l],
            );
        }
    }

    /// Mark the chunk unrouted (the owning stream carries no ISL model).
    fn clear_routing(&mut self) {
        self.routed = false;
        self.hop_delay = 0;
    }

    /// Was this fill routed through an ISL topology?
    pub fn routed(&self) -> bool {
        self.routed
    }

    /// Relay latency per hop in slots (0 when unrouted).
    pub fn hop_delay_slots(&self) -> usize {
        self.hop_delay
    }

    /// The contacts the engine walks at absolute step `i`: `(sats, hops)`.
    /// Routed chunks return the reach set with its hop counts; unrouted
    /// chunks return the direct set with an empty hop slice (all direct).
    pub fn contacts_at(&self, i: usize) -> (&[usize], &[u8]) {
        assert!(self.contains(i), "step {i} outside chunk [{}, {})", self.start, self.end());
        let l = i - self.start;
        if self.routed {
            (&self.reach_sets[l], &self.reach_hops[l])
        } else {
            (&self.sets[l], &[])
        }
    }

    /// Was this fill computed with pass durations?
    pub fn timed(&self) -> bool {
        self.timed
    }

    /// Pass durations parallel to [`Self::sats_at`] — empty when the
    /// owning stream computes no durations (full-slot capacity).
    pub fn durations_at(&self, i: usize) -> &[u16] {
        assert!(self.contains(i), "step {i} outside chunk [{}, {})", self.start, self.end());
        if self.timed {
            &self.durs[i - self.start]
        } else {
            &[]
        }
    }

    /// The engine's event list for this chunk, routed or not: a step has a
    /// reachable satellite iff it has a direct contact (relays need a
    /// ground-visible sink, and every sink is itself reachable), so the
    /// direct event list is exact in both cases. Absolute indexes, safe to
    /// concatenate across chunks.
    pub fn events(&self) -> &[usize] {
        &self.active
    }
}

/// A FedSpace planning window materialized from a stream: the per-step
/// contact sets of `[start, start + len)` in absolute indexing, plus the
/// global horizon length so forecast end-clamping matches the dense path
/// exactly. This is what `sched::forecast`/`sched::search` see instead of
/// the whole schedule.
#[derive(Clone, Debug)]
pub struct WindowView {
    start: usize,
    n_steps_total: usize,
    n_sats: usize,
    sets: Vec<Vec<usize>>,
    /// Hop counts parallel to `sets` (empty inner vecs when the stream
    /// carries no ISLs — the [`StepView::hops_at`] "all direct" default).
    hops: Vec<Vec<u8>>,
    /// Relay latency per hop in slots, copied from the owning stream so the
    /// forecast can discount relayed contacts (0 without ISLs).
    hop_delay: usize,
    /// Pass durations parallel to `sets` (empty inner vecs when the stream
    /// computes no durations — the [`StepView::durations_at`] full-slot
    /// default).
    durs: Vec<Vec<u16>>,
    /// Denominator of the duration fractions (1 without durations).
    denom: u16,
}

impl WindowView {
    /// First absolute step of the window.
    pub fn start(&self) -> usize {
        self.start
    }

    /// Number of materialized steps.
    pub fn len(&self) -> usize {
        self.sets.len()
    }

    /// True iff the window covers no steps.
    pub fn is_empty(&self) -> bool {
        self.sets.is_empty()
    }
}

impl StepView for WindowView {
    fn n_sats(&self) -> usize {
        self.n_sats
    }

    fn n_steps(&self) -> usize {
        self.n_steps_total
    }

    fn sats_at(&self, i: usize) -> &[usize] {
        &self.sets[i - self.start]
    }

    fn hops_at(&self, i: usize) -> &[u8] {
        &self.hops[i - self.start]
    }

    fn hop_delay_slots(&self) -> usize {
        self.hop_delay
    }

    fn durations_at(&self, i: usize) -> &[u16] {
        &self.durs[i - self.start]
    }

    fn duration_denom(&self) -> u16 {
        self.denom
    }
}

/// Monotone walking state over a [`ConnectivityStream`]: the current chunk
/// plus one spare, both recycled in place, so a whole-horizon walk holds
/// at most two chunks — peak schedule memory O(sats × chunk_len) instead
/// of O(sats × horizon).
pub struct StreamCursor<'a> {
    stream: &'a ConnectivityStream,
    current: ScheduleChunk,
    current_idx: Option<usize>,
    spare: ScheduleChunk,
    spare_idx: Option<usize>,
}

impl<'a> StreamCursor<'a> {
    /// A cursor with no chunk loaded yet.
    pub fn new(stream: &'a ConnectivityStream) -> Self {
        StreamCursor {
            stream,
            current: ScheduleChunk::default(),
            current_idx: None,
            spare: ScheduleChunk::default(),
            spare_idx: None,
        }
    }

    /// Make the current chunk cover absolute step `i`, computing it if
    /// needed (or swapping in the spare when a window materialization
    /// already computed it).
    pub fn seek(&mut self, i: usize) {
        assert!(i < self.stream.n_steps(), "seek past the horizon");
        let c = self.stream.chunk_of(i);
        if self.current_idx == Some(c) {
            return;
        }
        if self.spare_idx == Some(c) {
            std::mem::swap(&mut self.current, &mut self.spare);
            std::mem::swap(&mut self.current_idx, &mut self.spare_idx);
            return;
        }
        self.stream.fill_chunk(c, &mut self.current);
        self.current_idx = Some(c);
    }

    /// The chunk covering the last `seek` target.
    pub fn chunk(&self) -> &ScheduleChunk {
        assert!(self.current_idx.is_some(), "seek before reading the cursor");
        &self.current
    }

    /// Materialize the planning window `[start, start + len)` (clamped to
    /// the horizon) by copying per-step sets out of the covering chunks;
    /// chunks beyond the current one are computed into the recycled spare.
    /// The current chunk is left untouched, so `sats_at`/`active_steps`
    /// views taken after this call still see the walk position.
    pub fn window(&mut self, start: usize, len: usize) -> WindowView {
        let end = (start + len).min(self.stream.n_steps());
        let mut sets = Vec::with_capacity(end.saturating_sub(start));
        let mut hops = Vec::with_capacity(end.saturating_sub(start));
        let mut durs = Vec::with_capacity(end.saturating_sub(start));
        for i in start..end {
            let c = self.stream.chunk_of(i);
            let (set, hop, dur) = if self.current_idx == Some(c) {
                let (s, h) = self.current.contacts_at(i);
                (s.to_vec(), h.to_vec(), self.current.durations_at(i).to_vec())
            } else {
                if self.spare_idx != Some(c) {
                    self.stream.fill_chunk(c, &mut self.spare);
                    self.spare_idx = Some(c);
                }
                let (s, h) = self.spare.contacts_at(i);
                (s.to_vec(), h.to_vec(), self.spare.durations_at(i).to_vec())
            };
            sets.push(set);
            hops.push(hop);
            durs.push(dur);
        }
        WindowView {
            start,
            n_steps_total: self.stream.n_steps(),
            n_sats: self.stream.n_sats(),
            sets,
            hops,
            hop_delay: self.stream.hop_delay_slots(),
            durs,
            denom: self.stream.duration_denom(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orbit::{planet_ground_stations, planet_labs_like, DowntimeWindow};

    fn assert_stream_matches_dense(
        constellation: &Constellation,
        n_steps: usize,
        chunk_len: usize,
    ) {
        let gs = planet_ground_stations();
        let params = ConnectivityParams::default();
        let dense = ConnectivitySchedule::compute(constellation, &gs, n_steps, params.clone())
            .with_downtime(&constellation.downtime);
        let stream = ConnectivityStream::new(constellation, &gs, n_steps, params, chunk_len);
        assert_eq!(stream.n_chunks(), n_steps.div_ceil(chunk_len));
        let mut chunk = ScheduleChunk::default();
        let mut all_active = Vec::new();
        for c in 0..stream.n_chunks() {
            stream.fill_chunk(c, &mut chunk);
            let (start, end) = stream.chunk_bounds(c);
            assert_eq!((chunk.start(), chunk.end()), (start, end));
            for i in start..end {
                assert_eq!(chunk.sats_at(i), dense.sats_at(i), "step {i} chunk_len {chunk_len}");
                for k in 0..constellation.len() {
                    assert_eq!(chunk.connected(k, i), dense.connected(k, i), "k={k} i={i}");
                }
            }
            all_active.extend_from_slice(chunk.active_steps());
        }
        assert_eq!(all_active, dense.active_steps(), "chunk_len {chunk_len}");
    }

    #[test]
    fn chunks_concatenate_to_dense_schedule() {
        let c = planet_labs_like(20, 0);
        for chunk_len in [1, 7, 48, 96, 200] {
            assert_stream_matches_dense(&c, 96, chunk_len);
        }
    }

    #[test]
    fn downtime_on_chunk_edges_matches_dense_postpass() {
        // outage boundaries exactly on chunk edges (from 24, until 48 with
        // chunk_len 24) plus one straddling a boundary
        let c = planet_labs_like(12, 1).with_downtime(vec![
            DowntimeWindow { sat: 0, from_step: 24, until_step: 48 },
            DowntimeWindow { sat: 3, from_step: 20, until_step: 25 },
            DowntimeWindow { sat: 7, from_step: 0, until_step: 96 },
        ]);
        assert_stream_matches_dense(&c, 96, 24);
    }

    #[test]
    fn collect_dense_equals_compute_with_downtime() {
        let c = planet_labs_like(10, 2)
            .with_downtime(vec![DowntimeWindow { sat: 2, from_step: 10, until_step: 30 }]);
        let gs = planet_ground_stations();
        let params = ConnectivityParams::default();
        let dense = ConnectivitySchedule::compute(&c, &gs, 48, params.clone())
            .with_downtime(&c.downtime);
        let stream = ConnectivityStream::new(&c, &gs, 48, params, 13);
        let collected = stream.collect_dense();
        assert_eq!(collected.sets, dense.sets);
        assert_eq!(collected.contacts, dense.contacts);
    }

    #[test]
    fn cursor_walks_and_windows_across_boundaries() {
        let c = planet_labs_like(8, 3);
        let gs = planet_ground_stations();
        let params = ConnectivityParams::default();
        let dense = ConnectivitySchedule::compute(&c, &gs, 60, params.clone());
        let stream = ConnectivityStream::new(&c, &gs, 60, params, 16);
        let mut cur = StreamCursor::new(&stream);
        for i in 0..60 {
            cur.seek(i);
            assert!(cur.chunk().contains(i));
            assert_eq!(cur.chunk().sats_at(i), dense.sats_at(i), "step {i}");
        }
        // windows spanning one, two, and three chunks, plus horizon clamp
        let mut cur = StreamCursor::new(&stream);
        cur.seek(0);
        for (start, len) in [(0usize, 8usize), (12, 16), (10, 40), (50, 24)] {
            let w = cur.window(start, len);
            let end = (start + len).min(60);
            assert_eq!(w.len(), end - start);
            assert_eq!(StepView::n_steps(&w), 60);
            for i in start..end {
                assert_eq!(w.sats_at(i), dense.sats_at(i), "window step {i}");
            }
            // the current chunk still serves the walk position
            assert_eq!(cur.chunk().sats_at(0), dense.sats_at(0));
        }
    }

    #[test]
    fn seek_reuses_spare_chunk_from_window() {
        let c = planet_labs_like(6, 4);
        let gs = planet_ground_stations();
        let stream =
            ConnectivityStream::new(&c, &gs, 48, ConnectivityParams::default(), 12);
        let mut cur = StreamCursor::new(&stream);
        cur.seek(0);
        // window reaching into chunk 1 leaves it in the spare slot
        let _ = cur.window(8, 12);
        cur.seek(12); // swaps the spare in
        assert!(cur.chunk().contains(12));
        let dense = ConnectivitySchedule::compute(&c, &gs, 48, ConnectivityParams::default());
        assert_eq!(cur.chunk().sats_at(12), dense.sats_at(12));
    }

    #[test]
    fn routed_chunks_bit_identical_to_dense_contact_graph() {
        use super::super::graph::{ContactGraph, IslParams};
        use crate::orbit::{Constellation, WalkerPattern, WalkerSpec};
        let c = Constellation::walker(&WalkerSpec {
            pattern: WalkerPattern::Star,
            n_sats: 24,
            planes: 6,
            phasing: 2,
            alt_m: 780e3,
            inc_deg: 86.4,
        })
        .with_downtime(vec![DowntimeWindow { sat: 3, from_step: 10, until_step: 30 }]);
        let gs = planet_ground_stations();
        let params = ConnectivityParams::default();
        let topology = IslTopology::new(
            &c,
            IslParams {
                max_hops: 3,
                hop_delay_slots: 1,
                cross_plane: true,
                max_range_m: 4000e3,
                t0_s: params.t0_s,
            },
        )
        .unwrap();
        let dense = ConnectivitySchedule::compute(&c, &gs, 48, params.clone())
            .with_downtime(&c.downtime);
        let graph = ContactGraph::build(&topology, &dense);
        // deliberately awkward chunk length: boundaries inside the horizon
        let stream = ConnectivityStream::new(&c, &gs, 48, params, 13).with_isl(topology);
        assert!(stream.has_isl());
        assert_eq!(stream.hop_delay_slots(), 1);
        let mut chunk = ScheduleChunk::default();
        let mut events = Vec::new();
        for ci in 0..stream.n_chunks() {
            stream.fill_chunk(ci, &mut chunk);
            assert!(chunk.routed());
            assert_eq!(chunk.hop_delay_slots(), 1);
            for i in chunk.start()..chunk.end() {
                let (s, h) = chunk.contacts_at(i);
                assert_eq!(s, graph.sats_at(i), "reach set at step {i}");
                assert_eq!(h, graph.hops_at(i), "hop counts at step {i}");
                // direct contacts stay visible underneath the routing
                assert_eq!(chunk.sats_at(i), dense.sats_at(i), "direct set at step {i}");
            }
            events.extend_from_slice(chunk.events());
        }
        assert_eq!(events, graph.active_steps());
    }

    #[test]
    fn unrouted_chunks_report_direct_contacts() {
        let c = planet_labs_like(6, 0);
        let gs = planet_ground_stations();
        let stream = ConnectivityStream::new(&c, &gs, 24, ConnectivityParams::default(), 10);
        assert!(!stream.has_isl());
        assert_eq!(stream.hop_delay_slots(), 0);
        let chunk = stream.chunk(0);
        assert!(!chunk.routed());
        for i in chunk.start()..chunk.end() {
            let (s, h) = chunk.contacts_at(i);
            assert_eq!(s, chunk.sats_at(i));
            assert!(h.is_empty());
        }
        assert_eq!(chunk.events(), chunk.active_steps());
    }

    #[test]
    fn timed_chunks_match_dense_durations_bitwise() {
        // same membership as the untimed stream, and the duration of every
        // contact equals the dense compute_with_durations value — across
        // chunk boundaries and with downtime filtering applied
        let c = planet_labs_like(12, 0)
            .with_downtime(vec![DowntimeWindow { sat: 2, from_step: 10, until_step: 30 }]);
        let gs = planet_ground_stations();
        let params = ConnectivityParams::default();
        let dense = ConnectivitySchedule::compute_with_durations(&c, &gs, 48, params.clone())
            .with_downtime(&c.downtime);
        let stream =
            ConnectivityStream::new(&c, &gs, 48, params, 13).with_durations();
        assert!(stream.has_durations());
        assert_eq!(stream.duration_denom(), 10);
        let mut chunk = ScheduleChunk::default();
        for ci in 0..stream.n_chunks() {
            stream.fill_chunk(ci, &mut chunk);
            assert!(chunk.timed());
            for i in chunk.start()..chunk.end() {
                assert_eq!(chunk.sats_at(i), dense.sats_at(i), "sets at step {i}");
                assert_eq!(
                    chunk.durations_at(i),
                    dense.contact_durations_at(i),
                    "durations at step {i}"
                );
            }
        }
        // collect_dense carries the durations through
        let collected = stream.collect_dense();
        assert!(collected.has_durations());
        for i in 0..48 {
            assert_eq!(collected.contact_durations_at(i), dense.contact_durations_at(i));
        }
        // cursor windows expose them on the StepView surface
        let mut cur = StreamCursor::new(&stream);
        cur.seek(0);
        let w = cur.window(8, 20);
        assert_eq!(StepView::duration_denom(&w), 10);
        for i in 8..28 {
            assert_eq!(StepView::durations_at(&w, i), dense.contact_durations_at(i));
        }
        // an untimed stream's chunks and windows report full-slot defaults
        let plain = ConnectivityStream::new(&c, &gs, 48, ConnectivityParams::default(), 13);
        let ch = plain.chunk(0);
        assert!(!ch.timed());
        assert!(ch.durations_at(0).is_empty());
        let mut cur = StreamCursor::new(&plain);
        cur.seek(0);
        let w = cur.window(0, 8);
        assert!(StepView::durations_at(&w, 0).is_empty());
        assert_eq!(StepView::duration_denom(&w), 1);
    }

    #[test]
    #[should_panic(expected = "mutually exclusive")]
    fn durations_refuse_isl_routing() {
        use super::super::graph::IslParams;
        let c = planet_labs_like(6, 0);
        let gs = planet_ground_stations();
        let params = ConnectivityParams::default();
        let topology = IslTopology::new(
            &c,
            IslParams {
                max_hops: 2,
                hop_delay_slots: 1,
                cross_plane: true,
                max_range_m: 4000e3,
                t0_s: params.t0_s,
            },
        )
        .unwrap();
        let _ = ConnectivityStream::new(&c, &gs, 24, params, 12)
            .with_durations()
            .with_isl(topology);
    }

    #[test]
    fn last_partial_chunk_has_right_bounds() {
        let c = planet_labs_like(5, 5);
        let gs = planet_ground_stations();
        let stream =
            ConnectivityStream::new(&c, &gs, 50, ConnectivityParams::default(), 16);
        assert_eq!(stream.n_chunks(), 4);
        assert_eq!(stream.chunk_bounds(3), (48, 50));
        let ch = stream.chunk(3);
        assert_eq!((ch.start(), ch.end(), ch.len()), (48, 50, 2));
    }
}
