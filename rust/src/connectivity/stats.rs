//! Connectivity statistics — the data behind Figure 2.

use super::schedule::ConnectivitySchedule;

/// Summary statistics of a connectivity schedule.
#[derive(Clone, Debug)]
pub struct ConnectivityStats {
    /// |C_i| per time index (Figure 2a series).
    pub set_sizes: Vec<usize>,
    /// n_k = contacts per satellite over the window (Figure 2b histogram).
    pub contacts_per_sat: Vec<usize>,
    /// max_i |C_i|.
    pub max_set: usize,
    /// min_i |C_i|.
    pub min_set: usize,
    /// Mean n_k over satellites.
    pub mean_contacts: f64,
}

impl ConnectivityStats {
    /// Summarize a computed schedule.
    pub fn from_schedule(s: &ConnectivitySchedule) -> Self {
        let set_sizes = set_sizes(s);
        let contacts_per_sat: Vec<usize> = s.contacts.iter().map(|c| c.len()).collect();
        let max_set = set_sizes.iter().copied().max().unwrap_or(0);
        let min_set = set_sizes.iter().copied().min().unwrap_or(0);
        let mean_contacts = if contacts_per_sat.is_empty() {
            0.0
        } else {
            contacts_per_sat.iter().sum::<usize>() as f64 / contacts_per_sat.len() as f64
        };
        ConnectivityStats { set_sizes, contacts_per_sat, max_set, min_set, mean_contacts }
    }

    /// Histogram of n_k with the given bucket width.
    pub fn contacts_histogram(&self, bucket: usize) -> Vec<(usize, usize)> {
        assert!(bucket > 0);
        let max = self.contacts_per_sat.iter().copied().max().unwrap_or(0);
        let mut hist = vec![0usize; max / bucket + 1];
        for &n in &self.contacts_per_sat {
            hist[n / bucket] += 1;
        }
        hist.into_iter().enumerate().map(|(b, c)| (b * bucket, c)).collect()
    }
}

/// |C_i| series.
pub fn set_sizes(s: &ConnectivitySchedule) -> Vec<usize> {
    s.sets.iter().map(|c| c.len()).collect()
}

/// n_k over the first `steps_per_day` indexes (paper: 96 with T0=15 min).
pub fn contacts_per_day(s: &ConnectivitySchedule, steps_per_day: usize) -> Vec<usize> {
    let lim = steps_per_day.min(s.n_steps());
    s.contacts
        .iter()
        .map(|c| c.iter().take_while(|&&i| i < lim).count())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::connectivity::ConnectivitySchedule;

    fn sched() -> ConnectivitySchedule {
        ConnectivitySchedule::from_sets(
            vec![vec![0, 1], vec![2], vec![], vec![0, 1, 2], vec![1]],
            3,
        )
    }

    #[test]
    fn set_sizes_correct() {
        assert_eq!(set_sizes(&sched()), vec![2, 1, 0, 3, 1]);
    }

    #[test]
    fn stats_extrema() {
        let st = ConnectivityStats::from_schedule(&sched());
        assert_eq!(st.max_set, 3);
        assert_eq!(st.min_set, 0);
        assert_eq!(st.contacts_per_sat, vec![2, 3, 2]);
        assert!((st.mean_contacts - 7.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn contacts_per_day_respects_limit() {
        let n = contacts_per_day(&sched(), 3);
        assert_eq!(n, vec![1, 1, 1]);
    }

    #[test]
    fn histogram_sums_to_n_sats() {
        let st = ConnectivityStats::from_schedule(&sched());
        let h = st.contacts_histogram(1);
        let total: usize = h.iter().map(|(_, c)| c).sum();
        assert_eq!(total, 3);
    }
}
