//! CART regression tree: variance-reduction splits, depth/leaf limits,
//! optional per-split feature subsampling (used by the forest).

use crate::rng::Rng;

/// Tree hyper-parameters.
#[derive(Clone, Debug)]
pub struct TreeParams {
    /// Maximum tree depth.
    pub max_depth: usize,
    /// Minimum samples a leaf may hold.
    pub min_samples_leaf: usize,
    /// Minimum samples a node needs to be split further.
    pub min_samples_split: usize,
    /// Features considered per split; `None` = all (single-tree mode).
    pub max_features: Option<usize>,
}

impl Default for TreeParams {
    fn default() -> Self {
        TreeParams {
            max_depth: 12,
            min_samples_leaf: 2,
            min_samples_split: 4,
            max_features: None,
        }
    }
}

#[derive(Clone, Debug)]
enum Node {
    Leaf {
        value: f64,
    },
    Split {
        feature: usize,
        threshold: f64,
        left: usize,
        right: usize,
    },
}

/// A fitted regression tree (arena-allocated nodes).
#[derive(Clone, Debug)]
pub struct RegressionTree {
    /// Hyper-parameters the tree was built with.
    pub params: TreeParams,
    nodes: Vec<Node>,
    fitted: bool,
}

impl RegressionTree {
    /// An unfitted tree with the given hyper-parameters.
    pub fn new(params: TreeParams) -> Self {
        RegressionTree { params, nodes: Vec::new(), fitted: false }
    }

    /// Fit on the rows selected by `idx` (enables bootstrap without copying).
    pub fn fit_indices(
        &mut self,
        x: &[Vec<f64>],
        y: &[f64],
        idx: &[usize],
        rng: &mut Rng,
    ) {
        assert_eq!(x.len(), y.len());
        assert!(!idx.is_empty(), "empty training set");
        self.nodes.clear();
        let mut idx = idx.to_vec();
        self.build(x, y, &mut idx, 0, rng);
        self.fitted = true;
    }

    /// Fit on every row of `x`.
    pub fn fit(&mut self, x: &[Vec<f64>], y: &[f64], rng: &mut Rng) {
        let idx: Vec<usize> = (0..x.len()).collect();
        self.fit_indices(x, y, &idx, rng);
    }

    fn mean(y: &[f64], idx: &[usize]) -> f64 {
        idx.iter().map(|&i| y[i]).sum::<f64>() / idx.len() as f64
    }

    /// Build subtree over `idx`, returning its node id.
    fn build(
        &mut self,
        x: &[Vec<f64>],
        y: &[f64],
        idx: &mut [usize],
        depth: usize,
        rng: &mut Rng,
    ) -> usize {
        let value = Self::mean(y, idx);
        if depth >= self.params.max_depth || idx.len() < self.params.min_samples_split {
            return self.push(Node::Leaf { value });
        }
        match self.best_split(x, y, idx, rng) {
            None => self.push(Node::Leaf { value }),
            Some((feature, threshold)) => {
                // partition idx in place
                let mut lo = 0usize;
                for i in 0..idx.len() {
                    if x[idx[i]][feature] <= threshold {
                        idx.swap(lo, i);
                        lo += 1;
                    }
                }
                if lo == 0 || lo == idx.len() {
                    return self.push(Node::Leaf { value });
                }
                let id = self.push(Node::Leaf { value }); // placeholder
                let (l_idx, r_idx) = idx.split_at_mut(lo);
                let left = self.build(x, y, l_idx, depth + 1, rng);
                let right = self.build(x, y, r_idx, depth + 1, rng);
                self.nodes[id] = Node::Split { feature, threshold, left, right };
                id
            }
        }
    }

    fn push(&mut self, n: Node) -> usize {
        self.nodes.push(n);
        self.nodes.len() - 1
    }

    /// Best (feature, threshold) by weighted-variance reduction.
    fn best_split(
        &self,
        x: &[Vec<f64>],
        y: &[f64],
        idx: &[usize],
        rng: &mut Rng,
    ) -> Option<(usize, f64)> {
        let d = x[0].len();
        let features: Vec<usize> = match self.params.max_features {
            Some(m) if m < d => rng.choose_k(d, m),
            _ => (0..d).collect(),
        };
        let n = idx.len() as f64;
        let sum: f64 = idx.iter().map(|&i| y[i]).sum();
        let sum2: f64 = idx.iter().map(|&i| y[i] * y[i]).sum();
        let parent_sse = sum2 - sum * sum / n;
        let mut best: Option<(usize, f64, f64)> = None; // (feat, thr, sse)
        let min_leaf = self.params.min_samples_leaf;
        let mut order: Vec<usize> = idx.to_vec();
        for &f in &features {
            order.sort_by(|&a, &b| x[a][f].partial_cmp(&x[b][f]).unwrap());
            // prefix sums over sorted order
            let mut ls = 0.0;
            let mut ls2 = 0.0;
            for (pos, &i) in order.iter().enumerate() {
                ls += y[i];
                ls2 += y[i] * y[i];
                let nl = (pos + 1) as f64;
                let nr = n - nl;
                if (pos + 1) < min_leaf || (idx.len() - pos - 1) < min_leaf || nr == 0.0 {
                    continue;
                }
                // skip ties: cannot split between equal feature values
                if x[order[pos]][f] == x[order[pos + 1]][f] {
                    continue;
                }
                let rs = sum - ls;
                let rs2 = sum2 - ls2;
                let sse = (ls2 - ls * ls / nl) + (rs2 - rs * rs / nr);
                // Accept ties (sse == parent) when the node is impure —
                // greedy CART needs this to enter XOR-like interactions —
                // but never split pure nodes (parent_sse ≈ 0).
                let acceptable = parent_sse > 1e-12 && sse <= parent_sse;
                if best.map_or(acceptable, |(_, _, b)| sse < b) {
                    let thr = 0.5 * (x[order[pos]][f] + x[order[pos + 1]][f]);
                    best = Some((f, thr, sse));
                }
            }
        }
        best.map(|(f, t, _)| (f, t))
    }

    /// Predict one row.
    pub fn predict(&self, row: &[f64]) -> f64 {
        assert!(self.fitted, "predict before fit");
        let mut id = 0usize;
        loop {
            match &self.nodes[id] {
                Node::Leaf { value } => return *value,
                Node::Split { feature, threshold, left, right } => {
                    id = if row[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }

    /// Has `fit`/`fit_indices` run?
    pub fn is_fitted(&self) -> bool {
        self.fitted
    }

    /// Arena size (leaves + splits) of the fitted tree.
    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::new(0)
    }

    #[test]
    fn fits_step_function_exactly() {
        // y = 1 if x > 0.5 else 0 — one split suffices
        let x: Vec<Vec<f64>> = (0..100).map(|i| vec![i as f64 / 100.0]).collect();
        let y: Vec<f64> = x.iter().map(|r| if r[0] > 0.5 { 1.0 } else { 0.0 }).collect();
        let mut t = RegressionTree::new(TreeParams::default());
        t.fit(&x, &y, &mut rng());
        for (r, &want) in x.iter().zip(y.iter()) {
            assert_eq!(t.predict(r), want);
        }
    }

    #[test]
    fn constant_target_single_leaf() {
        let x: Vec<Vec<f64>> = (0..20).map(|i| vec![i as f64]).collect();
        let y = vec![3.5; 20];
        let mut t = RegressionTree::new(TreeParams::default());
        t.fit(&x, &y, &mut rng());
        assert_eq!(t.n_nodes(), 1);
        assert_eq!(t.predict(&[100.0]), 3.5);
    }

    #[test]
    fn respects_max_depth() {
        let x: Vec<Vec<f64>> = (0..256).map(|i| vec![i as f64]).collect();
        let y: Vec<f64> = (0..256).map(|i| i as f64).collect();
        let mut t = RegressionTree::new(TreeParams { max_depth: 2, ..Default::default() });
        t.fit(&x, &y, &mut rng());
        // depth-2 binary tree has at most 7 nodes
        assert!(t.n_nodes() <= 7, "n_nodes={}", t.n_nodes());
    }

    #[test]
    fn min_samples_leaf_respected() {
        let x: Vec<Vec<f64>> = (0..10).map(|i| vec![i as f64]).collect();
        let y: Vec<f64> = (0..10).map(|i| (i % 2) as f64).collect();
        let mut t = RegressionTree::new(TreeParams {
            min_samples_leaf: 5,
            ..Default::default()
        });
        t.fit(&x, &y, &mut rng());
        // only one split possible (5|5)
        assert!(t.n_nodes() <= 3);
    }

    #[test]
    fn two_feature_interaction() {
        // y = x0 XOR x1 on {0,1}^2 grid — needs depth 2
        let mut x = Vec::new();
        let mut y = Vec::new();
        for a in 0..2 {
            for b in 0..2 {
                for _ in 0..5 {
                    x.push(vec![a as f64, b as f64]);
                    y.push(((a + b) % 2) as f64);
                }
            }
        }
        let mut t = RegressionTree::new(TreeParams::default());
        t.fit(&x, &y, &mut rng());
        assert_eq!(t.predict(&[0.0, 1.0]), 1.0);
        assert_eq!(t.predict(&[1.0, 1.0]), 0.0);
    }

    #[test]
    fn duplicate_feature_values_no_invalid_split() {
        let x = vec![vec![1.0], vec![1.0], vec![1.0], vec![1.0]];
        let y = vec![0.0, 1.0, 0.0, 1.0];
        let mut t = RegressionTree::new(TreeParams::default());
        t.fit(&x, &y, &mut rng());
        assert_eq!(t.predict(&[1.0]), 0.5);
    }
}
