//! Ordinary least squares with ridge damping — the ablation baseline
//! regressor for û (DESIGN.md experiment index, bench_ablation).

use super::Regressor;

/// Linear regression fit by solving the (ridge-damped) normal equations
/// with Gaussian elimination — d is tiny (≈10 features) so O(d^3) is free.
#[derive(Clone)]
pub struct LinearRegression {
    /// ridge coefficient λ
    pub lambda: f64,
    /// learned weights, last entry is the intercept
    weights: Vec<f64>,
}

impl LinearRegression {
    /// An unfitted model with ridge coefficient `lambda`.
    pub fn new(lambda: f64) -> Self {
        LinearRegression { lambda, weights: Vec::new() }
    }

    /// Learned weights (last entry is the intercept); empty before `fit`.
    pub fn weights(&self) -> &[f64] {
        &self.weights
    }
}

/// Solve A x = b in place (A is n×n row-major) via partial-pivot elimination.
fn solve(mut a: Vec<Vec<f64>>, mut b: Vec<f64>) -> Vec<f64> {
    let n = b.len();
    for col in 0..n {
        // pivot
        let piv = (col..n)
            .max_by(|&i, &j| a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap())
            .unwrap();
        a.swap(col, piv);
        b.swap(col, piv);
        let p = a[col][col];
        assert!(p.abs() > 1e-12, "singular system");
        for row in (col + 1)..n {
            let f = a[row][col] / p;
            for k in col..n {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    // back substitution
    let mut x = vec![0.0; n];
    for col in (0..n).rev() {
        let mut s = b[col];
        for k in (col + 1)..n {
            s -= a[col][k] * x[k];
        }
        x[col] = s / a[col][col];
    }
    x
}

impl Regressor for LinearRegression {
    fn fit(&mut self, x: &[Vec<f64>], y: &[f64]) {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty());
        let d = x[0].len() + 1; // + intercept
        let mut xtx = vec![vec![0.0; d]; d];
        let mut xty = vec![0.0; d];
        for (row, &t) in x.iter().zip(y.iter()) {
            let aug: Vec<f64> = row.iter().copied().chain(std::iter::once(1.0)).collect();
            for i in 0..d {
                xty[i] += aug[i] * t;
                for j in 0..d {
                    xtx[i][j] += aug[i] * aug[j];
                }
            }
        }
        for (i, row) in xtx.iter_mut().enumerate().take(d - 1) {
            row[i] += self.lambda; // no ridge on intercept
        }
        self.weights = solve(xtx, xty);
    }

    fn predict(&self, row: &[f64]) -> f64 {
        assert!(!self.weights.is_empty(), "predict before fit");
        let d = self.weights.len();
        assert_eq!(row.len() + 1, d);
        row.iter().zip(&self.weights[..d - 1]).map(|(a, b)| a * b).sum::<f64>()
            + self.weights[d - 1]
    }

    fn is_fitted(&self) -> bool {
        !self.weights.is_empty()
    }

    fn clone_box(&self) -> Box<dyn Regressor> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn recovers_exact_linear_weights() {
        let mut rng = Rng::new(0);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..200 {
            let a = rng.gen_f64(-1.0, 1.0);
            let b = rng.gen_f64(-1.0, 1.0);
            x.push(vec![a, b]);
            y.push(3.0 * a - 2.0 * b + 0.5);
        }
        let mut lr = LinearRegression::new(1e-9);
        lr.fit(&x, &y);
        let w = lr.weights();
        assert!((w[0] - 3.0).abs() < 1e-6, "{w:?}");
        assert!((w[1] + 2.0).abs() < 1e-6);
        assert!((w[2] - 0.5).abs() < 1e-6);
        assert!((lr.predict(&[0.2, -0.3]) - (3.0 * 0.2 + 2.0 * 0.3 + 0.5)).abs() < 1e-9);
    }

    #[test]
    fn ridge_shrinks_weights() {
        let mut rng = Rng::new(1);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..50 {
            let a = rng.gen_f64(-1.0, 1.0);
            x.push(vec![a]);
            y.push(5.0 * a);
        }
        let mut loose = LinearRegression::new(1e-9);
        let mut tight = LinearRegression::new(100.0);
        loose.fit(&x, &y);
        tight.fit(&x, &y);
        assert!(tight.weights()[0].abs() < loose.weights()[0].abs());
    }

    #[test]
    fn solve_identity() {
        let a = vec![vec![1.0, 0.0], vec![0.0, 1.0]];
        let b = vec![2.0, -3.0];
        assert_eq!(solve(a, b), vec![2.0, -3.0]);
    }

    #[test]
    fn solve_requires_pivoting() {
        // leading zero forces a row swap
        let a = vec![vec![0.0, 1.0], vec![1.0, 0.0]];
        let b = vec![5.0, 7.0];
        let x = solve(a, b);
        assert!((x[0] - 7.0).abs() < 1e-12 && (x[1] - 5.0).abs() < 1e-12);
    }
}
