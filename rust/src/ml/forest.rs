//! Random forest regression = bagged CART trees + feature subsampling.

use super::tree::{RegressionTree, TreeParams};
use super::Regressor;
use crate::rng::Rng;

/// Forest hyper-parameters ("standard random forest regression", §4.1).
#[derive(Clone, Debug)]
pub struct RandomForestParams {
    /// Number of bagged trees.
    pub n_trees: usize,
    /// Per-tree hyper-parameters.
    pub tree: TreeParams,
    /// Features per split as a fraction of d (sqrt-rule applied if None).
    pub max_features_frac: Option<f64>,
    /// Bootstrap/feature-subsampling seed.
    pub seed: u64,
}

impl Default for RandomForestParams {
    fn default() -> Self {
        RandomForestParams {
            n_trees: 50,
            tree: TreeParams {
                max_depth: 12,
                min_samples_leaf: 2,
                min_samples_split: 4,
                max_features: None,
            },
            max_features_frac: None,
            seed: 0x0F0E,
        }
    }
}

/// A fitted random forest.
#[derive(Clone)]
pub struct RandomForest {
    /// Hyper-parameters the forest was built with.
    pub params: RandomForestParams,
    trees: Vec<RegressionTree>,
}

impl RandomForest {
    /// An unfitted forest with the given hyper-parameters.
    pub fn new(params: RandomForestParams) -> Self {
        RandomForest { params, trees: Vec::new() }
    }

    /// Number of fitted trees (0 before `fit`).
    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }
}

impl Regressor for RandomForest {
    fn fit(&mut self, x: &[Vec<f64>], y: &[f64]) {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty(), "empty training set");
        let d = x[0].len();
        let max_features = match self.params.max_features_frac {
            Some(frac) => ((d as f64 * frac).ceil() as usize).clamp(1, d),
            None => ((d as f64).sqrt().ceil() as usize).clamp(1, d),
        };
        let mut rng = Rng::new(self.params.seed);
        self.trees = (0..self.params.n_trees)
            .map(|t| {
                let mut tree_rng = rng.split(t as u64);
                // bootstrap sample (with replacement)
                let idx: Vec<usize> =
                    (0..x.len()).map(|_| tree_rng.gen_range(0, x.len())).collect();
                let mut tree = RegressionTree::new(TreeParams {
                    max_features: Some(max_features),
                    ..self.params.tree.clone()
                });
                tree.fit_indices(x, y, &idx, &mut tree_rng);
                tree
            })
            .collect();
    }

    fn predict(&self, row: &[f64]) -> f64 {
        assert!(!self.trees.is_empty(), "predict before fit");
        self.trees.iter().map(|t| t.predict(row)).sum::<f64>() / self.trees.len() as f64
    }

    fn is_fitted(&self) -> bool {
        !self.trees.is_empty()
    }

    fn clone_box(&self) -> Box<dyn Regressor> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::mse;
    use crate::rng::Rng;

    fn quadratic(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let a = rng.gen_f64(-2.0, 2.0);
            let b = rng.gen_f64(-2.0, 2.0);
            x.push(vec![a, b]);
            y.push(a * a - b + 0.05 * rng.next_normal());
        }
        (x, y)
    }

    #[test]
    fn learns_nonlinear_function() {
        let (x, y) = quadratic(600, 1);
        let (xt, yt) = quadratic(100, 2);
        let mut rf = RandomForest::new(RandomForestParams::default());
        rf.fit(&x, &y);
        let mean = y.iter().sum::<f64>() / y.len() as f64;
        let var = yt.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / yt.len() as f64;
        let err = mse(&rf, &xt, &yt);
        assert!(err < var * 0.25, "test mse={err} baseline var={var}");
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, y) = quadratic(200, 3);
        let mut a = RandomForest::new(RandomForestParams::default());
        let mut b = RandomForest::new(RandomForestParams::default());
        a.fit(&x, &y);
        b.fit(&x, &y);
        for row in x.iter().take(20) {
            assert_eq!(a.predict(row), b.predict(row));
        }
    }

    #[test]
    fn more_trees_reduce_variance() {
        let (x, y) = quadratic(300, 4);
        let (xt, yt) = quadratic(100, 5);
        let mut small = RandomForest::new(RandomForestParams {
            n_trees: 2,
            seed: 9,
            ..Default::default()
        });
        let mut large = RandomForest::new(RandomForestParams {
            n_trees: 80,
            seed: 9,
            ..Default::default()
        });
        small.fit(&x, &y);
        large.fit(&x, &y);
        assert!(mse(&large, &xt, &yt) <= mse(&small, &xt, &yt) * 1.2);
    }

    #[test]
    fn is_fitted_transitions() {
        let mut rf = RandomForest::new(RandomForestParams::default());
        assert!(!rf.is_fitted());
        let (x, y) = quadratic(50, 6);
        rf.fit(&x, &y);
        assert!(rf.is_fitted());
        assert_eq!(rf.n_trees(), rf.params.n_trees);
    }
}
