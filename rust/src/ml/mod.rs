//! Regression substrate for the utility function û (paper §3.2).
//!
//! The paper uses "a standard random forest regression" to learn
//! Δf = û(s, T). No ML crates exist in the offline vendor set, so this
//! module implements CART regression trees with bootstrap aggregation and
//! per-split feature subsampling from scratch, plus an ordinary
//! least-squares baseline used in the scheduler-ablation bench.

pub mod forest;
pub mod linreg;
pub mod tree;

pub use forest::{RandomForest, RandomForestParams};
pub use linreg::LinearRegression;
pub use tree::{RegressionTree, TreeParams};

/// Common trait so the FedSpace scheduler can swap regressors (ablation).
pub trait Regressor: Send + Sync {
    /// Fit on rows `x` (n × d, row-major) with targets `y` (n).
    fn fit(&mut self, x: &[Vec<f64>], y: &[f64]);
    /// Predict one row.
    fn predict(&self, row: &[f64]) -> f64;
    /// Has `fit` been called with non-empty data?
    fn is_fitted(&self) -> bool;
    /// Clone behind the trait object — lets a fitted û be shared across
    /// per-gateway planners (ADR-0006) without refitting.
    fn clone_box(&self) -> Box<dyn Regressor>;
}

/// Mean squared error of a fitted regressor over a dataset.
pub fn mse(model: &dyn Regressor, x: &[Vec<f64>], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    if x.is_empty() {
        return 0.0;
    }
    x.iter()
        .zip(y.iter())
        .map(|(row, &t)| {
            let p = model.predict(row);
            (p - t) * (p - t)
        })
        .sum::<f64>()
        / x.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Shared smoke dataset: y = 2*x0 - x1 + noise.
    pub fn linearish(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let a = rng.gen_f64(-1.0, 1.0);
            let b = rng.gen_f64(-1.0, 1.0);
            x.push(vec![a, b]);
            y.push(2.0 * a - b + 0.01 * rng.next_normal());
        }
        (x, y)
    }

    #[test]
    fn forest_beats_constant_predictor() {
        let (x, y) = linearish(400, 0);
        let mean = y.iter().sum::<f64>() / y.len() as f64;
        let var = y.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / y.len() as f64;
        let mut rf = RandomForest::new(RandomForestParams::default());
        rf.fit(&x, &y);
        let err = mse(&rf, &x, &y);
        assert!(err < var * 0.3, "mse={err} var={var}");
    }

    #[test]
    fn mse_zero_for_perfect_model() {
        struct Exact;
        impl Regressor for Exact {
            fn fit(&mut self, _: &[Vec<f64>], _: &[f64]) {}
            fn predict(&self, row: &[f64]) -> f64 {
                row[0]
            }
            fn is_fitted(&self) -> bool {
                true
            }
            fn clone_box(&self) -> Box<dyn Regressor> {
                Box::new(Exact)
            }
        }
        let x = vec![vec![1.0], vec![2.0]];
        let y = vec![1.0, 2.0];
        assert_eq!(mse(&Exact, &x, &y), 0.0);
    }
}
