//! Config system: a minimal TOML-subset parser (no `serde`/`toml` in the
//! offline vendor set), the typed experiment configuration the launcher
//! consumes, and the named scenario registry (`scenarios` CLI subcommand).

pub mod experiment;
pub mod scenario;
pub mod section;
pub mod toml;

pub use experiment::{AlgorithmKind, DataDist, EngineMode, ExperimentConfig};
pub use section::{apply_section, emit_section, validate_section, SectionCtx, SectionSpec};
pub use scenario::{ConstellationSpec, IslMode, IslSpec, Scenario, ShellSpec, StationNetwork};
pub use toml::{parse_toml, TomlValue};
