//! Config system: a minimal TOML-subset parser (no `serde`/`toml` in the
//! offline vendor set) plus the typed experiment configuration the launcher
//! consumes.

pub mod experiment;
pub mod toml;

pub use experiment::{AlgorithmKind, DataDist, ExperimentConfig};
pub use toml::{parse_toml, TomlValue};
