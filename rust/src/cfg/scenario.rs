//! The scenario registry and constellation zoo (ADR-0003).
//!
//! A [`Scenario`] bundles everything one reproducible experiment needs —
//! constellation spec, ground-station network, link model, duration,
//! algorithm grid, engine mode and scheduled satellite outages — behind a
//! name and a TOML round-trip. The built-ins cover the paper's §4.1 fleet
//! (`paper-fig7`) plus shapes the paper never ran: a Starlink-shell-1
//! Walker delta (Elmahallawy & Luo 2023, arXiv:2302.13447), the sparse
//! single-ground-station regime of Razmi et al. 2021 (arXiv:2109.01348),
//! an Iridium-like polar Walker star, and a Dove fleet with mid-run
//! satellite failures.
//!
//! Every scenario is runnable from the CLI: `fedspace scenarios run <name>`
//! (see `app::cmd`), and `Scenario::from_toml_text(&sc.to_toml())` is the
//! identity (tested per built-in).

use super::experiment::{AlgorithmKind, DataDist, EngineMode, ExperimentConfig};
use super::section::{apply_section, emit_section, validate_section, SectionCtx, SectionSpec};
use super::toml::{parse_toml, TomlDoc, TomlValue};
use crate::connectivity::{
    ConnectivityParams, ConnectivitySchedule, ConnectivityStream, ContactGraph, IslParams,
    IslTopology,
};
use crate::fl::{
    CodecKind, FederationSpec, LinkSpec, ReconcilePolicy, RobustKind, RobustSpec, ServeSpec,
    UploadRouting,
};
use crate::orbit::{
    planet_ground_stations, planet_labs_like, Constellation, DowntimeWindow, GroundStation,
    PlaneId, WalkerPattern, WalkerSpec,
};
use crate::sim::{AttackKind, AttackSpec, EventSpec};
use anyhow::{bail, Context, Result};

/// One Walker-delta shell of a multi-shell constellation (mega-fleet
/// specs: Starlink Gen1 and Kuiper file multiple shells at different
/// altitudes/inclinations).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShellSpec {
    /// t — satellites in this shell (divisible by `planes`).
    pub n_sats: usize,
    /// p — orbital planes.
    pub planes: usize,
    /// f — inter-plane phasing.
    pub phasing: usize,
    /// Shell altitude [km] (TOML-friendly unit).
    pub alt_km: f64,
    /// Inclination [deg].
    pub inc_deg: f64,
}

/// How a scenario's constellation is generated.
#[derive(Clone, Debug, PartialEq)]
pub enum ConstellationSpec {
    /// The paper's §4.1 fleet shape: SSO + ISS Dove flocks with jitter.
    PlanetLabsLike {
        /// Fleet size K.
        n_sats: usize,
        /// Jitter seed (the fleet drifts deterministically per seed).
        seed: u64,
    },
    /// An exact Walker `i:t/p/f` shell.
    Walker {
        /// Delta (360° RAAN spread) or star (180°).
        pattern: WalkerPattern,
        /// t — total satellites (divisible by `planes`).
        n_sats: usize,
        /// p — orbital planes.
        planes: usize,
        /// f — inter-plane phasing.
        phasing: usize,
        /// Shell altitude [km] (TOML-friendly unit).
        alt_km: f64,
        /// Inclination [deg].
        inc_deg: f64,
    },
    /// A stack of Walker-delta shells (satellite ids are assigned shell by
    /// shell, in order) — the real filing shapes of Starlink/Kuiper-class
    /// systems.
    Shells {
        /// The shells, in id-assignment order.
        shells: Vec<ShellSpec>,
    },
}

impl ConstellationSpec {
    /// Number of satellites the spec produces.
    pub fn n_sats(&self) -> usize {
        match self {
            ConstellationSpec::PlanetLabsLike { n_sats, .. } => *n_sats,
            ConstellationSpec::Walker { n_sats, .. } => *n_sats,
            ConstellationSpec::Shells { shells } => shells.iter().map(|s| s.n_sats).sum(),
        }
    }

    /// TOML `kind` spelling (`planet-labs`, `walker-delta`, `walker-star`,
    /// `walker-shells`).
    pub fn kind_name(&self) -> &'static str {
        match self {
            ConstellationSpec::PlanetLabsLike { .. } => "planet-labs",
            ConstellationSpec::Walker { pattern: WalkerPattern::Delta, .. } => "walker-delta",
            ConstellationSpec::Walker { pattern: WalkerPattern::Star, .. } => "walker-star",
            ConstellationSpec::Shells { .. } => "walker-shells",
        }
    }

    /// Materialize the orbits.
    pub fn build(&self) -> Constellation {
        match self {
            ConstellationSpec::PlanetLabsLike { n_sats, seed } => planet_labs_like(*n_sats, *seed),
            ConstellationSpec::Walker { pattern, n_sats, planes, phasing, alt_km, inc_deg } => {
                Constellation::walker(&WalkerSpec {
                    pattern: *pattern,
                    n_sats: *n_sats,
                    planes: *planes,
                    phasing: *phasing,
                    alt_m: alt_km * 1e3,
                    inc_deg: *inc_deg,
                })
            }
            ConstellationSpec::Shells { shells } => {
                let mut orbits = Vec::with_capacity(self.n_sats());
                let mut plane_ids = Vec::with_capacity(self.n_sats());
                for (group, sh) in shells.iter().enumerate() {
                    let sub = Constellation::walker(&WalkerSpec {
                        pattern: WalkerPattern::Delta,
                        n_sats: sh.n_sats,
                        planes: sh.planes,
                        phasing: sh.phasing,
                        alt_m: sh.alt_km * 1e3,
                        inc_deg: sh.inc_deg,
                    });
                    // each shell is its own ISL group: links never cross
                    // shells (different altitudes)
                    plane_ids
                        .extend(sub.plane_ids.iter().map(|p| PlaneId { group, plane: p.plane }));
                    orbits.extend(sub.orbits);
                }
                Constellation { orbits, downtime: Vec::new(), plane_ids }
            }
        }
    }
}

/// Which inter-satellite links a scenario's constellation maintains
/// (ADR-0005).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum IslMode {
    /// No ISLs: connectivity stays satellite⇄station only (the paper's
    /// model, and this repo's model up to PR 3).
    #[default]
    Off,
    /// Permanent intra-plane ring links only (each satellite ⇄ its two
    /// in-plane neighbors).
    IntraPlane,
    /// Intra-plane rings plus range-gated links to satellites in adjacent
    /// planes of the same shell (the "+grid" LEO network model).
    IntraCross,
}

impl IslMode {
    /// Parse the TOML/CLI spelling.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "off" | "none" => IslMode::Off,
            "intra-plane" | "intra_plane" | "intra" | "ring" => IslMode::IntraPlane,
            "intra-cross" | "intra_cross" | "intra+cross" | "grid" => IslMode::IntraCross,
            other => bail!("unknown ISL mode {other:?} (off | intra-plane | intra-cross)"),
        })
    }

    /// Canonical lowercase name (inverse of [`Self::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            IslMode::Off => "off",
            IslMode::IntraPlane => "intra-plane",
            IslMode::IntraCross => "intra-cross",
        }
    }
}

/// Inter-satellite-link model of a scenario (ADR-0005): which links exist,
/// how far routing may relay, and what each hop costs in slots. With
/// `mode = Off` every other field is inert and the scenario behaves —
/// bit for bit — like the pre-ISL engine (asserted in tests).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct IslSpec {
    /// Which link families the constellation maintains.
    pub mode: IslMode,
    /// Maximum relay hops from a satellite to its ground-visible sink.
    pub max_hops: usize,
    /// Cross-plane links switch on only within this slant range [km]
    /// (ignored in `IntraPlane` mode).
    pub max_range_km: f64,
    /// Relay latency charged per hop, in engine slots, on both the upload
    /// and the broadcast leg. 0 models ISL forwarding as fast relative to
    /// T0 (ms-scale links vs a 15-min slot); raise it for store-and-forward
    /// regimes where a hop costs a scheduling slot.
    pub hop_delay_slots: usize,
}

impl Default for IslSpec {
    fn default() -> Self {
        IslSpec { mode: IslMode::Off, max_hops: 3, max_range_km: 4000.0, hop_delay_slots: 0 }
    }
}

impl IslSpec {
    /// Does this spec enable any inter-satellite links?
    pub fn enabled(&self) -> bool {
        self.mode != IslMode::Off
    }

    /// Reject self-inconsistent ISL specs against an `n_steps` horizon —
    /// shared by `Scenario::validate` and `ExperimentConfig::validate` so
    /// the two config surfaces can never drift on the bounds.
    pub fn validate(&self, n_steps: usize) -> Result<()> {
        if !self.enabled() {
            return Ok(());
        }
        if self.max_hops == 0 {
            bail!("ISLs need max_hops >= 1");
        }
        if self.max_hops > u8::MAX as usize {
            bail!("isl max_hops {} exceeds the u8 hop counter", self.max_hops);
        }
        // the worst-case relay charge must stay within the horizon: a
        // longer delay can never deliver anything, and an unbounded
        // value would wrap the engine's delay arithmetic in release
        match self.max_hops.checked_mul(self.hop_delay_slots) {
            Some(worst) if worst <= n_steps => {}
            _ => bail!(
                "isl max_hops x hop_delay_slots ({} x {}) exceeds the {}-step horizon",
                self.max_hops,
                self.hop_delay_slots,
                n_steps
            ),
        }
        if self.mode == IslMode::IntraCross && self.max_range_km <= 0.0 {
            bail!("cross-plane ISLs need a positive max_range_km");
        }
        Ok(())
    }

    /// Parse the `[isl]` TOML section (defaults fill missing keys);
    /// `Ok(None)` when the section is absent — shared by the scenario and
    /// experiment config parsers.
    pub fn from_doc(doc: &TomlDoc) -> Result<Option<IslSpec>> {
        if doc.get("isl").is_none() {
            return Ok(None);
        }
        let get = |key: &str| doc.get("isl").and_then(|s| s.get(key));
        let mut spec = IslSpec::default();
        if let Some(v) = get("mode") {
            spec.mode = IslMode::parse(v.as_str().context("[isl] mode must be a string")?)?;
        }
        if let Some(v) = get("max_hops") {
            spec.max_hops =
                usize::try_from(v.as_int().context("[isl] max_hops must be an integer")?)?;
        }
        if let Some(v) = get("max_range_km") {
            spec.max_range_km = v.as_float().context("[isl] max_range_km must be a number")?;
        }
        if let Some(v) = get("hop_delay_slots") {
            spec.hop_delay_slots = usize::try_from(
                v.as_int().context("[isl] hop_delay_slots must be an integer")?,
            )?;
        }
        Ok(Some(spec))
    }

    /// Emit the `[isl]` TOML section (callers skip it when disabled so
    /// pre-ISL specs stay byte-identical).
    pub fn emit_toml(&self, out: &mut String) {
        use std::fmt::Write as _;
        let _ = writeln!(out, "\n[isl]");
        let _ = writeln!(out, "mode = \"{}\"", self.mode.name());
        let _ = writeln!(out, "max_hops = {}", self.max_hops);
        let _ = writeln!(out, "max_range_km = {}", self.max_range_km);
        let _ = writeln!(out, "hop_delay_slots = {}", self.hop_delay_slots);
    }

    /// The connectivity-layer routing parameters of this spec.
    pub fn params(&self, t0_s: f64) -> IslParams {
        IslParams {
            max_hops: self.max_hops,
            hop_delay_slots: self.hop_delay_slots,
            cross_plane: self.mode == IslMode::IntraCross,
            max_range_m: self.max_range_km * 1e3,
            t0_s,
        }
    }
}

impl SectionSpec for IslSpec {
    const SECTION: &'static str = "isl";

    fn from_doc(doc: &TomlDoc) -> Result<Option<Self>> {
        IslSpec::from_doc(doc)
    }

    fn emit_toml(&self, out: &mut String) {
        IslSpec::emit_toml(self, out)
    }

    fn is_emitted(&self) -> bool {
        self.enabled()
    }

    fn validate(&self, ctx: &SectionCtx) -> Result<()> {
        IslSpec::validate(self, ctx.n_steps)
    }
}

/// Named ground-station network a scenario links against.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StationNetwork {
    /// The paper's 12-station commercial network (§4.1).
    Planet12,
    /// A single polar station — the sparse regime of Razmi et al. 2021.
    SingleSvalbard,
    /// The four polar sites only (every SSO orbit sees them, ISS never).
    Polar4,
}

impl StationNetwork {
    /// Parse the TOML spelling.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "planet12" => StationNetwork::Planet12,
            "single-svalbard" | "single_svalbard" => StationNetwork::SingleSvalbard,
            "polar4" => StationNetwork::Polar4,
            other => bail!("unknown station network {other:?}"),
        })
    }

    /// Canonical lowercase name (inverse of [`Self::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            StationNetwork::Planet12 => "planet12",
            StationNetwork::SingleSvalbard => "single-svalbard",
            StationNetwork::Polar4 => "polar4",
        }
    }

    /// Materialize the station list.
    pub fn build(&self) -> Vec<GroundStation> {
        let all = planet_ground_stations();
        match self {
            StationNetwork::Planet12 => all,
            StationNetwork::SingleSvalbard => {
                all.into_iter().filter(|g| g.name == "svalbard").collect()
            }
            StationNetwork::Polar4 => {
                const POLAR: [&str; 4] = ["svalbard", "inuvik", "fairbanks", "troll_antarctica"];
                all.into_iter().filter(|g| POLAR.contains(&g.name.as_str())).collect()
            }
        }
    }
}

/// One named, fully-specified experiment setup.
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    /// Registry key (kebab-case).
    pub name: String,
    /// One-line description shown by `scenarios list`.
    pub summary: String,
    /// Constellation generator.
    pub constellation: ConstellationSpec,
    /// Ground-station network.
    pub stations: StationNetwork,
    /// Wall-clock seconds per time index T0.
    pub t0_s: f64,
    /// Simulated time indexes.
    pub n_steps: usize,
    /// Minimum elevation angle α_min [deg].
    pub min_elev_deg: f64,
    /// Algorithm grid `scenarios run` sweeps (ablation in one command).
    pub algorithms: Vec<AlgorithmKind>,
    /// FedBuff's M for grid entries that use it.
    pub fedbuff_m: usize,
    /// Data distribution for the mock/PJRT trainer.
    pub dist: DataDist,
    /// Dense per-step loop, sparse contact-list event loop, or the chunked
    /// streamed walk.
    pub engine_mode: EngineMode,
    /// Steps per connectivity chunk in streamed mode (ignored otherwise).
    pub chunk_len: usize,
    /// Scheduled per-satellite outages (deterministic, planner-visible).
    pub downtime: Vec<DowntimeWindow>,
    /// Inter-satellite-link model (ADR-0005); `IslMode::Off` by default.
    pub isl: IslSpec,
    /// Gateway federation (ADR-0006): station → gateway assignment and the
    /// cross-gateway reconcile policy. The default single central gateway
    /// reproduces the pre-federation engine bit for bit.
    pub federation: FederationSpec,
    /// Adversary / link-fault injection (ADR-0007). The default disabled
    /// spec builds no injector and consumes no adversary randomness, so
    /// attack-free runs stay bit-identical to the pre-robustness engine.
    pub attack: AttackSpec,
    /// Server-side robust aggregation (ADR-0007). The default
    /// [`RobustKind::Mean`] is the plain Eq.-4 [`crate::fl::CpuAggregator`],
    /// bit for bit.
    pub robust: RobustSpec,
    /// Link byte budget + upload codec (ADR-0008). The default disabled
    /// spec builds no codec, tracks no pass durations, and keeps the run
    /// bit-identical to the pre-link engine.
    pub link: LinkSpec,
    /// Run-event recording (ADR-0009). Off by default: the event stream is
    /// still how the trace is derived, but nothing is kept in memory.
    pub events: EventSpec,
    /// Serving front-end resource shape (ADR-0010): per-gateway ingestion
    /// queue capacity, drain batch size, validation shards. Only the
    /// `serve`/`loadgen` drivers read it; sim runs ignore it entirely.
    pub serve: ServeSpec,
}

impl Default for Scenario {
    fn default() -> Self {
        Scenario {
            name: String::new(),
            summary: String::new(),
            constellation: ConstellationSpec::PlanetLabsLike { n_sats: 191, seed: 0 },
            stations: StationNetwork::Planet12,
            t0_s: 15.0 * 60.0,
            n_steps: 480,
            min_elev_deg: 25.0,
            algorithms: vec![AlgorithmKind::FedSpace],
            fedbuff_m: 96,
            dist: DataDist::Iid,
            engine_mode: EngineMode::Dense,
            chunk_len: ConnectivityStream::DEFAULT_CHUNK_LEN,
            downtime: Vec::new(),
            isl: IslSpec::default(),
            federation: FederationSpec::single(),
            attack: AttackSpec::default(),
            robust: RobustSpec::default(),
            link: LinkSpec::default(),
            events: EventSpec::default(),
            serve: ServeSpec::default(),
        }
    }
}

impl Scenario {
    /// Reject self-inconsistent scenarios.
    pub fn validate(&self) -> Result<()> {
        if self.name.is_empty() {
            bail!("scenario needs a name");
        }
        if self.n_steps == 0 {
            bail!("n_steps must be > 0");
        }
        if self.t0_s <= 0.0 {
            bail!("t0_s must be positive");
        }
        if self.algorithms.is_empty() {
            bail!("algorithm grid is empty");
        }
        if self.fedbuff_m == 0 {
            bail!("fedbuff_m must be > 0");
        }
        if self.constellation.n_sats() == 0 {
            bail!("constellation has no satellites");
        }
        if self.chunk_len == 0 {
            bail!("chunk_len must be > 0");
        }
        match &self.constellation {
            ConstellationSpec::Walker { n_sats, planes, .. } => {
                if *planes == 0 || n_sats % planes != 0 {
                    bail!("walker: {n_sats} satellites not divisible into {planes} planes");
                }
            }
            ConstellationSpec::Shells { shells } => {
                if shells.is_empty() {
                    bail!("walker-shells needs at least one shell");
                }
                for (idx, sh) in shells.iter().enumerate() {
                    if sh.n_sats == 0 {
                        bail!("shell {idx} has no satellites");
                    }
                    if sh.planes == 0 || sh.n_sats % sh.planes != 0 {
                        bail!(
                            "shell {idx}: {} satellites not divisible into {} planes",
                            sh.n_sats,
                            sh.planes
                        );
                    }
                }
            }
            ConstellationSpec::PlanetLabsLike { .. } => {}
        }
        let k = self.constellation.n_sats();
        for w in &self.downtime {
            if w.sat >= k {
                bail!("downtime names satellite {} but the fleet has {k}", w.sat);
            }
            if w.from_step >= w.until_step {
                bail!("empty downtime window for satellite {}", w.sat);
            }
        }
        // every TOML section validates through the one SectionSpec surface,
        // so the scenario and experiment-config parsers share bounds
        let ctx = SectionCtx {
            n_steps: self.n_steps,
            n_sats: self.constellation.n_sats(),
            n_stations: Some(self.stations.build().len()),
        };
        validate_section(&self.isl, &ctx)?;
        validate_section(&self.federation, &ctx)?;
        validate_section(&self.attack, &ctx)?;
        validate_section(&self.robust, &ctx)?;
        validate_section(&self.link, &ctx)?;
        validate_section(&self.events, &ctx)?;
        validate_section(&self.serve, &ctx)?;
        if self.link.capacity_enabled() && self.isl.enabled() {
            bail!(
                "[link] byte budgets and [isl] routing are mutually exclusive: a relayed \
                 contact has no single pass duration to budget against"
            );
        }
        Ok(())
    }

    /// Names of the built-in scenarios, in catalog order.
    pub fn builtin_names() -> &'static [&'static str] {
        &[
            "paper-fig7",
            "walker-starlink-1584",
            "sparse-single-gs",
            "polar-iridium-66",
            "dove-dropout",
            "walker-starlink-4408",
            "kuiper-3236",
            "isl-iridium-66",
            "isl-starlink-1584",
            "fedspace-multi-gs",
            "byz-iridium-66",
            "byz-multi-gs",
            "compress-starlink-1584",
        ]
    }

    /// Look up one built-in scenario by name.
    pub fn builtin(name: &str) -> Option<Scenario> {
        /// Shorthand for the mega-fleet shell tables below.
        fn shell(
            n_sats: usize,
            planes: usize,
            phasing: usize,
            alt_km: f64,
            inc_deg: f64,
        ) -> ShellSpec {
            ShellSpec { n_sats, planes, phasing, alt_km, inc_deg }
        }
        let sc = match name {
            "paper-fig7" => Scenario {
                name: "paper-fig7".into(),
                summary: "the paper's §4.1 setup: 191 Doves, 12 stations, 5 days, \
                          full algorithm grid (Figure 7 data)"
                    .into(),
                algorithms: vec![
                    AlgorithmKind::Sync,
                    AlgorithmKind::Async,
                    AlgorithmKind::FedBuff,
                    AlgorithmKind::FedSpace,
                ],
                ..Default::default()
            },
            "walker-starlink-1584" => Scenario {
                name: "walker-starlink-1584".into(),
                summary: "Starlink shell 1 (Walker delta 53deg: 1584/72/17 at 550 km), \
                          1 day, contact-list engine (arXiv:2302.13447 regime)"
                    .into(),
                constellation: ConstellationSpec::Walker {
                    pattern: WalkerPattern::Delta,
                    n_sats: 1584,
                    planes: 72,
                    phasing: 17,
                    alt_km: 550.0,
                    inc_deg: 53.0,
                },
                n_steps: 96,
                algorithms: vec![AlgorithmKind::Async, AlgorithmKind::FedBuff],
                engine_mode: EngineMode::ContactList,
                ..Default::default()
            },
            "sparse-single-gs" => Scenario {
                name: "sparse-single-gs".into(),
                summary: "40-satellite Walker delta 80deg vs a single polar station \
                          (arXiv:2109.01348 regime), contact-list engine"
                    .into(),
                constellation: ConstellationSpec::Walker {
                    pattern: WalkerPattern::Delta,
                    n_sats: 40,
                    planes: 5,
                    phasing: 1,
                    alt_km: 600.0,
                    inc_deg: 80.0,
                },
                stations: StationNetwork::SingleSvalbard,
                algorithms: vec![AlgorithmKind::Async, AlgorithmKind::FedBuff],
                fedbuff_m: 8,
                engine_mode: EngineMode::ContactList,
                ..Default::default()
            },
            "polar-iridium-66" => Scenario {
                name: "polar-iridium-66".into(),
                summary: "Iridium-like Walker star (66/6/2 at 780 km, 86.4deg) over the \
                          four polar stations"
                    .into(),
                constellation: ConstellationSpec::Walker {
                    pattern: WalkerPattern::Star,
                    n_sats: 66,
                    planes: 6,
                    phasing: 2,
                    alt_km: 780.0,
                    inc_deg: 86.4,
                },
                stations: StationNetwork::Polar4,
                algorithms: vec![
                    AlgorithmKind::Sync,
                    AlgorithmKind::FedBuff,
                    AlgorithmKind::FedSpace,
                ],
                fedbuff_m: 16,
                ..Default::default()
            },
            "walker-starlink-4408" => Scenario {
                name: "walker-starlink-4408".into(),
                summary: "Starlink Gen1 as filed: 5 Walker-delta shells, 4408 satellites, \
                          2 days — only feasible in the streamed engine"
                    .into(),
                constellation: ConstellationSpec::Shells {
                    shells: vec![
                        shell(1584, 72, 17, 550.0, 53.0),
                        shell(1584, 72, 17, 540.0, 53.2),
                        shell(720, 36, 11, 570.0, 70.0),
                        shell(348, 6, 5, 560.0, 97.6),
                        shell(172, 4, 3, 560.0, 97.6),
                    ],
                },
                n_steps: 192,
                algorithms: vec![AlgorithmKind::Async, AlgorithmKind::FedBuff],
                engine_mode: EngineMode::Streamed,
                ..Default::default()
            },
            "kuiper-3236" => Scenario {
                name: "kuiper-3236".into(),
                summary: "Project Kuiper as filed: 3 Walker-delta shells, 3236 satellites, \
                          2 days — only feasible in the streamed engine"
                    .into(),
                constellation: ConstellationSpec::Shells {
                    shells: vec![
                        shell(1156, 34, 7, 630.0, 51.9),
                        shell(1296, 36, 9, 610.0, 42.0),
                        shell(784, 28, 5, 590.0, 33.0),
                    ],
                },
                n_steps: 192,
                algorithms: vec![AlgorithmKind::FedBuff],
                engine_mode: EngineMode::Streamed,
                ..Default::default()
            },
            "isl-iridium-66" => Scenario {
                name: "isl-iridium-66".into(),
                summary: "the Iridium shell with +grid ISLs (intra-plane rings + range-gated \
                          cross-plane links): non-visible satellites relay through a \
                          ground-visible sink, full algorithm grid (Matthiesen et al. / \
                          Elmahallawy & Luo regime)"
                    .into(),
                constellation: ConstellationSpec::Walker {
                    pattern: WalkerPattern::Star,
                    n_sats: 66,
                    planes: 6,
                    phasing: 2,
                    alt_km: 780.0,
                    inc_deg: 86.4,
                },
                stations: StationNetwork::Polar4,
                algorithms: vec![
                    AlgorithmKind::Sync,
                    AlgorithmKind::Async,
                    AlgorithmKind::FedBuff,
                    AlgorithmKind::FedSpace,
                ],
                fedbuff_m: 16,
                engine_mode: EngineMode::Streamed,
                isl: IslSpec {
                    mode: IslMode::IntraCross,
                    max_hops: 3,
                    max_range_km: 4000.0,
                    hop_delay_slots: 0,
                },
                ..Default::default()
            },
            "isl-starlink-1584" => Scenario {
                name: "isl-starlink-1584".into(),
                summary: "Starlink shell 1 with intra-plane ring ISLs: the 1584-satellite \
                          Walker delta where every plane ships updates through its visible \
                          members, 1 day, streamed engine"
                    .into(),
                constellation: ConstellationSpec::Walker {
                    pattern: WalkerPattern::Delta,
                    n_sats: 1584,
                    planes: 72,
                    phasing: 17,
                    alt_km: 550.0,
                    inc_deg: 53.0,
                },
                n_steps: 96,
                algorithms: vec![AlgorithmKind::Async, AlgorithmKind::FedBuff],
                engine_mode: EngineMode::Streamed,
                isl: IslSpec {
                    mode: IslMode::IntraPlane,
                    max_hops: 4,
                    hop_delay_slots: 0,
                    ..Default::default()
                },
                ..Default::default()
            },
            "fedspace-multi-gs" => Scenario {
                name: "fedspace-multi-gs".into(),
                summary: "the Iridium polar shell over polar4 split into two gateway \
                          networks (arctic: svalbard+inuvik+fairbanks, antarctic: troll) \
                          with periodic cross-gateway reconciliation — full four-algorithm \
                          grid (ADR-0006; Razmi et al. / Matthiesen et al. regime)"
                    .into(),
                constellation: ConstellationSpec::Walker {
                    pattern: WalkerPattern::Star,
                    n_sats: 66,
                    planes: 6,
                    phasing: 2,
                    alt_km: 780.0,
                    inc_deg: 86.4,
                },
                stations: StationNetwork::Polar4,
                algorithms: vec![
                    AlgorithmKind::Sync,
                    AlgorithmKind::Async,
                    AlgorithmKind::FedBuff,
                    AlgorithmKind::FedSpace,
                ],
                fedbuff_m: 16,
                federation: FederationSpec::split(
                    &["arctic", "antarctic"],
                    // polar4 build order: svalbard, inuvik, fairbanks, troll
                    &[0, 0, 0, 1],
                    ReconcilePolicy::Periodic { every: 24 },
                ),
                ..Default::default()
            },
            "byz-iridium-66" => Scenario {
                name: "byz-iridium-66".into(),
                summary: "the Iridium polar shell with 10% scaled-gradient Byzantine \
                          satellites, defended by trimmed-mean aggregation — full \
                          four-algorithm grid (ADR-0007)"
                    .into(),
                constellation: ConstellationSpec::Walker {
                    pattern: WalkerPattern::Star,
                    n_sats: 66,
                    planes: 6,
                    phasing: 2,
                    alt_km: 780.0,
                    inc_deg: 86.4,
                },
                stations: StationNetwork::Polar4,
                algorithms: vec![
                    AlgorithmKind::Sync,
                    AlgorithmKind::Async,
                    AlgorithmKind::FedBuff,
                    AlgorithmKind::FedSpace,
                ],
                fedbuff_m: 16,
                attack: AttackSpec {
                    kind: AttackKind::ScaledGrad,
                    fraction: 0.1,
                    scale: -20.0,
                    ..Default::default()
                },
                robust: RobustSpec {
                    aggregator: RobustKind::TrimmedMean,
                    trim: 0.15,
                    ..Default::default()
                },
                ..Default::default()
            },
            "byz-multi-gs" => Scenario {
                name: "byz-multi-gs".into(),
                summary: "fedspace-multi-gs under attack: one full orbital plane turns \
                          Byzantine under the arctic gateway, links drop and corrupt \
                          uploads, and every gateway aggregates with a coordinate-wise \
                          median (ADR-0007)"
                    .into(),
                constellation: ConstellationSpec::Walker {
                    pattern: WalkerPattern::Star,
                    n_sats: 66,
                    planes: 6,
                    phasing: 2,
                    alt_km: 780.0,
                    inc_deg: 86.4,
                },
                stations: StationNetwork::Polar4,
                algorithms: vec![
                    AlgorithmKind::Sync,
                    AlgorithmKind::Async,
                    AlgorithmKind::FedBuff,
                    AlgorithmKind::FedSpace,
                ],
                fedbuff_m: 16,
                federation: FederationSpec::split(
                    &["arctic", "antarctic"],
                    // polar4 build order: svalbard, inuvik, fairbanks, troll
                    &[0, 0, 0, 1],
                    ReconcilePolicy::Periodic { every: 24 },
                ),
                attack: AttackSpec {
                    kind: AttackKind::ScaledGrad,
                    // walker ids are assigned plane by plane: 0..11 is the
                    // whole first plane — adversaries concentrated in one
                    // orbital neighborhood rather than spread fleet-wide
                    sats: (0..11).collect(),
                    scale: -20.0,
                    drop_prob: 0.02,
                    corrupt_prob: 0.01,
                    ..Default::default()
                },
                robust: RobustSpec { aggregator: RobustKind::Median, ..Default::default() },
                ..Default::default()
            },
            "compress-starlink-1584" => Scenario {
                name: "compress-starlink-1584".into(),
                summary: "Starlink shell 1 under a finite downlink: every pass carries \
                          rate x duration bytes, uploads ship top-k 1% sparsified \
                          updates with error feedback, 1 day, streamed engine (ADR-0008)"
                    .into(),
                constellation: ConstellationSpec::Walker {
                    pattern: WalkerPattern::Delta,
                    n_sats: 1584,
                    planes: 72,
                    phasing: 17,
                    alt_km: 550.0,
                    inc_deg: 53.0,
                },
                n_steps: 96,
                algorithms: vec![AlgorithmKind::Async, AlgorithmKind::FedBuff],
                engine_mode: EngineMode::Streamed,
                link: LinkSpec {
                    // ~2 MB per full 15-min slot: short passes defer the
                    // dense fmow payload but carry the top-k one
                    rate_bytes_per_slot: 2_000_000,
                    codec: CodecKind::TopK,
                    topk_frac: 0.01,
                },
                ..Default::default()
            },
            "dove-dropout" => Scenario {
                name: "dove-dropout".into(),
                summary: "paper fleet with mid-run failures: 4 satellites go dark on day 2, \
                          2 recover on day 4 (planner-visible outages)"
                    .into(),
                algorithms: vec![AlgorithmKind::FedBuff, AlgorithmKind::FedSpace],
                downtime: vec![
                    DowntimeWindow { sat: 5, from_step: 192, until_step: 384 },
                    DowntimeWindow { sat: 17, from_step: 192, until_step: 384 },
                    DowntimeWindow { sat: 42, from_step: 192, until_step: 480 },
                    DowntimeWindow { sat: 108, from_step: 240, until_step: 480 },
                ],
                ..Default::default()
            },
            _ => return None,
        };
        debug_assert!(sc.validate().is_ok());
        Some(sc)
    }

    /// All built-in scenarios, in catalog order.
    pub fn builtins() -> Vec<Scenario> {
        Self::builtin_names().iter().map(|n| Self::builtin(n).unwrap()).collect()
    }

    /// Serialize to the TOML subset `from_toml_text` parses; the round trip
    /// is the identity for every built-in (tested).
    pub fn to_toml(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(s, "[scenario]");
        let _ = writeln!(s, "name = \"{}\"", self.name);
        let _ = writeln!(s, "summary = \"{}\"", self.summary);
        let _ = writeln!(s, "engine = \"{}\"", self.engine_mode.name());
        let _ = writeln!(s, "\n[constellation]");
        let _ = writeln!(s, "kind = \"{}\"", self.constellation.kind_name());
        match &self.constellation {
            ConstellationSpec::PlanetLabsLike { n_sats, seed } => {
                let _ = writeln!(s, "n_sats = {n_sats}");
                let _ = writeln!(s, "seed = {seed}");
            }
            ConstellationSpec::Walker { n_sats, planes, phasing, alt_km, inc_deg, .. } => {
                let _ = writeln!(s, "n_sats = {n_sats}");
                let _ = writeln!(s, "planes = {planes}");
                let _ = writeln!(s, "phasing = {phasing}");
                let _ = writeln!(s, "alt_km = {alt_km}");
                let _ = writeln!(s, "inc_deg = {inc_deg}");
            }
            ConstellationSpec::Shells { shells } => {
                let col = |f: &dyn Fn(&ShellSpec) -> String| -> String {
                    shells.iter().map(f).collect::<Vec<_>>().join(", ")
                };
                let _ = writeln!(s, "n_sats = [{}]", col(&|sh| sh.n_sats.to_string()));
                let _ = writeln!(s, "planes = [{}]", col(&|sh| sh.planes.to_string()));
                let _ = writeln!(s, "phasing = [{}]", col(&|sh| sh.phasing.to_string()));
                let _ = writeln!(s, "alt_km = [{}]", col(&|sh| sh.alt_km.to_string()));
                let _ = writeln!(s, "inc_deg = [{}]", col(&|sh| sh.inc_deg.to_string()));
            }
        }
        let _ = writeln!(s, "\n[stations]");
        let _ = writeln!(s, "network = \"{}\"", self.stations.name());
        let _ = writeln!(s, "\n[connectivity]");
        let _ = writeln!(s, "t0_s = {}", self.t0_s);
        let _ = writeln!(s, "n_steps = {}", self.n_steps);
        let _ = writeln!(s, "min_elev_deg = {}", self.min_elev_deg);
        let _ = writeln!(s, "chunk = {}", self.chunk_len);
        let _ = writeln!(s, "\n[fl]");
        let algs: Vec<String> =
            self.algorithms.iter().map(|a| format!("\"{}\"", a.name())).collect();
        let _ = writeln!(s, "algorithms = [{}]", algs.join(", "));
        let _ = writeln!(s, "fedbuff_m = {}", self.fedbuff_m);
        let _ = writeln!(
            s,
            "dist = \"{}\"",
            match self.dist {
                DataDist::Iid => "iid",
                DataDist::NonIid => "noniid",
            }
        );
        emit_section(&self.isl, &mut s);
        emit_section(&self.federation, &mut s);
        emit_section(&self.attack, &mut s);
        emit_section(&self.robust, &mut s);
        emit_section(&self.link, &mut s);
        emit_section(&self.events, &mut s);
        emit_section(&self.serve, &mut s);
        if !self.downtime.is_empty() {
            let col = |f: fn(&DowntimeWindow) -> usize| -> String {
                self.downtime.iter().map(|w| f(w).to_string()).collect::<Vec<_>>().join(", ")
            };
            let _ = writeln!(s, "\n[downtime]");
            let _ = writeln!(s, "sats = [{}]", col(|w| w.sat));
            let _ = writeln!(s, "from = [{}]", col(|w| w.from_step));
            let _ = writeln!(s, "until = [{}]", col(|w| w.until_step));
        }
        s
    }

    /// Parse a scenario from TOML text (defaults fill missing keys).
    pub fn from_toml_text(text: &str) -> Result<Scenario> {
        let doc = parse_toml(text)?;
        Self::from_doc(&doc)
    }

    /// Parse a scenario from a TOML file on disk.
    pub fn from_file(path: &str) -> Result<Scenario> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading scenario {path}"))?;
        Self::from_toml_text(&text)
    }

    fn from_doc(doc: &TomlDoc) -> Result<Scenario> {
        fn get<'a>(doc: &'a TomlDoc, sec: &str, key: &str) -> Option<&'a TomlValue> {
            doc.get(sec).and_then(|s| s.get(key))
        }
        fn get_str<'a>(doc: &'a TomlDoc, sec: &str, key: &str) -> Result<Option<&'a str>> {
            match get(doc, sec, key) {
                None => Ok(None),
                Some(v) => Ok(Some(
                    v.as_str().with_context(|| format!("[{sec}] {key} must be a string"))?,
                )),
            }
        }
        fn get_usize(doc: &TomlDoc, sec: &str, key: &str) -> Result<Option<usize>> {
            match get(doc, sec, key) {
                None => Ok(None),
                Some(v) => {
                    let i =
                        v.as_int().with_context(|| format!("[{sec}] {key} must be an integer"))?;
                    Ok(Some(usize::try_from(i)?))
                }
            }
        }
        fn get_f64(doc: &TomlDoc, sec: &str, key: &str) -> Result<Option<f64>> {
            match get(doc, sec, key) {
                None => Ok(None),
                Some(v) => Ok(Some(
                    v.as_float().with_context(|| format!("[{sec}] {key} must be a number"))?,
                )),
            }
        }

        let name = get_str(doc, "scenario", "name")?
            .context("scenario TOML missing [scenario] name")?
            .to_string();
        let mut sc = Scenario { name, ..Default::default() };
        if let Some(v) = get_str(doc, "scenario", "summary")? {
            sc.summary = v.to_string();
        }
        if let Some(v) = get_str(doc, "scenario", "engine")? {
            sc.engine_mode = EngineMode::parse(v)?;
        }

        let kind = get_str(doc, "constellation", "kind")?.unwrap_or("planet-labs").to_string();
        sc.constellation = match kind.as_str() {
            "planet-labs" => ConstellationSpec::PlanetLabsLike {
                n_sats: get_usize(doc, "constellation", "n_sats")?.unwrap_or(191),
                seed: get_usize(doc, "constellation", "seed")?.unwrap_or(0) as u64,
            },
            "walker-delta" | "walker-star" => ConstellationSpec::Walker {
                pattern: kind
                    .strip_prefix("walker-")
                    .and_then(WalkerPattern::parse)
                    .expect("walker- kinds carry a valid pattern suffix"),
                n_sats: get_usize(doc, "constellation", "n_sats")?
                    .context("[constellation] walker needs n_sats")?,
                planes: get_usize(doc, "constellation", "planes")?
                    .context("[constellation] walker needs planes")?,
                phasing: get_usize(doc, "constellation", "phasing")?.unwrap_or(1),
                alt_km: get_f64(doc, "constellation", "alt_km")?
                    .context("[constellation] walker needs alt_km")?,
                inc_deg: get_f64(doc, "constellation", "inc_deg")?
                    .context("[constellation] walker needs inc_deg")?,
            },
            "walker-shells" => {
                fn arr<'a>(doc: &'a TomlDoc, key: &str) -> Result<&'a [TomlValue]> {
                    match doc.get("constellation").and_then(|s| s.get(key)) {
                        Some(TomlValue::Array(items)) => Ok(items),
                        Some(_) => bail!("[constellation] {key} must be an array"),
                        None => bail!("[constellation] walker-shells needs a {key} array"),
                    }
                }
                fn usize_arr(doc: &TomlDoc, key: &str) -> Result<Vec<usize>> {
                    arr(doc, key)?
                        .iter()
                        .map(|it| {
                            let i = it
                                .as_int()
                                .with_context(|| format!("[constellation] {key}: integers"))?;
                            Ok(usize::try_from(i)?)
                        })
                        .collect()
                }
                fn f64_arr(doc: &TomlDoc, key: &str) -> Result<Vec<f64>> {
                    arr(doc, key)?
                        .iter()
                        .map(|it| {
                            it.as_float()
                                .with_context(|| format!("[constellation] {key}: numbers"))
                        })
                        .collect()
                }
                let n_sats = usize_arr(doc, "n_sats")?;
                let planes = usize_arr(doc, "planes")?;
                let phasing = usize_arr(doc, "phasing")?;
                let alt_km = f64_arr(doc, "alt_km")?;
                let inc_deg = f64_arr(doc, "inc_deg")?;
                let n = n_sats.len();
                if [planes.len(), phasing.len(), alt_km.len(), inc_deg.len()]
                    .iter()
                    .any(|&l| l != n)
                {
                    bail!("[constellation] walker-shells parallel arrays disagree in length");
                }
                let shells = (0..n)
                    .map(|i| ShellSpec {
                        n_sats: n_sats[i],
                        planes: planes[i],
                        phasing: phasing[i],
                        alt_km: alt_km[i],
                        inc_deg: inc_deg[i],
                    })
                    .collect();
                ConstellationSpec::Shells { shells }
            }
            other => bail!("unknown constellation kind {other:?}"),
        };

        if let Some(v) = get_str(doc, "stations", "network")? {
            sc.stations = StationNetwork::parse(v)?;
        }
        if let Some(v) = get_f64(doc, "connectivity", "t0_s")? {
            sc.t0_s = v;
        }
        if let Some(v) = get_usize(doc, "connectivity", "n_steps")? {
            sc.n_steps = v;
        }
        if let Some(v) = get_f64(doc, "connectivity", "min_elev_deg")? {
            sc.min_elev_deg = v;
        }
        if let Some(v) = get_usize(doc, "connectivity", "chunk")? {
            sc.chunk_len = v;
        }
        if let Some(v) = get(doc, "fl", "algorithms") {
            let TomlValue::Array(items) = v else {
                bail!("[fl] algorithms must be an array of strings");
            };
            sc.algorithms = items
                .iter()
                .map(|it| {
                    AlgorithmKind::parse(
                        it.as_str().context("[fl] algorithms entries must be strings")?,
                    )
                })
                .collect::<Result<_>>()?;
        }
        if let Some(v) = get_usize(doc, "fl", "fedbuff_m")? {
            sc.fedbuff_m = v;
        }
        if let Some(v) = get_str(doc, "fl", "dist")? {
            sc.dist = DataDist::parse(v)?;
        }

        apply_section(doc, &mut sc.isl)?;
        apply_section(doc, &mut sc.federation)?;
        apply_section(doc, &mut sc.attack)?;
        apply_section(doc, &mut sc.robust)?;
        apply_section(doc, &mut sc.link)?;
        apply_section(doc, &mut sc.events)?;
        apply_section(doc, &mut sc.serve)?;

        if doc.get("downtime").is_some() {
            let col = |key: &str| -> Result<Vec<usize>> {
                match get(doc, "downtime", key) {
                    None => bail!("[downtime] missing {key} array"),
                    Some(TomlValue::Array(items)) => items
                        .iter()
                        .map(|it| {
                            let i = it
                                .as_int()
                                .with_context(|| format!("[downtime] {key} must be integers"))?;
                            Ok(usize::try_from(i)?)
                        })
                        .collect(),
                    Some(_) => bail!("[downtime] {key} must be an array"),
                }
            };
            let (sats, from, until) = (col("sats")?, col("from")?, col("until")?);
            if sats.len() != from.len() || sats.len() != until.len() {
                bail!(
                    "[downtime] parallel arrays disagree: {} sats, {} from, {} until",
                    sats.len(),
                    from.len(),
                    until.len()
                );
            }
            sc.downtime = sats
                .into_iter()
                .zip(from)
                .zip(until)
                .map(|((sat, from_step), until_step)| DowntimeWindow { sat, from_step, until_step })
                .collect();
        }

        sc.validate()?;
        Ok(sc)
    }

    /// Build the constellation with its downtime windows attached.
    pub fn build_constellation(&self) -> Constellation {
        self.constellation.build().with_downtime(self.downtime.clone())
    }

    /// Station network + link params — the one place a scenario's
    /// station-side connectivity inputs are interpreted, shared by the
    /// schedule, stream, and upload-routing builds so none of them can
    /// diverge on sampling parameters.
    fn station_params(&self) -> (Vec<GroundStation>, ConnectivityParams) {
        let stations = self.stations.build();
        let params = ConnectivityParams {
            t0_s: self.t0_s,
            min_elev_deg: self.min_elev_deg,
            ..Default::default()
        };
        (stations, params)
    }

    /// Constellation (downtime attached) + station network + link params —
    /// the full input set of the dense and streamed materializations.
    fn connectivity_inputs(&self) -> (Constellation, Vec<GroundStation>, ConnectivityParams) {
        let constellation = self.build_constellation();
        let (stations, params) = self.station_params();
        (constellation, stations, params)
    }

    /// Build constellation + connectivity schedule, downtime applied — the
    /// one deterministic C every algorithm in the grid shares. With a byte
    /// budget enabled the schedule also records pass durations (ADR-0008);
    /// the contact membership is identical either way.
    pub fn build_schedule(&self) -> (Constellation, ConnectivitySchedule) {
        let (constellation, stations, params) = self.connectivity_inputs();
        let sched = if self.link.capacity_enabled() {
            ConnectivitySchedule::compute_with_durations(
                &constellation,
                &stations,
                self.n_steps,
                params,
            )
        } else {
            ConnectivitySchedule::compute(&constellation, &stations, self.n_steps, params)
        };
        let sched = sched.with_downtime(&constellation.downtime);
        (constellation, sched)
    }

    /// [`Self::build_schedule`] and [`Self::build_upload_routing`] fused
    /// into ONE visibility sweep for multi-gateway scenarios (the sampling
    /// pipeline used to run twice over the horizon); single-gateway
    /// scenarios keep the plain schedule build and return no routing.
    /// Bit-identical to calling the two builders separately — asserted by
    /// the `UploadRouting` fused-build tests.
    pub fn build_schedule_routed(
        &self,
    ) -> (Constellation, ConnectivitySchedule, Option<UploadRouting>) {
        if self.federation.is_single() {
            let (constellation, sched) = self.build_schedule();
            return (constellation, sched, None);
        }
        let (constellation, stations, params) = self.connectivity_inputs();
        let (sched, routing) = UploadRouting::build_with_schedule(
            &constellation,
            &stations,
            self.n_steps,
            &params,
            &self.federation.stations,
            self.link.capacity_enabled(),
        );
        (constellation, sched, Some(routing))
    }

    /// Build constellation + chunked connectivity stream — the streamed-
    /// engine counterpart of [`Self::build_schedule`]. Downtime windows are
    /// applied per chunk inside the stream, so chunks concatenate to
    /// exactly what `build_schedule` would materialize; with ISLs enabled
    /// the stream also routes every chunk (ADR-0005), concatenating to
    /// exactly the dense [`ContactGraph`].
    pub fn build_stream(&self) -> (Constellation, ConnectivityStream) {
        let (constellation, stations, params) = self.connectivity_inputs();
        let mut stream = ConnectivityStream::new(
            &constellation,
            &stations,
            self.n_steps,
            params,
            self.chunk_len,
        );
        if let Some(topology) = self.build_isl(&constellation) {
            stream = stream.with_isl(topology);
        }
        if self.link.capacity_enabled() {
            // validate() already rejects the ISL combination
            stream = stream.with_durations();
        }
        (constellation, stream)
    }

    /// The scenario's ISL routing topology over an already-built
    /// constellation (`None` when [`IslSpec::enabled`] is false). The
    /// constellation must be this scenario's own
    /// ([`Self::build_constellation`]) so plane metadata and downtime line
    /// up.
    pub fn build_isl(&self, constellation: &Constellation) -> Option<IslTopology> {
        if !self.isl.enabled() {
            return None;
        }
        // validate() bounds the spec and every ConstellationSpec builder
        // emits plane metadata, so construction cannot fail here
        Some(
            IslTopology::new(constellation, self.isl.params(self.t0_s))
                .expect("spec-built constellations always carry plane metadata"),
        )
    }

    /// Route a materialized schedule through the scenario's ISL topology —
    /// the dense/contact-list counterpart of the routed stream (`None`
    /// when ISLs are off).
    pub fn build_contact_graph(
        &self,
        constellation: &Constellation,
        sched: &ConnectivitySchedule,
    ) -> Option<ContactGraph> {
        self.build_isl(constellation).map(|t| ContactGraph::build(&t, sched))
    }

    /// The upload-routing table of a multi-gateway scenario (ADR-0006):
    /// which gateway hears which satellite at which step, attributed from
    /// the same visibility pipeline the schedule uses. `None` for
    /// single-gateway scenarios — the engine then skips routing entirely
    /// (the bit-identical fast path). The constellation must be this
    /// scenario's own ([`Self::build_constellation`]); one table is shared
    /// across the whole algorithm grid, like the schedule itself.
    pub fn build_upload_routing(&self, constellation: &Constellation) -> Option<UploadRouting> {
        if self.federation.is_single() {
            return None;
        }
        // same single source of station-side inputs as the schedule/stream
        // builds, so the routing table can never sample a different
        // visibility relation than the contacts it attributes — without
        // rebuilding the constellation the caller already holds
        let (stations, params) = self.station_params();
        Some(UploadRouting::build(
            constellation,
            &stations,
            self.n_steps,
            &params,
            &self.federation.stations,
        ))
    }

    /// Experiment configuration for one algorithm of the grid.
    pub fn experiment_config(&self, algorithm: AlgorithmKind) -> ExperimentConfig {
        let seed = match &self.constellation {
            ConstellationSpec::PlanetLabsLike { seed, .. } => *seed,
            ConstellationSpec::Walker { .. } | ConstellationSpec::Shells { .. } => 0,
        };
        // scenario-owned topology (ISLs, federation) is deliberately NOT
        // copied: those specs are bound to the scenario's constellation and
        // station network, and the config path always rebuilds planet12 —
        // the conversion stays standalone-runnable, and scenario runs pass
        // their graph/routing/spec explicitly (`app::runner::FederationRun`).
        // Attack, robust and link specs ARE copied: they are plain value
        // specs over satellite ids / the server aggregator / the upload
        // boundary, not topology.
        ExperimentConfig {
            n_sats: self.constellation.n_sats(),
            constellation_seed: seed,
            t0_s: self.t0_s,
            n_steps: self.n_steps,
            min_elev_deg: self.min_elev_deg,
            dist: self.dist,
            algorithm,
            fedbuff_m: self.fedbuff_m,
            engine_mode: self.engine_mode,
            attack: self.attack.clone(),
            robust: self.robust.clone(),
            link: self.link.clone(),
            events: self.events,
            serve: self.serve,
            ..Default::default()
        }
    }

    /// A proportionally scaled-down copy (small CLI smoke runs, CI tests):
    /// overrides the satellite count and/or step count while keeping the
    /// scenario's shape. Walker plane counts are preserved when the new
    /// count divides into them, otherwise reduced to 1 plane; `fedbuff_m`
    /// scales with the fleet so FedBuff keeps its buffered character
    /// instead of silently degenerating into Sync at small `--sats`.
    pub fn scaled(&self, n_sats: Option<usize>, n_steps: Option<usize>) -> Scenario {
        let mut sc = self.clone();
        if let Some(steps) = n_steps {
            sc.n_steps = steps;
        }
        if let Some(k) = n_sats {
            let k0 = self.constellation.n_sats().max(1);
            sc.fedbuff_m = (self.fedbuff_m * k / k0).max(1);
            sc.constellation = match sc.constellation {
                ConstellationSpec::PlanetLabsLike { seed, .. } => {
                    ConstellationSpec::PlanetLabsLike { n_sats: k, seed }
                }
                ConstellationSpec::Walker {
                    pattern, planes, phasing, alt_km, inc_deg, ..
                } => {
                    // keep the plane structure when it divides the new count
                    let planes = if planes > 0 && k % planes == 0 { planes } else { 1 };
                    ConstellationSpec::Walker {
                        pattern,
                        n_sats: k,
                        planes,
                        phasing,
                        alt_km,
                        inc_deg,
                    }
                }
                ConstellationSpec::Shells { shells } => {
                    // distribute k proportionally over the shells (each
                    // keeps ≥ 1 satellite), then absorb rounding drift into
                    // the largest shell; collapse to one shell when k is
                    // smaller than the shell count
                    let total: usize = shells.iter().map(|s| s.n_sats).sum::<usize>().max(1);
                    let mut scaled: Vec<ShellSpec> = shells
                        .iter()
                        .map(|sh| ShellSpec { n_sats: (sh.n_sats * k / total).max(1), ..*sh })
                        .collect();
                    let sum: usize = scaled.iter().map(|s| s.n_sats).sum();
                    let largest = scaled
                        .iter()
                        .enumerate()
                        .max_by_key(|(_, s)| s.n_sats)
                        .map(|(i, _)| i)
                        .unwrap_or(0);
                    let adjusted = scaled[largest].n_sats as i64 + k as i64 - sum as i64;
                    if adjusted >= 1 {
                        scaled[largest].n_sats = adjusted as usize;
                    } else {
                        scaled = vec![ShellSpec { n_sats: k, ..shells[0] }];
                    }
                    // restore per-shell plane divisibility
                    for sh in &mut scaled {
                        if sh.planes == 0 || sh.n_sats % sh.planes != 0 {
                            sh.planes = 1;
                        }
                    }
                    ConstellationSpec::Shells { shells: scaled }
                }
            };
        }
        // drop downtime windows that fell outside the scaled run
        let k = sc.constellation.n_sats();
        sc.downtime.retain(|w| w.sat < k && w.from_step < sc.n_steps);
        // explicit adversary ids beyond the scaled fleet no longer exist;
        // fraction-based adversary selection rescales automatically
        sc.attack.sats.retain(|&s| s < k);
        if sc.attack.kind != AttackKind::None && sc.attack.adversaries(k).iter().all(|a| !a) {
            // keep the adversarial character at tiny smoke scales, where
            // the strided fraction rounds to zero adversaries (or the
            // whole explicit list fell outside the fleet) — validate()
            // rejects an attack that selects nobody
            sc.attack.sats = vec![0];
        }
        let n_steps = sc.n_steps;
        for w in &mut sc.downtime {
            // retain guarantees from_step < n_steps, so the clamp range is valid
            w.until_step = w.until_step.clamp(w.from_step + 1, n_steps);
        }
        sc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_at_least_five_unique_builtins() {
        let names = Scenario::builtin_names();
        assert!(names.len() >= 5, "{names:?}");
        let mut sorted = names.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), names.len(), "duplicate scenario names");
        for n in names {
            let sc = Scenario::builtin(n).expect("registered builtin");
            assert_eq!(&sc.name, n);
            assert!(!sc.summary.is_empty(), "{n} has no summary");
            sc.validate().unwrap();
        }
        assert!(Scenario::builtin("warp-drive").is_none());
    }

    #[test]
    fn toml_roundtrip_every_builtin() {
        for sc in Scenario::builtins() {
            let toml = sc.to_toml();
            let back = Scenario::from_toml_text(&toml)
                .unwrap_or_else(|e| panic!("{}: {e}\n{toml}", sc.name));
            assert_eq!(sc, back, "round-trip changed {}:\n{toml}", sc.name);
        }
    }

    #[test]
    fn paper_fig7_matches_section_4_1() {
        let sc = Scenario::builtin("paper-fig7").unwrap();
        assert_eq!(sc.constellation.n_sats(), 191);
        assert_eq!(sc.stations, StationNetwork::Planet12);
        assert_eq!(sc.n_steps, 480);
        assert!((sc.t0_s - 900.0).abs() < 1e-9);
        assert_eq!(sc.algorithms.len(), 4);
        let cfg = sc.experiment_config(AlgorithmKind::FedSpace);
        cfg.validate().unwrap();
        assert_eq!(cfg.n_sats, 191);
        assert_eq!(cfg.fedbuff_m, 96);
    }

    #[test]
    fn builtin_shapes_cover_the_zoo() {
        let shells: Vec<String> = Scenario::builtins()
            .iter()
            .map(|sc| sc.constellation.kind_name().to_string())
            .collect();
        assert!(shells.contains(&"planet-labs".to_string()));
        assert!(shells.contains(&"walker-delta".to_string()));
        assert!(shells.contains(&"walker-star".to_string()));
        assert!(shells.contains(&"walker-shells".to_string()));
        assert!(Scenario::builtins().iter().any(|sc| !sc.downtime.is_empty()));
        assert!(Scenario::builtins()
            .iter()
            .any(|sc| sc.engine_mode == EngineMode::ContactList));
        assert!(Scenario::builtins()
            .iter()
            .any(|sc| sc.engine_mode == EngineMode::Streamed));
        assert!(Scenario::builtins()
            .iter()
            .any(|sc| sc.stations == StationNetwork::SingleSvalbard));
    }

    #[test]
    fn mega_builtins_match_the_filed_counts() {
        let sl = Scenario::builtin("walker-starlink-4408").unwrap();
        assert_eq!(sl.constellation.n_sats(), 4408);
        assert_eq!(sl.engine_mode, EngineMode::Streamed);
        let ConstellationSpec::Shells { shells } = &sl.constellation else {
            panic!("starlink-4408 should be a shell stack");
        };
        assert_eq!(shells.len(), 5);
        let ku = Scenario::builtin("kuiper-3236").unwrap();
        assert_eq!(ku.constellation.n_sats(), 3236);
        assert_eq!(ku.engine_mode, EngineMode::Streamed);
        // orbits materialize with per-shell altitudes, in id order
        let c = ku.build_constellation();
        assert_eq!(c.len(), 3236);
        let alt0 = c.orbits[0].a;
        let alt_last = c.orbits[3235].a;
        assert!(alt0 > alt_last, "first shell files higher than the last");
    }

    #[test]
    fn scaled_shells_keep_total_and_divisibility() {
        for k in [3usize, 12, 100, 441] {
            let sc = Scenario::builtin("walker-starlink-4408").unwrap().scaled(Some(k), Some(48));
            assert_eq!(sc.constellation.n_sats(), k, "k={k}");
            sc.validate().unwrap();
        }
        // unscaled leaves the filed shells untouched
        let same = Scenario::builtin("walker-starlink-4408").unwrap().scaled(None, Some(96));
        assert_eq!(same.constellation.n_sats(), 4408);
        assert_eq!(same.n_steps, 96);
    }

    #[test]
    fn build_stream_matches_build_schedule_on_small_fleet() {
        let sc = Scenario::builtin("dove-dropout").unwrap().scaled(Some(16), Some(48));
        let (_, sched) = sc.build_schedule();
        let (_, stream) = sc.build_stream();
        assert_eq!(stream.n_sats(), 16);
        assert_eq!(stream.n_steps(), 48);
        let collected = stream.collect_dense();
        assert_eq!(collected.sets, sched.sets, "stream must concatenate to the dense schedule");
    }

    #[test]
    fn chunk_len_round_trips_and_rejects_zero() {
        let mut sc = Scenario::builtin("paper-fig7").unwrap();
        sc.chunk_len = 17;
        let back = Scenario::from_toml_text(&sc.to_toml()).unwrap();
        assert_eq!(back.chunk_len, 17);
        sc.chunk_len = 0;
        assert!(sc.validate().is_err());
    }

    #[test]
    fn isl_builtins_declare_links_and_build_topologies() {
        let ir = Scenario::builtin("isl-iridium-66").unwrap();
        assert_eq!(ir.isl.mode, IslMode::IntraCross);
        assert_eq!(ir.algorithms.len(), 4, "the ISL grid must cover all four algorithms");
        let c = ir.build_constellation();
        let topo = ir.build_isl(&c).expect("isl on");
        assert_eq!(topo.n_sats(), 66);
        let sl = Scenario::builtin("isl-starlink-1584").unwrap();
        assert_eq!(sl.isl.mode, IslMode::IntraPlane);
        assert_eq!(sl.engine_mode, EngineMode::Streamed);
        // every pre-ISL builtin keeps ISLs off (trace compatibility)
        for name in ["paper-fig7", "walker-starlink-4408", "dove-dropout"] {
            let sc = Scenario::builtin(name).unwrap();
            assert!(!sc.isl.enabled(), "{name}");
            let c = sc.build_constellation();
            assert!(sc.build_isl(&c).is_none(), "{name}");
        }
    }

    #[test]
    fn isl_spec_round_trips_and_validates() {
        let mut sc = Scenario::builtin("isl-iridium-66").unwrap();
        sc.isl.max_hops = 5;
        sc.isl.hop_delay_slots = 2;
        let back = Scenario::from_toml_text(&sc.to_toml()).unwrap();
        assert_eq!(back.isl, sc.isl);
        // off specs emit no [isl] section and parse back to the default
        let off = Scenario::builtin("paper-fig7").unwrap();
        assert!(!off.to_toml().contains("[isl]"));
        assert_eq!(Scenario::from_toml_text(&off.to_toml()).unwrap().isl, IslSpec::default());
        // invalid specs rejected
        sc.isl.max_hops = 0;
        assert!(sc.validate().is_err());
        sc.isl.max_hops = 3;
        sc.isl.max_range_km = 0.0;
        assert!(sc.validate().is_err(), "cross mode needs a positive range");
        sc.isl.mode = IslMode::IntraPlane;
        sc.validate().unwrap();
        // the worst-case relay charge must fit the horizon (and the check
        // itself must not overflow)
        sc.isl.hop_delay_slots = usize::MAX;
        assert!(sc.validate().is_err(), "unbounded hop delay must be rejected");
        sc.isl.hop_delay_slots = sc.n_steps; // 3 hops x n_steps > n_steps
        assert!(sc.validate().is_err());
        assert!(Scenario::from_toml_text(
            "[scenario]\nname = \"x\"\n[isl]\nmode = \"laser-mesh\""
        )
        .is_err());
    }

    #[test]
    fn isl_mode_parse_roundtrip() {
        for m in [IslMode::Off, IslMode::IntraPlane, IslMode::IntraCross] {
            assert_eq!(IslMode::parse(m.name()).unwrap(), m);
        }
        assert!(IslMode::parse("mesh").is_err());
    }

    #[test]
    fn scaled_keeps_isl_spec() {
        let sc = Scenario::builtin("isl-iridium-66").unwrap().scaled(Some(24), Some(96));
        assert_eq!(sc.isl.mode, IslMode::IntraCross);
        sc.validate().unwrap();
        // the scaled constellation still carries plane metadata for ISLs
        let c = sc.build_constellation();
        assert!(sc.build_isl(&c).is_some());
    }

    #[test]
    fn routed_stream_concatenates_to_dense_contact_graph() {
        let sc = Scenario::builtin("isl-iridium-66").unwrap().scaled(Some(18), Some(48));
        let (c, sched) = sc.build_schedule();
        let graph = sc.build_contact_graph(&c, &sched).expect("isl on");
        let (_, stream) = sc.build_stream();
        assert!(stream.has_isl());
        let mut chunk = crate::connectivity::ScheduleChunk::default();
        for ci in 0..stream.n_chunks() {
            stream.fill_chunk(ci, &mut chunk);
            for i in chunk.start()..chunk.end() {
                let (s, h) = chunk.contacts_at(i);
                assert_eq!(s, graph.sats_at(i), "step {i}");
                assert_eq!(h, graph.hops_at(i), "step {i}");
            }
        }
    }

    #[test]
    fn shells_plane_metadata_never_crosses_shells() {
        let sc = Scenario::builtin("walker-starlink-4408").unwrap().scaled(Some(50), Some(24));
        let c = sc.build_constellation();
        assert_eq!(c.plane_ids.len(), c.len());
        let groups: std::collections::BTreeSet<usize> =
            c.plane_ids.iter().map(|p| p.group).collect();
        assert!(groups.len() >= 2, "scaled shell stack should keep >= 2 shells");
    }

    #[test]
    fn station_networks_build_expected_sizes() {
        assert_eq!(StationNetwork::Planet12.build().len(), 12);
        assert_eq!(StationNetwork::SingleSvalbard.build().len(), 1);
        assert_eq!(StationNetwork::Polar4.build().len(), 4);
        for n in [StationNetwork::Planet12, StationNetwork::SingleSvalbard, StationNetwork::Polar4]
        {
            assert_eq!(StationNetwork::parse(n.name()).unwrap(), n);
        }
    }

    #[test]
    fn federation_toml_roundtrip_present_and_omitted() {
        // a non-default federation section round-trips exactly
        let sc = Scenario::builtin("fedspace-multi-gs").unwrap();
        assert!(!sc.federation.is_default());
        let toml = sc.to_toml();
        assert!(toml.contains("[federation]"), "{toml}");
        assert!(toml.contains("reconcile = \"periodic\""), "{toml}");
        let back = Scenario::from_toml_text(&toml).unwrap();
        assert_eq!(back.federation, sc.federation);
        assert_eq!(back, sc);
        // the default single gateway emits nothing — pre-federation specs
        // stay byte-identical and parse back to the default
        let off = Scenario::builtin("paper-fig7").unwrap();
        assert!(!off.to_toml().contains("[federation]"));
        let back = Scenario::from_toml_text(&off.to_toml()).unwrap();
        assert!(back.federation.is_default());
    }

    #[test]
    fn federation_validate_through_scenario() {
        use crate::fl::{FederationSpec, ReconcilePolicy};
        let mut sc = Scenario::builtin("fedspace-multi-gs").unwrap();
        sc.validate().unwrap();
        // unmapped stations: polar4 has 4 stations, map covers 3
        sc.federation =
            FederationSpec::split(&["a", "b"], &[0, 0, 1], ReconcilePolicy::Centralized);
        assert!(sc.validate().is_err());
        // empty gateway
        sc.federation =
            FederationSpec::split(&["a", "b"], &[0, 0, 0, 0], ReconcilePolicy::Centralized);
        assert!(sc.validate().is_err());
        // zero periodic cadence
        sc.federation = FederationSpec::split(
            &["a", "b"],
            &[0, 0, 1, 1],
            ReconcilePolicy::Periodic { every: 0 },
        );
        assert!(sc.validate().is_err());
        // TOML-level rejection too
        assert!(Scenario::from_toml_text(
            "[scenario]\nname = \"x\"\n[federation]\ngateways = [\"a\", \"a\"]"
        )
        .is_err());
    }

    #[test]
    fn multi_gs_builtin_shape_and_routing() {
        let sc = Scenario::builtin("fedspace-multi-gs").unwrap();
        assert_eq!(sc.federation.n_gateways(), 2);
        assert_eq!(sc.algorithms.len(), 4, "the federation grid must cover all four algorithms");
        assert_eq!(sc.stations, StationNetwork::Polar4);
        let cfg = sc.experiment_config(AlgorithmKind::FedBuff);
        // the conversion stays standalone-runnable: scenario-owned topology
        // (federation, ISLs) is passed explicitly by run_scenario instead
        assert!(cfg.federation.is_default());
        assert!(!cfg.isl.enabled());
        // routing builds and attributes within bounds on a scaled copy
        let scaled = sc.scaled(Some(12), Some(48));
        assert_eq!(scaled.federation, sc.federation, "scaling must keep the federation");
        scaled.validate().unwrap();
        let c = scaled.build_constellation();
        let routing = scaled.build_upload_routing(&c).expect("multi-gateway scenario");
        assert_eq!(routing.n_steps(), 48);
        assert_eq!(routing.n_gateways(), 2);
        let (_, sched) = scaled.build_schedule();
        let mut per_gw = vec![0usize; 2];
        for i in 0..sched.n_steps() {
            for &s in sched.sats_at(i) {
                per_gw[routing.gateway_for(i, s, 0)] += 1;
            }
        }
        assert!(
            per_gw.iter().all(|&n| n > 0),
            "polar orbits should reach both gateway networks: {per_gw:?}"
        );
        // single-gateway scenarios build no table
        let single = Scenario::builtin("paper-fig7").unwrap().scaled(Some(8), Some(24));
        let c = single.build_constellation();
        assert!(single.build_upload_routing(&c).is_none());
    }

    #[test]
    fn from_toml_rejects_bad_specs() {
        // walker without required keys
        assert!(Scenario::from_toml_text(
            "[scenario]\nname = \"x\"\n[constellation]\nkind = \"walker-delta\"\nn_sats = 10"
        )
        .is_err());
        // indivisible walker planes
        assert!(Scenario::from_toml_text(
            "[scenario]\nname = \"x\"\n[constellation]\nkind = \"walker-delta\"\n\
             n_sats = 10\nplanes = 3\nalt_km = 500.0\ninc_deg = 53.0"
        )
        .is_err());
        // mismatched downtime arrays
        assert!(Scenario::from_toml_text(
            "[scenario]\nname = \"x\"\n[downtime]\nsats = [1, 2]\nfrom = [0]\nuntil = [5]"
        )
        .is_err());
        // mismatched / missing shell arrays
        assert!(Scenario::from_toml_text(
            "[scenario]\nname = \"x\"\n[constellation]\nkind = \"walker-shells\"\n\
             n_sats = [10, 20]\nplanes = [2]\nphasing = [1, 1]\nalt_km = [550.0, 540.0]\n\
             inc_deg = [53.0, 53.0]"
        )
        .is_err());
        assert!(Scenario::from_toml_text(
            "[scenario]\nname = \"x\"\n[constellation]\nkind = \"walker-shells\"\nn_sats = [10]"
        )
        .is_err());
        // indivisible shell planes
        assert!(Scenario::from_toml_text(
            "[scenario]\nname = \"x\"\n[constellation]\nkind = \"walker-shells\"\n\
             n_sats = [10]\nplanes = [3]\nphasing = [1]\nalt_km = [550.0]\ninc_deg = [53.0]"
        )
        .is_err());
        // downtime out of fleet range
        assert!(Scenario::from_toml_text(
            "[scenario]\nname = \"x\"\n[constellation]\nkind = \"planet-labs\"\nn_sats = 5\n\
             [downtime]\nsats = [7]\nfrom = [0]\nuntil = [5]"
        )
        .is_err());
        // unknown kind / network / algorithm
        assert!(Scenario::from_toml_text(
            "[scenario]\nname = \"x\"\n[constellation]\nkind = \"cube\""
        )
        .is_err());
        assert!(Scenario::from_toml_text(
            "[scenario]\nname = \"x\"\n[stations]\nnetwork = \"mars\""
        )
        .is_err());
        assert!(Scenario::from_toml_text(
            "[scenario]\nname = \"x\"\n[fl]\nalgorithms = [\"sgd\"]"
        )
        .is_err());
        // missing name
        assert!(Scenario::from_toml_text("[constellation]\nkind = \"planet-labs\"").is_err());
        // empty fleet
        assert!(Scenario::from_toml_text(
            "[scenario]\nname = \"x\"\n[constellation]\nkind = \"planet-labs\"\nn_sats = 0"
        )
        .is_err());
    }

    #[test]
    fn minimal_toml_gets_defaults() {
        let sc = Scenario::from_toml_text("[scenario]\nname = \"mine\"").unwrap();
        assert_eq!(sc.constellation.n_sats(), 191);
        assert_eq!(sc.stations, StationNetwork::Planet12);
        assert_eq!(sc.engine_mode, EngineMode::Dense);
        assert_eq!(sc.algorithms, vec![AlgorithmKind::FedSpace]);
    }

    #[test]
    fn builtin_constellations_build() {
        for sc in Scenario::builtins() {
            let c = sc.build_constellation();
            assert_eq!(c.len(), sc.constellation.n_sats(), "{}", sc.name);
            assert_eq!(c.downtime.len(), sc.downtime.len(), "{}", sc.name);
        }
    }

    #[test]
    fn scaled_keeps_fedbuff_buffered() {
        // M scales with the fleet: fedbuff must stay below the sync
        // threshold at small --sats instead of degenerating into sync
        let sc = Scenario::builtin("paper-fig7").unwrap().scaled(Some(12), None);
        assert!(sc.fedbuff_m >= 1 && sc.fedbuff_m < 12, "m={}", sc.fedbuff_m);
        // unscaled count leaves M untouched
        let same = Scenario::builtin("paper-fig7").unwrap().scaled(None, Some(48));
        assert_eq!(same.fedbuff_m, 96);
    }

    #[test]
    fn scaled_preserves_shape_and_trims_downtime() {
        let sc = Scenario::builtin("dove-dropout").unwrap().scaled(Some(24), Some(96));
        assert_eq!(sc.constellation.n_sats(), 24);
        assert_eq!(sc.n_steps, 96);
        for w in &sc.downtime {
            assert!(w.sat < 24);
            assert!(w.from_step < w.until_step && w.until_step <= 96);
        }
        sc.validate().unwrap();
        // walker scaling keeps divisibility
        let w = Scenario::builtin("walker-starlink-1584").unwrap().scaled(Some(36), Some(48));
        w.validate().unwrap();
        assert_eq!(w.constellation.n_sats(), 36);
        let schedule_ready = w.scaled(Some(35), None); // 35 % 72 != 0 -> 1 plane
        schedule_ready.validate().unwrap();
    }

    #[test]
    fn sparse_single_gs_schedule_is_actually_sparse() {
        let sc = Scenario::builtin("sparse-single-gs").unwrap().scaled(Some(10), Some(96));
        let (_, sched) = sc.build_schedule();
        let active = sched.active_steps().len();
        assert!(active < 96, "single-station schedule should have contact-free steps");
    }

    #[test]
    fn attack_robust_toml_roundtrip_present_and_omitted() {
        // a byz builtin emits both sections and round-trips exactly
        let sc = Scenario::builtin("byz-multi-gs").unwrap();
        let toml = sc.to_toml();
        assert!(toml.contains("[attack]"), "{toml}");
        assert!(toml.contains("[robust]"), "{toml}");
        assert!(toml.contains("kind = \"scaled-grad\""), "{toml}");
        assert!(toml.contains("aggregator = \"median\""), "{toml}");
        let back = Scenario::from_toml_text(&toml).unwrap();
        assert_eq!(back.attack, sc.attack);
        assert_eq!(back.robust, sc.robust);
        assert_eq!(back, sc);
        // attack-free specs emit neither section — pre-robustness scenario
        // files stay byte-identical and parse back to the defaults
        let off = Scenario::builtin("paper-fig7").unwrap();
        let toml = off.to_toml();
        assert!(!toml.contains("[attack]"), "{toml}");
        assert!(!toml.contains("[robust]"), "{toml}");
        let back = Scenario::from_toml_text(&toml).unwrap();
        assert!(!back.attack.enabled());
        assert!(back.robust.is_default());
    }

    #[test]
    fn byz_builtins_shape() {
        let ir = Scenario::builtin("byz-iridium-66").unwrap();
        assert_eq!(ir.algorithms.len(), 4, "the byz grid must cover all four algorithms");
        assert_eq!(ir.attack.kind, AttackKind::ScaledGrad);
        assert_eq!(ir.robust.aggregator, RobustKind::TrimmedMean);
        // 10% of 66 rounds to 7 strided adversaries
        let adv = ir.attack.adversaries(66);
        assert_eq!(adv.iter().filter(|&&a| a).count(), 7);
        // the attack and defense travel into the per-algorithm config
        let cfg = ir.experiment_config(AlgorithmKind::FedSpace);
        assert_eq!(cfg.attack, ir.attack);
        assert_eq!(cfg.robust, ir.robust);
        cfg.validate().unwrap();

        let mg = Scenario::builtin("byz-multi-gs").unwrap();
        assert_eq!(mg.federation.n_gateways(), 2);
        assert_eq!(mg.robust.aggregator, RobustKind::Median);
        assert!(mg.attack.drop_prob > 0.0 && mg.attack.corrupt_prob > 0.0);
        // the compromised set is exactly one orbital plane
        let c = mg.build_constellation();
        assert_eq!(mg.attack.sats.len(), 11);
        for &s in &mg.attack.sats {
            assert_eq!(c.plane_ids[s].plane, 0, "sat {s} should sit in plane 0");
        }
        // every pre-robustness builtin keeps the attack off and the plain
        // Eq.-4 mean (trace compatibility)
        for name in ["paper-fig7", "polar-iridium-66", "fedspace-multi-gs", "isl-iridium-66"] {
            let sc = Scenario::builtin(name).unwrap();
            assert!(!sc.attack.enabled(), "{name}");
            assert!(sc.robust.is_default(), "{name}");
        }
    }

    #[test]
    fn attack_robust_validate_through_scenario() {
        let mut sc = Scenario::builtin("byz-iridium-66").unwrap();
        sc.validate().unwrap();
        // adversary id outside the fleet
        sc.attack.sats = vec![66];
        assert!(sc.validate().is_err());
        sc.attack.sats = vec![3];
        sc.validate().unwrap();
        // trim fraction must leave survivors
        sc.robust.trim = 0.5;
        assert!(sc.validate().is_err());
        sc.robust.trim = 0.15;
        sc.validate().unwrap();
        // TOML-level rejection of unknown spellings
        assert!(Scenario::from_toml_text(
            "[scenario]\nname = \"x\"\n[attack]\nkind = \"jamming\""
        )
        .is_err());
        assert!(Scenario::from_toml_text(
            "[scenario]\nname = \"x\"\n[robust]\naggregator = \"blockchain\""
        )
        .is_err());
    }

    #[test]
    fn scaled_trims_attack_sats_and_keeps_an_adversary() {
        // explicit ids beyond the scaled fleet are dropped
        let sc = Scenario::builtin("byz-multi-gs").unwrap().scaled(Some(6), Some(48));
        assert!(!sc.attack.sats.is_empty());
        assert!(sc.attack.sats.iter().all(|&s| s < 6), "{:?}", sc.attack.sats);
        sc.validate().unwrap();
        // fraction-based selection that rounds to zero adversaries falls
        // back to one explicit adversary instead of failing validation
        let tiny = Scenario::builtin("byz-iridium-66").unwrap().scaled(Some(4), Some(24));
        assert!(tiny.attack.adversaries(4).iter().any(|&a| a));
        tiny.validate().unwrap();
        // the defense travels through scaling untouched
        assert_eq!(tiny.robust, Scenario::builtin("byz-iridium-66").unwrap().robust);
    }

    #[test]
    fn link_toml_roundtrip_present_and_omitted() {
        // the compress builtin emits the section and round-trips exactly
        let sc = Scenario::builtin("compress-starlink-1584").unwrap();
        let toml = sc.to_toml();
        assert!(toml.contains("[link]"), "{toml}");
        assert!(toml.contains("codec = \"top-k\""), "{toml}");
        let back = Scenario::from_toml_text(&toml).unwrap();
        assert_eq!(back.link, sc.link);
        assert_eq!(back, sc);
        // link-free specs emit no [link] section — pre-link scenario files
        // stay byte-identical and parse back to the default
        let off = Scenario::builtin("paper-fig7").unwrap();
        assert!(!off.to_toml().contains("[link]"), "{}", off.to_toml());
        assert_eq!(Scenario::from_toml_text(&off.to_toml()).unwrap().link, LinkSpec::default());
    }

    #[test]
    fn compress_builtin_shape() {
        let sc = Scenario::builtin("compress-starlink-1584").unwrap();
        assert_eq!(sc.engine_mode, EngineMode::Streamed);
        assert_eq!(sc.link.codec, CodecKind::TopK);
        assert!((sc.link.topk_frac - 0.01).abs() < 1e-12);
        assert!(sc.link.capacity_enabled());
        // the link spec travels into the per-algorithm config
        let cfg = sc.experiment_config(AlgorithmKind::FedBuff);
        assert_eq!(cfg.link, sc.link);
        cfg.validate().unwrap();
        // and through scaling untouched
        let scaled = sc.scaled(Some(12), Some(48));
        assert_eq!(scaled.link, sc.link);
        scaled.validate().unwrap();
        // every pre-link builtin keeps the link off (trace compatibility)
        for name in ["paper-fig7", "walker-starlink-4408", "byz-iridium-66", "isl-iridium-66"] {
            assert!(!Scenario::builtin(name).unwrap().link.enabled(), "{name}");
        }
    }

    #[test]
    fn link_validate_through_scenario() {
        let mut sc = Scenario::builtin("compress-starlink-1584").unwrap();
        sc.validate().unwrap();
        sc.link.topk_frac = 0.0;
        assert!(sc.validate().is_err());
        sc.link.topk_frac = 0.01;
        sc.validate().unwrap();
        // byte budgets and ISL relays cannot combine
        sc.isl.mode = IslMode::IntraPlane;
        assert!(sc.validate().is_err(), "capacity + ISL must be rejected");
        // codec-only compression composes with ISLs
        sc.link.rate_bytes_per_slot = 0;
        sc.engine_mode = EngineMode::Streamed;
        sc.validate().unwrap();
        // TOML-level rejection of unknown codecs
        assert!(Scenario::from_toml_text(
            "[scenario]\nname = \"x\"\n[link]\ncodec = \"gzip\""
        )
        .is_err());
    }

    #[test]
    fn capacity_scenarios_build_timed_connectivity() {
        let sc = Scenario::builtin("compress-starlink-1584").unwrap().scaled(Some(24), Some(48));
        let (_, sched) = sc.build_schedule();
        assert!(sched.has_durations(), "capacity on => durations recorded");
        let (_, stream) = sc.build_stream();
        assert!(stream.has_durations());
        // the timed stream concatenates to the timed dense schedule
        let collected = stream.collect_dense();
        assert_eq!(collected.sets, sched.sets);
        for i in 0..sched.n_steps() {
            assert_eq!(
                collected.contact_durations_at(i),
                sched.contact_durations_at(i),
                "step {i}"
            );
        }
        // capacity off => no duration tracking anywhere
        let plain = Scenario::builtin("paper-fig7").unwrap().scaled(Some(8), Some(24));
        assert!(!plain.build_schedule().1.has_durations());
        assert!(!plain.build_stream().1.has_durations());
    }

    #[test]
    fn dove_dropout_silences_failed_satellites() {
        let sc = Scenario::builtin("dove-dropout").unwrap().scaled(Some(30), Some(240));
        let (c, sched) = sc.build_schedule();
        for w in &c.downtime {
            for i in w.from_step..w.until_step.min(sched.n_steps()) {
                assert!(!sched.connected(w.sat, i), "sat {} connected at {i}", w.sat);
            }
        }
    }
}
