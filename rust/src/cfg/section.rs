//! One shared contract for every optional TOML section.
//!
//! `[isl]`, `[federation]`, `[attack]`, `[robust]`, `[link]`, `[events]`
//! and `[serve]` all follow the same lifecycle — absent ⇒ default ⇒ not emitted, present ⇒
//! parsed key-by-key over the default, validated against the run it rides
//! in — but before PR 8 each spec hand-rolled that surface and
//! `cfg/scenario.rs` / `cfg/experiment.rs` each open-coded the call chains.
//! [`SectionSpec`] names the contract once; the generic helpers below are
//! the only way the two config surfaces touch a section, so they can never
//! drift on parse/emit/validate order again, and the round-trip property is
//! tested once, generically, for every section.
//!
//! Trait impls live next to each spec (its home module keeps the domain
//! logic); they delegate to the existing inherent methods, which remain the
//! ergonomic call surface for direct users.

use crate::cfg::toml::TomlDoc;
use anyhow::Result;

/// What a section validates against. Scenarios know their full network;
/// the standalone experiment-config path does not yet know its station
/// count, so `n_stations` is optional and sections that need it fall back
/// to structure-only validation.
#[derive(Clone, Copy, Debug, Default)]
pub struct SectionCtx {
    /// Simulation horizon in slots.
    pub n_steps: usize,
    /// Fleet size.
    pub n_sats: usize,
    /// Ground-station count when the caller has resolved its network
    /// (`Scenario::validate`); `None` on the bare config path.
    pub n_stations: Option<usize>,
}

/// An optional TOML section of a scenario / experiment config.
///
/// The contract every section already obeyed informally:
/// - `Default` is the section-absent state and must emit nothing
///   ([`Self::is_emitted`] is false) so pre-section specs stay
///   byte-identical;
/// - [`Self::from_doc`] returns `Ok(None)` when the section is absent and
///   parses present keys over the default otherwise;
/// - [`Self::emit_toml`] writes a `\n[section]` block that
///   [`Self::from_doc`] round-trips exactly (tested generically below).
pub trait SectionSpec: Sized + Clone + PartialEq + std::fmt::Debug + Default {
    /// TOML section name, without brackets.
    const SECTION: &'static str;

    /// Parse the section from a document; `Ok(None)` when absent.
    fn from_doc(doc: &TomlDoc) -> Result<Option<Self>>;

    /// Append the `[SECTION]` block (unconditionally — emission gating is
    /// [`emit_section`]'s job).
    fn emit_toml(&self, out: &mut String);

    /// Should a config emit this section? False for the default state so
    /// that specs which never mention the section stay byte-identical.
    fn is_emitted(&self) -> bool;

    /// Reject self-inconsistent specs against the run they ride in.
    fn validate(&self, ctx: &SectionCtx) -> Result<()>;
}

/// Overwrite `slot` with the parsed section when present; keep the caller's
/// default otherwise. The single parse entry point both config surfaces use.
pub fn apply_section<S: SectionSpec>(doc: &TomlDoc, slot: &mut S) -> Result<()> {
    if let Some(spec) = S::from_doc(doc)? {
        *slot = spec;
    }
    Ok(())
}

/// Append the section iff it asks to be emitted — the single emit entry
/// point both config surfaces use.
pub fn emit_section<S: SectionSpec>(spec: &S, out: &mut String) {
    if spec.is_emitted() {
        spec.emit_toml(out);
    }
}

/// Validate one section against its run context (monomorphized so the
/// trait method resolves even where an inherent `validate` shadows it).
pub fn validate_section<S: SectionSpec>(spec: &S, ctx: &SectionCtx) -> Result<()> {
    spec.validate(ctx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfg::scenario::{IslMode, IslSpec};
    use crate::fl::codec::{CodecKind, LinkSpec};
    use crate::fl::federation::{FederationSpec, ReconcilePolicy};
    use crate::fl::robust::{RobustKind, RobustSpec};
    use crate::fl::serve::ServeSpec;
    use crate::sim::adversary::{AttackKind, AttackSpec};
    use crate::sim::events::EventSpec;

    /// emit → parse → from_doc must reproduce the spec exactly, and the
    /// default must neither emit nor fail validation in a benign context.
    fn roundtrip<S: SectionSpec>(spec: S) {
        assert!(
            !S::default().is_emitted(),
            "[{}] default must not be emitted (old specs must stay byte-identical)",
            S::SECTION
        );
        let mut out = String::new();
        emit_section(&spec, &mut out);
        assert!(
            out.contains(&format!("[{}]", S::SECTION)),
            "[{}] sample spec did not emit its own section:\n{out}",
            S::SECTION
        );
        let doc = crate::cfg::toml::parse_toml(&out).unwrap();
        let mut back = S::default();
        apply_section(&doc, &mut back).unwrap();
        assert_eq!(back, spec, "[{}] did not round-trip:\n{out}", S::SECTION);
        let ctx = SectionCtx { n_steps: 480, n_sats: 66, n_stations: Some(12) };
        validate_section(&back, &ctx).unwrap();
        validate_section(&back, &SectionCtx { n_stations: None, ..ctx }).unwrap();
        // absent section keeps the caller's value untouched
        let empty = crate::cfg::toml::parse_toml("[scenario]\nname = \"x\"").unwrap();
        let mut slot = spec.clone();
        apply_section(&empty, &mut slot).unwrap();
        assert_eq!(slot, spec, "[{}] absent section must keep the slot", S::SECTION);
        // and the default emits nothing at all through the gated path
        let mut silent = String::new();
        emit_section(&S::default(), &mut silent);
        assert!(silent.is_empty(), "[{}] default leaked TOML: {silent:?}", S::SECTION);
    }

    #[test]
    fn every_section_round_trips_generically() {
        roundtrip(IslSpec {
            mode: IslMode::IntraCross,
            max_hops: 2,
            max_range_km: 3500.0,
            hop_delay_slots: 1,
        });
        roundtrip(FederationSpec::split(
            &["ew", "polar"],
            &[0, 1, 0, 1, 0, 1, 0, 1, 0, 1, 0, 1],
            ReconcilePolicy::Periodic { every: 8 },
        ));
        roundtrip(AttackSpec {
            kind: AttackKind::ScaledGrad,
            fraction: 0.25,
            sats: vec![1, 4, 9],
            scale: -20.0,
            drop_prob: 0.125,
            corrupt_prob: 0.0625,
        });
        roundtrip(RobustSpec {
            aggregator: RobustKind::TrimmedMean,
            trim: 0.25,
            krum_f: 1,
            krum_m: 0,
        });
        roundtrip(LinkSpec {
            rate_bytes_per_slot: 2048,
            codec: CodecKind::TopK,
            topk_frac: 0.0625,
        });
        roundtrip(EventSpec { record: true });
        roundtrip(ServeSpec { queue_cap: 4096, batch: 64, shards: 4 });
    }

    #[test]
    fn validate_flows_through_the_trait() {
        // one representative per ctx field, proving ctx actually reaches
        // the inherent validators through the trait surface
        let isl = IslSpec {
            mode: IslMode::IntraPlane,
            max_hops: 4,
            hop_delay_slots: 10,
            ..Default::default()
        };
        let tight = SectionCtx { n_steps: 8, n_sats: 66, n_stations: Some(12) };
        assert!(validate_section(&isl, &tight).is_err(), "hop delay must respect n_steps");
        let attack = AttackSpec { kind: AttackKind::LabelFlip, sats: vec![70], ..Default::default() };
        let ctx = SectionCtx { n_steps: 480, n_sats: 66, n_stations: Some(12) };
        assert!(validate_section(&attack, &ctx).is_err(), "sat 70 outside a 66-sat fleet");
        let fed = FederationSpec::split(&["a", "b"], &[0, 1], ReconcilePolicy::OnAggregate);
        assert!(
            validate_section(&fed, &ctx).is_err(),
            "2-station map against a 12-station network"
        );
        assert!(
            validate_section(&fed, &SectionCtx { n_stations: None, ..ctx }).is_ok(),
            "structure-only validation must pass without a station count"
        );
    }
}
