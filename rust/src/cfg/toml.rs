//! Minimal TOML-subset parser.
//!
//! Supported: `[section]` headers, `key = value` with integers, floats,
//! booleans, double-quoted strings, and flat arrays of those; `#` comments.
//! This covers every config file the framework ships; nested tables and
//! datetimes are intentionally out of scope.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    /// Integer literal.
    Int(i64),
    /// Floating-point literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// Double-quoted string.
    Str(String),
    /// Flat array of the scalar kinds.
    Array(Vec<TomlValue>),
}

impl TomlValue {
    /// As integer, if this is an `Int`.
    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(v) => Some(*v),
            _ => None,
        }
    }

    /// As float; integers widen losslessly.
    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(v) => Some(*v),
            TomlValue::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    /// As bool, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(v) => Some(*v),
            _ => None,
        }
    }

    /// As string slice, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(v) => Some(v),
            _ => None,
        }
    }
}

/// section -> key -> value; keys before any `[section]` land in `""`.
pub type TomlDoc = BTreeMap<String, BTreeMap<String, TomlValue>>;

/// Parse a TOML-subset document.
pub fn parse_toml(text: &str) -> Result<TomlDoc> {
    let mut doc: TomlDoc = BTreeMap::new();
    let mut section = String::new();
    doc.entry(section.clone()).or_default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                bail!("line {}: malformed section header {line:?}", lineno + 1);
            }
            section = line[1..line.len() - 1].trim().to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
        let v = parse_value(value.trim())
            .with_context(|| format!("line {}: bad value {value:?}", lineno + 1))?;
        doc.get_mut(&section).unwrap().insert(key.trim().to_string(), v);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' inside quoted strings must survive
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if s.is_empty() {
        bail!("empty value");
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if s.starts_with('"') {
        if !s.ends_with('"') || s.len() < 2 {
            bail!("unterminated string");
        }
        return Ok(TomlValue::Str(s[1..s.len() - 1].to_string()));
    }
    if s.starts_with('[') {
        if !s.ends_with(']') {
            bail!("unterminated array");
        }
        let inner = &s[1..s.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in split_top_level(inner) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    if let Ok(v) = s.parse::<i64>() {
        return Ok(TomlValue::Int(v));
    }
    if let Ok(v) = s.parse::<f64>() {
        return Ok(TomlValue::Float(v));
    }
    bail!("unrecognized value: {s:?}")
}

/// Split on commas not inside quotes (arrays are flat, no nesting needed).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_scalar_types() {
        let doc = parse_toml(
            r#"
            # top comment
            name = "exp1"
            [run]
            steps = 480      # inline comment
            lr = 0.05
            verbose = true
            "#,
        )
        .unwrap();
        assert_eq!(doc[""]["name"], TomlValue::Str("exp1".into()));
        assert_eq!(doc["run"]["steps"], TomlValue::Int(480));
        assert_eq!(doc["run"]["lr"], TomlValue::Float(0.05));
        assert_eq!(doc["run"]["verbose"], TomlValue::Bool(true));
    }

    #[test]
    fn parses_arrays() {
        let doc = parse_toml("xs = [1, 2, 3]\nnames = [\"a\", \"b,c\"]\nempty = []").unwrap();
        assert_eq!(
            doc[""]["xs"],
            TomlValue::Array(vec![TomlValue::Int(1), TomlValue::Int(2), TomlValue::Int(3)])
        );
        assert_eq!(
            doc[""]["names"],
            TomlValue::Array(vec![
                TomlValue::Str("a".into()),
                TomlValue::Str("b,c".into())
            ])
        );
        assert_eq!(doc[""]["empty"], TomlValue::Array(vec![]));
    }

    #[test]
    fn hash_inside_string_survives() {
        let doc = parse_toml("s = \"a#b\" # trailing").unwrap();
        assert_eq!(doc[""]["s"], TomlValue::Str("a#b".into()));
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_toml("[unclosed").is_err());
        assert!(parse_toml("novalue").is_err());
        assert!(parse_toml("x = @@").is_err());
        assert!(parse_toml("s = \"open").is_err());
    }

    #[test]
    fn accessors() {
        assert_eq!(TomlValue::Int(3).as_float(), Some(3.0));
        assert_eq!(TomlValue::Float(2.5).as_int(), None);
        assert_eq!(TomlValue::Str("x".into()).as_str(), Some("x"));
        assert_eq!(TomlValue::Bool(true).as_bool(), Some(true));
    }
}
