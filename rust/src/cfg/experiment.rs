//! Typed experiment configuration — defaults reproduce the paper's §4.1
//! setup (191 satellites, 12 ground stations, T0 = 15 min, 5 days,
//! FedBuff M = 96, FedSpace I0 = 24, N_min = 4, N_max = 8, |R| = 5000).

use super::scenario::IslSpec;
use super::section::{apply_section, validate_section, SectionCtx};
use super::toml::{parse_toml, TomlDoc, TomlValue};
use crate::fl::{FederationSpec, LinkSpec, RobustSpec, ServeSpec};
use crate::sim::{AttackSpec, EventSpec};
use anyhow::{bail, Context, Result};

/// Which aggregation-indicator algorithm the GS runs (§2.4, Eq. 5–7, §3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlgorithmKind {
    /// Synchronous FL (Eq. 5): wait for every satellite each round.
    Sync,
    /// Asynchronous FL (Eq. 6): aggregate on every upload.
    Async,
    /// FedBuff (Eq. 7): aggregate once M distinct satellites contributed.
    FedBuff,
    /// FedSpace (§3): connectivity-aware scheduled aggregation.
    FedSpace,
}

impl AlgorithmKind {
    /// Parse a CLI/TOML spelling (case-insensitive, accepts long forms).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "sync" | "synchronous" => AlgorithmKind::Sync,
            "async" | "asynchronous" => AlgorithmKind::Async,
            "fedbuff" => AlgorithmKind::FedBuff,
            "fedspace" => AlgorithmKind::FedSpace,
            other => bail!("unknown algorithm {other:?}"),
        })
    }

    /// Canonical lowercase name (inverse of [`Self::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            AlgorithmKind::Sync => "sync",
            AlgorithmKind::Async => "async",
            AlgorithmKind::FedBuff => "fedbuff",
            AlgorithmKind::FedSpace => "fedspace",
        }
    }
}

/// Dataset distribution across satellites (§4.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DataDist {
    /// Uniform random split of the training set.
    Iid,
    /// UTM-zone split driven by each satellite's ground track.
    NonIid,
}

impl DataDist {
    /// Parse a CLI/TOML spelling (`"iid"` / `"noniid"` / `"non-iid"`).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "iid" => DataDist::Iid,
            "noniid" | "non-iid" | "non_iid" => DataDist::NonIid,
            other => bail!("unknown data distribution {other:?}"),
        })
    }
}

/// How the simulation engine walks the time axis.
///
/// All modes execute the identical Algorithm-1 step body and produce
/// bit-identical traces (asserted by `sim::engine` tests); contact-list
/// mode simply skips steps where provably nothing can happen, and streamed
/// mode additionally computes the schedule itself in recyclable chunks.
/// See ADR-0003 and ADR-0004 in `docs/ADRs.md` for the selection
/// rationale and the memory model.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EngineMode {
    /// Visit every time index 0..n_steps (the paper's literal loop).
    #[default]
    Dense,
    /// Advance directly between events (contacts, evaluations, scheduled
    /// aggregations, planner boundaries) derived from the bitset schedule —
    /// the right mode for sparse scenarios where most slots carry no
    /// contact. Still precomputes the whole schedule up front.
    ContactList,
    /// The contact-list walk driven by a
    /// [`crate::connectivity::ConnectivityStream`]: connectivity is
    /// computed chunk by chunk on demand, so peak schedule memory is
    /// O(sats × chunk) — the only mode in which the mega-constellation
    /// scenarios (`walker-starlink-4408`, `kuiper-3236`) are feasible.
    Streamed,
}

impl EngineMode {
    /// Parse a CLI/TOML spelling (`"dense"` / `"contacts"` /
    /// `"contact-list"` / `"streamed"`).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "dense" => EngineMode::Dense,
            "contacts" | "contact-list" | "contact_list" | "sparse" => EngineMode::ContactList,
            "streamed" | "stream" | "chunked" => EngineMode::Streamed,
            other => bail!("unknown engine mode {other:?}"),
        })
    }

    /// Canonical lowercase name (inverse of [`Self::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            EngineMode::Dense => "dense",
            EngineMode::ContactList => "contacts",
            EngineMode::Streamed => "streamed",
        }
    }
}

/// Full experiment configuration.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    // constellation / connectivity
    /// Number of satellites K.
    pub n_sats: usize,
    /// Seed for the constellation builder's jitter.
    pub constellation_seed: u64,
    /// Wall-clock seconds per time index T0 (paper: 15 min).
    pub t0_s: f64,
    /// Simulated time indexes (paper: 480 = 5 days).
    pub n_steps: usize,
    /// Minimum elevation angle α_min [deg].
    pub min_elev_deg: f64,
    // data
    /// IID or trajectory-driven Non-IID partition.
    pub dist: DataDist,
    /// Training-set size.
    pub n_train: usize,
    /// Validation-set size.
    pub n_val: usize,
    /// Per-pixel noise of the synthetic dataset (difficulty knob).
    pub noise_sigma: f32,
    /// Dataset-generation seed.
    pub data_seed: u64,
    // FL
    /// Aggregation-indicator algorithm the GS runs.
    pub algorithm: AlgorithmKind,
    /// FedBuff's M (distinct contributors per aggregation).
    pub fedbuff_m: usize,
    /// Staleness-compensation exponent α of Eq. 4.
    pub alpha: f64,
    /// Local-SGD learning rate.
    pub lr: f32,
    /// Target validation accuracy for time-to-accuracy runs (Table 2).
    pub target_accuracy: f64,
    // FedSpace scheduler
    /// Scheduling-window length I0 in slots.
    pub i0: usize,
    /// Minimum aggregations per window N_min.
    pub n_min: usize,
    /// Maximum aggregations per window N_max.
    pub n_max: usize,
    /// |R| — candidate vectors per random search.
    pub n_search: usize,
    /// Utility samples generated in phase 1.
    pub utility_samples: usize,
    /// Maximum staleness drawn when generating utility samples.
    pub s_max: usize,
    /// Utility regressor kind ("forest" or "linear").
    pub regressor: String,
    // model / runtime
    /// PJRT artifact size ("small" or "fmow").
    pub model_size: String,
    /// Directory holding the AOT artifacts.
    pub artifacts_dir: String,
    // simulation
    /// Engine seed (client RNG streams, planner search).
    pub sim_seed: u64,
    /// Evaluate every this many time indexes.
    pub eval_every: usize,
    /// Worker threads for the parallel hot paths (0 = auto); applied via
    /// `exec::set_default_parallelism` by the runner — a resource knob,
    /// never a semantics knob (results are thread-count independent).
    pub threads: usize,
    /// Dense per-step loop, sparse contact-list event loop, or the
    /// chunk-driven streamed loop.
    pub engine_mode: EngineMode,
    /// Inter-satellite-link model (ADR-0005) — the `[isl]` TOML section,
    /// so `train --config` can enable ISLs without going through a
    /// scenario. Off by default.
    pub isl: IslSpec,
    /// Gateway federation (ADR-0006) — the `[federation]` TOML section.
    /// The station map indexes the runner's planet12 network; the default
    /// single central gateway reproduces the pre-federation engine.
    pub federation: FederationSpec,
    /// Adversary / link-fault injection (ADR-0007) — the `[attack]` TOML
    /// section. Disabled by default: the engine builds no injector and the
    /// run stays bit-identical to the pre-robustness engine.
    pub attack: AttackSpec,
    /// Server-side robust aggregation (ADR-0007) — the `[robust]` TOML
    /// section. The default mean is the plain Eq.-4 aggregator.
    pub robust: RobustSpec,
    /// Link byte budget + upload codec (ADR-0008) — the `[link]` TOML
    /// section. Disabled by default: the engine builds no codec, skips
    /// every capacity check, and runs bit-identical to the pre-link engine.
    pub link: LinkSpec,
    /// Run-event recording (ADR-0009) — the `[events]` TOML section. Off
    /// by default; the event stream still drives the trace either way.
    pub events: EventSpec,
    /// Serving front-end resource shape (ADR-0010) — the `[serve]` TOML
    /// section. Only the `serve`/`loadgen` drivers read it; sim runs
    /// ignore it entirely.
    pub serve: ServeSpec,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            n_sats: 191,
            constellation_seed: 0,
            t0_s: 15.0 * 60.0,
            n_steps: 480, // 5 days at T0 = 15 min
            min_elev_deg: 25.0,
            dist: DataDist::Iid,
            n_train: 19_100,
            n_val: 2_048,
            noise_sigma: 0.8,
            data_seed: 2022,
            algorithm: AlgorithmKind::FedSpace,
            fedbuff_m: 96,
            alpha: 0.5,
            lr: 0.5,
            target_accuracy: 0.40,
            i0: 24,          // scheduler period: 6 h at T0 = 15 min
            n_min: 4,
            n_max: 8,
            n_search: 5000,  // |R|
            utility_samples: 400,
            s_max: 8,
            regressor: "forest".to_string(),
            model_size: "fmow".to_string(),
            artifacts_dir: "artifacts".to_string(),
            sim_seed: 7,
            eval_every: 4,
            threads: 0, // 0 = auto
            engine_mode: EngineMode::Dense,
            isl: IslSpec::default(),
            federation: FederationSpec::single(),
            attack: AttackSpec::default(),
            robust: RobustSpec::default(),
            link: LinkSpec::default(),
            events: EventSpec::default(),
            serve: ServeSpec::default(),
        }
    }
}

macro_rules! get {
    ($doc:ident, $section:expr, $key:expr, $conv:ident, $target:expr) => {
        if let Some(v) = $doc.get($section).and_then(|s| s.get($key)) {
            $target = v
                .$conv()
                .with_context(|| format!("[{}] {} has wrong type", $section, $key))?;
        }
    };
}

trait TomlConv {
    fn to_usize(&self) -> Result<usize>;
    fn to_u64(&self) -> Result<u64>;
    fn to_f64v(&self) -> Result<f64>;
    fn to_f32v(&self) -> Result<f32>;
    fn to_string_v(&self) -> Result<String>;
}

impl TomlConv for TomlValue {
    fn to_usize(&self) -> Result<usize> {
        let v = self.as_int().context("expected integer")?;
        Ok(usize::try_from(v)?)
    }
    fn to_u64(&self) -> Result<u64> {
        let v = self.as_int().context("expected integer")?;
        Ok(u64::try_from(v)?)
    }
    fn to_f64v(&self) -> Result<f64> {
        self.as_float().context("expected number")
    }
    fn to_f32v(&self) -> Result<f32> {
        Ok(self.as_float().context("expected number")? as f32)
    }
    fn to_string_v(&self) -> Result<String> {
        Ok(self.as_str().context("expected string")?.to_string())
    }
}

impl ExperimentConfig {
    /// Parse from TOML text, starting from the paper defaults.
    pub fn from_toml_text(text: &str) -> Result<Self> {
        let doc = parse_toml(text)?;
        Self::from_doc(&doc)
    }

    /// Parse from a TOML file on disk.
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        Self::from_toml_text(&text)
    }

    fn from_doc(doc: &TomlDoc) -> Result<Self> {
        let mut c = ExperimentConfig::default();
        get!(doc, "constellation", "n_sats", to_usize, c.n_sats);
        get!(doc, "constellation", "seed", to_u64, c.constellation_seed);
        get!(doc, "connectivity", "t0_s", to_f64v, c.t0_s);
        get!(doc, "connectivity", "n_steps", to_usize, c.n_steps);
        get!(doc, "connectivity", "min_elev_deg", to_f64v, c.min_elev_deg);
        get!(doc, "data", "n_train", to_usize, c.n_train);
        get!(doc, "data", "n_val", to_usize, c.n_val);
        get!(doc, "data", "noise_sigma", to_f32v, c.noise_sigma);
        get!(doc, "data", "seed", to_u64, c.data_seed);
        if let Some(v) = doc.get("data").and_then(|s| s.get("dist")) {
            c.dist = DataDist::parse(v.as_str().context("dist must be string")?)?;
        }
        if let Some(v) = doc.get("fl").and_then(|s| s.get("algorithm")) {
            c.algorithm = AlgorithmKind::parse(v.as_str().context("algorithm must be string")?)?;
        }
        get!(doc, "fl", "fedbuff_m", to_usize, c.fedbuff_m);
        get!(doc, "fl", "alpha", to_f64v, c.alpha);
        get!(doc, "fl", "lr", to_f32v, c.lr);
        get!(doc, "fl", "target_accuracy", to_f64v, c.target_accuracy);
        get!(doc, "fedspace", "i0", to_usize, c.i0);
        get!(doc, "fedspace", "n_min", to_usize, c.n_min);
        get!(doc, "fedspace", "n_max", to_usize, c.n_max);
        get!(doc, "fedspace", "n_search", to_usize, c.n_search);
        get!(doc, "fedspace", "utility_samples", to_usize, c.utility_samples);
        get!(doc, "fedspace", "s_max", to_usize, c.s_max);
        get!(doc, "fedspace", "regressor", to_string_v, c.regressor);
        get!(doc, "model", "size", to_string_v, c.model_size);
        get!(doc, "model", "artifacts_dir", to_string_v, c.artifacts_dir);
        get!(doc, "sim", "seed", to_u64, c.sim_seed);
        get!(doc, "sim", "eval_every", to_usize, c.eval_every);
        get!(doc, "sim", "threads", to_usize, c.threads);
        if let Some(v) = doc.get("sim").and_then(|s| s.get("engine")) {
            c.engine_mode = EngineMode::parse(v.as_str().context("engine must be string")?)?;
        }
        apply_section(doc, &mut c.isl)?;
        apply_section(doc, &mut c.federation)?;
        apply_section(doc, &mut c.attack)?;
        apply_section(doc, &mut c.robust)?;
        apply_section(doc, &mut c.link)?;
        apply_section(doc, &mut c.events)?;
        apply_section(doc, &mut c.serve)?;
        c.validate()?;
        Ok(c)
    }

    /// Reject configurations the engine or scheduler cannot honour.
    pub fn validate(&self) -> Result<()> {
        if self.n_sats == 0 {
            bail!("n_sats must be > 0");
        }
        if self.t0_s <= 0.0 {
            bail!("t0_s must be positive");
        }
        if self.n_min > self.n_max {
            bail!("n_min > n_max");
        }
        if self.n_max > self.i0 {
            bail!("n_max must be <= i0 (cannot aggregate more often than every slot)");
        }
        if self.fedbuff_m == 0 {
            bail!("fedbuff_m must be > 0");
        }
        if self.eval_every == 0 {
            bail!("eval_every must be > 0 (the engine evaluates on this modulus)");
        }
        if !(0.0..=1.0).contains(&self.target_accuracy) {
            bail!("target_accuracy must be in [0,1]");
        }
        // the station-count half of the federation check runs where the
        // station network is known (the runner against planet12; scenarios
        // validate against their own network) — signalled by the `None`
        // station count in the context
        let ctx = SectionCtx { n_steps: self.n_steps, n_sats: self.n_sats, n_stations: None };
        validate_section(&self.isl, &ctx)?;
        validate_section(&self.federation, &ctx)?;
        validate_section(&self.attack, &ctx)?;
        validate_section(&self.robust, &ctx)?;
        validate_section(&self.link, &ctx)?;
        validate_section(&self.events, &ctx)?;
        validate_section(&self.serve, &ctx)?;
        if self.link.capacity_enabled() && self.isl.enabled() {
            bail!(
                "[link] byte budgets and [isl] routing are mutually exclusive: a relayed \
                 contact has no single pass duration to budget against"
            );
        }
        Ok(())
    }

    /// Simulated days per time index.
    pub fn days_per_step(&self) -> f64 {
        self.t0_s / 86_400.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = ExperimentConfig::default();
        assert_eq!(c.n_sats, 191);
        assert_eq!(c.n_steps, 480);
        assert_eq!(c.fedbuff_m, 96);
        assert_eq!(c.i0, 24);
        assert_eq!((c.n_min, c.n_max), (4, 8));
        assert_eq!(c.n_search, 5000);
        assert!((c.t0_s - 900.0).abs() < 1e-9);
        c.validate().unwrap();
    }

    #[test]
    fn from_toml_overrides() {
        let c = ExperimentConfig::from_toml_text(
            r#"
            [constellation]
            n_sats = 20
            [fl]
            algorithm = "fedbuff"
            fedbuff_m = 10
            [data]
            dist = "noniid"
            [model]
            size = "small"
            "#,
        )
        .unwrap();
        assert_eq!(c.n_sats, 20);
        assert_eq!(c.algorithm, AlgorithmKind::FedBuff);
        assert_eq!(c.fedbuff_m, 10);
        assert_eq!(c.dist, DataDist::NonIid);
        assert_eq!(c.model_size, "small");
        // untouched default preserved
        assert_eq!(c.i0, 24);
    }

    #[test]
    fn rejects_invalid() {
        assert!(ExperimentConfig::from_toml_text("[fedspace]\nn_min = 10\nn_max = 2").is_err());
        assert!(ExperimentConfig::from_toml_text("[fl]\nalgorithm = \"sgd\"").is_err());
        assert!(ExperimentConfig::from_toml_text("[constellation]\nn_sats = 0").is_err());
        // would divide by zero in the engine's evaluation modulus
        assert!(ExperimentConfig::from_toml_text("[sim]\neval_every = 0").is_err());
    }

    #[test]
    fn isl_section_reaches_the_config_path() {
        // ROADMAP item: `train --config` can enable ISLs
        let c = ExperimentConfig::from_toml_text(
            "[isl]\nmode = \"intra-cross\"\nmax_hops = 2\nmax_range_km = 3000.0\n\
             hop_delay_slots = 1",
        )
        .unwrap();
        assert!(c.isl.enabled());
        assert_eq!(c.isl.max_hops, 2);
        assert_eq!(c.isl.hop_delay_slots, 1);
        assert!(!ExperimentConfig::default().isl.enabled());
        // bounds enforced on the config path too
        assert!(ExperimentConfig::from_toml_text("[isl]\nmode = \"ring\"\nmax_hops = 0").is_err());
        assert!(ExperimentConfig::from_toml_text(
            "[connectivity]\nn_steps = 10\n[isl]\nmode = \"ring\"\nmax_hops = 3\n\
             hop_delay_slots = 100"
        )
        .is_err());
    }

    #[test]
    fn federation_section_reaches_the_config_path() {
        let c = ExperimentConfig::from_toml_text(
            "[federation]\ngateways = [\"a\", \"b\"]\n\
             stations = [0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 1, 1]\n\
             reconcile = \"periodic\"\nevery = 24",
        )
        .unwrap();
        assert_eq!(c.federation.n_gateways(), 2);
        assert!(ExperimentConfig::default().federation.is_default());
        // structural rejection at parse time (duplicate names, zero cadence)
        assert!(ExperimentConfig::from_toml_text(
            "[federation]\ngateways = [\"a\", \"a\"]\nstations = [0, 1]"
        )
        .is_err());
        assert!(ExperimentConfig::from_toml_text(
            "[federation]\ngateways = [\"a\", \"b\"]\nstations = [0, 1]\n\
             reconcile = \"periodic\"\nevery = 0"
        )
        .is_err());
    }

    #[test]
    fn attack_and_robust_sections_reach_the_config_path() {
        let c = ExperimentConfig::from_toml_text(
            "[attack]\nkind = \"scaled-grad\"\nfraction = 0.2\nscale = -5.0\n\
             drop_prob = 0.05\n\n[robust]\naggregator = \"trimmed-mean\"\ntrim = 0.25",
        )
        .unwrap();
        assert!(c.attack.enabled());
        assert!((c.attack.fraction - 0.2).abs() < 1e-12);
        assert!(!c.robust.is_default());
        assert!(!ExperimentConfig::default().attack.enabled());
        assert!(ExperimentConfig::default().robust.is_default());
        // bounds enforced on the config path too
        assert!(ExperimentConfig::from_toml_text("[attack]\nkind = \"label-flip\"\nfraction = 1.5")
            .is_err());
        assert!(ExperimentConfig::from_toml_text("[robust]\naggregator = \"median\"\ntrim = 0.5")
            .is_err());
        // an attack that selects no adversaries is rejected against n_sats
        assert!(ExperimentConfig::from_toml_text(
            "[constellation]\nn_sats = 4\n[attack]\nkind = \"label-flip\"\nfraction = 0.05"
        )
        .is_err());
    }

    #[test]
    fn link_section_reaches_the_config_path() {
        use crate::fl::CodecKind;
        let c = ExperimentConfig::from_toml_text(
            "[link]\nrate_bytes_per_slot = 1500000\ncodec = \"top-k\"\ntopk_frac = 0.02",
        )
        .unwrap();
        assert!(c.link.enabled() && c.link.capacity_enabled());
        assert_eq!(c.link.codec, CodecKind::TopK);
        assert!((c.link.topk_frac - 0.02).abs() < 1e-12);
        assert!(!ExperimentConfig::default().link.enabled());
        // bounds enforced on the config path too
        assert!(ExperimentConfig::from_toml_text(
            "[link]\ncodec = \"top-k\"\ntopk_frac = 0.0"
        )
        .is_err());
        assert!(ExperimentConfig::from_toml_text("[link]\ncodec = \"gzip\"").is_err());
        // byte budgets and ISL relays cannot combine: a relayed contact
        // has no single pass duration
        assert!(ExperimentConfig::from_toml_text(
            "[link]\nrate_bytes_per_slot = 1000\n[isl]\nmode = \"ring\""
        )
        .is_err());
        // codec-only compression composes with ISLs (no capacity check)
        let c = ExperimentConfig::from_toml_text(
            "[link]\ncodec = \"quant-q8\"\n[isl]\nmode = \"ring\"",
        )
        .unwrap();
        assert!(c.link.enabled() && !c.link.capacity_enabled());
    }

    #[test]
    fn serve_section_reaches_the_config_path() {
        let c = ExperimentConfig::from_toml_text(
            "[serve]\nqueue_cap = 64\nbatch = 16\nshards = 2",
        )
        .unwrap();
        assert_eq!((c.serve.queue_cap, c.serve.batch, c.serve.shards), (64, 16, 2));
        assert!(ExperimentConfig::default().serve.is_default());
        // bounds enforced on the config path too
        assert!(ExperimentConfig::from_toml_text("[serve]\nqueue_cap = 0").is_err());
        assert!(ExperimentConfig::from_toml_text("[serve]\nbatch = 0").is_err());
    }

    #[test]
    fn days_per_step() {
        let c = ExperimentConfig::default();
        assert!((c.days_per_step() - 1.0 / 96.0).abs() < 1e-12);
    }

    #[test]
    fn algorithm_roundtrip() {
        for k in ["sync", "async", "fedbuff", "fedspace"] {
            assert_eq!(AlgorithmKind::parse(k).unwrap().name(), k);
        }
    }

    #[test]
    fn engine_mode_parse_and_toml() {
        assert_eq!(EngineMode::parse("dense").unwrap(), EngineMode::Dense);
        for s in ["contacts", "contact-list", "contact_list", "sparse"] {
            assert_eq!(EngineMode::parse(s).unwrap(), EngineMode::ContactList);
        }
        for s in ["streamed", "stream", "chunked"] {
            assert_eq!(EngineMode::parse(s).unwrap(), EngineMode::Streamed);
        }
        assert_eq!(EngineMode::Streamed.name(), "streamed");
        assert!(EngineMode::parse("warp").is_err());
        let c = ExperimentConfig::from_toml_text("[sim]\nengine = \"contacts\"").unwrap();
        assert_eq!(c.engine_mode, EngineMode::ContactList);
        assert_eq!(ExperimentConfig::default().engine_mode, EngineMode::Dense);
    }
}
