//! Metrics: histograms, training curves, CSV emission — the plumbing behind
//! every figure and table the benches regenerate.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Integer-bucket histogram (staleness values, idle counts, n_k, ...).
#[derive(Clone, Debug, Default)]
pub struct Histogram {
    counts: BTreeMap<i64, u64>,
    total: u64,
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Count one observation of `v`.
    pub fn add(&mut self, v: i64) {
        *self.counts.entry(v).or_insert(0) += 1;
        self.total += 1;
    }

    /// Count `n` observations of `v`.
    pub fn add_n(&mut self, v: i64, n: u64) {
        if n > 0 {
            *self.counts.entry(v).or_insert(0) += n;
            self.total += n;
        }
    }

    /// Observations of exactly `v`.
    pub fn count(&self, v: i64) -> u64 {
        self.counts.get(&v).copied().unwrap_or(0)
    }

    /// Total observations.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// (value, count) pairs in ascending value order.
    pub fn entries(&self) -> impl Iterator<Item = (i64, u64)> + '_ {
        self.counts.iter().map(|(&k, &v)| (k, v))
    }

    /// Largest observed value, if any.
    pub fn max_key(&self) -> Option<i64> {
        self.counts.keys().next_back().copied()
    }

    /// Add every entry of `other` into this histogram.
    pub fn merge(&mut self, other: &Histogram) {
        for (k, v) in other.entries() {
            self.add_n(k, v);
        }
    }

    /// Render `value,count` CSV.
    pub fn to_csv(&self, header: &str) -> String {
        let mut s = format!("{header}\n");
        for (k, v) in self.entries() {
            let _ = writeln!(s, "{k},{v}");
        }
        s
    }
}

/// One evaluation point of a training run.
#[derive(Clone, Copy, Debug)]
pub struct CurvePoint {
    /// simulated days since start
    pub day: f64,
    /// time index i
    pub step: usize,
    /// global round index i_g
    pub round: usize,
    /// Validation top-1 accuracy.
    pub accuracy: f64,
    /// Validation loss.
    pub loss: f64,
}

/// A training curve (Figure 6 series) with target-time extraction (Table 2).
#[derive(Clone, Debug, Default)]
pub struct TrainingCurve {
    /// Evaluation points in chronological order.
    pub points: Vec<CurvePoint>,
}

impl TrainingCurve {
    /// Append one evaluation point.
    pub fn push(&mut self, p: CurvePoint) {
        self.points.push(p);
    }

    /// Best accuracy seen over the run (0.0 for an empty curve).
    pub fn best_accuracy(&self) -> f64 {
        self.points.iter().map(|p| p.accuracy).fold(0.0, f64::max)
    }

    /// First simulated day at which accuracy ≥ target (Table 2's metric);
    /// `None` if never reached — the paper's "-" entry for async FL.
    pub fn days_to_accuracy(&self, target: f64) -> Option<f64> {
        self.points.iter().find(|p| p.accuracy >= target).map(|p| p.day)
    }

    /// Render `day,step,round,accuracy,loss` CSV.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("day,step,round,accuracy,loss\n");
        for p in &self.points {
            let _ = writeln!(
                s,
                "{:.4},{},{},{:.4},{:.4}",
                p.day, p.step, p.round, p.accuracy, p.loss
            );
        }
        s
    }
}

/// Simple aligned-table writer for bench output (criterion substitute).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// A table with the given column headers.
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append one row (must match the header count).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with right-aligned, width-fitted columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = line(&self.headers);
        out.push('\n');
        out.push_str(&"-".repeat(out.len().saturating_sub(1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }
}

/// Write a string to a file, creating parent dirs.
pub fn write_file(path: &str, contents: &str) -> std::io::Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, contents)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_counts() {
        let mut h = Histogram::new();
        h.add(0);
        h.add(0);
        h.add(3);
        h.add(-1);
        assert_eq!(h.count(0), 2);
        assert_eq!(h.count(3), 1);
        assert_eq!(h.count(-1), 1);
        assert_eq!(h.count(7), 0);
        assert_eq!(h.total(), 4);
        assert_eq!(h.max_key(), Some(3));
    }

    #[test]
    fn histogram_merge() {
        let mut a = Histogram::new();
        a.add(1);
        let mut b = Histogram::new();
        b.add(1);
        b.add(2);
        a.merge(&b);
        assert_eq!(a.count(1), 2);
        assert_eq!(a.count(2), 1);
        assert_eq!(a.total(), 3);
    }

    #[test]
    fn curve_days_to_accuracy() {
        let mut c = TrainingCurve::default();
        for (day, acc) in [(0.5, 0.1), (1.0, 0.35), (1.5, 0.42), (2.0, 0.45)] {
            c.push(CurvePoint { day, step: 0, round: 0, accuracy: acc, loss: 1.0 });
        }
        assert_eq!(c.days_to_accuracy(0.40), Some(1.5));
        assert_eq!(c.days_to_accuracy(0.50), None);
        assert!((c.best_accuracy() - 0.45).abs() < 1e-12);
    }

    #[test]
    fn curve_csv_header_and_rows() {
        let mut c = TrainingCurve::default();
        c.push(CurvePoint { day: 0.25, step: 24, round: 3, accuracy: 0.2, loss: 3.9 });
        let csv = c.to_csv();
        assert!(csv.starts_with("day,step,round,accuracy,loss\n"));
        assert!(csv.contains("0.2500,24,3,0.2000,3.9000"));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["scheme", "days"]);
        t.row(&["sync".into(), "30.3".into()]);
        t.row(&["fedspace".into(), "2.3".into()]);
        let s = t.render();
        assert!(s.contains("scheme"));
        assert!(s.contains("fedspace"));
    }
}
