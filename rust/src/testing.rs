//! Mini property-testing framework — substrate built from scratch (no
//! `proptest` in the offline vendor set).
//!
//! Usage mirrors the proptest idiom the coordinator tests rely on:
//!
//! ```no_run
//! use fedspace::testing::property;
//! property(100, |rng| {
//!     let n = rng.gen_range(1, 50);
//!     let xs = (0..n).map(|_| rng.next_f32()).collect::<Vec<_>>();
//!     let s: f32 = xs.iter().sum();
//!     assert!(s >= 0.0);
//! });
//! ```
//!
//! Each case runs with an independently seeded [`crate::rng::Rng`]; on panic
//! the failing case's seed is printed so the case replays deterministically
//! via [`replay`].

use crate::rng::Rng;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Base seed for property runs. Override with env `FEDSPACE_PROP_SEED` to
/// reproduce CI failures locally.
fn base_seed() -> u64 {
    std::env::var("FEDSPACE_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xFED5_9ACE)
}

/// Run `f` against `cases` independently-seeded RNGs; panic with the failing
/// seed on the first failure.
pub fn property<F: Fn(&mut Rng)>(cases: u64, f: F) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base.wrapping_add(case).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        let result = catch_unwind(AssertUnwindSafe(|| f(&mut rng)));
        if let Err(err) = result {
            eprintln!(
                "property case {case}/{cases} FAILED — replay with \
                 fedspace::testing::replay({seed:#x}, ..)"
            );
            std::panic::resume_unwind(err);
        }
    }
}

/// Replay a single failing property case by seed.
pub fn replay<F: Fn(&mut Rng)>(seed: u64, f: F) {
    let mut rng = Rng::new(seed);
    f(&mut rng);
}

/// Assert two engine runs are equivalent on everything deterministic —
/// counters, staleness histogram, curve accuracy/loss bits, final model
/// bits, and (when recorded) the full typed event streams; wall-clock
/// timing fields — and [`RunEvent::Timing`] events — are exempt by design
/// (ADR-0002).
///
/// This is the single dense-vs-contact-list equivalence gate shared by the
/// engine unit tests, `tests/scenarios.rs`, and `bench_engine_modes` (the
/// bench asserts identity before reporting any speedup), so adding a field
/// to `RunTrace` only needs strengthening one checker. Event streams are a
/// strictly stronger check than the derived counters (ordering and
/// per-event payloads, not just totals); runs made without
/// `record_events` carry empty streams and the comparison is vacuous.
pub fn assert_same_run(a: &crate::sim::RunResult, b: &crate::sim::RunResult, ctx: &str) {
    assert_eq!(a.final_round, b.final_round, "{ctx}: final_round");
    assert_eq!(a.trace.connections, b.trace.connections, "{ctx}: connections");
    assert_eq!(a.trace.uploads, b.trace.uploads, "{ctx}: uploads");
    assert_eq!(a.trace.relayed, b.trace.relayed, "{ctx}: relayed uploads");
    assert_eq!(a.trace.idle, b.trace.idle, "{ctx}: idle");
    assert_eq!(a.trace.global_updates, b.trace.global_updates, "{ctx}: global_updates");
    assert_eq!(a.trace.gateway_aggs, b.trace.gateway_aggs, "{ctx}: per-gateway aggregations");
    assert_eq!(
        a.trace.gateway_uploads, b.trace.gateway_uploads,
        "{ctx}: per-gateway uploads"
    );
    assert_eq!(a.trace.reconciles, b.trace.reconciles, "{ctx}: reconcile merges");
    assert_eq!(a.trace.injected, b.trace.injected, "{ctx}: injected uploads");
    assert_eq!(a.trace.dropped, b.trace.dropped, "{ctx}: dropped uploads");
    assert_eq!(a.trace.corrupted, b.trace.corrupted, "{ctx}: corrupted uploads");
    assert_eq!(a.trace.deferred, b.trace.deferred, "{ctx}: capacity-deferred uploads");
    assert_eq!(
        a.trace.staleness.entries().collect::<Vec<_>>(),
        b.trace.staleness.entries().collect::<Vec<_>>(),
        "{ctx}: staleness histogram"
    );
    assert_eq!(a.days_to_target, b.days_to_target, "{ctx}: days_to_target");
    assert_eq!(a.trace.curve.points.len(), b.trace.curve.points.len(), "{ctx}: curve length");
    for (p, q) in a.trace.curve.points.iter().zip(b.trace.curve.points.iter()) {
        assert_eq!(p.step, q.step, "{ctx}: curve step");
        assert_eq!(p.round, q.round, "{ctx}: curve round");
        assert_eq!(p.accuracy.to_bits(), q.accuracy.to_bits(), "{ctx}: accuracy bits");
        assert_eq!(p.loss.to_bits(), q.loss.to_bits(), "{ctx}: loss bits");
    }
    assert_eq!(a.final_w.len(), b.final_w.len(), "{ctx}: model dim");
    for (x, y) in a.final_w.iter().zip(b.final_w.iter()) {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: final_w bits");
    }
    // recorded event streams, with the wall-clock-dependent Timing events
    // filtered out (the stream analogue of the timing-field exemption)
    let ea: Vec<_> = a.events.iter().filter(|e| e.is_deterministic()).collect();
    let eb: Vec<_> = b.events.iter().filter(|e| e.is_deterministic()).collect();
    assert_eq!(ea.len(), eb.len(), "{ctx}: event count");
    for (idx, (x, y)) in ea.iter().zip(eb.iter()).enumerate() {
        assert_eq!(x, y, "{ctx}: event #{idx}");
    }
}

/// Assert two f32 slices are element-wise close.
pub fn assert_allclose(got: &[f32], want: &[f32], rtol: f32, atol: f32) {
    assert_eq!(got.len(), want.len(), "length mismatch");
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        let tol = atol + rtol * w.abs();
        assert!(
            (g - w).abs() <= tol,
            "mismatch at {i}: got {g}, want {w} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn property_runs_all_cases() {
        let mut count = 0u64;
        // count via a cell captured by the closure
        let counter = std::cell::Cell::new(0u64);
        property(25, |_| counter.set(counter.get() + 1));
        count += counter.get();
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic]
    fn property_propagates_failure() {
        property(10, |rng| {
            let v = rng.next_f64();
            assert!(v < 0.5, "intentional failure for v={v}");
        });
    }

    #[test]
    fn allclose_accepts_equal() {
        assert_allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-5);
    }

    #[test]
    #[should_panic]
    fn allclose_rejects_far() {
        assert_allclose(&[1.0], &[2.0], 1e-5, 1e-5);
    }
}
