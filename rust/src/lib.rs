//! # FedSpace
//!
//! A production-quality reproduction of *FedSpace: An Efficient Federated
//! Learning Framework at Satellites and Ground Stations* (So et al., 2022)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate)** — the ground-station coordinator: connectivity
//!   prediction from orbital mechanics, the Sync/Async/FedBuff baselines,
//!   the FedSpace aggregation scheduler (utility regression + random
//!   search), and the discrete-time simulation engine of Algorithm 1.
//! - **Layer 2** — the satellite workload (frozen-extractor classifier)
//!   written in JAX, AOT-lowered to HLO text in `artifacts/`.
//! - **Layer 1** — Pallas kernels (tiled matmul, staleness-weighted
//!   aggregation) inside the L2 graph.
//!
//! Python never runs at coordination time: `runtime` loads the HLO text via
//! the PJRT C API and executes it natively.
//!
//! See the top-level README.md for the quickstart and scenario catalog,
//! docs/ADRs.md for the architecture decision records, and EXPERIMENTS.md
//! for the paper-vs-measured record of every table and figure.

#![forbid(unsafe_code)]
#![warn(missing_docs)]

pub mod analysis;
pub mod app;
pub mod bench_report;
pub mod bench_util;
pub mod cfg;
pub mod connectivity;
pub mod data;
pub mod exec;
pub mod fl;
pub mod metrics;
pub mod ml;
pub mod orbit;
pub mod rng;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod testing;
