//! Inter-satellite-link geometry — which satellite pairs *can* maintain a
//! link, derived deterministically from the Walker plane structure
//! (ADR-0005 in docs/ADRs.md).
//!
//! Two link families, following the standard "+grid" LEO network model
//! (Matthiesen et al. 2023, arXiv:2206.00307; Elmahallawy & Luo 2023):
//!
//! - **intra-plane ring**: each satellite keeps a permanent link to its two
//!   in-plane neighbors (previous/next by argument of latitude). In-plane
//!   relative geometry is static for station-kept shells, so these edges
//!   are time-invariant.
//! - **cross-plane candidates**: satellites in *adjacent* planes of the
//!   same group (shell/flock) may link, but only while within a maximum
//!   slant range — cross-plane relative geometry oscillates over an orbit,
//!   so these edges are range-gated per time step by the routing layer
//!   ([`crate::connectivity::IslTopology`]).
//!
//! Links never cross groups (different shells fly at different altitudes),
//! and plane adjacency wraps around the RAAN circle; for Walker-star
//! shells the wrap pair models the seam, where the range gate — counter-
//! rotating planes separate fast — keeps links short-lived, matching how
//! real star constellations treat seam crossings as opportunistic.

use super::constellation::Constellation;
use super::kepler::{OrbitBasis, Vec3};
use anyhow::{ensure, Result};
use std::collections::BTreeMap;

/// The static link-candidate structure of a constellation: intra-plane
/// rings plus adjacent-plane candidate lists, with the orbit bases needed
/// to evaluate the cross-plane range gate at any instant.
#[derive(Clone, Debug)]
pub struct IslGeometry {
    n_sats: usize,
    /// ring[k] = the (≤ 2) in-plane ring neighbors of satellite k, sorted.
    ring: Vec<Vec<usize>>,
    /// cross[k] = satellites in planes adjacent to k's plane (same group),
    /// sorted — candidates only; the range gate decides per instant.
    cross: Vec<Vec<usize>>,
    bases: Vec<OrbitBasis>,
}

impl IslGeometry {
    /// Derive the link-candidate structure from a constellation's plane
    /// metadata. Fails when the constellation was assembled by hand and
    /// carries no [`crate::orbit::PlaneId`]s.
    pub fn new(constellation: &Constellation) -> Result<Self> {
        let n = constellation.len();
        ensure!(
            constellation.plane_ids.len() == n,
            "constellation carries no plane metadata ({} ids for {} satellites) — \
             ISLs need a spec-driven builder (walker / from_specs / shells)",
            constellation.plane_ids.len(),
            n
        );
        let mut by_plane: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
        for (k, pid) in constellation.plane_ids.iter().enumerate() {
            by_plane.entry((pid.group, pid.plane)).or_default().push(k);
        }

        // intra-plane rings, ordered by argument of latitude at epoch
        let mut ring = vec![Vec::new(); n];
        for members in by_plane.values() {
            let mut m = members.clone();
            m.sort_by(|&a, &b| {
                constellation.orbits[a]
                    .phase0
                    .total_cmp(&constellation.orbits[b].phase0)
                    .then(a.cmp(&b))
            });
            match m.len() {
                0 | 1 => {}
                2 => {
                    ring[m[0]].push(m[1]);
                    ring[m[1]].push(m[0]);
                }
                len => {
                    for idx in 0..len {
                        let (u, v) = (m[idx], m[(idx + 1) % len]);
                        ring[u].push(v);
                        ring[v].push(u);
                    }
                }
            }
        }
        for r in &mut ring {
            r.sort_unstable();
            r.dedup();
        }

        // cross-plane candidates: adjacent planes within each group
        let mut planes_by_group: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for &(group, plane) in by_plane.keys() {
            planes_by_group.entry(group).or_default().push(plane);
        }
        let mut cross = vec![Vec::new(); n];
        for (group, planes) in &planes_by_group {
            let np = planes.len();
            if np < 2 {
                continue;
            }
            for idx in 0..np {
                // consecutive pairs + RAAN wrap; with exactly two planes the
                // wrap collapses onto the single pair, so emit it once
                if np == 2 && idx == 1 {
                    continue;
                }
                let (p, q) = (planes[idx], planes[(idx + 1) % np]);
                for &u in &by_plane[&(*group, p)] {
                    for &v in &by_plane[&(*group, q)] {
                        cross[u].push(v);
                        cross[v].push(u);
                    }
                }
            }
        }
        for c in &mut cross {
            c.sort_unstable();
            c.dedup();
        }

        Ok(IslGeometry {
            n_sats: n,
            ring,
            cross,
            bases: constellation.orbits.iter().map(|o| o.basis()).collect(),
        })
    }

    /// Number of satellites the geometry covers.
    pub fn n_sats(&self) -> usize {
        self.n_sats
    }

    /// In-plane ring neighbors of satellite `k` (0, 1 or 2 ids, sorted).
    pub fn ring_neighbors(&self, k: usize) -> &[usize] {
        &self.ring[k]
    }

    /// Adjacent-plane link candidates of satellite `k`, sorted.
    pub fn cross_candidates(&self, k: usize) -> &[usize] {
        &self.cross[k]
    }

    /// ECI position of satellite `k` at time `t` [s after epoch].
    pub fn position_at(&self, k: usize, t: f64) -> Vec3 {
        self.bases[k].position_eci(t)
    }

    /// ECI positions of every satellite at time `t`, into a recycled buffer.
    pub fn positions_at(&self, t: f64, out: &mut Vec<Vec3>) {
        out.clear();
        out.extend(self.bases.iter().map(|b| b.position_eci(t)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orbit::{planet_labs_like, WalkerPattern, WalkerSpec};

    fn iridium_like() -> Constellation {
        Constellation::walker(&WalkerSpec {
            pattern: WalkerPattern::Star,
            n_sats: 66,
            planes: 6,
            phasing: 2,
            alt_m: 780e3,
            inc_deg: 86.4,
        })
    }

    #[test]
    fn ring_gives_every_satellite_two_in_plane_neighbors() {
        let g = IslGeometry::new(&iridium_like()).unwrap();
        for k in 0..66 {
            assert_eq!(g.ring_neighbors(k).len(), 2, "sat {k}");
            for &v in g.ring_neighbors(k) {
                assert!(g.ring_neighbors(v).contains(&k), "{k} <-> {v} asymmetric");
            }
        }
    }

    #[test]
    fn cross_candidates_are_adjacent_planes_only() {
        let c = iridium_like();
        let g = IslGeometry::new(&c).unwrap();
        for k in 0..66 {
            let pk = c.plane_ids[k].plane as i64;
            // 11 satellites per adjacent plane, 2 adjacent planes
            assert_eq!(g.cross_candidates(k).len(), 22, "sat {k}");
            for &v in g.cross_candidates(k) {
                let pv = c.plane_ids[v].plane as i64;
                let dp = (pk - pv).rem_euclid(6);
                assert!(dp == 1 || dp == 5, "sat {k} (plane {pk}) links plane {pv}");
                assert!(g.cross_candidates(v).contains(&k), "{k} <-> {v} asymmetric");
            }
        }
    }

    #[test]
    fn two_plane_group_links_each_plane_once() {
        let c = Constellation::walker(&WalkerSpec {
            pattern: WalkerPattern::Delta,
            n_sats: 8,
            planes: 2,
            phasing: 1,
            alt_m: 550e3,
            inc_deg: 53.0,
        });
        let g = IslGeometry::new(&c).unwrap();
        for k in 0..8 {
            // 4 satellites in the single other plane, no duplicates
            assert_eq!(g.cross_candidates(k).len(), 4, "sat {k}");
        }
    }

    #[test]
    fn jittered_fleet_rings_stay_within_planes() {
        let c = planet_labs_like(40, 3);
        let g = IslGeometry::new(&c).unwrap();
        for k in 0..40 {
            for &v in g.ring_neighbors(k) {
                assert_eq!(c.plane_ids[k], c.plane_ids[v], "{k} ringed across planes to {v}");
            }
            for &v in g.cross_candidates(k) {
                assert_eq!(c.plane_ids[k].group, c.plane_ids[v].group, "{k} crossed groups");
                assert_ne!(c.plane_ids[k].plane, c.plane_ids[v].plane);
            }
        }
    }

    #[test]
    fn handmade_constellation_is_rejected() {
        let mut c = planet_labs_like(5, 0);
        c.plane_ids.clear();
        assert!(IslGeometry::new(&c).is_err());
    }

    #[test]
    fn single_satellite_plane_has_no_ring() {
        let c = Constellation::walker(&WalkerSpec {
            pattern: WalkerPattern::Delta,
            n_sats: 3,
            planes: 3,
            phasing: 0,
            alt_m: 550e3,
            inc_deg: 53.0,
        });
        let g = IslGeometry::new(&c).unwrap();
        for k in 0..3 {
            assert!(g.ring_neighbors(k).is_empty(), "sat {k}");
            // every other plane is adjacent on the 3-plane circle
            assert_eq!(g.cross_candidates(k).len(), 2);
        }
    }
}
