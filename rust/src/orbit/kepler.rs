//! Circular Keplerian two-body orbit propagation (ECI frame).

use super::earth::MU_EARTH;

/// Minimal 3-vector (no external linear-algebra crate offline).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Vec3 {
    /// x component.
    pub x: f64,
    /// y component.
    pub y: f64,
    /// z component.
    pub z: f64,
}

impl Vec3 {
    /// The origin.
    pub const ZERO: Vec3 = Vec3 { x: 0.0, y: 0.0, z: 0.0 };

    /// Construct from components.
    pub fn new(x: f64, y: f64, z: f64) -> Self {
        Vec3 { x, y, z }
    }

    /// Dot product.
    pub fn dot(&self, o: &Vec3) -> f64 {
        self.x * o.x + self.y * o.y + self.z * o.z
    }

    /// Euclidean norm.
    pub fn norm(&self) -> f64 {
        self.dot(self).sqrt()
    }

    /// Component-wise difference `self − o`.
    pub fn sub(&self, o: &Vec3) -> Vec3 {
        Vec3::new(self.x - o.x, self.y - o.y, self.z - o.z)
    }

    /// Scalar multiple.
    pub fn scale(&self, s: f64) -> Vec3 {
        Vec3::new(self.x * s, self.y * s, self.z * s)
    }

    /// Unit vector in this direction; panics on the zero vector.
    pub fn normalized(&self) -> Vec3 {
        let n = self.norm();
        assert!(n > 0.0, "normalizing zero vector");
        self.scale(1.0 / n)
    }
}

/// A circular orbit described by semi-major axis, inclination, RAAN and an
/// initial argument of latitude (phase along the orbit at t = 0).
#[derive(Clone, Copy, Debug)]
pub struct CircularOrbit {
    /// Semi-major axis [m] (= orbital radius for circular orbits).
    pub a: f64,
    /// Inclination [rad].
    pub inc: f64,
    /// Right ascension of the ascending node [rad].
    pub raan: f64,
    /// Argument of latitude at epoch [rad].
    pub phase0: f64,
}

impl CircularOrbit {
    /// Construct from altitude above the (spherical) Earth surface [m].
    pub fn from_altitude(alt_m: f64, inc_rad: f64, raan_rad: f64, phase0_rad: f64) -> Self {
        CircularOrbit {
            a: super::earth::R_EARTH_EQ + alt_m,
            inc: inc_rad,
            raan: raan_rad,
            phase0: phase0_rad,
        }
    }

    /// Mean motion n = sqrt(mu / a^3) [rad/s].
    pub fn mean_motion(&self) -> f64 {
        (MU_EARTH / (self.a * self.a * self.a)).sqrt()
    }

    /// Orbital period [s].
    pub fn period_s(&self) -> f64 {
        2.0 * std::f64::consts::PI / self.mean_motion()
    }

    /// ECI position at time `t` seconds after epoch.
    ///
    /// For a circular orbit the argument of latitude advances linearly:
    /// u(t) = phase0 + n·t. Position is the perifocal circle rotated by
    /// inclination about x, then RAAN about z.
    pub fn position_eci(&self, t: f64) -> Vec3 {
        let u = self.phase0 + self.mean_motion() * t;
        let (su, cu) = u.sin_cos();
        let (si, ci) = self.inc.sin_cos();
        let (so, co) = self.raan.sin_cos();
        // In-plane coordinates.
        let xp = self.a * cu;
        let yp = self.a * su;
        // Rotate by inclination (about x), then RAAN (about z).
        Vec3::new(
            xp * co - yp * ci * so,
            xp * so + yp * ci * co,
            yp * si,
        )
    }

    /// Precompute the constant part of [`Self::position_eci`] for hot loops.
    pub fn basis(&self) -> OrbitBasis {
        let (si, ci) = self.inc.sin_cos();
        let (so, co) = self.raan.sin_cos();
        OrbitBasis {
            ap: Vec3::new(self.a * co, self.a * so, 0.0),
            aq: Vec3::new(-self.a * ci * so, self.a * ci * co, self.a * si),
            n: self.mean_motion(),
            phase0: self.phase0,
        }
    }
}

/// Hoisted propagation state of one circular orbit: the scaled in-plane ECI
/// basis vectors (a·P, a·Q), mean motion and phase, so that a position in a
/// hot loop is one `sin_cos` plus six multiplies —
/// r(t) = cos(u)·aP + sin(u)·aQ with u = phase0 + n·t — instead of four
/// trig pairs and a square root per call.
#[derive(Clone, Copy, Debug)]
pub struct OrbitBasis {
    /// a·P: in-plane x basis scaled by the orbital radius.
    pub ap: Vec3,
    /// a·Q: in-plane y basis scaled by the orbital radius.
    pub aq: Vec3,
    /// Mean motion [rad/s].
    pub n: f64,
    /// Argument of latitude at epoch [rad].
    pub phase0: f64,
}

impl OrbitBasis {
    /// ECI position at time `t` (same trajectory as
    /// [`CircularOrbit::position_eci`] up to floating-point reassociation).
    #[inline]
    pub fn position_eci(&self, t: f64) -> Vec3 {
        let (su, cu) = (self.phase0 + self.n * t).sin_cos();
        Vec3::new(
            cu * self.ap.x + su * self.aq.x,
            cu * self.ap.y + su * self.aq.y,
            cu * self.ap.z + su * self.aq.z,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orbit::earth::R_EARTH_EQ;
    use std::f64::consts::PI;

    #[test]
    fn radius_is_constant() {
        let o = CircularOrbit::from_altitude(500e3, 97.4_f64.to_radians(), 1.0, 0.3);
        for i in 0..100 {
            let r = o.position_eci(i as f64 * 60.0).norm();
            assert!((r - (R_EARTH_EQ + 500e3)).abs() < 1e-3, "r={r}");
        }
    }

    #[test]
    fn period_of_500km_orbit_about_94_minutes() {
        let o = CircularOrbit::from_altitude(500e3, 0.0, 0.0, 0.0);
        let p_min = o.period_s() / 60.0;
        assert!((p_min - 94.6).abs() < 1.0, "period={p_min} min");
    }

    #[test]
    fn position_periodic() {
        let o = CircularOrbit::from_altitude(420e3, 51.6_f64.to_radians(), 0.7, 0.1);
        let p0 = o.position_eci(0.0);
        let p1 = o.position_eci(o.period_s());
        assert!(p0.sub(&p1).norm() < 1.0, "drift={}", p0.sub(&p1).norm());
    }

    #[test]
    fn equatorial_orbit_stays_in_plane() {
        let o = CircularOrbit::from_altitude(500e3, 0.0, 0.0, 0.0);
        for i in 0..50 {
            assert!(o.position_eci(i as f64 * 100.0).z.abs() < 1e-6);
        }
    }

    #[test]
    fn polar_orbit_reaches_poles() {
        let o = CircularOrbit::from_altitude(500e3, PI / 2.0, 0.0, 0.0);
        let quarter = o.period_s() / 4.0;
        let p = o.position_eci(quarter);
        // At a quarter period the satellite is over a pole: |z| ~ radius.
        assert!((p.z.abs() - o.a).abs() / o.a < 1e-6);
    }

    #[test]
    fn max_latitude_bounded_by_inclination() {
        let inc = 51.6_f64.to_radians();
        let o = CircularOrbit::from_altitude(420e3, inc, 0.4, 0.0);
        for i in 0..500 {
            let p = o.position_eci(i as f64 * 13.7);
            let lat = (p.z / p.norm()).asin();
            assert!(lat.abs() <= inc + 1e-9);
        }
    }

    #[test]
    fn basis_matches_direct_propagation() {
        let o = CircularOrbit::from_altitude(500e3, 97.4_f64.to_radians(), 1.1, 0.4);
        let b = o.basis();
        for i in 0..200 {
            let t = i as f64 * 37.0;
            let p = o.position_eci(t);
            let q = b.position_eci(t);
            assert!(p.sub(&q).norm() < 1e-6, "t={t} drift={}", p.sub(&q).norm());
        }
    }

    #[test]
    fn vec3_ops() {
        let a = Vec3::new(1.0, 2.0, 2.0);
        assert_eq!(a.norm(), 3.0);
        assert_eq!(a.dot(&Vec3::new(1.0, 0.0, 0.0)), 1.0);
        let n = a.normalized();
        assert!((n.norm() - 1.0).abs() < 1e-12);
    }
}
