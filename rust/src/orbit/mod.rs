//! Orbital mechanics substrate — replaces the `cote` simulator (Denby &
//! Lucia, ASPLOS 2020) the paper used to derive satellite⇄ground-station
//! connectivity (DESIGN.md §3 Substitutions).
//!
//! Scope: circular Keplerian two-body propagation in an Earth-centered
//! inertial (ECI) frame, Greenwich-rotation to ECEF, geodetic ground-station
//! coordinates, and minimum-elevation-angle visibility (§2.2 of the paper:
//! a link is feasible when the satellite is visible within elevation
//! ≥ α_min). This is sufficient to reproduce both connectivity
//! heterogeneities of Figure 2 — time-varying |C_i| and the per-satellite
//! contact-count spread n_k — because those are driven by constellation
//! geometry and Earth rotation, not by perturbation terms.
//!
//! [`isl`] extends the model beyond the paper with inter-satellite-link
//! geometry (intra-plane rings + range-gated adjacent-plane candidates,
//! ADR-0005), consumed by the routing layer in `connectivity/graph.rs`.

pub mod constellation;
pub mod earth;
pub mod ground;
pub mod isl;
pub mod kepler;
pub mod visibility;

pub use constellation::{
    planet_labs_like, Constellation, DowntimeWindow, OrbitalPlaneSpec, PlaneId, WalkerPattern,
    WalkerSpec,
};
pub use isl::IslGeometry;
pub use earth::{
    ecef_from_geodetic, eci_to_ecef, eci_to_ecef_rot, gmst_rad, EARTH_OMEGA, MU_EARTH, R_EARTH_EQ,
};
pub use ground::{planet_ground_stations, station_frames, GroundStation, StationFrame};
pub use kepler::{CircularOrbit, OrbitBasis, Vec3};
pub use visibility::{elevation_deg, is_visible, subsatellite_point, visible_from_frame};
