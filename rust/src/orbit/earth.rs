//! Earth model: constants, rotation (GMST), geodetic → ECEF conversion.

use super::kepler::Vec3;

/// Standard gravitational parameter of Earth [m^3/s^2].
pub const MU_EARTH: f64 = 3.986_004_418e14;
/// WGS84 equatorial radius [m].
pub const R_EARTH_EQ: f64 = 6_378_137.0;
/// WGS84 flattening.
pub const WGS84_F: f64 = 1.0 / 298.257_223_563;
/// Earth rotation rate [rad/s] (sidereal).
pub const EARTH_OMEGA: f64 = 7.292_115_9e-5;

/// Greenwich mean sidereal time angle at `t` seconds after epoch [rad].
///
/// The simulation epoch is arbitrary (the paper's 5-day window is relative),
/// so GMST(0) = 0 without loss of generality.
pub fn gmst_rad(t: f64) -> f64 {
    (EARTH_OMEGA * t).rem_euclid(2.0 * std::f64::consts::PI)
}

/// Rotate an ECI position into the Earth-fixed (ECEF) frame at time `t`.
pub fn eci_to_ecef(p_eci: &Vec3, t: f64) -> Vec3 {
    let (s, c) = gmst_rad(t).sin_cos();
    eci_to_ecef_rot(p_eci, s, c)
}

/// [`eci_to_ecef`] with the GMST rotation `(sin θ, cos θ)` hoisted out —
/// the connectivity hot loop computes θ once per sample timestamp and
/// reuses it across every satellite and station.
#[inline]
pub fn eci_to_ecef_rot(p_eci: &Vec3, sin_theta: f64, cos_theta: f64) -> Vec3 {
    // ECEF = Rz(-theta) * ECI
    Vec3::new(
        cos_theta * p_eci.x + sin_theta * p_eci.y,
        -sin_theta * p_eci.x + cos_theta * p_eci.y,
        p_eci.z,
    )
}

/// Geodetic (lat, lon in degrees, height in m) → ECEF position (WGS84).
pub fn ecef_from_geodetic(lat_deg: f64, lon_deg: f64, h_m: f64) -> Vec3 {
    let lat = lat_deg.to_radians();
    let lon = lon_deg.to_radians();
    let e2 = WGS84_F * (2.0 - WGS84_F);
    let sl = lat.sin();
    let n = R_EARTH_EQ / (1.0 - e2 * sl * sl).sqrt();
    Vec3::new(
        (n + h_m) * lat.cos() * lon.cos(),
        (n + h_m) * lat.cos() * lon.sin(),
        (n * (1.0 - e2) + h_m) * sl,
    )
}

/// Geodetic surface normal ("up" direction) at a ground site.
pub fn geodetic_up(lat_deg: f64, lon_deg: f64) -> Vec3 {
    let lat = lat_deg.to_radians();
    let lon = lon_deg.to_radians();
    Vec3::new(lat.cos() * lon.cos(), lat.cos() * lon.sin(), lat.sin())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::f64::consts::PI;

    #[test]
    fn gmst_wraps() {
        let day_sidereal = 2.0 * PI / EARTH_OMEGA; // ~86164 s
        assert!(gmst_rad(day_sidereal) < 1e-6);
        assert!((gmst_rad(day_sidereal / 2.0) - PI).abs() < 1e-9);
    }

    #[test]
    fn eci_to_ecef_identity_at_t0() {
        let p = Vec3::new(7e6, 1e5, -2e6);
        let q = eci_to_ecef(&p, 0.0);
        assert!(p.sub(&q).norm() < 1e-9);
    }

    #[test]
    fn eci_to_ecef_preserves_norm_and_z() {
        let p = Vec3::new(7e6, 1e5, -2e6);
        let q = eci_to_ecef(&p, 12_345.0);
        assert!((p.norm() - q.norm()).abs() < 1e-6);
        assert_eq!(p.z, q.z);
    }

    #[test]
    fn ecef_equator_prime_meridian() {
        let p = ecef_from_geodetic(0.0, 0.0, 0.0);
        assert!((p.x - R_EARTH_EQ).abs() < 1.0);
        assert!(p.y.abs() < 1e-6 && p.z.abs() < 1e-6);
    }

    #[test]
    fn ecef_north_pole() {
        let p = ecef_from_geodetic(90.0, 0.0, 0.0);
        let b = R_EARTH_EQ * (1.0 - WGS84_F); // polar radius ~6356752 m
        assert!(p.x.abs() < 1.0 && p.y.abs() < 1e-6);
        assert!((p.z - b).abs() < 1.0, "z={}", p.z);
    }

    #[test]
    fn up_vector_is_unit() {
        for (lat, lon) in [(0.0, 0.0), (45.0, 120.0), (-78.0, -30.0)] {
            let u = geodetic_up(lat, lon);
            assert!((u.norm() - 1.0).abs() < 1e-12);
        }
    }
}
