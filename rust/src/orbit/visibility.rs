//! Link feasibility: minimum-elevation-angle visibility (paper §2.2).
//!
//! A link between satellite k and ground station g is feasible at time t iff
//! the elevation of the satellite above g's local horizon is ≥ α_min, i.e.
//! the paper's ∠(r_g, r_k − r_g) ≤ π/2 − α_min condition.

use super::earth::eci_to_ecef;
use super::ground::{GroundStation, StationFrame};
use super::kepler::{CircularOrbit, Vec3};

/// Elevation [deg] of a satellite (ECEF) as seen from a station.
pub fn elevation_deg(sat_ecef: &Vec3, gs: &GroundStation) -> f64 {
    let d = sat_ecef.sub(&gs.position_ecef());
    let up = gs.up_ecef();
    let sin_el = up.dot(&d.normalized());
    sin_el.asin().to_degrees()
}

/// Is the satellite visible from the station within `min_elev_deg`?
pub fn is_visible(sat_eci: &Vec3, t: f64, gs: &GroundStation, min_elev_deg: f64) -> bool {
    let sat_ecef = eci_to_ecef(sat_eci, t);
    elevation_deg(&sat_ecef, gs) >= min_elev_deg
}

/// Sin-space visibility against a cached [`StationFrame`]: true iff the
/// elevation of `sat_ecef` is ≥ α_min, where `sin_min_elev` = sin(α_min).
///
/// Equivalent to `elevation_deg(..) >= min_elev_deg` without `asin`/degree
/// conversion: sin is monotone on [−π/2, π/2], so
/// `up·d / |d| ≥ sin(α_min)  ⇔  up·d ≥ sin(α_min)·|d|` (|d| > 0 preserves
/// the inequality for either sign of the left side).
#[inline]
pub fn visible_from_frame(sat_ecef: &Vec3, frame: &StationFrame, sin_min_elev: f64) -> bool {
    let d = sat_ecef.sub(&frame.pos);
    frame.up.dot(&d) >= sin_min_elev * d.norm()
}

/// Subsatellite point (geocentric lat, lon in degrees) at time `t` — used
/// by the Non-IID partitioner to find which UTM zones a satellite overflies.
pub fn subsatellite_point(orbit: &CircularOrbit, t: f64) -> (f64, f64) {
    let p = eci_to_ecef(&orbit.position_eci(t), t);
    let lat = (p.z / p.norm()).asin().to_degrees();
    let lon = p.y.atan2(p.x).to_degrees();
    (lat, lon)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orbit::earth::R_EARTH_EQ;

    fn station(lat: f64, lon: f64) -> GroundStation {
        GroundStation::new("test", lat, lon, 0.0)
    }

    #[test]
    fn zenith_satellite_has_90deg_elevation() {
        let gs = station(0.0, 0.0);
        // directly above the station at 500 km
        let sat = Vec3::new(R_EARTH_EQ + 500e3, 0.0, 0.0);
        let el = elevation_deg(&sat, &gs);
        assert!((el - 90.0).abs() < 0.2, "el={el}");
    }

    #[test]
    fn antipodal_satellite_below_horizon() {
        let gs = station(0.0, 0.0);
        let sat = Vec3::new(-(R_EARTH_EQ + 500e3), 0.0, 0.0);
        assert!(elevation_deg(&sat, &gs) < -80.0);
    }

    #[test]
    fn horizon_distance_consistent() {
        // A 500 km LEO is above the 10° horizon only within ~1600 km ground
        // range; 30° of longitude away (~3300 km) it must be invisible.
        let gs = station(0.0, 0.0);
        let sat = Vec3::new(
            (R_EARTH_EQ + 500e3) * 30f64.to_radians().cos(),
            (R_EARTH_EQ + 500e3) * 30f64.to_radians().sin(),
            0.0,
        );
        assert!(!is_visible(&sat, 0.0, &gs, 10.0));
    }

    #[test]
    fn visibility_monotone_in_threshold() {
        let gs = station(10.0, 20.0);
        let orbit = CircularOrbit::from_altitude(500e3, 0.9, 0.3, 0.0);
        for i in 0..200 {
            let t = i as f64 * 47.0;
            let p = orbit.position_eci(t);
            if is_visible(&p, t, &gs, 25.0) {
                assert!(is_visible(&p, t, &gs, 10.0));
            }
        }
    }

    #[test]
    fn frame_visibility_agrees_with_elevation_path() {
        // the sin-space fast path must agree with asin-based elevation_deg
        // across a full orbit, for several thresholds (incl. a negative one)
        let gs = station(47.0, -15.0);
        let frame = gs.frame();
        let orbit = CircularOrbit::from_altitude(520e3, 1.2, 0.8, 0.2);
        for min_elev in [-5.0f64, 0.0, 10.0, 25.0, 60.0] {
            let sin_min = min_elev.to_radians().sin();
            for i in 0..400 {
                let t = i as f64 * 23.0;
                let e = eci_to_ecef(&orbit.position_eci(t), t);
                let slow = elevation_deg(&e, &gs) >= min_elev;
                let fast = visible_from_frame(&e, &frame, sin_min);
                assert_eq!(slow, fast, "t={t} min_elev={min_elev}");
            }
        }
    }

    #[test]
    fn subsatellite_latitude_bounded_by_inclination() {
        let inc = 51.6_f64.to_radians();
        let orbit = CircularOrbit::from_altitude(420e3, inc, 0.0, 0.0);
        for i in 0..500 {
            let (lat, lon) = subsatellite_point(&orbit, i as f64 * 60.0);
            assert!(lat.abs() <= 51.7, "lat={lat}");
            assert!((-180.0..=180.0).contains(&lon));
        }
    }
}
