//! Constellation builder: the Planet-Labs-like 191-satellite fleet (§4.1).

use super::kepler::CircularOrbit;
use crate::rng::Rng;
use std::f64::consts::PI;

/// One orbital "flock": n satellites sharing altitude/inclination, spread
/// over `planes` RAAN values with in-plane phasing.
#[derive(Clone, Debug)]
pub struct OrbitalPlaneSpec {
    pub n_sats: usize,
    pub alt_m: f64,
    pub inc_deg: f64,
    pub planes: usize,
    /// RAAN of the first plane [deg]; planes are spread evenly over 360°/planes_span.
    pub raan0_deg: f64,
    pub raan_span_deg: f64,
}

/// A full constellation: named satellites with their orbits.
#[derive(Clone, Debug)]
pub struct Constellation {
    pub orbits: Vec<CircularOrbit>,
}

impl Constellation {
    pub fn len(&self) -> usize {
        self.orbits.len()
    }

    pub fn is_empty(&self) -> bool {
        self.orbits.is_empty()
    }

    /// Build from flock specs; `jitter` perturbs phases/RAAN slightly so the
    /// fleet is not artificially symmetric (Planet's Doves drift apart via
    /// differential drag — Foster et al. 2018).
    pub fn from_specs(specs: &[OrbitalPlaneSpec], rng: &mut Rng) -> Self {
        let mut orbits = Vec::new();
        for spec in specs {
            for i in 0..spec.n_sats {
                let plane = i % spec.planes;
                let slot = i / spec.planes;
                let slots_per_plane = spec.n_sats.div_ceil(spec.planes);
                let raan = (spec.raan0_deg
                    + spec.raan_span_deg * plane as f64 / spec.planes as f64)
                    .to_radians()
                    + rng.gen_f64(-0.01, 0.01);
                let phase = 2.0 * PI * slot as f64 / slots_per_plane as f64
                    + rng.gen_f64(0.0, 2.0 * PI / slots_per_plane as f64);
                orbits.push(CircularOrbit::from_altitude(
                    spec.alt_m + rng.gen_f64(-10e3, 10e3),
                    spec.inc_deg.to_radians(),
                    raan,
                    phase,
                ));
            }
        }
        Constellation { orbits }
    }
}

/// The default constellation for every experiment: 191 Dove-like satellites.
///
/// Planet's fleet at the paper's time was dominated by sun-synchronous
/// flocks (~97.4°, ~475–525 km, launched into a handful of local-time
/// planes) plus ISS-deployed flocks (51.6°, ~420 km). The SSO/ISS split and
/// plane counts here reproduce the Figure 2 heterogeneity: SSO satellites
/// see the polar stations nearly every orbit (n_k high), ISS satellites
/// never see them (n_k low), and plane geometry drives the time-of-day
/// swings in |C_i|.
pub fn planet_labs_like(n_sats: usize, seed: u64) -> Constellation {
    let mut rng = Rng::new(seed);
    let n_sso = n_sats * 7 / 10;
    let n_iss = n_sats - n_sso;
    let specs = [
        OrbitalPlaneSpec {
            n_sats: n_sso,
            alt_m: 500e3,
            inc_deg: 97.4,
            planes: 4,
            raan0_deg: 10.0,
            raan_span_deg: 180.0,
        },
        OrbitalPlaneSpec {
            n_sats: n_iss,
            alt_m: 420e3,
            inc_deg: 51.6,
            planes: 3,
            raan0_deg: 45.0,
            raan_span_deg: 360.0,
        },
    ];
    Constellation::from_specs(&specs, &mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_requested_count() {
        let c = planet_labs_like(191, 0);
        assert_eq!(c.len(), 191);
    }

    #[test]
    fn deterministic_from_seed() {
        let a = planet_labs_like(191, 7);
        let b = planet_labs_like(191, 7);
        for (x, y) in a.orbits.iter().zip(b.orbits.iter()) {
            assert_eq!(x.a, y.a);
            assert_eq!(x.phase0, y.phase0);
        }
    }

    #[test]
    fn two_inclination_families() {
        let c = planet_labs_like(191, 0);
        let sso = c
            .orbits
            .iter()
            .filter(|o| (o.inc.to_degrees() - 97.4).abs() < 0.1)
            .count();
        let iss = c
            .orbits
            .iter()
            .filter(|o| (o.inc.to_degrees() - 51.6).abs() < 0.1)
            .count();
        assert_eq!(sso + iss, 191);
        assert!(sso > iss, "sso={sso} iss={iss}");
    }

    #[test]
    fn altitudes_leo_band() {
        let c = planet_labs_like(191, 0);
        for o in &c.orbits {
            let alt = o.a - crate::orbit::earth::R_EARTH_EQ;
            assert!((380e3..560e3).contains(&alt), "alt={alt}");
        }
    }

    #[test]
    fn phases_spread_not_clustered() {
        let c = planet_labs_like(100, 3);
        // mean pairwise phase difference should be far from zero
        let mut phases: Vec<f64> = c.orbits.iter().map(|o| o.phase0).collect();
        phases.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let span = phases.last().unwrap() - phases.first().unwrap();
        assert!(span > PI, "span={span}");
    }
}
