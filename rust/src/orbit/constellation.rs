//! Constellation builders: the Planet-Labs-like fleet of the paper's §4.1
//! plus a general Walker-delta/star generator and per-satellite downtime
//! windows — the "constellation zoo" substrate behind
//! [`crate::cfg::Scenario`].

use super::kepler::CircularOrbit;
use crate::rng::Rng;
use std::f64::consts::PI;

/// One orbital "flock": n satellites sharing altitude/inclination, spread
/// over `planes` RAAN values with in-plane phasing.
#[derive(Clone, Debug)]
pub struct OrbitalPlaneSpec {
    /// Number of satellites in this flock.
    pub n_sats: usize,
    /// Orbital altitude above the spherical Earth surface [m].
    pub alt_m: f64,
    /// Inclination [deg].
    pub inc_deg: f64,
    /// Number of orbital planes the flock is spread over.
    pub planes: usize,
    /// RAAN of the first plane [deg]; planes are spread evenly over 360°/planes_span.
    pub raan0_deg: f64,
    /// Total RAAN span the planes cover [deg].
    pub raan_span_deg: f64,
}

/// Walker constellation phasing pattern (Walker 1984 notation `i:t/p/f`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WalkerPattern {
    /// Delta pattern: planes spread over the full 360° of RAAN
    /// (Starlink/Galileo-style).
    Delta,
    /// Star pattern: planes spread over 180° of RAAN so ascending and
    /// descending passes interleave (Iridium-style near-polar shells).
    Star,
}

impl WalkerPattern {
    /// Parse the pattern spelling (`"delta"` / `"star"`) — the suffix of the
    /// scenario-TOML constellation kinds `walker-delta` / `walker-star`.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "delta" => Some(WalkerPattern::Delta),
            "star" => Some(WalkerPattern::Star),
            _ => None,
        }
    }

    /// Canonical lowercase name (inverse of [`Self::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            WalkerPattern::Delta => "delta",
            WalkerPattern::Star => "star",
        }
    }

    /// RAAN span the planes are spread over [rad].
    pub fn raan_span(&self) -> f64 {
        match self {
            WalkerPattern::Delta => 2.0 * PI,
            WalkerPattern::Star => PI,
        }
    }
}

/// A Walker constellation `i:t/p/f`: `n_sats` (t) satellites in `planes`
/// (p) evenly-spaced planes at one altitude and inclination, with
/// inter-plane phasing offset `phasing` (f).
#[derive(Clone, Debug)]
pub struct WalkerSpec {
    /// Delta (360° RAAN spread) or star (180°).
    pub pattern: WalkerPattern,
    /// t — total satellite count; must be divisible by `planes`.
    pub n_sats: usize,
    /// p — number of orbital planes.
    pub planes: usize,
    /// f — phasing: satellites in adjacent planes are offset in argument of
    /// latitude by `f · 360° / t`.
    pub phasing: usize,
    /// Shell altitude [m].
    pub alt_m: f64,
    /// Inclination [deg].
    pub inc_deg: f64,
}

/// Orbital-plane membership of one satellite — the structural metadata the
/// inter-satellite-link model ([`crate::orbit::isl`]) is derived from.
///
/// `group` distinguishes independently-filed sub-constellations (one per
/// [`OrbitalPlaneSpec`] flock or Walker shell); `plane` indexes the orbital
/// plane within that group. ISLs never cross groups: different shells fly
/// at different altitudes, so a persistent link between them is not
/// maintainable.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlaneId {
    /// Sub-constellation (flock / shell) index.
    pub group: usize,
    /// Orbital-plane index within the group.
    pub plane: usize,
}

/// One scheduled outage: satellite `sat` is treated as unreachable for every
/// time index `i` with `from_step <= i < until_step` (power fault, tumbling
/// after a debris hit, decommissioning). Applied to a connectivity schedule
/// via [`crate::connectivity::ConnectivitySchedule::with_downtime`]; the
/// scheduler then sees the outage as part of the deterministic C.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DowntimeWindow {
    /// Satellite id the outage applies to.
    pub sat: usize,
    /// First affected time index (inclusive).
    pub from_step: usize,
    /// First unaffected time index (exclusive); `usize::MAX` = never recovers.
    pub until_step: usize,
}

impl DowntimeWindow {
    /// Does this window silence its satellite at time index `i`?
    pub fn covers(&self, i: usize) -> bool {
        self.from_step <= i && i < self.until_step
    }
}

/// A full constellation: satellite orbits plus any scheduled downtime.
#[derive(Clone, Debug)]
pub struct Constellation {
    /// Per-satellite circular orbits; the index is the satellite id.
    pub orbits: Vec<CircularOrbit>,
    /// Scheduled per-satellite outages (applied at the connectivity layer).
    pub downtime: Vec<DowntimeWindow>,
    /// Plane membership per satellite (same indexing as `orbits`). Filled
    /// by every spec-driven builder ([`Self::from_specs`], [`Self::walker`],
    /// the scenario shell stacker); empty for hand-assembled constellations,
    /// which therefore cannot carry ISLs.
    pub plane_ids: Vec<PlaneId>,
}

impl Constellation {
    /// Number of satellites.
    pub fn len(&self) -> usize {
        self.orbits.len()
    }

    /// True iff the constellation has no satellites.
    pub fn is_empty(&self) -> bool {
        self.orbits.is_empty()
    }

    /// Build from flock specs; `jitter` perturbs phases/RAAN slightly so the
    /// fleet is not artificially symmetric (Planet's Doves drift apart via
    /// differential drag — Foster et al. 2018).
    pub fn from_specs(specs: &[OrbitalPlaneSpec], rng: &mut Rng) -> Self {
        let mut orbits = Vec::new();
        let mut plane_ids = Vec::new();
        for (group, spec) in specs.iter().enumerate() {
            for i in 0..spec.n_sats {
                let plane = i % spec.planes;
                plane_ids.push(PlaneId { group, plane });
                let slot = i / spec.planes;
                let slots_per_plane = spec.n_sats.div_ceil(spec.planes);
                let raan = (spec.raan0_deg
                    + spec.raan_span_deg * plane as f64 / spec.planes as f64)
                    .to_radians()
                    + rng.gen_f64(-0.01, 0.01);
                let phase = 2.0 * PI * slot as f64 / slots_per_plane as f64
                    + rng.gen_f64(0.0, 2.0 * PI / slots_per_plane as f64);
                orbits.push(CircularOrbit::from_altitude(
                    spec.alt_m + rng.gen_f64(-10e3, 10e3),
                    spec.inc_deg.to_radians(),
                    raan,
                    phase,
                ));
            }
        }
        Constellation { orbits, downtime: Vec::new(), plane_ids }
    }

    /// Build an exact Walker `i:t/p/f` constellation (no jitter — Walker
    /// shells are station-kept, unlike drifting Dove flocks).
    ///
    /// Satellite `s` of plane `p` sits at RAAN `span·p/P` and argument of
    /// latitude `360°·s/S + f·360°·p/t` (S = t/P satellites per plane).
    pub fn walker(spec: &WalkerSpec) -> Self {
        assert!(spec.planes > 0, "walker: planes must be > 0");
        assert!(
            spec.n_sats % spec.planes == 0,
            "walker: {} satellites not divisible into {} planes",
            spec.n_sats,
            spec.planes
        );
        let per_plane = spec.n_sats / spec.planes;
        let span = spec.pattern.raan_span();
        let mut orbits = Vec::with_capacity(spec.n_sats);
        let mut plane_ids = Vec::with_capacity(spec.n_sats);
        for plane in 0..spec.planes {
            let raan = span * plane as f64 / spec.planes as f64;
            let plane_phase = 2.0 * PI * (spec.phasing * plane) as f64 / spec.n_sats as f64;
            for slot in 0..per_plane {
                let phase = 2.0 * PI * slot as f64 / per_plane as f64 + plane_phase;
                orbits.push(CircularOrbit::from_altitude(
                    spec.alt_m,
                    spec.inc_deg.to_radians(),
                    raan,
                    phase,
                ));
                plane_ids.push(PlaneId { group: 0, plane });
            }
        }
        Constellation { orbits, downtime: Vec::new(), plane_ids }
    }

    /// Attach scheduled outages (builder style). Windows naming satellites
    /// beyond `len()` are rejected.
    pub fn with_downtime(mut self, windows: Vec<DowntimeWindow>) -> Self {
        for w in &windows {
            assert!(w.sat < self.len(), "downtime for unknown satellite {}", w.sat);
            assert!(w.from_step < w.until_step, "empty downtime window {w:?}");
        }
        self.downtime = windows;
        self
    }
}

/// The default constellation for every experiment: 191 Dove-like satellites.
///
/// Planet's fleet at the paper's time was dominated by sun-synchronous
/// flocks (~97.4°, ~475–525 km, launched into a handful of local-time
/// planes) plus ISS-deployed flocks (51.6°, ~420 km). The SSO/ISS split and
/// plane counts here reproduce the Figure 2 heterogeneity: SSO satellites
/// see the polar stations nearly every orbit (n_k high), ISS satellites
/// never see them (n_k low), and plane geometry drives the time-of-day
/// swings in |C_i|.
pub fn planet_labs_like(n_sats: usize, seed: u64) -> Constellation {
    let mut rng = Rng::new(seed);
    let n_sso = n_sats * 7 / 10;
    let n_iss = n_sats - n_sso;
    let specs = [
        OrbitalPlaneSpec {
            n_sats: n_sso,
            alt_m: 500e3,
            inc_deg: 97.4,
            planes: 4,
            raan0_deg: 10.0,
            raan_span_deg: 180.0,
        },
        OrbitalPlaneSpec {
            n_sats: n_iss,
            alt_m: 420e3,
            inc_deg: 51.6,
            planes: 3,
            raan0_deg: 45.0,
            raan_span_deg: 360.0,
        },
    ];
    Constellation::from_specs(&specs, &mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_requested_count() {
        let c = planet_labs_like(191, 0);
        assert_eq!(c.len(), 191);
    }

    #[test]
    fn deterministic_from_seed() {
        let a = planet_labs_like(191, 7);
        let b = planet_labs_like(191, 7);
        for (x, y) in a.orbits.iter().zip(b.orbits.iter()) {
            assert_eq!(x.a, y.a);
            assert_eq!(x.phase0, y.phase0);
        }
    }

    #[test]
    fn two_inclination_families() {
        let c = planet_labs_like(191, 0);
        let sso = c
            .orbits
            .iter()
            .filter(|o| (o.inc.to_degrees() - 97.4).abs() < 0.1)
            .count();
        let iss = c
            .orbits
            .iter()
            .filter(|o| (o.inc.to_degrees() - 51.6).abs() < 0.1)
            .count();
        assert_eq!(sso + iss, 191);
        assert!(sso > iss, "sso={sso} iss={iss}");
    }

    #[test]
    fn altitudes_leo_band() {
        let c = planet_labs_like(191, 0);
        for o in &c.orbits {
            let alt = o.a - crate::orbit::earth::R_EARTH_EQ;
            assert!((380e3..560e3).contains(&alt), "alt={alt}");
        }
    }

    #[test]
    fn phases_spread_not_clustered() {
        let c = planet_labs_like(100, 3);
        // mean pairwise phase difference should be far from zero
        let mut phases: Vec<f64> = c.orbits.iter().map(|o| o.phase0).collect();
        phases.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let span = phases.last().unwrap() - phases.first().unwrap();
        assert!(span > PI, "span={span}");
    }

    fn walker_66() -> WalkerSpec {
        WalkerSpec {
            pattern: WalkerPattern::Star,
            n_sats: 66,
            planes: 6,
            phasing: 2,
            alt_m: 780e3,
            inc_deg: 86.4,
        }
    }

    #[test]
    fn walker_counts_and_geometry() {
        let c = Constellation::walker(&walker_66());
        assert_eq!(c.len(), 66);
        // every orbit shares altitude and inclination exactly
        for o in &c.orbits {
            assert_eq!(o.a, c.orbits[0].a);
            assert_eq!(o.inc, c.orbits[0].inc);
        }
        // 6 distinct RAAN values spread over at most 180° (star pattern)
        let mut raans: Vec<f64> = c.orbits.iter().map(|o| o.raan).collect();
        raans.sort_by(|a, b| a.partial_cmp(b).unwrap());
        raans.dedup_by(|a, b| (*a - *b).abs() < 1e-12);
        assert_eq!(raans.len(), 6);
        assert!(raans.last().unwrap() - raans.first().unwrap() < PI + 1e-9);
    }

    #[test]
    fn walker_delta_spans_full_circle() {
        let c = Constellation::walker(&WalkerSpec {
            pattern: WalkerPattern::Delta,
            n_sats: 24,
            planes: 8,
            phasing: 1,
            alt_m: 550e3,
            inc_deg: 53.0,
        });
        let mut raans: Vec<f64> = c.orbits.iter().map(|o| o.raan).collect();
        raans.sort_by(|a, b| a.partial_cmp(b).unwrap());
        raans.dedup_by(|a, b| (*a - *b).abs() < 1e-12);
        assert_eq!(raans.len(), 8);
        // delta spacing: adjacent planes 360°/8 apart
        assert!((raans[1] - raans[0] - 2.0 * PI / 8.0).abs() < 1e-12);
    }

    #[test]
    fn walker_phasing_offsets_adjacent_planes() {
        let mut spec = walker_66();
        spec.pattern = WalkerPattern::Delta;
        let c = Constellation::walker(&spec);
        let per_plane = 66 / 6;
        // first satellite of plane 1 leads plane 0's by f·360°/t
        let lead = c.orbits[per_plane].phase0 - c.orbits[0].phase0;
        assert!((lead - 2.0 * PI * 2.0 / 66.0).abs() < 1e-12, "lead={lead}");
    }

    #[test]
    #[should_panic]
    fn walker_rejects_indivisible_planes() {
        let mut spec = walker_66();
        spec.planes = 7; // 66 % 7 != 0
        let _ = Constellation::walker(&spec);
    }

    #[test]
    fn walker_pattern_parse_roundtrip() {
        for p in [WalkerPattern::Delta, WalkerPattern::Star] {
            assert_eq!(WalkerPattern::parse(p.name()), Some(p));
        }
        assert_eq!(WalkerPattern::parse("helix"), None);
    }

    #[test]
    fn plane_ids_cover_every_satellite() {
        let c = planet_labs_like(191, 0);
        assert_eq!(c.plane_ids.len(), 191);
        // two groups (SSO flock, ISS flock) with 4 and 3 planes
        let sso_planes: std::collections::BTreeSet<usize> =
            c.plane_ids.iter().filter(|p| p.group == 0).map(|p| p.plane).collect();
        let iss_planes: std::collections::BTreeSet<usize> =
            c.plane_ids.iter().filter(|p| p.group == 1).map(|p| p.plane).collect();
        assert_eq!(sso_planes.len(), 4);
        assert_eq!(iss_planes.len(), 3);
    }

    #[test]
    fn walker_plane_ids_match_raan_structure() {
        let c = Constellation::walker(&walker_66());
        assert_eq!(c.plane_ids.len(), 66);
        // satellites sharing a plane id share an exact RAAN
        for (a, pa) in c.plane_ids.iter().enumerate() {
            for (b, pb) in c.plane_ids.iter().enumerate() {
                if pa == pb {
                    assert_eq!(c.orbits[a].raan, c.orbits[b].raan);
                }
            }
        }
        let planes: std::collections::BTreeSet<usize> =
            c.plane_ids.iter().map(|p| p.plane).collect();
        assert_eq!(planes.len(), 6);
    }

    #[test]
    fn downtime_window_covers_half_open_range() {
        let w = DowntimeWindow { sat: 3, from_step: 10, until_step: 20 };
        assert!(!w.covers(9));
        assert!(w.covers(10));
        assert!(w.covers(19));
        assert!(!w.covers(20));
    }

    #[test]
    fn with_downtime_attaches_windows() {
        let c = planet_labs_like(10, 0)
            .with_downtime(vec![DowntimeWindow { sat: 2, from_step: 0, until_step: 5 }]);
        assert_eq!(c.downtime.len(), 1);
    }

    #[test]
    #[should_panic]
    fn with_downtime_rejects_unknown_satellite() {
        let _ = planet_labs_like(5, 0)
            .with_downtime(vec![DowntimeWindow { sat: 9, from_step: 0, until_step: 1 }]);
    }
}
