//! Ground stations: geodetic sites + the Planet-Labs-like default network.

use super::earth::{ecef_from_geodetic, geodetic_up};
use super::kepler::Vec3;

/// A ground station at a fixed geodetic site.
#[derive(Clone, Debug)]
pub struct GroundStation {
    /// Site name (unique within a network).
    pub name: String,
    /// Geodetic latitude [deg].
    pub lat_deg: f64,
    /// Longitude [deg].
    pub lon_deg: f64,
    /// Altitude above the WGS84 ellipsoid [m].
    pub alt_m: f64,
}

impl GroundStation {
    /// Construct from a geodetic site.
    pub fn new(name: &str, lat_deg: f64, lon_deg: f64, alt_m: f64) -> Self {
        GroundStation { name: name.to_string(), lat_deg, lon_deg, alt_m }
    }

    /// Earth-fixed position (constant — the station rotates with the frame).
    pub fn position_ecef(&self) -> Vec3 {
        ecef_from_geodetic(self.lat_deg, self.lon_deg, self.alt_m)
    }

    /// Local zenith direction in ECEF.
    pub fn up_ecef(&self) -> Vec3 {
        geodetic_up(self.lat_deg, self.lon_deg)
    }

    /// Precompute this station's cached ECEF frame for visibility hot loops.
    pub fn frame(&self) -> StationFrame {
        let pos = self.position_ecef();
        let up = self.up_ecef();
        StationFrame { up_dot_pos: up.dot(&pos), pos, up }
    }
}

/// Cached Earth-fixed frame of a ground station — its constant ECEF
/// position, zenith direction, and their dot product — so visibility tests
/// don't re-derive geodetic trig per call ([`GroundStation::position_ecef`]
/// and [`GroundStation::up_ecef`] each cost several trig evaluations).
#[derive(Clone, Copy, Debug)]
pub struct StationFrame {
    /// ECEF position [m].
    pub pos: Vec3,
    /// Unit zenith direction in ECEF.
    pub up: Vec3,
    /// up · pos — the local-horizon plane offset: a point `e` is above the
    /// station's 0° horizon plane iff up · e ≥ up_dot_pos.
    pub up_dot_pos: f64,
}

/// Cached frames for a station network, in input order.
pub fn station_frames(stations: &[GroundStation]) -> Vec<StationFrame> {
    stations.iter().map(GroundStation::frame).collect()
}

/// The 12-station network used throughout the paper's evaluation (§4.1).
///
/// Planet Labs' exact station list is not public; these are the publicly
/// known polar + mid-latitude commercial downlink sites (KSAT/AWS/Planet
/// class), chosen so the network has the paper's character: polar stations
/// that SSO satellites see every orbit, plus sparse mid/low-latitude sites
/// (DESIGN.md §3 Substitutions).
pub fn planet_ground_stations() -> Vec<GroundStation> {
    vec![
        GroundStation::new("svalbard", 78.23, 15.39, 450.0),
        GroundStation::new("inuvik", 68.36, -133.72, 15.0),
        GroundStation::new("fairbanks", 64.84, -147.71, 135.0),
        GroundStation::new("reykjavik", 64.13, -21.90, 45.0),
        GroundStation::new("troll_antarctica", -72.01, 2.53, 1275.0),
        GroundStation::new("awarua_nz", -46.53, 168.38, 10.0),
        GroundStation::new("punta_arenas", -53.16, -70.91, 35.0),
        GroundStation::new("cork_ireland", 51.90, -8.47, 50.0),
        GroundStation::new("dubbo_australia", -32.24, 148.60, 275.0),
        GroundStation::new("hartebeesthoek", -25.89, 27.69, 1555.0),
        GroundStation::new("hawaii", 19.82, -155.47, 3000.0),
        GroundStation::new("singapore", 1.35, 103.82, 15.0),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twelve_stations() {
        assert_eq!(planet_ground_stations().len(), 12);
    }

    #[test]
    fn positions_near_earth_surface() {
        for gs in planet_ground_stations() {
            let r = gs.position_ecef().norm();
            assert!(
                (6.35e6..6.40e6).contains(&r),
                "{} radius {r}",
                gs.name
            );
        }
    }

    #[test]
    fn names_unique() {
        let gs = planet_ground_stations();
        let mut names: Vec<_> = gs.iter().map(|g| g.name.clone()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), gs.len());
    }

    #[test]
    fn frame_caches_position_and_up() {
        for gs in planet_ground_stations() {
            let f = gs.frame();
            assert_eq!(f.pos, gs.position_ecef());
            assert_eq!(f.up, gs.up_ecef());
            assert!((f.up_dot_pos - f.up.dot(&f.pos)).abs() < 1e-9);
        }
    }

    #[test]
    fn up_roughly_aligned_with_position() {
        for gs in planet_ground_stations() {
            let cos = gs.up_ecef().dot(&gs.position_ecef().normalized());
            // geodetic vs geocentric normal differ by < ~0.2 deg of arc cos
            assert!(cos > 0.9998, "{}: cos={cos}", gs.name);
        }
    }
}
