//! Typed run events, the observer layer, and run-artifact bundles
//! (ADR-0009).
//!
//! Every observable thing a run does — a contact, an upload attempt, a
//! gateway aggregation, a cross-gateway reconcile, an evaluation, a planner
//! decision — is one [`RunEvent`], emitted from the *single* `run_step`
//! body all three engine modes share. Because emission happens only there,
//! the event stream inherits the repo's core invariant for free: Dense,
//! ContactList and Streamed modes produce identical streams, and
//! `testing::assert_same_run` compares streams element-wise — a strictly
//! stronger gate than the old hand-picked counter comparison.
//!
//! Consumers implement [`EventSink`]. Three built-ins cover the framework's
//! needs:
//!
//! - [`NullSink`] — the default observer: a zero-sized type whose `emit`
//!   is an inlined empty body, so events-off runs monomorphize to exactly
//!   the pre-events engine (no allocation, no branch, bit- and
//!   speed-identical);
//! - [`TraceSink`] — rebuilds [`RunTrace`] from events. The engine derives
//!   its trace exclusively through [`TraceSink::apply`], which is now the
//!   *only* place trace counters mutate: every `RunTrace` field is a
//!   derived view over the stream;
//! - [`ArtifactSink`] — records the stream verbatim for the JSON
//!   run-artifact bundle ([`RunArtifact`]) that `scenarios run` renders
//!   its tables from and `--json` emits for CI/EXPERIMENTS tooling.
//!
//! The `[events]` TOML section ([`EventSpec`]) switches stream *recording*
//! into `RunResult::events` on; observation via [`EventSink`] needs no
//! config at all.

use crate::cfg::section::{SectionCtx, SectionSpec};
use crate::cfg::toml::TomlDoc;
use crate::metrics::CurvePoint;
use crate::sim::trace::RunTrace;
use anyhow::{Context, Result};
use std::fmt::Write as _;

/// Schema tag written into every run-artifact bundle.
pub const ARTIFACT_SCHEMA: &str = "fedspace-run-artifact-v1";

/// How one upload attempt at a contact resolved.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UploadOutcome {
    /// The gradient reached its gateway's buffer.
    Delivered,
    /// The satellite was in contact but had no finished update to send.
    Idle,
    /// The update did not fit the contact's byte budget (ADR-0008); the
    /// satellite retries at its next pass.
    Deferred,
    /// The link dropped the frame in transit (ADR-0007).
    Dropped,
}

impl UploadOutcome {
    /// Stable lowercase name (artifact-bundle spelling).
    pub fn name(&self) -> &'static str {
        match self {
            UploadOutcome::Delivered => "delivered",
            UploadOutcome::Idle => "idle",
            UploadOutcome::Deferred => "deferred",
            UploadOutcome::Dropped => "dropped",
        }
    }
}

/// Which engine phase a [`RunEvent::Timing`] measured.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TimingPhase {
    /// Local training (`Trainer::local_update`).
    Train,
    /// Gateway aggregation (Eq. 4).
    Aggregate,
    /// Global-model evaluation.
    Eval,
}

impl TimingPhase {
    /// Stable lowercase name (artifact-bundle spelling).
    pub fn name(&self) -> &'static str {
        match self {
            TimingPhase::Train => "train",
            TimingPhase::Aggregate => "aggregate",
            TimingPhase::Eval => "eval",
        }
    }
}

/// One observation from the shared `run_step` body. Everything except
/// [`RunEvent::Timing`] is deterministic per (scenario, seed) and identical
/// across the three engine modes — the property `assert_same_run` gates.
#[derive(Clone, Debug, PartialEq)]
pub enum RunEvent {
    /// The run began: fleet and horizon shape, emitted exactly once so
    /// sinks can size per-gateway state before any traffic.
    RunStart {
        /// Fleet size.
        n_sats: usize,
        /// Horizon in slots.
        n_steps: usize,
        /// Gateway count (1 for the implicit single-gateway federation).
        n_gateways: usize,
    },
    /// A satellite was in (possibly relayed) contact with the ground at
    /// `step` — the geometry fact, before any transport outcome.
    Contact {
        /// Engine step index.
        step: usize,
        /// Satellite id.
        sat: usize,
        /// ISL relay hops to its ground-visible sink (0 = direct).
        hops: usize,
    },
    /// How the contact's upload opportunity resolved.
    Upload {
        /// Engine step index.
        step: usize,
        /// Originating satellite id.
        origin: usize,
        /// Receiving gateway index — meaningful only for
        /// [`UploadOutcome::Delivered`] (0 otherwise; routing is not
        /// consulted for idle/deferred/dropped attempts, exactly as the
        /// pre-events engine never routed them).
        gateway: usize,
        /// Relay hops the upload path used.
        hops: usize,
        /// Nominal wire size of one update under the `[link]` codec
        /// (0 when byte budgets are off — nothing is charged).
        bytes: u64,
        /// Transport outcome.
        outcome: UploadOutcome,
        /// A compromised satellite transformed this upload (ADR-0007).
        injected: bool,
        /// A link fault flipped one stored bit (ADR-0007).
        corrupted: bool,
    },
    /// A gateway ran its aggregation (Eq. 4) over its buffer.
    Aggregate {
        /// Engine step index.
        step: usize,
        /// Aggregating gateway index.
        gateway: usize,
        /// Global round count *after* this aggregation.
        round: usize,
        /// Staleness of every aggregated update, in buffer order.
        staleness: Vec<usize>,
    },
    /// Cross-gateway reconciliation merged the gateway models (ADR-0006).
    Reconcile {
        /// Engine step index.
        step: usize,
        /// Merge operations performed (one per reconcile trigger).
        merges: usize,
    },
    /// The global model was evaluated — one training-curve point.
    Eval {
        /// Engine step index (0 for the pre-run baseline eval).
        step: usize,
        /// Global round count at evaluation time.
        round: usize,
        /// Simulated days since start.
        day: f64,
        /// Validation top-1 accuracy.
        accuracy: f64,
        /// Validation loss.
        loss: f64,
    },
    /// A FedSpace planner committed a scheduling window (Alg. 1 line 4).
    PlanDecision {
        /// Engine step index the window starts at.
        step: usize,
        /// Planning gateway index.
        gateway: usize,
        /// Window length in slots.
        horizon: usize,
        /// Steps inside the window the planner marked for aggregation.
        planned_aggs: usize,
    },
    /// Wall-clock phase timing. Identity-exempt (ADR-0002): values differ
    /// between otherwise bit-identical runs, so `assert_same_run` filters
    /// these out of the stream comparison.
    Timing {
        /// Which engine phase was measured.
        phase: TimingPhase,
        /// Wall-clock seconds spent.
        seconds: f64,
    },
    /// The serving driver drained one gateway's ingestion queue (ADR-0010).
    /// Queue state is a pure function of the replayed trace, so this event
    /// IS part of the determinism contract — the shard-count determinism
    /// test compares these streams element-wise.
    ServeBatch {
        /// Serving-clock tick (drain batches completed, the serve analogue
        /// of the engine step).
        tick: usize,
        /// Drained gateway index.
        gateway: usize,
        /// Uploads taken off the queue and aggregated in this batch.
        drained: usize,
        /// Queue depth observed just before the drain (after this tick's
        /// ingest), feeding the queue-depth histogram.
        depth: usize,
        /// Offers this gateway's full queue deferred since the last batch
        /// (PR 7 `Deferred` backpressure — the callers retry, nothing
        /// drops).
        deferred: usize,
    },
    /// End-of-run serving throughput summary. Wall-clock derived, so
    /// identity-exempt like [`RunEvent::Timing`]: two bit-identical serving
    /// runs report different sustained rates and latency percentiles.
    ServeReport {
        /// Uploads accepted into gateway buffers over the whole replay.
        uploads: u64,
        /// Wall-clock seconds the replay took.
        wall_s: f64,
        /// Sustained accepted-upload rate (`uploads / wall_s`).
        uploads_per_s: f64,
        /// Median per-tick reconcile (drain + aggregate) latency, ms.
        p50_ms: f64,
        /// 99th-percentile per-tick reconcile latency, ms.
        p99_ms: f64,
    },
}

impl RunEvent {
    /// Stable snake-case tag (the artifact bundle's `"type"` field).
    pub fn kind(&self) -> &'static str {
        match self {
            RunEvent::RunStart { .. } => "run_start",
            RunEvent::Contact { .. } => "contact",
            RunEvent::Upload { .. } => "upload",
            RunEvent::Aggregate { .. } => "aggregate",
            RunEvent::Reconcile { .. } => "reconcile",
            RunEvent::Eval { .. } => "eval",
            RunEvent::PlanDecision { .. } => "plan_decision",
            RunEvent::Timing { .. } => "timing",
            RunEvent::ServeBatch { .. } => "serve_batch",
            RunEvent::ServeReport { .. } => "serve_report",
        }
    }

    /// Is this event part of the determinism contract? False only for the
    /// wall-clock events — [`RunEvent::Timing`] and the serving-throughput
    /// [`RunEvent::ServeReport`] (ADR-0002's identity exemption; ADR-0010
    /// extends it to serving: model state is deterministic, timing is not).
    pub fn is_deterministic(&self) -> bool {
        !matches!(self, RunEvent::Timing { .. } | RunEvent::ServeReport { .. })
    }

    /// One-line JSON object (an element of the bundle's `"events"` array).
    pub fn to_json(&self) -> String {
        let mut s = format!("{{\"type\": \"{}\"", self.kind());
        match self {
            RunEvent::RunStart { n_sats, n_steps, n_gateways } => {
                let _ = write!(
                    s,
                    ", \"n_sats\": {n_sats}, \"n_steps\": {n_steps}, \"n_gateways\": {n_gateways}"
                );
            }
            RunEvent::Contact { step, sat, hops } => {
                let _ = write!(s, ", \"step\": {step}, \"sat\": {sat}, \"hops\": {hops}");
            }
            RunEvent::Upload { step, origin, gateway, hops, bytes, outcome, injected, corrupted } => {
                let _ = write!(
                    s,
                    ", \"step\": {step}, \"origin\": {origin}, \"gateway\": {gateway}, \
                     \"hops\": {hops}, \"bytes\": {bytes}, \"outcome\": \"{}\", \
                     \"injected\": {injected}, \"corrupted\": {corrupted}",
                    outcome.name()
                );
            }
            RunEvent::Aggregate { step, gateway, round, staleness } => {
                let stale: Vec<String> = staleness.iter().map(|v| v.to_string()).collect();
                let _ = write!(
                    s,
                    ", \"step\": {step}, \"gateway\": {gateway}, \"round\": {round}, \
                     \"staleness\": [{}]",
                    stale.join(", ")
                );
            }
            RunEvent::Reconcile { step, merges } => {
                let _ = write!(s, ", \"step\": {step}, \"merges\": {merges}");
            }
            RunEvent::Eval { step, round, day, accuracy, loss } => {
                let _ = write!(
                    s,
                    ", \"step\": {step}, \"round\": {round}, \"day\": {day}, \
                     \"accuracy\": {accuracy}, \"loss\": {loss}"
                );
            }
            RunEvent::PlanDecision { step, gateway, horizon, planned_aggs } => {
                let _ = write!(
                    s,
                    ", \"step\": {step}, \"gateway\": {gateway}, \"horizon\": {horizon}, \
                     \"planned_aggs\": {planned_aggs}"
                );
            }
            RunEvent::Timing { phase, seconds } => {
                let _ = write!(s, ", \"phase\": \"{}\", \"seconds\": {seconds}", phase.name());
            }
            RunEvent::ServeBatch { tick, gateway, drained, depth, deferred } => {
                let _ = write!(
                    s,
                    ", \"tick\": {tick}, \"gateway\": {gateway}, \"drained\": {drained}, \
                     \"depth\": {depth}, \"deferred\": {deferred}"
                );
            }
            RunEvent::ServeReport { uploads, wall_s, uploads_per_s, p50_ms, p99_ms } => {
                let _ = write!(
                    s,
                    ", \"uploads\": {uploads}, \"wall_s\": {wall_s}, \
                     \"uploads_per_s\": {uploads_per_s}, \"p50_ms\": {p50_ms}, \
                     \"p99_ms\": {p99_ms}"
                );
            }
        }
        s.push('}');
        s
    }
}

/// An observer of the engine's event stream.
///
/// The engine is generic over its sink and monomorphizes per
/// implementation, so an empty `emit` body compiles to nothing — the
/// zero-cost contract [`NullSink`] relies on. `emit` takes the event by
/// reference: sinks that keep events clone them, everyone else reads in
/// place.
pub trait EventSink {
    /// Observe one event.
    fn emit(&mut self, event: &RunEvent);
}

/// The default observer: does nothing, costs nothing. Runs driven through
/// `Engine::run` use this sink, and the monomorphized engine is the
/// pre-events engine — asserted bit-identical in the property tests.
#[derive(Clone, Copy, Debug, Default)]
pub struct NullSink;

impl EventSink for NullSink {
    #[inline(always)]
    fn emit(&mut self, _event: &RunEvent) {}
}

/// Rebuilds a [`RunTrace`] from the event stream. The engine itself
/// derives its trace through [`TraceSink::apply`] — the single site where
/// trace counters mutate — so a standalone `TraceSink` fed a recorded
/// stream reproduces the run's trace exactly (tested in
/// `tests/scenarios.rs`).
#[derive(Clone, Debug, Default)]
pub struct TraceSink {
    /// The trace derived so far.
    pub trace: RunTrace,
}

impl TraceSink {
    /// A sink starting from an empty trace.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one event into a trace — the counter semantics of every
    /// `RunTrace` field, in one place. Gateway vectors are sized by
    /// [`RunEvent::RunStart`] (and grown defensively if a stream starts
    /// mid-run), so zero-activity gateways still report a 0 entry.
    pub fn apply(trace: &mut RunTrace, event: &RunEvent) {
        match event {
            RunEvent::RunStart { n_gateways, .. } => {
                trace.gateway_aggs.resize(*n_gateways, 0);
                trace.gateway_uploads.resize(*n_gateways, 0);
            }
            RunEvent::Contact { .. } => trace.connections += 1,
            RunEvent::Upload { gateway, hops, outcome, injected, corrupted, .. } => {
                match outcome {
                    UploadOutcome::Delivered => {
                        trace.uploads += 1;
                        if *hops > 0 {
                            trace.relayed += 1;
                        }
                        if trace.gateway_uploads.len() <= *gateway {
                            trace.gateway_uploads.resize(*gateway + 1, 0);
                        }
                        trace.gateway_uploads[*gateway] += 1;
                    }
                    UploadOutcome::Idle => trace.idle += 1,
                    UploadOutcome::Deferred => trace.deferred += 1,
                    UploadOutcome::Dropped => trace.dropped += 1,
                }
                if *injected {
                    trace.injected += 1;
                }
                if *corrupted {
                    trace.corrupted += 1;
                }
            }
            RunEvent::Aggregate { gateway, staleness, .. } => {
                trace.global_updates += 1;
                if trace.gateway_aggs.len() <= *gateway {
                    trace.gateway_aggs.resize(*gateway + 1, 0);
                }
                trace.gateway_aggs[*gateway] += 1;
                for &s in staleness {
                    trace.staleness.add(s as i64);
                }
            }
            RunEvent::Reconcile { merges, .. } => trace.reconciles += merges,
            RunEvent::Eval { step, round, day, accuracy, loss } => {
                trace.curve.push(CurvePoint {
                    day: *day,
                    step: *step,
                    round: *round,
                    accuracy: *accuracy,
                    loss: *loss,
                });
            }
            RunEvent::PlanDecision { .. } => {}
            // serving-only events carry no trace counters: the queue/latency
            // surface lives in the artifact events, not in RunTrace
            RunEvent::ServeBatch { .. } | RunEvent::ServeReport { .. } => {}
            RunEvent::Timing { phase, seconds } => match phase {
                TimingPhase::Train => trace.t_train_s += seconds,
                TimingPhase::Aggregate => trace.t_agg_s += seconds,
                TimingPhase::Eval => trace.t_eval_s += seconds,
            },
        }
    }

    /// The derived trace.
    pub fn into_trace(self) -> RunTrace {
        self.trace
    }
}

impl EventSink for TraceSink {
    fn emit(&mut self, event: &RunEvent) {
        Self::apply(&mut self.trace, event);
    }
}

/// Records the stream verbatim — the in-memory form of the run-artifact
/// bundle's `"events"` array.
#[derive(Clone, Debug, Default)]
pub struct ArtifactSink {
    /// Events in emission order.
    pub events: Vec<RunEvent>,
}

impl ArtifactSink {
    /// An empty recorder.
    pub fn new() -> Self {
        Self::default()
    }
}

impl EventSink for ArtifactSink {
    fn emit(&mut self, event: &RunEvent) {
        self.events.push(event.clone());
    }
}

/// The `[events]` TOML section: opt into recording the full event stream
/// into `RunResult::events` (and therefore into the artifact bundle).
/// Off by default — recording allocates one `Vec` entry per event, which
/// mega-constellation runs don't want unless asked.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EventSpec {
    /// Record the typed event stream into the run result.
    pub record: bool,
}

impl EventSpec {
    /// Exactly the implicit default (controls `[events]` emission).
    pub fn is_default(&self) -> bool {
        *self == EventSpec::default()
    }

    /// Emit the `[events]` TOML section (callers skip it when default so
    /// pre-events specs stay byte-identical).
    pub fn emit_toml(&self, out: &mut String) {
        let _ = writeln!(out, "\n[events]");
        let _ = writeln!(out, "record = {}", self.record);
    }

    /// Parse the `[events]` section; `Ok(None)` when absent (callers keep
    /// their default) — the shared scenario/experiment-config idiom.
    pub fn from_doc(doc: &TomlDoc) -> Result<Option<EventSpec>> {
        if doc.get("events").is_none() {
            return Ok(None);
        }
        let mut spec = EventSpec::default();
        if let Some(v) = doc.get("events").and_then(|s| s.get("record")) {
            spec.record = v.as_bool().context("[events] record must be a boolean")?;
        }
        Ok(Some(spec))
    }
}

impl SectionSpec for EventSpec {
    const SECTION: &'static str = "events";

    fn from_doc(doc: &TomlDoc) -> Result<Option<Self>> {
        EventSpec::from_doc(doc)
    }

    fn emit_toml(&self, out: &mut String) {
        EventSpec::emit_toml(self, out)
    }

    fn is_emitted(&self) -> bool {
        !self.is_default()
    }

    fn validate(&self, _ctx: &SectionCtx) -> Result<()> {
        Ok(())
    }
}

/// One run's artifact bundle: metadata + the derived trace + the recorded
/// event stream, serializable to the `fedspace-run-artifact-v1` JSON
/// document. `scenarios run` renders its human table *from* this struct,
/// and `--json` emits it verbatim, so humans and CI read the same surface.
#[derive(Clone, Debug)]
pub struct RunArtifact {
    /// Scenario name the run came from.
    pub scenario: String,
    /// Algorithm name (`sync` / `async` / `fedbuff` / `fedspace`).
    pub algorithm: String,
    /// Engine mode name (`dense` / `contact-list` / `streamed`).
    pub engine: String,
    /// Fleet size of the run.
    pub n_sats: usize,
    /// Horizon of the run in slots.
    pub n_steps: usize,
    /// Global rounds completed.
    pub final_round: usize,
    /// First simulated day the accuracy target was reached, if ever.
    pub days_to_target: Option<f64>,
    /// The run's derived trace (every counter a view over the events).
    pub trace: RunTrace,
    /// Recorded event stream (empty unless `[events] record = true` or the
    /// run was driven with `--json`).
    pub events: Vec<RunEvent>,
}

impl RunArtifact {
    /// Bundle one engine run. `result` is `sim::RunResult` — taken by its
    /// parts to keep this constructor usable from every caller layer.
    pub fn from_run(
        scenario: &str,
        algorithm: &str,
        engine: &str,
        n_sats: usize,
        n_steps: usize,
        result: &crate::sim::engine::RunResult,
    ) -> Self {
        RunArtifact {
            scenario: scenario.to_string(),
            algorithm: algorithm.to_string(),
            engine: engine.to_string(),
            n_sats,
            n_steps,
            final_round: result.final_round,
            days_to_target: result.days_to_target,
            trace: result.trace.clone(),
            events: result.events.clone(),
        }
    }

    /// Serialize to one `fedspace-run-artifact-v1` JSON object (parsed
    /// back by `bench_report::parse_json` in the tests).
    pub fn to_json(&self) -> String {
        let t = &self.trace;
        let mut s = String::from("{\n");
        let _ = writeln!(s, "  \"schema\": \"{ARTIFACT_SCHEMA}\",");
        let _ = writeln!(s, "  \"scenario\": \"{}\",", json_escape(&self.scenario));
        let _ = writeln!(s, "  \"algorithm\": \"{}\",", json_escape(&self.algorithm));
        let _ = writeln!(s, "  \"engine\": \"{}\",", json_escape(&self.engine));
        let _ = writeln!(s, "  \"n_sats\": {},", self.n_sats);
        let _ = writeln!(s, "  \"n_steps\": {},", self.n_steps);
        s.push_str("  \"summary\": {\n");
        let _ = writeln!(s, "    \"final_round\": {},", self.final_round);
        let _ = writeln!(s, "    \"global_updates\": {},", t.global_updates);
        let _ = writeln!(s, "    \"connections\": {},", t.connections);
        let _ = writeln!(s, "    \"uploads\": {},", t.uploads);
        let _ = writeln!(s, "    \"relayed\": {},", t.relayed);
        let _ = writeln!(s, "    \"deferred\": {},", t.deferred);
        let _ = writeln!(s, "    \"idle\": {},", t.idle);
        let _ = writeln!(s, "    \"idle_fraction\": {},", t.idle_fraction());
        let _ = writeln!(s, "    \"injected\": {},", t.injected);
        let _ = writeln!(s, "    \"dropped\": {},", t.dropped);
        let _ = writeln!(s, "    \"corrupted\": {},", t.corrupted);
        let _ = writeln!(s, "    \"reconciles\": {},", t.reconciles);
        let _ = writeln!(s, "    \"gateway_aggs\": {},", json_usize_array(&t.gateway_aggs));
        let _ = writeln!(s, "    \"gateway_uploads\": {},", json_usize_array(&t.gateway_uploads));
        let _ = writeln!(s, "    \"max_staleness\": {},", t.staleness.max_key().unwrap_or(0));
        let _ = writeln!(s, "    \"best_accuracy\": {},", t.curve.best_accuracy());
        let _ = writeln!(s, "    \"days_to_target\": {},", json_opt_f64(self.days_to_target));
        let _ = writeln!(s, "    \"t_train_s\": {},", t.t_train_s);
        let _ = writeln!(s, "    \"t_agg_s\": {},", t.t_agg_s);
        let _ = writeln!(s, "    \"t_eval_s\": {}", t.t_eval_s);
        s.push_str("  },\n");
        let stale: Vec<String> =
            t.staleness.entries().map(|(v, n)| format!("[{v}, {n}]")).collect();
        let _ = writeln!(s, "  \"staleness\": [{}],", stale.join(", "));
        s.push_str("  \"curve\": [");
        let curve: Vec<String> = t
            .curve
            .points
            .iter()
            .map(|p| {
                format!(
                    "\n    {{\"day\": {}, \"step\": {}, \"round\": {}, \"accuracy\": {}, \
                     \"loss\": {}}}",
                    p.day, p.step, p.round, p.accuracy, p.loss
                )
            })
            .collect();
        s.push_str(&curve.join(","));
        if !curve.is_empty() {
            s.push_str("\n  ");
        }
        s.push_str("],\n");
        s.push_str("  \"events\": [");
        let events: Vec<String> =
            self.events.iter().map(|e| format!("\n    {}", e.to_json())).collect();
        s.push_str(&events.join(","));
        if !events.is_empty() {
            s.push_str("\n  ");
        }
        s.push_str("]\n}\n");
        s
    }
}

/// Wrap per-algorithm artifacts of one `scenarios run` invocation into a
/// single JSON document (the `--json` output).
pub fn bundle_json(artifacts: &[RunArtifact]) -> String {
    let mut s = String::from("{\n");
    let _ = writeln!(s, "  \"schema\": \"{ARTIFACT_SCHEMA}\",");
    let _ = writeln!(s, "  \"runs\": [");
    let runs: Vec<String> = artifacts
        .iter()
        .map(|a| {
            let body = a.to_json();
            // indent the nested object two spaces, dropping its trailing \n
            body.trim_end().lines().map(|l| format!("    {l}")).collect::<Vec<_>>().join("\n")
        })
        .collect();
    s.push_str(&runs.join(",\n"));
    s.push_str("\n  ]\n}\n");
    s
}

/// Escape a string for a JSON double-quoted literal (the subset our names
/// can contain; control characters are dropped to keep the writer total).
pub(crate) fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {}
            c => out.push(c),
        }
    }
    out
}

fn json_usize_array(xs: &[usize]) -> String {
    let items: Vec<String> = xs.iter().map(|x| x.to_string()).collect();
    format!("[{}]", items.join(", "))
}

fn json_opt_f64(v: Option<f64>) -> String {
    match v {
        Some(x) => format!("{x}"),
        None => "null".to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synthetic_stream() -> Vec<RunEvent> {
        vec![
            RunEvent::RunStart { n_sats: 4, n_steps: 10, n_gateways: 2 },
            RunEvent::Eval { step: 0, round: 0, day: 0.0, accuracy: 0.1, loss: 2.3 },
            RunEvent::Contact { step: 1, sat: 0, hops: 0 },
            RunEvent::Upload {
                step: 1,
                origin: 0,
                gateway: 1,
                hops: 0,
                bytes: 64,
                outcome: UploadOutcome::Delivered,
                injected: true,
                corrupted: false,
            },
            RunEvent::Contact { step: 1, sat: 1, hops: 2 },
            RunEvent::Upload {
                step: 1,
                origin: 1,
                gateway: 0,
                hops: 2,
                bytes: 64,
                outcome: UploadOutcome::Delivered,
                injected: false,
                corrupted: true,
            },
            RunEvent::Contact { step: 2, sat: 2, hops: 0 },
            RunEvent::Upload {
                step: 2,
                origin: 2,
                gateway: 0,
                hops: 0,
                bytes: 64,
                outcome: UploadOutcome::Idle,
                injected: false,
                corrupted: false,
            },
            RunEvent::Contact { step: 3, sat: 3, hops: 0 },
            RunEvent::Upload {
                step: 3,
                origin: 3,
                gateway: 0,
                hops: 0,
                bytes: 64,
                outcome: UploadOutcome::Deferred,
                injected: false,
                corrupted: false,
            },
            RunEvent::Contact { step: 4, sat: 0, hops: 0 },
            RunEvent::Upload {
                step: 4,
                origin: 0,
                gateway: 0,
                hops: 0,
                bytes: 64,
                outcome: UploadOutcome::Dropped,
                injected: false,
                corrupted: false,
            },
            RunEvent::PlanDecision { step: 4, gateway: 0, horizon: 24, planned_aggs: 3 },
            RunEvent::Aggregate { step: 5, gateway: 1, round: 1, staleness: vec![0, 2, 2] },
            RunEvent::Timing { phase: TimingPhase::Aggregate, seconds: 0.25 },
            RunEvent::Reconcile { step: 5, merges: 1 },
            RunEvent::Eval { step: 5, round: 1, day: 0.5, accuracy: 0.4, loss: 1.1 },
            RunEvent::Timing { phase: TimingPhase::Eval, seconds: 0.125 },
            RunEvent::ServeBatch { tick: 6, gateway: 0, drained: 2, depth: 3, deferred: 1 },
            RunEvent::ServeReport {
                uploads: 2,
                wall_s: 0.5,
                uploads_per_s: 4.0,
                p50_ms: 1.5,
                p99_ms: 9.0,
            },
        ]
    }

    #[test]
    fn null_sink_is_free() {
        assert_eq!(std::mem::size_of::<NullSink>(), 0, "NullSink must stay zero-sized");
        let mut sink = NullSink;
        for e in synthetic_stream() {
            sink.emit(&e);
        }
    }

    #[test]
    fn trace_sink_derives_every_counter() {
        let mut sink = TraceSink::new();
        for e in synthetic_stream() {
            sink.emit(&e);
        }
        let t = sink.into_trace();
        assert_eq!(t.connections, 5);
        assert_eq!(t.uploads, 2);
        assert_eq!(t.relayed, 1);
        assert_eq!(t.idle, 1);
        assert_eq!(t.deferred, 1);
        assert_eq!(t.dropped, 1);
        assert_eq!(t.injected, 1);
        assert_eq!(t.corrupted, 1);
        assert_eq!(t.global_updates, 1);
        assert_eq!(t.gateway_aggs, vec![0, 1], "RunStart must pre-size zero-activity gateways");
        assert_eq!(t.gateway_uploads, vec![1, 1]);
        assert_eq!(t.reconciles, 1);
        assert_eq!(t.staleness.count(2), 2);
        assert_eq!(t.staleness.total(), 3);
        assert_eq!(t.curve.points.len(), 2);
        assert_eq!(t.curve.points[1].step, 5);
        assert!((t.t_agg_s - 0.25).abs() < 1e-12);
        assert!((t.t_eval_s - 0.125).abs() < 1e-12);
        assert!((t.t_train_s).abs() < 1e-12);
    }

    #[test]
    fn artifact_and_timing_filters() {
        let stream = synthetic_stream();
        let mut sink = ArtifactSink::new();
        for e in &stream {
            sink.emit(e);
        }
        assert_eq!(sink.events, stream, "artifact sink must record verbatim");
        let det: Vec<&RunEvent> = stream.iter().filter(|e| e.is_deterministic()).collect();
        assert_eq!(
            stream.len() - det.len(),
            3,
            "exactly the two Timing events and the ServeReport filter out"
        );
        assert!(
            RunEvent::ServeBatch { tick: 0, gateway: 0, drained: 0, depth: 0, deferred: 0 }
                .is_deterministic(),
            "queue state is deterministic — only wall-clock serving metrics are exempt"
        );
    }

    #[test]
    fn artifact_json_parses_back() {
        let mut trace = RunTrace::default();
        for e in synthetic_stream() {
            TraceSink::apply(&mut trace, &e);
        }
        let artifact = RunArtifact {
            scenario: "paper-fig7".into(),
            algorithm: "fedbuff".into(),
            engine: "dense".into(),
            n_sats: 4,
            n_steps: 10,
            final_round: 1,
            days_to_target: None,
            trace,
            events: synthetic_stream(),
        };
        let json = artifact.to_json();
        let doc = crate::bench_report::parse_json(&json).unwrap();
        assert_eq!(doc.get("schema").and_then(|v| v.as_str()), Some(ARTIFACT_SCHEMA));
        assert_eq!(doc.get("algorithm").and_then(|v| v.as_str()), Some("fedbuff"));
        let summary = doc.get("summary").expect("summary object");
        assert_eq!(summary.get("uploads").and_then(|v| v.as_num()), Some(2.0));
        assert_eq!(summary.get("reconciles").and_then(|v| v.as_num()), Some(1.0));
        assert_eq!(summary.get("days_to_target").map(|v| v.is_null()), Some(true));
        let events = doc.get("events").and_then(|v| v.as_arr()).expect("events array");
        assert_eq!(events.len(), artifact.events.len());
        assert_eq!(events[0].get("type").and_then(|v| v.as_str()), Some("run_start"));
        let curve = doc.get("curve").and_then(|v| v.as_arr()).expect("curve array");
        assert_eq!(curve.len(), 2);
        assert_eq!(curve[1].get("accuracy").and_then(|v| v.as_num()), Some(0.4));
        // the bundle wrapper parses too and nests both runs
        let bundle = bundle_json(&[artifact.clone(), artifact]);
        let doc = crate::bench_report::parse_json(&bundle).unwrap();
        assert_eq!(doc.get("runs").and_then(|v| v.as_arr()).map(|r| r.len()), Some(2));
    }

    #[test]
    fn event_spec_knob() {
        assert!(!EventSpec::default().record, "recording must be opt-in");
        assert!(EventSpec::default().is_default());
        let on = EventSpec { record: true };
        let mut s = String::new();
        on.emit_toml(&mut s);
        let doc = crate::cfg::toml::parse_toml(&s).unwrap();
        assert_eq!(EventSpec::from_doc(&doc).unwrap(), Some(on));
        let bad = crate::cfg::toml::parse_toml("[events]\nrecord = 3").unwrap();
        assert!(EventSpec::from_doc(&bad).is_err());
        let absent = crate::cfg::toml::parse_toml("[scenario]\nname = \"x\"").unwrap();
        assert_eq!(EventSpec::from_doc(&absent).unwrap(), None);
    }

    #[test]
    fn json_escape_covers_the_subset() {
        assert_eq!(json_escape("plain-name_1"), "plain-name_1");
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }
}
