//! Discrete-time simulation of Algorithm 1 over a connectivity schedule —
//! the engine behind Figure 6, Table 2 and Figure 7.

pub mod adversary;
pub mod engine;
pub mod events;
pub mod trace;
pub mod trainer;

pub use adversary::{Adversary, ApplyOutcome, AttackKind, AttackSpec};
pub use engine::{Engine, EngineBuilder, EngineConfig, RunResult, ScheduleSource};
pub use events::{
    bundle_json, ArtifactSink, EventSink, EventSpec, NullSink, RunArtifact, RunEvent, TimingPhase,
    TraceSink, UploadOutcome,
};
pub use trace::RunTrace;
pub use trainer::{MockTrainer, PjrtTrainer, Trainer, TrainerSampleBackend};
