//! Per-run trace: everything Figures 6–7 and Table 2 need.

use crate::metrics::{Histogram, TrainingCurve};

/// Collected over one simulated run of Algorithm 1.
#[derive(Clone, Debug, Default)]
pub struct RunTrace {
    /// staleness of every aggregated gradient (Figure 7 left)
    pub staleness: Histogram,
    /// idle connections (Figure 7 right): connected, nothing new to send
    pub idle: usize,
    /// total connections observed
    pub connections: usize,
    /// total uploads received
    pub uploads: usize,
    /// uploads that arrived over ≥ 1 inter-satellite relay hop (subset of
    /// `uploads`; always 0 when the scenario carries no ISLs — ADR-0005)
    pub relayed: usize,
    /// number of global updates (i_g at the end)
    pub global_updates: usize,
    /// aggregations per gateway, in gateway-index order (ADR-0006); length
    /// 1 for single-gateway runs, and the entries sum to `global_updates`
    pub gateway_aggs: Vec<usize>,
    /// uploads received per gateway, in gateway-index order (sums to
    /// `uploads`)
    pub gateway_uploads: Vec<usize>,
    /// cross-gateway reconcile merges performed (0 under `Centralized`
    /// and for every single-gateway run that never diverges)
    pub reconciles: usize,
    /// uploads transformed by a Byzantine satellite (ADR-0007); always 0
    /// when the scenario carries no `[attack]` section
    pub injected: usize,
    /// uploads lost to injected link faults (not counted in `uploads` —
    /// the federation never saw them)
    pub dropped: usize,
    /// uploads that suffered a single-bit link corruption (subset of
    /// `uploads`)
    pub corrupted: usize,
    /// uploads deferred because the contact's byte budget could not carry
    /// the encoded payload (ADR-0008); always 0 when the scenario carries
    /// no `[link]` byte budget. A deferred upload stays pending on the
    /// satellite — it is neither an `upload` nor an `idle` contact.
    pub deferred: usize,
    /// accuracy/loss curve (Figure 6)
    pub curve: TrainingCurve,
    /// wall-clock seconds spent in local training / aggregation / eval
    pub t_train_s: f64,
    /// wall-clock seconds spent in Eq.-4 aggregation
    pub t_agg_s: f64,
    /// wall-clock seconds spent in evaluation
    pub t_eval_s: f64,
}

impl RunTrace {
    /// Fraction of connections that carried no upload (Figure 7 right).
    pub fn idle_fraction(&self) -> f64 {
        if self.connections == 0 {
            0.0
        } else {
            self.idle as f64 / self.connections as f64
        }
    }

    /// staleness histogram as (staleness, count) rows
    pub fn staleness_rows(&self) -> Vec<(i64, u64)> {
        self.staleness.entries().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_fraction_bounds() {
        let mut t = RunTrace::default();
        assert_eq!(t.idle_fraction(), 0.0);
        t.connections = 10;
        t.idle = 9;
        assert!((t.idle_fraction() - 0.9).abs() < 1e-12);
    }
}
