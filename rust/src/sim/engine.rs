//! The discrete-time engine: Algorithm 1, executed over a connectivity
//! schedule with any aggregation policy and any trainer backend.
//!
//! Three execution modes share one step body (the private `run_step`,
//! selected by [`crate::cfg::EngineMode`]):
//!
//! - **Dense** walks every time index — the paper's literal loop.
//! - **ContactList** advances directly between *events*: steps with a
//!   contact ([`ConnectivitySchedule::active_steps`]), periodic-evaluation
//!   steps, the final step, and — for FedSpace — planner window boundaries
//!   and planned aggregation slots. Skipped steps are exactly those where
//!   the step body is a provable no-op: client state only changes at
//!   contacts, and every policy's `decide` is a pure function of the buffer
//!   (which skipped steps cannot change) except `ScheduledPolicy`, whose
//!   potential firing slots are enumerated events. Traces are therefore
//!   bit-identical between modes — asserted by the tests below and by
//!   `tests/scenarios.rs` on the `paper-fig7` scenario.
//! - **Streamed** drives the same contact-list walk from the recyclable
//!   chunks of a [`ConnectivityStream`] (ADR-0004): contact events come
//!   from the current chunk's `active_steps`, chunk boundaries are extra
//!   visited steps (at worst provable no-ops, by the same argument that
//!   makes skipping sound), and FedSpace planning windows are materialized
//!   on demand ([`StreamCursor::window`]). Peak schedule memory is
//!   O(sats × chunk) instead of O(sats × horizon), which is what lets the
//!   mega-constellation scenarios run at all.

use crate::cfg::{AlgorithmKind, EngineMode};
use crate::connectivity::{
    ConnectivitySchedule, ConnectivityStream, ContactGraph, StepView, StreamCursor,
};
use crate::fl::{
    AggregationPolicy, AsyncPolicy, FedBuffPolicy, Federation, FederationSpec, LinkSpec,
    ReconcilePolicy, ScheduledPolicy, ServerAggregator, SyncPolicy, Update, UpdateCodec,
    UploadRouting,
};
use crate::fl::client::SatClient;
use crate::rng::Rng;
use crate::sched::{FedSpacePlanner, SatForecastState};
use crate::sim::adversary::{Adversary, ApplyOutcome, AttackSpec};
use crate::sim::events::{EventSink, NullSink, RunEvent, TimingPhase, TraceSink, UploadOutcome};
use crate::sim::trace::RunTrace;
use crate::sim::trainer::Trainer;
use anyhow::Result;
use std::time::Instant;

/// Engine knobs (subset of `ExperimentConfig` the loop itself needs).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Aggregation-indicator policy to run.
    pub algorithm: AlgorithmKind,
    /// Staleness-compensation exponent α (Eq. 4).
    pub alpha: f64,
    /// FedBuff's M (clamped to the effective client count).
    pub fedbuff_m: usize,
    /// evaluate every this many time indexes
    pub eval_every: usize,
    /// Simulated days per time index (T0 / 86400).
    pub days_per_step: f64,
    /// stop as soon as validation accuracy reaches this (Table 2 runs)
    pub stop_at_accuracy: Option<f64>,
    /// local-training duration in slots (1 = done by next contact)
    pub train_duration_slots: usize,
    /// Seed for the engine's client RNG streams.
    pub seed: u64,
    /// FedSpace scheduling period I0 (ignored by other algorithms)
    pub i0: usize,
    /// Dense per-step walk, sparse contact-list event walk, or the
    /// chunk-driven streamed walk.
    pub mode: EngineMode,
    /// Adversary / fault injection at the upload boundary (ADR-0007);
    /// disabled by default — no injector is built and no adversary
    /// randomness is consumed.
    pub attack: AttackSpec,
    /// Link byte budget + update codec (ADR-0008); disabled by default —
    /// no codec is built, no capacity check runs, and the upload path is
    /// byte-for-byte the plain one.
    pub link: LinkSpec,
    /// Record the typed event stream into [`RunResult::events`]
    /// (ADR-0009). Off by default: the stream is still *emitted* (that is
    /// how the trace is derived), but nothing is allocated to keep it.
    pub record_events: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            algorithm: AlgorithmKind::FedBuff,
            alpha: 0.5,
            fedbuff_m: 96,
            eval_every: 4,
            days_per_step: 1.0 / 96.0,
            stop_at_accuracy: None,
            train_duration_slots: 1,
            seed: 7,
            i0: 24,
            mode: EngineMode::Dense,
            attack: AttackSpec::default(),
            link: LinkSpec::default(),
            record_events: false,
        }
    }
}

/// Outcome of one run.
pub struct RunResult {
    /// Everything the figures/tables need from the run — a derived view
    /// over the event stream (ADR-0009).
    pub trace: RunTrace,
    /// simulated days at which the target accuracy was first reached
    pub days_to_target: Option<f64>,
    /// Final global model w.
    pub final_w: Vec<f32>,
    /// Final global round index i_g.
    pub final_round: usize,
    /// The typed event stream, recorded only when
    /// [`EngineConfig::record_events`] is set (empty otherwise).
    /// `testing::assert_same_run` compares these element-wise.
    pub events: Vec<RunEvent>,
}

enum PolicyImpl {
    Sync(SyncPolicy),
    Async(AsyncPolicy),
    FedBuff(FedBuffPolicy),
    FedSpace(ScheduledPolicy),
}

impl PolicyImpl {
    fn decide(&mut self, i: usize, conn: &[usize], buffer: &crate::fl::Buffer) -> bool {
        match self {
            PolicyImpl::Sync(p) => p.decide(i, conn, buffer),
            PolicyImpl::Async(p) => p.decide(i, conn, buffer),
            PolicyImpl::FedBuff(p) => p.decide(i, conn, buffer),
            PolicyImpl::FedSpace(p) => p.decide(i, conn, buffer),
        }
    }

    /// Can `decide` fire at a step with no contact and no scheduled slot?
    /// Only the degenerate zero-threshold policies (no satellite has data,
    /// so Sync's K and FedBuff's clamped M are 0): they aggregate an empty
    /// buffer every single step, so the contact-list walk must not skip any.
    fn fires_unconditionally(&self) -> bool {
        match self {
            PolicyImpl::Sync(p) => p.n_sats == 0,
            PolicyImpl::FedBuff(p) => p.m == 0,
            _ => false,
        }
    }
}

/// First step `>= after` at which the Algorithm-1 step body can differ from
/// a no-op, given the current policy state — the contact-list mode's
/// advance function. Returns `n_steps` when no further event exists.
///
/// Event sources, mirroring the step body top to bottom:
/// - FedSpace replanning at the committed horizon (`sp.horizon() <= i`),
///   for any gateway's policy;
/// - any step with a contact (`active`, ascending);
/// - FedSpace planned aggregation slots (can fire with an empty C_i);
/// - periodic evaluation steps (`(i+1) % eval_every == 0`) — these also
///   refresh the `last_loss` the planner reads, so they must not be skipped;
/// - `Periodic` reconcile boundaries (`reconcile_every`, same modulus
///   shape) — a merge after an event-step aggregation can land on an
///   otherwise quiet step, and skipping it would defer the merge
///   (ADR-0006). Quiet boundaries are no-op merges, so visiting them is
///   sound in every mode;
/// - the final step (closing evaluation).
fn next_event(
    after: usize,
    active: &[usize],
    policies: &[PolicyImpl],
    n_steps: usize,
    eval_every: usize,
    reconcile_every: Option<usize>,
) -> usize {
    if after >= n_steps {
        return n_steps;
    }
    if policies.iter().any(PolicyImpl::fires_unconditionally) {
        return after;
    }
    // the final step is always an event, so start from it and tighten
    let mut next = n_steps - 1;
    let idx = active.partition_point(|&s| s < after);
    if idx < active.len() {
        next = next.min(active[idx]);
    }
    let ee = eval_every.max(1);
    let next_eval = (after + 1).div_ceil(ee) * ee - 1;
    next = next.min(next_eval);
    if let Some(every) = reconcile_every {
        let re = every.max(1);
        next = next.min((after + 1).div_ceil(re) * re - 1);
    }
    for policy in policies {
        if let PolicyImpl::FedSpace(sp) = policy {
            next = next.min(sp.horizon().max(after));
            if let Some(slot) = sp.next_scheduled(after) {
                next = next.min(slot);
            }
        }
    }
    next
}

/// Byte budget of contact `j` at one step (ADR-0008): the link rate scaled
/// by the contact's pass-duration fraction. An empty duration slice means
/// "full slot" — the whole rate. Integer math, so the budget is exact and
/// platform-independent.
#[inline]
fn contact_budget(rate: u64, durs: &[u16], j: usize, denom: u16) -> u64 {
    match durs.get(j) {
        None => rate,
        Some(&d) => rate * d as u64 / denom.max(1) as u64,
    }
}

/// A planning window with capacity-infeasible contacts removed (ADR-0008):
/// the FedSpace forecast must not count on an upload the byte budget can't
/// carry. Materialized only at replan steps, and only when the budget is
/// on — capacity-off planning reads the raw view, untouched.
struct CapacityView {
    start: usize,
    n_steps_total: usize,
    n_sats: usize,
    sets: Vec<Vec<usize>>,
    hops: Vec<Vec<u8>>,
    hop_delay: usize,
}

impl StepView for CapacityView {
    fn n_sats(&self) -> usize {
        self.n_sats
    }

    fn n_steps(&self) -> usize {
        self.n_steps_total
    }

    fn sats_at(&self, i: usize) -> &[usize] {
        &self.sets[i - self.start]
    }

    fn hops_at(&self, i: usize) -> &[u8] {
        &self.hops[i - self.start]
    }

    fn hop_delay_slots(&self) -> usize {
        self.hop_delay
    }
}

/// Copy `[start, start + len)` of `view`, dropping every contact whose
/// byte budget is below the nominal payload. Hop slices stay parallel to
/// the filtered sets (empty stays empty — "all direct").
fn capacity_filtered(view: &dyn StepView, start: usize, len: usize, payload: u64, rate: u64) -> CapacityView {
    let end = (start + len).min(view.n_steps());
    let mut sets = Vec::with_capacity(end.saturating_sub(start));
    let mut hops = Vec::with_capacity(end.saturating_sub(start));
    let denom = view.duration_denom();
    for i in start..end {
        let conn = view.sats_at(i);
        let h = view.hops_at(i);
        let durs = view.durations_at(i);
        let mut set = Vec::with_capacity(conn.len());
        let mut hop = Vec::with_capacity(h.len());
        for (j, &s) in conn.iter().enumerate() {
            if payload <= contact_budget(rate, durs, j, denom) {
                set.push(s);
                if !h.is_empty() {
                    hop.push(h[j]);
                }
            }
        }
        sets.push(set);
        hops.push(hop);
    }
    CapacityView {
        start,
        n_steps_total: view.n_steps(),
        n_sats: view.n_sats(),
        sets,
        hops,
        hop_delay: view.hop_delay_slots(),
    }
}

/// Where the engine reads the deterministic schedule C from.
#[derive(Clone, Copy)]
pub enum ScheduleSource<'a> {
    /// A fully materialized schedule (dense and contact-list modes).
    Precomputed(&'a ConnectivitySchedule),
    /// A chunked on-demand stream (streamed mode, ADR-0004).
    Streamed(&'a ConnectivityStream),
}

impl ScheduleSource<'_> {
    /// Number of satellites the schedule covers.
    pub fn n_sats(&self) -> usize {
        match self {
            ScheduleSource::Precomputed(s) => s.n_sats,
            ScheduleSource::Streamed(s) => s.n_sats(),
        }
    }

    /// Number of time indexes the schedule covers.
    pub fn n_steps(&self) -> usize {
        match self {
            ScheduleSource::Precomputed(s) => s.n_steps(),
            ScheduleSource::Streamed(s) => s.n_steps(),
        }
    }
}

/// Mutable per-run state threaded through every walk — one bundle so the
/// three time-axis walks can share the single step body [`run_step`].
/// The server side is a [`Federation`] (ADR-0006): one gateway per spec
/// entry, each with its own buffer and its own policy instance; the
/// single-gateway default reduces to the pre-federation `GsState` engine
/// bit for bit.
struct RunState {
    clients: Vec<SatClient>,
    sat_rngs: Vec<Rng>,
    fed: Federation,
    /// One aggregation-indicator policy per gateway (index = gateway).
    policies: Vec<PolicyImpl>,
    /// Attack/fault injector (ADR-0007); `None` when the spec is disabled,
    /// in which case the upload path is byte-for-byte the clean one.
    adversary: Option<Adversary>,
    /// Update codec at the upload boundary (ADR-0008), applied BEFORE the
    /// adversary — the attacker tampers with what actually crosses the
    /// link, i.e. the encoded wire payload. `None` when `[link]` is
    /// disabled: uploads move as plain dense vectors, untouched.
    codec: Option<UpdateCodec>,
    /// Nominal encoded upload size in bytes (the wire model of
    /// [`LinkSpec::payload_bytes`] at the trainer's dimension); 0 when the
    /// byte budget is off, in which case no capacity check runs.
    payload_bytes: u64,
    /// Derived view over the event stream: mutated exclusively through
    /// [`TraceSink::apply`] inside [`emit_event`] (ADR-0009).
    trace: RunTrace,
    /// Recorded event stream; `Some` iff [`EngineConfig::record_events`].
    recorded: Option<Vec<RunEvent>>,
    last_loss: f64,
    days_to_target: Option<f64>,
}

impl RunState {
    /// Will any gateway's FedSpace policy replan at step `i`? The streamed
    /// walk materializes the planning window only when this holds. (All
    /// gateways extend their horizon by I0 at the same boundaries, so "any"
    /// and "all" coincide in practice.)
    fn needs_replan(&self, i: usize) -> bool {
        self.policies
            .iter()
            .any(|p| matches!(p, PolicyImpl::FedSpace(sp) if sp.horizon() <= i))
    }
}

/// Route one [`RunEvent`] through the three consumer paths (ADR-0009):
/// the trace derivation ([`TraceSink::apply`] — the only place trace
/// counters mutate), the observer (monomorphized; [`NullSink`] inlines to
/// nothing), and the recorder (populated only under
/// [`EngineConfig::record_events`]). Takes disjoint `RunState` fields so
/// call sites may hold other `st` borrows.
#[inline]
fn emit_event<S: EventSink>(
    trace: &mut RunTrace,
    recorded: &mut Option<Vec<RunEvent>>,
    observer: &mut S,
    event: RunEvent,
) {
    TraceSink::apply(trace, &event);
    observer.emit(&event);
    if let Some(log) = recorded {
        log.push(event);
    }
}

/// Algorithm 1's step body at time index `i` — the single implementation
/// every engine mode executes, so traces can only differ if a walk visits
/// the wrong steps (which the bit-identity tests would catch).
///
/// `plan_view` must cover `[i, i + I0)` of C whenever
/// [`RunState::needs_replan`] holds: the precomputed walks pass the whole
/// schedule, the streamed walk passes a window materialized from the
/// stream. Returns `true` when the early-stop accuracy target was reached.
///
/// With ISLs (ADR-0005), `conn` is the step's *reach* set and `conn_hops`
/// the parallel minimal hop counts; each contact's relay latency
/// `hops × hop_delay` is charged on both legs — an upload must have been
/// ready `delay` slots before `i` to arrive now, and a relayed broadcast
/// extends local training by `delay` slots. Uploads stay attributed to the
/// origin satellite, so staleness is measured from its local train time,
/// not the relay time. An empty `conn_hops` means "all direct" (the plain
/// PR 3 path, bit-identical to before).
///
/// With a multi-gateway federation (ADR-0006), `routing` is `Some`: every
/// upload and broadcast goes through the gateway of the station that heard
/// the satellite, each gateway's policy `decide`s against its own buffer
/// (in gateway-index order), FedSpace plans per gateway over
/// [`UploadRouting::gateway_window`] slices, and `Periodic` reconciles
/// fire at the end of the step, before evaluation. `routing == None` is
/// the single-gateway fast path — no lookup, no filtering, no merge: the
/// pre-federation engine bit for bit.
#[allow(clippy::too_many_arguments)]
fn run_step<S: EventSink>(
    st: &mut RunState,
    trainer: &dyn Trainer,
    aggregator: &mut dyn ServerAggregator,
    planners: &mut [FedSpacePlanner],
    routing: Option<&UploadRouting>,
    cfg: &EngineConfig,
    plan_view: Option<&dyn StepView>,
    conn: &[usize],
    conn_hops: &[u8],
    hop_delay: usize,
    conn_durs: &[u16],
    dur_denom: u16,
    i: usize,
    n_steps: usize,
    observer: &mut S,
) -> Result<bool> {
    // FedSpace: (re)plan at window boundaries using the live state, one
    // window per gateway (a single shared `states` snapshot — versions and
    // staleness are global, ADR-0006)
    if st.needs_replan(i) {
        let round = st.fed.round();
        let states: Vec<SatForecastState> = st
            .clients
            .iter()
            .map(|c| SatForecastState {
                pending: c.pending.is_some(),
                staleness_now: round.saturating_sub(c.base_round),
                holds_current: c.held_version == Some(round),
                has_data: c.has_data(),
            })
            .collect();
        let raw_view = plan_view.expect("replanning step without a planning window");
        // byte budget on: the forecast sees only capacity-feasible contacts
        // (ADR-0008) — an upload the budget can't carry will be deferred at
        // run time, so planning around it would schedule phantom arrivals
        let cap_view;
        let view: &dyn StepView = if st.payload_bytes > 0 {
            let i0 = planners.first().map_or(cfg.i0, |p| p.params.i0).max(1);
            cap_view = capacity_filtered(
                raw_view,
                i,
                i0,
                st.payload_bytes,
                cfg.link.rate_bytes_per_slot,
            );
            &cap_view
        } else {
            raw_view
        };
        for (g, policy) in st.policies.iter_mut().enumerate() {
            if let PolicyImpl::FedSpace(sp) = policy {
                if sp.horizon() <= i {
                    let planner = &mut planners[g];
                    let window = match routing {
                        None => planner.plan(view, i, &states, st.last_loss),
                        Some(r) => {
                            // each gateway forecasts only the contacts the
                            // station map routes to it
                            let i0 = planner.params.i0.max(1);
                            let gw_view = r.gateway_window(view, i, i0, g);
                            planner.plan(&gw_view, i, &states, st.last_loss)
                        }
                    };
                    sp.extend(&window);
                    emit_event(
                        &mut st.trace,
                        &mut st.recorded,
                        observer,
                        RunEvent::PlanDecision {
                            step: i,
                            gateway: g,
                            horizon: window.len(),
                            planned_aggs: window.iter().filter(|&&b| b).count(),
                        },
                    );
                }
            }
        }
    }

    // upload/broadcast routing: the gateway of the station that heard the
    // satellite; relayed contacts land at the step's first listening
    // station (UploadRouting::gateway_for). Single gateway: everything is 0.
    let route = |s: usize, hops: usize| -> usize {
        match routing {
            None => 0,
            Some(r) => r.gateway_for(i, s, hops),
        }
    };

    // 1. receive uploads (Algorithm 1's for k ∈ C_i loop; C_i is the reach
    // set when ISLs are on, and relayed gradients keep their origin id).
    // The adversary sits exactly at the upload boundary (ADR-0007): the
    // satellite has committed its transmission, the federation hasn't seen
    // it yet. Contact steps are events in every engine mode and dense-only
    // extra steps have an empty `conn`, so the injector's RNG draws — and
    // therefore the whole attacked trace — stay tri-mode bit-identical.
    for (j, &s) in conn.iter().enumerate() {
        let hops = if conn_hops.is_empty() { 0 } else { conn_hops[j] as usize };
        let delay = hops * hop_delay;
        emit_event(
            &mut st.trace,
            &mut st.recorded,
            observer,
            RunEvent::Contact { step: i, sat: s, hops },
        );
        if st.clients[s].can_upload_relayed(i, delay) {
            // byte budget (ADR-0008): the encoded payload must fit the
            // contact's capacity (rate × pass duration). A blocked upload
            // stays pending on the satellite for its next contact — no
            // client state changes, no RNG draws, not an idle contact.
            if st.payload_bytes > 0
                && st.payload_bytes > contact_budget(cfg.link.rate_bytes_per_slot, conn_durs, j, dur_denom)
            {
                emit_event(
                    &mut st.trace,
                    &mut st.recorded,
                    observer,
                    RunEvent::Upload {
                        step: i,
                        origin: s,
                        gateway: 0,
                        hops,
                        bytes: st.payload_bytes,
                        outcome: UploadOutcome::Deferred,
                        injected: false,
                        corrupted: false,
                    },
                );
                continue;
            }
            let (grad, base) = st.clients[s].upload(i);
            // codec BEFORE adversary (ADR-0008): the attacker tampers with
            // the encoded wire payload. Codec-off is a plain move into the
            // dense wire form — zero arithmetic, zero randomness.
            let grad: Update = match &mut st.codec {
                None => grad.into(),
                Some(codec) => codec.encode(grad, &mut st.clients[s].residual),
            };
            let fx = match &mut st.adversary {
                None => ApplyOutcome::clean(grad),
                Some(adv) => adv.apply(s, grad),
            };
            let (outcome, gateway) = match fx.update {
                Some(grad) => {
                    let g = route(s, hops);
                    st.fed.receive(g, s, grad, base, st.clients[s].n_samples);
                    (UploadOutcome::Delivered, g)
                }
                None => (UploadOutcome::Dropped, 0),
            };
            emit_event(
                &mut st.trace,
                &mut st.recorded,
                observer,
                RunEvent::Upload {
                    step: i,
                    origin: s,
                    gateway,
                    hops,
                    bytes: st.payload_bytes,
                    outcome,
                    injected: fx.injected,
                    corrupted: fx.corrupted,
                },
            );
        } else {
            emit_event(
                &mut st.trace,
                &mut st.recorded,
                observer,
                RunEvent::Upload {
                    step: i,
                    origin: s,
                    gateway: 0,
                    hops,
                    bytes: st.payload_bytes,
                    outcome: UploadOutcome::Idle,
                    injected: false,
                    corrupted: false,
                },
            );
        }
    }

    // 2. SCHEDULER + SERVERUPDATE, per gateway in index order (the
    // deterministic merge/update order of ADR-0006)
    for (g, policy) in st.policies.iter_mut().enumerate() {
        if policy.decide(i, conn, &st.fed.gateways[g].buffer) {
            let reconciles_before = st.fed.reconciles;
            // lint: allow(wall-clock): Timing events are identity-exempt (ADR-0002)
            let t = Instant::now();
            let stalenesses = st.fed.update(g, aggregator)?;
            let dt = t.elapsed().as_secs_f64();
            emit_event(
                &mut st.trace,
                &mut st.recorded,
                observer,
                RunEvent::Aggregate {
                    step: i,
                    gateway: g,
                    round: st.fed.round(),
                    staleness: stalenesses,
                },
            );
            emit_event(
                &mut st.trace,
                &mut st.recorded,
                observer,
                RunEvent::Timing { phase: TimingPhase::Aggregate, seconds: dt },
            );
            let merges = st.fed.reconciles - reconciles_before;
            if merges > 0 {
                emit_event(
                    &mut st.trace,
                    &mut st.recorded,
                    observer,
                    RunEvent::Reconcile { step: i, merges },
                );
            }
        }
    }

    // 3. broadcast (w^{i+1}, i_g) from each satellite's gateway and start
    // local training; a relayed delivery spends `delay` slots in flight,
    // pushing ready_at out. The version stamp is the global round.
    let round = st.fed.round();
    for (j, &s) in conn.iter().enumerate() {
        let hops = if conn_hops.is_empty() { 0 } else { conn_hops[j] as usize };
        let delay = hops * hop_delay;
        if st.clients[s].has_data() && st.clients[s].wants_model(round, i) {
            st.clients[s].receive(round, i, cfg.train_duration_slots + delay);
            // lint: allow(wall-clock): Timing events are identity-exempt (ADR-0002)
            let t = Instant::now();
            let model = st.fed.broadcast_model(route(s, hops));
            let (delta, _train_loss) = trainer.local_update(s, model, &mut st.sat_rngs[s])?;
            let dt = t.elapsed().as_secs_f64();
            emit_event(
                &mut st.trace,
                &mut st.recorded,
                observer,
                RunEvent::Timing { phase: TimingPhase::Train, seconds: dt },
            );
            st.clients[s].set_update(delta);
        }
    }

    // 3b. cross-gateway reconcile cadence (ADR-0006): before evaluation,
    // so the curve sees the model "after reconcile". A no-op for
    // `Centralized` and on quiet boundaries.
    let reconciles_before = st.fed.reconciles;
    st.fed.end_of_step(i);
    let merges = st.fed.reconciles - reconciles_before;
    if merges > 0 {
        emit_event(
            &mut st.trace,
            &mut st.recorded,
            observer,
            RunEvent::Reconcile { step: i, merges },
        );
    }

    // 4. periodic evaluation (of the global model)
    let last_step = i + 1 == n_steps;
    if (i + 1) % cfg.eval_every == 0 || last_step {
        // lint: allow(wall-clock): Timing events are identity-exempt (ADR-0002)
        let t = Instant::now();
        let global_w = st.fed.global_model();
        let (loss, acc) = trainer.evaluate(&global_w)?;
        let dt = t.elapsed().as_secs_f64();
        st.last_loss = loss;
        let day = (i + 1) as f64 * cfg.days_per_step;
        emit_event(
            &mut st.trace,
            &mut st.recorded,
            observer,
            RunEvent::Eval {
                step: i + 1,
                round: st.fed.round(),
                day,
                accuracy: acc,
                loss,
            },
        );
        emit_event(
            &mut st.trace,
            &mut st.recorded,
            observer,
            RunEvent::Timing { phase: TimingPhase::Eval, seconds: dt },
        );
        if let Some(target) = cfg.stop_at_accuracy {
            if acc >= target && st.days_to_target.is_none() {
                st.days_to_target = Some(day);
                return Ok(true);
            }
        }
    }
    Ok(false)
}

/// The simulation engine.
pub struct Engine<'a> {
    /// The deterministic connectivity schedule C to execute over.
    pub source: ScheduleSource<'a>,
    /// Local-training backend (PJRT artifacts or the analytic mock).
    pub trainer: &'a dyn Trainer,
    /// Eq.-4 server-update implementation (CPU or Pallas artifact) —
    /// engine-owned and shared across gateways (a stateless kernel,
    /// ADR-0006).
    pub aggregator: &'a mut dyn ServerAggregator,
    /// Engine knobs.
    pub cfg: EngineConfig,
    /// Per-gateway FedSpace planners, in gateway-index order (one entry
    /// per gateway iff algorithm == FedSpace, empty otherwise), collected
    /// by [`EngineBuilder::planner`] / [`EngineBuilder::planners`].
    pub planners: Vec<FedSpacePlanner>,
    /// Routed contact graph for precomputed-schedule engines (ADR-0005);
    /// streamed engines take their routing from the stream itself.
    isl: Option<&'a ContactGraph>,
    /// Federation topology + upload routing (ADR-0006); `None` runs the
    /// implicit single central gateway.
    federation: Option<(&'a FederationSpec, Option<&'a UploadRouting>)>,
}

/// Typed, validated construction of an [`Engine`] — the one surface that
/// replaced the `new` / `new_streamed` / `with_contact_graph` /
/// `with_federation` sprawl. Setters are order-free and purely assign;
/// every structural invariant (source/mode agreement, graph and routing
/// shape, planner-per-gateway counts) is asserted once, in
/// [`EngineBuilder::build`], so no partially-checked engine can exist.
pub struct EngineBuilder<'a> {
    source: Option<ScheduleSource<'a>>,
    trainer: Option<&'a dyn Trainer>,
    aggregator: Option<&'a mut dyn ServerAggregator>,
    cfg: Option<EngineConfig>,
    planners: Vec<FedSpacePlanner>,
    isl: Option<&'a ContactGraph>,
    federation: Option<(&'a FederationSpec, Option<&'a UploadRouting>)>,
}

impl<'a> EngineBuilder<'a> {
    /// Execute over a materialized schedule (dense / contact-list modes).
    /// Mutually exclusive with [`Self::stream`]; the later call wins.
    pub fn schedule(mut self, sched: &'a ConnectivitySchedule) -> Self {
        self.source = Some(ScheduleSource::Precomputed(sched));
        self
    }

    /// Execute over a chunked connectivity stream (streamed mode).
    pub fn stream(mut self, stream: &'a ConnectivityStream) -> Self {
        self.source = Some(ScheduleSource::Streamed(stream));
        self
    }

    /// Local-training backend.
    pub fn trainer(mut self, trainer: &'a dyn Trainer) -> Self {
        self.trainer = Some(trainer);
        self
    }

    /// Eq.-4 server-update implementation (shared across gateways).
    pub fn aggregator(mut self, aggregator: &'a mut dyn ServerAggregator) -> Self {
        self.aggregator = Some(aggregator);
        self
    }

    /// Engine knobs.
    pub fn config(mut self, cfg: EngineConfig) -> Self {
        self.cfg = Some(cfg);
        self
    }

    /// Append one FedSpace planner in gateway-index order (`None` appends
    /// nothing) — the single-gateway convenience form of [`Self::planners`].
    pub fn planner(mut self, planner: Option<FedSpacePlanner>) -> Self {
        self.planners.extend(planner);
        self
    }

    /// Append per-gateway FedSpace planners in gateway-index order. FedSpace
    /// engines need exactly one planner per gateway by [`Self::build`] time;
    /// other algorithms take none.
    pub fn planners(mut self, planners: Vec<FedSpacePlanner>) -> Self {
        self.planners.extend(planners);
        self
    }

    /// Attach a routed contact graph (ISLs, ADR-0005) to a
    /// precomputed-schedule engine: the walk then visits reach sets instead
    /// of direct contact sets, and the planner forecasts over the routed
    /// relation. `None` detaches (the plain satellite⇄station walk).
    /// Streamed engines reject this at build — they route inside their
    /// stream.
    pub fn contact_graph(mut self, graph: Option<&'a ContactGraph>) -> Self {
        self.isl = graph;
        self
    }

    /// Attach a multi-gateway federation (ADR-0006): `spec` names the
    /// gateways and reconcile policy; `routing` is required (and only
    /// consulted) when the spec has more than one gateway — single-gateway
    /// specs keep the raw pre-federation fast path.
    pub fn federation(
        mut self,
        spec: &'a FederationSpec,
        routing: Option<&'a UploadRouting>,
    ) -> Self {
        self.federation = Some((spec, routing));
        self
    }

    /// Validate and assemble the engine. Panics on structural misuse —
    /// missing required parts, source/mode disagreement, mis-shaped contact
    /// graph or routing table, wrong planner count — exactly the contracts
    /// the four retired constructors checked piecemeal.
    pub fn build(self) -> Engine<'a> {
        let source = self.source.expect("EngineBuilder needs a schedule(..) or stream(..)");
        let trainer = self.trainer.expect("EngineBuilder needs a trainer(..)");
        let aggregator = self.aggregator.expect("EngineBuilder needs an aggregator(..)");
        let cfg = self.cfg.expect("EngineBuilder needs a config(..)");
        match source {
            ScheduleSource::Precomputed(_) => assert!(
                cfg.mode != EngineMode::Streamed,
                "streamed mode executes over a ConnectivityStream — build with .stream(..)"
            ),
            ScheduleSource::Streamed(_) => assert!(
                cfg.mode == EngineMode::Streamed,
                "a ConnectivityStream source requires EngineMode::Streamed"
            ),
        }
        if let Some(g) = self.isl {
            assert!(
                matches!(source, ScheduleSource::Precomputed(_)),
                "streamed engines take ISLs from their ConnectivityStream"
            );
            assert_eq!(g.n_sats(), source.n_sats(), "graph/schedule fleet mismatch");
            assert_eq!(g.n_steps(), source.n_steps(), "graph/schedule horizon mismatch");
        }
        let federation = self.federation.map(|(spec, routing)| {
            let g = spec.n_gateways();
            assert!(g >= 1, "federation needs at least one gateway");
            let routing = if g > 1 {
                let r = routing.expect("multi-gateway federation needs an UploadRouting");
                assert_eq!(
                    r.n_steps(),
                    source.n_steps(),
                    "routing/schedule horizon mismatch"
                );
                // a table built for a wider federation would emit gateway
                // indexes past the spec's Federation (OOB mid-run); for a
                // validated spec the table's map-max+1 equals the gateway
                // count
                assert!(
                    r.n_gateways() <= g,
                    "routing table addresses {} gateways but the spec has {g}",
                    r.n_gateways()
                );
                Some(r)
            } else {
                None
            };
            (spec, routing)
        });
        let n_gateways = federation.map_or(1, |(spec, _)| spec.n_gateways());
        if cfg.algorithm == AlgorithmKind::FedSpace {
            assert_eq!(
                self.planners.len(),
                n_gateways,
                "FedSpace needs exactly one planner per gateway"
            );
            // the streamed walk materializes ONE planning window sized by
            // gateway 0's I0 and every gateway slices it — heterogeneous
            // window lengths would index past the materialized steps, so
            // reject them here instead of panicking inside the walk
            if let Some(first) = self.planners.first() {
                for p in &self.planners[1..] {
                    assert_eq!(
                        p.params.i0, first.params.i0,
                        "per-gateway planners must share one I0 window length"
                    );
                }
            }
        } else {
            assert!(self.planners.is_empty(), "planners without FedSpace");
        }
        Engine {
            source,
            trainer,
            aggregator,
            cfg,
            planners: self.planners,
            isl: self.isl,
            federation,
        }
    }
}

impl<'a> Engine<'a> {
    /// Start building an engine — see [`EngineBuilder`].
    pub fn builder() -> EngineBuilder<'a> {
        EngineBuilder {
            source: None,
            trainer: None,
            aggregator: None,
            cfg: None,
            planners: Vec::new(),
            isl: None,
            federation: None,
        }
    }

    /// Pre-builder constructor shim over a materialized schedule.
    #[deprecated(note = "use Engine::builder() — schedule/trainer/aggregator/config/planner")]
    pub fn new(
        sched: &'a ConnectivitySchedule,
        trainer: &'a dyn Trainer,
        aggregator: &'a mut dyn ServerAggregator,
        cfg: EngineConfig,
        planner: Option<FedSpacePlanner>,
    ) -> Self {
        Engine::builder()
            .schedule(sched)
            .trainer(trainer)
            .aggregator(aggregator)
            .config(cfg)
            .planner(planner)
            .build()
    }

    /// Pre-builder shim: attach a routed contact graph (ADR-0005) by
    /// rebuilding through [`EngineBuilder`], which re-checks every
    /// structural invariant.
    #[deprecated(note = "use Engine::builder().contact_graph(..)")]
    pub fn with_contact_graph(self, graph: Option<&'a ContactGraph>) -> Self {
        let Engine { source, trainer, aggregator, cfg, planners, isl: _, federation } = self;
        let mut b = Engine::builder()
            .trainer(trainer)
            .aggregator(aggregator)
            .config(cfg)
            .planners(planners)
            .contact_graph(graph);
        b.source = Some(source);
        b.federation = federation;
        b.build()
    }

    /// Pre-builder shim: attach a multi-gateway federation (ADR-0006) plus
    /// the planners of gateways `1..` by rebuilding through
    /// [`EngineBuilder`].
    #[deprecated(note = "use Engine::builder().federation(..) with .planners(..)")]
    pub fn with_federation(
        self,
        spec: &'a FederationSpec,
        routing: Option<&'a UploadRouting>,
        extra_planners: Vec<FedSpacePlanner>,
    ) -> Self {
        let Engine { source, trainer, aggregator, cfg, mut planners, isl, federation: _ } = self;
        planners.extend(extra_planners);
        let mut b = Engine::builder()
            .trainer(trainer)
            .aggregator(aggregator)
            .config(cfg)
            .planners(planners)
            .contact_graph(isl)
            .federation(spec, routing);
        b.source = Some(source);
        b.build()
    }

    /// Pre-builder constructor shim over a connectivity stream.
    #[deprecated(note = "use Engine::builder().stream(..)")]
    pub fn new_streamed(
        stream: &'a ConnectivityStream,
        trainer: &'a dyn Trainer,
        aggregator: &'a mut dyn ServerAggregator,
        cfg: EngineConfig,
        planner: Option<FedSpacePlanner>,
    ) -> Self {
        Engine::builder()
            .stream(stream)
            .trainer(trainer)
            .aggregator(aggregator)
            .config(cfg)
            .planner(planner)
            .build()
    }

    /// Build one gateway's policy. `quorum` is the gateway's per-gateway
    /// Sync threshold under `ReconcilePolicy::Quorum` — the with-data
    /// satellites the routing table attributes directly to it; `None`
    /// keeps the global with-data fleet (every other policy). The quorum
    /// is clamped to `[1, with_data]`: never below 1 (a zero-threshold
    /// Sync fires unconditionally on every step — a degenerate busy-loop,
    /// not a starved gateway's rescue) and never above the fleet that can
    /// contribute at all.
    fn make_policy(&self, quorum: Option<usize>) -> PolicyImpl {
        // effective client count: satellites with data (sync must not wait
        // forever for satellites that can never contribute)
        let with_data = (0..self.source.n_sats())
            .filter(|&k| self.trainer.sat_samples(k) > 0)
            .count();
        match self.cfg.algorithm {
            AlgorithmKind::Sync => {
                let n_sats = quorum.map_or(with_data, |q| q.max(1).min(with_data.max(1)));
                PolicyImpl::Sync(SyncPolicy { n_sats })
            }
            AlgorithmKind::Async => PolicyImpl::Async(AsyncPolicy),
            AlgorithmKind::FedBuff => {
                PolicyImpl::FedBuff(FedBuffPolicy { m: self.cfg.fedbuff_m.min(with_data) })
            }
            AlgorithmKind::FedSpace => PolicyImpl::FedSpace(ScheduledPolicy::new()),
        }
    }

    /// Execute Algorithm 1 end to end with the default [`NullSink`]
    /// observer (zero-cost: the sink monomorphizes to empty inlined
    /// calls, so unobserved runs stay bit- and speed-identical).
    pub fn run(&mut self) -> Result<RunResult> {
        self.run_observed(&mut NullSink)
    }

    /// Execute Algorithm 1 end to end, pushing every [`RunEvent`] into
    /// `observer` as it happens (ADR-0009). The engine's own `RunTrace`
    /// is itself derived from the same stream via [`TraceSink::apply`] —
    /// there is exactly one emission site per phenomenon and no separate
    /// counter bookkeeping.
    pub fn run_observed<S: EventSink>(&mut self, observer: &mut S) -> Result<RunResult> {
        let cfg = self.cfg.clone();
        let k = self.source.n_sats();
        let n_steps = self.source.n_steps();
        let mut rng = Rng::new(cfg.seed);
        let sat_rngs: Vec<Rng> = (0..k).map(|i| rng.split(i as u64 + 1)).collect();
        let clients: Vec<SatClient> =
            (0..k).map(|i| SatClient::new(i, self.trainer.sat_samples(i))).collect();
        // the implicit single central gateway unless a spec was attached
        let default_spec;
        let (spec, routing) = match self.federation {
            Some((s, r)) => (s, r),
            None => {
                default_spec = FederationSpec::single();
                (&default_spec, None)
            }
        };
        if cfg.algorithm == AlgorithmKind::FedSpace {
            assert_eq!(
                self.planners.len(),
                spec.n_gateways(),
                "FedSpace needs one planner per gateway"
            );
        }
        let fed = Federation::new(spec, self.trainer.init(&mut rng), cfg.alpha);
        let reconcile_every = spec.reconcile.cadence();
        // per-gateway sync quorum (ReconcilePolicy::Quorum): each gateway
        // awaits only the with-data satellites the routing table attributes
        // directly to it. Single-gateway runs have no table — the quorum
        // falls back to the global with-data fleet (≡ Periodic).
        let quorums: Option<Vec<usize>> = match spec.reconcile {
            ReconcilePolicy::Quorum { .. } => routing
                .map(|r| r.quorum_counts(k, |s| self.trainer.sat_samples(s) > 0)),
            _ => None,
        };
        let policies: Vec<PolicyImpl> = (0..spec.n_gateways())
            .map(|g| self.make_policy(quorums.as_ref().map(|q| q[g])))
            .collect();
        let adversary = cfg
            .attack
            .enabled()
            .then(|| Adversary::new(&cfg.attack, k, cfg.seed));
        let codec = cfg.link.enabled().then(|| UpdateCodec::new(&cfg.link, cfg.seed));
        let payload_bytes = if cfg.link.capacity_enabled() {
            cfg.link.payload_bytes(self.trainer.d())
        } else {
            0
        };
        let mut st = RunState {
            clients,
            sat_rngs,
            fed,
            policies,
            adversary,
            codec,
            payload_bytes,
            trace: RunTrace::default(),
            recorded: cfg.record_events.then(Vec::new),
            last_loss: 0.0,
            days_to_target: None,
        };

        // stream header: sizes every derived per-gateway vector up front,
        // so zero-activity gateways still show up as explicit zeros
        emit_event(
            &mut st.trace,
            &mut st.recorded,
            observer,
            RunEvent::RunStart { n_sats: k, n_steps, n_gateways: spec.n_gateways() },
        );

        // initial evaluation seeds the curve and the training status T
        // lint: allow(wall-clock): Timing events are identity-exempt (ADR-0002)
        let t0 = Instant::now();
        let (loss0, acc0) = self.trainer.evaluate(&st.fed.global_model())?;
        let dt0 = t0.elapsed().as_secs_f64();
        st.last_loss = loss0;
        emit_event(
            &mut st.trace,
            &mut st.recorded,
            observer,
            RunEvent::Eval { step: 0, round: 0, day: 0.0, accuracy: acc0, loss: loss0 },
        );
        emit_event(
            &mut st.trace,
            &mut st.recorded,
            observer,
            RunEvent::Timing { phase: TimingPhase::Eval, seconds: dt0 },
        );

        match self.source {
            ScheduleSource::Precomputed(sched) => {
                // ContactList: precompute the contact-event list once (from
                // the routed graph when ISLs are on); the other event
                // sources (planner horizon, scheduled slots) depend on live
                // policy state and are queried in `next_event`.
                let graph = self.isl;
                let hop_delay = graph.map_or(0, |g| g.hop_delay_slots);
                let active: Option<Vec<usize>> = match cfg.mode {
                    EngineMode::Dense => None,
                    EngineMode::ContactList => Some(match graph {
                        Some(g) => g.active_steps().to_vec(),
                        None => sched.active_steps(),
                    }),
                    EngineMode::Streamed => unreachable!("rejected by EngineBuilder::build"),
                };
                // the planner forecasts over the routed relation, so a
                // relayed satellite counts as reachable in the window
                let plan_view: &dyn StepView = match graph {
                    Some(g) => g,
                    None => sched,
                };
                // pass durations ride the plain schedule only (ISL reach
                // sets have no single pass duration — ADR-0008)
                let dur_denom = match graph {
                    Some(_) => 1,
                    None => StepView::duration_denom(sched),
                };
                let mut i = 0usize;
                while i < n_steps {
                    // zero-copy views into the sorted contact/reach lists
                    let (conn, hops, durs) = match graph {
                        Some(g) => (g.sats_at(i), g.hops_at(i), &[][..]),
                        None => (sched.sats_at(i), &[][..], sched.contact_durations_at(i)),
                    };
                    let stop = run_step(
                        &mut st,
                        self.trainer,
                        self.aggregator,
                        &mut self.planners,
                        routing,
                        &cfg,
                        Some(plan_view),
                        conn,
                        hops,
                        hop_delay,
                        durs,
                        dur_denom,
                        i,
                        n_steps,
                        observer,
                    )?;
                    if stop {
                        break;
                    }
                    i = match &active {
                        None => i + 1,
                        Some(act) => next_event(
                            i + 1,
                            act,
                            &st.policies,
                            n_steps,
                            cfg.eval_every,
                            reconcile_every,
                        ),
                    };
                }
            }
            ScheduleSource::Streamed(stream) => {
                let hop_delay = stream.hop_delay_slots();
                let mut cursor = StreamCursor::new(stream);
                let mut i = 0usize;
                while i < n_steps {
                    cursor.seek(i);
                    // materialize the planning window only at replan steps,
                    // sized by the planner's own I0 (candidate vectors must
                    // never index past the materialized window); the window
                    // carries the routed sets when the stream has ISLs
                    let window = if st.needs_replan(i) {
                        let i0 = self.planners.first().map_or(cfg.i0, |p| p.params.i0).max(1);
                        Some(cursor.window(i, i0))
                    } else {
                        None
                    };
                    let plan_view = window.as_ref().map(|w| w as &dyn StepView);
                    let (conn, hops) = cursor.chunk().contacts_at(i);
                    let durs = cursor.chunk().durations_at(i);
                    let stop = run_step(
                        &mut st,
                        self.trainer,
                        self.aggregator,
                        &mut self.planners,
                        routing,
                        &cfg,
                        plan_view,
                        conn,
                        hops,
                        hop_delay,
                        durs,
                        stream.duration_denom(),
                        i,
                        n_steps,
                        observer,
                    )?;
                    if stop {
                        break;
                    }
                    // contact events from the current chunk (routed when
                    // ISLs are on), global events from `next_event`; capped
                    // at the chunk boundary so lookahead never leaves the
                    // chunk. Visiting a boundary step early is at worst a
                    // provable no-op — the same argument that makes
                    // contact-list skipping sound.
                    let mut ni = next_event(
                        i + 1,
                        cursor.chunk().events(),
                        &st.policies,
                        n_steps,
                        cfg.eval_every,
                        reconcile_every,
                    );
                    let chunk_end = cursor.chunk().end();
                    if chunk_end < n_steps {
                        ni = ni.min(chunk_end);
                    }
                    i = ni;
                }
            }
        }

        // every trace counter is a derived view over the event stream
        // (ADR-0009) — the federation's own counters are kept only as an
        // independent cross-check that the derivation didn't drift
        debug_assert_eq!(st.trace.global_updates, st.fed.round());
        debug_assert_eq!(st.trace.reconciles, st.fed.reconciles);
        debug_assert_eq!(
            st.trace.gateway_aggs,
            st.fed.gateways.iter().map(|g| g.aggregations).collect::<Vec<_>>()
        );
        debug_assert_eq!(
            st.trace.gateway_uploads,
            st.fed.gateways.iter().map(|g| g.uploads).collect::<Vec<_>>()
        );
        let final_round = st.fed.round();
        Ok(RunResult {
            days_to_target: st
                .days_to_target
                .or_else(|| st.trace.curve.days_to_accuracy(cfg.stop_at_accuracy.unwrap_or(2.0))),
            trace: st.trace,
            events: st.recorded.take().unwrap_or_default(),
            final_round,
            final_w: st.fed.into_global_model(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::CpuAggregator;
    use crate::orbit::{planet_ground_stations, planet_labs_like};
    use crate::sched::{SearchParams, UtilityModel};
    use crate::sim::trainer::MockTrainer;

    fn small_sched(n_sats: usize, steps: usize) -> ConnectivitySchedule {
        let c = planet_labs_like(n_sats, 0);
        let gs = planet_ground_stations();
        ConnectivitySchedule::compute(&c, &gs, steps, Default::default())
    }

    fn run_mock(algorithm: AlgorithmKind, m: usize, steps: usize) -> RunResult {
        let sched = small_sched(12, steps);
        let trainer = MockTrainer::new(16, 12, 0.3, 0);
        let mut agg = CpuAggregator;
        let cfg = EngineConfig {
            algorithm,
            fedbuff_m: m,
            eval_every: 4,
            ..Default::default()
        };
        let mut e = Engine::builder()
            .schedule(&sched)
            .trainer(&trainer)
            .aggregator(&mut agg)
            .config(cfg)
            .planner(mode_planner(algorithm))
            .build();
        e.run().unwrap()
    }

    #[test]
    fn all_algorithms_complete_and_learn() {
        for alg in [
            AlgorithmKind::Sync,
            AlgorithmKind::Async,
            AlgorithmKind::FedBuff,
            AlgorithmKind::FedSpace,
        ] {
            let r = run_mock(alg, 4, 96);
            assert!(!r.trace.curve.points.is_empty(), "{alg:?}");
            if alg != AlgorithmKind::Sync {
                // everyone except sync should make multiple global updates
                // in a simulated day
                assert!(r.final_round >= 1, "{alg:?} rounds={}", r.final_round);
                let first = r.trace.curve.points.first().unwrap().accuracy;
                let best = r.trace.curve.best_accuracy();
                assert!(best > first, "{alg:?} did not improve");
            }
        }
    }

    #[test]
    fn sync_has_more_idle_fraction_than_async() {
        let sync = run_mock(AlgorithmKind::Sync, 4, 96);
        let asy = run_mock(AlgorithmKind::Async, 4, 96);
        assert!(sync.trace.idle_fraction() > asy.trace.idle_fraction());
    }

    #[test]
    fn async_updates_most_frequently() {
        let asy = run_mock(AlgorithmKind::Async, 4, 96);
        let fb = run_mock(AlgorithmKind::FedBuff, 6, 96);
        let sync = run_mock(AlgorithmKind::Sync, 4, 96);
        assert!(asy.final_round >= fb.final_round);
        assert!(fb.final_round >= sync.final_round);
    }

    #[test]
    fn async_has_larger_max_staleness_than_fedbuff() {
        let asy = run_mock(AlgorithmKind::Async, 4, 192);
        let fb = run_mock(AlgorithmKind::FedBuff, 6, 192);
        let max = |r: &RunResult| r.trace.staleness.max_key().unwrap_or(0);
        assert!(max(&asy) >= max(&fb), "async={} fedbuff={}", max(&asy), max(&fb));
    }

    #[test]
    #[ignore = "tuning sweep, run with --ignored --nocapture"]
    fn sweep_mock_regimes() {
        for (het, lr, noise, target) in [
            (1.0f32, 0.15f32, 0.3f32, 0.9f64),
            (1.5, 0.1, 0.5, 0.9),
            (2.0, 0.1, 0.8, 0.9),
        ] {
            println!("--- het={het} lr={lr} noise={noise} target={target}");
            for m in [1usize, 2, 4, 8, 12] {
                let sched = small_sched(12, 480);
                let mut trainer = MockTrainer::new(16, 12, het, 0);
                trainer.lr = lr;
                trainer.noise = noise;
                let mut agg = CpuAggregator;
                let cfg = EngineConfig {
                    algorithm: if m == 12 { AlgorithmKind::Sync } else { AlgorithmKind::FedBuff },
                    fedbuff_m: m,
                    stop_at_accuracy: Some(target),
                    ..Default::default()
                };
                let mut e = Engine::builder()
            .schedule(&sched)
            .trainer(&trainer)
            .aggregator(&mut agg)
            .config(cfg)
            .build();
                let r = e.run().unwrap();
                println!(
                    "  M={m:<3} days={:?} best={:.3} rounds={} max_s={:?}",
                    r.days_to_target,
                    r.trace.curve.best_accuracy(),
                    r.final_round,
                    r.trace.staleness.max_key()
                );
            }
        }
    }

    /// the staleness-matters regime found by `sweep_mock_regimes`: async
    /// plateaus below the target, buffered schemes reach it — the paper's
    /// Figure 6 shape.
    fn hard_mock(n_sats: usize) -> MockTrainer {
        let mut t = MockTrainer::new(16, n_sats, 1.0, 0);
        t.lr = 0.15;
        t.noise = 0.3;
        t
    }

    #[test]
    #[ignore = "debug instrumentation"]
    fn debug_fedspace_schedule() {
        let sched = small_sched(12, 480);
        let trainer = hard_mock(12);
        let backend =
            crate::sim::trainer::TrainerSampleBackend { trainer: &trainer, n_sats: 12 };
        let mut urng = crate::rng::Rng::new(0);
        let bank = crate::sched::pretrain_bank(&backend, 20, 6, 0.5, &mut urng).unwrap();
        let (inp, tgt) =
            crate::sched::generate_samples(&backend, &bank, 400, 8, 12, 0.5, &mut urng).unwrap();
        let mut utility = UtilityModel::new("forest").unwrap();
        utility.fit(&inp, &tgt);
        // probe û's shape
        for t in [bank.losses[0], bank.losses[10], bank.losses[19]] {
            println!(
                "T={t:.4}: u([0x1])={:.4} u([0x4])={:.4} u([0x8])={:.4} u([4x4])={:.4}",
                utility.predict(&[0], t),
                utility.predict(&[0, 0, 0, 0], t),
                utility.predict(&[0; 8], t),
                utility.predict(&[4, 4, 4, 4], t)
            );
        }
        let mut planner = FedSpacePlanner::new(
            utility,
            SearchParams { i0: 24, n_min: 4, n_max: 16, n_search: 300 },
            0,
        );
        // plan first window from fresh states and show the forecast
        let states = vec![crate::sched::SatForecastState::fresh(); 12];
        let w = planner.plan(&sched, 0, &states, bank.losses[0]);
        let n: usize = w.iter().filter(|&&b| b).count();
        println!("window0: n_agg={n} predicted_u={:.4}", planner.planned_utilities[0]);
        let f = crate::sched::forecast_window(&sched, 0, &w, &states);
        println!("forecast aggs: {:?}", f.aggregations);
        // live run comparison
        let mut agg = CpuAggregator;
        let cfg = EngineConfig {
            algorithm: AlgorithmKind::FedSpace,
            stop_at_accuracy: Some(0.9),
            ..Default::default()
        };
        let mut e = Engine::builder()
            .schedule(&sched)
            .trainer(&trainer)
            .aggregator(&mut agg)
            .config(cfg)
            .planner(Some(planner))
            .build();
        let r = e.run().unwrap();
        println!(
            "fedspace live: days={:?} rounds={} uploads={} idle={} stal={:?}",
            r.days_to_target,
            r.final_round,
            r.trace.uploads,
            r.trace.idle,
            r.trace.staleness.entries().collect::<Vec<_>>()
        );
        for p in r.trace.curve.points.iter().take(20) {
            println!("  day={:.2} acc={:.3} round={}", p.day, p.accuracy, p.round);
        }
        let trainer2 = hard_mock(12);
        let mut agg2 = CpuAggregator;
        let cfg2 = EngineConfig {
            algorithm: AlgorithmKind::FedBuff,
            fedbuff_m: 8,
            stop_at_accuracy: Some(0.9),
            ..Default::default()
        };
        let mut e2 = Engine::builder()
            .schedule(&sched)
            .trainer(&trainer2)
            .aggregator(&mut agg2)
            .config(cfg2)
            .build();
        let r2 = e2.run().unwrap();
        println!(
            "fedbuff8 live: days={:?} rounds={} uploads={} idle={} stal={:?}",
            r2.days_to_target,
            r2.final_round,
            r2.trace.uploads,
            r2.trace.idle,
            r2.trace.staleness.entries().collect::<Vec<_>>()
        );
        for p in r2.trace.curve.points.iter().take(20) {
            println!("  day={:.2} acc={:.3} round={}", p.day, p.accuracy, p.round);
        }
    }

    #[test]
    fn fedspace_reaches_target_no_slower_than_fedbuff() {
        // With a fitted û, FedSpace's schedule should be competitive
        // (within 1.5x) with the best FedBuff configuration.
        const TARGET: f64 = 0.9;
        const K: usize = 48;
        let mut best_fb = f64::INFINITY;
        for m in [8, 16, 32] {
            let sched = small_sched(K, 480);
            let trainer = hard_mock(K);
            let mut agg = CpuAggregator;
            let cfg = EngineConfig {
                algorithm: AlgorithmKind::FedBuff,
                fedbuff_m: m,
                stop_at_accuracy: Some(TARGET),
                ..Default::default()
            };
            let mut e = Engine::builder()
            .schedule(&sched)
            .trainer(&trainer)
            .aggregator(&mut agg)
            .config(cfg)
            .build();
            if let Some(d) = e.run().unwrap().days_to_target {
                best_fb = best_fb.min(d);
            }
        }
        let sched = small_sched(K, 480);
        let trainer = hard_mock(K);
        let mut agg = CpuAggregator;
        // fit û via phase 1 on the *same* task (paper §4.3: source = target)
        let backend =
            crate::sim::trainer::TrainerSampleBackend { trainer: &trainer, n_sats: K };
        let mut urng = crate::rng::Rng::new(0);
        let bank = crate::sched::pretrain_bank(&backend, 20, 8, 0.5, &mut urng).unwrap();
        let (inp, tgt) =
            crate::sched::generate_samples(&backend, &bank, 400, 8, 24, 0.5, &mut urng).unwrap();
        let mut utility = UtilityModel::new("forest").unwrap();
        utility.fit(&inp, &tgt);
        let planner = FedSpacePlanner::new(
            utility,
            SearchParams { i0: 24, n_min: 4, n_max: 8, n_search: 300 },
            0,
        );
        let cfg = EngineConfig {
            algorithm: AlgorithmKind::FedSpace,
            stop_at_accuracy: Some(TARGET),
            ..Default::default()
        };
        let mut e = Engine::builder()
            .schedule(&sched)
            .trainer(&trainer)
            .aggregator(&mut agg)
            .config(cfg)
            .planner(Some(planner))
            .build();
        let fs = e.run().unwrap().days_to_target;
        assert!(best_fb.is_finite(), "fedbuff never reached target");
        let fs = fs.expect("fedspace never reached target");
        assert!(fs <= best_fb * 1.5, "fedspace={fs} fedbuff={best_fb}");
    }

    #[test]
    fn trace_global_updates_single_source_of_truth() {
        // trace.global_updates counts engine-performed aggregations; it must
        // equal the GS round counter at the end for every policy (it used to
        // be overwritten with gs.i_g, hiding any divergence)
        for alg in [
            AlgorithmKind::Sync,
            AlgorithmKind::Async,
            AlgorithmKind::FedBuff,
            AlgorithmKind::FedSpace,
        ] {
            let r = run_mock(alg, 4, 96);
            assert_eq!(
                r.trace.global_updates, r.final_round,
                "{alg:?}: trace={} final_round={}",
                r.trace.global_updates, r.final_round
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_mock(AlgorithmKind::FedBuff, 4, 48);
        let b = run_mock(AlgorithmKind::FedBuff, 4, 48);
        assert_eq!(a.final_round, b.final_round);
        assert_eq!(a.trace.curve.points.len(), b.trace.curve.points.len());
        for (p, q) in a.trace.curve.points.iter().zip(b.trace.curve.points.iter()) {
            assert_eq!(p.accuracy, q.accuracy);
        }
    }

    use crate::testing::assert_same_run;

    fn mode_planner(algorithm: AlgorithmKind) -> Option<FedSpacePlanner> {
        if algorithm == AlgorithmKind::FedSpace {
            Some(FedSpacePlanner::new(
                UtilityModel::new("forest").unwrap(),
                SearchParams { i0: 24, n_min: 2, n_max: 8, n_search: 100 },
                0,
            ))
        } else {
            None
        }
    }

    /// Run one algorithm in any of the three engine modes over the same
    /// 12-satellite constellation; streamed mode goes through a
    /// [`ConnectivityStream`] with a deliberately awkward chunk length so
    /// events land on chunk boundaries.
    fn run_mock_mode(
        algorithm: AlgorithmKind,
        m: usize,
        steps: usize,
        mode: crate::cfg::EngineMode,
        stop_at: Option<f64>,
    ) -> RunResult {
        let trainer = MockTrainer::new(16, 12, 0.3, 0);
        let mut agg = CpuAggregator;
        let cfg = EngineConfig {
            algorithm,
            fedbuff_m: m,
            eval_every: 4,
            stop_at_accuracy: stop_at,
            mode,
            ..Default::default()
        };
        if mode == crate::cfg::EngineMode::Streamed {
            let c = planet_labs_like(12, 0);
            let gs = planet_ground_stations();
            let stream = ConnectivityStream::new(&c, &gs, steps, Default::default(), 31);
            let mut e = Engine::builder()
                .stream(&stream)
                .trainer(&trainer)
                .aggregator(&mut agg)
                .config(cfg)
                .planner(mode_planner(algorithm))
                .build();
            e.run().unwrap()
        } else {
            let sched = small_sched(12, steps);
            let mut e = Engine::builder()
                .schedule(&sched)
                .trainer(&trainer)
                .aggregator(&mut agg)
                .config(cfg)
                .planner(mode_planner(algorithm))
                .build();
            e.run().unwrap()
        }
    }

    #[test]
    fn contact_list_mode_bit_identical_to_dense_all_algorithms() {
        use crate::cfg::EngineMode;
        for alg in [
            AlgorithmKind::Sync,
            AlgorithmKind::Async,
            AlgorithmKind::FedBuff,
            AlgorithmKind::FedSpace,
        ] {
            let dense = run_mock_mode(alg, 4, 192, EngineMode::Dense, None);
            let sparse = run_mock_mode(alg, 4, 192, EngineMode::ContactList, None);
            assert_same_run(&dense, &sparse, &format!("{alg:?}"));
        }
    }

    #[test]
    fn streamed_mode_bit_identical_to_dense_and_contact_list() {
        use crate::cfg::EngineMode;
        for alg in [
            AlgorithmKind::Sync,
            AlgorithmKind::Async,
            AlgorithmKind::FedBuff,
            AlgorithmKind::FedSpace,
        ] {
            let dense = run_mock_mode(alg, 4, 192, EngineMode::Dense, None);
            let sparse = run_mock_mode(alg, 4, 192, EngineMode::ContactList, None);
            let streamed = run_mock_mode(alg, 4, 192, EngineMode::Streamed, None);
            assert_same_run(&dense, &streamed, &format!("{alg:?} dense vs streamed"));
            assert_same_run(&sparse, &streamed, &format!("{alg:?} contacts vs streamed"));
        }
    }

    #[test]
    fn streamed_mode_matches_dense_with_early_stop() {
        use crate::cfg::EngineMode;
        let dense = run_mock_mode(AlgorithmKind::FedBuff, 4, 192, EngineMode::Dense, Some(0.6));
        let streamed =
            run_mock_mode(AlgorithmKind::FedBuff, 4, 192, EngineMode::Streamed, Some(0.6));
        assert_same_run(&dense, &streamed, "fedbuff stop@0.6 streamed");
    }

    #[test]
    fn streamed_mode_chunk_len_is_a_resource_knob_not_a_semantics_knob() {
        // any chunk length must reproduce the identical trace — chunk
        // boundaries are only extra visited no-op steps
        use crate::cfg::EngineMode;
        let c = planet_labs_like(12, 0);
        let gs = planet_ground_stations();
        let trainer = MockTrainer::new(16, 12, 0.3, 0);
        let cfg = EngineConfig {
            algorithm: AlgorithmKind::FedSpace,
            eval_every: 4,
            mode: EngineMode::Streamed,
            ..Default::default()
        };
        let mut results = Vec::new();
        for chunk_len in [1usize, 5, 24, 96, 500] {
            let stream = ConnectivityStream::new(&c, &gs, 96, Default::default(), chunk_len);
            let mut agg = CpuAggregator;
            let mut e = Engine::builder()
                .stream(&stream)
                .trainer(&trainer)
                .aggregator(&mut agg)
                .config(cfg.clone())
                .planner(mode_planner(AlgorithmKind::FedSpace))
                .build();
            results.push(e.run().unwrap());
        }
        for r in &results[1..] {
            assert_same_run(&results[0], r, "chunk-length sweep");
        }
    }

    #[test]
    fn contact_list_mode_matches_dense_with_early_stop() {
        use crate::cfg::EngineMode;
        let dense = run_mock_mode(AlgorithmKind::FedBuff, 4, 192, EngineMode::Dense, Some(0.6));
        let sparse =
            run_mock_mode(AlgorithmKind::FedBuff, 4, 192, EngineMode::ContactList, Some(0.6));
        assert_same_run(&dense, &sparse, "fedbuff stop@0.6");
    }

    #[test]
    fn contact_list_mode_handles_sparse_schedules() {
        use crate::cfg::EngineMode;
        // hand-built schedule where most steps are contact-free, including
        // a long dead tail and a dead head
        let mut sets = vec![Vec::new(); 200];
        sets[7] = vec![0, 1];
        sets[8] = vec![2];
        sets[55] = vec![0, 3];
        sets[56] = vec![1, 2, 3];
        sets[120] = vec![0, 1, 2, 3];
        let sched = ConnectivitySchedule::from_sets(sets, 4);
        let trainer = MockTrainer::new(8, 4, 0.2, 1);
        let mut results = Vec::new();
        for mode in [EngineMode::Dense, EngineMode::ContactList] {
            let mut agg = CpuAggregator;
            let cfg = EngineConfig {
                algorithm: AlgorithmKind::Async,
                eval_every: 16,
                mode,
                ..Default::default()
            };
            let mut e = Engine::builder()
            .schedule(&sched)
            .trainer(&trainer)
            .aggregator(&mut agg)
            .config(cfg)
            .build();
            results.push(e.run().unwrap());
        }
        assert_same_run(&results[0], &results[1], "sparse async");
        assert!(results[0].final_round >= 1);
    }

    /// A single 5-satellite plane (ring 0-1-2-3-4-0) where only satellite 0
    /// ever sees the ground: everything reaches the GS through relays.
    fn ring5_graph(max_hops: usize, hop_delay_slots: usize, steps: usize) -> ContactGraph {
        use crate::connectivity::{IslParams, IslTopology};
        use crate::orbit::{Constellation, WalkerPattern, WalkerSpec};
        let c = Constellation::walker(&WalkerSpec {
            pattern: WalkerPattern::Delta,
            n_sats: 5,
            planes: 1,
            phasing: 0,
            alt_m: 550e3,
            inc_deg: 53.0,
        });
        let topo = IslTopology::new(
            &c,
            IslParams {
                max_hops,
                hop_delay_slots,
                cross_plane: false,
                max_range_m: 0.0,
                t0_s: 900.0,
            },
        )
        .unwrap();
        let sched = ConnectivitySchedule::from_sets(vec![vec![0]; steps], 5);
        ContactGraph::build(&topo, &sched)
    }

    fn run_ring5(graph: &ContactGraph, steps: usize) -> RunResult {
        let sched = ConnectivitySchedule::from_sets(vec![vec![0]; steps], 5);
        let trainer = MockTrainer::new(8, 5, 0.2, 0);
        let mut agg = CpuAggregator;
        let cfg = EngineConfig {
            algorithm: AlgorithmKind::Async,
            eval_every: 4,
            ..Default::default()
        };
        let mut e = Engine::builder()
            .schedule(&sched)
            .trainer(&trainer)
            .aggregator(&mut agg)
            .config(cfg)
            .contact_graph(Some(graph))
            .build();
        e.run().unwrap()
    }

    #[test]
    fn relays_let_non_visible_satellites_contribute() {
        const STEPS: usize = 24;
        let graph = ring5_graph(2, 0, STEPS);
        let routed = run_ring5(&graph, STEPS);
        // without ISLs only satellite 0 ever uploads
        let sched = ConnectivitySchedule::from_sets(vec![vec![0]; STEPS], 5);
        let trainer = MockTrainer::new(8, 5, 0.2, 0);
        let mut agg = CpuAggregator;
        let cfg = EngineConfig {
            algorithm: AlgorithmKind::Async,
            eval_every: 4,
            ..Default::default()
        };
        let mut e = Engine::builder()
            .schedule(&sched)
            .trainer(&trainer)
            .aggregator(&mut agg)
            .config(cfg)
            .build();
        let direct = e.run().unwrap();
        assert!(routed.trace.relayed > 0, "no relayed uploads on a relay-only topology");
        assert!(
            routed.trace.uploads > direct.trace.uploads,
            "relays must add uploads: routed={} direct={}",
            routed.trace.uploads,
            direct.trace.uploads
        );
        // attribution: relayed gradients land under their origin ids, so
        // more distinct satellites contribute than the one visible sat
        assert_eq!(routed.trace.connections, STEPS * 5);
    }

    #[test]
    fn hop_delay_defers_relayed_uploads() {
        const STEPS: usize = 24;
        let free = run_ring5(&ring5_graph(2, 0, STEPS), STEPS);
        let slow = run_ring5(&ring5_graph(2, 2, STEPS), STEPS);
        // charging 2 slots per hop on both legs strictly reduces how many
        // uploads fit into the same horizon
        assert!(
            slow.trace.uploads < free.trace.uploads,
            "hop delay had no effect: slow={} free={}",
            slow.trace.uploads,
            free.trace.uploads
        );
        assert!(slow.trace.relayed > 0, "delayed relays must still arrive");
    }

    #[test]
    fn contact_graph_engine_identical_across_dense_and_contact_list() {
        use crate::cfg::EngineMode;
        const STEPS: usize = 48;
        let graph = ring5_graph(2, 1, STEPS);
        let sched = ConnectivitySchedule::from_sets(vec![vec![0]; STEPS], 5);
        let trainer = MockTrainer::new(8, 5, 0.2, 0);
        let mut results = Vec::new();
        for mode in [EngineMode::Dense, EngineMode::ContactList] {
            let mut agg = CpuAggregator;
            let cfg = EngineConfig {
                algorithm: AlgorithmKind::FedBuff,
                fedbuff_m: 3,
                eval_every: 4,
                mode,
                ..Default::default()
            };
            let mut e = Engine::builder()
                .schedule(&sched)
                .trainer(&trainer)
                .aggregator(&mut agg)
                .config(cfg)
                .contact_graph(Some(&graph))
                .build();
            results.push(e.run().unwrap());
        }
        assert_same_run(&results[0], &results[1], "ring5 routed dense vs contacts");
        assert!(results[0].trace.relayed > 0);
    }

    #[test]
    fn next_event_enumerates_event_superset() {
        // contacts at 3 and 10, eval_every=4 (evals at 3, 7, 11, ...), 16 steps
        let active = vec![3usize, 10];
        let policy = [PolicyImpl::Async(AsyncPolicy)];
        let mut events = Vec::new();
        let mut i = 0usize;
        while i < 16 {
            events.push(i);
            i = next_event(i + 1, &active, &policy, 16, 4, None);
        }
        // step 0 (loop entry), evals at 3/7/11/15, contacts at 3/10, last=15
        assert_eq!(events, vec![0, 3, 7, 10, 11, 15]);
        // degenerate sync (no clients) must not skip anything
        let sync0 = [PolicyImpl::Sync(SyncPolicy { n_sats: 0 })];
        assert_eq!(next_event(5, &active, &sync0, 16, 4, None), 5);
        // past the end
        assert_eq!(next_event(16, &active, &policy, 16, 4, None), 16);
        // periodic reconcile boundaries are events: every=6 fires at steps
        // 5 and 11 (end of slots 6 and 12)
        assert_eq!(next_event(4, &active, &policy, 16, 100, Some(6)), 5);
        assert_eq!(next_event(6, &active, &policy, 16, 100, Some(6)), 10);
        assert_eq!(next_event(11, &active, &policy, 16, 100, Some(6)), 11);
        // a degenerate policy in ANY gateway slot disables skipping
        let mixed = [PolicyImpl::Async(AsyncPolicy), PolicyImpl::FedBuff(FedBuffPolicy { m: 0 })];
        assert_eq!(next_event(5, &active, &mixed, 16, 4, None), 5);
    }

    /// Run one algorithm under an explicit federation spec over the
    /// 12-satellite fleet and the full 12-station network (6/6 split for
    /// two gateways), dense mode.
    fn run_fed(spec: &FederationSpec, algorithm: AlgorithmKind, steps: usize) -> RunResult {
        let c = planet_labs_like(12, 0);
        let stations = planet_ground_stations();
        let params: crate::connectivity::ConnectivityParams = Default::default();
        let sched = ConnectivitySchedule::compute(&c, &stations, steps, params.clone());
        spec.validate(stations.len()).unwrap();
        let routing = (!spec.is_single()).then(|| {
            crate::fl::UploadRouting::build(&c, &stations, steps, &params, &spec.stations)
        });
        let trainer = MockTrainer::new(16, 12, 0.3, 0);
        let mut agg = CpuAggregator;
        let cfg = EngineConfig {
            algorithm,
            fedbuff_m: 4,
            eval_every: 4,
            ..Default::default()
        };
        let extra: Vec<FedSpacePlanner> = if algorithm == AlgorithmKind::FedSpace {
            (1..spec.n_gateways())
                .map(|g| {
                    FedSpacePlanner::new(
                        UtilityModel::new("forest").unwrap(),
                        SearchParams { i0: 24, n_min: 2, n_max: 8, n_search: 100 },
                        g as u64,
                    )
                })
                .collect()
        } else {
            Vec::new()
        };
        let mut e = Engine::builder()
            .schedule(&sched)
            .trainer(&trainer)
            .aggregator(&mut agg)
            .config(cfg)
            .planner(mode_planner(algorithm))
            .planners(extra)
            .federation(spec, routing.as_ref())
            .build();
        e.run().unwrap()
    }

    fn half_half_spec(reconcile: crate::fl::ReconcilePolicy) -> FederationSpec {
        FederationSpec::split(&["west", "east"], &[0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 1, 1], reconcile)
    }

    #[test]
    fn single_gateway_federation_identical_to_implicit_engine() {
        // an explicit 1-gateway spec must reproduce the plain engine path
        // bit for bit — the federation refactor's core safety net
        for alg in [AlgorithmKind::Async, AlgorithmKind::FedBuff, AlgorithmKind::FedSpace] {
            let plain = run_mock(alg, 4, 96);
            let fed = run_fed(&FederationSpec::single(), alg, 96);
            assert_same_run(&plain, &fed, &format!("{alg:?} single-gateway spec"));
            assert_eq!(fed.trace.gateway_aggs, vec![fed.final_round]);
            assert_eq!(fed.trace.gateway_uploads, vec![fed.trace.uploads]);
            assert_eq!(fed.trace.reconciles, 0);
        }
    }

    #[test]
    fn single_gateway_periodic_reconcile_identical_to_centralized() {
        // the ISSUE's property: Periodic { every } with ONE gateway must be
        // trace-identical to Centralized for any cadence and algorithm —
        // merging one full-weight model is an exact copy. Only the merge
        // counter may differ (Periodic counts its no-op-on-bits merges).
        crate::testing::property(6, |rng| {
            let every = rng.gen_range(1, 40);
            let alg = match rng.gen_range(0, 3) {
                0 => AlgorithmKind::Async,
                1 => AlgorithmKind::FedBuff,
                _ => AlgorithmKind::FedSpace,
            };
            let central = run_fed(&FederationSpec::single(), alg, 96);
            let spec = FederationSpec::single()
                .with_reconcile(crate::fl::ReconcilePolicy::Periodic { every });
            let mut periodic = run_fed(&spec, alg, 96);
            periodic.trace.reconciles = central.trace.reconciles;
            assert_same_run(&central, &periodic, &format!("{alg:?} every={every}"));
        });
    }

    #[test]
    fn on_aggregate_reconcile_identical_to_centralized_on_two_gateways() {
        // eager reconciliation pushes every aggregation through the merge
        // machinery; arithmetically that IS centralized aggregation, so the
        // traces must agree bit for bit (modulo the merge counter) — the
        // strongest gate on the merge path
        let spec = half_half_spec(crate::fl::ReconcilePolicy::Centralized);
        let central = run_fed(&spec, AlgorithmKind::FedBuff, 96);
        let spec = half_half_spec(crate::fl::ReconcilePolicy::OnAggregate);
        let mut eager = run_fed(&spec, AlgorithmKind::FedBuff, 96);
        assert!(eager.trace.reconciles > 0, "eager reconcile never merged");
        eager.trace.reconciles = central.trace.reconciles;
        assert_same_run(&central, &eager, "on-aggregate vs centralized");
    }

    #[test]
    fn two_gateways_report_per_gateway_counters() {
        let spec = half_half_spec(crate::fl::ReconcilePolicy::Centralized);
        let r = run_fed(&spec, AlgorithmKind::Async, 96);
        assert_eq!(r.trace.gateway_aggs.len(), 2);
        assert_eq!(r.trace.gateway_uploads.len(), 2);
        assert_eq!(r.trace.gateway_aggs.iter().sum::<usize>(), r.final_round);
        assert_eq!(r.trace.gateway_uploads.iter().sum::<usize>(), r.trace.uploads);
        // the planet12 network splits real traffic across both halves
        assert!(
            r.trace.gateway_uploads.iter().all(|&u| u > 0),
            "both gateways should hear satellites: {:?}",
            r.trace.gateway_uploads
        );
    }

    #[test]
    fn periodic_reconcile_changes_the_trace_deterministically() {
        let spec = half_half_spec(crate::fl::ReconcilePolicy::Periodic { every: 12 });
        let a = run_fed(&spec, AlgorithmKind::FedBuff, 192);
        let b = run_fed(&spec, AlgorithmKind::FedBuff, 192);
        assert_same_run(&a, &b, "periodic replay");
        assert!(a.trace.reconciles > 0, "cadence never fired");
        // diverged gateway replicas must leave a visible mark vs centralized
        let cspec = half_half_spec(crate::fl::ReconcilePolicy::Centralized);
        let central = run_fed(&cspec, AlgorithmKind::FedBuff, 192);
        let diverged = a
            .final_w
            .iter()
            .zip(central.final_w.iter())
            .any(|(x, y)| x.to_bits() != y.to_bits())
            || a.trace
                .curve
                .points
                .iter()
                .zip(central.trace.curve.points.iter())
                .any(|(p, q)| p.accuracy.to_bits() != q.accuracy.to_bits());
        assert!(diverged, "periodic reconcile left no trace difference");
    }

    #[test]
    fn satellites_without_data_never_upload() {
        // trainer reporting zero samples for sat 0
        struct NoDataSat(MockTrainer);
        impl Trainer for NoDataSat {
            fn d(&self) -> usize {
                self.0.d()
            }
            fn init(&self, rng: &mut Rng) -> Vec<f32> {
                self.0.init(rng)
            }
            fn local_update(&self, s: usize, w: &[f32], r: &mut Rng) -> Result<(Vec<f32>, f32)> {
                assert_ne!(s, 0, "satellite 0 has no data but trained");
                self.0.local_update(s, w, r)
            }
            fn evaluate(&self, w: &[f32]) -> Result<(f64, f64)> {
                self.0.evaluate(w)
            }
            fn sat_samples(&self, s: usize) -> usize {
                if s == 0 {
                    0
                } else {
                    100
                }
            }
        }
        let sched = small_sched(6, 96);
        let trainer = NoDataSat(MockTrainer::new(8, 6, 0.1, 0));
        let mut agg = CpuAggregator;
        let cfg = EngineConfig { algorithm: AlgorithmKind::Async, ..Default::default() };
        let mut e = Engine::builder()
            .schedule(&sched)
            .trainer(&trainer)
            .aggregator(&mut agg)
            .config(cfg)
            .build();
        let r = e.run().unwrap();
        assert!(r.final_round > 0);
    }

    #[test]
    fn make_policy_applies_the_sync_quorum_clamped() {
        let sched = small_sched(12, 24);
        let trainer = MockTrainer::new(16, 12, 0.3, 0);
        let mut agg = CpuAggregator;
        let cfg = EngineConfig { algorithm: AlgorithmKind::Sync, ..Default::default() };
        let e = Engine::builder()
            .schedule(&sched)
            .trainer(&trainer)
            .aggregator(&mut agg)
            .config(cfg)
            .build();
        // no quorum: the global with-data fleet
        let PolicyImpl::Sync(p) = e.make_policy(None) else { panic!() };
        assert_eq!(p.n_sats, 12);
        // a gateway that hears 3 with-data satellites awaits exactly those
        let PolicyImpl::Sync(p) = e.make_policy(Some(3)) else { panic!() };
        assert_eq!(p.n_sats, 3);
        // clamped below by 1 (quorum 0 must not become an unconditional
        // every-step aggregation) and above by the with-data fleet
        let PolicyImpl::Sync(p) = e.make_policy(Some(0)) else { panic!() };
        assert_eq!(p.n_sats, 1);
        let PolicyImpl::Sync(p) = e.make_policy(Some(99)) else { panic!() };
        assert_eq!(p.n_sats, 12);
        // the quorum only touches Sync
        let mut agg = CpuAggregator;
        let cfg = EngineConfig {
            algorithm: AlgorithmKind::FedBuff,
            fedbuff_m: 4,
            ..Default::default()
        };
        let e = Engine::builder()
            .schedule(&sched)
            .trainer(&trainer)
            .aggregator(&mut agg)
            .config(cfg)
            .build();
        let PolicyImpl::FedBuff(p) = e.make_policy(Some(2)) else { panic!() };
        assert_eq!(p.m, 4);
    }

    #[test]
    fn builder_run_matches_the_deprecated_shims() {
        // the retired constructors are now thin shims that rebuild through
        // the builder, so both surfaces must produce bit-identical runs
        let sched = small_sched(6, 48);
        let trainer = MockTrainer::new(8, 6, 0.3, 0);
        let cfg = EngineConfig {
            algorithm: AlgorithmKind::FedBuff,
            fedbuff_m: 3,
            ..Default::default()
        };
        let mut agg = CpuAggregator;
        let mut e = Engine::builder()
            .schedule(&sched)
            .trainer(&trainer)
            .aggregator(&mut agg)
            .config(cfg.clone())
            .build();
        let a = e.run().unwrap();
        let mut agg = CpuAggregator;
        #[allow(deprecated)]
        let mut e = Engine::new(&sched, &trainer, &mut agg, cfg, None);
        let b = e.run().unwrap();
        assert_same_run(&a, &b, "builder vs deprecated constructor shim");
    }

    #[test]
    #[should_panic(expected = "streamed mode executes over a ConnectivityStream")]
    fn builder_rejects_streamed_mode_over_a_schedule() {
        let sched = small_sched(6, 24);
        let trainer = MockTrainer::new(8, 6, 0.3, 0);
        let mut agg = CpuAggregator;
        let cfg = EngineConfig { mode: EngineMode::Streamed, ..Default::default() };
        let _ = Engine::builder()
            .schedule(&sched)
            .trainer(&trainer)
            .aggregator(&mut agg)
            .config(cfg)
            .build();
    }

    #[test]
    #[should_panic(expected = "FedSpace needs exactly one planner per gateway")]
    fn builder_rejects_fedspace_without_planners() {
        let sched = small_sched(6, 24);
        let trainer = MockTrainer::new(8, 6, 0.3, 0);
        let mut agg = CpuAggregator;
        let cfg = EngineConfig { algorithm: AlgorithmKind::FedSpace, ..Default::default() };
        let _ = Engine::builder()
            .schedule(&sched)
            .trainer(&trainer)
            .aggregator(&mut agg)
            .config(cfg)
            .build();
    }

    #[test]
    fn quorum_single_gateway_identical_to_periodic() {
        // with one gateway there is no routing table: the quorum falls back
        // to the global with-data fleet and the cadence machinery is shared,
        // so Quorum ≡ Periodic bit for bit
        for alg in [AlgorithmKind::Sync, AlgorithmKind::FedBuff] {
            let p = FederationSpec::single()
                .with_reconcile(crate::fl::ReconcilePolicy::Periodic { every: 12 });
            let q = FederationSpec::single()
                .with_reconcile(crate::fl::ReconcilePolicy::Quorum { every: 12 });
            let a = run_fed(&p, alg, 96);
            let b = run_fed(&q, alg, 96);
            assert_same_run(&a, &b, &format!("{alg:?} single-gateway quorum vs periodic"));
        }
    }

    #[test]
    fn quorum_is_periodic_for_non_sync_algorithms() {
        // FedBuff's M and Async are already per-gateway-local: the quorum
        // policy differs from Periodic only through Sync thresholds, so on
        // any other algorithm the two runs are bit-identical
        let p = half_half_spec(crate::fl::ReconcilePolicy::Periodic { every: 12 });
        let q = half_half_spec(crate::fl::ReconcilePolicy::Quorum { every: 12 });
        for alg in [AlgorithmKind::Async, AlgorithmKind::FedBuff] {
            let a = run_fed(&p, alg, 192);
            let b = run_fed(&q, alg, 192);
            assert_same_run(&a, &b, &format!("{alg:?} quorum vs periodic, two gateways"));
        }
    }

    #[test]
    fn quorum_sync_two_gateways_replays_and_lowers_thresholds() {
        let spec = half_half_spec(crate::fl::ReconcilePolicy::Quorum { every: 12 });
        let a = run_fed(&spec, AlgorithmKind::Sync, 192);
        let b = run_fed(&spec, AlgorithmKind::Sync, 192);
        assert_same_run(&a, &b, "sync quorum replay");
        // the thresholds the engine derived: per-gateway direct audiences,
        // each a nonempty subset of the fleet
        let c = planet_labs_like(12, 0);
        let stations = planet_ground_stations();
        let params: crate::connectivity::ConnectivityParams = Default::default();
        let routing =
            crate::fl::UploadRouting::build(&c, &stations, 192, &params, &spec.stations);
        let counts = routing.quorum_counts(12, |_| true);
        assert_eq!(counts.len(), 2);
        assert!(
            counts.iter().all(|&q| (1..=12).contains(&q)),
            "per-gateway quorums out of range: {counts:?}"
        );
    }

    /// [`run_mock_mode`] with an attack spec attached.
    fn run_mock_mode_atk(
        algorithm: AlgorithmKind,
        steps: usize,
        mode: crate::cfg::EngineMode,
        attack: AttackSpec,
    ) -> RunResult {
        let trainer = MockTrainer::new(16, 12, 0.3, 0);
        let mut agg = CpuAggregator;
        let cfg = EngineConfig {
            algorithm,
            fedbuff_m: 4,
            eval_every: 4,
            mode,
            attack,
            ..Default::default()
        };
        if mode == crate::cfg::EngineMode::Streamed {
            let c = planet_labs_like(12, 0);
            let gs = planet_ground_stations();
            let stream = ConnectivityStream::new(&c, &gs, steps, Default::default(), 31);
            let mut e = Engine::builder()
                .stream(&stream)
                .trainer(&trainer)
                .aggregator(&mut agg)
                .config(cfg)
                .planner(mode_planner(algorithm))
                .build();
            e.run().unwrap()
        } else {
            let sched = small_sched(12, steps);
            let mut e = Engine::builder()
                .schedule(&sched)
                .trainer(&trainer)
                .aggregator(&mut agg)
                .config(cfg)
                .planner(mode_planner(algorithm))
                .build();
            e.run().unwrap()
        }
    }

    fn noisy_attack() -> AttackSpec {
        AttackSpec {
            kind: crate::sim::adversary::AttackKind::ScaledGrad,
            fraction: 0.25,
            scale: -20.0,
            drop_prob: 0.15,
            corrupt_prob: 0.1,
            ..Default::default()
        }
    }

    #[test]
    fn attacked_runs_bit_identical_across_all_modes() {
        // the tentpole invariant: adversary RNG draws happen only inside
        // the conn loop at contact steps — events in every mode — so the
        // attacked trace is tri-mode bit-identical for every algorithm
        use crate::cfg::EngineMode;
        for alg in [
            AlgorithmKind::Sync,
            AlgorithmKind::Async,
            AlgorithmKind::FedBuff,
            AlgorithmKind::FedSpace,
        ] {
            let dense = run_mock_mode_atk(alg, 192, EngineMode::Dense, noisy_attack());
            let sparse = run_mock_mode_atk(alg, 192, EngineMode::ContactList, noisy_attack());
            let streamed = run_mock_mode_atk(alg, 192, EngineMode::Streamed, noisy_attack());
            assert_same_run(&dense, &sparse, &format!("{alg:?} attacked dense vs contacts"));
            assert_same_run(&dense, &streamed, &format!("{alg:?} attacked dense vs streamed"));
            assert!(dense.trace.injected > 0, "{alg:?}: no adversarial uploads landed");
        }
    }

    #[test]
    fn attack_changes_the_run_but_not_connectivity() {
        use crate::cfg::EngineMode;
        let clean = run_mock_mode(AlgorithmKind::Async, 4, 192, EngineMode::Dense, None);
        let attacked = run_mock_mode_atk(AlgorithmKind::Async, 192, EngineMode::Dense, noisy_attack());
        // geometry is untouched: the same contacts occur
        assert_eq!(clean.trace.connections, attacked.trace.connections);
        // the clean run has pristine counters
        assert_eq!(clean.trace.injected, 0);
        assert_eq!(clean.trace.dropped, 0);
        assert_eq!(clean.trace.corrupted, 0);
        // the attacked run visibly injected, dropped, and corrupted
        assert!(attacked.trace.injected > 0);
        assert!(attacked.trace.dropped > 0);
        assert!(attacked.trace.corrupted > 0);
        // dropped uploads never reached a buffer
        assert!(attacked.trace.uploads < clean.trace.uploads + attacked.trace.dropped);
        // and the poisoned model is a different model
        let same_bits = clean
            .final_w
            .iter()
            .zip(attacked.final_w.iter())
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(!same_bits, "a -20x scaled-gradient attack left the model untouched");
    }

    #[test]
    fn attacked_run_is_seed_reproducible() {
        use crate::cfg::EngineMode;
        let a = run_mock_mode_atk(AlgorithmKind::FedBuff, 192, EngineMode::Dense, noisy_attack());
        let b = run_mock_mode_atk(AlgorithmKind::FedBuff, 192, EngineMode::Dense, noisy_attack());
        assert_same_run(&a, &b, "attacked replay");
    }

    #[test]
    fn stale_replay_and_label_flip_inject_through_the_engine() {
        use crate::cfg::EngineMode;
        use crate::sim::adversary::AttackKind;
        for kind in [AttackKind::LabelFlip, AttackKind::StaleReplay] {
            let attack = AttackSpec { kind, fraction: 0.25, ..Default::default() };
            let r = run_mock_mode_atk(AlgorithmKind::Async, 192, EngineMode::Dense, attack);
            assert!(r.trace.injected > 0, "{kind:?} never injected");
            assert_eq!(r.trace.dropped, 0, "{kind:?} has no link faults configured");
        }
    }

    /// [`run_mock_mode`] with a `[link]` spec attached; capacity-enabled
    /// specs get pass durations (dense: `compute_with_durations`, streamed:
    /// `with_durations` — bit-identical by the stream tests).
    fn run_mock_mode_link(
        algorithm: AlgorithmKind,
        steps: usize,
        mode: crate::cfg::EngineMode,
        link: crate::fl::LinkSpec,
    ) -> RunResult {
        let trainer = MockTrainer::new(16, 12, 0.3, 0);
        let mut agg = CpuAggregator;
        let cfg = EngineConfig {
            algorithm,
            fedbuff_m: 4,
            eval_every: 4,
            mode,
            link,
            ..Default::default()
        };
        let c = planet_labs_like(12, 0);
        let gs = planet_ground_stations();
        if mode == crate::cfg::EngineMode::Streamed {
            let mut stream = ConnectivityStream::new(&c, &gs, steps, Default::default(), 31);
            if cfg.link.capacity_enabled() {
                stream = stream.with_durations();
            }
            let mut e = Engine::builder()
                .stream(&stream)
                .trainer(&trainer)
                .aggregator(&mut agg)
                .config(cfg)
                .planner(mode_planner(algorithm))
                .build();
            e.run().unwrap()
        } else {
            let sched = if cfg.link.capacity_enabled() {
                ConnectivitySchedule::compute_with_durations(&c, &gs, steps, Default::default())
            } else {
                small_sched(12, steps)
            };
            let mut e = Engine::builder()
                .schedule(&sched)
                .trainer(&trainer)
                .aggregator(&mut agg)
                .config(cfg)
                .planner(mode_planner(algorithm))
                .build();
            e.run().unwrap()
        }
    }

    /// Top-k at 1/16 of the mock model (k=1, 8-byte payload) over a 20 B/slot
    /// link: short passes (duration < 4/10 of a slot) can't carry the
    /// payload, long ones can — exercises defer AND deliver in one run.
    fn lossy_link() -> crate::fl::LinkSpec {
        crate::fl::LinkSpec {
            rate_bytes_per_slot: 20,
            codec: crate::fl::CodecKind::TopK,
            topk_frac: 0.05,
        }
    }

    #[test]
    fn codec_and_budget_runs_bit_identical_across_all_modes() {
        // the PR's tentpole invariant: codec RNG draws and capacity checks
        // happen only inside the conn loop at contact steps — events in
        // every mode — and all three modes see bit-identical pass durations,
        // so the compressed, capacity-limited trace is tri-mode identical
        use crate::cfg::EngineMode;
        for alg in [
            AlgorithmKind::Sync,
            AlgorithmKind::Async,
            AlgorithmKind::FedBuff,
            AlgorithmKind::FedSpace,
        ] {
            let dense = run_mock_mode_link(alg, 192, EngineMode::Dense, lossy_link());
            let sparse = run_mock_mode_link(alg, 192, EngineMode::ContactList, lossy_link());
            let streamed = run_mock_mode_link(alg, 192, EngineMode::Streamed, lossy_link());
            assert_same_run(&dense, &sparse, &format!("{alg:?} link dense vs contacts"));
            assert_same_run(&dense, &streamed, &format!("{alg:?} link dense vs streamed"));
            assert!(dense.trace.uploads > 0, "{alg:?}: budget starved every upload");
            assert!(dense.trace.deferred > 0, "{alg:?}: budget never deferred an upload");
        }
    }

    #[test]
    fn generous_budget_identity_codec_is_bit_identical_to_plain() {
        // capacity machinery on (durations computed, budget checked every
        // contact) + identity codec + a budget no payload exceeds ⇒ the
        // run must be bit-for-bit the plain engine's
        use crate::cfg::EngineMode;
        let link = crate::fl::LinkSpec {
            rate_bytes_per_slot: 1_000_000,
            ..Default::default()
        };
        for mode in [EngineMode::Dense, EngineMode::ContactList, EngineMode::Streamed] {
            let plain = run_mock_mode(AlgorithmKind::FedBuff, 4, 192, mode, None);
            let budgeted = run_mock_mode_link(AlgorithmKind::FedBuff, 192, mode, link.clone());
            assert_same_run(&plain, &budgeted, &format!("{mode:?} generous budget"));
            assert_eq!(budgeted.trace.deferred, 0);
        }
    }

    #[test]
    fn codec_changes_the_run_but_not_connectivity() {
        // quantization without a byte budget: same contacts, no deferrals,
        // different arithmetic — and an error-bounded one (the run still
        // learns)
        use crate::cfg::EngineMode;
        let link = crate::fl::LinkSpec {
            codec: crate::fl::CodecKind::QuantQ8,
            ..Default::default()
        };
        let clean = run_mock_mode(AlgorithmKind::FedBuff, 4, 192, EngineMode::Dense, None);
        let coded = run_mock_mode_link(AlgorithmKind::FedBuff, 192, EngineMode::Dense, link);
        assert_eq!(clean.trace.connections, coded.trace.connections);
        assert_eq!(clean.trace.uploads, coded.trace.uploads);
        assert_eq!(coded.trace.deferred, 0, "no byte budget, nothing to defer");
        let same_bits = clean
            .final_w
            .iter()
            .zip(coded.final_w.iter())
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(!same_bits, "q8 quantization left the model untouched");
        let first = coded.trace.curve.points.first().unwrap().accuracy;
        assert!(coded.trace.curve.best_accuracy() > first, "quantized run did not learn");
    }

    #[test]
    fn codec_run_is_seed_reproducible() {
        use crate::cfg::EngineMode;
        let a = run_mock_mode_link(AlgorithmKind::FedBuff, 192, EngineMode::Dense, lossy_link());
        let b = run_mock_mode_link(AlgorithmKind::FedBuff, 192, EngineMode::Dense, lossy_link());
        assert_same_run(&a, &b, "link replay");
    }

    /// [`run_fed`] with an attack spec — the quorum-under-link-faults gate.
    fn run_fed_atk(
        spec: &FederationSpec,
        algorithm: AlgorithmKind,
        steps: usize,
        attack: AttackSpec,
    ) -> RunResult {
        let c = planet_labs_like(12, 0);
        let stations = planet_ground_stations();
        let params: crate::connectivity::ConnectivityParams = Default::default();
        let sched = ConnectivitySchedule::compute(&c, &stations, steps, params.clone());
        spec.validate(stations.len()).unwrap();
        let routing = (!spec.is_single()).then(|| {
            crate::fl::UploadRouting::build(&c, &stations, steps, &params, &spec.stations)
        });
        let trainer = MockTrainer::new(16, 12, 0.3, 0);
        let mut agg = CpuAggregator;
        let cfg = EngineConfig {
            algorithm,
            fedbuff_m: 4,
            eval_every: 4,
            attack,
            ..Default::default()
        };
        let mut e = Engine::builder()
            .schedule(&sched)
            .trainer(&trainer)
            .aggregator(&mut agg)
            .config(cfg)
            .planner(mode_planner(algorithm))
            .federation(spec, routing.as_ref())
            .build();
        e.run().unwrap()
    }

    #[test]
    fn dropped_uploads_never_count_toward_a_sync_quorum() {
        // drop_prob 1.0: every committed upload dies on the link before any
        // gateway buffer sees it. Under ReconcilePolicy::Quorum the Sync
        // thresholds therefore never fill — zero aggregations, zero quorum
        // reconciles — even though every contact still happened.
        let spec = half_half_spec(crate::fl::ReconcilePolicy::Quorum { every: 12 });
        let all_dropped = AttackSpec { drop_prob: 1.0, ..Default::default() };
        let starved = run_fed_atk(&spec, AlgorithmKind::Sync, 96, all_dropped.clone());
        assert!(starved.trace.dropped > 0, "links never fired");
        assert_eq!(starved.trace.uploads, 0, "a dropped upload reached a buffer");
        assert_eq!(starved.trace.gateway_uploads, vec![0, 0]);
        assert_eq!(starved.final_round, 0, "a quorum filled without uploads");
        assert_eq!(starved.trace.reconciles, 0, "zero-activity reconcile must not merge");
        assert!(starved.trace.connections > 0, "geometry must be untouched");
        // the run replays bit for bit
        let replay = run_fed_atk(&spec, AlgorithmKind::Sync, 96, all_dropped);
        assert_same_run(&starved, &replay, "all-dropped quorum replay");
        // control: with the links healthy the same spec aggregates
        let healthy = run_fed_atk(&spec, AlgorithmKind::Sync, 96, AttackSpec::default());
        assert!(healthy.final_round > 0, "control run never aggregated");
    }
}
