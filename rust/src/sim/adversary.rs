//! Deterministic adversary / fault injection at the upload boundary
//! (ADR-0007): label-flip and scaled-gradient Byzantine satellites,
//! stale-update replay, and link-level faults (dropped uploads,
//! bit-corrupted gradients).
//!
//! Everything here is a *scenario axis*, not a mode switch: the `[attack]`
//! TOML section selects which satellites misbehave and how lossy the links
//! are, and the [`Adversary`] runtime applies those transforms to each
//! upload inside the shared `run_step` body — after the satellite hands
//! over its gradient, before the federation receives it. Because contact
//! steps are events in all three engine modes and the dense mode's extra
//! steps see an empty contact list (so no adversary RNG is consumed),
//! attack-on runs stay trace-bit-identical across Dense / ContactList /
//! Streamed, and attack-off runs consume no adversary randomness at all —
//! bit-identical to a build without this module.
//!
//! Seed stability: the injector draws from its own xoshiro stream,
//! `Rng::new(run_seed ^ ADVERSARY_STREAM)`, created only when the attack
//! is enabled. The training / planning / data streams are untouched, so
//! the honest side of an attacked run matches the clean run until the
//! first poisoned aggregate lands.

use crate::cfg::section::{SectionCtx, SectionSpec};
use crate::cfg::toml::{TomlDoc, TomlValue};
use crate::fl::codec::Update;
use crate::rng::Rng;
use anyhow::{bail, Context, Result};

/// Stream-id XOR'd into the run seed for the adversary RNG, keeping its
/// draws independent of the training (`split(i+1)`), planner/utility/data
/// (`PLANNER_STREAM` / `UTILITY_STREAM` / `DATA_STREAM` in `app::runner`)
/// and codec (`CODEC_STREAM`) streams — pairwise distinctness is
/// machine-checked by `fedspace lint`'s `rng-stream` rule.
pub const ADVERSARY_STREAM: u64 = 0xBAD5_EED5;

/// What compromised satellites do to their own updates (the `[attack]`
/// TOML `kind` key). Link faults (`drop_prob` / `corrupt_prob`) are
/// orthogonal and may run with `kind = "none"`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AttackKind {
    /// No compromised satellites (link faults may still apply).
    #[default]
    None,
    /// Sign-flipped gradients — the classic label-flip proxy: the update
    /// points away from descent.
    LabelFlip,
    /// Gradients multiplied by `scale` (negative scale both flips and
    /// amplifies — the strongest mean-poisoning primitive).
    ScaledGrad,
    /// Each upload is swapped with the adversary's previously transmitted
    /// gradient — replaying genuinely stale updates that hide inside the
    /// staleness model (the first upload passes through honestly while
    /// being recorded).
    StaleReplay,
}

impl AttackKind {
    /// Parse the TOML/CLI spelling.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "none" => AttackKind::None,
            "label-flip" | "label_flip" => AttackKind::LabelFlip,
            "scaled-grad" | "scaled_grad" | "scaled" => AttackKind::ScaledGrad,
            "stale-replay" | "stale_replay" | "replay" => AttackKind::StaleReplay,
            other => bail!(
                "unknown attack kind {other:?} (none | label-flip | scaled-grad | stale-replay)"
            ),
        })
    }

    /// Canonical lowercase name (inverse of [`Self::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            AttackKind::None => "none",
            AttackKind::LabelFlip => "label-flip",
            AttackKind::ScaledGrad => "scaled-grad",
            AttackKind::StaleReplay => "stale-replay",
        }
    }
}

/// The `[attack]` TOML section: which satellites are compromised, what
/// they do, and how faulty the links are. Omitted ⇒ default ⇒ disabled ⇒
/// byte-identical old specs and bit-identical clean runs.
#[derive(Clone, Debug, PartialEq)]
pub struct AttackSpec {
    /// Adversary behaviour.
    pub kind: AttackKind,
    /// Fraction of the fleet compromised (used when `sats` is empty);
    /// resolved to `round(fraction · n)` evenly strided satellite ids.
    pub fraction: f64,
    /// Explicit compromised satellite ids (overrides `fraction`).
    pub sats: Vec<usize>,
    /// Multiplier for `scaled-grad`.
    pub scale: f64,
    /// Per-contact probability an upload is dropped in transit.
    pub drop_prob: f64,
    /// Per-contact probability one bit of the gradient is corrupted.
    pub corrupt_prob: f64,
}

impl Default for AttackSpec {
    fn default() -> Self {
        AttackSpec {
            kind: AttackKind::None,
            fraction: 0.1,
            sats: Vec::new(),
            scale: -10.0,
            drop_prob: 0.0,
            corrupt_prob: 0.0,
        }
    }
}

impl AttackSpec {
    /// Whether this spec injects anything at all. Disabled ⇒ the engine
    /// builds no [`Adversary`] and consumes no adversary randomness.
    pub fn enabled(&self) -> bool {
        self.kind != AttackKind::None || self.drop_prob > 0.0 || self.corrupt_prob > 0.0
    }

    /// Reject self-inconsistent specs against the fleet size.
    pub fn validate(&self, n_sats: usize) -> Result<()> {
        if !(0.0..=1.0).contains(&self.fraction) {
            bail!("[attack] fraction must be in [0, 1], got {}", self.fraction);
        }
        for p in [("drop_prob", self.drop_prob), ("corrupt_prob", self.corrupt_prob)] {
            if !(0.0..=1.0).contains(&p.1) {
                bail!("[attack] {} must be in [0, 1], got {}", p.0, p.1);
            }
        }
        if self.kind == AttackKind::ScaledGrad && (!self.scale.is_finite() || self.scale == 0.0) {
            bail!("[attack] scale must be finite and nonzero for scaled-grad, got {}", self.scale);
        }
        for &s in &self.sats {
            if s >= n_sats {
                bail!("[attack] sats lists satellite {s} but the fleet has {n_sats}");
            }
        }
        if self.kind != AttackKind::None && self.adversaries(n_sats).iter().all(|a| !a) {
            bail!(
                "[attack] kind = \"{}\" selects no adversaries (fraction {} of {} satellites)",
                self.kind.name(),
                self.fraction,
                n_sats
            );
        }
        Ok(())
    }

    /// Resolve the compromised set to a per-satellite mask: explicit
    /// `sats` verbatim, else `round(fraction · n)` ids strided evenly
    /// across the fleet (`j·n/count` — deterministic, constellation-shape
    /// independent, distinct because `count ≤ n`).
    pub fn adversaries(&self, n_sats: usize) -> Vec<bool> {
        let mut mask = vec![false; n_sats];
        if self.kind == AttackKind::None {
            return mask;
        }
        if !self.sats.is_empty() {
            for &s in &self.sats {
                if s < n_sats {
                    mask[s] = true;
                }
            }
            return mask;
        }
        let count = ((self.fraction * n_sats as f64).round() as usize).min(n_sats);
        for j in 0..count {
            mask[j * n_sats / count] = true;
        }
        mask
    }

    /// Emit the `[attack]` TOML section (callers skip the call when
    /// `!enabled()` so pre-attack specs stay byte-identical).
    pub fn emit_toml(&self, out: &mut String) {
        use std::fmt::Write as _;
        let _ = writeln!(out, "\n[attack]");
        let _ = writeln!(out, "kind = \"{}\"", self.kind.name());
        let _ = writeln!(out, "fraction = {}", self.fraction);
        if !self.sats.is_empty() {
            let ids: Vec<String> = self.sats.iter().map(|s| s.to_string()).collect();
            let _ = writeln!(out, "sats = [{}]", ids.join(", "));
        }
        let _ = writeln!(out, "scale = {}", self.scale);
        let _ = writeln!(out, "drop_prob = {}", self.drop_prob);
        let _ = writeln!(out, "corrupt_prob = {}", self.corrupt_prob);
    }

    /// Parse the `[attack]` section; `Ok(None)` when absent (callers keep
    /// their default) — the shared scenario/experiment-config idiom.
    pub fn from_doc(doc: &TomlDoc) -> Result<Option<AttackSpec>> {
        if doc.get("attack").is_none() {
            return Ok(None);
        }
        let get = |key: &str| -> Option<&TomlValue> { doc.get("attack").and_then(|s| s.get(key)) };
        let mut spec = AttackSpec::default();
        if let Some(v) = get("kind") {
            spec.kind = AttackKind::parse(v.as_str().context("[attack] kind must be a string")?)?;
        }
        if let Some(v) = get("fraction") {
            spec.fraction = v.as_float().context("[attack] fraction must be a number")?;
        }
        if let Some(v) = get("sats") {
            let TomlValue::Array(items) = v else {
                bail!("[attack] sats must be an array of satellite ids");
            };
            spec.sats = items
                .iter()
                .map(|x| {
                    usize::try_from(x.as_int().context("[attack] sats entries must be integers")?)
                        .map_err(Into::into)
                })
                .collect::<Result<Vec<usize>>>()?;
        }
        if let Some(v) = get("scale") {
            spec.scale = v.as_float().context("[attack] scale must be a number")?;
        }
        if let Some(v) = get("drop_prob") {
            spec.drop_prob = v.as_float().context("[attack] drop_prob must be a number")?;
        }
        if let Some(v) = get("corrupt_prob") {
            spec.corrupt_prob = v.as_float().context("[attack] corrupt_prob must be a number")?;
        }
        Ok(Some(spec))
    }
}

impl SectionSpec for AttackSpec {
    const SECTION: &'static str = "attack";

    fn from_doc(doc: &TomlDoc) -> Result<Option<Self>> {
        AttackSpec::from_doc(doc)
    }

    fn emit_toml(&self, out: &mut String) {
        AttackSpec::emit_toml(self, out)
    }

    fn is_emitted(&self) -> bool {
        self.enabled()
    }

    fn validate(&self, ctx: &SectionCtx) -> Result<()> {
        AttackSpec::validate(self, ctx.n_sats)
    }
}

/// What [`Adversary::apply`] did to one upload. The engine folds these
/// flags into its `Upload` run event (ADR-0009) — the adversary itself no
/// longer touches any trace.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ApplyOutcome {
    /// The (possibly transformed) upload; `None` when the link dropped it.
    pub update: Option<Update>,
    /// A compromised satellite transformed the upload (a replayed *first*
    /// upload passes through honestly and is not flagged).
    pub injected: bool,
    /// A link fault flipped one stored bit.
    pub corrupted: bool,
}

impl ApplyOutcome {
    /// An untouched pass-through (the attack-off path).
    pub fn clean(update: Update) -> Self {
        ApplyOutcome { update: Some(update), injected: false, corrupted: false }
    }
}

/// Live injector owned by the engine's `RunState`, built only when
/// [`AttackSpec::enabled`]. [`Self::apply`] transforms each upload at the
/// boundary between `SatClient::upload` and `Federation::receive`, in a
/// fixed draw order (drop → transform → corrupt) so every engine mode
/// consumes the stream identically.
pub struct Adversary {
    spec: AttackSpec,
    is_adv: Vec<bool>,
    /// Per-satellite previously transmitted update for `stale-replay`.
    replay: Vec<Option<Update>>,
    rng: Rng,
}

impl Adversary {
    /// Build the injector for a fleet of `n_sats` under `run_seed` (the
    /// scenario seed; the adversary stream is derived, not shared).
    pub fn new(spec: &AttackSpec, n_sats: usize, run_seed: u64) -> Adversary {
        Adversary {
            is_adv: spec.adversaries(n_sats),
            replay: vec![None; n_sats],
            rng: Rng::new(run_seed ^ ADVERSARY_STREAM),
            spec: spec.clone(),
        }
    }

    /// Transform one upload from satellite `sat`. The returned
    /// [`ApplyOutcome`] carries `update: None` when the link drops it (the
    /// satellite has already consumed its `upload`, so it believes it
    /// transmitted — exactly a lost frame) plus the injected/corrupted
    /// flags the engine folds into its `Upload` run event. The upload
    /// arrives in the codec's wire form (ADR-0008: encode runs first), and
    /// every transform operates on the *stored* values — dense
    /// coordinates, or a sparse payload's `(indices, values)` values — so
    /// an adversary poisons what is actually transmitted. For dense
    /// updates this is bit-identical to the pre-codec behaviour. Draw
    /// order is part of the determinism contract:
    /// 1. link drop (`drop_prob`) — a drop short-circuits, so a dropped
    ///    upload is never also flagged injected/corrupted;
    /// 2. adversary transform when `sat` is compromised (a replayed
    ///    *first* upload passes through honestly, unflagged);
    /// 3. single-bit corruption (`corrupt_prob`) — the flipped bit is
    ///    drawn from the mantissa (0..=22) or sign (31), never the
    ///    exponent, so a finite gradient stays finite (no NaN/inf can
    ///    enter Eq. 4 through this fault).
    pub fn apply(&mut self, sat: usize, mut grad: Update) -> ApplyOutcome {
        if self.spec.drop_prob > 0.0 && self.rng.gen_bool(self.spec.drop_prob) {
            return ApplyOutcome { update: None, injected: false, corrupted: false };
        }
        let mut injected = false;
        if self.is_adv[sat] {
            match self.spec.kind {
                AttackKind::None => {}
                AttackKind::LabelFlip => {
                    for v in grad.values_mut() {
                        *v = -*v;
                    }
                    injected = true;
                }
                AttackKind::ScaledGrad => {
                    let scale = self.spec.scale as f32;
                    for v in grad.values_mut() {
                        *v *= scale;
                    }
                    injected = true;
                }
                AttackKind::StaleReplay => match &mut self.replay[sat] {
                    slot @ None => {
                        *slot = Some(grad.clone());
                    }
                    Some(stored) => {
                        std::mem::swap(stored, &mut grad);
                        injected = true;
                    }
                },
            }
        }
        let mut corrupted = false;
        if self.spec.corrupt_prob > 0.0
            && self.rng.gen_bool(self.spec.corrupt_prob)
            && !grad.values().is_empty()
        {
            let e = self.rng.gen_range(0, grad.values().len());
            let sel = self.rng.gen_range(0, 24);
            let bit = if sel == 23 { 31 } else { sel };
            let vals = grad.values_mut();
            vals[e] = f32::from_bits(vals[e].to_bits() ^ (1u32 << bit));
            corrupted = true;
        }
        ApplyOutcome { update: Some(grad), injected, corrupted }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strided_resolution_hits_the_requested_count() {
        let spec = AttackSpec { kind: AttackKind::LabelFlip, fraction: 0.1, ..Default::default() };
        let mask = spec.adversaries(66);
        assert_eq!(mask.iter().filter(|&&a| a).count(), 7, "round(0.1 · 66)");
        let spec = AttackSpec { kind: AttackKind::LabelFlip, fraction: 1.0, ..Default::default() };
        assert!(spec.adversaries(5).iter().all(|&a| a));
        let spec = AttackSpec {
            kind: AttackKind::LabelFlip,
            sats: vec![3, 7],
            ..Default::default()
        };
        let mask = spec.adversaries(10);
        assert_eq!(mask.iter().filter(|&&a| a).count(), 2);
        assert!(mask[3] && mask[7], "explicit ids override fraction");
        // kind None selects nobody even with fraction 1
        let spec = AttackSpec { fraction: 1.0, ..Default::default() };
        assert!(spec.adversaries(10).iter().all(|&a| !a));
    }

    #[test]
    fn transforms_are_seed_stable() {
        let spec = AttackSpec {
            kind: AttackKind::ScaledGrad,
            fraction: 0.5,
            scale: -3.0,
            drop_prob: 0.2,
            corrupt_prob: 0.2,
            ..Default::default()
        };
        let run = |seed: u64| {
            let mut adv = Adversary::new(&spec, 4, seed);
            let (mut injected, mut dropped, mut corrupted) = (0usize, 0usize, 0usize);
            let mut out = Vec::new();
            for i in 0..64usize {
                let g = vec![i as f32, -(i as f32), 0.5];
                let fx = adv.apply(i % 4, g.into());
                injected += fx.injected as usize;
                dropped += fx.update.is_none() as usize;
                corrupted += fx.corrupted as usize;
                out.push(fx.update);
            }
            (out, injected, dropped, corrupted)
        };
        let a = run(42);
        let b = run(42);
        assert_eq!(a.0, b.0, "same seed ⇒ identical transformed stream");
        assert_eq!((a.1, a.2, a.3), (b.1, b.2, b.3));
        let c = run(43);
        assert_ne!(a.0, c.0, "different seed ⇒ different drop/corrupt draws");
        assert!(a.2 > 0 && a.3 > 0, "probabilistic faults actually fired: {a:?}");
    }

    #[test]
    fn corruption_never_breaks_finiteness() {
        // exponent bits are excluded, so finite inputs stay finite no
        // matter how many corruption draws land
        let spec =
            AttackSpec { corrupt_prob: 1.0, ..Default::default() };
        let mut adv = Adversary::new(&spec, 1, 7);
        let mut corrupted = 0usize;
        for i in 0..2000 {
            let g = vec![1.5e30, -2.5e-30, 0.0, i as f32];
            let fx = adv.apply(0, g.into());
            corrupted += fx.corrupted as usize;
            let up = fx.update.unwrap();
            for v in up.values() {
                assert!(v.is_finite(), "corruption produced a non-finite value: {v}");
            }
        }
        assert_eq!(corrupted, 2000);
    }

    #[test]
    fn stale_replay_swaps_from_the_second_upload() {
        let spec = AttackSpec { kind: AttackKind::StaleReplay, sats: vec![0], ..Default::default() };
        let mut adv = Adversary::new(&spec, 2, 1);
        // first upload passes through honestly while being recorded
        let fx = adv.apply(0, vec![1.0].into());
        assert_eq!(fx.update, Some(vec![1.0].into()));
        assert!(!fx.injected, "honest first pass must not be flagged");
        // second upload is replaced by the first; the second is now stored
        let fx = adv.apply(0, vec![2.0].into());
        assert_eq!(fx.update, Some(vec![1.0].into()));
        assert!(fx.injected);
        let fx = adv.apply(0, vec![3.0].into());
        assert_eq!(fx.update, Some(vec![2.0].into()), "rolling swap, always one upload behind");
        assert!(fx.injected);
        // honest satellite untouched
        let fx = adv.apply(1, vec![9.0].into());
        assert_eq!(fx.update, Some(vec![9.0].into()));
        assert!(!fx.injected);
    }

    #[test]
    fn transforms_act_on_sparse_wire_payloads() {
        // codec→adversary ordering (ADR-0008): a top-k sparse upload is
        // poisoned on its stored values — indices and dimension untouched
        let spec = AttackSpec {
            kind: AttackKind::ScaledGrad,
            sats: vec![0],
            scale: -2.0,
            ..Default::default()
        };
        let mut adv = Adversary::new(&spec, 1, 5);
        let up = Update::Sparse { dim: 10, idx: vec![2, 7], val: vec![1.0, -3.0] };
        let fx = adv.apply(0, up);
        assert_eq!(
            fx.update,
            Some(Update::Sparse { dim: 10, idx: vec![2, 7], val: vec![-2.0, 6.0] })
        );
        assert!(fx.injected);
        // corruption indexes the stored values, never past nnz
        let spec = AttackSpec { corrupt_prob: 1.0, ..Default::default() };
        let mut adv = Adversary::new(&spec, 1, 6);
        for _ in 0..200 {
            let up = Update::Sparse { dim: 1_000_000, idx: vec![5, 999_999], val: vec![1.0, 2.0] };
            let fx = adv.apply(0, up);
            assert!(fx.corrupted);
            let Some(Update::Sparse { dim, idx, val }) = fx.update else { panic!() };
            assert_eq!((dim, idx.len(), val.len()), (1_000_000, 2, 2));
            assert!(val.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn spec_round_trips_and_validates() {
        let spec = AttackSpec {
            kind: AttackKind::ScaledGrad,
            fraction: 0.25,
            sats: vec![1, 4, 9],
            scale: -20.0,
            drop_prob: 0.02,
            corrupt_prob: 0.01,
        };
        let mut s = String::new();
        spec.emit_toml(&mut s);
        let doc = crate::cfg::toml::parse_toml(&s).unwrap();
        let back = AttackSpec::from_doc(&doc).unwrap().expect("section present");
        assert_eq!(back, spec, "{s}");
        assert!(spec.validate(10).is_ok());
        // absent section -> None; disabled default never emits
        let doc = crate::cfg::toml::parse_toml("[scenario]\nname = \"x\"").unwrap();
        assert!(AttackSpec::from_doc(&doc).unwrap().is_none());
        assert!(!AttackSpec::default().enabled());
        // fault-only spec is enabled with kind none
        let faults = AttackSpec { drop_prob: 0.1, ..Default::default() };
        assert!(faults.enabled());
        assert!(faults.validate(10).is_ok());
        // rejections: out-of-range sat, bad probs, zero scale, empty selection
        assert!(spec.validate(5).is_err(), "sat 9 out of a 5-sat fleet");
        let bad = AttackSpec { drop_prob: 1.5, ..Default::default() };
        assert!(bad.validate(10).is_err());
        let bad = AttackSpec { kind: AttackKind::ScaledGrad, scale: 0.0, ..Default::default() };
        assert!(bad.validate(10).is_err());
        let bad =
            AttackSpec { kind: AttackKind::LabelFlip, fraction: 0.0, ..Default::default() };
        assert!(bad.validate(10).is_err(), "attack kind set but nobody compromised");
        assert!(AttackKind::parse("gaussian").is_err());
        for k in [
            AttackKind::None,
            AttackKind::LabelFlip,
            AttackKind::ScaledGrad,
            AttackKind::StaleReplay,
        ] {
            assert_eq!(AttackKind::parse(k.name()).unwrap(), k);
        }
    }
}
