//! Satellite local-training backends.
//!
//! [`PjrtTrainer`] is the shipped path: E SGD steps through the AOT
//! `local_train` artifact (Layer 2 + Pallas Layer 1).  [`MockTrainer`] is an
//! analytic federated least-squares problem for fast scheduler-level tests
//! and benches — same interface, no PJRT.

use crate::data::{Dataset, Partition};
use crate::rng::Rng;
use crate::runtime::ModelRuntime;
use anyhow::Result;

/// Produces one local update (g_k = w_E − w_0, mean training loss) for a
/// satellite, and evaluates global validation metrics.
pub trait Trainer {
    /// Flat parameter dimension.
    fn d(&self) -> usize;
    /// initial global model
    fn init(&self, rng: &mut Rng) -> Vec<f32>;
    /// E local SGD steps for satellite `sat` from model `w`
    fn local_update(&self, sat: usize, w: &[f32], rng: &mut Rng) -> Result<(Vec<f32>, f32)>;
    /// (validation loss, top-1 accuracy) of `w`
    fn evaluate(&self, w: &[f32]) -> Result<(f64, f64)>;
    /// m_k per satellite
    fn sat_samples(&self, sat: usize) -> usize;
}

/// The production trainer: real data batches through the PJRT artifacts.
pub struct PjrtTrainer<'a> {
    /// Loaded artifact runtime.
    pub rt: &'a ModelRuntime,
    /// The dataset satellites sample batches from.
    pub dataset: &'a Dataset,
    /// Per-satellite sample assignment.
    pub partition: &'a Partition,
    /// Local-SGD learning rate.
    pub lr: f32,
    /// validation samples used per evaluation (subset for speed)
    pub eval_samples: usize,
}

impl<'a> PjrtTrainer<'a> {
    /// Wire a trainer over loaded runtime + data.
    pub fn new(
        rt: &'a ModelRuntime,
        dataset: &'a Dataset,
        partition: &'a Partition,
        lr: f32,
        eval_samples: usize,
    ) -> Self {
        PjrtTrainer { rt, dataset, partition, lr, eval_samples }
    }

    /// Sample E·B training rows from the satellite's local shard.
    fn sample_batches(&self, sat: usize, rng: &mut Rng) -> (Vec<f32>, Vec<f32>) {
        let local = &self.partition.assignments[sat];
        let m = &self.rt.meta;
        let n = m.e_steps * m.batch;
        let idx: Vec<usize> = (0..n).map(|_| local[rng.gen_range(0, local.len())]).collect();
        self.dataset.make_batch(&self.dataset.train, &idx)
    }
}

impl Trainer for PjrtTrainer<'_> {
    fn d(&self) -> usize {
        self.rt.meta.d
    }

    fn init(&self, rng: &mut Rng) -> Vec<f32> {
        self.rt.init_params(rng)
    }

    fn local_update(&self, sat: usize, w: &[f32], rng: &mut Rng) -> Result<(Vec<f32>, f32)> {
        let (xs, ys) = self.sample_batches(sat, rng);
        self.rt.local_train(w, &xs, &ys, self.lr)
    }

    fn evaluate(&self, w: &[f32]) -> Result<(f64, f64)> {
        let m = &self.rt.meta;
        let eb = m.eval_batch;
        let n = self.eval_samples.min(self.dataset.val.len()) / eb * eb;
        anyhow::ensure!(n > 0, "eval_samples smaller than one eval batch");
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        for start in (0..n).step_by(eb) {
            let idx: Vec<usize> = (start..start + eb).collect();
            let (x, y) = self.dataset.make_batch(&self.dataset.val, &idx);
            let (ls, c) = self.rt.eval_batch(w, &x, &y)?;
            loss_sum += ls as f64;
            correct += c as f64;
        }
        Ok((loss_sum / n as f64, correct / n as f64))
    }

    fn sat_samples(&self, sat: usize) -> usize {
        self.partition.assignments[sat].len()
    }
}

/// Analytic mock: satellite k's objective is ½‖w − c_k‖² around a per-
/// satellite center; the global optimum is the mean of centers. "Accuracy"
/// is a monotone map of distance-to-optimum so time-to-target-accuracy is
/// meaningful. Staleness hurts exactly as in real SGD: stale deltas point
/// at where the model used to be.
pub struct MockTrainer {
    /// Parameter dimension.
    pub dim: usize,
    /// Per-satellite objective centers c_k.
    pub centers: Vec<Vec<f32>>,
    /// Local-SGD step size.
    pub lr: f32,
    /// Gradient noise std.
    pub noise: f32,
    /// Local SGD steps per update E.
    pub e_steps: usize,
    optimum: Vec<f32>,
    init_dist: f64,
}

impl MockTrainer {
    /// A mock federated task; `heterogeneity` spreads the per-satellite
    /// centers (the Non-IID knob).
    pub fn new(dim: usize, n_sats: usize, heterogeneity: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        // shared task center + per-satellite offset (Non-IID knob)
        let task: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let centers: Vec<Vec<f32>> = (0..n_sats)
            .map(|_| {
                task.iter()
                    .map(|t| t + rng.normal_f32(0.0, heterogeneity))
                    .collect()
            })
            .collect();
        let mut optimum = vec![0.0f32; dim];
        for c in &centers {
            for (o, v) in optimum.iter_mut().zip(c.iter()) {
                *o += v / n_sats as f32;
            }
        }
        // distance scale for the accuracy mapping: from the zero init
        let init_dist = optimum.iter().map(|&o| (o as f64).powi(2)).sum::<f64>().sqrt();
        MockTrainer {
            dim,
            centers,
            lr: 0.3,
            noise: 0.02,
            e_steps: 2,
            optimum,
            init_dist: init_dist.max(1e-9),
        }
    }

    fn dist_to_opt(&self, w: &[f32]) -> f64 {
        w.iter()
            .zip(self.optimum.iter())
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt()
    }
}

impl Trainer for MockTrainer {
    fn d(&self) -> usize {
        self.dim
    }

    fn init(&self, _rng: &mut Rng) -> Vec<f32> {
        vec![0.0; self.dim]
    }

    fn local_update(&self, sat: usize, w: &[f32], rng: &mut Rng) -> Result<(Vec<f32>, f32)> {
        let c = &self.centers[sat];
        let mut cur: Vec<f32> = w.to_vec();
        let mut loss_acc = 0.0f32;
        for _ in 0..self.e_steps {
            let mut loss = 0.0f32;
            for (wi, ci) in cur.iter_mut().zip(c.iter()) {
                let g = *wi - ci + rng.normal_f32(0.0, self.noise);
                loss += 0.5 * (*wi - ci) * (*wi - ci);
                *wi -= self.lr * g;
            }
            loss_acc += loss / self.dim as f32;
        }
        let delta: Vec<f32> = cur.iter().zip(w.iter()).map(|(a, b)| a - b).collect();
        Ok((delta, loss_acc / self.e_steps as f32))
    }

    fn evaluate(&self, w: &[f32]) -> Result<(f64, f64)> {
        let d = self.dist_to_opt(w);
        let loss = 0.5 * d * d / self.dim as f64;
        // accuracy: 1 at the optimum, ~0 at the init distance
        let acc = (1.0 - d / self.init_dist).clamp(0.0, 1.0);
        Ok((loss, acc))
    }

    fn sat_samples(&self, _sat: usize) -> usize {
        100
    }
}

/// Adapter: expose any [`Trainer`] as a [`SampleBackend`] for utility-
/// sample generation — the paper's "for simplicity, we use fMoW as the
/// source dataset D^s" (§4.3): the scheduler learns û on the same task the
/// satellites train.
pub struct TrainerSampleBackend<'a> {
    /// The trainer supplying local updates and losses.
    pub trainer: &'a dyn Trainer,
    /// Satellites to draw contributors from.
    pub n_sats: usize,
}

impl crate::sched::SampleBackend for TrainerSampleBackend<'_> {
    fn d(&self) -> usize {
        self.trainer.d()
    }

    fn init(&self, rng: &mut crate::rng::Rng) -> Vec<f32> {
        self.trainer.init(rng)
    }

    fn local_delta(&self, w: &[f32], rng: &mut crate::rng::Rng) -> Result<Vec<f32>> {
        // contributions come from random satellites, like live uploads
        let mut sat = rng.gen_range(0, self.n_sats);
        for _ in 0..self.n_sats {
            if self.trainer.sat_samples(sat) > 0 {
                break;
            }
            sat = rng.gen_range(0, self.n_sats);
        }
        Ok(self.trainer.local_update(sat, w, rng)?.0)
    }

    fn loss(&self, w: &[f32]) -> Result<f64> {
        Ok(self.trainer.evaluate(w)?.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_local_update_descends() {
        let t = MockTrainer::new(8, 3, 0.1, 0);
        let mut rng = Rng::new(1);
        let w = t.init(&mut rng);
        let (delta, loss) = t.local_update(0, &w, &mut rng).unwrap();
        assert_eq!(delta.len(), 8);
        assert!(loss > 0.0);
        // moving by delta reduces satellite-0 loss
        let w1: Vec<f32> = w.iter().zip(&delta).map(|(a, b)| a + b).collect();
        let (_, l1) = t.local_update(0, &w1, &mut rng).unwrap();
        assert!(l1 < loss);
    }

    #[test]
    fn mock_accuracy_increases_toward_optimum() {
        let t = MockTrainer::new(8, 4, 0.1, 0);
        let mut rng = Rng::new(2);
        let w0 = t.init(&mut rng);
        let (_, a0) = t.evaluate(&w0).unwrap();
        // move halfway to the optimum
        let w1: Vec<f32> = w0
            .iter()
            .zip(t.optimum.iter())
            .map(|(a, b)| a + 0.5 * (b - a))
            .collect();
        let (_, a1) = t.evaluate(&w1).unwrap();
        let (_, a2) = t.evaluate(&t.optimum.clone()).unwrap();
        assert!(a0 < a1 && a1 < a2);
        assert!((a2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn mock_heterogeneity_spreads_centers() {
        let iid = MockTrainer::new(16, 8, 0.0, 3);
        let non = MockTrainer::new(16, 8, 1.0, 3);
        let spread = |t: &MockTrainer| -> f64 {
            let c0 = &t.centers[0];
            t.centers[1]
                .iter()
                .zip(c0.iter())
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
        };
        assert!(spread(&non) > spread(&iid));
    }
}
