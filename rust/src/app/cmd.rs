//! CLI subcommand implementations.

use super::args::Args;
use super::runner::{
    run_loadgen, run_mock_experiment, run_pjrt_experiment, run_scenario, LoadgenOpts,
};
use crate::cfg::{AlgorithmKind, DataDist, EngineMode, ExperimentConfig, Scenario};
use crate::connectivity::ConnectivityStats;
use crate::fl::illustrative;
use crate::metrics::{write_file, Table};
use crate::rng::Rng;
use crate::sched::{generate_samples, pretrain_bank, MockBackend, UtilityModel};
use crate::sim::{bundle_json, EventSpec, RunArtifact};
use anyhow::{bail, Context, Result};

/// Top-level CLI usage text (`fedspace help`).
pub const HELP: &str = "\
fedspace — FL coordinator for satellites and ground stations (So et al. 2022)

USAGE: fedspace <command> [options]

COMMANDS:
  connectivity  compute constellation connectivity (Figure 2 data)
                  --sats N (191)  --steps N (96)  --out-dir DIR (results)
  illustrative  the 3-satellite example (Figures 3-4, Table 1)
  train         run one FL experiment
                  --config FILE           TOML config (optional; [isl],
                                          [federation] and [link] supported)
                  --algorithm sync|async|fedbuff|fedspace (fedspace)
                  --dist iid|noniid (iid) --steps N (480) --sats N (191)
                  --engine dense|contacts|streamed (dense)  time-axis mode
                  --mock                  analytic backend (default: PJRT)
                  --size small|fmow       model size for PJRT (fmow)
                  --eval-samples N (512)  --target ACC (none)
                  --out FILE              write the accuracy curve CSV
  scenarios     the named scenario registry (constellation zoo)
                  scenarios list                 catalog of built-ins
                  scenarios describe <name>      summary + full TOML spec
                    --json [FILE]                spec as JSON (stdout or FILE)
                  scenarios run <name|--config FILE>
                    --sats N / --steps N         scale the scenario down
                    --algorithm A                run one grid entry only
                    --engine dense|contacts|streamed  override engine mode
                    --target ACC                 stop at accuracy
                    --out-dir DIR                write per-algorithm curves
                                                 + the run-artifact bundle
                    --json [FILE]                run-artifact bundle with the
                                                 full event stream (ADR-0009)
                                                 to stdout or FILE
  lint          static-check the determinism contract over the sources
                (ADR-0011): wall-clock, hash-order, rng-stream,
                event-coverage, float-reduce, section-registry
                  --path DIR              scan root (default: src or rust/src)
                  --deny                  exit non-zero if any finding survives
                  --json [FILE]           fedspace-lint-v1 report (stdout/FILE)
  bench-check   compare bench JSON against the committed baseline (CI gate)
                  --baseline A.json,B.json committed baselines, newest first;
                                          the first non-provisional one gates
                  --current A.json,B.json bench outputs to merge and compare
                  --max-regress F (0.25)  relative slowdown budget per path
                  --summary-out FILE      also write the markdown summary
  bench-baseline  merge bench JSON outputs into a ready-to-commit,
                  non-provisional baseline (the CI arming artifact)
                  --current A.json,B.json bench outputs to merge
                  --out FILE              baseline file to write
  serve         drive the serving front end over a scenario's contact trace,
                paced in wall-clock time (ADR-0010)
                  serve <name|--config FILE>
                    --sats N / --steps N         scale the scenario down
                    --pace S (0.05)              wall seconds per replayed slot
                    --queue-cap N / --batch N / --shards N   [serve] overrides
                    --json [FILE]                run-artifact bundle
  loadgen       replay a scenario's contact trace at full speed and report
                sustained uploads/sec + p50/p99 drain latency (ADR-0010)
                  loadgen <name|--config FILE>
                    --sats N / --steps N         scale the scenario down
                    --queue-cap N / --batch N / --shards N   [serve] overrides
                    --json [FILE]                run-artifact bundle
  utility       phase-1 utility pipeline on the mock backend; reports MSE
                  --samples N (400)
  schedule      plan one FedSpace aggregation window over the real
                constellation and print the forecast timeline
                  --sats N (191)  --i0 N (24)  --n-min N (1) --n-max N (8)
  help          this text
";

/// Apply common CLI overrides onto a config.
fn config_from(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::from_file(path)?,
        None => ExperimentConfig::default(),
    };
    if let Some(a) = args.get("algorithm") {
        cfg.algorithm = AlgorithmKind::parse(a)?;
    }
    if let Some(d) = args.get("dist") {
        cfg.dist = DataDist::parse(d)?;
    }
    cfg.n_steps = args.get_usize("steps", cfg.n_steps)?;
    cfg.n_sats = args.get_usize("sats", cfg.n_sats)?;
    cfg.fedbuff_m = args.get_usize("fedbuff-m", cfg.fedbuff_m)?;
    if let Some(s) = args.get("size") {
        cfg.model_size = s.to_string();
    }
    if let Some(e) = args.get("engine") {
        cfg.engine_mode = EngineMode::parse(e)?;
    }
    cfg.validate()?;
    Ok(cfg)
}

/// `fedspace connectivity` — Figure 2 data for the default fleet.
pub fn connectivity(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig {
        n_sats: args.get_usize("sats", 191)?,
        n_steps: args.get_usize("steps", 96)?,
        ..Default::default()
    };
    let out_dir = args.get_or("out-dir", "results");
    let (_, sched) = super::runner::build_schedule(&cfg);
    let stats = ConnectivityStats::from_schedule(&sched);
    println!(
        "constellation: {} satellites, 12 ground stations, T0 = {} min, {} steps",
        cfg.n_sats,
        cfg.t0_s / 60.0,
        cfg.n_steps
    );
    println!("|C_i|: min={} max={}", stats.min_set, stats.max_set);
    println!("mean contacts/satellite: {:.1}", stats.mean_contacts);
    let mut csv = String::from("i,n_connected\n");
    for (i, n) in stats.set_sizes.iter().enumerate() {
        csv.push_str(&format!("{i},{n}\n"));
    }
    write_file(&format!("{out_dir}/fig2a_set_sizes.csv"), &csv)?;
    let mut csv = String::from("n_contacts,n_satellites\n");
    for (bucket, count) in stats.contacts_histogram(1) {
        csv.push_str(&format!("{bucket},{count}\n"));
    }
    write_file(&format!("{out_dir}/fig2b_contacts_hist.csv"), &csv)?;
    println!("wrote {out_dir}/fig2a_set_sizes.csv, {out_dir}/fig2b_contacts_hist.csv");
    Ok(())
}

/// `fedspace illustrative` — Table 1 of the 3-satellite example.
pub fn illustrative(_args: &Args) -> Result<()> {
    let mut table = Table::new(&["scheme", "updates", "s=0", "s=1", "s=2", "s=5", "total", "idle"]);
    for r in illustrative::table1() {
        table.row(&[
            r.scheme.to_string(),
            r.global_updates.to_string(),
            r.staleness.count(0).to_string(),
            r.staleness.count(1).to_string(),
            r.staleness.count(2).to_string(),
            r.staleness.count(5).to_string(),
            r.total_aggregated.to_string(),
            r.idle.to_string(),
        ]);
    }
    println!("Table 1 (3-satellite illustrative example):\n{}", table.render());
    Ok(())
}

/// `fedspace train` — one FL experiment (mock or PJRT backend).
pub fn train(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let stop_at = args.get("target").map(|t| t.parse::<f64>()).transpose()?;
    let eval_samples = args.get_usize("eval-samples", 512)?;
    println!(
        "running {} / {:?} on {} satellites, {} steps ({} backend)",
        cfg.algorithm.name(),
        cfg.dist,
        cfg.n_sats,
        cfg.n_steps,
        if args.has_flag("mock") { "mock" } else { "pjrt" }
    );
    let out = if args.has_flag("mock") {
        run_mock_experiment(&cfg, stop_at)?
    } else {
        run_pjrt_experiment(&cfg, eval_samples, stop_at)?
    };
    let r = &out.result;
    println!(
        "finished: rounds={} uploads={} idle={} ({:.1}%) best_acc={:.4}",
        r.final_round,
        r.trace.uploads,
        r.trace.idle,
        100.0 * r.trace.idle_fraction(),
        r.trace.curve.best_accuracy()
    );
    if let Some(t) = stop_at {
        match r.days_to_target {
            Some(d) => println!("reached {:.0}% accuracy after {:.2} simulated days", t * 100.0, d),
            None => println!("never reached {:.0}% accuracy", t * 100.0),
        }
    }
    println!(
        "time: train={:.1}s agg={:.1}s eval={:.1}s",
        r.trace.t_train_s, r.trace.t_agg_s, r.trace.t_eval_s
    );
    if let Some(path) = args.get("out") {
        write_file(path, &r.trace.curve.to_csv())?;
        println!("curve written to {path}");
    }
    Ok(())
}

/// `fedspace utility` — phase-1 utility-regression pipeline on the mock.
pub fn utility(args: &Args) -> Result<()> {
    let n = args.get_usize("samples", 400)?;
    let backend = MockBackend::new(32, 0);
    let mut rng = Rng::new(1);
    let bank = pretrain_bank(&backend, 20, 8, 0.5, &mut rng)?;
    let (inputs, targets) = generate_samples(&backend, &bank, n, 8, 16, 0.5, &mut rng)?;
    let split = n * 4 / 5;
    for kind in ["forest", "linear"] {
        let mut u = UtilityModel::new(kind)?;
        u.fit(&inputs[..split].to_vec(), &targets[..split]);
        let mse: f64 = inputs[split..]
            .iter()
            .zip(&targets[split..])
            .map(|((s, t), y)| {
                let p = u.predict(s, *t);
                (p - y) * (p - y)
            })
            .sum::<f64>()
            / (n - split) as f64;
        println!("{kind:>8}: test MSE = {mse:.6} over {} held-out samples", n - split);
    }
    Ok(())
}

/// Standalone §3 demo: fit û on the mock, plan a^{0,I0} over the real
/// constellation, print the slot-by-slot forecast.
pub fn schedule(args: &Args) -> Result<()> {
    use crate::sched::{
        forecast_window, generate_samples, pretrain_bank, FedSpacePlanner, MockBackend,
        SatForecastState, SearchParams, UtilityModel,
    };
    let n_sats = args.get_usize("sats", 191)?;
    let i0 = args.get_usize("i0", 24)?;
    let n_min = args.get_usize("n-min", 1)?;
    let n_max = args.get_usize("n-max", 8)?.min(i0);
    let cfg = ExperimentConfig { n_sats, n_steps: i0, ..Default::default() };
    let (_, sched) = super::runner::build_schedule(&cfg);

    // phase 1 on the mock source task
    let backend = MockBackend::new(32, 0);
    let mut rng = Rng::new(1);
    let bank = pretrain_bank(&backend, 16, 8, 0.5, &mut rng)?;
    let (inp, tgt) = generate_samples(&backend, &bank, 300, 8, 16, 0.5, &mut rng)?;
    let mut utility = UtilityModel::new("forest")?;
    utility.fit(&inp, &tgt);

    // phase 2: random search
    let params = SearchParams { i0, n_min, n_max, n_search: 2000 };
    let mut planner = FedSpacePlanner::new(utility, params, 0);
    let states = vec![SatForecastState::fresh(); n_sats];
    // lint: allow(wall-clock): reporting planner latency to the operator, not trace state
    let t0 = std::time::Instant::now();
    let window = planner.plan(&sched, 0, &states, bank.losses[1]);
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    let f = forecast_window(&sched, 0, &window, &states);

    println!("planned a^(0..{i0}) over {n_sats} satellites in {ms:.0} ms (|R|=2000):\n");
    let mut agg_idx = 0usize;
    for (l, &a) in window.iter().enumerate() {
        let conn = sched.sets[l].len();
        if a && agg_idx < f.aggregations.len() {
            let st = &f.aggregations[agg_idx];
            if !st.is_empty() {
                let max_s = st.iter().max().unwrap();
                println!(
                    "  slot {l:>2}: AGGREGATE  |C|={conn:<3} gradients={} staleness<= {max_s}",
                    st.len()
                );
                agg_idx += 1;
                continue;
            }
        }
        println!("  slot {l:>2}:            |C|={conn}");
    }
    println!(
        "\nforecast: {} aggregations, {} gradients total, {} idle of {} contacts",
        f.aggregations.len(),
        f.aggregations.iter().map(|a| a.len()).sum::<usize>(),
        f.idle,
        f.contacts
    );
    println!("predicted window utility: {:.4}", planner.planned_utilities[0]);
    Ok(())
}

/// Benches added after the newest committed baseline was armed: reported by
/// the harness but knowingly absent from the baseline until the next
/// bench-baseline refresh. `bench-check` lists these as "pending" instead of
/// warning about an unknown name, so a freshly added bench reads as expected
/// lag rather than a misconfiguration.
const PENDING_BASELINE_BENCHES: &[&str] = &[
    "event_sink_overhead",
    "sparse_aggregate_dense_ref",
    "sparse_aggregate_topk",
    "contact_capacity_route",
    "robust_aggregate_mean",
    "robust_aggregate_median",
    "robust_aggregate_trimmed",
    "robust_aggregate_krum",
    "federation_reconcile",
    "serve_ingest_throughput",
    "serve_reconcile_latency",
];

/// `fedspace lint` — the determinism-contract static analysis (ADR-0011).
///
/// Scans every `.rs` file under the root, prints one `file:line: rule:
/// message` per finding, and optionally emits the `fedspace-lint-v1`
/// JSON report. The report is written *before* `--deny` bails so CI can
/// always upload it as an artifact, findings or not.
pub fn lint(args: &Args) -> Result<()> {
    use std::path::{Path, PathBuf};
    let root: PathBuf = match args.get("path") {
        Some(p) => PathBuf::from(p),
        None => ["src", "rust/src"]
            .iter()
            .map(Path::new)
            .find(|p| p.join("lib.rs").is_file())
            .map(Path::to_path_buf)
            .context("no src/lib.rs or rust/src/lib.rs below the working directory; pass --path DIR")?,
    };
    let report = crate::analysis::lint_dir(&root)?;
    match json_request(args) {
        JsonOut::No => {}
        JsonOut::Stdout => println!("{}", report.to_json()),
        JsonOut::File(path) => {
            write_file(&path, &report.to_json())?;
            println!("lint report written to {path}");
        }
    }
    print!("{}", report.render_text());
    if args.has_flag("deny") && !report.clean() {
        bail!("lint --deny: {} finding(s)", report.findings.len());
    }
    Ok(())
}

/// `fedspace bench-check` — the CI perf-regression gate: merge one or more
/// bench JSON outputs, compare them against the committed baseline, print
/// a markdown table (also written to `--summary-out` for the CI step
/// summary), and fail on any tracked path slower than the budget. A
/// provisional baseline reports in bootstrap mode and never fails (see
/// `bench_report`).
pub fn bench_check(args: &Args) -> Result<()> {
    use crate::bench_report::{compare, BenchReport};
    let baseline_arg = args.require("baseline")?;
    let current_paths = args.require("current")?;
    let max_regress = args.get_f64("max-regress", 0.25)?;
    if max_regress <= 0.0 {
        bail!("--max-regress must be positive");
    }
    // `--baseline` is a newest-first list: the gate prefers the newest
    // non-provisional baseline and falls back to the first entry (bootstrap
    // mode) when every committed baseline is still provisional
    let mut chosen: Option<(String, BenchReport)> = None;
    let mut fallback: Option<(String, BenchReport)> = None;
    for path in baseline_arg.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let report = BenchReport::from_file(path)?;
        if !report.provisional {
            chosen = Some((path.to_string(), report));
            break;
        }
        if fallback.is_none() {
            fallback = Some((path.to_string(), report));
        }
    }
    let (baseline_path, baseline) =
        chosen.or(fallback).context("--baseline lists no readable files")?;
    println!(
        "baseline: {baseline_path}{}",
        if baseline.provisional { " (provisional — bootstrap mode)" } else { "" }
    );
    let merged = merge_bench_reports(current_paths)?;
    let cmp = compare(&baseline, &merged, max_regress);
    let md = cmp.to_markdown();
    println!("{md}");
    if let Some(path) = args.get("summary-out") {
        // written before any gate failure below, so CI can append it to the
        // step summary whether the gate passes or fails
        write_file(path, &md)?;
    }
    if !cmp.new_paths.is_empty() {
        // benches the harness reports but the committed baseline predates are
        // expected to lag one baseline refresh behind — list them as pending
        // rather than crying wolf; anything NOT on the pending list is a
        // genuinely unknown name and keeps the loud warning, because a bench
        // absent from the baseline is not gated, and silence here would let
        // new benches dodge the gate forever
        let (pending, unknown): (Vec<&str>, Vec<&str>) = cmp
            .new_paths
            .iter()
            .map(String::as_str)
            .partition(|p| PENDING_BASELINE_BENCHES.contains(p));
        if !pending.is_empty() {
            println!(
                "note: {} bench(es) reported but not yet gated (newer than the armed \
                 baseline): {} — refresh the committed baseline (the CI bench-baseline \
                 artifact) to arm them",
                pending.len(),
                pending.join(", ")
            );
        }
        if !unknown.is_empty() {
            eprintln!(
                "warning: {} tracked path(s) have no baseline entry and are NOT gated: {} — \
                 commit an updated baseline (the CI bench-baseline artifact) to arm them",
                unknown.len(),
                unknown.join(", ")
            );
        }
    }
    if !cmp.regressions.is_empty() {
        bail!(
            "perf regression gate failed: {} path(s) >{:.0}% slower than {}: {}",
            cmp.regressions.len(),
            max_regress * 100.0,
            baseline_path,
            cmp.regressions.join(", ")
        );
    }
    Ok(())
}

/// Merge a comma-separated list of bench JSON files into one
/// non-provisional report (later files win on duplicate keys); errors when
/// the merge comes out empty. Shared by `bench-check` and `bench-baseline`
/// so their `--current` semantics can never diverge.
fn merge_bench_reports(paths: &str) -> Result<crate::bench_report::BenchReport> {
    use crate::bench_report::BenchReport;
    let mut merged = BenchReport { provisional: false, benches: Default::default() };
    for path in paths.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let part = BenchReport::from_file(path)?;
        merged.benches.extend(part.benches);
    }
    if merged.benches.is_empty() {
        bail!("no bench results found in --current {paths}");
    }
    Ok(merged)
}

/// `fedspace bench-baseline` — merge bench JSON outputs into a
/// non-provisional baseline document, ready to commit as `rust/BENCH_*.json`.
/// CI runs this after a green gate and uploads the result as the
/// `bench-baseline` artifact, so arming (or refreshing) the perf gate is a
/// single download-and-commit.
pub fn bench_baseline(args: &Args) -> Result<()> {
    let current_paths = args.require("current")?;
    let out = args.require("out")?;
    let merged = merge_bench_reports(current_paths)?;
    write_file(out, &merged.to_json())?;
    println!(
        "armed baseline written to {out} ({} tracked paths, provisional: false)",
        merged.benches.len()
    );
    Ok(())
}

/// Resolve the scenario a `scenarios describe|run` invocation names: a
/// registry name as the second positional argument, or `--config FILE`.
fn resolve_scenario(args: &Args) -> Result<Scenario> {
    resolve_scenario_at(args, 1, "fedspace scenarios <list|describe|run> [name] [options]")
}

/// [`resolve_scenario`] generalized over the positional slot the name sits
/// in (`scenarios run <name>` puts it second; `serve <name>` / `loadgen
/// <name>` put it first).
fn resolve_scenario_at(args: &Args, pos: usize, usage: &str) -> Result<Scenario> {
    if let Some(path) = args.get("config") {
        return Scenario::from_file(path);
    }
    match args.positional.get(pos) {
        Some(name) => Scenario::builtin(name).with_context(|| {
            format!(
                "unknown scenario {name:?} — `fedspace scenarios list` shows: {}",
                Scenario::builtin_names().join(", ")
            )
        }),
        None => bail!("usage: {usage}"),
    }
}

/// The shared body of `fedspace serve` / `fedspace loadgen` (ADR-0010):
/// resolve + scale the scenario, apply `[serve]` knob overrides, replay the
/// contact trace into the serving front end, report throughput/latency, and
/// emit the run-artifact bundle on `--json`.
fn serve_replay(args: &Args, cmd_name: &str, pace_default: f64) -> Result<()> {
    let sc = resolve_scenario_at(args, 0, &format!("fedspace {cmd_name} <name> [options]"))?;
    let sats = args.get("sats").map(|v| v.parse::<usize>()).transpose()?;
    let steps = args.get("steps").map(|v| v.parse::<usize>()).transpose()?;
    let mut sc = sc.scaled(sats, steps);
    sc.serve.queue_cap = args.get_usize("queue-cap", sc.serve.queue_cap)?;
    sc.serve.batch = args.get_usize("batch", sc.serve.batch)?;
    sc.serve.shards = args.get_usize("shards", sc.serve.shards)?;
    let pace_s = args.get_f64("pace", pace_default)?;
    let json_out = json_request(args);
    println!(
        "{cmd_name} {}: {} sats, {} steps, {} gateway(s), queue_cap {}, batch {}, shards {}{}",
        sc.name,
        sc.constellation.n_sats(),
        sc.n_steps,
        sc.federation.n_gateways(),
        sc.serve.queue_cap,
        sc.serve.batch,
        sc.serve.shards,
        if pace_s > 0.0 { format!(", pace {pace_s}s/slot") } else { String::new() }
    );
    let opts = LoadgenOpts { pace_s, record_events: true };
    let r = run_loadgen(&sc, &opts)?;
    println!(
        "served {} uploads in {:.2}s — {:.0} uploads/s (deferred {}, rejected {})",
        r.uploads, r.wall_s, r.uploads_per_s, r.deferred_offers, r.rejected
    );
    println!(
        "ticks {} rounds {} reconciles {}; drain latency p50 {:.3} ms, p99 {:.3} ms",
        r.ticks, r.final_round, r.reconciles, r.p50_ms, r.p99_ms
    );
    // queue depths at drain, log2-bucketed — the saturation picture
    let depths: Vec<String> = r
        .depth_hist
        .iter()
        .enumerate()
        .filter(|(_, &n)| n > 0)
        .map(|(b, &n)| {
            if b == 0 {
                format!("0: {n}")
            } else {
                format!("[{}, {}): {n}", 1usize << (b - 1), 1usize << b)
            }
        })
        .collect();
    println!("queue depth at drain: {}", depths.join("  "));
    match json_out {
        JsonOut::Stdout => println!("{}", bundle_json(&[r.artifact])),
        JsonOut::File(path) => {
            write_file(&path, &bundle_json(&[r.artifact]))?;
            println!("run-artifact bundle written to {path}");
        }
        JsonOut::No => {}
    }
    Ok(())
}

/// `fedspace serve` — the serving front end paced in wall-clock time: the
/// long-lived-driver mode (a replayed trace stands in for live gateways).
pub fn serve(args: &Args) -> Result<()> {
    serve_replay(args, "serve", 0.05)
}

/// `fedspace loadgen` — the same replay at maximum speed: the
/// throughput/latency measurement mode (sustained uploads/sec, p50/p99).
pub fn loadgen(args: &Args) -> Result<()> {
    serve_replay(args, "loadgen", 0.0)
}

/// Where a `--json` request routes machine-readable output: nowhere (flag
/// absent), stdout (`--json` bare), or a file (`--json FILE`).
enum JsonOut {
    No,
    Stdout,
    File(String),
}

/// Decode the `--json [FILE]` option shared by `scenarios describe` and
/// `scenarios run`. A bare `--json` parses as a flag; `--json FILE` binds
/// the path as an option value (see `args::Args`).
fn json_request(args: &Args) -> JsonOut {
    if let Some(path) = args.get("json") {
        JsonOut::File(path.to_string())
    } else if args.has_flag("json") {
        JsonOut::Stdout
    } else {
        JsonOut::No
    }
}

/// Render a scenario description as a standalone JSON document (schema
/// `fedspace-scenario-v1`): identity fields plus the full TOML spec, so a
/// consumer can both inspect and replay the scenario.
fn describe_json(sc: &Scenario) -> String {
    use crate::sim::events::json_escape;
    format!(
        "{{\"schema\":\"fedspace-scenario-v1\",\"name\":\"{}\",\"summary\":\"{}\",\
         \"engine\":\"{}\",\"n_sats\":{},\"n_steps\":{},\"toml\":\"{}\"}}",
        json_escape(&sc.name),
        json_escape(&sc.summary),
        sc.engine_mode.name(),
        sc.constellation.n_sats(),
        sc.n_steps,
        json_escape(&sc.to_toml()),
    )
}

/// `fedspace scenarios` — list, describe or run the constellation zoo.
pub fn scenarios(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        None | Some("list") => {
            let mut t = Table::new(&[
                "name", "constellation", "sats", "stations", "steps", "engine", "isl",
                "gateways", "attack", "agg", "codec", "algorithms",
            ]);
            for sc in Scenario::builtins() {
                t.row(&[
                    sc.name.clone(),
                    sc.constellation.kind_name().to_string(),
                    sc.constellation.n_sats().to_string(),
                    sc.stations.name().to_string(),
                    sc.n_steps.to_string(),
                    sc.engine_mode.name().to_string(),
                    sc.isl.mode.name().to_string(),
                    if sc.federation.is_single() {
                        "1".to_string()
                    } else {
                        let fed = &sc.federation;
                        format!("{} ({})", fed.n_gateways(), fed.reconcile.name())
                    },
                    sc.attack.kind.name().to_string(),
                    sc.robust.aggregator.name().to_string(),
                    if sc.link.enabled() {
                        sc.link.codec.name().to_string()
                    } else {
                        "off".to_string()
                    },
                    sc.algorithms
                        .iter()
                        .map(|a| a.name().to_string())
                        .collect::<Vec<_>>()
                        .join("+"),
                ]);
            }
            println!("built-in scenarios:\n{}", t.render());
            println!("run one: fedspace scenarios run <name> [--sats N --steps N]");
            Ok(())
        }
        Some("describe") => {
            let sc = resolve_scenario(args)?;
            match json_request(args) {
                JsonOut::No => {
                    println!("# {} — {}\n", sc.name, sc.summary);
                    print!("{}", sc.to_toml());
                }
                JsonOut::Stdout => println!("{}", describe_json(&sc)),
                JsonOut::File(path) => {
                    write_file(&path, &describe_json(&sc))?;
                    println!("scenario description written to {path}");
                }
            }
            Ok(())
        }
        Some("run") => {
            let sc = resolve_scenario(args)?;
            let sats = args.get("sats").map(|v| v.parse::<usize>()).transpose()?;
            let steps = args.get("steps").map(|v| v.parse::<usize>()).transpose()?;
            let mut sc = sc.scaled(sats, steps);
            if let Some(a) = args.get("algorithm") {
                sc.algorithms = vec![AlgorithmKind::parse(a)?];
            }
            if let Some(e) = args.get("engine") {
                sc.engine_mode = EngineMode::parse(e)?;
            }
            let stop_at = args.get("target").map(|t| t.parse::<f64>()).transpose()?;
            let json_out = json_request(args);
            if !matches!(json_out, JsonOut::No) {
                // a bundle without its event stream is just the trace again;
                // force recording on so --json always carries full events
                sc.events = EventSpec { record: true };
            }
            println!(
                "scenario {}: {} ({} sats, {} stations, {} steps, {} engine, isl {}, \
                 {} gateway(s), attack {}, agg {}, codec {})",
                sc.name,
                sc.summary,
                sc.constellation.n_sats(),
                sc.stations.build().len(),
                sc.n_steps,
                sc.engine_mode.name(),
                sc.isl.mode.name(),
                sc.federation.n_gateways(),
                sc.attack.kind.name(),
                sc.robust.aggregator.name(),
                if sc.link.enabled() { sc.link.codec.name() } else { "off" }
            );
            let outs = run_scenario(&sc, stop_at)?;
            // every run becomes a run-artifact first; the human table below
            // is rendered FROM the artifacts, so table and bundle can never
            // disagree (ADR-0009)
            let artifacts: Vec<RunArtifact> = outs
                .iter()
                .map(|out| {
                    RunArtifact::from_run(
                        &sc.name,
                        out.algorithm.name(),
                        sc.engine_mode.name(),
                        sc.constellation.n_sats(),
                        sc.n_steps,
                        &out.result,
                    )
                })
                .collect();
            let mut t = Table::new(&[
                "algorithm", "rounds", "gw aggs", "uploads", "deferred", "relayed",
                "inj/drop/corr", "idle%", "max stale", "best acc", "days→target",
            ]);
            for art in &artifacts {
                t.row(&[
                    art.algorithm.clone(),
                    art.final_round.to_string(),
                    art.trace
                        .gateway_aggs
                        .iter()
                        .map(|n| n.to_string())
                        .collect::<Vec<_>>()
                        .join("/"),
                    art.trace.uploads.to_string(),
                    art.trace.deferred.to_string(),
                    art.trace.relayed.to_string(),
                    format!(
                        "{}/{}/{}",
                        art.trace.injected, art.trace.dropped, art.trace.corrupted
                    ),
                    format!("{:.1}", 100.0 * art.trace.idle_fraction()),
                    art.trace.staleness.max_key().unwrap_or(0).to_string(),
                    format!("{:.4}", art.trace.curve.best_accuracy()),
                    match art.days_to_target {
                        Some(d) => format!("{d:.2}"),
                        None => "-".to_string(),
                    },
                ]);
                if let Some(dir) = args.get("out-dir") {
                    let path = format!("{dir}/{}_{}.csv", sc.name, art.algorithm);
                    write_file(&path, &art.trace.curve.to_csv())?;
                    println!("curve written to {path}");
                }
            }
            println!("{}", t.render());
            match json_out {
                JsonOut::Stdout => println!("{}", bundle_json(&artifacts)),
                JsonOut::File(path) => {
                    write_file(&path, &bundle_json(&artifacts))?;
                    println!("run-artifact bundle written to {path}");
                }
                JsonOut::No => {
                    if let Some(dir) = args.get("out-dir") {
                        let path = format!("{dir}/{}_artifact.json", sc.name);
                        write_file(&path, &bundle_json(&artifacts))?;
                        println!("run-artifact bundle written to {path}");
                    }
                }
            }
            Ok(())
        }
        Some(other) => bail!("unknown scenarios action {other:?} (list|describe|run)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn illustrative_runs() {
        illustrative(&args("illustrative")).unwrap();
    }

    #[test]
    fn train_mock_tiny() {
        train(&args(
            "train --mock --algorithm fedbuff --fedbuff-m 3 --sats 6 --steps 24",
        ))
        .unwrap();
    }

    #[test]
    fn schedule_command_plans_a_window() {
        schedule(&args("schedule --sats 12 --i0 12 --n-max 4")).unwrap();
    }

    #[test]
    fn config_overrides() {
        let cfg = config_from(&args(
            "train --algorithm sync --dist noniid --sats 20 --engine contacts",
        ))
        .unwrap();
        assert_eq!(cfg.algorithm, AlgorithmKind::Sync);
        assert_eq!(cfg.dist, DataDist::NonIid);
        assert_eq!(cfg.n_sats, 20);
        assert_eq!(cfg.engine_mode, EngineMode::ContactList);
    }

    #[test]
    fn scenarios_list_and_describe() {
        scenarios(&args("scenarios list")).unwrap();
        scenarios(&args("scenarios")).unwrap();
        for name in Scenario::builtin_names() {
            scenarios(&args(&format!("scenarios describe {name}"))).unwrap();
        }
        assert!(scenarios(&args("scenarios describe nope")).is_err());
        assert!(scenarios(&args("scenarios explode")).is_err());
        assert!(scenarios(&args("scenarios run")).is_err());
    }

    #[test]
    fn bench_check_gates_and_bootstraps() {
        use crate::bench_report::BenchReport;
        // per-process dir: concurrent test runs must not race on the files
        let dir =
            std::env::temp_dir().join(format!("fedspace_bench_check_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = |n: &str| dir.join(n).to_string_lossy().into_owned();
        let report = |prov: bool, v: f64| BenchReport {
            provisional: prov,
            benches: [("a".to_string(), v)].into_iter().collect(),
        };
        std::fs::write(path("base.json"), report(false, 1.0).to_json()).unwrap();
        std::fs::write(path("ok.json"), report(false, 1.1).to_json()).unwrap();
        std::fs::write(path("bad.json"), report(false, 2.0).to_json()).unwrap();
        std::fs::write(path("prov.json"), report(true, 0.001).to_json()).unwrap();
        // `base` is a comma list of file names already resolved to paths
        let run_raw = |base: &str, cur: &str| {
            bench_check(&args(&format!(
                "bench-check --baseline {} --current {} --summary-out {}",
                base,
                path(cur),
                path("summary.md")
            )))
        };
        let run = |base: &str, cur: &str| run_raw(&path(base), cur);
        run("base.json", "ok.json").unwrap();
        assert!(run("base.json", "bad.json").is_err(), "2x slowdown must fail the gate");
        // provisional baseline: report-only, never fails
        run("prov.json", "bad.json").unwrap();
        let summary = std::fs::read_to_string(path("summary.md")).unwrap();
        assert!(summary.contains("Bootstrap mode"));
        // missing inputs error out
        assert!(run("nope.json", "ok.json").is_err());
        // newest-first baseline list: the first non-provisional entry gates
        // (prov.json first must NOT put the gate in bootstrap mode)
        let list = format!("{},{}", path("prov.json"), path("base.json"));
        assert!(run_raw(&list, "bad.json").is_err(), "armed baseline later in the list must gate");
        run_raw(&list, "ok.json").unwrap();
        // all-provisional list falls back to bootstrap
        run_raw(&format!("{0},{0}", path("prov.json")), "bad.json").unwrap();
        // a bench unknown to the baseline is a warning, not a silent pass
        let new_path = BenchReport {
            provisional: false,
            benches: [("a".to_string(), 1.0), ("brand_new".to_string(), 9.0)]
                .into_iter()
                .collect(),
        };
        std::fs::write(path("new.json"), new_path.to_json()).unwrap();
        run("base.json", "new.json").unwrap();
        let summary = std::fs::read_to_string(path("summary.md")).unwrap();
        assert!(summary.contains("no baseline entry"), "{summary}");
        assert!(summary.contains("brand_new"));
    }

    #[test]
    fn bench_baseline_merges_and_arms() {
        use crate::bench_report::BenchReport;
        let dir =
            std::env::temp_dir().join(format!("fedspace_bench_baseline_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = |n: &str| dir.join(n).to_string_lossy().into_owned();
        let part = |entries: &[(&str, f64)]| BenchReport {
            // the merge must force provisional to false whatever the inputs say
            provisional: true,
            benches: entries.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        };
        std::fs::write(path("a.json"), part(&[("x", 1.0)]).to_json()).unwrap();
        std::fs::write(path("b.json"), part(&[("y", 2.0)]).to_json()).unwrap();
        bench_baseline(&args(&format!(
            "bench-baseline --current {},{} --out {}",
            path("a.json"),
            path("b.json"),
            path("armed.json")
        )))
        .unwrap();
        let armed = BenchReport::from_file(&path("armed.json")).unwrap();
        assert!(!armed.provisional);
        assert_eq!(armed.benches.len(), 2);
        assert_eq!(armed.benches["x"], 1.0);
        // empty merge errors
        std::fs::write(path("empty.json"), "{\"benches\": {}}").unwrap();
        assert!(bench_baseline(&args(&format!(
            "bench-baseline --current {} --out {}",
            path("empty.json"),
            path("armed2.json")
        )))
        .is_err());
    }

    #[test]
    fn bench_check_lists_pending_benches() {
        use crate::bench_report::BenchReport;
        let dir =
            std::env::temp_dir().join(format!("fedspace_bench_pending_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = |n: &str| dir.join(n).to_string_lossy().into_owned();
        let report = |benches: &[(&str, f64)]| BenchReport {
            provisional: false,
            benches: benches.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        };
        std::fs::write(path("base.json"), report(&[("a", 1.0)]).to_json()).unwrap();
        // a pending bench is reported (and wildly slow) yet never gated —
        // the note replaces the unknown-name warning, the gate stays green
        std::fs::write(
            path("cur.json"),
            report(&[("a", 1.0), ("event_sink_overhead", 9.0)]).to_json(),
        )
        .unwrap();
        bench_check(&args(&format!(
            "bench-check --baseline {} --current {} --summary-out {}",
            path("base.json"),
            path("cur.json"),
            path("summary.md")
        )))
        .unwrap();
        let summary = std::fs::read_to_string(path("summary.md")).unwrap();
        assert!(summary.contains("event_sink_overhead"), "{summary}");
        assert!(PENDING_BASELINE_BENCHES.contains(&"event_sink_overhead"));
    }

    #[test]
    fn scenarios_json_outputs_round_trip() {
        use crate::bench_report::parse_json;
        let dir =
            std::env::temp_dir().join(format!("fedspace_scen_json_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let bundle = dir.join("bundle.json").to_string_lossy().into_owned();
        scenarios(&args(&format!(
            "scenarios run paper-fig7 --sats 6 --steps 24 --algorithm fedbuff --json {bundle}"
        )))
        .unwrap();
        let doc = parse_json(&std::fs::read_to_string(&bundle).unwrap()).unwrap();
        assert_eq!(
            doc.get("schema").and_then(|v| v.as_str()),
            Some("fedspace-run-artifact-v1")
        );
        let runs = doc.get("runs").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(runs.len(), 1, "one grid entry, one artifact");
        let run = &runs[0];
        assert_eq!(run.get("algorithm").and_then(|v| v.as_str()), Some("fedbuff"));
        assert_eq!(run.get("n_sats").and_then(|v| v.as_num()), Some(6.0));
        // --json forces event recording: the stream opens with run_start
        let events = run.get("events").and_then(|v| v.as_arr()).unwrap();
        assert!(!events.is_empty(), "--json must carry the event stream");
        assert_eq!(events[0].get("type").and_then(|v| v.as_str()), Some("run_start"));
        // every summary counter in the bundle is parseable as a number
        let summary = run.get("summary").unwrap();
        assert!(summary.get("uploads").and_then(|v| v.as_num()).is_some());
        // describe --json round-trips through the same in-repo parser
        let desc = dir.join("desc.json").to_string_lossy().into_owned();
        scenarios(&args(&format!("scenarios describe paper-fig7 --json {desc}"))).unwrap();
        let doc = parse_json(&std::fs::read_to_string(&desc).unwrap()).unwrap();
        assert_eq!(doc.get("schema").and_then(|v| v.as_str()), Some("fedspace-scenario-v1"));
        assert_eq!(doc.get("name").and_then(|v| v.as_str()), Some("paper-fig7"));
        let toml = doc.get("toml").and_then(|v| v.as_str()).unwrap();
        assert!(toml.contains("[constellation]"), "embedded TOML spec survives escaping");
    }

    #[test]
    fn loadgen_and_serve_commands_replay_a_trace() {
        use crate::bench_report::parse_json;
        let dir = std::env::temp_dir().join(format!("fedspace_loadgen_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let bundle = dir.join("serve_bundle.json").to_string_lossy().into_owned();
        loadgen(&args(&format!(
            "loadgen fedspace-multi-gs --sats 8 --steps 24 --json {bundle}"
        )))
        .unwrap();
        let doc = parse_json(&std::fs::read_to_string(&bundle).unwrap()).unwrap();
        assert_eq!(doc.get("schema").and_then(|v| v.as_str()), Some("fedspace-run-artifact-v1"));
        let runs = doc.get("runs").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(runs.len(), 1);
        let events = runs[0].get("events").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(events[0].get("type").and_then(|v| v.as_str()), Some("run_start"));
        let report = events
            .iter()
            .find(|e| e.get("type").and_then(|v| v.as_str()) == Some("serve_report"))
            .expect("the replay must end in a serve_report");
        assert!(report.get("uploads_per_s").and_then(|v| v.as_num()).unwrap() > 0.0);
        // the paced driver runs the same machinery (pace 0 keeps tests fast)
        serve(&args("serve paper-fig7 --sats 6 --steps 12 --pace 0 --batch 8")).unwrap();
        // a missing scenario name is a usage error
        assert!(loadgen(&args("loadgen")).is_err());
    }

    #[test]
    fn scenarios_run_tiny() {
        scenarios(&args(
            "scenarios run paper-fig7 --sats 6 --steps 24 --algorithm fedbuff",
        ))
        .unwrap();
        scenarios(&args(
            "scenarios run sparse-single-gs --sats 10 --steps 48 --engine contacts",
        ))
        .unwrap();
        // the multi-gateway builtin sweeps with per-gateway agg columns
        scenarios(&args(
            "scenarios run fedspace-multi-gs --sats 12 --steps 24 --algorithm fedbuff",
        ))
        .unwrap();
    }
}
