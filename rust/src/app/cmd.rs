//! CLI subcommand implementations.

use super::args::Args;
use super::runner::{run_mock_experiment, run_pjrt_experiment};
use crate::cfg::{AlgorithmKind, DataDist, ExperimentConfig};
use crate::connectivity::ConnectivityStats;
use crate::fl::illustrative;
use crate::metrics::{write_file, Table};
use crate::rng::Rng;
use crate::sched::{generate_samples, pretrain_bank, MockBackend, UtilityModel};
use anyhow::Result;

pub const HELP: &str = "\
fedspace — FL coordinator for satellites and ground stations (So et al. 2022)

USAGE: fedspace <command> [options]

COMMANDS:
  connectivity  compute constellation connectivity (Figure 2 data)
                  --sats N (191)  --steps N (96)  --out-dir DIR (results)
  illustrative  the 3-satellite example (Figures 3-4, Table 1)
  train         run one FL experiment
                  --config FILE           TOML config (optional)
                  --algorithm sync|async|fedbuff|fedspace (fedspace)
                  --dist iid|noniid (iid) --steps N (480) --sats N (191)
                  --mock                  analytic backend (default: PJRT)
                  --size small|fmow       model size for PJRT (fmow)
                  --eval-samples N (512)  --target ACC (none)
                  --out FILE              write the accuracy curve CSV
  utility       phase-1 utility pipeline on the mock backend; reports MSE
                  --samples N (400)
  schedule      plan one FedSpace aggregation window over the real
                constellation and print the forecast timeline
                  --sats N (191)  --i0 N (24)  --n-min N (1) --n-max N (8)
  help          this text
";

/// Apply common CLI overrides onto a config.
fn config_from(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::from_file(path)?,
        None => ExperimentConfig::default(),
    };
    if let Some(a) = args.get("algorithm") {
        cfg.algorithm = AlgorithmKind::parse(a)?;
    }
    if let Some(d) = args.get("dist") {
        cfg.dist = DataDist::parse(d)?;
    }
    cfg.n_steps = args.get_usize("steps", cfg.n_steps)?;
    cfg.n_sats = args.get_usize("sats", cfg.n_sats)?;
    cfg.fedbuff_m = args.get_usize("fedbuff-m", cfg.fedbuff_m)?;
    if let Some(s) = args.get("size") {
        cfg.model_size = s.to_string();
    }
    cfg.validate()?;
    Ok(cfg)
}

pub fn connectivity(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig {
        n_sats: args.get_usize("sats", 191)?,
        n_steps: args.get_usize("steps", 96)?,
        ..Default::default()
    };
    let out_dir = args.get_or("out-dir", "results");
    let (_, sched) = super::runner::build_schedule(&cfg);
    let stats = ConnectivityStats::from_schedule(&sched);
    println!(
        "constellation: {} satellites, 12 ground stations, T0 = {} min, {} steps",
        cfg.n_sats,
        cfg.t0_s / 60.0,
        cfg.n_steps
    );
    println!("|C_i|: min={} max={}", stats.min_set, stats.max_set);
    println!("mean contacts/satellite: {:.1}", stats.mean_contacts);
    let mut csv = String::from("i,n_connected\n");
    for (i, n) in stats.set_sizes.iter().enumerate() {
        csv.push_str(&format!("{i},{n}\n"));
    }
    write_file(&format!("{out_dir}/fig2a_set_sizes.csv"), &csv)?;
    let mut csv = String::from("n_contacts,n_satellites\n");
    for (bucket, count) in stats.contacts_histogram(1) {
        csv.push_str(&format!("{bucket},{count}\n"));
    }
    write_file(&format!("{out_dir}/fig2b_contacts_hist.csv"), &csv)?;
    println!("wrote {out_dir}/fig2a_set_sizes.csv, {out_dir}/fig2b_contacts_hist.csv");
    Ok(())
}

pub fn illustrative(_args: &Args) -> Result<()> {
    let mut table = Table::new(&["scheme", "updates", "s=0", "s=1", "s=2", "s=5", "total", "idle"]);
    for r in illustrative::table1() {
        table.row(&[
            r.scheme.to_string(),
            r.global_updates.to_string(),
            r.staleness.count(0).to_string(),
            r.staleness.count(1).to_string(),
            r.staleness.count(2).to_string(),
            r.staleness.count(5).to_string(),
            r.total_aggregated.to_string(),
            r.idle.to_string(),
        ]);
    }
    println!("Table 1 (3-satellite illustrative example):\n{}", table.render());
    Ok(())
}

pub fn train(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let stop_at = args.get("target").map(|t| t.parse::<f64>()).transpose()?;
    let eval_samples = args.get_usize("eval-samples", 512)?;
    println!(
        "running {} / {:?} on {} satellites, {} steps ({} backend)",
        cfg.algorithm.name(),
        cfg.dist,
        cfg.n_sats,
        cfg.n_steps,
        if args.has_flag("mock") { "mock" } else { "pjrt" }
    );
    let out = if args.has_flag("mock") {
        run_mock_experiment(&cfg, stop_at)?
    } else {
        run_pjrt_experiment(&cfg, eval_samples, stop_at)?
    };
    let r = &out.result;
    println!(
        "finished: rounds={} uploads={} idle={} ({:.1}%) best_acc={:.4}",
        r.final_round,
        r.trace.uploads,
        r.trace.idle,
        100.0 * r.trace.idle_fraction(),
        r.trace.curve.best_accuracy()
    );
    if let Some(t) = stop_at {
        match r.days_to_target {
            Some(d) => println!("reached {:.0}% accuracy after {:.2} simulated days", t * 100.0, d),
            None => println!("never reached {:.0}% accuracy", t * 100.0),
        }
    }
    println!(
        "time: train={:.1}s agg={:.1}s eval={:.1}s",
        r.trace.t_train_s, r.trace.t_agg_s, r.trace.t_eval_s
    );
    if let Some(path) = args.get("out") {
        write_file(path, &r.trace.curve.to_csv())?;
        println!("curve written to {path}");
    }
    Ok(())
}

pub fn utility(args: &Args) -> Result<()> {
    let n = args.get_usize("samples", 400)?;
    let backend = MockBackend::new(32, 0);
    let mut rng = Rng::new(1);
    let bank = pretrain_bank(&backend, 20, 8, 0.5, &mut rng)?;
    let (inputs, targets) = generate_samples(&backend, &bank, n, 8, 16, 0.5, &mut rng)?;
    let split = n * 4 / 5;
    for kind in ["forest", "linear"] {
        let mut u = UtilityModel::new(kind)?;
        u.fit(&inputs[..split].to_vec(), &targets[..split]);
        let mse: f64 = inputs[split..]
            .iter()
            .zip(&targets[split..])
            .map(|((s, t), y)| {
                let p = u.predict(s, *t);
                (p - y) * (p - y)
            })
            .sum::<f64>()
            / (n - split) as f64;
        println!("{kind:>8}: test MSE = {mse:.6} over {} held-out samples", n - split);
    }
    Ok(())
}

/// Standalone §3 demo: fit û on the mock, plan a^{0,I0} over the real
/// constellation, print the slot-by-slot forecast.
pub fn schedule(args: &Args) -> Result<()> {
    use crate::sched::{
        forecast_window, generate_samples, pretrain_bank, FedSpacePlanner, MockBackend,
        SatForecastState, SearchParams, UtilityModel,
    };
    let n_sats = args.get_usize("sats", 191)?;
    let i0 = args.get_usize("i0", 24)?;
    let n_min = args.get_usize("n-min", 1)?;
    let n_max = args.get_usize("n-max", 8)?.min(i0);
    let cfg = ExperimentConfig { n_sats, n_steps: i0, ..Default::default() };
    let (_, sched) = super::runner::build_schedule(&cfg);

    // phase 1 on the mock source task
    let backend = MockBackend::new(32, 0);
    let mut rng = Rng::new(1);
    let bank = pretrain_bank(&backend, 16, 8, 0.5, &mut rng)?;
    let (inp, tgt) = generate_samples(&backend, &bank, 300, 8, 16, 0.5, &mut rng)?;
    let mut utility = UtilityModel::new("forest")?;
    utility.fit(&inp, &tgt);

    // phase 2: random search
    let params = SearchParams { i0, n_min, n_max, n_search: 2000 };
    let mut planner = FedSpacePlanner::new(utility, params, 0);
    let states = vec![SatForecastState::fresh(); n_sats];
    let t0 = std::time::Instant::now();
    let window = planner.plan(&sched, 0, &states, bank.losses[1]);
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    let f = forecast_window(&sched, 0, &window, &states);

    println!("planned a^(0..{i0}) over {n_sats} satellites in {ms:.0} ms (|R|=2000):\n");
    let mut agg_idx = 0usize;
    for (l, &a) in window.iter().enumerate() {
        let conn = sched.sets[l].len();
        if a && agg_idx < f.aggregations.len() {
            let st = &f.aggregations[agg_idx];
            if !st.is_empty() {
                let max_s = st.iter().max().unwrap();
                println!(
                    "  slot {l:>2}: AGGREGATE  |C|={conn:<3} gradients={} staleness<= {max_s}",
                    st.len()
                );
                agg_idx += 1;
                continue;
            }
        }
        println!("  slot {l:>2}:            |C|={conn}");
    }
    println!(
        "\nforecast: {} aggregations, {} gradients total, {} idle of {} contacts",
        f.aggregations.len(),
        f.aggregations.iter().map(|a| a.len()).sum::<usize>(),
        f.idle,
        f.contacts
    );
    println!("predicted window utility: {:.4}", planner.planned_utilities[0]);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn illustrative_runs() {
        illustrative(&args("illustrative")).unwrap();
    }

    #[test]
    fn train_mock_tiny() {
        train(&args(
            "train --mock --algorithm fedbuff --fedbuff-m 3 --sats 6 --steps 24",
        ))
        .unwrap();
    }

    #[test]
    fn schedule_command_plans_a_window() {
        schedule(&args("schedule --sats 12 --i0 12 --n-max 4")).unwrap();
    }

    #[test]
    fn config_overrides() {
        let cfg = config_from(&args("train --algorithm sync --dist noniid --sats 20")).unwrap();
        assert_eq!(cfg.algorithm, AlgorithmKind::Sync);
        assert_eq!(cfg.dist, DataDist::NonIid);
        assert_eq!(cfg.n_sats, 20);
    }
}
