//! Experiment runner: wires config → substrates → engine, for both the
//! mock (scheduler-level) and PJRT (full three-layer) backends.

use crate::cfg::{AlgorithmKind, DataDist, EngineMode, ExperimentConfig, Scenario};
use crate::connectivity::{
    ConnectivityParams, ConnectivitySchedule, ConnectivityStream, ContactGraph,
};
use crate::data::{
    partition::cell_visits, partition_iid, partition_noniid, Dataset, Partition, SynthConfig,
};
use crate::fl::CpuAggregator;
use crate::orbit::{planet_ground_stations, planet_labs_like, Constellation};
use crate::rng::Rng;
use crate::runtime::{ModelRuntime, PjrtAggregator};
use crate::sched::{
    generate_samples, pretrain_bank, samples_from_csv, samples_to_csv, FedSpacePlanner,
    MockBackend, SampleBackend, SearchParams, UtilityModel,
};
use crate::sim::{Engine, EngineConfig, MockTrainer, PjrtTrainer, RunResult};
use anyhow::{Context, Result};

/// Everything a bench/figure needs from one run.
pub struct ExperimentOutput {
    /// Trace, curve and final model of the run.
    pub result: RunResult,
    /// Algorithm that produced it.
    pub algorithm: AlgorithmKind,
    /// Data distribution it ran under.
    pub dist: DataDist,
}

/// Constellation + station network + link params for a config — the one
/// place the config's connectivity inputs are interpreted, so the dense
/// and streamed paths can never diverge on them.
fn connectivity_inputs(
    cfg: &ExperimentConfig,
) -> (Constellation, Vec<crate::orbit::GroundStation>, ConnectivityParams) {
    crate::exec::set_default_parallelism(cfg.threads);
    let constellation = planet_labs_like(cfg.n_sats, cfg.constellation_seed);
    let stations = planet_ground_stations();
    let params = ConnectivityParams {
        t0_s: cfg.t0_s,
        min_elev_deg: cfg.min_elev_deg,
        ..Default::default()
    };
    (constellation, stations, params)
}

/// Constellation + connectivity for a config.
pub fn build_schedule(cfg: &ExperimentConfig) -> (Constellation, ConnectivitySchedule) {
    let (constellation, stations, params) = connectivity_inputs(cfg);
    let sched = ConnectivitySchedule::compute(&constellation, &stations, cfg.n_steps, params);
    (constellation, sched)
}

/// Constellation + chunked connectivity stream for a config — the
/// streamed-engine counterpart of [`build_schedule`]: nothing horizon-sized
/// is materialized.
pub fn build_stream(cfg: &ExperimentConfig) -> (Constellation, ConnectivityStream) {
    let (constellation, stations, params) = connectivity_inputs(cfg);
    let stream = ConnectivityStream::new(
        &constellation,
        &stations,
        cfg.n_steps,
        params,
        ConnectivityStream::DEFAULT_CHUNK_LEN,
    );
    (constellation, stream)
}

/// IID or Non-IID partition per §4.1.
pub fn build_partition(
    cfg: &ExperimentConfig,
    dataset: &Dataset,
    constellation: &Constellation,
    rng: &mut Rng,
) -> Partition {
    match cfg.dist {
        DataDist::Iid => partition_iid(dataset.train.len(), cfg.n_sats, rng),
        DataDist::NonIid => {
            let horizon_s = cfg.n_steps as f64 * cfg.t0_s;
            let visits = cell_visits(constellation, horizon_s, 60.0);
            partition_noniid(dataset, &visits, rng)
        }
    }
}

/// Phase 1 of FedSpace (Figure 5): pretrain → sample → fit û.
/// Samples are cached as CSV under `cache_path` (if given) so repeated
/// experiment sweeps refit instantly.
pub fn build_utility_model(
    cfg: &ExperimentConfig,
    backend: &dyn SampleBackend,
    cache_path: Option<&str>,
    rng: &mut Rng,
) -> Result<UtilityModel> {
    let samples = if let Some(path) = cache_path.filter(|p| std::path::Path::new(p).exists()) {
        samples_from_csv(&std::fs::read_to_string(path)?)
            .with_context(|| format!("parsing cached utility samples {path}"))?
    } else {
        let rounds = (cfg.s_max * 3).max(12);
        let bank = pretrain_bank(backend, rounds, 8, cfg.alpha, rng)?;
        let samples =
            generate_samples(backend, &bank, cfg.utility_samples, cfg.s_max, 16, cfg.alpha, rng)?;
        if let Some(path) = cache_path {
            crate::metrics::write_file(path, &samples_to_csv(&samples))?;
        }
        samples
    };
    let mut u = UtilityModel::new(&cfg.regressor)?;
    u.fit(&samples.0, &samples.1);
    Ok(u)
}

fn engine_cfg(cfg: &ExperimentConfig, stop_at: Option<f64>) -> EngineConfig {
    EngineConfig {
        algorithm: cfg.algorithm,
        alpha: cfg.alpha,
        fedbuff_m: cfg.fedbuff_m,
        eval_every: cfg.eval_every,
        days_per_step: cfg.days_per_step(),
        stop_at_accuracy: stop_at,
        train_duration_slots: 1,
        seed: cfg.sim_seed,
        i0: cfg.i0,
        mode: cfg.engine_mode,
    }
}

fn make_planner(
    cfg: &ExperimentConfig,
    utility: UtilityModel,
) -> FedSpacePlanner {
    FedSpacePlanner::new(
        utility,
        SearchParams {
            i0: cfg.i0,
            n_min: cfg.n_min,
            n_max: cfg.n_max,
            n_search: cfg.n_search,
        },
        cfg.sim_seed ^ 0x5EED,
    )
}

/// Scheduler-level experiment on the analytic mock objective. Fast: used by
/// tests, the ablation bench and quick CLI iterations. Streamed-mode
/// configs route through a [`ConnectivityStream`] automatically.
pub fn run_mock_experiment(
    cfg: &ExperimentConfig,
    stop_at: Option<f64>,
) -> Result<ExperimentOutput> {
    if cfg.engine_mode == EngineMode::Streamed {
        let (_, stream) = build_stream(cfg);
        return run_mock_on_stream(cfg, &stream, stop_at);
    }
    let (_, sched) = build_schedule(cfg);
    run_mock_on_schedule(cfg, &sched, stop_at)
}

/// Mock trainer + optional FedSpace planner for one experiment config —
/// the wiring shared by the schedule-backed and stream-backed mock runs.
fn mock_parts(cfg: &ExperimentConfig) -> Result<(MockTrainer, Option<FedSpacePlanner>)> {
    crate::exec::set_default_parallelism(cfg.threads);
    let heterogeneity = match cfg.dist {
        DataDist::Iid => 0.1,
        DataDist::NonIid => 0.8,
    };
    let trainer = MockTrainer::new(32, cfg.n_sats, heterogeneity, cfg.data_seed);
    let planner = if cfg.algorithm == AlgorithmKind::FedSpace {
        let mut rng = Rng::new(cfg.sim_seed ^ 0xA11CE);
        let backend = MockBackend::new(32, cfg.data_seed);
        let utility = build_utility_model(cfg, &backend, None, &mut rng)?;
        Some(make_planner(cfg, utility))
    } else {
        None
    };
    Ok((trainer, planner))
}

/// [`run_mock_experiment`] over a caller-built schedule — scenario grid runs
/// compute the (expensive) connectivity once and sweep algorithms over it.
pub fn run_mock_on_schedule(
    cfg: &ExperimentConfig,
    sched: &ConnectivitySchedule,
    stop_at: Option<f64>,
) -> Result<ExperimentOutput> {
    run_mock_on_schedule_routed(cfg, sched, None, stop_at)
}

/// [`run_mock_on_schedule`] with an optional routed contact graph
/// (ADR-0005): scenario grids with ISLs route the schedule once and share
/// the graph across every algorithm, exactly like they share the schedule.
pub fn run_mock_on_schedule_routed(
    cfg: &ExperimentConfig,
    sched: &ConnectivitySchedule,
    graph: Option<&ContactGraph>,
    stop_at: Option<f64>,
) -> Result<ExperimentOutput> {
    anyhow::ensure!(
        sched.n_sats == cfg.n_sats,
        "schedule covers {} satellites but config says {}",
        sched.n_sats,
        cfg.n_sats
    );
    anyhow::ensure!(
        cfg.engine_mode != EngineMode::Streamed,
        "engine mode 'streamed' runs over a ConnectivityStream — use run_mock_on_stream"
    );
    let (trainer, planner) = mock_parts(cfg)?;
    let mut agg = CpuAggregator;
    let mut engine = Engine::new(sched, &trainer, &mut agg, engine_cfg(cfg, stop_at), planner)
        .with_contact_graph(graph);
    Ok(ExperimentOutput { result: engine.run()?, algorithm: cfg.algorithm, dist: cfg.dist })
}

/// [`run_mock_experiment`] over a caller-built connectivity stream — the
/// streamed engine mode's entry point; scenario grids share one stream
/// (each run walks it chunk by chunk, recycling two chunk buffers).
pub fn run_mock_on_stream(
    cfg: &ExperimentConfig,
    stream: &ConnectivityStream,
    stop_at: Option<f64>,
) -> Result<ExperimentOutput> {
    anyhow::ensure!(
        stream.n_sats() == cfg.n_sats,
        "stream covers {} satellites but config says {}",
        stream.n_sats(),
        cfg.n_sats
    );
    anyhow::ensure!(
        cfg.engine_mode == EngineMode::Streamed,
        "run_mock_on_stream requires engine mode 'streamed' (got {})",
        cfg.engine_mode.name()
    );
    let (trainer, planner) = mock_parts(cfg)?;
    let mut agg = CpuAggregator;
    let mut engine =
        Engine::new_streamed(stream, &trainer, &mut agg, engine_cfg(cfg, stop_at), planner);
    Ok(ExperimentOutput { result: engine.run()?, algorithm: cfg.algorithm, dist: cfg.dist })
}

/// Run a scenario's whole algorithm grid on the mock backend. Dense and
/// contact-list scenarios compute one schedule and share it across the
/// grid; streamed scenarios share the stream *generator* but each grid
/// entry re-derives the chunks while walking (that per-run compute is the
/// price of never materializing the horizon — pass a single algorithm for
/// time-capped runs like the CI mega smoke). Returns one
/// [`ExperimentOutput`] per grid entry, in grid order.
pub fn run_scenario(sc: &Scenario, stop_at: Option<f64>) -> Result<Vec<ExperimentOutput>> {
    sc.validate()?;
    if sc.engine_mode == EngineMode::Streamed {
        // ISLs (if any) ride inside the stream: chunks come out routed
        let (_, stream) = sc.build_stream();
        return sc
            .algorithms
            .iter()
            .map(|&alg| run_mock_on_stream(&sc.experiment_config(alg), &stream, stop_at))
            .collect();
    }
    let (constellation, sched) = sc.build_schedule();
    // one routed graph shared across the grid, like the schedule itself
    let graph = sc.build_contact_graph(&constellation, &sched);
    sc.algorithms
        .iter()
        .map(|&alg| {
            run_mock_on_schedule_routed(&sc.experiment_config(alg), &sched, graph.as_ref(), stop_at)
        })
        .collect()
}

/// PJRT sample backend: local updates and losses through the artifacts.
struct PjrtSampleBackend<'a> {
    rt: &'a ModelRuntime,
    dataset: &'a Dataset,
    eval_samples: usize,
    lr: f32,
}

impl SampleBackend for PjrtSampleBackend<'_> {
    fn d(&self) -> usize {
        self.rt.meta.d
    }

    fn init(&self, rng: &mut Rng) -> Vec<f32> {
        self.rt.init_params(rng)
    }

    fn local_delta(&self, w: &[f32], rng: &mut Rng) -> Result<Vec<f32>> {
        let m = &self.rt.meta;
        let n = m.e_steps * m.batch;
        let idx: Vec<usize> =
            (0..n).map(|_| rng.gen_range(0, self.dataset.train.len())).collect();
        let (xs, ys) = self.dataset.make_batch(&self.dataset.train, &idx);
        Ok(self.rt.local_train(w, &xs, &ys, self.lr)?.0)
    }

    fn loss(&self, w: &[f32]) -> Result<f64> {
        let m = &self.rt.meta;
        let eb = m.eval_batch;
        let n = self.eval_samples.min(self.dataset.val.len()) / eb * eb;
        let mut loss_sum = 0.0f64;
        for start in (0..n).step_by(eb) {
            let idx: Vec<usize> = (start..start + eb).collect();
            let (x, y) = self.dataset.make_batch(&self.dataset.val, &idx);
            loss_sum += self.rt.eval_batch(w, &x, &y)?.0 as f64;
        }
        Ok(loss_sum / n as f64)
    }
}

/// The full three-layer experiment: real dataset, PJRT local training, the
/// Pallas aggregation artifact on the GS hot path.
pub fn run_pjrt_experiment(
    cfg: &ExperimentConfig,
    eval_samples: usize,
    stop_at: Option<f64>,
) -> Result<ExperimentOutput> {
    let rt = ModelRuntime::load(&cfg.artifacts_dir, &cfg.model_size)?;
    let dataset = Dataset::generate(SynthConfig {
        n_train: cfg.n_train,
        n_val: cfg.n_val,
        noise_sigma: cfg.noise_sigma,
        seed: cfg.data_seed,
        ..Default::default()
    });
    // time axis: chunked stream in streamed mode, materialized schedule
    // otherwise — either way the constellation feeds the data partition
    let (constellation, sched, stream) = if cfg.engine_mode == EngineMode::Streamed {
        let (c, s) = build_stream(cfg);
        (c, None, Some(s))
    } else {
        let (c, s) = build_schedule(cfg);
        (c, Some(s), None)
    };
    let mut rng = Rng::new(cfg.sim_seed ^ 0xDA7A);
    let partition = build_partition(cfg, &dataset, &constellation, &mut rng);
    let trainer = PjrtTrainer::new(&rt, &dataset, &partition, cfg.lr, eval_samples);
    let planner = if cfg.algorithm == AlgorithmKind::FedSpace {
        let backend = PjrtSampleBackend { rt: &rt, dataset: &dataset, eval_samples, lr: cfg.lr };
        let cache = format!(
            "{}/utility_samples_{}.csv",
            cfg.artifacts_dir, cfg.model_size
        );
        let utility = build_utility_model(cfg, &backend, Some(&cache), &mut rng)?;
        Some(make_planner(cfg, utility))
    } else {
        None
    };
    let mut agg = PjrtAggregator { rt: &rt };
    let ecfg = engine_cfg(cfg, stop_at);
    let result = match (&sched, &stream) {
        (Some(s), _) => Engine::new(s, &trainer, &mut agg, ecfg, planner).run()?,
        (None, Some(st)) => Engine::new_streamed(st, &trainer, &mut agg, ecfg, planner).run()?,
        (None, None) => unreachable!("one time axis is always built"),
    };
    Ok(ExperimentOutput { result, algorithm: cfg.algorithm, dist: cfg.dist })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(alg: AlgorithmKind) -> ExperimentConfig {
        ExperimentConfig {
            n_sats: 8,
            n_steps: 48,
            algorithm: alg,
            fedbuff_m: 3,
            n_search: 50,
            utility_samples: 60,
            i0: 12,
            n_min: 2,
            n_max: 6,
            ..Default::default()
        }
    }

    #[test]
    fn mock_experiment_all_algorithms() {
        for alg in [
            AlgorithmKind::Sync,
            AlgorithmKind::Async,
            AlgorithmKind::FedBuff,
            AlgorithmKind::FedSpace,
        ] {
            let out = run_mock_experiment(&tiny_cfg(alg), None).unwrap();
            assert!(!out.result.trace.curve.points.is_empty(), "{alg:?}");
        }
    }

    #[test]
    fn streamed_mock_experiment_matches_dense() {
        let mut cfg = tiny_cfg(AlgorithmKind::FedBuff);
        let dense = run_mock_experiment(&cfg, None).unwrap();
        cfg.engine_mode = EngineMode::Streamed;
        let streamed = run_mock_experiment(&cfg, None).unwrap();
        crate::testing::assert_same_run(&dense.result, &streamed.result, "runner streamed");
    }

    #[test]
    fn run_scenario_streams_mega_builtins_scaled() {
        for name in ["walker-starlink-4408", "kuiper-3236"] {
            let sc = Scenario::builtin(name).unwrap().scaled(Some(10), Some(24));
            assert_eq!(sc.engine_mode, EngineMode::Streamed, "{name}");
            let outs = run_scenario(&sc, None).unwrap();
            assert_eq!(outs.len(), sc.algorithms.len(), "{name}");
            for out in &outs {
                assert!(!out.result.trace.curve.points.is_empty(), "{name}");
            }
        }
    }

    #[test]
    fn run_scenario_routes_isl_builtins() {
        // streamed (as declared) and dense (shared ContactGraph) both run
        let mut sc = Scenario::builtin("isl-iridium-66").unwrap().scaled(Some(12), Some(24));
        sc.algorithms = vec![AlgorithmKind::FedBuff];
        let streamed = run_scenario(&sc, None).unwrap();
        assert_eq!(streamed.len(), 1);
        let mut dense = sc.clone();
        dense.engine_mode = EngineMode::Dense;
        let douts = run_scenario(&dense, None).unwrap();
        crate::testing::assert_same_run(
            &streamed[0].result,
            &douts[0].result,
            "isl-iridium-66 streamed vs dense",
        );
    }

    #[test]
    fn run_scenario_sweeps_whole_grid() {
        let sc = Scenario::builtin("paper-fig7").unwrap().scaled(Some(8), Some(48));
        let outs = run_scenario(&sc, None).unwrap();
        assert_eq!(outs.len(), sc.algorithms.len());
        for (out, &alg) in outs.iter().zip(&sc.algorithms) {
            assert_eq!(out.algorithm, alg);
            assert!(!out.result.trace.curve.points.is_empty(), "{alg:?}");
        }
    }

    #[test]
    fn noniid_partition_built_from_overflights() {
        let cfg = ExperimentConfig {
            n_sats: 10,
            n_steps: 24,
            dist: DataDist::NonIid,
            n_train: 500,
            ..Default::default()
        };
        let dataset = Dataset::generate(SynthConfig {
            n_train: cfg.n_train,
            n_val: 16,
            seed: cfg.data_seed,
            ..Default::default()
        });
        let (constellation, _) = build_schedule(&cfg);
        let mut rng = Rng::new(0);
        let p = build_partition(&cfg, &dataset, &constellation, &mut rng);
        assert_eq!(p.n_sats(), 10);
        assert!(p.total() <= 500);
        assert!(p.total() > 0);
    }
}
