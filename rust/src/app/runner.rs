//! Experiment runner: wires config → substrates → engine, for both the
//! mock (scheduler-level) and PJRT (full three-layer) backends.

use crate::cfg::{AlgorithmKind, DataDist, EngineMode, ExperimentConfig, Scenario};
use crate::connectivity::{
    ConnectivityParams, ConnectivitySchedule, ConnectivityStream, ContactGraph, IslTopology,
};
use crate::data::{
    partition::cell_visits, partition_iid, partition_noniid, Dataset, Partition, SynthConfig,
};
use crate::fl::{
    CpuAggregator, FederationSpec, Offer, PendingUpload, ServeCore, UploadRouting,
};
use crate::orbit::{planet_ground_stations, planet_labs_like, Constellation};
use crate::rng::Rng;
use crate::runtime::{ModelRuntime, PjrtAggregator};
use crate::sched::{
    generate_samples, pretrain_bank, samples_from_csv, samples_to_csv, FedSpacePlanner,
    MockBackend, SampleBackend, SearchParams, UtilityModel,
};
use crate::sim::{
    ArtifactSink, Engine, EngineConfig, MockTrainer, PjrtTrainer, RunArtifact, RunEvent,
    RunResult, TraceSink, UploadOutcome,
};
use anyhow::{ensure, Context, Result};

/// A multi-gateway federation to run under (ADR-0006): the spec and an
/// upload-routing table built against the *same* station network — for
/// scenario runs that is the scenario's network, for the config path the
/// runner's planet12. Passed explicitly so `Scenario::experiment_config`
/// stays standalone-runnable instead of smuggling a network-bound spec
/// through `ExperimentConfig`.
#[derive(Clone, Copy)]
pub struct FederationRun<'a> {
    /// Gateway names, station map, reconcile policy.
    pub spec: &'a FederationSpec,
    /// Per-contact upload routing for the spec's station network.
    pub routing: &'a UploadRouting,
}

impl<'a> FederationRun<'a> {
    /// Pair a spec with its routing table (`None` routing — the
    /// single-gateway case — yields `None`): the one place the pairing
    /// happens, so a spec can't silently ride with another network's
    /// table.
    pub fn of(spec: &'a FederationSpec, routing: Option<&'a UploadRouting>) -> Option<Self> {
        routing.map(|routing| FederationRun { spec, routing })
    }
}

/// Everything a bench/figure needs from one run.
pub struct ExperimentOutput {
    /// Trace, curve and final model of the run.
    pub result: RunResult,
    /// Algorithm that produced it.
    pub algorithm: AlgorithmKind,
    /// Data distribution it ran under.
    pub dist: DataDist,
}

/// Constellation + station network + link params for a config — the one
/// place the config's connectivity inputs are interpreted, so the dense
/// and streamed paths can never diverge on them.
fn connectivity_inputs(
    cfg: &ExperimentConfig,
) -> (Constellation, Vec<crate::orbit::GroundStation>, ConnectivityParams) {
    crate::exec::set_default_parallelism(cfg.threads);
    let constellation = planet_labs_like(cfg.n_sats, cfg.constellation_seed);
    let stations = planet_ground_stations();
    let params = ConnectivityParams {
        t0_s: cfg.t0_s,
        min_elev_deg: cfg.min_elev_deg,
        ..Default::default()
    };
    (constellation, stations, params)
}

/// Constellation + connectivity for a config. With a `[link]` byte budget
/// the schedule also records pass durations (ADR-0008); contact membership
/// is identical either way.
pub fn build_schedule(cfg: &ExperimentConfig) -> (Constellation, ConnectivitySchedule) {
    let (constellation, stations, params) = connectivity_inputs(cfg);
    let sched = if cfg.link.capacity_enabled() {
        ConnectivitySchedule::compute_with_durations(&constellation, &stations, cfg.n_steps, params)
    } else {
        ConnectivitySchedule::compute(&constellation, &stations, cfg.n_steps, params)
    };
    (constellation, sched)
}

/// The config path's ISL routing model (`[isl]` on `ExperimentConfig`,
/// ROADMAP item): `None` when disabled. The planet-labs constellation
/// always carries plane metadata, and `ExperimentConfig::validate` bounds
/// the spec, so construction cannot fail for validated configs.
fn cfg_isl_topology(cfg: &ExperimentConfig, constellation: &Constellation) -> Option<IslTopology> {
    if !cfg.isl.enabled() {
        return None;
    }
    Some(
        IslTopology::new(constellation, cfg.isl.params(cfg.t0_s))
            .expect("planet-labs constellations always carry plane metadata"),
    )
}

/// The config path's upload-routing table (ADR-0006): built against the
/// planet12 network the config path always links with. `None` for the
/// single-gateway default. Errors when the station map doesn't cover
/// planet12 — the half of federation validation only the runner can check.
pub fn build_upload_routing(cfg: &ExperimentConfig) -> Result<Option<UploadRouting>> {
    if cfg.federation.is_single() {
        return Ok(None);
    }
    let (constellation, stations, params) = connectivity_inputs(cfg);
    cfg.federation.validate(stations.len())?;
    Ok(Some(UploadRouting::build(
        &constellation,
        &stations,
        cfg.n_steps,
        &params,
        &cfg.federation.stations,
    )))
}

/// Constellation + chunked connectivity stream for a config — the
/// streamed-engine counterpart of [`build_schedule`]: nothing horizon-sized
/// is materialized. Carries the config's ISL topology when `[isl]` is on.
pub fn build_stream(cfg: &ExperimentConfig) -> (Constellation, ConnectivityStream) {
    let (constellation, stations, params) = connectivity_inputs(cfg);
    let mut stream = ConnectivityStream::new(
        &constellation,
        &stations,
        cfg.n_steps,
        params,
        ConnectivityStream::DEFAULT_CHUNK_LEN,
    );
    if let Some(topology) = cfg_isl_topology(cfg, &constellation) {
        stream = stream.with_isl(topology);
    }
    if cfg.link.capacity_enabled() {
        // validate() already rejects the ISL combination
        stream = stream.with_durations();
    }
    (constellation, stream)
}

/// IID or Non-IID partition per §4.1.
pub fn build_partition(
    cfg: &ExperimentConfig,
    dataset: &Dataset,
    constellation: &Constellation,
    rng: &mut Rng,
) -> Partition {
    match cfg.dist {
        DataDist::Iid => partition_iid(dataset.train.len(), cfg.n_sats, rng),
        DataDist::NonIid => {
            let horizon_s = cfg.n_steps as f64 * cfg.t0_s;
            let visits = cell_visits(constellation, horizon_s, 60.0);
            partition_noniid(dataset, &visits, rng)
        }
    }
}

/// Phase 1 of FedSpace (Figure 5): pretrain → sample → fit û.
/// Samples are cached as CSV under `cache_path` (if given) so repeated
/// experiment sweeps refit instantly.
pub fn build_utility_model(
    cfg: &ExperimentConfig,
    backend: &dyn SampleBackend,
    cache_path: Option<&str>,
    rng: &mut Rng,
) -> Result<UtilityModel> {
    let samples = if let Some(path) = cache_path.filter(|p| std::path::Path::new(p).exists()) {
        samples_from_csv(&std::fs::read_to_string(path)?)
            .with_context(|| format!("parsing cached utility samples {path}"))?
    } else {
        let rounds = (cfg.s_max * 3).max(12);
        let bank = pretrain_bank(backend, rounds, 8, cfg.alpha, rng)?;
        let samples =
            generate_samples(backend, &bank, cfg.utility_samples, cfg.s_max, 16, cfg.alpha, rng)?;
        if let Some(path) = cache_path {
            crate::metrics::write_file(path, &samples_to_csv(&samples))?;
        }
        samples
    };
    let mut u = UtilityModel::new(&cfg.regressor)?;
    u.fit(&samples.0, &samples.1);
    Ok(u)
}

fn engine_cfg(cfg: &ExperimentConfig, stop_at: Option<f64>) -> EngineConfig {
    EngineConfig {
        algorithm: cfg.algorithm,
        alpha: cfg.alpha,
        fedbuff_m: cfg.fedbuff_m,
        eval_every: cfg.eval_every,
        days_per_step: cfg.days_per_step(),
        stop_at_accuracy: stop_at,
        train_duration_slots: 1,
        seed: cfg.sim_seed,
        i0: cfg.i0,
        mode: cfg.engine_mode,
        attack: cfg.attack.clone(),
        link: cfg.link.clone(),
        record_events: cfg.events.record,
    }
}

/// Planner random-search stream tag (ADR-0002): independent deterministic
/// RNG streams derive as `sim_seed ^ <NAME>_STREAM`, and `fedspace lint`'s
/// `rng-stream` rule checks all `*_STREAM` values are pairwise distinct
/// numerically across the crate ([`crate::fl::CODEC_STREAM`] and
/// [`crate::sim::adversary::ADVERSARY_STREAM`] live with their
/// subsystems). The values predate the names — changing one would shift
/// every seeded trace.
pub const PLANNER_STREAM: u64 = 0x5EED;
/// Utility-model pretrain/sample stream (the phase-1 pipeline).
pub const UTILITY_STREAM: u64 = 0xA11CE;
/// Serving-replay upload-synthesis stream (`serve` / `loadgen`).
pub const LOADGEN_STREAM: u64 = 0x10AD;
/// Mock-data partition stream (PJRT dataset sharding).
pub const DATA_STREAM: u64 = 0xDA7A;

/// Seed of gateway `g`'s planner search RNG. Gateway 0 keeps the legacy
/// derivation exactly (single-gateway bit-identity); higher gateways get
/// independent, deterministic streams.
fn planner_seed(sim_seed: u64, g: usize) -> u64 {
    let base = sim_seed ^ PLANNER_STREAM;
    if g == 0 {
        base
    } else {
        base ^ (g as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
    }
}

/// One FedSpace planner per gateway (ADR-0006): the fitted û is shared
/// (cloned) across gateways — phase 1 is offline and gateway-independent —
/// while each planner draws from its own seeded search RNG.
fn make_planners(
    cfg: &ExperimentConfig,
    utility: UtilityModel,
    n_gateways: usize,
) -> Vec<FedSpacePlanner> {
    let params = SearchParams {
        i0: cfg.i0,
        n_min: cfg.n_min,
        n_max: cfg.n_max,
        n_search: cfg.n_search,
    };
    (0..n_gateways)
        .map(|gi| {
            FedSpacePlanner::new(utility.clone(), params.clone(), planner_seed(cfg.sim_seed, gi))
        })
        .collect()
}

/// Scheduler-level experiment on the analytic mock objective. Fast: used by
/// tests, the ablation bench and quick CLI iterations. Streamed-mode
/// configs route through a [`ConnectivityStream`] automatically; `[isl]`
/// configs route through a shared [`ContactGraph`] (or the routed stream),
/// and multi-gateway `[federation]` configs build their planet12 upload
/// routing here.
pub fn run_mock_experiment(
    cfg: &ExperimentConfig,
    stop_at: Option<f64>,
) -> Result<ExperimentOutput> {
    let routing = build_upload_routing(cfg)?;
    let fed = FederationRun::of(&cfg.federation, routing.as_ref());
    if cfg.engine_mode == EngineMode::Streamed {
        let (_, stream) = build_stream(cfg);
        return run_mock_on_stream_fed(cfg, &stream, fed, stop_at);
    }
    let (constellation, sched) = build_schedule(cfg);
    let graph = cfg_isl_topology(cfg, &constellation).map(|t| ContactGraph::build(&t, &sched));
    run_mock_on_schedule_fed(cfg, &sched, graph.as_ref(), fed, stop_at)
}

/// Mock trainer + per-gateway FedSpace planners for one experiment config —
/// the wiring shared by the schedule-backed and stream-backed mock runs.
/// The planner vec is empty for non-FedSpace algorithms and has exactly
/// one entry per gateway otherwise.
fn mock_parts(
    cfg: &ExperimentConfig,
    n_gateways: usize,
) -> Result<(MockTrainer, Vec<FedSpacePlanner>)> {
    crate::exec::set_default_parallelism(cfg.threads);
    let heterogeneity = match cfg.dist {
        DataDist::Iid => 0.1,
        DataDist::NonIid => 0.8,
    };
    let trainer = MockTrainer::new(32, cfg.n_sats, heterogeneity, cfg.data_seed);
    let planners = if cfg.algorithm == AlgorithmKind::FedSpace {
        let mut rng = Rng::new(cfg.sim_seed ^ UTILITY_STREAM);
        let backend = MockBackend::new(32, cfg.data_seed);
        let utility = build_utility_model(cfg, &backend, None, &mut rng)?;
        make_planners(cfg, utility, n_gateways)
    } else {
        Vec::new()
    };
    Ok((trainer, planners))
}

/// [`run_mock_experiment`] over a caller-built schedule — scenario grid runs
/// compute the (expensive) connectivity once and sweep algorithms over it.
pub fn run_mock_on_schedule(
    cfg: &ExperimentConfig,
    sched: &ConnectivitySchedule,
    stop_at: Option<f64>,
) -> Result<ExperimentOutput> {
    run_mock_on_schedule_fed(cfg, sched, None, None, stop_at)
}

/// [`run_mock_on_schedule`] with an optional routed contact graph
/// (ADR-0005): scenario grids with ISLs route the schedule once and share
/// the graph across every algorithm, exactly like they share the schedule.
pub fn run_mock_on_schedule_routed(
    cfg: &ExperimentConfig,
    sched: &ConnectivitySchedule,
    graph: Option<&ContactGraph>,
    stop_at: Option<f64>,
) -> Result<ExperimentOutput> {
    run_mock_on_schedule_fed(cfg, sched, graph, None, stop_at)
}

/// The full-form schedule-backed mock run (ADR-0005 + ADR-0006): optional
/// shared contact graph and optional shared [`FederationRun`]. When `fed`
/// is `Some`, its spec governs the run (built by the scenario against *its*
/// station network, or lifted from `cfg.federation` + planet12 routing by
/// [`run_mock_experiment`]); when `None`, `cfg.federation` must be the
/// single-gateway default — the narrower entry points refuse multi-gateway
/// configs instead of silently collapsing them to one gateway.
pub fn run_mock_on_schedule_fed(
    cfg: &ExperimentConfig,
    sched: &ConnectivitySchedule,
    graph: Option<&ContactGraph>,
    fed: Option<FederationRun<'_>>,
    stop_at: Option<f64>,
) -> Result<ExperimentOutput> {
    ensure!(
        sched.n_sats == cfg.n_sats,
        "schedule covers {} satellites but config says {}",
        sched.n_sats,
        cfg.n_sats
    );
    ensure!(
        cfg.engine_mode != EngineMode::Streamed,
        "engine mode 'streamed' runs over a ConnectivityStream — use run_mock_on_stream"
    );
    ensure!(
        fed.is_some() || cfg.federation.is_single(),
        "multi-gateway config without a FederationRun — go through \
         run_mock_experiment, or pass the spec + routing explicitly"
    );
    let spec = fed.map_or(&cfg.federation, |f| f.spec);
    let (trainer, planners) = mock_parts(cfg, spec.n_gateways())?;
    // [robust] picks the Eq.-4 aggregator family; the default is the plain
    // CpuAggregator, bit for bit (ADR-0007)
    let mut agg = cfg.robust.make();
    let mut engine = Engine::builder()
        .schedule(sched)
        .trainer(&trainer)
        .aggregator(&mut *agg)
        .config(engine_cfg(cfg, stop_at))
        .planners(planners)
        .contact_graph(graph)
        .federation(spec, fed.map(|f| f.routing))
        .build();
    Ok(ExperimentOutput { result: engine.run()?, algorithm: cfg.algorithm, dist: cfg.dist })
}

/// [`run_mock_experiment`] over a caller-built connectivity stream — the
/// streamed engine mode's entry point; scenario grids share one stream
/// (each run walks it chunk by chunk, recycling two chunk buffers).
pub fn run_mock_on_stream(
    cfg: &ExperimentConfig,
    stream: &ConnectivityStream,
    stop_at: Option<f64>,
) -> Result<ExperimentOutput> {
    run_mock_on_stream_fed(cfg, stream, None, stop_at)
}

/// The full-form stream-backed mock run: [`run_mock_on_stream`] plus the
/// optional shared [`FederationRun`] of a multi-gateway federation
/// (ADR-0006; same contract as [`run_mock_on_schedule_fed`]).
pub fn run_mock_on_stream_fed(
    cfg: &ExperimentConfig,
    stream: &ConnectivityStream,
    fed: Option<FederationRun<'_>>,
    stop_at: Option<f64>,
) -> Result<ExperimentOutput> {
    ensure!(
        stream.n_sats() == cfg.n_sats,
        "stream covers {} satellites but config says {}",
        stream.n_sats(),
        cfg.n_sats
    );
    ensure!(
        cfg.engine_mode == EngineMode::Streamed,
        "run_mock_on_stream requires engine mode 'streamed' (got {})",
        cfg.engine_mode.name()
    );
    ensure!(
        fed.is_some() || cfg.federation.is_single(),
        "multi-gateway config without a FederationRun — go through \
         run_mock_experiment, or pass the spec + routing explicitly"
    );
    let spec = fed.map_or(&cfg.federation, |f| f.spec);
    let (trainer, planners) = mock_parts(cfg, spec.n_gateways())?;
    let mut agg = cfg.robust.make();
    let mut engine = Engine::builder()
        .stream(stream)
        .trainer(&trainer)
        .aggregator(&mut *agg)
        .config(engine_cfg(cfg, stop_at))
        .planners(planners)
        .federation(spec, fed.map(|f| f.routing))
        .build();
    Ok(ExperimentOutput { result: engine.run()?, algorithm: cfg.algorithm, dist: cfg.dist })
}

/// Run a scenario's whole algorithm grid on the mock backend. Dense and
/// contact-list scenarios compute one schedule and share it across the
/// grid; streamed scenarios share the stream *generator* but each grid
/// entry re-derives the chunks while walking (that per-run compute is the
/// price of never materializing the horizon — pass a single algorithm for
/// time-capped runs like the CI mega smoke). Returns one
/// [`ExperimentOutput`] per grid entry, in grid order.
pub fn run_scenario(sc: &Scenario, stop_at: Option<f64>) -> Result<Vec<ExperimentOutput>> {
    sc.validate()?;
    if sc.engine_mode == EngineMode::Streamed {
        // ISLs (if any) ride inside the stream: chunks come out routed;
        // the federation (multi-gateway only) is shared across the grid
        // like the stream generator
        let (constellation, stream) = sc.build_stream();
        let routing = sc.build_upload_routing(&constellation);
        let fed = FederationRun::of(&sc.federation, routing.as_ref());
        return sc
            .algorithms
            .iter()
            .map(|&alg| run_mock_on_stream_fed(&sc.experiment_config(alg), &stream, fed, stop_at))
            .collect();
    }
    // schedule + routing out of ONE fused visibility sweep; one routed
    // graph + one federation shared across the grid, like the schedule
    let (constellation, sched, routing) = sc.build_schedule_routed();
    let graph = sc.build_contact_graph(&constellation, &sched);
    let fed = FederationRun::of(&sc.federation, routing.as_ref());
    sc.algorithms
        .iter()
        .map(|&alg| {
            let cfg = sc.experiment_config(alg);
            run_mock_on_schedule_fed(&cfg, &sched, graph.as_ref(), fed, stop_at)
        })
        .collect()
}

/// Options of one serving replay ([`run_loadgen`]): pacing and whether the
/// recorded event stream rides into the artifact.
#[derive(Clone, Copy, Debug)]
pub struct LoadgenOpts {
    /// Wall-clock seconds to spend per replayed slot (`0` = replay as fast
    /// as possible — the throughput-measurement mode). The `serve`
    /// subcommand paces; `loadgen` does not.
    pub pace_s: f64,
    /// Keep the full event stream in the returned artifact (the `--json`
    /// bundle needs it; human-table runs can skip the memory).
    pub record_events: bool,
}

impl Default for LoadgenOpts {
    fn default() -> Self {
        LoadgenOpts { pace_s: 0.0, record_events: true }
    }
}

/// What one serving replay measured (ADR-0010). Model-state fields are
/// deterministic per (scenario, seed); the wall-clock fields are not —
/// exactly the split `RunEvent::is_deterministic` encodes.
pub struct LoadgenReport {
    /// The run-artifact bundle entry (schema `fedspace-run-artifact-v1`).
    pub artifact: RunArtifact,
    /// Uploads accepted into gateway queues.
    pub uploads: u64,
    /// Offers backpressured by a full queue (every one was retried).
    pub deferred_offers: u64,
    /// Uploads discarded by ingest validation.
    pub rejected: u64,
    /// Serving ticks (drains) executed.
    pub ticks: usize,
    /// Global rounds the federation completed.
    pub final_round: usize,
    /// Cross-gateway merges performed.
    pub reconciles: usize,
    /// Power-of-two queue-depth histogram (bucket 0 = drained-empty).
    pub depth_hist: Vec<u64>,
    /// Wall-clock seconds the replay took.
    pub wall_s: f64,
    /// Sustained accepted-upload rate.
    pub uploads_per_s: f64,
    /// Median per-tick drain+aggregate latency, ms.
    pub p50_ms: f64,
    /// 99th-percentile per-tick drain+aggregate latency, ms.
    pub p99_ms: f64,
}

/// Replay a scenario's contact trace into the serving front end
/// (ADR-0010): every schedule contact becomes one seeded mock upload
/// offered to its routed gateway's bounded queue, one schedule step is one
/// serving tick, and deferred offers retry ahead of newer arrivals so no
/// gateway's stream reorders. After the trace, queues flush to empty.
/// Reports sustained uploads/sec and p50/p99 tick latency; the final model
/// and the deterministic event stream depend only on (scenario, seed).
pub fn run_loadgen(sc: &Scenario, opts: &LoadgenOpts) -> Result<LoadgenReport> {
    use std::collections::VecDeque;
    use std::time::Instant;
    sc.validate()?;
    sc.serve.validate()?;
    let (_constellation, sched, routing) = sc.build_schedule_routed();
    let cfg = sc.experiment_config(sc.algorithms[0]);
    crate::exec::set_default_parallelism(cfg.threads);
    let dim = 32usize; // mock-trainer model width; serving is backend-mock-grade
    let mut rng = Rng::new(cfg.sim_seed ^ LOADGEN_STREAM);
    let mut serve = ServeCore::new(&sc.federation, &sc.serve, vec![0.0; dim], cfg.alpha);
    let n_gateways = sc.federation.n_gateways();
    let mut agg = CpuAggregator;
    let mut sink = ArtifactSink::new();
    sink.emit(&RunEvent::RunStart { n_sats: sched.n_sats, n_steps: sched.n_steps(), n_gateways });
    // deferred offers park here and re-offer before any newer upload — the
    // FIFO-per-gateway guarantee the backpressure test gates
    let mut retry: VecDeque<(usize, PendingUpload)> = VecDeque::new();
    let mut latencies_ms: Vec<f64> = Vec::new();
    // lint: allow(wall-clock): loadgen throughput reporting; ServeReport is identity-exempt
    let started = Instant::now();
    let offer = |serve: &mut ServeCore,
                     retry: &mut VecDeque<(usize, PendingUpload)>,
                     sink: &mut ArtifactSink,
                     step: usize,
                     g: usize,
                     up: PendingUpload| {
        let origin = up.sat;
        match serve.offer(g, up) {
            Offer::Accepted => sink.emit(&RunEvent::Upload {
                step,
                origin,
                gateway: g,
                hops: 0,
                bytes: 0,
                outcome: UploadOutcome::Delivered,
                injected: false,
                corrupted: false,
            }),
            Offer::Deferred(up) => {
                sink.emit(&RunEvent::Upload {
                    step,
                    origin,
                    gateway: 0,
                    hops: 0,
                    bytes: 0,
                    outcome: UploadOutcome::Deferred,
                    injected: false,
                    corrupted: false,
                });
                retry.push_back((g, up));
            }
        }
    };
    for i in 0..sched.n_steps() {
        // lint: allow(wall-clock): wall pacing of the replay tick (ADR-0010)
        let tick_started = Instant::now();
        for _ in 0..retry.len() {
            let (g, up) = retry.pop_front().expect("counted");
            offer(&mut serve, &mut retry, &mut sink, i, g, up);
        }
        for &sat in sched.sats_at(i) {
            let grad: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 0.1)).collect();
            let up = PendingUpload {
                sat,
                grad: grad.into(),
                base_round: serve.core().round(),
                n_samples: 1 + sat % 5,
            };
            let g = routing.as_ref().map_or(0, |r| r.gateway_for(i, sat, 0));
            offer(&mut serve, &mut retry, &mut sink, i, g, up);
        }
        // lint: allow(wall-clock): drain latency feeds the p50/p99 report, not the trace
        let drain_started = Instant::now();
        serve.drain(&mut agg, &mut sink)?;
        latencies_ms.push(drain_started.elapsed().as_secs_f64() * 1e3);
        if opts.pace_s > 0.0 {
            let left = opts.pace_s - tick_started.elapsed().as_secs_f64();
            if left > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(left));
            }
        }
    }
    // flush: the trace is over, drain until every queue (and the retry
    // park) is empty — backpressure defers, it never strands an upload
    let mut flush_guard = serve.accepted() + serve.deferred() + 16;
    while !retry.is_empty() || (0..n_gateways).any(|g| serve.queue_depth(g) > 0) {
        ensure!(flush_guard > 0, "serving flush failed to converge (batch too small?)");
        flush_guard -= 1;
        let step = sched.n_steps();
        for _ in 0..retry.len() {
            let (g, up) = retry.pop_front().expect("counted");
            offer(&mut serve, &mut retry, &mut sink, step, g, up);
        }
        // lint: allow(wall-clock): drain latency feeds the p50/p99 report, not the trace
        let drain_started = Instant::now();
        serve.drain(&mut agg, &mut sink)?;
        latencies_ms.push(drain_started.elapsed().as_secs_f64() * 1e3);
    }
    let wall_s = started.elapsed().as_secs_f64();
    let uploads = serve.accepted();
    let uploads_per_s = uploads as f64 / wall_s.max(1e-9);
    let p50_ms = crate::fl::serve::percentile(&latencies_ms, 50.0);
    let p99_ms = crate::fl::serve::percentile(&latencies_ms, 99.0);
    sink.emit(&RunEvent::ServeReport { uploads, wall_s, uploads_per_s, p50_ms, p99_ms });
    let mut trace = crate::sim::RunTrace::default();
    for e in &sink.events {
        TraceSink::apply(&mut trace, e);
    }
    let final_round = serve.core().round();
    let reconciles = serve.core().reconciles;
    Ok(LoadgenReport {
        artifact: RunArtifact {
            scenario: sc.name.clone(),
            algorithm: "loadgen".into(),
            engine: "serve".into(),
            n_sats: sched.n_sats,
            n_steps: sched.n_steps(),
            final_round,
            days_to_target: None,
            trace,
            events: if opts.record_events { sink.events } else { Vec::new() },
        },
        uploads,
        deferred_offers: serve.deferred(),
        rejected: serve.rejected(),
        ticks: serve.ticks(),
        final_round,
        reconciles,
        depth_hist: serve.depth_hist().to_vec(),
        wall_s,
        uploads_per_s,
        p50_ms,
        p99_ms,
    })
}

/// PJRT sample backend: local updates and losses through the artifacts.
struct PjrtSampleBackend<'a> {
    rt: &'a ModelRuntime,
    dataset: &'a Dataset,
    eval_samples: usize,
    lr: f32,
}

impl SampleBackend for PjrtSampleBackend<'_> {
    fn d(&self) -> usize {
        self.rt.meta.d
    }

    fn init(&self, rng: &mut Rng) -> Vec<f32> {
        self.rt.init_params(rng)
    }

    fn local_delta(&self, w: &[f32], rng: &mut Rng) -> Result<Vec<f32>> {
        let m = &self.rt.meta;
        let n = m.e_steps * m.batch;
        let idx: Vec<usize> =
            (0..n).map(|_| rng.gen_range(0, self.dataset.train.len())).collect();
        let (xs, ys) = self.dataset.make_batch(&self.dataset.train, &idx);
        Ok(self.rt.local_train(w, &xs, &ys, self.lr)?.0)
    }

    fn loss(&self, w: &[f32]) -> Result<f64> {
        let m = &self.rt.meta;
        let eb = m.eval_batch;
        let n = self.eval_samples.min(self.dataset.val.len()) / eb * eb;
        let mut loss_sum = 0.0f64;
        for start in (0..n).step_by(eb) {
            let idx: Vec<usize> = (start..start + eb).collect();
            let (x, y) = self.dataset.make_batch(&self.dataset.val, &idx);
            loss_sum += self.rt.eval_batch(w, &x, &y)?.0 as f64;
        }
        Ok(loss_sum / n as f64)
    }
}

/// The full three-layer experiment: real dataset, PJRT local training, the
/// Pallas aggregation artifact on the GS hot path.
pub fn run_pjrt_experiment(
    cfg: &ExperimentConfig,
    eval_samples: usize,
    stop_at: Option<f64>,
) -> Result<ExperimentOutput> {
    ensure!(
        cfg.robust.is_default(),
        "[robust] aggregators run on the CPU Eq.-4 path only — the PJRT path \
         aggregates through the Pallas artifact (use the mock backend for \
         robust-aggregation studies)"
    );
    let rt = ModelRuntime::load(&cfg.artifacts_dir, &cfg.model_size)?;
    let dataset = Dataset::generate(SynthConfig {
        n_train: cfg.n_train,
        n_val: cfg.n_val,
        noise_sigma: cfg.noise_sigma,
        seed: cfg.data_seed,
        ..Default::default()
    });
    // time axis: chunked stream in streamed mode, materialized schedule
    // otherwise — either way the constellation feeds the data partition.
    // `[isl]` rides inside the stream / a routed graph, `[federation]`
    // builds its planet12 routing table (ADR-0005/0006) — the PJRT path
    // carries the full topology surface of the mock path.
    let routing = build_upload_routing(cfg)?;
    let (constellation, sched, stream) = if cfg.engine_mode == EngineMode::Streamed {
        let (c, s) = build_stream(cfg);
        (c, None, Some(s))
    } else {
        let (c, s) = build_schedule(cfg);
        (c, Some(s), None)
    };
    let graph = match &sched {
        Some(s) => cfg_isl_topology(cfg, &constellation).map(|t| ContactGraph::build(&t, s)),
        None => None,
    };
    let mut rng = Rng::new(cfg.sim_seed ^ DATA_STREAM);
    let partition = build_partition(cfg, &dataset, &constellation, &mut rng);
    let trainer = PjrtTrainer::new(&rt, &dataset, &partition, cfg.lr, eval_samples);
    let planners = if cfg.algorithm == AlgorithmKind::FedSpace {
        let backend = PjrtSampleBackend { rt: &rt, dataset: &dataset, eval_samples, lr: cfg.lr };
        let cache = format!(
            "{}/utility_samples_{}.csv",
            cfg.artifacts_dir, cfg.model_size
        );
        let utility = build_utility_model(cfg, &backend, Some(&cache), &mut rng)?;
        make_planners(cfg, utility, cfg.federation.n_gateways())
    } else {
        Vec::new()
    };
    let mut agg = PjrtAggregator { rt: &rt };
    let ecfg = engine_cfg(cfg, stop_at);
    let result = match (&sched, &stream) {
        (Some(s), _) => Engine::builder()
            .schedule(s)
            .trainer(&trainer)
            .aggregator(&mut agg)
            .config(ecfg)
            .planners(planners)
            .contact_graph(graph.as_ref())
            .federation(&cfg.federation, routing.as_ref())
            .build()
            .run()?,
        (None, Some(st)) => Engine::builder()
            .stream(st)
            .trainer(&trainer)
            .aggregator(&mut agg)
            .config(ecfg)
            .planners(planners)
            .federation(&cfg.federation, routing.as_ref())
            .build()
            .run()?,
        (None, None) => unreachable!("one time axis is always built"),
    };
    Ok(ExperimentOutput { result, algorithm: cfg.algorithm, dist: cfg.dist })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(alg: AlgorithmKind) -> ExperimentConfig {
        ExperimentConfig {
            n_sats: 8,
            n_steps: 48,
            algorithm: alg,
            fedbuff_m: 3,
            n_search: 50,
            utility_samples: 60,
            i0: 12,
            n_min: 2,
            n_max: 6,
            ..Default::default()
        }
    }

    #[test]
    fn mock_experiment_all_algorithms() {
        for alg in [
            AlgorithmKind::Sync,
            AlgorithmKind::Async,
            AlgorithmKind::FedBuff,
            AlgorithmKind::FedSpace,
        ] {
            let out = run_mock_experiment(&tiny_cfg(alg), None).unwrap();
            assert!(!out.result.trace.curve.points.is_empty(), "{alg:?}");
        }
    }

    #[test]
    fn streamed_mock_experiment_matches_dense() {
        let mut cfg = tiny_cfg(AlgorithmKind::FedBuff);
        let dense = run_mock_experiment(&cfg, None).unwrap();
        cfg.engine_mode = EngineMode::Streamed;
        let streamed = run_mock_experiment(&cfg, None).unwrap();
        crate::testing::assert_same_run(&dense.result, &streamed.result, "runner streamed");
    }

    #[test]
    fn run_scenario_streams_mega_builtins_scaled() {
        for name in ["walker-starlink-4408", "kuiper-3236"] {
            let sc = Scenario::builtin(name).unwrap().scaled(Some(10), Some(24));
            assert_eq!(sc.engine_mode, EngineMode::Streamed, "{name}");
            let outs = run_scenario(&sc, None).unwrap();
            assert_eq!(outs.len(), sc.algorithms.len(), "{name}");
            for out in &outs {
                assert!(!out.result.trace.curve.points.is_empty(), "{name}");
            }
        }
    }

    #[test]
    fn run_scenario_routes_isl_builtins() {
        // streamed (as declared) and dense (shared ContactGraph) both run
        let mut sc = Scenario::builtin("isl-iridium-66").unwrap().scaled(Some(12), Some(24));
        sc.algorithms = vec![AlgorithmKind::FedBuff];
        let streamed = run_scenario(&sc, None).unwrap();
        assert_eq!(streamed.len(), 1);
        let mut dense = sc.clone();
        dense.engine_mode = EngineMode::Dense;
        let douts = run_scenario(&dense, None).unwrap();
        crate::testing::assert_same_run(
            &streamed[0].result,
            &douts[0].result,
            "isl-iridium-66 streamed vs dense",
        );
    }

    #[test]
    fn run_scenario_sweeps_whole_grid() {
        let sc = Scenario::builtin("paper-fig7").unwrap().scaled(Some(8), Some(48));
        let outs = run_scenario(&sc, None).unwrap();
        assert_eq!(outs.len(), sc.algorithms.len());
        for (out, &alg) in outs.iter().zip(&sc.algorithms) {
            assert_eq!(out.algorithm, alg);
            assert!(!out.result.trace.curve.points.is_empty(), "{alg:?}");
        }
    }

    #[test]
    fn config_path_runs_multi_gateway_federation() {
        use crate::fl::{FederationSpec, ReconcilePolicy};
        let mut cfg = tiny_cfg(AlgorithmKind::FedBuff);
        cfg.federation = FederationSpec::split(
            &["west", "east"],
            &[0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 1, 1],
            ReconcilePolicy::Periodic { every: 12 },
        );
        let out = run_mock_experiment(&cfg, None).unwrap();
        let t = &out.result.trace;
        assert_eq!(t.gateway_aggs.len(), 2);
        assert_eq!(t.gateway_aggs.iter().sum::<usize>(), out.result.final_round);
        assert_eq!(t.gateway_uploads.iter().sum::<usize>(), t.uploads);
        // streamed mode over the same config is bit-identical
        cfg.engine_mode = EngineMode::Streamed;
        let streamed = run_mock_experiment(&cfg, None).unwrap();
        crate::testing::assert_same_run(
            &out.result,
            &streamed.result,
            "multi-gateway config streamed",
        );
        // a station map that doesn't cover planet12 errors at routing build
        cfg.federation =
            FederationSpec::split(&["a", "b"], &[0, 1], ReconcilePolicy::Centralized);
        assert!(run_mock_experiment(&cfg, None).is_err());
        // and the narrow schedule-backed entry refuses multi-gateway configs
        cfg.engine_mode = EngineMode::Dense;
        cfg.federation = FederationSpec::split(
            &["west", "east"],
            &[0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 1, 1],
            ReconcilePolicy::Centralized,
        );
        let (_, sched) = build_schedule(&cfg);
        assert!(run_mock_on_schedule(&cfg, &sched, None).is_err());
    }

    #[test]
    fn config_path_enables_isls() {
        // ROADMAP item: `train --config` with an [isl] section relays
        use crate::cfg::{IslMode, IslSpec};
        let mut cfg = tiny_cfg(AlgorithmKind::FedBuff);
        cfg.isl = IslSpec {
            mode: IslMode::IntraCross,
            max_hops: 3,
            max_range_km: 4000.0,
            hop_delay_slots: 0,
        };
        cfg.validate().unwrap();
        let routed = run_mock_experiment(&cfg, None).unwrap();
        let mut off = cfg.clone();
        off.isl = IslSpec::default();
        let direct = run_mock_experiment(&off, None).unwrap();
        assert!(
            routed.result.trace.connections >= direct.result.trace.connections,
            "ISLs must never remove reach"
        );
        // streamed config path carries the topology inside the stream
        cfg.engine_mode = EngineMode::Streamed;
        let streamed = run_mock_experiment(&cfg, None).unwrap();
        crate::testing::assert_same_run(
            &routed.result,
            &streamed.result,
            "isl config streamed vs dense",
        );
    }

    #[test]
    fn config_path_carries_attack_and_robust() {
        use crate::fl::RobustKind;
        use crate::sim::{AttackKind, AttackSpec};
        let mut cfg = tiny_cfg(AlgorithmKind::FedBuff);
        cfg.attack = AttackSpec {
            kind: AttackKind::ScaledGrad,
            fraction: 0.25,
            scale: -20.0,
            ..Default::default()
        };
        cfg.robust.aggregator = RobustKind::TrimmedMean;
        cfg.robust.trim = 0.2;
        cfg.validate().unwrap();
        let dense = run_mock_experiment(&cfg, None).unwrap();
        assert!(dense.result.trace.injected > 0, "adversaries never uploaded");
        // the attacked, robustly-aggregated run keeps the tri-mode identity
        cfg.engine_mode = EngineMode::Streamed;
        let streamed = run_mock_experiment(&cfg, None).unwrap();
        crate::testing::assert_same_run(
            &dense.result,
            &streamed.result,
            "attacked config streamed vs dense",
        );
        // attack-free configs build no injector: counters stay zero
        let clean = run_mock_experiment(&tiny_cfg(AlgorithmKind::FedBuff), None).unwrap();
        let t = &clean.result.trace;
        assert_eq!((t.injected, t.dropped, t.corrupted), (0, 0, 0));
        // the PJRT path refuses robust aggregators (Pallas artifact only)
        assert!(run_pjrt_experiment(&cfg, 16, None).is_err());
    }

    #[test]
    fn config_path_carries_link() {
        use crate::fl::{CodecKind, LinkSpec};
        let mut cfg = tiny_cfg(AlgorithmKind::FedBuff);
        cfg.link =
            LinkSpec { rate_bytes_per_slot: 64, codec: CodecKind::TopK, topk_frac: 0.05 };
        cfg.validate().unwrap();
        // capacity on => the config path builds timed connectivity
        let (_, sched) = build_schedule(&cfg);
        assert!(sched.has_durations());
        let (_, stream) = build_stream(&cfg);
        assert!(stream.has_durations());
        let dense = run_mock_experiment(&cfg, None).unwrap();
        assert!(dense.result.trace.uploads > 0, "some passes must fit the budget");
        // the compressed, budgeted run keeps the tri-mode identity
        cfg.engine_mode = EngineMode::Streamed;
        let streamed = run_mock_experiment(&cfg, None).unwrap();
        crate::testing::assert_same_run(
            &dense.result,
            &streamed.result,
            "link config streamed vs dense",
        );
        // link-free configs track no durations and defer nothing
        let plain = run_mock_experiment(&tiny_cfg(AlgorithmKind::FedBuff), None).unwrap();
        assert_eq!(plain.result.trace.deferred, 0);
        assert!(!build_schedule(&tiny_cfg(AlgorithmKind::FedBuff)).1.has_durations());
    }

    #[test]
    fn loadgen_replay_is_deterministic_and_flushes() {
        // the serving replay: same scenario ⇒ same accepted-upload count,
        // same final round, identical deterministic event stream — only
        // the wall-clock fields may differ between the two runs
        let sc = Scenario::builtin("fedspace-multi-gs").unwrap().scaled(Some(10), Some(32));
        let a = run_loadgen(&sc, &LoadgenOpts::default()).unwrap();
        let b = run_loadgen(&sc, &LoadgenOpts::default()).unwrap();
        assert!(a.uploads > 0, "the trace must carry contacts");
        assert_eq!(a.uploads, b.uploads);
        assert_eq!(a.final_round, b.final_round);
        assert_eq!(a.rejected, 0);
        let det = |r: &LoadgenReport| -> Vec<crate::sim::RunEvent> {
            r.artifact.events.iter().filter(|e| e.is_deterministic()).cloned().collect()
        };
        assert_eq!(det(&a), det(&b), "deterministic serving streams diverged");
        assert_eq!(a.artifact.events[0].kind(), "run_start");
        assert!(a.artifact.events.iter().any(|e| e.kind() == "serve_report"));
        // every queue flushed: accepted == drained into the federation
        assert_eq!(a.artifact.trace.uploads as u64, a.uploads);
        // the artifact JSON carries the v1 schema the CI smoke pins
        let json = crate::sim::bundle_json(&[a.artifact]);
        assert!(json.contains("fedspace-run-artifact-v1"));
    }

    #[test]
    fn loadgen_backpressures_under_a_tiny_queue() {
        // a 2-deep queue in front of a 12-sat fleet must defer — and still
        // deliver every upload (flush drains to empty, nothing strands)
        let mut sc = Scenario::builtin("paper-fig7").unwrap().scaled(Some(12), Some(24));
        sc.algorithms = vec![AlgorithmKind::FedBuff];
        sc.serve = crate::fl::ServeSpec { queue_cap: 2, batch: 1, shards: 2 };
        let r = run_loadgen(&sc, &LoadgenOpts::default()).unwrap();
        assert!(r.deferred_offers > 0, "cap 2 must backpressure this fleet");
        assert_eq!(r.artifact.trace.uploads as u64, r.uploads, "deferred offers must land");
        assert!(r.ticks >= 24, "flush ticks extend the serving clock");
    }

    #[test]
    fn noniid_partition_built_from_overflights() {
        let cfg = ExperimentConfig {
            n_sats: 10,
            n_steps: 24,
            dist: DataDist::NonIid,
            n_train: 500,
            ..Default::default()
        };
        let dataset = Dataset::generate(SynthConfig {
            n_train: cfg.n_train,
            n_val: 16,
            seed: cfg.data_seed,
            ..Default::default()
        });
        let (constellation, _) = build_schedule(&cfg);
        let mut rng = Rng::new(0);
        let p = build_partition(&cfg, &dataset, &constellation, &mut rng);
        assert_eq!(p.n_sats(), 10);
        assert!(p.total() <= 500);
        assert!(p.total() > 0);
    }
}
