//! Minimal CLI argument parser (no `clap` in the offline vendor set).
//!
//! Grammar: `fedspace <command> [--key value | --key=value | --flag] ...`

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// The leading subcommand word.
    pub command: String,
    /// Bare words after the command, in order.
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse an argv iterator (without the program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self> {
        let mut it = argv.into_iter();
        let command = it.next().unwrap_or_default();
        let mut args = Args { command, ..Default::default() };
        let rest: Vec<String> = it.collect();
        let mut i = 0;
        while i < rest.len() {
            let tok = &rest[i];
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                    args.options.insert(name.to_string(), rest[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.push(name.to_string());
                }
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    /// Was `--name` given without a value?
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Value of option `--name`, if given.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Value of `--name` or a default.
    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Integer value of `--name` or a default; errors on non-integers.
    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} must be an integer")),
        }
    }

    /// Float value of `--name` or a default; errors on non-numbers.
    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} must be a number")),
        }
    }

    /// Value of `--name`, or an error naming the missing option.
    pub fn require(&self, name: &str) -> Result<&str> {
        match self.get(name) {
            Some(v) => Ok(v),
            None => bail!("missing required option --{name}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn parses_command_options_flags() {
        let a = parse("train pos1 --algorithm fedspace --steps=480 --mock");
        assert_eq!(a.command, "train");
        assert_eq!(a.get("algorithm"), Some("fedspace"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 480);
        assert!(a.has_flag("mock"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn bare_word_after_option_name_is_its_value() {
        // documented ambiguity: `--mock pos1` binds pos1 to --mock
        let a = parse("x --mock pos1");
        assert_eq!(a.get("mock"), Some("pos1"));
        assert!(!a.has_flag("mock"));
    }

    #[test]
    fn defaults_and_requires() {
        let a = parse("x --k v");
        assert_eq!(a.get_or("missing", "d"), "d");
        assert!(a.require("missing").is_err());
        assert_eq!(a.require("k").unwrap(), "v");
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse("x --n abc");
        assert!(a.get_usize("n", 1).is_err());
    }

    #[test]
    fn empty_command() {
        let a = Args::parse(Vec::<String>::new()).unwrap();
        assert_eq!(a.command, "");
    }
}
