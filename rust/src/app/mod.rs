//! High-level experiment orchestration shared by the CLI launcher, the
//! examples and the bench harness: config → constellation → connectivity →
//! dataset/partition → engine run.

pub mod args;
pub mod cmd;
pub mod runner;

pub use args::Args;
pub use runner::{
    build_partition, build_schedule, build_stream, build_upload_routing, build_utility_model,
    run_loadgen, run_mock_experiment, run_mock_on_schedule, run_mock_on_schedule_fed,
    run_mock_on_schedule_routed, run_mock_on_stream, run_mock_on_stream_fed, run_pjrt_experiment,
    run_scenario, ExperimentOutput, FederationRun, LoadgenOpts, LoadgenReport,
};
