//! IID and Non-IID dataset partitioning across satellites (paper §4.1).

use crate::data::synth::Dataset;
use crate::data::utm::{utm_cell, N_CELLS};
use crate::orbit::{subsatellite_point, Constellation};
use crate::rng::Rng;

/// Assignment of training-sample indices to satellites.
#[derive(Clone, Debug)]
pub struct Partition {
    /// per-satellite indices into `dataset.train`
    pub assignments: Vec<Vec<usize>>,
}

impl Partition {
    /// Number of satellites the partition covers.
    pub fn n_sats(&self) -> usize {
        self.assignments.len()
    }

    /// m_k per satellite.
    pub fn sizes(&self) -> Vec<usize> {
        self.assignments.iter().map(|a| a.len()).collect()
    }

    /// Total assigned samples m.
    pub fn total(&self) -> usize {
        self.assignments.iter().map(|a| a.len()).sum()
    }

    /// Label distribution skew: mean over satellites of the fraction of the
    /// satellite's samples in its single most frequent class. IID ≈ 1/62;
    /// the paper's Non-IID UTM assignment pushes this far higher.
    pub fn label_skew(&self, dataset: &Dataset) -> f64 {
        let mut total = 0.0;
        let mut counted = 0usize;
        for a in &self.assignments {
            if a.is_empty() {
                continue;
            }
            let mut counts = vec![0usize; dataset.cfg.num_classes];
            for &i in a {
                counts[dataset.train[i].class as usize] += 1;
            }
            total += *counts.iter().max().unwrap() as f64 / a.len() as f64;
            counted += 1;
        }
        if counted == 0 {
            0.0
        } else {
            total / counted as f64
        }
    }
}

/// IID: shuffle and split the train set uniformly across K satellites.
pub fn partition_iid(n_samples: usize, n_sats: usize, rng: &mut Rng) -> Partition {
    let mut idx: Vec<usize> = (0..n_samples).collect();
    rng.shuffle(&mut idx);
    let mut assignments = vec![Vec::new(); n_sats];
    for (j, i) in idx.into_iter().enumerate() {
        assignments[j % n_sats].push(i);
    }
    Partition { assignments }
}

/// UTM cells a satellite's subsatellite track crosses during the simulation
/// window, with multiplicity (one count per `sample_dt_s` of overflight).
pub fn cell_visits(
    constellation: &Constellation,
    horizon_s: f64,
    sample_dt_s: f64,
) -> Vec<Vec<usize>> {
    constellation
        .orbits
        .iter()
        .map(|orbit| {
            let n = (horizon_s / sample_dt_s) as usize;
            let mut counts = vec![0usize; N_CELLS];
            for s in 0..n {
                let (lat, lon) = subsatellite_point(orbit, s as f64 * sample_dt_s);
                counts[utm_cell(lat, lon)] += 1;
            }
            counts
        })
        .collect()
}

/// Non-IID (paper §4.1): partition samples by UTM cell; within each cell,
/// assign randomly across the satellites whose trajectory passes the cell
/// during the window, proportionally to their number of visits.
///
/// Satellites that overfly no sampled cell receive nothing (they idle in
/// the FL process — handled by the simulation engine). The latitude-band
/// dimension is what differentiates trajectories: ISS-inclination
/// satellites never visit polar bands while SSO satellites cross them every
/// orbit, which skews both labels and m_k exactly as the paper describes.
pub fn partition_noniid(
    dataset: &Dataset,
    visits: &[Vec<usize>],
    rng: &mut Rng,
) -> Partition {
    let n_sats = visits.len();
    let mut assignments = vec![Vec::new(); n_sats];
    // group train indices by cell
    let mut by_cell: Vec<Vec<usize>> = vec![Vec::new(); N_CELLS];
    for (i, s) in dataset.train.iter().enumerate() {
        by_cell[s.utm_cell()].push(i);
    }
    for (cell, samples) in by_cell.iter().enumerate() {
        if samples.is_empty() {
            continue;
        }
        let weights: Vec<f64> = visits.iter().map(|v| v[cell] as f64).collect();
        let total: f64 = weights.iter().sum();
        if total == 0.0 {
            // nobody overflies this cell in the window: its imagery is
            // never captured — drop it, as a real constellation would.
            continue;
        }
        for &i in samples {
            assignments[rng.choose_weighted(&weights)].push(i);
        }
    }
    Partition { assignments }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthConfig;
    use crate::orbit::planet_labs_like;

    fn dataset() -> Dataset {
        Dataset::generate(SynthConfig { n_train: 1000, n_val: 10, ..Default::default() })
    }

    #[test]
    fn iid_covers_all_samples_evenly() {
        let mut rng = Rng::new(0);
        let p = partition_iid(1000, 16, &mut rng);
        assert_eq!(p.total(), 1000);
        let sizes = p.sizes();
        assert!(sizes.iter().all(|&s| s == 62 || s == 63), "{sizes:?}");
        // no duplicates
        let mut all: Vec<usize> = p.assignments.concat();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 1000);
    }

    #[test]
    fn iid_label_skew_near_uniform() {
        let d = dataset();
        let mut rng = Rng::new(1);
        let p = partition_iid(d.train.len(), 10, &mut rng);
        let skew = p.label_skew(&d);
        assert!(skew < 0.10, "IID skew={skew}");
    }

    #[test]
    fn cell_visits_counts_positive() {
        let c = planet_labs_like(5, 0);
        let v = cell_visits(&c, 6.0 * 3600.0, 60.0);
        assert_eq!(v.len(), 5);
        for counts in &v {
            let total: usize = counts.iter().sum();
            assert_eq!(total, (6.0 * 3600.0 / 60.0) as usize);
        }
    }

    #[test]
    fn low_inclination_satellites_never_visit_polar_cells() {
        let c = planet_labs_like(30, 0);
        let v = cell_visits(&c, 12.0 * 3600.0, 60.0);
        for (orbit, counts) in c.orbits.iter().zip(v.iter()) {
            if orbit.inc.to_degrees() < 60.0 {
                // bands 17+ start at 56°N — out of reach at 51.6° inclination
                for zone in 0..60 {
                    for band in 18..crate::data::utm::N_BANDS {
                        assert_eq!(counts[zone * crate::data::utm::N_BANDS + band], 0);
                    }
                }
            }
        }
    }

    #[test]
    fn noniid_assigns_only_to_visitors() {
        let d = dataset();
        // 3 satellites with hand-crafted visits: sat 0 visits cells 0..600,
        // sat 1 cells 600..1200, sat 2 nothing.
        let mut visits = vec![vec![0usize; N_CELLS]; 3];
        for c in 0..600 {
            visits[0][c] = 5;
        }
        for c in 600..N_CELLS {
            visits[1][c] = 5;
        }
        let mut rng = Rng::new(2);
        let p = partition_noniid(&d, &visits, &mut rng);
        assert!(p.assignments[2].is_empty());
        for &i in &p.assignments[0] {
            assert!(d.train[i].utm_cell() < 600);
        }
        for &i in &p.assignments[1] {
            assert!(d.train[i].utm_cell() >= 600);
        }
    }

    #[test]
    fn noniid_more_skewed_than_iid() {
        let d = dataset();
        let c = planet_labs_like(30, 0);
        let v = cell_visits(&c, 24.0 * 3600.0, 120.0);
        let mut rng = Rng::new(3);
        let pn = partition_noniid(&d, &v, &mut rng);
        let pi = partition_iid(d.train.len(), 30, &mut rng);
        assert!(
            pn.label_skew(&d) > pi.label_skew(&d),
            "noniid={} iid={}",
            pn.label_skew(&d),
            pi.label_skew(&d)
        );
    }

    #[test]
    fn noniid_heterogeneous_sample_counts() {
        // the paper: Non-IID "incurs ... heterogeneity of number of samples"
        let d = dataset();
        let c = planet_labs_like(30, 0);
        let v = cell_visits(&c, 24.0 * 3600.0, 120.0);
        let mut rng = Rng::new(4);
        let p = partition_noniid(&d, &v, &mut rng);
        let sizes = p.sizes();
        let max = *sizes.iter().max().unwrap();
        let min = *sizes.iter().min().unwrap();
        assert!(max > min, "sizes unexpectedly uniform: {sizes:?}");
    }

    #[test]
    fn noniid_proportional_to_visits() {
        let d = dataset();
        // two sats both visit every cell, one 3x more often
        let mut visits = vec![vec![0usize; N_CELLS]; 2];
        for c in 0..N_CELLS {
            visits[0][c] = 1;
            visits[1][c] = 3;
        }
        let mut rng = Rng::new(4);
        let p = partition_noniid(&d, &visits, &mut rng);
        let (a, b) = (p.assignments[0].len() as f64, p.assignments[1].len() as f64);
        let ratio = b / a;
        assert!((2.0..4.5).contains(&ratio), "ratio={ratio}");
    }
}
