//! UTM zones/cells — the paper's Non-IID partition key (§4.1).
//!
//! fMoW metadata carries full UTM designators (longitude zone 1–60 plus the
//! 8° latitude band letter, e.g. "18N"); the paper partitions by that key.
//! We use the same 2-D cell: longitude zone × latitude band. The band
//! dimension is what makes the partition *trajectory-driven*: a 51.6°-
//! inclination (ISS-deployed) satellite never overflies polar bands, while
//! sun-synchronous satellites cover them every orbit.

/// Number of longitude zones.
pub const N_ZONES: usize = 60;
/// Number of 8° latitude bands (UTM bands C..X span −80°..+84°).
pub const N_BANDS: usize = 20;
/// Total partition cells.
pub const N_CELLS: usize = N_ZONES * N_BANDS;

/// UTM longitude zone (1..=60) for a longitude in degrees.
pub fn utm_zone(lon_deg: f64) -> usize {
    let lon = ((lon_deg + 180.0).rem_euclid(360.0)) - 180.0;
    let zone = ((lon + 180.0) / 6.0).floor() as usize + 1;
    zone.min(60)
}

/// Latitude band index (0..N_BANDS) for a latitude in degrees; latitudes
/// outside [−80, 84] are clamped into the edge bands like UTM's C/X.
pub fn utm_band(lat_deg: f64) -> usize {
    let lat = lat_deg.clamp(-80.0, 83.999);
    (((lat + 80.0) / 8.0).floor() as usize).min(N_BANDS - 1)
}

/// Flat cell id (0..N_CELLS) combining zone and band.
pub fn utm_cell(lat_deg: f64, lon_deg: f64) -> usize {
    (utm_zone(lon_deg) - 1) * N_BANDS + utm_band(lat_deg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zone_bounds() {
        assert_eq!(utm_zone(-180.0), 1);
        assert_eq!(utm_zone(-174.001), 1);
        assert_eq!(utm_zone(-174.0), 2);
        assert_eq!(utm_zone(0.0), 31);
        assert_eq!(utm_zone(179.999), 60);
    }

    #[test]
    fn wraps_out_of_range_longitudes() {
        assert_eq!(utm_zone(185.0), utm_zone(-175.0));
        assert_eq!(utm_zone(-190.0), utm_zone(170.0));
        assert_eq!(utm_zone(360.0), utm_zone(0.0));
    }

    #[test]
    fn all_zones_reachable() {
        let mut seen = vec![false; 61];
        for i in 0..360 {
            let lon = -180.0 + i as f64 + 0.5;
            seen[utm_zone(lon)] = true;
        }
        assert!(seen[1..=60].iter().all(|&s| s));
    }

    #[test]
    fn band_bounds() {
        assert_eq!(utm_band(-90.0), 0);
        assert_eq!(utm_band(-80.0), 0);
        assert_eq!(utm_band(-72.1), 0);
        assert_eq!(utm_band(-72.0), 1);
        assert_eq!(utm_band(0.0), 10);
        assert_eq!(utm_band(83.9), N_BANDS - 1);
        assert_eq!(utm_band(90.0), N_BANDS - 1);
    }

    #[test]
    fn cells_unique_per_zone_band() {
        let a = utm_cell(10.0, 0.0);
        let b = utm_cell(10.0, 7.0);
        let c = utm_cell(30.0, 0.0);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert!(a < N_CELLS && b < N_CELLS && c < N_CELLS);
    }

    #[test]
    fn polar_cells_unreachable_by_low_inclination() {
        // a satellite capped at |lat| <= 52 can never produce a band >= 17
        assert!(utm_band(52.0) < 17);
        assert!(utm_band(70.0) >= 17);
    }
}
